//! Export flow: from dataset to manufacturable bespoke Verilog.
//!
//! ```bash
//! cargo run --release --example rtl_export [-- <dataset> <out_dir>]
//! ```
//!
//! Produces, for the chosen dataset (default: vertebral):
//!   * `<out>/<ds>_exact.v`   — exact 8-bit bespoke design (behavioral +
//!     EGT-mapped structural netlist),
//!   * `<ds>_approx.v`        — best 1%-loss approximate design,
//!   * a summary of the area/power/delay deltas.
//!
//! The structural netlists instantiate the EGT cell names
//! (EGT_NAND2/EGT_NOR2/…), i.e. what a printed-PDK P&R flow would consume.

use axdt::coordinator::{optimize_dataset, EngineChoice, RunOptions};
use axdt::data::generators;
use axdt::dt::{train, TrainConfig};
use axdt::hw::synth::{self, TreeApprox};
use axdt::hw::{rtl, EgtLibrary};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let dataset = args.first().map(String::as_str).unwrap_or("vertebral").to_string();
    let out_dir = args.get(1).map(String::as_str).unwrap_or("results/rtl").to_string();
    std::fs::create_dir_all(&out_dir)?;

    let seed = 42;
    let spec = generators::spec(&dataset)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset {dataset}"))?;
    let lib = EgtLibrary::default();

    // Exact design.
    let data = generators::generate(spec, seed);
    let (train_d, _) = data.split(0.3, seed);
    let tree = train(&train_d, &TrainConfig { max_leaves: spec.max_leaves, min_samples_split: 2 });
    let exact = TreeApprox::exact(&tree);
    let exact_circuit = synth::synth_tree(&tree, &exact);
    let exact_rep = exact_circuit.netlist.report(&lib);
    let exact_path = format!("{out_dir}/{dataset}_exact.v");
    std::fs::write(&exact_path, rtl::export(&tree, &exact, &exact_circuit, &format!("{dataset}_exact")))?;

    // Approximate design from the co-design search.
    let opts = RunOptions {
        seed,
        pop_size: 32,
        generations: 20,
        margin_max: 5,
        engine: EngineChoice::Native,
        microbatch: 0,
    };
    let run = optimize_dataset(&dataset, &opts, None)?;
    let best = run
        .best_within_loss(0.01)
        .or_else(|| run.front.first())
        .ok_or_else(|| anyhow::anyhow!("empty front"))?;
    let approx_circuit = synth::synth_tree(&tree, &best.approx);
    let approx_path = format!("{out_dir}/{dataset}_approx.v");
    std::fs::write(
        &approx_path,
        rtl::export(&tree, &best.approx, &approx_circuit, &format!("{dataset}_approx")),
    )?;

    println!("wrote {exact_path} and {approx_path}\n");
    println!("{:<10} {:>11} {:>11} {:>11} {:>9}", "design", "area(mm^2)", "power(mW)", "delay(ms)", "accuracy");
    println!(
        "{:<10} {:>11.2} {:>11.3} {:>11.1} {:>9.3}",
        "exact", exact_rep.area_mm2, exact_rep.power_mw, exact_rep.delay_ms, run.baseline_accuracy
    );
    println!(
        "{:<10} {:>11.2} {:>11.3} {:>11.1} {:>9.3}",
        "approx",
        best.measured.area_mm2,
        best.measured.power_mw,
        best.measured.delay_ms,
        best.accuracy
    );
    println!(
        "\nsavings: {:.2}x area, {:.2}x power, accuracy {:+.3}",
        exact_rep.area_mm2 / best.measured.area_mm2,
        exact_rep.power_mw / best.measured.power_mw,
        best.accuracy - run.baseline_accuracy
    );

    // Per-comparator precision histogram of the chosen design.
    let mut hist = [0usize; 9];
    for &b in &best.approx.bits {
        hist[b as usize] += 1;
    }
    println!("\nprecision histogram of the approximate design:");
    for bits in 2..=8 {
        if hist[bits] > 0 {
            println!("  {bits}-bit: {:<3} {}", hist[bits], "#".repeat(hist[bits]));
        }
    }
    Ok(())
}
