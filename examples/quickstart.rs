//! Quickstart: the whole co-design loop on one small dataset, in seconds.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Trains an exact bespoke decision tree for the Seeds dataset, synthesizes
//! it against the printed-EGT library, runs a short NSGA-II search over
//! dual approximations (per-comparator precision + threshold substitution),
//! and prints the accuracy/area pareto front plus a snippet of the bespoke
//! RTL for the best 1%-loss design.

use axdt::coordinator::{optimize_dataset, EngineChoice, RunOptions};
use axdt::data::generators;
use axdt::hw::{rtl, synth};

fn main() -> anyhow::Result<()> {
    // 1. Full pipeline: generate → train → synthesize baseline → optimize.
    let opts = RunOptions {
        seed: 42,
        pop_size: 32,
        generations: 20,
        margin_max: 5,
        engine: EngineChoice::Native, // no artifacts needed for quickstart
        microbatch: 0,
    };
    let run = optimize_dataset("seeds", &opts, None)?;

    println!("== exact bespoke baseline (Seeds) ==");
    println!(
        "accuracy {:.3} | {} comparators | {:.2} mm^2 | {:.2} mW | {:.1} ms",
        run.baseline_accuracy,
        run.n_comparators,
        run.baseline.area_mm2,
        run.baseline.power_mw,
        run.baseline.delay_ms
    );

    println!("\n== approximate pareto front ==");
    println!("{:>9} {:>11} {:>11} {:>10}", "accuracy", "area(mm^2)", "power(mW)", "norm.area");
    for p in &run.front {
        println!(
            "{:>9.4} {:>11.2} {:>11.3} {:>10.3}",
            p.accuracy,
            p.measured.area_mm2,
            p.measured.power_mw,
            p.measured.area_mm2 / run.baseline.area_mm2
        );
    }

    // 2. Pick the best design within 1% accuracy loss and emit its RTL.
    if let Some(best) = run.best_within_loss(0.01) {
        println!(
            "\n== best within 1% loss: {:.3} accuracy at {:.2} mm^2 ({:.2}x smaller) ==",
            best.accuracy,
            best.measured.area_mm2,
            run.baseline.area_mm2 / best.measured.area_mm2
        );
        let spec = generators::spec("seeds").unwrap();
        let data = generators::generate(spec, opts.seed);
        let (train_d, _) = data.split(0.3, opts.seed);
        let tree = axdt::dt::train(
            &train_d,
            &axdt::dt::TrainConfig { max_leaves: spec.max_leaves, min_samples_split: 2 },
        );
        let verilog = rtl::tree_verilog(&tree, &best.approx, "seeds_approx_dt");
        let head: String = verilog.lines().take(14).collect::<Vec<_>>().join("\n");
        println!("\n-- bespoke RTL (first lines) --\n{head}\n...");
        let circuit = synth::synth_tree(&tree, &best.approx);
        println!(
            "gate-level: {} EGT cells after synthesis",
            circuit.netlist.cell_counts().values().sum::<usize>()
        );
    } else {
        println!("\n(no design within 1% loss at this tiny GA budget — rerun with more generations)");
    }
    Ok(())
}
