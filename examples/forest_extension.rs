//! Extension beyond the paper: approximate bespoke **random forests**.
//!
//! ```bash
//! cargo run --release --example forest_extension [-- <dataset> <n_trees>]
//! ```
//!
//! The paper's intro motivates DT/RF/SVM as the printed-ML family but
//! evaluates single trees.  This example lifts the dual-approximation
//! machinery to bagging ensembles: the chromosome concatenates every
//! member tree's (precision, margin) genes, fitness evaluates the voted
//! ensemble, and the bespoke circuit is K member netlists sharing feature
//! buses plus a printed popcount/argmax vote stage (`hw::vote`).

use axdt::data::generators;
use axdt::dt::forest::{train_forest, Forest, ForestConfig};
use axdt::ga::{run_nsga2, Chromosome, DecodeContext, Evaluator, NsgaConfig};
use axdt::hw::synth::FEATURE_BITS;
use axdt::hw::{vote, AreaLut, EgtLibrary};
use axdt::quant;

/// Forest fitness: (1 − voted accuracy, Σ member LUT areas).
struct ForestEval<'a> {
    forest: &'a Forest,
    thresholds: Vec<f32>,
    lut: &'a AreaLut,
    codes: Vec<u32>,
    labels: Vec<u32>,
    n_features: usize,
}

impl<'a> Evaluator for ForestEval<'a> {
    fn evaluate(&mut self, pop: &[Chromosome]) -> Vec<[f64; 2]> {
        let ctx = DecodeContext { thresholds: &self.thresholds, lut: self.lut, margin_max: 5 };
        pop.iter()
            .map(|c| {
                let approx = c.decode(&ctx);
                let parts = self.forest.split_approx(&approx);
                let n = self.labels.len();
                let mut correct = 0usize;
                for s in 0..n {
                    let codes = &self.codes[s * self.n_features..(s + 1) * self.n_features];
                    if self.forest.predict_codes(&parts, codes) == self.labels[s] {
                        correct += 1;
                    }
                }
                let acc = correct as f64 / n as f64;
                let area: f64 = approx
                    .bits
                    .iter()
                    .zip(&approx.thr_int)
                    .map(|(&b, &t)| self.lut.area(b, t))
                    .sum();
                [1.0 - acc, area]
            })
            .collect()
    }
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let dataset = args.first().map(String::as_str).unwrap_or("cardio");
    let n_trees: usize = args.get(1).and_then(|a| a.parse().ok()).unwrap_or(5);

    let spec = generators::spec(dataset).ok_or_else(|| anyhow::anyhow!("unknown dataset"))?;
    let lib = EgtLibrary::default();
    let lut = AreaLut::build(&lib);
    let data = generators::generate(spec, 42);
    let (train_d, test_d) = data.split(0.3, 42);

    // Ensemble of shallow trees vs the paper's single deep tree.
    let forest = train_forest(
        &train_d,
        &ForestConfig {
            n_trees,
            max_leaves: (spec.max_leaves / n_trees).max(8),
            sample_frac: 1.0,
            seed: 42,
        },
    );
    let single = axdt::dt::train(
        &train_d,
        &axdt::dt::TrainConfig { max_leaves: spec.max_leaves, min_samples_split: 2 },
    );
    let acc_forest = forest.accuracy(&test_d.x, &test_d.y, test_d.n_features);
    let acc_single = single.accuracy(&test_d.x, &test_d.y, test_d.n_features);

    // Exact bespoke forest circuit.
    let exact_parts = forest.split_approx(&forest.exact_approx());
    let exact_circ = vote::synth_forest(&forest, &exact_parts);
    let exact_rep = exact_circ.netlist.report(&lib);
    println!(
        "== {dataset}: {n_trees}-tree bespoke forest vs single tree ==\n\
         single tree : acc {acc_single:.3}, {} comparators\n\
         exact forest: acc {acc_forest:.3}, {} comparators, {:.2} mm^2, {:.2} mW, {:.1} ms",
        single.n_comparators(),
        forest.n_comparators(),
        exact_rep.area_mm2,
        exact_rep.power_mw,
        exact_rep.delay_ms
    );

    // Approximate the ensemble.
    let codes: Vec<u32> = test_d.x.iter().map(|&x| quant::code(x, FEATURE_BITS)).collect();
    let mut eval = ForestEval {
        forest: &forest,
        thresholds: forest.thresholds(),
        lut: &lut,
        codes,
        labels: test_d.y.clone(),
        n_features: test_d.n_features,
    };
    let cfg = NsgaConfig { pop_size: 32, generations: 15, seed: 42, ..Default::default() };
    let res = run_nsga2(forest.n_comparators(), &cfg, &mut eval);

    println!("\n== approximate forest pareto front (synthesized) ==");
    println!("{:>9} {:>11} {:>11} {:>10}", "accuracy", "area(mm^2)", "power(mW)", "vs exact");
    let ctx = DecodeContext { thresholds: &eval.thresholds, lut: &lut, margin_max: 5 };
    for s in res.pareto_front().iter().take(8) {
        let approx = s.chromosome.decode(&ctx);
        let parts = forest.split_approx(&approx);
        let rep = vote::synth_forest(&forest, &parts).netlist.report(&lib);
        println!(
            "{:>9.4} {:>11.2} {:>11.3} {:>9.2}x",
            1.0 - s.objectives[0],
            rep.area_mm2,
            rep.power_mw,
            exact_rep.area_mm2 / rep.area_mm2
        );
    }
    println!(
        "\nvote-stage overhead is fixed ({} classes x {} trees); member trees shrink under approximation.",
        forest.n_classes, n_trees
    );
    Ok(())
}
