//! Deployment scenario from the paper's intro: which classifiers can a
//! *printed battery* (Blue Spark, <3 mW) or an *energy harvester*
//! (<0.1 mW) actually power?
//!
//! ```bash
//! cargo run --release --example battery_fit [-- <mW budget>]
//! ```
//!
//! For every dataset this searches the approximation space and reports the
//! most accurate design that fits the budget — the question a smart-
//! packaging/FMCG integrator would ask of this framework.

use axdt::coordinator::{optimize_dataset, EngineChoice, RunOptions};
use axdt::report::{BATTERY_MW, HARVESTER_MW};

fn main() -> anyhow::Result<()> {
    let budget_mw: f64 = std::env::args()
        .nth(1)
        .and_then(|a| a.parse().ok())
        .unwrap_or(BATTERY_MW);
    let opts = RunOptions {
        seed: 42,
        pop_size: 32,
        generations: 15,
        margin_max: 5,
        engine: EngineChoice::Native,
        microbatch: 0,
    };

    println!("power budget: {budget_mw} mW  (battery {BATTERY_MW} mW, harvester {HARVESTER_MW} mW)\n");
    println!(
        "{:<13} {:>9} {:>10} {:>11} {:>11} {:>9} {:>13}",
        "dataset", "base acc", "base mW", "fit acc", "fit mW", "fit mm^2", "acc sacrifice"
    );

    for id in axdt::data::generators::all_ids() {
        let run = optimize_dataset(id, &opts, None)?;
        // Most accurate front design within the power budget.
        let fit = run
            .front
            .iter()
            .filter(|p| p.measured.power_mw <= budget_mw)
            .max_by(|a, b| a.accuracy.partial_cmp(&b.accuracy).unwrap());
        match fit {
            Some(p) => println!(
                "{:<13} {:>9.3} {:>10.2} {:>11.3} {:>11.3} {:>9.2} {:>+13.3}",
                id,
                run.baseline_accuracy,
                run.baseline.power_mw,
                p.accuracy,
                p.measured.power_mw,
                p.measured.area_mm2,
                p.accuracy - run.baseline_accuracy,
            ),
            None => println!(
                "{:<13} {:>9.3} {:>10.2}   -- infeasible at this budget/GA budget --",
                id, run.baseline_accuracy, run.baseline.power_mw
            ),
        }
    }
    println!("\n(baselines from Table I; fits found by the NSGA-II co-design search)");
    Ok(())
}
