//! END-TO-END DRIVER: full reproduction of the paper's evaluation on all
//! ten datasets through the production (XLA) path.
//!
//! ```bash
//! make artifacts && cargo run --release --example paper_repro
//! # smaller budget:
//! AXDT_REPRO_POP=24 AXDT_REPRO_GENS=10 cargo run --release --example paper_repro
//! ```
//!
//! This is the "all layers compose" proof: the trained trees' population
//! fitness is evaluated by the AOT-compiled Pallas/JAX artifact through the
//! PJRT runtime behind the coordinator's routing/batching service — Python
//! never runs.  Produces Table I, Fig. 4, all ten Fig. 5 fronts, Table II,
//! the per-dataset vs-paper comparison, and writes
//! `results/paper_repro.json`.  The numbers are recorded in EXPERIMENTS.md.

use std::io::Write as _;

use axdt::coordinator::{optimize_dataset, EngineChoice, EvalService, RunOptions};
use axdt::data::generators;
use axdt::report;
use axdt::util::stats::geomean;

fn env_usize(k: &str, d: usize) -> usize {
    std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
}

fn main() -> anyhow::Result<()> {
    let pop = env_usize("AXDT_REPRO_POP", 48);
    let gens = env_usize("AXDT_REPRO_GENS", 30);
    let seed = env_usize("AXDT_REPRO_SEED", 42) as u64;
    let t_start = std::time::Instant::now();

    // ---- Table I -------------------------------------------------------
    let datasets: Vec<String> = generators::all_ids().iter().map(|s| s.to_string()).collect();
    let (t1, _) = report::table1(&datasets, seed)?;
    println!("{t1}");

    // ---- Fig. 4 ----------------------------------------------------------
    let (f4, _, _) = report::fig4();
    println!("{f4}");

    // ---- Fig. 5 over the XLA engine --------------------------------------
    let service = EvalService::spawn_xla("artifacts")
        .map_err(|e| anyhow::anyhow!("{e}; run `make artifacts` first"))?;
    let opts = RunOptions {
        seed,
        pop_size: pop,
        generations: gens,
        margin_max: 5,
        engine: EngineChoice::Xla,
        microbatch: 0,
    };
    let mut runs = Vec::new();
    for d in &datasets {
        eprintln!("[paper_repro] optimizing {d} (pop {pop} x {gens} gens, XLA engine)…");
        let run = optimize_dataset(d, &opts, Some(&service))?;
        eprintln!(
            "[paper_repro]   {d}: {} front points, gain@1% {:.2}x, gain@2% {:.2}x, {:.1}s ({:.0} evals/s)",
            run.front.len(),
            run.area_gain(0.01).unwrap_or(f64::NAN),
            run.area_gain(0.02).unwrap_or(f64::NAN),
            run.elapsed_s,
            run.evaluations as f64 / run.elapsed_s,
        );
        runs.push(run);
    }
    for r in &runs {
        println!("{}", report::render_fig5(r));
    }

    // ---- Table II ---------------------------------------------------------
    println!("{}", report::table2(&runs, 0.01));
    println!("{}", report::table2(&runs, 0.02));

    // ---- headline comparison -----------------------------------------------
    let gains_1: Vec<f64> = runs.iter().filter_map(|r| r.area_gain(0.01)).collect();
    let power_gains_1: Vec<f64> = runs
        .iter()
        .filter_map(|r| {
            r.best_within_loss(0.01)
                .map(|p| r.baseline.power_mw / p.measured.power_mw)
        })
        .collect();
    println!(
        "headline: geo-mean area gain @1% loss = {:.2}x (paper 3.2x), power gain = {:.2}x (paper 3.4x)",
        geomean(&gains_1),
        geomean(&power_gains_1)
    );
    println!("eval service: {}", service.metrics.render());
    println!("total wall-clock: {:.1}s", t_start.elapsed().as_secs_f64());

    // ---- archive ------------------------------------------------------------
    std::fs::create_dir_all("results")?;
    let mut f = std::fs::File::create("results/paper_repro.json")?;
    let archive = report::RunArchive {
        runs: &runs,
        service: Some(service.metrics.histograms_json()),
    };
    writeln!(f, "{}", archive.to_json())?;
    eprintln!("[paper_repro] wrote results/paper_repro.json");
    service.shutdown();
    Ok(())
}
