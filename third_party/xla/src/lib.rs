//! Offline stub of the `xla` PJRT binding.
//!
//! The real crate (PJRT C-API bindings + XLA runtime) is not available in
//! this image's offline registry, but the `axdt` backend code that uses it
//! should still *type-check* under `--features xla` so the boundary does
//! not rot.  This stub mirrors exactly the API subset `axdt` touches (see
//! `rust/src/runtime/mod.rs` and `rust/src/bin/probe_artifact.rs`):
//!
//! * pure constructors ([`Literal::vec1`], [`XlaComputation::from_proto`],
//!   [`Literal::reshape`]) succeed and carry no data;
//! * every entry point that would reach PJRT ([`PjRtClient::cpu`],
//!   `compile`, `execute*`, buffer transfers, HLO parsing) returns
//!   [`Error`] with an "unvendored" message.
//!
//! Replacing this crate with a real binding is tracked in ROADMAP.md; the
//! swap is a one-line change in the workspace manifest (point the `xla`
//! path/version somewhere real).

use std::fmt;

/// Error type matching the `xla::Error` surface `axdt` maps into `anyhow`.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// Result alias used by all stub entry points.
pub type Result<T> = std::result::Result<T, Error>;

fn unvendored(what: &str) -> Error {
    Error(format!(
        "{what}: the `xla`/PJRT binding is not vendored in this build \
         (this is the in-tree stub at third_party/xla); \
         use `--engine native` or `--engine native-service` instead"
    ))
}

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient(());

/// Device-resident buffer handle.
pub struct PjRtBuffer(());

/// Compiled executable handle.
pub struct PjRtLoadedExecutable(());

/// Parsed HLO module proto.
pub struct HloModuleProto(());

/// XLA computation wrapper.
pub struct XlaComputation(());

/// Host-side literal (stub: shape-less placeholder).
#[derive(Clone)]
pub struct Literal(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unvendored("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unvendored("PjRtClient::compile"))
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(unvendored("PjRtClient::buffer_from_host_buffer"))
    }
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unvendored("HloModuleProto::from_text_file"))
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unvendored("PjRtLoadedExecutable::execute"))
    }

    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unvendored("PjRtLoadedExecutable::execute_b"))
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unvendored("PjRtBuffer::to_literal_sync"))
    }
}

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(self.clone())
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        Ok(self.clone())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unvendored("Literal::to_vec"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pure_constructors_succeed() {
        let lit = Literal::vec1(&[1.0, 2.0]);
        assert!(lit.reshape(&[2, 1]).is_ok());
        assert!(lit.to_tuple1().is_ok());
    }

    #[test]
    fn runtime_entry_points_report_unvendored() {
        let err = match PjRtClient::cpu() {
            Err(e) => e,
            Ok(_) => panic!("stub must not construct a client"),
        };
        let msg = format!("{err}");
        assert!(msg.contains("not vendored"), "{msg}");
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        assert!(Literal::vec1(&[0.0]).to_vec::<f32>().is_err());
    }
}
