#!/usr/bin/env bash
# CI guard: production code goes through the two-phase submit/wait seam.
#
# The blocking `eval` is `wait(submit(..))` and lives in exactly two
# places: `rust/src/coordinator/shard.rs` (the pool, where the adapter is
# defined) and `rust/src/coordinator/service.rs` (the facade passthrough
# and the `XlaEngine` collect-side heal retry).  Any OTHER file under
# rust/src calling a blocking pool/service eval is a regression off the
# async seam and fails this check.
#
# Scope:
#   * flags `pool.eval(`, `pool().eval(`, `svc.eval(`, `service.eval(`
#     and `.eval_typed(` receivers — NOT `Netlist::eval` etc., whose
#     receivers (`nl`, `opt`, `netlist`) never match;
#   * rust/tests/ and rust/benches/ are exempt: blocking baselines there
#     are the comparison the pipelined path is measured against.
#
# Exit 0 = clean, 1 = violations found.
set -u

ROOT="$(cd "$(dirname "$0")/.." && pwd)"
status=0

while IFS= read -r line; do
    file="${line%%:*}"
    case "$file" in
        */coordinator/shard.rs | */coordinator/service.rs) continue ;;
    esac
    code="${line#*:*:}"
    # Comment lines may talk about blocking eval; only code counts.
    trimmed="${code#"${code%%[![:space:]]*}"}"
    if [[ "$trimmed" == //* ]]; then
        continue
    fi
    echo "FORBIDDEN (blocking eval outside the adapter): $line"
    status=1
done < <(grep -rnE '(pool\(\)|pool|svc|service)\.eval\(|\.eval_typed\(' \
    "$ROOT/rust/src" --include='*.rs')

if ((status == 0)); then
    echo "OK: blocking pool/service eval call sites are confined to the adapter"
fi
exit $status
