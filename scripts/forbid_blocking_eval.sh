#!/usr/bin/env bash
# CI guard: production code goes through the two-phase submit/wait seam.
#
# Thin wrapper over the real implementation — `axdt-lint`'s `ticket-seam`
# rule (tools/axdt-lint), which lexes the sources so strings, comments and
# `#[cfg(test)]` regions can never false-positive, and which supports
# justified `// axdt-lint: allow(ticket-seam): <why>` suppressions.
#
# Exit 0 = clean, 1 = violations found.
set -u

cd "$(dirname "$0")/.."
exec cargo run -q -p axdt-lint -- --rule ticket-seam
