#!/usr/bin/env bash
# CI guard: integration tests must not gate correctness on wall-clock
# sleeps — the timing surface runs on the injectable Clock
# (`util::clock::ManualClock`), and any `thread::sleep` in rust/tests/
# beyond 100 ms (or with a non-literal duration) is a regression toward
# the flaky pre-Clock world.
#
# Thin wrapper over the real implementation — `axdt-lint`'s
# `no-sleep-in-tests` rule (tools/axdt-lint), which audits the literal
# `Duration::from_*` argument at the token level.
#
# Exit 0 = clean, 1 = violations found.
set -u

cd "$(dirname "$0")/.."
exec cargo run -q -p axdt-lint -- --rule no-sleep-in-tests
