#!/usr/bin/env bash
# CI guard: integration tests must not gate correctness on wall-clock
# sleeps.  The timing surface runs on the injectable Clock
# (`util::clock::ManualClock`), so any `thread::sleep` longer than 100 ms
# in rust/tests/ is a regression toward the flaky pre-Clock world.
#
# Flags, in any file under rust/tests/:
#   * thread::sleep(Duration::from_millis(N)) with N > 100
#   * thread::sleep(Duration::from_secs*/from_micros(N) beyond the same
#     100 ms budget
#   * thread::sleep with a non-literal duration (cannot be audited)
#
# Exit 0 = clean, 1 = violations found.
set -u

ROOT="$(cd "$(dirname "$0")/.." && pwd)"
TESTS_DIR="$ROOT/rust/tests"
LIMIT_MS=100
status=0

while IFS= read -r line; do
    file="${line%%:*}"
    rest="${line#*:}"
    lineno="${rest%%:*}"
    code="${rest#*:}"

    # Comment lines (//, //!, ///) may talk about sleeping; only code sleeps.
    trimmed="${code#"${code%%[![:space:]]*}"}"
    if [[ "$trimmed" == //* ]]; then
        continue
    fi

    ms=""
    if [[ "$code" =~ from_millis\(([0-9_]+)\) ]]; then
        ms=$(( ${BASH_REMATCH[1]//_/} ))
    elif [[ "$code" =~ from_secs\(([0-9_]+)\) ]]; then
        ms=$(( ${BASH_REMATCH[1]//_/} * 1000 ))
    elif [[ "$code" =~ from_secs_f(32|64)\(([0-9.]+)\) ]]; then
        # Round up: any fractional-second sleep is at least its integer ms.
        ms=$(awk -v s="${BASH_REMATCH[2]}" 'BEGIN { printf "%d", s * 1000 }')
    elif [[ "$code" =~ from_micros\(([0-9_]+)\) ]]; then
        ms=$(( ${BASH_REMATCH[1]//_/} / 1000 ))
    elif [[ "$code" =~ from_nanos\(([0-9_]+)\) ]]; then
        ms=$(( ${BASH_REMATCH[1]//_/} / 1000000 ))
    fi

    if [[ -z "$ms" ]]; then
        echo "FORBIDDEN (unauditable sleep duration): $file:$lineno: $code"
        status=1
    elif (( ms > LIMIT_MS )); then
        echo "FORBIDDEN (sleep ${ms} ms > ${LIMIT_MS} ms): $file:$lineno: $code"
        status=1
    fi
done < <(grep -rn "thread::sleep" "$TESTS_DIR" --include='*.rs')

if (( status == 0 )); then
    echo "OK: no test under rust/tests sleeps longer than ${LIMIT_MS} ms"
fi
exit $status
