# Convenience targets. The default rust build needs none of these — see
# README.md for the build matrix.

.PHONY: artifacts test bench clean

# Lower the L2 accuracy-evaluation graph to HLO text artifacts consumed by
# the XLA backend (`--features xla`). Requires jax in the python env.
artifacts:
	cd python && python3 -m compile.aot --out-dir ../artifacts

test:
	cargo build --release && cargo test -q

bench:
	cargo bench

clean:
	cargo clean
	rm -rf artifacts results
