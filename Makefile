# Convenience targets. The default rust build needs none of these — see
# README.md for the build matrix.

.PHONY: artifacts test bench lint tsan clean

# Lower the L2 accuracy-evaluation graph to HLO text artifacts consumed by
# the XLA backend (`--features xla`). Requires jax in the python env.
artifacts:
	cd python && python3 -m compile.aot --out-dir ../artifacts

test:
	cargo build --release && cargo test -q

bench:
	cargo bench

# Architectural lints (tools/axdt-lint): Clock seam, Ticket seam,
# panic-free workers, mutex discipline, test-sleep budget, plus the
# dataflow rules (lock-order, ticket-leak, trace-ordering, clock-taint).
# `--format sarif` emits SARIF 2.1.0. See "Static analysis" in README.md.
lint:
	cargo run -q -p axdt-lint
	cargo test -q -p axdt-lint

# ThreadSanitizer over the five concurrency suites (needs a nightly
# toolchain with the rust-src component; mirrors .github/workflows/tsan.yml).
tsan:
	RUSTFLAGS="-Zsanitizer=thread" AXDT_THREADS=2 \
	cargo +nightly test -Zbuild-std --target x86_64-unknown-linux-gnu \
		--test shard_pool --test failover --test adaptive_coalesce --test async_eval \
		--test cache

clean:
	cargo clean
	rm -rf artifacts results
