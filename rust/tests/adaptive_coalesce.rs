//! Adaptive-coalescing contracts, driven entirely on a `ManualClock`:
//! zero wall-clock sleeps, every timing assertion is exact because
//! virtual time only moves when the test advances it.
//!
//! * the per-problem EWMA of request inter-arrival times converges under
//!   scripted arrival schedules, bit-exactly against a reference
//!   computed from the exported `ADAPTIVE_*` constants;
//! * the controller's window clamps at both bounds (the configured max
//!   before any estimate / under huge gaps, zero under same-instant
//!   arrivals);
//! * the all-drivers early flush fires the moment every registered
//!   driver of a problem has work queued — with no clock advance at all;
//! * an armed adaptive deadline sits exactly at `IA_MULT x EWMA` past
//!   the arrival: nothing flushes one nanosecond early;
//! * adaptive-mode results are bit-identical to fixed-window mode and to
//!   the direct native engine (merging changes batching, never
//!   arithmetic).

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use axdt::coordinator::shard::{ADAPTIVE_EWMA_ALPHA, ADAPTIVE_WINDOW_IA_MULT};
use axdt::coordinator::{CoalesceMode, EvalService, PoolOptions};
use axdt::fitness::native::NativeEngine;
use axdt::fitness::AccuracyEngine;
use axdt::util::clock::ManualClock;
use axdt::util::testbed::{named_problem, random_batch, wait_until};

fn adaptive_opts(max_us: u64) -> PoolOptions {
    PoolOptions {
        workers: 1,
        coalesce: CoalesceMode::Adaptive,
        coalesce_window_max_us: max_us,
        engine_threads: 1,
        ..PoolOptions::default()
    }
}

/// Reference EWMA, computed exactly like the worker does (same f64 ops in
/// the same order, so comparisons are bit-exact).
fn ewma_ref(samples_ns: &[u64]) -> f64 {
    let mut e: Option<f64> = None;
    for &s in samples_ns {
        e = Some(match e {
            None => s as f64,
            Some(prev) => ADAPTIVE_EWMA_ALPHA * s as f64 + (1.0 - ADAPTIVE_EWMA_ALPHA) * prev,
        });
    }
    e.expect("at least one sample")
}

fn window_ref(ewma: f64, max_us: u64) -> u64 {
    ((ADAPTIVE_WINDOW_IA_MULT * ewma) as u64).min(max_us * 1_000)
}

/// Scripted arrival schedule: steady arrivals converge the EWMA to the
/// gap; a late burst of slower arrivals pulls it up by exactly the
/// reference recurrence.  The per-shard gauges expose the controller
/// state after every arrival.
#[test]
fn ewma_converges_under_scripted_arrivals() {
    const MAX_US: u64 = 100_000; // 100 ms cap, never the binding constraint here
    const T: u64 = 10_000_000; // 10 ms steady gap, in ns

    let clock = Arc::new(ManualClock::new());
    let svc = EvalService::spawn_native_with_clock(64, &adaptive_opts(MAX_US), Arc::clone(&clock));
    let p = named_problem("seeds");
    let (id, _) = svc.register(Arc::clone(&p)).unwrap();
    let gauges = || {
        let s = &svc.metrics.shards()[0];
        (s.window_ns.load(Ordering::Relaxed), s.ewma_ia_ns.load(Ordering::Relaxed))
    };

    // One registered driver whose request is queued = all drivers queued:
    // each eval early-flushes immediately, so these calls are synchronous
    // script steps.  First arrival: no estimate yet, window = the cap.
    assert_eq!(svc.eval(id, random_batch(&p, 3, 1)).unwrap().len(), 3);
    assert_eq!(gauges(), (MAX_US * 1_000, 0), "no EWMA before two arrivals");

    // Steady arrivals every T: the first sample sets the estimate to T
    // and identical samples keep it there; the window tracks 2T.
    let mut samples = Vec::new();
    for round in 0..4u64 {
        clock.advance(Duration::from_nanos(T));
        samples.push(T);
        assert_eq!(svc.eval(id, random_batch(&p, 3, 10 + round)).unwrap().len(), 3);
        let want_ewma = ewma_ref(&samples);
        assert_eq!(
            gauges(),
            (window_ref(want_ewma, MAX_US), want_ewma as u64),
            "round {round}"
        );
    }
    assert_eq!(gauges().1, T, "identical samples converge exactly to the gap");

    // A slower phase (4T gaps) pulls the estimate up by the published
    // recurrence — never instantly, never past the cap.
    for round in 0..3u64 {
        clock.advance(Duration::from_nanos(4 * T));
        samples.push(4 * T);
        assert_eq!(svc.eval(id, random_batch(&p, 3, 20 + round)).unwrap().len(), 3);
        let want_ewma = ewma_ref(&samples);
        assert_eq!(
            gauges(),
            (window_ref(want_ewma, MAX_US), want_ewma as u64),
            "slow round {round}"
        );
    }
    let (_, final_ewma) = gauges();
    assert!(
        final_ewma > T && final_ewma < 4 * T,
        "EWMA moves toward the new rate without jumping: {final_ewma}"
    );

    // The operator-facing render shows what the controller chose.
    let render = svc.metrics.render();
    assert!(render.contains("win=") && render.contains("ia="), "{render}");
    assert!(render.contains("early "), "{render}");
    svc.shutdown();
}

/// Clamp behavior at both bounds: the cap before any estimate and under
/// huge inter-arrival gaps; zero under same-instant arrivals.
#[test]
fn window_clamps_at_both_bounds() {
    const MAX_US: u64 = 500; // 0.5 ms cap in us
    let clock = Arc::new(ManualClock::new());
    let svc = EvalService::spawn_native_with_clock(64, &adaptive_opts(MAX_US), Arc::clone(&clock));
    let p = named_problem("seeds");
    let (id, _) = svc.register(Arc::clone(&p)).unwrap();
    let window = || svc.metrics.shards()[0].window_ns.load(Ordering::Relaxed);

    // Upper clamp, no estimate: the cap.
    svc.eval(id, random_batch(&p, 2, 1)).unwrap();
    assert_eq!(window(), MAX_US * 1_000);

    // Upper clamp, huge gap: the unclamped window (2 x EWMA) would be
    // 20x the cap; the armed window is the cap.
    clock.advance(Duration::from_micros(MAX_US * 10));
    svc.eval(id, random_batch(&p, 2, 2)).unwrap();
    assert_eq!(window(), MAX_US * 1_000);
    assert!(svc.metrics.shards()[0].ewma_ia_ns.load(Ordering::Relaxed) > MAX_US * 1_000);

    // Lower clamp: same-instant arrivals drive the samples — and with
    // them the window — to zero.  (ALPHA < 1, so a few rounds are needed
    // for the estimate itself to underflow u64 granularity; the window
    // hits the floor as soon as `2 x EWMA < 1 ns`.)
    for round in 0..64u64 {
        svc.eval(id, random_batch(&p, 2, 10 + round)).unwrap();
    }
    assert_eq!(window(), 0, "same-instant arrivals clamp the window to zero");
    svc.shutdown();
}

/// The all-drivers early flush: two drivers register the same problem
/// (driver counts flow through `register`), each queues a sub-width
/// batch, and the worker merges them into ONE execution the moment the
/// second batch arrives — the virtual clock never moves, so no window
/// ever expired.
#[test]
fn early_flush_when_all_registered_drivers_have_work_queued() {
    let clock = Arc::new(ManualClock::new());
    // A cap of a full virtual second: only the early flush can dispatch.
    let svc =
        EvalService::spawn_native_with_clock(64, &adaptive_opts(1_000_000), Arc::clone(&clock));
    let p = named_problem("seeds");
    let (id_a, _) = svc.register(Arc::clone(&p)).unwrap();
    let (id_b, _) = svc.register(Arc::clone(&p)).unwrap();
    assert_eq!(id_a.shard(), id_b.shard(), "same problem pins to one shard");

    let batch_a = random_batch(&p, 5, 71);
    let batch_b = random_batch(&p, 4, 72);
    std::thread::scope(|s| {
        let (svc_a, svc_b) = (svc.clone(), svc.clone());
        let (ba, bb) = (batch_a.clone(), batch_b.clone());
        let ha = s.spawn(move || svc_a.eval(id_a, ba).unwrap());
        let hb = s.spawn(move || svc_b.eval(id_b, bb).unwrap());
        let mut direct = NativeEngine::default();
        assert_eq!(ha.join().unwrap(), direct.batch_accuracy(&p, &batch_a).unwrap());
        assert_eq!(hb.join().unwrap(), direct.batch_accuracy(&p, &batch_b).unwrap());
    });

    let m = &svc.metrics;
    assert_eq!(m.executions.load(Ordering::Relaxed), 1, "one merged execution");
    assert_eq!(m.early_flushes.load(Ordering::Relaxed), 1);
    assert_eq!(m.deadline_flushes.load(Ordering::Relaxed), 0);
    assert_eq!(m.coalesced_executions.load(Ordering::Relaxed), 1);
    assert_eq!(m.coalesced_requests.load(Ordering::Relaxed), 2);
    assert_eq!(m.chromosomes.load(Ordering::Relaxed), 9);
    svc.shutdown();
}

/// An armed adaptive deadline sits exactly `IA_MULT x EWMA` past the
/// arrival: with the EWMA primed to T, a lone driver's batch (one of two
/// registered) flushes at 2T on the virtual clock and not one nanosecond
/// earlier.
#[test]
fn adaptive_deadline_uses_ewma_sized_window() {
    const T: u64 = 10_000_000; // 10 ms in ns
    let clock = Arc::new(ManualClock::new());
    let svc = EvalService::spawn_native_with_clock(64, &adaptive_opts(100_000), Arc::clone(&clock));
    let p = named_problem("seeds");
    let (id, _) = svc.register(Arc::clone(&p)).unwrap();

    // Prime the EWMA to exactly T with steady solo arrivals (a single
    // registered driver early-flushes, so each call returns immediately).
    svc.eval(id, random_batch(&p, 2, 1)).unwrap();
    for round in 0..3u64 {
        clock.advance(Duration::from_nanos(T));
        svc.eval(id, random_batch(&p, 2, 2 + round)).unwrap();
    }
    assert_eq!(svc.metrics.shards()[0].ewma_ia_ns.load(Ordering::Relaxed), T);
    let primed_execs = svc.metrics.executions.load(Ordering::Relaxed);

    // Second driver registers: now a lone queued batch no longer
    // early-flushes; it arms a deadline sized by the controller.
    let (_id2, _) = svc.register(Arc::clone(&p)).unwrap();
    clock.advance(Duration::from_nanos(T)); // keep the sample stream steady
    let batch = random_batch(&p, 3, 99);
    std::thread::scope(|s| {
        let eval_svc = svc.clone();
        let b = batch.clone();
        let h = s.spawn(move || eval_svc.eval(id, b).unwrap());
        wait_until("batch coalescing", || {
            svc.metrics.shards()[0].coalescing.load(Ordering::Relaxed) == 3
        });
        // The window is 2 x EWMA = 2T.  One nanosecond short: no flush.
        clock.advance(Duration::from_nanos(2 * T - 1));
        // Synchronize before the negative assert: a register round-trip
        // through the same worker (FIFO channel) proves the worker has
        // already consumed the clock nudge and re-checked the deadline
        // at 2T - 1 — so "no flush yet" is a real boundary check, not a
        // not-woken-yet accident.
        svc.register(named_problem("sync")).unwrap();
        assert_eq!(svc.metrics.executions.load(Ordering::Relaxed), primed_execs);
        // The final nanosecond expires the adaptive deadline.
        clock.advance(Duration::from_nanos(1));
        let got = h.join().unwrap();
        let mut direct = NativeEngine::default();
        assert_eq!(got, direct.batch_accuracy(&p, &batch).unwrap());
    });
    assert_eq!(svc.metrics.deadline_flushes.load(Ordering::Relaxed), 1);
    svc.shutdown();
}

/// Mode equivalence: the same seeded two-driver workload produces
/// bit-identical per-request results under adaptive, fixed, and off
/// coalescing — and all three match the direct native engine.  Merging
/// changes batching, never arithmetic.
///
/// Each round is width-completing (2 x 16 at width 32), so every mode
/// flushes deterministically with the virtual clock parked: fixed mode
/// on width-full, adaptive on width-full/all-drivers, off immediately.
#[test]
fn adaptive_results_bit_identical_to_fixed_window_mode() {
    const DRIVERS: usize = 2;
    const ROUNDS: u64 = 4;
    const BATCH: usize = 16;

    let run = |opts: &PoolOptions| -> Vec<Vec<Vec<f64>>> {
        let clock = Arc::new(ManualClock::new());
        let svc = EvalService::spawn_native_with_clock(32, opts, Arc::clone(&clock));
        let p = named_problem("seeds");
        let ids: Vec<_> = (0..DRIVERS)
            .map(|_| svc.register(Arc::clone(&p)).unwrap().0)
            .collect();
        let out = std::thread::scope(|s| {
            let handles: Vec<_> = ids
                .iter()
                .enumerate()
                .map(|(d, &id)| {
                    let svc = svc.clone();
                    let p = Arc::clone(&p);
                    s.spawn(move || {
                        (0..ROUNDS)
                            .map(|round| {
                                // Seeds depend only on (driver, round):
                                // identical batches across modes.
                                let seed = d as u64 * 1000 + round * 10;
                                svc.eval(id, random_batch(&p, BATCH, seed)).unwrap()
                            })
                            .collect::<Vec<_>>()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect::<Vec<_>>()
        });
        svc.shutdown();
        out
    };

    let adaptive = run(&adaptive_opts(1_000_000));
    let fixed = run(&PoolOptions {
        workers: 1,
        coalesce: CoalesceMode::Fixed,
        coalesce_window_us: 200,
        engine_threads: 1,
        ..PoolOptions::default()
    });
    let off = run(&PoolOptions {
        workers: 1,
        coalesce: CoalesceMode::Off,
        engine_threads: 1,
        ..PoolOptions::default()
    });
    assert_eq!(adaptive, fixed, "adaptive vs fixed-window results diverged");
    assert_eq!(adaptive, off, "adaptive vs uncoalesced results diverged");

    // And against the engine the service wraps.
    let p = named_problem("seeds");
    let mut direct = NativeEngine::default();
    for (d, per_driver) in adaptive.iter().enumerate() {
        for (round, got) in per_driver.iter().enumerate() {
            let seed = d as u64 * 1000 + round as u64 * 10;
            let want = direct.batch_accuracy(&p, &random_batch(&p, BATCH, seed)).unwrap();
            assert_eq!(got, &want, "driver {d} round {round}");
        }
    }
}
