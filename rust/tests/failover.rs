//! Failover contracts of the sharded eval pool, via the public API with
//! the panic-injection backend from `util::testbed` (no artifacts
//! required):
//!
//! * a backend panic downs ONLY its shard: the in-flight request gets a
//!   typed [`ServiceError::ShardDown`] (no hang, no panic escape), the
//!   queue-depth gauge returns to zero, and survivors keep serving;
//! * re-registration re-routes a dead home shard to a live shard, and the
//!   `XlaEngine` stale-id heal path does this transparently mid-run;
//! * a full multi-dataset optimization completes — bit-identical to the
//!   direct native engine — even when its dataset's shard is killed
//!   mid-run (the acceptance scenario: lose at most the in-flight batch,
//!   never a dataset);
//! * re-routing around dead shards agrees with the pure
//!   [`rendezvous_route`] function the property suite pins;
//! * `--respawn-shards` brings a dead worker back exactly once.
//!
//! No test here sleeps: coalescer-timing scenarios run on a
//! `ManualClock`, and cross-thread synchronization goes through
//! observable state (`wait_until` on gauges/liveness), so nothing
//! depends on wall-clock scheduling.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use axdt::coordinator::shard::rendezvous_route;
use axdt::coordinator::{
    optimize_dataset, EngineChoice, EvalService, PoolOptions, RunOptions, ServiceError,
    XlaEngine,
};
use axdt::fitness::native::NativeEngine;
use axdt::fitness::AccuracyEngine;
use axdt::util::clock::ManualClock;
use axdt::util::testbed::{
    named_problem, random_batch, spawn_killable_native, spawn_killable_native_with_clock,
    wait_until, DRIVER_NAMES,
};

fn killable_service(workers: usize, respawn: bool, kill: &Arc<AtomicU64>) -> EvalService {
    let pool = spawn_killable_native(
        8,
        &PoolOptions {
            workers,
            coalesce_window_us: 0,
            engine_threads: 1,
            respawn,
            ..PoolOptions::default()
        },
        Arc::clone(kill),
    );
    EvalService::from_pool(pool)
}

/// Acceptance scenario, service-level half: kill one worker of a 4-shard
/// pool mid-run and observe typed `ShardDown`, surviving shards serving,
/// re-registration landing on a live shard, and the gauge back at zero.
#[test]
fn killing_one_worker_of_four_strands_nothing() {
    let kill = Arc::new(AtomicU64::new(0));
    let svc = killable_service(4, false, &kill);
    assert_eq!(svc.workers(), 4);

    // 8 problems spread 2-per-shard over the 4 workers (pinned routing).
    let problems: Vec<_> = DRIVER_NAMES
        .iter()
        .map(|name| {
            let p = named_problem(name);
            let (id, _) = svc.register(Arc::clone(&p)).unwrap();
            (p, id)
        })
        .collect();

    let (victim_p, victim_id) = &problems[0];
    let victim_shard = victim_id.shard();

    // Arm the kill and hit the victim shard: the in-flight request must
    // get a typed ShardDown, not a hang or a propagated panic.
    kill.store(victim_shard as u64 + 1, Ordering::SeqCst);
    let err = svc
        .eval_typed(*victim_id, random_batch(victim_p, 5, 1))
        .unwrap_err();
    assert!(
        matches!(err, ServiceError::ShardDown { shard } if shard == victim_shard),
        "{err:?}"
    );
    assert!(err.is_stale_id(), "ShardDown must be healable by re-registering");
    assert!(format!("{err}").contains("down"), "{err}");
    assert!(!svc.pool().shard_alive(victim_shard));
    assert_eq!(svc.pool().live_workers(), 3);

    // Survivors keep serving, bit-identical to the direct engine.
    let mut survivors = 0;
    for (p, id) in &problems {
        if id.shard() == victim_shard {
            // The dead shard now fails fast and typed, instead of leaving
            // clients blocked on a dropped reply channel.
            let e = svc.eval_typed(*id, random_batch(p, 3, 2)).unwrap_err();
            assert!(matches!(e, ServiceError::ShardDown { .. }), "{e:?}");
        } else {
            let batch = random_batch(p, 5, 3);
            let got = svc.eval_typed(*id, batch.clone()).unwrap();
            let mut direct = NativeEngine::default();
            assert_eq!(got, direct.batch_accuracy(p, &batch).unwrap());
            survivors += 1;
        }
    }
    assert_eq!(survivors, 6, "2 problems on each of the 3 surviving shards");

    // Re-registration re-routes the dead home shard to a live one.
    let (new_id, _) = svc.register(Arc::clone(victim_p)).unwrap();
    assert_ne!(new_id.shard(), victim_shard);
    assert!(svc.pool().shard_alive(new_id.shard()));
    let batch = random_batch(victim_p, 5, 4);
    let got = svc.eval_typed(new_id, batch.clone()).unwrap();
    let mut direct = NativeEngine::default();
    assert_eq!(got, direct.batch_accuracy(victim_p, &batch).unwrap());

    // The dead shard's queue gauge returned to zero and the death is in
    // the metrics (and the rendered report).
    let m = &svc.metrics;
    assert_eq!(m.shards()[victim_shard].queue_depth.load(Ordering::Relaxed), 0);
    assert_eq!(m.shard_deaths.load(Ordering::Relaxed), 1);
    assert!(m.shards()[victim_shard].down.load(Ordering::Relaxed));
    let render = m.render();
    assert!(render.contains("deaths=1"), "{render}");
    svc.shutdown();
}

/// A request QUEUED behind the one that kills the shard must also get the
/// typed error (not a dropped channel), and both charges must come off
/// the queue-depth gauge.
#[test]
fn queued_requests_get_typed_shard_down() {
    let kill = Arc::new(AtomicU64::new(0));
    // Single worker on a ManualClock with a sub-second window the test
    // never advances past: the first sub-width batch waits in the
    // coalescer, the second completes the width and triggers the panic
    // while both are in the coalescer (only the width-full flush can
    // fire — the virtual window cannot expire on its own).
    let clock = Arc::new(ManualClock::new());
    let pool = spawn_killable_native_with_clock(
        8,
        &PoolOptions {
            workers: 1,
            coalesce_window_us: 500_000,
            engine_threads: 1,
            respawn: false,
            ..PoolOptions::default()
        },
        Arc::clone(&kill),
        Arc::clone(&clock),
    );
    let svc = EvalService::from_pool(pool);
    let p = named_problem("seeds");
    let (id, _) = svc.register(Arc::clone(&p)).unwrap();
    kill.store(1, Ordering::SeqCst); // shard 0

    let first = std::thread::spawn({
        let svc = svc.clone();
        let p = Arc::clone(&p);
        move || svc.eval_typed(id, random_batch(&p, 5, 7))
    });
    // The first batch reaches the coalescer and arms its (virtual)
    // window — observable on the coalescing gauge, no sleep needed.
    wait_until("first batch coalescing", || {
        svc.metrics.shards()[0].coalescing.load(Ordering::Relaxed) == 5
    });
    let second = svc.eval_typed(id, random_batch(&p, 4, 8));

    let first = first.join().unwrap();
    for res in [first, second] {
        let err = res.unwrap_err();
        assert!(matches!(err, ServiceError::ShardDown { shard: 0 }), "{err:?}");
    }
    assert_eq!(svc.metrics.shards()[0].queue_depth.load(Ordering::Relaxed), 0);
    assert_eq!(svc.metrics.shards()[0].coalescing.load(Ordering::Relaxed), 0);
    assert_eq!(svc.metrics.stranded_requests.load(Ordering::Relaxed), 2);
    svc.shutdown();
}

/// The queue-depth gauge must never underflow (wrap to a huge u64)
/// across a worker death: the death-path drain and the client facade
/// can both settle charges for the same jobs, and every decrement path
/// saturates at zero.
#[test]
fn queue_depth_gauge_never_underflows_across_worker_death() {
    let kill = Arc::new(AtomicU64::new(0));
    let clock = Arc::new(ManualClock::new());
    let pool = spawn_killable_native_with_clock(
        8,
        &PoolOptions {
            workers: 1,
            coalesce_window_us: 500_000,
            engine_threads: 1,
            respawn: false,
            ..PoolOptions::default()
        },
        Arc::clone(&kill),
        Arc::clone(&clock),
    );
    let svc = EvalService::from_pool(pool);
    let p = named_problem("seeds");
    let (id, _) = svc.register(Arc::clone(&p)).unwrap();
    kill.store(1, Ordering::SeqCst); // shard 0 dies on its next execution

    // The first sub-width batch parks in the coalescer (the virtual
    // window cannot expire on its own)...
    let t1 = svc.submit(id, random_batch(&p, 5, 21)).unwrap();
    wait_until("first batch coalescing", || {
        svc.metrics.shards()[0].coalescing.load(Ordering::Relaxed) == 5
    });
    // ...the width-completing batch triggers the killing flush, and a
    // third submit races the death — in the channel, in the coalescer,
    // or rejected at submit, every path must settle its gauge charge.
    let t2 = svc.submit(id, random_batch(&p, 3, 22)).unwrap();
    let t3 = svc.submit(id, random_batch(&p, 4, 23));

    let err = svc.wait_typed(t1).unwrap_err();
    assert!(matches!(err, ServiceError::ShardDown { shard: 0 }), "{err:?}");
    let err = svc.wait_typed(t2).unwrap_err();
    assert!(matches!(err, ServiceError::ShardDown { shard: 0 }), "{err:?}");
    if let Ok(t3) = t3 {
        assert!(svc.wait_typed(t3).is_err());
    }

    let depth = || svc.metrics.shards()[0].queue_depth.load(Ordering::Relaxed);
    // The drain settles every queued charge: the gauge reads exactly
    // zero, not a wrapped 2^64-ish value.
    wait_until("gauge settles at zero", || depth() == 0);
    // Extra dequeues (a shutdown racing the drain) saturate at zero
    // instead of wrapping.
    svc.metrics.shard_dequeued(0);
    svc.metrics.shard_dequeued(0);
    assert_eq!(depth(), 0, "queue_depth underflowed");
    // The live snapshot reports the same sane value.
    let snap = svc.metrics.snapshot_json(0).to_string();
    assert!(snap.contains("\"queue_depth\":0"), "{snap}");
    svc.shutdown();
}

/// The engine facade heals a mid-run shard death transparently: the
/// failed batch is re-registered onto a live shard and retried, so the
/// caller sees correct results, not an error.
#[test]
fn xla_engine_heals_over_a_dead_shard() {
    let kill = Arc::new(AtomicU64::new(0));
    let svc = killable_service(4, false, &kill);
    let p = named_problem("drv0");
    let mut engine = XlaEngine::register(&svc, Arc::clone(&p)).unwrap();
    let home = engine.shard();

    kill.store(home as u64 + 1, Ordering::SeqCst);
    let batch = random_batch(&p, 6, 9);
    let got = engine.batch_accuracy(&p, &batch).unwrap();
    let mut direct = NativeEngine::default();
    assert_eq!(got, direct.batch_accuracy(&p, &batch).unwrap());
    assert_ne!(engine.shard(), home, "healed registration moved to a live shard");
    assert!(!svc.pool().shard_alive(home));
    svc.shutdown();
}

/// Acceptance scenario, run-level half: a 2-dataset optimization over a
/// 4-worker pool completes BOTH datasets although one dataset's shard is
/// killed mid-run — and the healed run stays bit-identical to the direct
/// native engine (the retried batch re-executes the same chromosomes).
#[test]
fn optimization_run_survives_mid_run_worker_death() {
    let kill = Arc::new(AtomicU64::new(0));
    let pool = spawn_killable_native(
        16,
        &PoolOptions {
            workers: 4,
            coalesce_window_us: 0,
            engine_threads: 1,
            respawn: false,
            ..PoolOptions::default()
        },
        Arc::clone(&kill),
    );
    let svc = EvalService::from_pool(pool);
    let opts = RunOptions {
        seed: 42,
        pop_size: 16,
        generations: 6,
        margin_max: 5,
        engine: EngineChoice::NativeService,
        microbatch: 0,
    };

    // Arm the kill for the shard "seeds" pins to: its first GA batch
    // panics the worker mid-run, and the heal path must carry the run.
    let victim = svc.pool().shard_for("seeds");
    kill.store(victim as u64 + 1, Ordering::SeqCst);

    let run = optimize_dataset("seeds", &opts, Some(&svc)).unwrap();
    assert!(!run.front.is_empty());
    assert!(!svc.pool().shard_alive(victim), "the kill really fired");
    assert_eq!(svc.metrics.shard_deaths.load(Ordering::Relaxed), 1);

    // A second dataset still completes on the degraded pool.
    let run2 = optimize_dataset("cardio", &opts, Some(&svc)).unwrap();
    assert!(!run2.front.is_empty());

    // Determinism: the healed run matches a pure native run exactly.
    let native = optimize_dataset(
        "seeds",
        &RunOptions { engine: EngineChoice::Native, ..opts },
        None,
    )
    .unwrap();
    assert_eq!(run.front.len(), native.front.len());
    for (a, b) in run.front.iter().zip(&native.front) {
        assert_eq!(a.accuracy, b.accuracy);
        assert_eq!(a.est_area_mm2, b.est_area_mm2);
    }
    svc.shutdown();
}

/// The live pool's re-routing must agree with the pure
/// [`rendezvous_route`] function the property suite checks: kill shards
/// one at a time and, after every kill, every registration lands exactly
/// where the pure function says it should for the current liveness.
#[test]
fn pool_registration_matches_pure_rendezvous_route() {
    let kill = Arc::new(AtomicU64::new(0));
    let svc = killable_service(4, false, &kill);
    let problems: Vec<_> = DRIVER_NAMES.iter().map(|n| named_problem(n)).collect();
    for p in &problems {
        svc.register(Arc::clone(p)).unwrap();
    }

    // Kill shards 0..=2 in turn (leaving one survivor), re-registering
    // every problem after each death.
    for victim in 0..3usize {
        // Trigger the death by evaluating any problem routed to the
        // victim under the CURRENT liveness.
        let alive: Vec<bool> = (0..4).map(|s| svc.pool().shard_alive(s)).collect();
        let routed_here = problems
            .iter()
            .find(|p| rendezvous_route(&p.name, &alive) == Some(victim))
            .expect("some problem routes to every live shard");
        let (vid, _) = svc.register(Arc::clone(routed_here)).unwrap();
        assert_eq!(vid.shard(), victim, "pure route predicts the pool's route");
        kill.store(victim as u64 + 1, Ordering::SeqCst);
        let err = svc.eval_typed(vid, random_batch(routed_here, 3, victim as u64)).unwrap_err();
        assert!(matches!(err, ServiceError::ShardDown { shard } if shard == victim));

        let alive: Vec<bool> = (0..4).map(|s| svc.pool().shard_alive(s)).collect();
        assert!(!alive[victim]);
        for p in &problems {
            let want = rendezvous_route(&p.name, &alive).expect("a live shard remains");
            let (id, _) = svc.register(Arc::clone(p)).unwrap();
            assert_eq!(
                id.shard(),
                want,
                "{}: pool route diverged from rendezvous_route with dead set {:?}",
                p.name,
                alive
            );
        }
    }
    assert_eq!(svc.pool().live_workers(), 1);
    svc.shutdown();
}

/// `--respawn-shards`: the first death brings the worker back (home
/// routing resumes); the second death is permanent.
#[test]
fn respawn_revives_a_shard_exactly_once() {
    let kill = Arc::new(AtomicU64::new(0));
    let svc = killable_service(2, true, &kill);
    let p = named_problem("seeds");
    let (id, _) = svc.register(Arc::clone(&p)).unwrap();
    let home = id.shard();

    // First death: typed error, then the shard comes back.  The respawn
    // completes in bounded worker-side work, so waiting on the liveness
    // flag is deterministic (no sleep, no wall-clock deadline).
    kill.store(home as u64 + 1, Ordering::SeqCst);
    let err = svc.eval_typed(id, random_batch(&p, 3, 11)).unwrap_err();
    assert!(matches!(err, ServiceError::ShardDown { .. }), "{err:?}");
    wait_until("respawn revives the shard", || svc.pool().shard_alive(home));
    assert_eq!(svc.metrics.respawns.load(Ordering::Relaxed), 1);
    assert!(!svc.metrics.shards()[home].down.load(Ordering::Relaxed));

    // The respawned worker has no registrations: the old id is stale, a
    // fresh registration lands back on the home shard and serves.
    let err = svc.eval_typed(id, random_batch(&p, 3, 12)).unwrap_err();
    assert!(err.is_stale_id(), "{err:?}");
    let (id2, _) = svc.register(Arc::clone(&p)).unwrap();
    assert_eq!(id2.shard(), home, "routing returns home after the respawn");
    let batch = random_batch(&p, 5, 13);
    let got = svc.eval_typed(id2, batch.clone()).unwrap();
    let mut direct = NativeEngine::default();
    assert_eq!(got, direct.batch_accuracy(&p, &batch).unwrap());
    // The pre-death id must STILL read stale after the new registration:
    // a respawned worker issues indices past its predecessor's, so an old
    // id can never silently alias (and evaluate against) a new problem.
    assert_ne!(id, id2);
    let err = svc.eval_typed(id, random_batch(&p, 3, 15)).unwrap_err();
    assert!(err.is_stale_id(), "pre-death id aliased a fresh registration: {err:?}");

    // Second death: no second respawn, the shard stays dead.  The
    // `respawn_attempted` latch makes a second revival impossible by
    // construction, so once the death is counted the flags are final —
    // no grace-period sleep required.
    kill.store(home as u64 + 1, Ordering::SeqCst);
    let err = svc.eval_typed(id2, random_batch(&p, 3, 14)).unwrap_err();
    assert!(matches!(err, ServiceError::ShardDown { .. }), "{err:?}");
    wait_until("second death counted", || {
        svc.metrics.shard_deaths.load(Ordering::Relaxed) == 2
    });
    assert!(!svc.pool().shard_alive(home), "a shard is respawned at most once");
    assert_eq!(svc.metrics.respawns.load(Ordering::Relaxed), 1);
    assert_eq!(svc.metrics.shard_deaths.load(Ordering::Relaxed), 2);
    // The pool still serves through the survivor.
    let (id3, _) = svc.register(Arc::clone(&p)).unwrap();
    assert_ne!(id3.shard(), home);
    assert_eq!(svc.eval_typed(id3, batch).unwrap().len(), 5);
    svc.shutdown();
}
