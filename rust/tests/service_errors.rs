//! Error-path integration tests for the evaluation service, via the public
//! API only and with no artifacts required (native backend).
//!
//! These pin the contracts restored in ISSUE 1: an invalid/stale
//! [`ProblemId`] is rejected with `Err` instead of panicking the worker
//! thread (which wedged every client blocked on its reply channel),
//! register/eval after `shutdown()` return `Err` instead of hanging, and
//! the `width = 1` batching edge stays bit-identical to the direct engine.
//!
//! [`ProblemId`]: axdt::coordinator::service::ProblemId

use std::sync::atomic::Ordering;
use std::sync::Arc;

use axdt::coordinator::EvalService;
use axdt::data::generators;
use axdt::dt::{train, TrainConfig};
use axdt::fitness::native::NativeEngine;
use axdt::fitness::{AccuracyEngine, Problem};
use axdt::hw::synth::TreeApprox;
use axdt::hw::{AreaLut, EgtLibrary};
use axdt::util::rng::Pcg64;

fn seeds_problem() -> Arc<Problem> {
    let lib = EgtLibrary::default();
    let lut = AreaLut::build(&lib);
    let spec = generators::spec("seeds").unwrap();
    let data = generators::generate(spec, 42);
    let (train_d, test_d) = data.split(0.3, 42);
    let tree = train(
        &train_d,
        &TrainConfig { max_leaves: spec.max_leaves, min_samples_split: 2 },
    );
    Arc::new(Problem::new(spec.id, tree, &test_d, &lut, &lib, 5))
}

fn random_batch(p: &Problem, count: usize, seed: u64) -> Vec<TreeApprox> {
    let mut rng = Pcg64::seeded(seed);
    let n = p.n_comparators();
    (0..count)
        .map(|_| {
            let bits: Vec<u8> = (0..n).map(|_| rng.int_in(2, 8) as u8).collect();
            let thr_int: Vec<u32> = (0..n)
                .map(|j| axdt::quant::int_threshold(p.thresholds[j], bits[j]))
                .collect();
            TreeApprox { bits, thr_int }
        })
        .collect()
}

/// A `ProblemId` issued by one service must be rejected by another — both
/// when its index is out of range there (the seed panicked the worker and
/// wedged every client) AND when it happens to be in range (which would
/// silently evaluate against the wrong problem without the service token).
#[test]
fn stale_problem_id_is_rejected_and_worker_survives() {
    let a = EvalService::spawn_native(8);
    let b = EvalService::spawn_native(8);
    let p = seeds_problem();

    let (id_a, _) = a.register(Arc::clone(&p)).unwrap();
    let (id_b0, _) = b.register(Arc::clone(&p)).unwrap();
    let (id_b1, _) = b.register(Arc::clone(&p)).unwrap();
    assert_ne!(id_a, id_b0, "ids carry the issuing service's token");

    let batch = random_batch(&p, 4, 7);

    // In-range foreign id (index 0 exists on `a` too): token mismatch.
    let err = a.eval(id_b0, batch.clone()).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("different EvalService"), "{msg}");

    // Out-of-range foreign id (index 1 does not exist on `a`): also Err,
    // never a worker panic.
    assert!(a.eval(id_b1, batch.clone()).is_err());

    // The worker thread must still be alive and correct afterwards.
    let got = a.eval(id_a, batch.clone()).unwrap();
    let mut direct = NativeEngine::default();
    assert_eq!(got, direct.batch_accuracy(&p, &batch).unwrap());

    a.shutdown();
    b.shutdown();
}

/// Shutdown is queued FIFO ahead of later requests, so register/eval after
/// `shutdown()` must deterministically return `Err` — never block forever
/// on a reply that will not come.
#[test]
fn requests_after_shutdown_return_err_not_hang() {
    let svc = EvalService::spawn_native(4);
    let p = seeds_problem();
    let (id, _) = svc.register(Arc::clone(&p)).unwrap();
    svc.shutdown();

    assert!(svc.register(Arc::clone(&p)).is_err());
    assert!(svc.eval(id, random_batch(&p, 2, 11)).is_err());
    // Idempotent: a second shutdown on a dead service is a no-op.
    svc.shutdown();
}

/// `width = 1` degenerates batching into one execution per chromosome and
/// must still match the direct native engine exactly.
#[test]
fn width_one_service_parity_with_direct_engine() {
    let svc = EvalService::spawn_native(1);
    let p = seeds_problem();
    let (id, bucket) = svc.register(Arc::clone(&p)).unwrap();
    assert!(bucket.is_none(), "native backend routes to no bucket");

    let batch = random_batch(&p, 6, 13);
    let got = svc.eval(id, batch.clone()).unwrap();
    let mut direct = NativeEngine::default();
    assert_eq!(got, direct.batch_accuracy(&p, &batch).unwrap());
    assert_eq!(svc.metrics.executions.load(Ordering::Relaxed), 6);
    assert_eq!(svc.metrics.padding_waste(), 0.0);
    svc.shutdown();
}
