//! Cross-layer integration tests: the AOT XLA artifact (L1/L2) against the
//! native rust oracle (L3), through the full coordinator machinery.
//!
//! The `xla_*` tests compile only with `--features xla` and additionally
//! skip themselves (with a message) when `artifacts/meta.json` is absent or
//! the PJRT runtime cannot start — run `make artifacts` and vendor a real
//! `xla` binding to exercise them.  When they do run, they ARE the proof
//! that the three layers compute the same function.

use std::sync::Arc;

#[cfg(feature = "xla")]
use axdt::coordinator::{EvalService, XlaEngine};
use axdt::data::generators;
use axdt::dt::{train, TrainConfig};
use axdt::fitness::native::NativeEngine;
use axdt::fitness::{FitnessEvaluator, Problem};
use axdt::ga::{run_nsga2, Chromosome, NsgaConfig};
#[cfg(feature = "xla")]
use axdt::hw::synth::TreeApprox;
use axdt::hw::{AreaLut, EgtLibrary};
#[cfg(feature = "xla")]
use axdt::util::rng::Pcg64;

#[cfg(feature = "xla")]
use axdt::fitness::AccuracyEngine;

#[cfg(feature = "xla")]
const ART: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");

/// Spawn the XLA eval service, or skip the calling test with a reason:
/// missing artifacts (run `make artifacts`) or an unvendored/unavailable
/// PJRT runtime.
#[cfg(feature = "xla")]
fn spawn_xla_or_skip() -> Option<EvalService> {
    if !std::path::Path::new(ART).join("meta.json").exists() {
        eprintln!("skipping: {ART}/meta.json not found; run `make artifacts` first");
        return None;
    }
    match EvalService::spawn_xla(ART) {
        Ok(svc) => Some(svc),
        Err(e) => {
            eprintln!("skipping: XLA eval service unavailable ({e:#})");
            None
        }
    }
}

fn problem_for(dataset: &str, seed: u64) -> Problem {
    let lib = EgtLibrary::default();
    let lut = AreaLut::build(&lib);
    let spec = generators::spec(dataset).unwrap();
    let data = generators::generate(spec, seed);
    let (train_d, test_d) = data.split(0.3, seed);
    let tree = train(
        &train_d,
        &TrainConfig { max_leaves: spec.max_leaves, min_samples_split: 2 },
    );
    Problem::new(spec.id, tree, &test_d, &lut, &lib, 5)
}

#[cfg(feature = "xla")]
fn random_batch(p: &Problem, count: usize, seed: u64) -> Vec<TreeApprox> {
    let mut rng = Pcg64::seeded(seed);
    let n = p.n_comparators();
    (0..count)
        .map(|_| {
            let bits: Vec<u8> = (0..n).map(|_| rng.int_in(2, 8) as u8).collect();
            let thr_int: Vec<u32> = (0..n)
                .map(|j| {
                    let t = axdt::quant::int_threshold(p.thresholds[j], bits[j]);
                    axdt::quant::substitute(t, rng.int_in(-5, 5) as i32, bits[j])
                })
                .collect();
            TreeApprox { bits, thr_int }
        })
        .collect()
}

/// The headline correctness test: for several datasets (covering all three
/// shape buckets), the XLA artifact and the native tree walk agree on every
/// chromosome to f32 precision.
#[test]
#[cfg(feature = "xla")]
fn xla_engine_matches_native_oracle() {
    let Some(svc) = spawn_xla_or_skip() else { return };
    // seeds → small bucket, cardio → medium, har would be large (slow; the
    // large bucket is covered by the quick variant below).
    for (dataset, n_chrom) in [("seeds", 40), ("vertebral", 12), ("cardio", 8)] {
        let problem = Arc::new(problem_for(dataset, 42));
        let mut xla = XlaEngine::register(&svc, Arc::clone(&problem)).unwrap();
        let mut native = NativeEngine::default();
        let batch = random_batch(&problem, n_chrom, 7);
        let a_xla = xla.batch_accuracy(&problem, &batch).unwrap();
        let a_nat = native.batch_accuracy(&problem, &batch).unwrap();
        for i in 0..batch.len() {
            assert!(
                (a_xla[i] - a_nat[i]).abs() < 1e-5,
                "{dataset} chromosome {i}: xla={} native={}",
                a_xla[i],
                a_nat[i]
            );
        }
    }
    svc.shutdown();
}

/// Exact chromosome through the artifact == 8-bit baseline accuracy.
#[test]
#[cfg(feature = "xla")]
fn xla_exact_baseline_accuracy() {
    let Some(svc) = spawn_xla_or_skip() else { return };
    let problem = Arc::new(problem_for("seeds", 42));
    let mut xla = XlaEngine::register(&svc, Arc::clone(&problem)).unwrap();
    let exact = TreeApprox::exact(&problem.tree);
    let acc = xla.batch_accuracy(&problem, &[exact.clone()]).unwrap()[0];
    let want = NativeEngine::accuracy_one(&problem, &exact);
    assert!((acc - want).abs() < 1e-5, "xla {acc} native {want}");
    svc.shutdown();
}

/// A short NSGA-II run with the XLA engine produces a sane front whose
/// accuracies re-verify against the native engine.
#[test]
#[cfg(feature = "xla")]
fn ga_over_xla_engine_front_verifies() {
    let Some(svc) = spawn_xla_or_skip() else { return };
    let lib = EgtLibrary::default();
    let lut = AreaLut::build(&lib);
    let problem = Arc::new(problem_for("seeds", 42));
    let engine = XlaEngine::register(&svc, Arc::clone(&problem)).unwrap();
    let mut ev = FitnessEvaluator::new(&problem, &lut, engine);
    let cfg = NsgaConfig { pop_size: 16, generations: 5, seed: 3, ..Default::default() };
    let res = run_nsga2(problem.n_comparators(), &cfg, &mut ev);
    // Surface a mid-run engine failure directly instead of letting the
    // pessimistic placeholder objectives fail the accuracy asserts below
    // with a baffling numeric mismatch.
    if let Some(e) = ev.take_error() {
        panic!("eval engine failed mid-run: {e:#}");
    }
    let front = res.pareto_front();
    assert!(!front.is_empty());

    let ctx = problem.decode_context(&lut);
    let mut native = NativeEngine::default();
    for s in &front {
        let approx = s.chromosome.decode(&ctx);
        let acc_native = native.batch_accuracy(&problem, &[approx]).unwrap()[0];
        let acc_ga = 1.0 - s.objectives[0];
        assert!(
            (acc_native - acc_ga).abs() < 1e-5,
            "front point: ga {acc_ga} native {acc_native}"
        );
    }
    // Metrics recorded real executions.
    assert!(svc.metrics.executions.load(std::sync::atomic::Ordering::Relaxed) > 0);
    svc.shutdown();
}

/// Batches wider than the artifact population width split + pad correctly.
#[test]
#[cfg(feature = "xla")]
fn xla_batch_splitting_consistency() {
    let Some(svc) = spawn_xla_or_skip() else { return };
    let problem = Arc::new(problem_for("seeds", 42));
    let mut xla = XlaEngine::register(&svc, Arc::clone(&problem)).unwrap();
    // 45 chromosomes: one full 32-slot execution plus a padded 13-slot one.
    let batch = random_batch(&problem, 45, 11);
    let whole = xla.batch_accuracy(&problem, &batch).unwrap();
    let first = xla.batch_accuracy(&problem, &batch[..7]).unwrap();
    assert_eq!(&whole[..7], &first[..], "same chromosomes, same answers");
    let waste = svc.metrics.padding_waste();
    assert!(waste > 0.0, "tail chunk must have been padded");
    svc.shutdown();
}

/// Deterministic native pipeline: the exact chromosome dominates nothing it
/// shouldn't — included here as a cross-module sanity sweep on two more
/// datasets without XLA (fast).
#[test]
fn native_front_no_worse_than_exact() {
    let lib = EgtLibrary::default();
    let lut = AreaLut::build(&lib);
    for dataset in ["seeds", "vertebral"] {
        let problem = Arc::new(problem_for(dataset, 42));
        let mut ev = FitnessEvaluator::new(&problem, &lut, NativeEngine::default());
        let cfg = NsgaConfig { pop_size: 16, generations: 8, seed: 5, ..Default::default() };
        let res = run_nsga2(problem.n_comparators(), &cfg, &mut ev);
        // exact seeded in: front must contain a point with area <= exact
        // estimate and accuracy >= exact - small.
        let exact = Chromosome::exact(problem.n_comparators());
        let ctx = problem.decode_context(&lut);
        let exact_area = problem.estimate_area(&lut, &exact.decode(&ctx));
        let front = res.pareto_front();
        assert!(
            front.iter().all(|s| s.objectives[1] <= exact_area * 1.001),
            "{dataset}: some front point is larger than the exact design"
        );
    }
}
