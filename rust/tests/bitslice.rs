//! Bit-sliced kernel ≡ scalar oracle, pinned across every dataset
//! generator and across test-set sizes that exercise the tail-lane mask.
//!
//! The native engine's default kernel evaluates 64 samples per `u64` word
//! (see `fitness::native`); the scalar per-sample walk is kept as the
//! oracle.  These tests pin the two **bit-identical** — same `f64` bits,
//! not approximately equal — on every generator in `generators::SPECS`
//! (each has its own feature distribution: continuous, discrete-grid,
//! imbalanced, wide) and on test-set sizes that are deliberately NOT
//! multiples of 64, where a wrong tail mask would count phantom lanes.
//!
//! Big generators are row-subsampled before the split: tier-1 runs this
//! in debug mode, and the kernel contract is about code distributions and
//! word tails, not the paper's full cardinalities.

use axdt::data::{generators, Dataset};
use axdt::dt::{train, TrainConfig};
use axdt::fitness::native::{accuracy_sliced, NativeEngine};
use axdt::fitness::{AccuracyEngine, Problem};
use axdt::hw::synth::TreeApprox;
use axdt::hw::{AreaLut, EgtLibrary};
use axdt::util::prop::{check, PropConfig};
use axdt::util::rng::Pcg64;

/// Row-subsampled problem: first `keep` generated rows, leaf-capped tree
/// (debug-mode tier-1 budget; the kernel contract doesn't need the
/// paper-size trees).
fn subsampled_problem(
    spec: &generators::DatasetSpec,
    keep: usize,
    lut: &AreaLut,
    lib: &EgtLibrary,
) -> Problem {
    let full = generators::generate(spec, 11);
    let n = full.n_samples.min(keep);
    let data = Dataset {
        name: full.name.clone(),
        x: full.x[..n * full.n_features].to_vec(),
        y: full.y[..n].to_vec(),
        n_samples: n,
        n_features: full.n_features,
        n_classes: full.n_classes,
    };
    let (train_d, test_d) = data.split(0.3, 11);
    let tree = train(
        &train_d,
        &TrainConfig { max_leaves: spec.max_leaves.min(24), min_samples_split: 2 },
    );
    Problem::new(spec.id, tree, &test_d, lut, lib, 5)
}

fn random_approx(p: &Problem, rng: &mut Pcg64) -> TreeApprox {
    let n = p.n_comparators();
    let bits: Vec<u8> = (0..n).map(|_| rng.int_in(2, 8) as u8).collect();
    let thr_int: Vec<u32> = (0..n)
        .map(|j| {
            let t = axdt::quant::int_threshold(p.thresholds[j], bits[j]);
            axdt::quant::substitute(t, rng.int_in(-5, 5) as i32, bits[j])
        })
        .collect();
    TreeApprox { bits, thr_int }
}

/// Every generator in SPECS: batched bit-sliced accuracy is bit-identical
/// to the scalar oracle, chromosome by chromosome.  The per-spec row caps
/// land test-set sizes on a mix of word tails — exact multiples of 64 and
/// odd remainders both — so a tail-mask regression on any distribution
/// shape fails here by name.
#[test]
fn sliced_matches_scalar_on_every_generator() {
    let lib = EgtLibrary::default();
    let lut = AreaLut::build(&lib);
    // round(0.3 × keep) = n_test: 64 exactly (one full-word boundary),
    // then a spread of non-multiples across 1..4 words.
    let keeps = [213usize, 437, 203, 533, 337, 713, 257, 190, 310, 497];
    assert_eq!(keeps.len(), generators::SPECS.len());

    let mut tails_seen = std::collections::BTreeSet::new();
    for (spec, &keep) in generators::SPECS.iter().zip(&keeps) {
        let p = subsampled_problem(spec, keep, &lut, &lib);
        tails_seen.insert(p.n_test % 64);

        let mut rng = Pcg64::seeded(0xB17 ^ keep as u64);
        let batch: Vec<TreeApprox> = (0..4).map(|_| random_approx(&p, &mut rng)).collect();
        let mut engine = NativeEngine { threads: 2, scalar: false };
        let accs = engine.batch_accuracy(&p, &batch).unwrap();
        for (approx, &sliced) in batch.iter().zip(&accs) {
            let scalar = NativeEngine::accuracy_one(&p, approx);
            assert_eq!(
                scalar.to_bits(),
                sliced.to_bits(),
                "{}: n_test={} scalar={scalar} sliced={sliced}",
                spec.id,
                p.n_test
            );
        }
    }
    assert!(
        tails_seen.contains(&0) && tails_seen.len() >= 4,
        "row caps must exercise full-word and varied partial-word tails, got {tails_seen:?}"
    );
}

/// Seeded property test: random trees-by-subsample, random precisions and
/// substitutions, random odd test-set truncations — sliced == scalar,
/// bit for bit, every case (failure replays by printed seed).
#[test]
fn prop_sliced_equals_scalar_on_random_approximations() {
    let lib = EgtLibrary::default();
    let lut = AreaLut::build(&lib);
    // Three tail shapes per problem set: sub-word, exact word, multi-word
    // with an odd tail.
    let problems: Vec<Problem> = [("seeds", 210usize), ("vertebral", 310), ("balance", 427)]
        .iter()
        .map(|&(id, keep)| subsampled_problem(generators::spec(id).unwrap(), keep, &lut, &lib))
        .collect();
    for (p, want_tail) in problems.iter().zip([63usize, 29, 0]) {
        // Guard the fixture: each problem must land on its intended tail.
        assert_eq!(p.n_test % 64, want_tail, "{}: n_test={}", p.name, p.n_test);
    }

    check(
        "bitslice==scalar",
        PropConfig { cases: 48, seed: 0x511CED },
        |rng| {
            let which = rng.below(problems.len() as u64) as usize;
            (which, random_approx(&problems[which], rng))
        },
        |(which, approx)| {
            let p = &problems[*which];
            let scalar = NativeEngine::accuracy_one(p, approx);
            let sliced = accuracy_sliced(p, approx);
            if scalar.to_bits() == sliced.to_bits() {
                Ok(())
            } else {
                Err(format!(
                    "{} (n_test={}): scalar {scalar} != sliced {sliced}",
                    p.name, p.n_test
                ))
            }
        },
    );
}
