//! Cross-module pipeline properties and failure injection.
//!
//! These run without artifacts (native engines) and stress the seams
//! between substrates: trainer → synthesis → fitness → GA → report.

#[cfg(feature = "xla")]
use std::sync::Arc;

use axdt::coordinator::{optimize_dataset, EngineChoice, EvalService, RunOptions};
use axdt::data::generators;
use axdt::dt::{train, TrainConfig};
use axdt::fitness::native::NativeEngine;
use axdt::fitness::{encode, Problem};
use axdt::ga::nsga2;
use axdt::hw::synth::{self, TreeApprox, FEATURE_BITS};
use axdt::hw::{rtl, AreaLut, EgtLibrary};
use axdt::util::prop::{check, PropConfig};
use axdt::util::rng::Pcg64;

fn problem_for(dataset: &str, seed: u64, margin: u32) -> Problem {
    let lib = EgtLibrary::default();
    let lut = AreaLut::build(&lib);
    let spec = generators::spec(dataset).unwrap();
    let data = generators::generate(spec, seed);
    let (train_d, test_d) = data.split(0.3, seed);
    let tree = train(
        &train_d,
        &TrainConfig { max_leaves: spec.max_leaves, min_samples_split: 2 },
    );
    Problem::new(spec.id, tree, &test_d, &lut, &lib, margin)
}

/// Netlist evaluation ≡ quantized tree walk ≡ dense tensor oracle, on
/// random mixed-precision approximations of a real trained tree.
#[test]
fn three_way_equivalence_on_random_approximations() {
    let problem = problem_for("vertebral", 9, 5);
    let tree = &problem.tree;
    let bucket = encode::Bucket { name: "t".into(), s: 128, n: 64, l: 64, c: 16, p: 4 };
    // Take the first 128 test samples for the dense oracle bucket.
    let mut small = problem_for("vertebral", 9, 5);
    small.n_test = small.n_test.min(128);
    let st = encode::encode_static(&small, &bucket);

    check(
        "netlist==walk==dense",
        PropConfig { cases: 6, seed: 0xF00D },
        |rng| {
            let n = tree.n_comparators();
            let bits: Vec<u8> = (0..n).map(|_| rng.int_in(2, 8) as u8).collect();
            let thr_int: Vec<u32> = (0..n)
                .map(|j| {
                    let t = axdt::quant::int_threshold(problem.thresholds[j], bits[j]);
                    axdt::quant::substitute(t, rng.int_in(-5, 5) as i32, bits[j])
                })
                .collect();
            (TreeApprox { bits, thr_int }, rng.next_u64())
        },
        |(approx, sample_seed)| {
            // (a) walk vs netlist on random feature codes.  The slot table
            // is the problem's precomputed node→slot map (same tree).
            let circuit = synth::synth_tree(tree, approx);
            let mut rng = Pcg64::seeded(*sample_seed);
            for _ in 0..16 {
                let codes: Vec<u32> =
                    (0..tree.n_features).map(|_| rng.below(256) as u32).collect();
                let mut ins = vec![false; circuit.netlist.n_inputs];
                for (&feat, &bus) in &circuit.feature_bus {
                    for k in 0..FEATURE_BITS as usize {
                        ins[bus * FEATURE_BITS as usize + k] = (codes[feat] >> k) & 1 == 1;
                    }
                }
                let out = circuit.netlist.eval(&ins);
                let got: u32 =
                    out.iter().enumerate().map(|(m, &b)| (b as u32) << m).sum();
                let want = synth::predict_codes_with_slots(
                    tree,
                    &problem.slot_of_node,
                    approx,
                    &codes,
                );
                if got != want {
                    return Err(format!("netlist {got} != walk {want}"));
                }
            }
            // (b) dense oracle vs walk accuracy over the truncated test set.
            let (thr, scale) = encode::pack_population(&small, &bucket, &[approx.clone()]);
            let dense = encode::reference_accuracy(&st, &thr, &scale, 1)[0];
            let walk = NativeEngine::accuracy_one(&small, approx);
            if (dense - walk).abs() > 1e-6 {
                return Err(format!("dense {dense} != walk {walk}"));
            }
            Ok(())
        },
    );
}

/// GA front invariants on a real problem: non-dominated, within bounds,
/// and the exact design's estimate equals the baseline synthesis.
#[test]
fn ga_front_invariants_real_problem() {
    let run = optimize_dataset(
        "seeds",
        &RunOptions {
            pop_size: 20,
            generations: 8,
            engine: EngineChoice::Native,
            ..Default::default()
        },
        None,
    )
    .unwrap();
    let objs: Vec<[f64; 2]> = run
        .front
        .iter()
        .map(|p| [1.0 - p.accuracy, p.est_area_mm2])
        .collect();
    for (i, a) in objs.iter().enumerate() {
        for (j, b) in objs.iter().enumerate() {
            if i != j {
                assert!(!nsga2::dominates(a, b) || a == b, "front member dominates another");
            }
        }
    }
    for p in &run.front {
        assert!(p.measured.power_mw > 0.0 && p.measured.delay_ms > 0.0);
        assert!(p.est_area_mm2 <= run.baseline.area_mm2 * 1.001);
    }
}

/// Larger margins can only improve the best-estimated-area design (the
/// substitution argmin is monotone in the search window).
#[test]
fn margin_monotonicity_of_area_estimates() {
    let lib = EgtLibrary::default();
    let lut = AreaLut::build(&lib);
    let problem = problem_for("seeds", 42, 5);
    let exact = TreeApprox::exact(&problem.tree);
    let mut prev = f64::INFINITY;
    for margin in [0u32, 1, 3, 5, 10] {
        let thr_int: Vec<u32> = exact
            .thr_int
            .iter()
            .map(|&t| lut.cheapest_in_margin(8, t, margin).0)
            .collect();
        let approx = TreeApprox { bits: exact.bits.clone(), thr_int };
        let est = problem.estimate_area(&lut, &approx);
        assert!(est <= prev + 1e-9, "margin {margin}: {est} > {prev}");
        prev = est;
    }
}

/// Verilog emission is structurally consistent for random approximations.
#[test]
fn rtl_emission_consistent() {
    let problem = problem_for("seeds", 42, 5);
    let tree = &problem.tree;
    let mut rng = Pcg64::seeded(0xA11);
    for _ in 0..4 {
        let n = tree.n_comparators();
        let bits: Vec<u8> = (0..n).map(|_| rng.int_in(2, 8) as u8).collect();
        let thr_int: Vec<u32> = (0..n)
            .map(|j| axdt::quant::int_threshold(problem.thresholds[j], bits[j]))
            .collect();
        let approx = TreeApprox { bits, thr_int };
        let v = rtl::tree_verilog(tree, &approx, "m");
        assert_eq!(v.matches("wire cmp_").count(), n);
        assert_eq!(v.matches("module ").count(), 1);
        assert_eq!(v.matches("endmodule").count(), 1);
        let circuit = synth::synth_tree(tree, &approx);
        let sv = rtl::netlist_verilog(&circuit.netlist, "g");
        let live = circuit.netlist.live_mask().iter().filter(|&&l| l).count();
        assert_eq!(sv.matches("EGT_").count(), live);
    }
}

// ---- failure injection ----------------------------------------------------

/// Spawn the XLA service over a fabricated artifact dir, or skip the
/// calling test when the PJRT runtime itself is unavailable (unvendored
/// stub build).  Shared by the failure-injection tests below.
#[cfg(feature = "xla")]
fn spawn_xla_or_skip(dir: &std::path::Path) -> Option<EvalService> {
    match EvalService::spawn_xla(dir) {
        Ok(svc) => Some(svc),
        Err(e) => {
            eprintln!("skipping: PJRT runtime unavailable ({e:#})");
            None
        }
    }
}

#[test]
fn xla_service_with_missing_artifacts_fails_cleanly() {
    let err = match EvalService::spawn_xla("/nonexistent/dir") {
        Err(e) => e,
        Ok(_) => panic!("service must not start without artifacts"),
    };
    // With the `xla` feature: a missing-artifacts message.  Without it: a
    // clear built-without-the-feature message.  Either way, no hang/panic.
    let msg = format!("{err:#}");
    assert!(
        msg.contains("meta.json") || msg.contains("artifacts") || msg.contains("feature"),
        "{msg}"
    );
}

#[test]
#[cfg(feature = "xla")]
fn problem_too_large_for_buckets_is_rejected() {
    // A fabricated meta with tiny buckets: registration must fail with a
    // routing error, not a crash.
    let dir = std::env::temp_dir().join("axdt_tiny_buckets");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("meta.json"),
        r#"{"tile_s": 128, "input_names": [], "buckets":
            {"nano": {"s": 128, "n": 2, "l": 2, "c": 2, "p": 4,
                      "file": "missing.hlo.txt"}}}"#,
    )
    .unwrap();
    let Some(svc) = spawn_xla_or_skip(&dir) else { return };
    let problem = Arc::new(problem_for("seeds", 42, 5));
    let err = svc.register(problem).unwrap_err();
    assert!(format!("{err}").contains("no bucket fits"), "{err}");
    svc.shutdown();
}

#[test]
fn corrupt_meta_rejected() {
    let dir = std::env::temp_dir().join("axdt_corrupt_meta");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("meta.json"), "{not json").unwrap();
    assert!(axdt::runtime::ArtifactMeta::load(&dir).is_err());
    std::fs::write(dir.join("meta.json"), r#"{"tile_s": 128}"#).unwrap();
    assert!(axdt::runtime::ArtifactMeta::load(&dir).is_err());
}

#[test]
#[cfg(feature = "xla")]
fn truncated_hlo_artifact_fails_at_compile_not_crash() {
    let dir = std::env::temp_dir().join("axdt_bad_hlo");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("meta.json"),
        r#"{"tile_s": 128, "input_names": [], "buckets":
            {"small": {"s": 256, "n": 64, "l": 64, "c": 16, "p": 32,
                       "file": "bad.hlo.txt"}}}"#,
    )
    .unwrap();
    std::fs::write(dir.join("bad.hlo.txt"), "HloModule garbage\n\nENTRY %oops {").unwrap();
    let Some(svc) = spawn_xla_or_skip(&dir) else { return };
    let problem = Arc::new(problem_for("seeds", 42, 5));
    assert!(svc.register(problem).is_err());
    svc.shutdown();
}

/// Dataset generation edge: margin 0 disables substitution entirely.
#[test]
fn margin_zero_pipeline_runs() {
    let run = optimize_dataset(
        "seeds",
        &RunOptions {
            pop_size: 12,
            generations: 3,
            margin_max: 0,
            engine: EngineChoice::Native,
            ..Default::default()
        },
        None,
    )
    .unwrap();
    assert!(!run.front.is_empty());
}
