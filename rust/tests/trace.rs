//! Ticket-lifecycle tracing contracts (ISSUE 7), through the public API
//! only — no artifacts, no wall-clock sleeps for the determinism half
//! (timing runs on the `ManualClock`):
//!
//! * two identical virtual-clock runs journal BYTE-IDENTICAL event
//!   sequences: same seq numbers, same clock-seam timestamps, same
//!   payloads — the journal is bit-reproducible, not merely "similar";
//! * one submit→wait round trip journals the full lifecycle in causal
//!   order (submitted → enqueued → coalesced → flushed → executing →
//!   executed → collected), covering both a width-full `Full` flush and
//!   a virtual-deadline `Deadline` flush;
//! * a real `optimize_dataset` run over the service brackets the GA in
//!   driver-track spans (dataset / ga / per-generation / synthesis) on
//!   the SAME journal the shard events land in.

use std::sync::Arc;
use std::time::Duration;

use axdt::coordinator::{
    optimize_dataset, CoalesceMode, EngineChoice, EvalService, PoolOptions, RunOptions,
};
use axdt::util::clock::{Clock, ManualClock};
use axdt::util::testbed::{named_problem, random_batch, wait_until};

/// One scripted two-ticket run on a parked `ManualClock`: a width-full
/// batch (synchronous `Full` flush, all at t=0) followed by a sub-width
/// batch that parks in the coalescer until a 250 µs virtual advance
/// expires its 200 µs window (`Deadline` flush).  Returns the journal's
/// canonical one-line renderings.
fn run_once() -> Vec<String> {
    let clock = Arc::new(ManualClock::new());
    let svc = EvalService::spawn_native_with_clock(
        8,
        &PoolOptions {
            workers: 1,
            coalesce: CoalesceMode::Fixed,
            coalesce_window_us: 200,
            engine_threads: 1,
            ..PoolOptions::default()
        },
        Arc::clone(&clock) as Arc<dyn Clock>,
    );
    svc.metrics.trace.set_enabled(true);
    let p = named_problem("traced");
    let (id, _) = svc.register(Arc::clone(&p)).unwrap();

    // Width-full ticket: flushes synchronously inside the worker's Eval
    // arm, and `wait` returns only after the worker's `Executed` record,
    // so the seven records are totally ordered.
    let full = random_batch(&p, 8, 7);
    svc.wait(svc.submit(id, full).unwrap()).unwrap();
    assert_eq!(svc.metrics.trace.len(), 7, "full-width ticket journals its whole lifecycle");

    // Sub-width ticket: parks until the deadline.  The barrier is on the
    // JOURNAL length, not the coalescing gauge — the gauge is bumped
    // before the Enqueued/Coalesced records are written, so a gauge
    // barrier would let the advance race the records.
    let tail = random_batch(&p, 4, 8);
    let ticket = svc.submit(id, tail).unwrap();
    wait_until("enqueued+coalesced journaled", || svc.metrics.trace.len() == 10);
    clock.advance(Duration::from_micros(250));
    svc.wait(ticket).unwrap();

    assert_eq!(svc.metrics.trace.dropped(), 0);
    let lines: Vec<String> =
        svc.metrics.trace.snapshot().iter().map(ToString::to_string).collect();
    svc.shutdown();
    lines
}

/// Acceptance (ISSUE 7): the journal is deterministic under the virtual
/// clock — two identical runs produce byte-identical event sequences —
/// and one run covers every lifecycle stage for both flush shapes.
#[test]
fn ticket_lifecycle_trace_is_bit_reproducible_on_manual_clock() {
    let a = run_once();
    let b = run_once();
    assert_eq!(a, b, "identical virtual-clock runs must journal byte-identical sequences");
    assert_eq!(a.len(), 14);

    // Causal lifecycle order, for both the Full and the Deadline ticket.
    let kinds: Vec<&str> = a
        .iter()
        .map(|line| line.splitn(3, ' ').nth(2).unwrap().split(' ').next().unwrap())
        .collect();
    assert_eq!(
        kinds,
        [
            "submitted",
            "enqueued",
            "coalesced",
            "flushed(Full)",
            "executing",
            "executed",
            "collected",
            "submitted",
            "enqueued",
            "coalesced",
            "flushed(Deadline)",
            "executing",
            "executed",
            "collected",
        ],
        "{a:#?}"
    );

    // Seq numbers are dense from zero; timestamps come off the virtual
    // clock: everything up to the parked sub-width submit is at t=0, the
    // deadline flush and its collect land exactly at the 250 µs advance.
    for (i, line) in a.iter().enumerate() {
        assert!(line.starts_with(&format!("seq={i} ")), "{line}");
    }
    for line in &a[..10] {
        assert!(line.contains(" ts=0 "), "{line}");
    }
    for line in &a[10..] {
        assert!(line.contains(" ts=250000 "), "{line}");
    }
    assert!(a[3].contains("width=8"), "{}", a[3]);
    assert!(a[10].contains("width=4"), "{}", a[10]);
    assert!(a[13].ends_with("latency=250000"), "{}", a[13]);
}

/// A real optimization run over the service journals driver spans —
/// dataset, ga, per-generation, synthesis — on its own driver track,
/// interleaved with the shard-side ticket lifecycle in one journal.
#[test]
fn driver_spans_bracket_the_ga_on_the_shared_journal() {
    let svc = EvalService::spawn_native_with(
        8,
        &PoolOptions { workers: 1, engine_threads: 1, ..PoolOptions::default() },
    );
    svc.metrics.trace.set_enabled(true);
    let run = optimize_dataset(
        "seeds",
        &RunOptions {
            seed: 42,
            pop_size: 8,
            generations: 2,
            margin_max: 5,
            engine: EngineChoice::NativeService,
            microbatch: 0,
        },
        Some(&svc),
    )
    .unwrap();
    assert!(!run.front.is_empty());

    let lines: Vec<String> =
        svc.metrics.trace.snapshot().iter().map(ToString::to_string).collect();
    for name in ["dataset seeds", "ga", "gen 0", "gen 1", "synthesis"] {
        let begin = format!("span-begin track=1 name={name}");
        let end = format!("span-end track=1 name={name}");
        assert!(lines.iter().any(|l| l.contains(&begin)), "missing `{begin}`");
        assert!(lines.iter().any(|l| l.contains(&end)), "missing `{end}`");
    }
    assert_eq!(svc.metrics.trace.track_names(), ["seeds"]);
    // Shard events share the journal with the driver spans.
    assert!(lines.iter().any(|l| l.contains("submitted shard=0")));
    assert!(lines.iter().any(|l| l.contains("executed shard=0")));
    svc.shutdown();
}
