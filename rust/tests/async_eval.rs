//! Two-phase (ticketed submit/wait) eval contracts, through the public
//! API only — no artifacts, no wall-clock sleeps (timing runs on the
//! `ManualClock`):
//!
//! * tickets collect out of order, across problems and shards, with
//!   results matched to the ticket, never to arrival order;
//! * many tickets sit in flight across coalescing groups on a parked
//!   virtual clock, and one `advance` flushes every group's merged batch
//!   (deterministic submit→collect latency gauge included);
//! * a shard dying with a ticket in flight fails it with the typed,
//!   healable `ServiceError::ShardDown`, and later submits fail fast;
//! * the `XlaEngine` facade heals a mid-flight kill on the collect side
//!   (re-register onto a survivor + repeat the batch);
//! * a pipelined (micro-batched) optimization run is bit-identical to the
//!   blocking run and to the direct native engine.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use axdt::coordinator::{
    optimize_dataset, CoalesceMode, EngineChoice, EvalService, PoolOptions, RunOptions,
    ServiceError, XlaEngine,
};
use axdt::fitness::native::NativeEngine;
use axdt::fitness::AccuracyEngine;
use axdt::util::clock::ManualClock;
use axdt::util::testbed::{named_problem, random_batch, spawn_killable_native, wait_until};

/// Tickets are not FIFO: submit to two problems, collect in reverse, and
/// every result still belongs to its own batch (bit-identical to the
/// direct native engine).
#[test]
fn tickets_collect_out_of_order_across_problems() {
    let svc = EvalService::spawn_native_with(
        8,
        &PoolOptions {
            workers: 2,
            coalesce: CoalesceMode::Off,
            engine_threads: 1,
            ..PoolOptions::default()
        },
    );
    let pa = named_problem("drv0");
    let pb = named_problem("drv1");
    let (id_a, _) = svc.register(Arc::clone(&pa)).unwrap();
    let (id_b, _) = svc.register(Arc::clone(&pb)).unwrap();
    let batch_a = random_batch(&pa, 7, 11);
    let batch_b = random_batch(&pb, 9, 12);
    let mut direct = NativeEngine::default();
    let want_a = direct.batch_accuracy(&pa, &batch_a).unwrap();
    let want_b = direct.batch_accuracy(&pb, &batch_b).unwrap();

    let ta = svc.submit(id_a, batch_a).unwrap();
    let tb = svc.submit(id_b, batch_b).unwrap();
    // Reverse order: the second ticket resolves first.
    assert_eq!(svc.wait(tb).unwrap(), want_b);
    assert_eq!(svc.wait(ta).unwrap(), want_a);
    assert_eq!(svc.metrics.tickets_submitted.load(Ordering::Relaxed), 2);
    assert_eq!(svc.metrics.tickets_in_flight.load(Ordering::Relaxed), 0);
    svc.shutdown();
}

/// Five tickets in flight across two coalescing groups on a parked
/// `ManualClock`: nothing flushes until the advance, then both groups
/// flush as merged deadline batches and every ticket resolves (out of
/// order) with exact, deterministic submit→collect latency.
#[test]
fn many_tickets_across_coalescing_groups_on_manual_clock() {
    let clock = Arc::new(ManualClock::new());
    let svc = EvalService::spawn_native_with_clock(
        32,
        &PoolOptions {
            workers: 1,
            coalesce: CoalesceMode::Fixed,
            coalesce_window_us: 200,
            engine_threads: 1,
            ..PoolOptions::default()
        },
        Arc::clone(&clock) as Arc<dyn axdt::util::clock::Clock>,
    );
    let pa = named_problem("groupA");
    let pb = named_problem("groupB");
    let (id_a, _) = svc.register(Arc::clone(&pa)).unwrap();
    let (id_b, _) = svc.register(Arc::clone(&pb)).unwrap();
    let mut direct = NativeEngine::default();

    let batches_a: Vec<_> = (0..3).map(|i| random_batch(&pa, 5, 20 + i)).collect();
    let batches_b: Vec<_> = (0..2).map(|i| random_batch(&pb, 5, 40 + i)).collect();
    let tickets_a: Vec<_> = batches_a
        .iter()
        .map(|b| svc.submit(id_a, b.clone()).unwrap())
        .collect();
    let tickets_b: Vec<_> = batches_b
        .iter()
        .map(|b| svc.submit(id_b, b.clone()).unwrap())
        .collect();
    assert_eq!(svc.metrics.tickets_in_flight.load(Ordering::Relaxed), 5);
    assert_eq!(svc.metrics.tickets_peak.load(Ordering::Relaxed), 5);

    // All 25 chromosomes reach the coalescer; with the clock parked,
    // nothing may execute.
    wait_until("25 chromosomes coalescing", || {
        svc.metrics.shards()[0].coalescing.load(Ordering::Relaxed) == 25
    });
    assert_eq!(svc.metrics.executions.load(Ordering::Relaxed), 0);

    // One virtual advance past the window flushes BOTH groups as merged
    // deadline batches.
    clock.advance(Duration::from_micros(250));
    for (t, b) in tickets_b.into_iter().zip(&batches_b) {
        assert_eq!(svc.wait(t).unwrap(), direct.batch_accuracy(&pb, b).unwrap());
    }
    for (t, b) in tickets_a.into_iter().zip(&batches_a) {
        assert_eq!(svc.wait(t).unwrap(), direct.batch_accuracy(&pa, b).unwrap());
    }
    assert_eq!(svc.metrics.executions.load(Ordering::Relaxed), 2, "one per group");
    assert_eq!(svc.metrics.deadline_flushes.load(Ordering::Relaxed), 2);
    assert_eq!(svc.metrics.coalesced_executions.load(Ordering::Relaxed), 2);
    assert_eq!(svc.metrics.tickets_in_flight.load(Ordering::Relaxed), 0);
    // Virtual time makes the ticket gauges exact: every ticket was
    // submitted at t=0 and collected after the 250us advance, in
    // micro-batches of 5.  The log₂ histograms keep the exact max and
    // per-sample counts, so both are assertable without wall time.
    let lat = svc.metrics.ticket_latency_hist();
    assert_eq!(lat.count(), 5);
    assert_eq!(lat.max, 250_000);
    assert_eq!(lat.percentile(1.0), 250_000);
    let widths = svc.metrics.microbatch_width_hist();
    assert_eq!(widths.count(), 5);
    assert_eq!(widths.max, 5);
    svc.shutdown();
}

/// A shard dying with a ticket in flight answers it with the typed,
/// healable `ShardDown`; submits against the dead shard then fail fast at
/// submit time, not at wait time.
#[test]
fn mid_flight_shard_kill_fails_ticket_with_shard_down() {
    let kill = Arc::new(AtomicU64::new(0));
    let pool = spawn_killable_native(
        8,
        &PoolOptions {
            workers: 1,
            coalesce: CoalesceMode::Off,
            engine_threads: 1,
            ..PoolOptions::default()
        },
        Arc::clone(&kill),
    );
    let svc = EvalService::from_pool(pool);
    let p = named_problem("seeds");
    let (id, _) = svc.register(Arc::clone(&p)).unwrap();
    let batch = random_batch(&p, 8, 3);
    let mut direct = NativeEngine::default();
    assert_eq!(
        svc.wait(svc.submit(id, batch.clone()).unwrap()).unwrap(),
        direct.batch_accuracy(&p, &batch).unwrap()
    );

    kill.store(1, Ordering::SeqCst); // shard 0 + 1
    let ticket = svc.submit_typed(id, batch.clone()).unwrap();
    let err = svc.wait_typed(ticket).unwrap_err();
    assert!(matches!(err, ServiceError::ShardDown { shard: 0 }), "{err:?}");
    assert!(err.is_stale_id(), "clients must heal ShardDown by re-registering");
    assert!(!svc.pool().shard_alive(0));
    assert!(svc.metrics.stranded_requests.load(Ordering::Relaxed) >= 1);

    // The death is already visible at submit time for later tickets.
    let err = svc.submit_typed(id, batch).unwrap_err();
    assert!(matches!(err, ServiceError::ShardDown { shard: 0 }), "{err:?}");
    svc.shutdown();
}

/// The engine facade heals a mid-flight kill on the COLLECT side:
/// re-register onto a survivor and repeat the retained batch, so the
/// caller sees correct results, never the ShardDown.  With SEVERAL
/// tickets in flight on the dying shard, only the first collected
/// failure re-registers — the rest retry under the moved registration —
/// so one pipelining driver never inflates the coalescing group's
/// member count.
#[test]
fn engine_collect_heals_mid_flight_shard_kill() {
    let kill = Arc::new(AtomicU64::new(0));
    let pool = spawn_killable_native(
        8,
        &PoolOptions {
            workers: 4,
            coalesce: CoalesceMode::Off,
            engine_threads: 1,
            ..PoolOptions::default()
        },
        Arc::clone(&kill),
    );
    let svc = EvalService::from_pool(pool);
    let p = named_problem("seeds");
    let mut engine = XlaEngine::register(&svc, Arc::clone(&p)).unwrap();
    let victim = engine.shard();
    let batch = random_batch(&p, 8, 9);
    let mut direct = NativeEngine::default();
    let want = direct.batch_accuracy(&p, &batch).unwrap();

    kill.store(victim as u64 + 1, Ordering::SeqCst);
    let t1 = engine.submit_accuracy(&p, &batch[..4]);
    let t2 = engine.submit_accuracy(&p, &batch[4..]);
    assert_eq!(engine.collect(t1).unwrap(), want[..4].to_vec());
    assert_eq!(engine.collect(t2).unwrap(), want[4..].to_vec());
    assert_ne!(engine.shard(), victim, "healed onto a survivor");
    assert!(!svc.pool().shard_alive(victim));
    assert_eq!(svc.metrics.shard_deaths.load(Ordering::Relaxed), 1);
    assert_eq!(
        svc.metrics.problems.load(Ordering::Relaxed),
        2,
        "initial registration + exactly ONE heal for both failed tickets"
    );
    svc.shutdown();
}

/// Acceptance (ISSUE 5): the pipelined path is bit-identical to the
/// blocking path and to the native engine on the same seed — micro-batch
/// slicing, ticket interleaving, and coalescing never change the
/// per-chromosome arithmetic.
#[test]
fn pipelined_blocking_native_fronts_bit_identical() {
    let opts = RunOptions {
        seed: 42,
        pop_size: 16,
        generations: 5,
        margin_max: 5,
        engine: EngineChoice::NativeService,
        microbatch: 0,
    };
    let native = optimize_dataset(
        "seeds",
        &RunOptions { engine: EngineChoice::Native, ..opts.clone() },
        None,
    )
    .unwrap();

    let svc = EvalService::spawn_native_with(
        8,
        &PoolOptions { workers: 2, engine_threads: 1, ..PoolOptions::default() },
    );
    // Blocking: one whole-generation submit per evaluate call.
    let blocking = optimize_dataset(
        "seeds",
        &RunOptions { microbatch: 1_000_000, ..opts.clone() },
        Some(&svc),
    )
    .unwrap();
    // Pipelined: tiny micro-batches, many tickets in flight per
    // generation.
    let piped =
        optimize_dataset("seeds", &RunOptions { microbatch: 4, ..opts }, Some(&svc)).unwrap();

    for run in [&blocking, &piped] {
        assert_eq!(native.front.len(), run.front.len());
        for (a, b) in native.front.iter().zip(&run.front) {
            assert_eq!(a.accuracy, b.accuracy);
            assert_eq!(a.est_area_mm2, b.est_area_mm2);
        }
    }
    assert_eq!(blocking.stats.engine_evals, piped.stats.engine_evals);
    assert!(piped.stats.engine_evals > 0);
    assert!(svc.metrics.tickets_submitted.load(Ordering::Relaxed) > 0);
    // The driver folded both runs' EvalStats into the service render.
    let render = svc.metrics.render();
    assert!(render.contains("eval: requested="), "{render}");
    svc.shutdown();
}
