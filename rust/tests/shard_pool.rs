//! Integration contracts of the sharded eval pool, via the public API
//! only and with no artifacts required (native backend):
//!
//! * hash-routing is stable: the same problem name always pins to the
//!   same shard, and re-registration lands on the worker that already
//!   owns the problem's buffers;
//! * problems spread across N workers and evaluate correctly under
//!   concurrent drivers;
//! * the coalescer flushes on width-full and on deadline expiry, merging
//!   concurrent sub-width batches into fewer, fuller executions;
//! * shutdown drains in-flight jobs instead of stranding blocked clients;
//! * service failures are typed ([`ServiceError`]) with stable Display.

use std::sync::atomic::Ordering;
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use axdt::coordinator::{EvalService, PoolOptions, ServiceError};
use axdt::fitness::native::NativeEngine;
use axdt::fitness::AccuracyEngine;
use axdt::util::testbed::{named_problem, random_batch, DRIVER_NAMES};

#[test]
fn hash_route_is_stable_and_problems_pin_to_shards() {
    let svc = EvalService::spawn_native_with(
        8,
        &PoolOptions {
            workers: 4,
            coalesce_window_us: 0,
            engine_threads: 1,
            ..PoolOptions::default()
        },
    );
    assert_eq!(svc.workers(), 4);
    let mut shards_seen = std::collections::BTreeSet::new();
    for name in DRIVER_NAMES {
        let p = named_problem(name);
        let (id1, _) = svc.register(Arc::clone(&p)).unwrap();
        let (id2, _) = svc.register(Arc::clone(&p)).unwrap();
        assert_ne!(id1, id2, "each registration gets a fresh id");
        assert_eq!(
            id1.shard(),
            id2.shard(),
            "{name}: re-registration must stay on the owning shard"
        );
        assert!(id1.shard() < 4);
        shards_seen.insert(id1.shard());

        let batch = random_batch(&p, 5, 7);
        let got = svc.eval(id1, batch.clone()).unwrap();
        let mut direct = NativeEngine::default();
        assert_eq!(got, direct.batch_accuracy(&p, &batch).unwrap());
    }
    // The pinned hash spreads these 8 names over all 4 shards (routing is
    // a stability contract: device buffers live on the owning shard).
    assert_eq!(shards_seen.len(), 4, "shards used: {shards_seen:?}");
    assert_eq!(svc.metrics.problems.load(Ordering::Relaxed), 16);
    svc.shutdown();
}

#[test]
fn concurrent_drivers_on_problems_across_workers() {
    let svc = EvalService::spawn_native_with(
        8,
        &PoolOptions {
            workers: 4,
            coalesce_window_us: 200,
            engine_threads: 1,
            ..PoolOptions::default()
        },
    );
    let problems: Vec<_> = DRIVER_NAMES
        .iter()
        .map(|name| {
            let p = named_problem(name);
            let (id, _) = svc.register(Arc::clone(&p)).unwrap();
            (p, id)
        })
        .collect();

    std::thread::scope(|s| {
        for (t, (p, id)) in problems.iter().enumerate() {
            let svc = svc.clone();
            let p = Arc::clone(p);
            let id = *id;
            s.spawn(move || {
                for round in 0..3u64 {
                    let batch = random_batch(&p, 11, 1000 + t as u64 * 10 + round);
                    let got = svc.eval(id, batch.clone()).unwrap();
                    let mut direct = NativeEngine::default();
                    assert_eq!(got, direct.batch_accuracy(&p, &batch).unwrap());
                }
            });
        }
    });
    assert_eq!(svc.metrics.problems.load(Ordering::Relaxed), 8);
    // 8 drivers x 3 rounds x 11 chromosomes all arrived.
    assert_eq!(svc.metrics.chromosomes.load(Ordering::Relaxed), 8 * 3 * 11);
    // Work landed on more than one shard.
    let active = svc
        .metrics
        .shards()
        .iter()
        .filter(|s| s.executions.load(Ordering::Relaxed) > 0)
        .count();
    assert!(active >= 2, "only {active} shard(s) executed work");
    svc.shutdown();
}

/// Two concurrent sub-width requests (5 + 5 at width 8) merge: one
/// width-full flush, then the 2-item remainder on the deadline.
#[test]
fn coalescer_flushes_on_full_width_and_merges_requests() {
    let svc = EvalService::spawn_native_with(
        8,
        &PoolOptions {
            workers: 1,
            coalesce_window_us: 400_000,
            engine_threads: 1,
            ..PoolOptions::default()
        },
    );
    let p = named_problem("seeds");
    let (id, _) = svc.register(Arc::clone(&p)).unwrap();

    let barrier = Arc::new(Barrier::new(2));
    std::thread::scope(|s| {
        for t in 0..2u64 {
            let svc = svc.clone();
            let p = Arc::clone(&p);
            let barrier = Arc::clone(&barrier);
            s.spawn(move || {
                let batch = random_batch(&p, 5, 2000 + t);
                barrier.wait();
                let got = svc.eval(id, batch.clone()).unwrap();
                let mut direct = NativeEngine::default();
                assert_eq!(got, direct.batch_accuracy(&p, &batch).unwrap());
            });
        }
    });

    let m = &svc.metrics;
    assert_eq!(m.executions.load(Ordering::Relaxed), 2, "8 + 2, not 5 + 5");
    assert_eq!(m.full_flushes.load(Ordering::Relaxed), 1);
    assert_eq!(m.deadline_flushes.load(Ordering::Relaxed), 1);
    assert!(m.coalesced_executions.load(Ordering::Relaxed) >= 1);
    assert_eq!(m.chromosomes.load(Ordering::Relaxed), 10);
    // Merged dispatch pads 8->8 and 2->8 (6 wasted); uncoalesced would
    // have padded 5->8 twice (also 6) but in two extra-small executions —
    // the win shows up as fewer, fuller executions.
    assert_eq!(m.padded_slots.load(Ordering::Relaxed), 6);
    svc.shutdown();
}

#[test]
fn coalescer_flushes_on_deadline() {
    let svc = EvalService::spawn_native_with(
        8,
        &PoolOptions {
            workers: 1,
            coalesce_window_us: 60_000,
            engine_threads: 1,
            ..PoolOptions::default()
        },
    );
    let p = named_problem("seeds");
    let (id, _) = svc.register(Arc::clone(&p)).unwrap();

    let batch = random_batch(&p, 3, 31);
    let t0 = Instant::now();
    let got = svc.eval(id, batch.clone()).unwrap();
    let waited = t0.elapsed();
    let mut direct = NativeEngine::default();
    assert_eq!(got, direct.batch_accuracy(&p, &batch).unwrap());
    assert!(
        waited >= Duration::from_millis(40),
        "sub-width batch must wait out the window (waited {waited:?})"
    );
    assert_eq!(svc.metrics.executions.load(Ordering::Relaxed), 1);
    assert_eq!(svc.metrics.deadline_flushes.load(Ordering::Relaxed), 1);
    assert_eq!(svc.metrics.full_flushes.load(Ordering::Relaxed), 0);
    svc.shutdown();
}

/// Shutdown with a sub-width batch still waiting on its coalescing window
/// must flush it (the blocked client gets its results), not strand it.
#[test]
fn shutdown_flushes_in_flight_jobs() {
    let svc = EvalService::spawn_native_with(
        8,
        // Deliberately absurd window: only the shutdown drain can flush
        // within the test's lifetime.
        &PoolOptions {
            workers: 2,
            coalesce_window_us: 1_000_000,
            engine_threads: 1,
            ..PoolOptions::default()
        },
    );
    let p = named_problem("seeds");
    let (id, _) = svc.register(Arc::clone(&p)).unwrap();

    let t0 = Instant::now();
    std::thread::scope(|s| {
        let worker_svc = svc.clone();
        let p2 = Arc::clone(&p);
        let h = s.spawn(move || {
            let batch = random_batch(&p2, 3, 77);
            let got = worker_svc.eval(id, batch.clone()).unwrap();
            let mut direct = NativeEngine::default();
            assert_eq!(got, direct.batch_accuracy(&p2, &batch).unwrap());
        });
        std::thread::sleep(Duration::from_millis(100));
        svc.shutdown();
        h.join().unwrap();
    });
    assert!(
        t0.elapsed() < Duration::from_millis(900),
        "shutdown must flush pending work early, not wait out the window"
    );
    assert_eq!(svc.metrics.executions.load(Ordering::Relaxed), 1);
    // A shutdown drain is not a window expiry.
    assert_eq!(svc.metrics.deadline_flushes.load(Ordering::Relaxed), 0);

    // After shutdown both register and eval fail (typed, not hanging).
    assert!(svc.register(Arc::clone(&p)).is_err());
    assert!(svc.eval(id, random_batch(&p, 2, 78)).is_err());
}

#[test]
fn service_errors_are_typed_with_stable_display() {
    let opts = PoolOptions {
        workers: 2,
        coalesce_window_us: 0,
        engine_threads: 1,
        ..PoolOptions::default()
    };
    let a = EvalService::spawn_native_with(8, &opts);
    let b = EvalService::spawn_native_with(8, &opts);
    let p = named_problem("seeds");
    let (id_b, _) = b.register(Arc::clone(&p)).unwrap();

    let err = a.eval(id_b, random_batch(&p, 3, 5)).unwrap_err();
    let service_err = err
        .downcast_ref::<ServiceError>()
        .expect("service failures must be typed");
    assert!(
        matches!(service_err, ServiceError::ForeignProblemId { .. }),
        "{service_err:?}"
    );
    assert!(service_err.is_stale_id());
    assert!(format!("{err:#}").contains("different EvalService"), "{err:#}");

    a.shutdown();
    b.shutdown();
}
