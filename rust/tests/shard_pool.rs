//! Integration contracts of the sharded eval pool, via the public API
//! only and with no artifacts required (native backend):
//!
//! * hash-routing is stable: the same problem name always pins to the
//!   same shard, and re-registration lands on the worker that already
//!   owns the problem's buffers;
//! * the liveness-aware routing function satisfies its rendezvous
//!   properties for random shard counts and kill orders (seeded
//!   property test — no ambient randomness);
//! * problems spread across N workers and evaluate correctly under
//!   concurrent drivers;
//! * the coalescer flushes on width-full and on deadline expiry, merging
//!   concurrent sub-width batches into fewer, fuller executions;
//! * shutdown drains in-flight jobs instead of stranding blocked clients;
//! * service failures are typed ([`ServiceError`]) with stable Display.
//!
//! Every deadline-dependent assertion runs on a `ManualClock`: virtual
//! time only moves when the test advances it, so there are no
//! wall-clock-timing races and zero `thread::sleep` calls.

use std::sync::atomic::Ordering;
use std::sync::{Arc, Barrier};
use std::time::Duration;

use axdt::coordinator::shard::{rendezvous_route, rendezvous_score};
use axdt::coordinator::{EvalService, PoolOptions, ServiceError};
use axdt::fitness::native::NativeEngine;
use axdt::fitness::AccuracyEngine;
use axdt::util::clock::ManualClock;
use axdt::util::prop::{check, PropConfig};
use axdt::util::testbed::{named_problem, random_batch, wait_until, DRIVER_NAMES};

#[test]
fn hash_route_is_stable_and_problems_pin_to_shards() {
    let svc = EvalService::spawn_native_with(
        8,
        &PoolOptions {
            workers: 4,
            coalesce_window_us: 0,
            engine_threads: 1,
            ..PoolOptions::default()
        },
    );
    assert_eq!(svc.workers(), 4);
    let mut shards_seen = std::collections::BTreeSet::new();
    for name in DRIVER_NAMES {
        let p = named_problem(name);
        let (id1, _) = svc.register(Arc::clone(&p)).unwrap();
        let (id2, _) = svc.register(Arc::clone(&p)).unwrap();
        assert_ne!(id1, id2, "each registration gets a fresh id");
        assert_eq!(
            id1.shard(),
            id2.shard(),
            "{name}: re-registration must stay on the owning shard"
        );
        assert!(id1.shard() < 4);
        shards_seen.insert(id1.shard());

        let batch = random_batch(&p, 5, 7);
        let got = svc.eval(id1, batch.clone()).unwrap();
        let mut direct = NativeEngine::default();
        assert_eq!(got, direct.batch_accuracy(&p, &batch).unwrap());
    }
    // The pinned hash spreads these 8 names over all 4 shards (routing is
    // a stability contract: device buffers live on the owning shard).
    assert_eq!(shards_seen.len(), 4, "shards used: {shards_seen:?}");
    assert_eq!(svc.metrics.problems.load(Ordering::Relaxed), 16);
    svc.shutdown();
}

/// Property-style randomized check of the pool's pure routing function
/// (`register` routes through exactly it): for random shard counts and
/// kill orders —
///
/// * a route always lands on a live shard (or `None` when all are dead);
/// * survivors' routes never move: a name whose current route is still
///   alive after the next kill keeps it;
/// * a name whose home shard is dead re-routes to the rendezvous-best
///   live shard (the argmax of the pinned score over the live set).
///
/// Seeded through `util::prop` (replay with `AXDT_PROP_SEED`); no
/// ambient `Math.random`-style nondeterminism anywhere.
#[test]
fn rendezvous_routing_properties_hold_for_random_kill_orders() {
    let names: Vec<String> = (0..32).map(|i| format!("prob{i}")).collect();
    check(
        "rendezvous-routing",
        PropConfig { cases: 64, seed: 0xC0A1 },
        |rng| {
            let shards = rng.int_in(1, 8) as usize;
            let mut order: Vec<usize> = (0..shards).collect();
            rng.shuffle(&mut order);
            (shards, order)
        },
        |&(shards, ref order)| {
            let all_alive = vec![true; shards];
            let mut alive = all_alive.clone();
            let mut routes: Vec<usize> = Vec::with_capacity(names.len());
            for name in &names {
                let home = rendezvous_route(name, &alive)
                    .ok_or_else(|| "no route with every shard alive".to_string())?;
                if home >= shards {
                    return Err(format!("{name}: home {home} out of range"));
                }
                routes.push(home);
            }
            for &kill in order {
                alive[kill] = false;
                let any_live = alive.iter().any(|&a| a);
                for (i, name) in names.iter().enumerate() {
                    match rendezvous_route(name, &alive) {
                        None => {
                            if any_live {
                                return Err(format!(
                                    "{name}: no route though live shards remain"
                                ));
                            }
                        }
                        Some(s) => {
                            if !any_live {
                                return Err(format!("{name}: routed on a dead pool"));
                            }
                            if !alive[s] {
                                return Err(format!("{name}: routed to dead shard {s}"));
                            }
                            // Survivor stability under this kill.
                            let prev = routes[i];
                            if alive[prev] && prev != s {
                                return Err(format!(
                                    "{name}: route moved {prev} -> {s} though {prev} \
                                     is still alive"
                                ));
                            }
                            // Re-routes land on the rendezvous argmax.
                            let home = rendezvous_route(name, &all_alive)
                                .expect("all-alive route exists");
                            if !alive[home] {
                                for (t, &ok) in alive.iter().enumerate() {
                                    if ok
                                        && rendezvous_score(name, t)
                                            > rendezvous_score(name, s)
                                    {
                                        return Err(format!(
                                            "{name}: re-route {s} is not the \
                                             rendezvous-best live shard ({t} scores \
                                             higher)"
                                        ));
                                    }
                                }
                            }
                            routes[i] = s;
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn concurrent_drivers_on_problems_across_workers() {
    let svc = EvalService::spawn_native_with(
        8,
        &PoolOptions {
            workers: 4,
            coalesce_window_us: 200,
            engine_threads: 1,
            ..PoolOptions::default()
        },
    );
    let problems: Vec<_> = DRIVER_NAMES
        .iter()
        .map(|name| {
            let p = named_problem(name);
            let (id, _) = svc.register(Arc::clone(&p)).unwrap();
            (p, id)
        })
        .collect();

    std::thread::scope(|s| {
        for (t, (p, id)) in problems.iter().enumerate() {
            let svc = svc.clone();
            let p = Arc::clone(p);
            let id = *id;
            s.spawn(move || {
                for round in 0..3u64 {
                    let batch = random_batch(&p, 11, 1000 + t as u64 * 10 + round);
                    let got = svc.eval(id, batch.clone()).unwrap();
                    let mut direct = NativeEngine::default();
                    assert_eq!(got, direct.batch_accuracy(&p, &batch).unwrap());
                }
            });
        }
    });
    assert_eq!(svc.metrics.problems.load(Ordering::Relaxed), 8);
    // 8 drivers x 3 rounds x 11 chromosomes all arrived.
    assert_eq!(svc.metrics.chromosomes.load(Ordering::Relaxed), 8 * 3 * 11);
    // Work landed on more than one shard.
    let active = svc
        .metrics
        .shards()
        .iter()
        .filter(|s| s.executions.load(Ordering::Relaxed) > 0)
        .count();
    assert!(active >= 2, "only {active} shard(s) executed work");
    svc.shutdown();
}

/// Two concurrent sub-width requests (5 + 5 at width 8) merge: one
/// width-full flush on their own, then the 2-item remainder exactly when
/// the test advances the virtual clock past the window.
#[test]
fn coalescer_flushes_on_full_width_and_merges_requests() {
    let clock = Arc::new(ManualClock::new());
    let svc = EvalService::spawn_native_with_clock(
        8,
        &PoolOptions {
            workers: 1,
            coalesce_window_us: 400_000,
            engine_threads: 1,
            ..PoolOptions::default()
        },
        Arc::clone(&clock),
    );
    let p = named_problem("seeds");
    let (id, _) = svc.register(Arc::clone(&p)).unwrap();

    let barrier = Arc::new(Barrier::new(2));
    std::thread::scope(|s| {
        for t in 0..2u64 {
            let svc = svc.clone();
            let p = Arc::clone(&p);
            let barrier = Arc::clone(&barrier);
            s.spawn(move || {
                let batch = random_batch(&p, 5, 2000 + t);
                barrier.wait();
                let got = svc.eval(id, batch.clone()).unwrap();
                let mut direct = NativeEngine::default();
                assert_eq!(got, direct.batch_accuracy(&p, &batch).unwrap());
            });
        }
        // The width-full flush (8 of 10) needs no time at all; the 2-item
        // remainder sits in the coalescer until the window expires — which
        // only the test can make happen.
        wait_until("width-full flush done, remainder coalescing", || {
            svc.metrics.full_flushes.load(Ordering::Relaxed) == 1
                && svc.metrics.shards()[0].coalescing.load(Ordering::Relaxed) == 2
        });
        assert_eq!(svc.metrics.executions.load(Ordering::Relaxed), 1);
        clock.advance(Duration::from_micros(400_000));
    });

    let m = &svc.metrics;
    assert_eq!(m.executions.load(Ordering::Relaxed), 2, "8 + 2, not 5 + 5");
    assert_eq!(m.full_flushes.load(Ordering::Relaxed), 1);
    assert_eq!(m.deadline_flushes.load(Ordering::Relaxed), 1);
    assert!(m.coalesced_executions.load(Ordering::Relaxed) >= 1);
    assert_eq!(m.chromosomes.load(Ordering::Relaxed), 10);
    // Merged dispatch pads 8->8 and 2->8 (6 wasted); uncoalesced would
    // have padded 5->8 twice (also 6) but in two extra-small executions —
    // the win shows up as fewer, fuller executions.
    assert_eq!(m.padded_slots.load(Ordering::Relaxed), 6);
    svc.shutdown();
}

/// A lone sub-width batch flushes exactly at the window boundary on the
/// virtual clock: nothing at window - 1 ns, the deadline flush at window.
#[test]
fn coalescer_flushes_on_deadline() {
    let clock = Arc::new(ManualClock::new());
    let svc = EvalService::spawn_native_with_clock(
        8,
        &PoolOptions {
            workers: 1,
            coalesce_window_us: 60_000,
            engine_threads: 1,
            ..PoolOptions::default()
        },
        Arc::clone(&clock),
    );
    let p = named_problem("seeds");
    let (id, _) = svc.register(Arc::clone(&p)).unwrap();

    let batch = random_batch(&p, 3, 31);
    std::thread::scope(|s| {
        let eval_svc = svc.clone();
        let b = batch.clone();
        let h = s.spawn(move || eval_svc.eval(id, b).unwrap());
        // The batch reaches the coalescer (window armed at virtual t=0).
        wait_until("batch coalescing", || {
            svc.metrics.shards()[0].coalescing.load(Ordering::Relaxed) == 3
        });
        // One nanosecond short of the window: flushing is impossible.
        clock.advance(Duration::from_nanos(60_000 * 1_000 - 1));
        // Synchronize before the negative assert: a register round-trip
        // through the same worker (FIFO channel) proves the clock nudge
        // was consumed and the deadline re-checked at window - 1 ns.
        svc.register(named_problem("sync")).unwrap();
        assert_eq!(svc.metrics.executions.load(Ordering::Relaxed), 0);
        // The final nanosecond expires the deadline.
        clock.advance(Duration::from_nanos(1));
        let got = h.join().unwrap();
        let mut direct = NativeEngine::default();
        assert_eq!(got, direct.batch_accuracy(&p, &batch).unwrap());
    });
    assert_eq!(svc.metrics.executions.load(Ordering::Relaxed), 1);
    assert_eq!(svc.metrics.deadline_flushes.load(Ordering::Relaxed), 1);
    assert_eq!(svc.metrics.full_flushes.load(Ordering::Relaxed), 0);
    svc.shutdown();
}

/// Shutdown with a sub-width batch still waiting on its coalescing window
/// must flush it (the blocked client gets its results), not strand it.
/// The window is virtual and the clock never moves, so ONLY the shutdown
/// drain can be what flushed it.
#[test]
fn shutdown_flushes_in_flight_jobs() {
    let clock = Arc::new(ManualClock::new());
    let svc = EvalService::spawn_native_with_clock(
        8,
        &PoolOptions {
            workers: 2,
            coalesce_window_us: 1_000_000,
            engine_threads: 1,
            ..PoolOptions::default()
        },
        Arc::clone(&clock),
    );
    let p = named_problem("seeds");
    let (id, _) = svc.register(Arc::clone(&p)).unwrap();

    std::thread::scope(|s| {
        let worker_svc = svc.clone();
        let p2 = Arc::clone(&p);
        let h = s.spawn(move || {
            let batch = random_batch(&p2, 3, 77);
            let got = worker_svc.eval(id, batch.clone()).unwrap();
            let mut direct = NativeEngine::default();
            assert_eq!(got, direct.batch_accuracy(&p2, &batch).unwrap());
        });
        // The batch is in the coalescer with its (virtual, never-expiring)
        // window armed; shutdown must flush it.
        wait_until("batch coalescing", || {
            svc.metrics.shards()[id.shard()].coalescing.load(Ordering::Relaxed) == 3
        });
        svc.shutdown();
        h.join().unwrap();
    });
    assert_eq!(svc.metrics.executions.load(Ordering::Relaxed), 1);
    // A shutdown drain is not a window expiry.
    assert_eq!(svc.metrics.deadline_flushes.load(Ordering::Relaxed), 0);

    // After shutdown both register and eval fail (typed, not hanging).
    assert!(svc.register(Arc::clone(&p)).is_err());
    assert!(svc.eval(id, random_batch(&p, 2, 78)).is_err());
}

#[test]
fn service_errors_are_typed_with_stable_display() {
    let opts = PoolOptions {
        workers: 2,
        coalesce_window_us: 0,
        engine_threads: 1,
        ..PoolOptions::default()
    };
    let a = EvalService::spawn_native_with(8, &opts);
    let b = EvalService::spawn_native_with(8, &opts);
    let p = named_problem("seeds");
    let (id_b, _) = b.register(Arc::clone(&p)).unwrap();

    let err = a.eval(id_b, random_batch(&p, 3, 5)).unwrap_err();
    let service_err = err
        .downcast_ref::<ServiceError>()
        .expect("service failures must be typed");
    assert!(
        matches!(service_err, ServiceError::ForeignProblemId { .. }),
        "{service_err:?}"
    );
    assert!(service_err.is_stale_id());
    assert!(format!("{err:#}").contains("different EvalService"), "{err:#}");

    a.shutdown();
    b.shutdown();
}
