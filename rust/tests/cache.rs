//! Tiered persistent eval-cache contracts (the cache tentpole), through
//! the public API:
//!
//! * **L2 durability**: a corrupted, truncated, or wrong-magic segment
//!   loses exactly the bad tail — the loader keeps the good prefix and
//!   counts one error, never fails the run;
//! * **concurrency**: N driver threads hammering one shared cache on a
//!   `ManualClock` publish each distinct phenotype exactly once and,
//!   once warm, hit L1 an exactly predictable number of times (this
//!   suite runs under ThreadSanitizer nightly — see Makefile `tsan`);
//! * **repeat runs**: a spill → load → re-optimize cycle performs zero
//!   engine evaluations (every hit attributed to L2) and reproduces the
//!   front bit-exactly;
//! * **warm start**: a GA seeded from a cold run's archived front
//!   reaches the cold run's final hypervolume in half the generations,
//!   bit-reproducibly.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::Ordering::Relaxed;
use std::sync::Arc;

use axdt::coordinator::{optimize_dataset, EngineChoice, EvalService, Metrics, RunOptions};
use axdt::fitness::cache::{DatasetFingerprint, EvalCache};
use axdt::fitness::{native::NativeEngine, FitnessEvaluator, SharedCache};
use axdt::ga::{Chromosome, Evaluator};
use axdt::hw::{AreaLut, EgtLibrary};
use axdt::util::clock::{Clock, ManualClock};
use axdt::util::rng::Pcg64;
use axdt::util::testbed::named_problem;

fn tmp_dir(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("axdt_cache_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn quick_opts() -> RunOptions {
    RunOptions { pop_size: 16, generations: 6, ..RunOptions::default() }
}

/// Segment layout constants mirrored from `fitness::cache`: an 8-byte
/// magic, then 44-byte records (4-byte length + 32-byte payload + 8-byte
/// FNV checksum).  A layout change bumps the magic, which this test
/// would catch as a whole-file rejection.
const MAGIC_LEN: usize = 8;
const REC_LEN: usize = 4 + 32 + 8;

#[test]
fn corrupt_and_truncated_segments_lose_only_the_bad_tail() {
    let dir = tmp_dir("durability");
    let fp = DatasetFingerprint::compute("seeds", 42, 210, 8);
    let cache = EvalCache::persistent(&dir);
    let n = 10u64;
    // Keys 1..=10: spill sorts records by key, so record j holds key j+1.
    for i in 0..n {
        cache.publish(fp, i as u128 + 1, [i as f64 * 0.01, 2.0 + i as f64]);
    }
    let spilled = cache.spill().unwrap();
    assert_eq!((spilled.segments, spilled.records), (1, n));
    let seg = dir.join(format!("{}.seg", fp.hex()));
    let pristine = std::fs::read(&seg).unwrap();
    assert_eq!(pristine.len(), MAGIC_LEN + REC_LEN * n as usize);

    // Pristine reload: every record, as L2, zero errors.
    let clean = EvalCache::persistent(&dir);
    let rep = clean.load();
    assert_eq!((rep.segments, rep.records, rep.errors), (1, n, 0));
    assert_eq!(clean.len(), n as usize);

    // One flipped payload bit in record 6: its checksum fails, records
    // 0..6 survive, everything after the corruption is distrusted, and
    // exactly one error is counted for the caller to surface.
    let mut corrupt = pristine.clone();
    corrupt[MAGIC_LEN + REC_LEN * 6 + 4] ^= 0x40;
    std::fs::write(&seg, &corrupt).unwrap();
    let c = EvalCache::persistent(&dir);
    let rep = c.load();
    assert_eq!((rep.records, rep.errors), (6, 1));
    assert_eq!(c.len(), 6);
    for key in 1..=6u128 {
        assert!(c.lookup(fp, key).is_some(), "good prefix key {key} survives");
    }
    assert!(c.lookup(fp, 7).is_none(), "the corrupted record is dropped");

    // A torn tail (crash mid-append): the last record is cut inside its
    // checksum; the good prefix replays with one counted error.
    std::fs::write(&seg, &pristine[..pristine.len() - 7]).unwrap();
    let t = EvalCache::persistent(&dir);
    let rep = t.load();
    assert_eq!((rep.records, rep.errors), (n - 1, 1));

    // A wrong magic (foreign or future-layout file) rejects the whole
    // segment with one error instead of misparsing it.
    let mut bad_magic = pristine.clone();
    bad_magic[0] ^= 0xFF;
    std::fs::write(&seg, &bad_magic).unwrap();
    let m = EvalCache::persistent(&dir);
    let rep = m.load();
    assert_eq!((rep.records, rep.errors), (0, 1));

    // An impossible record length likewise ends the replay at the bad
    // record, keeping what came before it.
    let mut bad_len = pristine.clone();
    bad_len[MAGIC_LEN + REC_LEN * 3] = 0xFF;
    std::fs::write(&seg, &bad_len).unwrap();
    let l = EvalCache::persistent(&dir);
    let rep = l.load();
    assert_eq!((rep.records, rep.errors), (3, 1));
    let _ = std::fs::remove_dir_all(&dir);
}

/// N concurrent drivers over ONE shared cache, timestamps from a parked
/// `ManualClock` (the cache itself never reads the OS clock).  The cold
/// racing phase must publish each distinct phenotype exactly once
/// (first-writer-wins under the stripe locks); the warm phase has fully
/// deterministic per-thread counts: every distinct phenotype is one L1
/// hit, every duplicate a per-run memo hit, zero engine evals.
#[test]
fn concurrent_drivers_share_one_cache_with_exact_warm_hits() {
    const DRIVERS: usize = 4;
    let problem = named_problem("seeds");
    let lut = AreaLut::build(&EgtLibrary::default());
    let metrics = Arc::new(Metrics::default());
    let clock: Arc<dyn Clock> = Arc::new(ManualClock::new());
    let cache = Arc::new(EvalCache::in_memory());
    let fp = DatasetFingerprint::compute("seeds", 42, 210, 8);
    let mut rng = Pcg64::seeded(0xC0FFEE);
    let pop: Vec<Chromosome> =
        (0..24).map(|_| Chromosome::random(&mut rng, problem.n_comparators())).collect();
    let wire = || SharedCache {
        cache: Arc::clone(&cache),
        fingerprint: fp,
        metrics: Arc::clone(&metrics),
        clock: Arc::clone(&clock),
    };

    // Reference evaluator (no shared tiers): the expected objectives and
    // the number of distinct phenotypes in `pop`.
    let mut probe = FitnessEvaluator::new(&problem, &lut, NativeEngine::default());
    let want = probe.evaluate(&pop);
    let distinct = probe.stats.engine_evals;
    assert!(distinct > 0);

    // Phase 1: DRIVERS cold evaluators race on the same population.
    std::thread::scope(|s| {
        for _ in 0..DRIVERS {
            s.spawn(|| {
                let mut ev =
                    FitnessEvaluator::new(&problem, &lut, NativeEngine::default());
                ev.shared = Some(wire());
                let got = ev.evaluate(&pop);
                assert_eq!(got, want, "shared tiers never change arithmetic");
                assert_eq!(ev.stats.requested, pop.len());
                assert_eq!(ev.stats.l2_hits, 0, "nothing was ever loaded from disk");
            });
        }
    });
    assert_eq!(cache.len(), distinct, "each phenotype published exactly once");
    let l1_after_cold = metrics.cache_l1_hits.load(Relaxed);

    // Phase 2: DRIVERS warm evaluators — exact counts, zero engine work.
    std::thread::scope(|s| {
        for _ in 0..DRIVERS {
            s.spawn(|| {
                let mut ev =
                    FitnessEvaluator::new(&problem, &lut, NativeEngine::default());
                ev.shared = Some(wire());
                let got = ev.evaluate(&pop);
                assert_eq!(got, want);
                assert_eq!(ev.stats.engine_evals, 0, "warm run is pure lookups");
                assert_eq!(ev.stats.l1_hits, distinct, "one L1 hit per phenotype");
                assert_eq!(ev.stats.cache_hits, pop.len() - distinct, "dupes hit the memo");
            });
        }
    });
    assert_eq!(
        metrics.cache_l1_hits.load(Relaxed),
        l1_after_cold + (DRIVERS * distinct) as u64,
        "live counter attributes every warm hit"
    );
    assert_eq!(cache.len(), distinct, "warm phase publishes nothing new");
}

/// The tentpole's acceptance cycle at integration scale: optimize, spill,
/// reload in a fresh cache (a new process, in effect), optimize again —
/// the repeat performs ZERO engine evaluations, every hit is attributed
/// to L2, and the front is bit-identical.
#[test]
fn warm_repeat_across_spill_and_load_is_engine_free() {
    let dir = tmp_dir("l2_repeat");
    let opts = |cache: &Arc<EvalCache>| RunOptions {
        engine: EngineChoice::NativeService,
        cache: Some(Arc::clone(cache)),
        ..quick_opts()
    };

    let svc = EvalService::spawn_native(8);
    let cache = Arc::new(EvalCache::persistent(&dir));
    let cold = optimize_dataset("seeds", &opts(&cache), Some(&svc)).unwrap();
    assert!(cold.stats.engine_evals > 0);
    let spilled = cache.spill().unwrap();
    assert_eq!(spilled.records as usize, cache.len());
    svc.shutdown();

    let svc2 = EvalService::spawn_native(8);
    let cache2 = Arc::new(EvalCache::persistent(&dir));
    let loaded = cache2.load();
    assert_eq!((loaded.records as usize, loaded.errors), (cache.len(), 0));
    let warm = optimize_dataset("seeds", &opts(&cache2), Some(&svc2)).unwrap();
    assert_eq!(warm.stats.engine_evals, 0, "repeat must be engine-free: {:?}", warm.stats);
    assert_eq!(warm.stats.l1_hits, 0, "nothing was produced in-process");
    assert!(warm.stats.l2_hits > 0, "every hit comes from disk");
    assert_eq!(warm.stats.requested, cold.stats.requested);
    assert_eq!(
        svc2.metrics.cache_l2_hits.load(Relaxed),
        warm.stats.l2_hits as u64
    );
    assert_eq!(cold.front.len(), warm.front.len());
    for (a, b) in cold.front.iter().zip(&warm.front) {
        assert_eq!(a.accuracy, b.accuracy, "f64 objectives round-trip bit-exactly");
        assert_eq!(a.est_area_mm2, b.est_area_mm2);
        assert_eq!(a.genes, b.genes);
    }
    svc2.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// 2D hypervolume of a front against `ref_pt` (both objectives
/// minimized), by the standard staircase sweep: sort by the first
/// objective ascending and accumulate each point's uncovered rectangle.
fn hypervolume(points: &[(f64, f64)], ref_pt: (f64, f64)) -> f64 {
    let mut pts: Vec<(f64, f64)> = points
        .iter()
        .copied()
        .filter(|p| p.0 < ref_pt.0 && p.1 < ref_pt.1)
        .collect();
    pts.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.total_cmp(&b.1)));
    let mut hv = 0.0;
    let mut prev_area = ref_pt.1;
    for (err, area) in pts {
        if area < prev_area {
            hv += (ref_pt.0 - err) * (prev_area - area);
            prev_area = area;
        }
    }
    hv
}

/// Warm-starting from a cold run's archived front reaches the cold run's
/// final hypervolume in HALF the generations: the seeded population
/// contains the whole cold front (pop 48 leaves room behind the 15
/// exact/ladder anchors), and elitist NSGA-II never lets a nondominated
/// seed regress — so the warm front weakly dominates the cold one.
/// Running the warm configuration twice must reproduce the front
/// bit-identically (seeds are injected deterministically).
#[test]
fn warm_start_reaches_cold_hypervolume_in_half_the_generations() {
    let cold = optimize_dataset(
        "seeds",
        &RunOptions { pop_size: 16, generations: 8, ..RunOptions::default() },
        None,
    )
    .unwrap();
    let mut archive: HashMap<String, Vec<Vec<f64>>> = HashMap::new();
    archive
        .insert("seeds".into(), cold.front.iter().map(|p| p.genes.clone()).collect());
    let warm_opts = RunOptions {
        pop_size: 48,
        generations: 4, // half of the cold run's 8
        warm_start: Some(Arc::new(archive)),
        ..RunOptions::default()
    };
    let warm = optimize_dataset("seeds", &warm_opts, None).unwrap();
    let warm2 = optimize_dataset("seeds", &warm_opts, None).unwrap();
    assert_eq!(warm.front.len(), warm2.front.len(), "warm start is deterministic");
    for (a, b) in warm.front.iter().zip(&warm2.front) {
        assert_eq!(a.accuracy, b.accuracy);
        assert_eq!(a.est_area_mm2, b.est_area_mm2);
        assert_eq!(a.genes, b.genes);
    }

    let objs = |run: &axdt::coordinator::DatasetRun| -> Vec<(f64, f64)> {
        run.front.iter().map(|p| (1.0 - p.accuracy, p.est_area_mm2)).collect()
    };
    let (co, wo) = (objs(&cold), objs(&warm));
    let max_area = co
        .iter()
        .chain(&wo)
        .map(|p| p.1)
        .fold(0.0f64, f64::max);
    let ref_pt = (1.5, max_area * 1.5 + 1.0);
    let (hv_cold, hv_warm) = (hypervolume(&co, ref_pt), hypervolume(&wo, ref_pt));
    assert!(hv_cold > 0.0);
    assert!(
        hv_warm >= hv_cold - 1e-9,
        "half-generation warm run must reach the cold hypervolume: {hv_warm} vs {hv_cold}"
    );
}
