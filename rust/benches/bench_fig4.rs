//! Fig. 4 regeneration: bespoke-comparator area vs hardwired threshold, at
//! 6-bit (a) and 8-bit (b) precision, plus synthesis-throughput timings
//! (the LUT build cost is the one-time setup of the GA's area oracle).

use axdt::hw::synth::synth_comparator;
use axdt::hw::{AreaLut, EgtLibrary};
use axdt::util::bench::{black_box, Bench};
use axdt::util::stats::Summary;

fn main() {
    let mut b = Bench::new("fig4");
    let lib = EgtLibrary::default();

    // The figure.
    let (text, c6, c8) = axdt::report::fig4();
    b.row(&text);

    // Shape diagnostics the paper's narrative relies on: non-linearity and
    // the existence of much-cheaper neighbours.
    for (bits, curve) in [(6u8, &c6), (8u8, &c8)] {
        let s = Summary::from_slice(curve);
        let mut neighbour_gain = Summary::new();
        let lut = AreaLut::build(&lib);
        for t in 0..curve.len() as u32 {
            let (_, best) = lut.cheapest_in_margin(bits, t, 5);
            if curve[t as usize] > 0.0 {
                neighbour_gain.push(best / curve[t as usize]);
            }
        }
        // One sort per summary answers the whole quantile batch.
        let ps = s.percentiles(&[0.1, 0.9]);
        b.row(&format!(
            "fig4/{bits}bit: area mean {:.3} mm^2, p10 {:.3}, p90 {:.3}; ±5 substitution keeps {:.0}% of area on median",
            s.mean(),
            ps[0],
            ps[1],
            100.0 * neighbour_gain.median(),
        ));
    }

    // Timings.
    b.iter("synth_comparator/8bit_t170", || black_box(synth_comparator(8, 170)));
    b.iter("synth_comparator/6bit_t42", || black_box(synth_comparator(6, 42)));
    b.iter("area_lut_build/all_508_comparators", || {
        black_box(AreaLut::build(&lib))
    });
}
