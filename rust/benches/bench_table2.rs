//! Table II regeneration: area/power of the best design within a 1%
//! accuracy-loss budget, with Blue-Spark-battery (<3 mW) and
//! energy-harvester (<0.1 mW) feasibility classification, plus the
//! aggregate area/power gain the paper headlines (3.2× / 3.4×).
//!
//! Same environment knobs as bench_fig5 (AXDT_BENCH_DATASETS/POP/GENS/
//! ENGINE).  Selection + full re-synthesis of the winning designs is timed.

use axdt::coordinator::{EngineChoice, EvalService, RunOptions};
use axdt::report;
use axdt::util::bench::Bench;

fn main() {
    let mut b = Bench::new("table2");
    let datasets = match std::env::var("AXDT_BENCH_DATASETS").ok().as_deref() {
        None => vec!["seeds".to_string(), "vertebral".to_string(), "mammographic".to_string()],
        Some("all") => axdt::data::generators::all_ids().iter().map(|s| s.to_string()).collect(),
        Some(list) => list.split(',').map(|s| s.trim().to_string()).collect(),
    };
    let pop: usize = std::env::var("AXDT_BENCH_POP").ok().and_then(|v| v.parse().ok()).unwrap_or(32);
    let gens: usize =
        std::env::var("AXDT_BENCH_GENS").ok().and_then(|v| v.parse().ok()).unwrap_or(15);
    let engine = match std::env::var("AXDT_BENCH_ENGINE").ok().as_deref() {
        Some("xla") => EngineChoice::Xla,
        _ => EngineChoice::Native,
    };
    let service = match engine {
        EngineChoice::Xla => Some(EvalService::spawn_xla("artifacts").expect("make artifacts")),
        _ => None,
    };
    let opts = RunOptions { pop_size: pop, generations: gens, engine, ..Default::default() };

    let mut runs = Vec::new();
    for d in &datasets {
        let t0 = std::time::Instant::now();
        runs.push(report::fig5_run(d, &opts, service.as_ref()).expect("run"));
        b.record_once(&format!("optimize/{d}"), t0.elapsed());
    }

    let t0 = std::time::Instant::now();
    let table = report::table2(&runs, 0.01);
    b.record_once("select_and_render/loss1pct", t0.elapsed());
    b.row(&table);
    b.row(&report::table2(&runs, 0.02));

    if let Some(svc) = service {
        svc.shutdown();
    }
}
