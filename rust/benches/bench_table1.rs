//! Table I regeneration + stage timing.
//!
//! Prints the paper's Table I (exact bespoke baselines, paper values
//! alongside) and times each pipeline stage — dataset generation, CART
//! training, bespoke synthesis — for a small/medium/large dataset.
//!
//! Run: `cargo bench --bench bench_table1` (add `-- --quick` for CI).

use axdt::data::generators;
use axdt::dt::{train, TrainConfig};
use axdt::hw::synth::{self, TreeApprox};
use axdt::hw::EgtLibrary;
use axdt::report;
use axdt::util::bench::{black_box, Bench};

fn main() {
    let mut b = Bench::new("table1");

    // The table itself (the paper artifact).
    let datasets: Vec<String> = generators::all_ids().iter().map(|s| s.to_string()).collect();
    let t0 = std::time::Instant::now();
    let (text, rows) = report::table1(&datasets, 42).expect("table1");
    b.row(&text);
    b.record_once("full_table_10_datasets", t0.elapsed());

    // Stage timings on representative datasets.
    let lib = EgtLibrary::default();
    for id in ["seeds", "cardio", "whitewine"] {
        let spec = generators::spec(id).unwrap();
        b.iter(&format!("generate/{id}"), || black_box(generators::generate(spec, 42)));

        let data = generators::generate(spec, 42);
        let (train_d, _) = data.split(0.3, 42);
        let cfg = TrainConfig { max_leaves: spec.max_leaves, min_samples_split: 2 };
        if b.quick() && id == "whitewine" {
            continue;
        }
        b.iter(&format!("train/{id}"), || black_box(train(&train_d, &cfg)));

        let tree = train(&train_d, &cfg);
        let approx = TreeApprox::exact(&tree);
        b.iter(&format!("synth_exact/{id}"), || {
            black_box(synth::synth_tree(&tree, &approx).netlist.report(&lib))
        });
    }

    // Fidelity summary vs the paper (goes to EXPERIMENTS.md).
    let mut max_acc_err: f64 = 0.0;
    for r in &rows {
        max_acc_err = max_acc_err.max((r.accuracy - r.spec.paper_accuracy).abs());
    }
    b.row(&format!("max |accuracy - paper| across datasets: {max_acc_err:.3}"));
}
