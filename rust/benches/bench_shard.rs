//! Shard-pool scaling bench: eval-service throughput with 1 vs N workers
//! on a synthetic multi-driver workload, padding waste with the coalescer
//! off vs on, and the tiered eval-cache's repeat-run payoff.
//!
//! The workload models the production shape: several GA drivers (one per
//! dataset), each hammering its own registered problem with
//! population-sized batches.  Problems hash-pin to shards, so with N
//! workers the drivers fan out across backends; with 1 worker they
//! serialize behind it.  Each worker's native engine is pinned to a
//! single thread (`engine_threads: 1`) so the bench isolates service-level
//! scaling — the realistic regime, since a real accelerator backend is
//! serial per device/client.
//!
//! Acceptance (ISSUE 2): >= 2x throughput with --workers 4 over
//! --workers 1, and strictly less padding waste with coalescing on.
//! Acceptance (ISSUE 5): one driver's micro-batched submit/poll beats its
//! own monolithic blocking loop >= 1.5x on a 4-shard pool and keeps >= 2
//! shards busy (blocking pins ~1), bit-identically.
//! Acceptance (cache tentpole): replaying the same phenotype stream
//! against a warm shared cache issues ZERO engine evaluations and beats
//! the cold pass >= 5x wall-clock (`repeat_speedup` in BENCH_shard.json).
//!
//! Every scenario lands in `BENCH_shard.json` (written atomically via
//! `Bench::save_json`, like `BENCH_hotpath.json`): wall-clock per scenario
//! under `benches`, throughput/speedup scalars under `derived`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use axdt::coordinator::{CoalesceMode, EvalService, PoolOptions, XlaEngine};
use axdt::fitness::cache::{DatasetFingerprint, EvalCache};
use axdt::fitness::native::NativeEngine;
use axdt::fitness::{AccuracyEngine, FitnessEvaluator, Problem, SharedCache};
use axdt::ga::{Chromosome, Evaluator};
use axdt::hw::synth::TreeApprox;
use axdt::hw::{AreaLut, EgtLibrary};
use axdt::util::bench::Bench;
use axdt::util::rng::Pcg64;
use axdt::util::testbed::{named_problem, random_batch, spawn_killable_native, DRIVER_NAMES};

/// Drive `DRIVER_NAMES.len()` concurrent drivers for `iters` rounds each;
/// returns (chromosome evaluations per second, wall time, metrics line).
fn multi_driver_throughput(workers: usize, width: usize, iters: usize) -> (f64, Duration, String) {
    let svc = EvalService::spawn_native_with(
        width,
        &PoolOptions {
            workers,
            coalesce_window_us: 200,
            engine_threads: 1,
            ..PoolOptions::default()
        },
    );
    let registered: Vec<(Arc<Problem>, _)> = DRIVER_NAMES
        .iter()
        .map(|name| {
            let p = named_problem(name);
            let (id, _) = svc.register(Arc::clone(&p)).unwrap();
            (p, id)
        })
        .collect();
    if workers > 1 {
        // The comparison is only meaningful if the driver problems really
        // fan out; guard against the name list drifting off-spread.
        let shards: std::collections::BTreeSet<usize> =
            registered.iter().map(|(_, id)| id.shard()).collect();
        assert!(shards.len() >= 3, "driver names no longer spread: {shards:?}");
    }
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for (t, (p, id)) in registered.iter().enumerate() {
            let svc = svc.clone();
            let p = Arc::clone(p);
            let id = *id;
            s.spawn(move || {
                let batch = random_batch(&p, width, 7 + t as u64);
                for _ in 0..iters {
                    svc.eval(id, batch.clone()).unwrap();
                }
            });
        }
    });
    let dt = t0.elapsed();
    let evals = (DRIVER_NAMES.len() * iters * width) as f64;
    let report = svc.metrics.render();
    svc.shutdown();
    (evals / dt.as_secs_f64(), dt, report)
}

/// 4 drivers hammer ONE problem with sub-width batches (5 at width 32):
/// with the window off every request pads 5→32 alone; with it on,
/// concurrent batches merge before padding.
fn padding_waste(window_us: u64, rounds: usize) -> (f64, String) {
    let width = 32;
    let svc = EvalService::spawn_native_with(
        width,
        &PoolOptions {
            workers: 1,
            coalesce_window_us: window_us,
            engine_threads: 1,
            ..PoolOptions::default()
        },
    );
    let p = named_problem("seeds");
    let (id, _) = svc.register(Arc::clone(&p)).unwrap();
    std::thread::scope(|s| {
        for d in 0..4u64 {
            let svc = svc.clone();
            let p = Arc::clone(&p);
            s.spawn(move || {
                let batch = random_batch(&p, 5, 100 + d);
                for _ in 0..rounds {
                    svc.eval(id, batch.clone()).unwrap();
                }
            });
        }
    });
    let waste = svc.metrics.padding_waste();
    let report = svc.metrics.render();
    svc.shutdown();
    (waste, report)
}

/// ISSUE 5 acceptance scenario: ONE driver thread over the 8 spread
/// problems on a 4-shard pool — monolithic blocking eval vs micro-batched
/// submit/poll.  Blocking waits out each problem's eval before touching
/// the next shard, so at most one worker runs at a time; the pipelined
/// driver submits every problem's micro-batch before collecting any, so
/// all four shards execute concurrently under the same single thread.
/// Returns (evals/s, mean shards busy, wall time, first-round results,
/// metrics).
fn one_driver(
    pipelined: bool,
    width: usize,
    rounds: usize,
) -> (f64, f64, Duration, Vec<Vec<f64>>, String) {
    let svc = EvalService::spawn_native_with(
        width,
        &PoolOptions {
            workers: 4,
            coalesce_window_us: 200,
            engine_threads: 1,
            ..PoolOptions::default()
        },
    );
    let registered: Vec<(Arc<Problem>, _)> = DRIVER_NAMES
        .iter()
        .map(|name| {
            let p = named_problem(name);
            let (id, _) = svc.register(Arc::clone(&p)).unwrap();
            (p, id)
        })
        .collect();
    let batches: Vec<Vec<TreeApprox>> = registered
        .iter()
        .enumerate()
        .map(|(t, (p, _))| random_batch(p, width, 7 + t as u64))
        .collect();
    let mut first_round = Vec::new();
    let t0 = Instant::now();
    for r in 0..rounds {
        let results: Vec<Vec<f64>> = if pipelined {
            let tickets: Vec<_> = registered
                .iter()
                .zip(&batches)
                .map(|((_, id), b)| svc.submit(*id, b.clone()).unwrap())
                .collect();
            tickets.into_iter().map(|t| svc.wait(t).unwrap()).collect()
        } else {
            registered
                .iter()
                .zip(&batches)
                .map(|((_, id), b)| svc.eval(*id, b.clone()).unwrap())
                .collect()
        };
        if r == 0 {
            first_round = results;
        }
    }
    let dt = t0.elapsed();
    // Mean shard occupancy: total backend-busy time across shards over
    // the wall time — "how many workers did this driver keep running".
    let busy: u64 = svc.metrics.shards().iter().map(|s| s.busy_ns.load(Ordering::Relaxed)).sum();
    let occupancy = busy as f64 / dt.as_nanos() as f64;
    let thr = (DRIVER_NAMES.len() * rounds * width) as f64 / dt.as_secs_f64();
    let report = svc.metrics.render();
    svc.shutdown();
    (thr, occupancy, dt, first_round, report)
}

/// Failover cost: the multi-driver workload with one of 4 workers killed
/// a quarter of the way in.  Drivers go through the `XlaEngine` facade,
/// so the dead shard's drivers heal (re-register onto survivors) instead
/// of erroring — throughput degrades toward 3/4 of the healthy pool, it
/// does not collapse to zero.
fn failover_throughput(width: usize, iters: usize) -> (f64, Duration, String) {
    let kill = Arc::new(AtomicU64::new(0));
    let pool = spawn_killable_native(
        width,
        &PoolOptions {
            workers: 4,
            coalesce_window_us: 200,
            engine_threads: 1,
            ..PoolOptions::default()
        },
        Arc::clone(&kill),
    );
    let svc = EvalService::from_pool(pool);
    let engines: Vec<(Arc<Problem>, XlaEngine)> = DRIVER_NAMES
        .iter()
        .map(|name| {
            let p = named_problem(name);
            let engine = XlaEngine::register(&svc, Arc::clone(&p)).unwrap();
            (p, engine)
        })
        .collect();
    let victim = engines[0].1.shard() as u64 + 1;
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for (t, (p, mut engine)) in engines.into_iter().enumerate() {
            let kill = Arc::clone(&kill);
            s.spawn(move || {
                let batch = random_batch(&p, width, 7 + t as u64);
                for i in 0..iters {
                    if t == 0 && i == iters / 4 {
                        kill.store(victim, Ordering::SeqCst);
                    }
                    engine.batch_accuracy(&p, &batch).unwrap();
                }
            });
        }
    });
    let dt = t0.elapsed();
    let evals = (DRIVER_NAMES.len() * iters * width) as f64;
    let report = svc.metrics.render();
    svc.shutdown();
    (evals / dt.as_secs_f64(), dt, report)
}

/// Fixed vs adaptive coalescing under two arrival shapes: 4 drivers, each
/// holding its OWN registration of one shared problem (driver counts flow
/// through `register`, which is what arms the adaptive all-drivers early
/// flush), firing sub-width batches of 5 at width 32.
///
/// * `bursty` — a per-round barrier models generation-synchronized GA
///   drivers: all four batches land together.  Adaptive flushes the
///   instant the 4th driver queues; fixed waits out its window.
/// * steady — free-running drivers; adaptive sizes the window from the
///   observed EWMA of inter-arrival times.
///
/// Returns (evals/s, mean executed batch width, padding waste, report).
fn coalesce_policy_run(
    mode: CoalesceMode,
    bursty: bool,
    rounds: usize,
) -> (f64, f64, f64, String) {
    let width = 32;
    let drivers = 4usize;
    let svc = EvalService::spawn_native_with(
        width,
        &PoolOptions {
            workers: 1,
            coalesce: mode,
            coalesce_window_us: 200,
            coalesce_window_max_us: 1_000,
            engine_threads: 1,
            ..PoolOptions::default()
        },
    );
    let p = named_problem("seeds");
    let ids: Vec<_> = (0..drivers)
        .map(|_| svc.register(Arc::clone(&p)).unwrap().0)
        .collect();
    let barrier = Arc::new(std::sync::Barrier::new(drivers));
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for (d, &id) in ids.iter().enumerate() {
            let svc = svc.clone();
            let p = Arc::clone(&p);
            let barrier = Arc::clone(&barrier);
            s.spawn(move || {
                let mut direct = NativeEngine::default();
                for r in 0..rounds {
                    if bursty {
                        barrier.wait();
                    }
                    let batch = random_batch(&p, 5, (d * 1_000 + r) as u64);
                    let got = svc.eval(id, batch.clone()).unwrap();
                    if r == 0 {
                        // Acceptance: no correctness drift — coalesced
                        // results stay bit-identical to the native engine.
                        assert_eq!(got, direct.batch_accuracy(&p, &batch).unwrap());
                    }
                }
            });
        }
    });
    let dt = t0.elapsed().as_secs_f64();
    let mean_width = svc.metrics.batch_width_mean();
    let waste = svc.metrics.padding_waste();
    let report = svc.metrics.render();
    svc.shutdown();
    ((drivers * rounds * 5) as f64 / dt, mean_width, waste, report)
}

/// The cache tentpole's repeat-run scenario: one phenotype stream driven
/// through a service-backed evaluator twice against ONE shared cache.
/// The cold pass pays the ticket seam and the engine; the warm pass must
/// resolve every phenotype from L1 — zero engine evaluations,
/// bit-identical objectives — which is where the >= 5x wall-clock payoff
/// comes from.  Returns (cold wall, warm wall, cold engine evals, warm
/// engine evals, warm L1 hits, metrics line).
fn repeat_eval(width: usize, rounds: usize) -> (Duration, Duration, usize, usize, usize, String) {
    let svc = EvalService::spawn_native(width);
    let p = named_problem("seeds");
    let lut = AreaLut::build(&EgtLibrary::default());
    let cache = Arc::new(EvalCache::in_memory());
    let fp = DatasetFingerprint::compute("seeds", 42, 210, 8);
    let wire = || SharedCache {
        cache: Arc::clone(&cache),
        fingerprint: fp,
        metrics: Arc::clone(&svc.metrics),
        clock: svc.clock(),
    };
    // The same deterministic stream of mostly-distinct phenotypes for
    // both passes: `rounds` GA-generation-sized populations.
    let pops: Vec<Vec<Chromosome>> = (0..rounds)
        .map(|r| {
            let mut rng = Pcg64::seeded(0xBEEF + r as u64);
            (0..width * 4).map(|_| Chromosome::random(&mut rng, p.n_comparators())).collect()
        })
        .collect();

    let engine = XlaEngine::register(&svc, Arc::clone(&p)).unwrap();
    let mut cold = FitnessEvaluator::new(&p, &lut, engine);
    cold.shared = Some(wire());
    let t0 = Instant::now();
    let cold_objs: Vec<_> = pops.iter().map(|pop| cold.evaluate(pop)).collect();
    let cold_dt = t0.elapsed();
    assert!(cold.take_error().is_none());

    let engine = XlaEngine::register(&svc, Arc::clone(&p)).unwrap();
    let mut warm = FitnessEvaluator::new(&p, &lut, engine);
    warm.shared = Some(wire());
    let t1 = Instant::now();
    let warm_objs: Vec<_> = pops.iter().map(|pop| warm.evaluate(pop)).collect();
    let warm_dt = t1.elapsed();
    assert!(warm.take_error().is_none());
    assert_eq!(warm_objs, cold_objs, "warm pass must be bit-identical to cold");
    assert_eq!(warm.stats.engine_evals, 0, "warm pass must never touch the engine");

    let report = svc.metrics.render();
    let (ce, we, wl1) = (cold.stats.engine_evals, warm.stats.engine_evals, warm.stats.l1_hits);
    svc.shutdown();
    (cold_dt, warm_dt, ce, we, wl1, report)
}

fn main() {
    let mut b = Bench::new("shard");
    let quick = b.quick();
    let width = 32;
    let iters = if quick { 30 } else { 150 };
    // Scalar metrics accumulate here and land under `derived` in
    // BENCH_shard.json next to the per-scenario wall-clock benches.
    let mut derived: Vec<(String, f64)> = Vec::new();

    let mut throughput = Vec::new();
    for workers in [1usize, 4] {
        let (thr, dt, report) = multi_driver_throughput(workers, width, iters);
        throughput.push(thr);
        b.record_once(&format!("throughput_w{workers}"), dt);
        b.row(&format!(
            "shard/throughput workers={workers}: {thr:.0} evals/s \
             ({} drivers x {iters} iters x {width} batch)",
            DRIVER_NAMES.len()
        ));
        b.row(&format!("shard/metrics workers={workers}: {report}"));
        derived.push((format!("throughput_w{workers}_evals_per_s"), thr));
    }
    let speedup = throughput[1] / throughput[0];
    b.row(&format!(
        "shard/speedup workers4_vs_workers1 = {speedup:.2}x (acceptance target >= 2x)"
    ));
    derived.push(("speedup_4v1".into(), speedup));

    // Pipelined submit/poll vs monolithic blocking eval, ONE driver on a
    // 4-shard pool (acceptance: >= 1.5x and >= 2 shards busy where
    // blocking keeps ~1, bit-identically).
    let pb_rounds = if quick { 20 } else { 80 };
    let (thr_block, occ_block, dt_block, res_block, rep_block) =
        one_driver(false, width, pb_rounds);
    let (thr_pipe, occ_pipe, dt_pipe, res_pipe, rep_pipe) = one_driver(true, width, pb_rounds);
    assert_eq!(res_pipe, res_block, "pipelined must be bit-identical to blocking");
    {
        // …and both must match the direct native engine.
        let mut direct = NativeEngine::default();
        for (t, name) in DRIVER_NAMES.iter().enumerate() {
            let p = named_problem(name);
            let batch = random_batch(&p, width, 7 + t as u64);
            assert_eq!(
                res_pipe[t],
                direct.batch_accuracy(&p, &batch).unwrap(),
                "pipelined must be bit-identical to native ({name})"
            );
        }
    }
    b.record_once("pipeline_blocking", dt_block);
    b.record_once("pipeline_ticketed", dt_pipe);
    let speedup_pipe = thr_pipe / thr_block;
    b.row(&format!(
        "shard/pipeline blocking 1-driver: {thr_block:.0} evals/s, \
         {occ_block:.2} shards busy"
    ));
    b.row(&format!("shard/pipeline blocking metrics: {rep_block}"));
    b.row(&format!(
        "shard/pipeline ticketed 1-driver: {thr_pipe:.0} evals/s, \
         {occ_pipe:.2} shards busy"
    ));
    b.row(&format!("shard/pipeline ticketed metrics: {rep_pipe}"));
    b.row(&format!(
        "shard/pipeline speedup = {speedup_pipe:.2}x, occupancy {occ_block:.2} -> \
         {occ_pipe:.2} (acceptance >= 1.5x and >= 2 shards busy: {})",
        speedup_pipe >= 1.5 && occ_pipe >= 2.0
    ));
    derived.push(("pipeline_blocking_evals_per_s".into(), thr_block));
    derived.push(("pipeline_ticketed_evals_per_s".into(), thr_pipe));
    derived.push(("pipeline_speedup".into(), speedup_pipe));
    derived.push(("pipeline_blocking_shards_busy".into(), occ_block));
    derived.push(("pipeline_ticketed_shards_busy".into(), occ_pipe));

    let (thr_failover, dt_failover, report) = failover_throughput(width, iters);
    let retained = thr_failover / throughput[1];
    b.record_once("failover", dt_failover);
    b.row(&format!(
        "shard/failover 1-of-4 workers killed at 25%: {thr_failover:.0} evals/s \
         ({:.0}% of healthy 4-worker throughput; all drivers completed)",
        100.0 * retained
    ));
    b.row(&format!("shard/failover metrics: {report}"));
    derived.push(("failover_evals_per_s".into(), thr_failover));
    derived.push(("failover_retained_vs_healthy".into(), retained));

    let rounds = if quick { 40 } else { 150 };
    let (waste_off, report_off) = padding_waste(0, rounds);
    let (waste_on, report_on) = padding_waste(500, rounds);
    b.row(&format!(
        "shard/padding uncoalesced: waste={:.1}% ({report_off})",
        100.0 * waste_off
    ));
    b.row(&format!(
        "shard/padding coalesced(500us): waste={:.1}% ({report_on})",
        100.0 * waste_on
    ));
    b.row(&format!(
        "shard/coalescing padding waste {:.1}% -> {:.1}% (strictly less: {})",
        100.0 * waste_off,
        100.0 * waste_on,
        waste_on < waste_off
    ));
    derived.push(("padding_waste_uncoalesced".into(), waste_off));
    derived.push(("padding_waste_coalesced".into(), waste_on));

    // Fixed vs adaptive coalescing under bursty and steady arrivals.
    // Acceptance (ISSUE 4): under bursty arrivals, adaptive's mean
    // coalesced width >= fixed's, with no correctness drift (the drivers
    // assert bit-identity against the native engine inline).
    let policy_rounds = if quick { 40 } else { 150 };
    for (pattern, bursty) in [("bursty", true), ("steady", false)] {
        let mut widths = Vec::new();
        for (label, mode) in
            [("fixed", CoalesceMode::Fixed), ("adaptive", CoalesceMode::Adaptive)]
        {
            let (thr, mean_width, waste, report) =
                coalesce_policy_run(mode, bursty, policy_rounds);
            widths.push(mean_width);
            b.row(&format!(
                "shard/coalesce {pattern}/{label}: {thr:.0} evals/s, \
                 mean_width={mean_width:.1}, waste={:.1}%",
                100.0 * waste
            ));
            b.row(&format!("shard/coalesce {pattern}/{label} metrics: {report}"));
            derived.push((format!("coalesce_{pattern}_{label}_evals_per_s"), thr));
            derived.push((format!("coalesce_{pattern}_{label}_mean_width"), mean_width));
            derived.push((format!("coalesce_{pattern}_{label}_padding_waste"), waste));
        }
        let (fixed_w, adaptive_w) = (widths[0], widths[1]);
        b.row(&format!(
            "shard/coalesce {pattern}: adaptive mean width {adaptive_w:.1} vs fixed \
             {fixed_w:.1} (adaptive >= fixed: {})",
            adaptive_w >= fixed_w
        ));
        derived.push((format!("coalesce_{pattern}_width_ratio"), adaptive_w / fixed_w.max(1e-9)));
    }

    // Repeat-run cold/warm over one shared cache (the tentpole's payoff).
    // Zero warm engine evals and bit-identity are hard-asserted inside
    // `repeat_eval` (deterministic contracts); the wall-clock ratio is
    // reported, not asserted — timing thresholds flake on shared runners.
    let repeat_rounds = if quick { 4 } else { 12 };
    let (cold_dt, warm_dt, cold_evals, warm_evals, warm_l1, report) =
        repeat_eval(width, repeat_rounds);
    b.record_once("repeat_cold", cold_dt);
    b.record_once("repeat_warm", warm_dt);
    let repeat_speedup = cold_dt.as_secs_f64() / warm_dt.as_secs_f64().max(1e-12);
    b.row(&format!(
        "shard/repeat cold: {cold_evals} engine evals in {:.1} ms; warm: {warm_evals} \
         engine evals, {warm_l1} L1 hits in {:.1} ms",
        cold_dt.as_secs_f64() * 1e3,
        warm_dt.as_secs_f64() * 1e3
    ));
    b.row(&format!("shard/repeat metrics: {report}"));
    b.row(&format!(
        "shard/repeat speedup = {repeat_speedup:.2}x (acceptance target >= 5x: {})",
        repeat_speedup >= 5.0
    ));
    derived.push(("repeat_speedup".into(), repeat_speedup));
    derived.push(("repeat_cold_engine_evals".into(), cold_evals as f64));
    derived.push(("repeat_warm_engine_evals".into(), warm_evals as f64));
    derived.push(("repeat_warm_l1_hits".into(), warm_l1 as f64));

    let derived_refs: Vec<(&str, f64)> =
        derived.iter().map(|(k, v)| (k.as_str(), *v)).collect();
    b.save_json("BENCH_shard.json", &derived_refs)
        .expect("writing BENCH_shard.json");
    b.row("shard: wrote BENCH_shard.json");
}
