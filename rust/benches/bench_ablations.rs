//! Ablations on the design choices the paper calls out:
//!
//!   1. **Mixed vs uniform precision** (§II-B: "we investigate ultra-low
//!      precision mixed-precision bespoke architectures … at a finer
//!      granularity"): run the GA with per-comparator precision genes vs a
//!      single shared precision, same budget, compare fronts.
//!   2. **Substitution margin m** (§III-A, paper fixes ±5): sweep
//!      m ∈ {0, 1, 3, 5, 10} and report the area of the best design within
//!      1% accuracy loss.
//!   3. **Estimated vs synthesized area fidelity** (Fig. 5's estimated
//!      front vs measured points): correlation and mean relative error of
//!      the LUT-sum estimate across a front.

use axdt::coordinator::{EngineChoice, RunOptions};
use axdt::data::generators;
use axdt::dt::{train, TrainConfig};
use axdt::fitness::{native::NativeEngine, FitnessEvaluator, Problem};
use axdt::fitness::AccuracyEngine;
use axdt::ga::{run_nsga2, NsgaConfig};
use axdt::hw::synth::TreeApprox;
use axdt::hw::{AreaLut, EgtLibrary};
use axdt::report;
use axdt::util::bench::Bench;

fn main() {
    let b = Bench::new("ablations");
    let quick = b.quick();
    let gens = if quick { 4 } else { 15 };
    let pop = if quick { 12 } else { 32 };
    let lib = EgtLibrary::default();
    let lut = AreaLut::build(&lib);

    // ---- 1. mixed vs uniform precision --------------------------------
    for dataset in ["seeds", "vertebral"] {
        let spec = generators::spec(dataset).unwrap();
        let data = generators::generate(spec, 42);
        let (train_d, test_d) = data.split(0.3, 42);
        let tree =
            train(&train_d, &TrainConfig { max_leaves: spec.max_leaves, min_samples_split: 2 });
        let problem = Problem::new(spec.id, tree, &test_d, &lut, &lib, 5);
        let n = problem.n_comparators();
        let baseline_acc = NativeEngine::accuracy_one(&problem, &TreeApprox::exact(&problem.tree));

        // Mixed precision: the framework as-is.
        let mut ev = FitnessEvaluator::new(&problem, &lut, NativeEngine::default());
        let cfg = NsgaConfig { pop_size: pop, generations: gens, seed: 1, ..Default::default() };
        let mixed = run_nsga2(n, &cfg, &mut ev);
        let mixed_best = best_area_within(&problem, &lut, &mixed, baseline_acc, 0.01);

        // Uniform precision: exhaustive over the 7 precisions (the
        // alternative the paper argues against), margin search included.
        let mut uniform_best = f64::INFINITY;
        let mut engine = NativeEngine::default();
        for bits in 2u8..=8 {
            for margin in [0u32, 5] {
                let thr_int: Vec<u32> = problem
                    .thresholds
                    .iter()
                    .map(|&t| {
                        let t0 = axdt::quant::int_threshold(t, bits);
                        lut.cheapest_in_margin(bits, t0, margin).0
                    })
                    .collect();
                let approx = TreeApprox { bits: vec![bits; n], thr_int };
                let acc =
                    engine.batch_accuracy(&problem, std::slice::from_ref(&approx)).unwrap()[0];
                if acc >= baseline_acc - 0.01 {
                    uniform_best = uniform_best.min(problem.estimate_area(&lut, &approx));
                }
            }
        }
        b.row(&format!(
            "ablation/precision/{dataset}: mixed {:.2} mm^2 vs uniform {:.2} mm^2 within 1% loss ({}x finer)",
            mixed_best,
            uniform_best,
            if mixed_best < uniform_best { "mixed wins, " } else { "uniform wins, " },
        ));
    }

    // ---- 2. margin sweep ------------------------------------------------
    for margin in [0u32, 1, 3, 5, 10] {
        let opts = RunOptions {
            pop_size: pop,
            generations: gens,
            margin_max: margin,
            engine: EngineChoice::Native,
            ..Default::default()
        };
        let run = report::fig5_run("seeds", &opts, None).unwrap();
        b.row(&format!(
            "ablation/margin/seeds m=±{margin}: best area @1% loss = {:.2} mm^2 (gain {:.2}x)",
            run.best_within_loss(0.01).map(|p| p.measured.area_mm2).unwrap_or(f64::NAN),
            run.area_gain(0.01).unwrap_or(f64::NAN),
        ));
    }

    // ---- 3. estimated vs synthesized area fidelity -----------------------
    let opts = RunOptions {
        pop_size: pop,
        generations: gens,
        engine: EngineChoice::Native,
        ..Default::default()
    };
    for dataset in ["seeds", "balance"] {
        let run = report::fig5_run(dataset, &opts, None).unwrap();
        let mut rel_err = Vec::new();
        for p in &run.front {
            if p.measured.area_mm2 > 0.0 {
                rel_err.push((p.est_area_mm2 - p.measured.area_mm2).abs() / p.measured.area_mm2);
            }
        }
        let mean_err = rel_err.iter().sum::<f64>() / rel_err.len().max(1) as f64;
        b.row(&format!(
            "ablation/estimate-fidelity/{dataset}: mean |est-meas|/meas = {:.1}% over {} front designs",
            100.0 * mean_err,
            rel_err.len(),
        ));
    }
}

fn best_area_within(
    problem: &Problem,
    lut: &AreaLut,
    res: &axdt::ga::NsgaResult,
    baseline_acc: f64,
    loss: f64,
) -> f64 {
    let ctx = problem.decode_context(lut);
    res.pareto_front()
        .iter()
        .filter(|s| 1.0 - s.objectives[0] >= baseline_acc - loss)
        .map(|s| problem.estimate_area(lut, &s.chromosome.decode(&ctx)))
        .fold(f64::INFINITY, f64::min)
}
