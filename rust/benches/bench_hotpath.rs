//! Fitness hot-path microbenchmarks (the paper's §IV time-complexity
//! discussion: "the slowest single-chromosome evaluation had a duration of
//! 3.08 ms, for the HAR dataset").
//!
//! Measures per-chromosome accuracy-evaluation latency for:
//!   * the native tree-walk engine, single chromosome and batched;
//!   * the XLA artifact, amortized over a full population execution
//!     (requires `make artifacts`; skipped otherwise);
//! on the small (seeds) and large (HAR) ends of the workload spectrum,
//! plus coordinator overhead (service round-trip vs direct call).

use std::sync::Arc;

use axdt::coordinator::{EvalService, PoolOptions, XlaEngine};
use axdt::data::generators;
use axdt::dt::{train, TrainConfig};
use axdt::fitness::native::NativeEngine;
use axdt::fitness::{AccuracyEngine, Problem};
use axdt::hw::synth::TreeApprox;
use axdt::hw::{AreaLut, EgtLibrary};
use axdt::util::bench::{black_box, Bench};
use axdt::util::rng::Pcg64;

/// Single worker, no coalescing: the seed service's dispatch behavior,
/// which is what the latency comparisons here are calibrated against.
fn latency_opts() -> PoolOptions {
    PoolOptions { workers: 1, coalesce_window_us: 0, engine_threads: 0, ..PoolOptions::default() }
}

fn problem_for(dataset: &str) -> Problem {
    let lib = EgtLibrary::default();
    let lut = AreaLut::build(&lib);
    let spec = generators::spec(dataset).unwrap();
    let data = generators::generate(spec, 42);
    let (train_d, test_d) = data.split(0.3, 42);
    let tree = train(&train_d, &TrainConfig { max_leaves: spec.max_leaves, min_samples_split: 2 });
    Problem::new(spec.id, tree, &test_d, &lut, &lib, 5)
}

fn random_batch(p: &Problem, count: usize, seed: u64) -> Vec<TreeApprox> {
    let mut rng = Pcg64::seeded(seed);
    let n = p.n_comparators();
    (0..count)
        .map(|_| {
            let bits: Vec<u8> = (0..n).map(|_| rng.int_in(2, 8) as u8).collect();
            let thr_int: Vec<u32> = (0..n)
                .map(|j| axdt::quant::int_threshold(p.thresholds[j], bits[j]))
                .collect();
            TreeApprox { bits, thr_int }
        })
        .collect()
}

fn main() {
    let mut b = Bench::new("hotpath");
    let quick = b.quick();

    for dataset in ["seeds", "har"] {
        if quick && dataset == "har" {
            continue;
        }
        let p = problem_for(dataset);
        let batch32 = random_batch(&p, 32, 7);

        // Native: single chromosome.
        b.iter(&format!("native_single/{dataset}"), || {
            black_box(NativeEngine::accuracy_one(&p, &batch32[0]))
        });
        // Native: batch of 32 across the thread pool (per-chromosome cost
        // is this divided by 32).
        let mut native = NativeEngine::default();
        b.iter(&format!("native_batch32/{dataset}"), || {
            black_box(native.batch_accuracy(&p, &batch32).unwrap())
        });
    }

    // XLA path (compiled only with `--features xla`; skip silently when the
    // feature is off or artifacts are absent).
    // Coalescing off: this bench measures per-request latency, and a
    // sub-width batch would otherwise wait out the merge window.
    #[cfg(feature = "xla")]
    match EvalService::spawn_xla_with("artifacts", &latency_opts()) {
        Err(e) => b.row(&format!("xla: skipped ({e})")),
        Ok(svc) => {
            for dataset in ["seeds", "har"] {
                if quick && dataset == "har" {
                    continue;
                }
                let p = Arc::new(problem_for(dataset));
                let mut engine = XlaEngine::register(&svc, Arc::clone(&p)).unwrap();
                let batch32 = random_batch(&p, 32, 7);
                // Warm (compile + first exec) before timing.
                let _ = engine.batch_accuracy(&p, &batch32[..1]);
                b.iter(&format!("xla_exec_pop32/{dataset}"), || {
                    black_box(engine.batch_accuracy(&p, &batch32).unwrap())
                });
                b.iter(&format!("xla_exec_pop1/{dataset}"), || {
                    black_box(engine.batch_accuracy(&p, &batch32[..1]).unwrap())
                });
            }
            b.row(&format!("eval service: {}", svc.metrics.render()));
            b.row("paper reference: slowest single-chromosome eval = 3.08 ms (HAR, python)");
            svc.shutdown();
        }
    }
    #[cfg(not(feature = "xla"))]
    b.row("xla: skipped (built without the `xla` feature)");

    // Coordinator overhead: service round-trip vs direct native call.
    let p = Arc::new(problem_for("seeds"));
    let svc = EvalService::spawn_native_with(32, &latency_opts());
    let mut via_service = XlaEngine::register(&svc, Arc::clone(&p)).unwrap();
    let batch = random_batch(&p, 32, 9);
    let mut direct = NativeEngine::default();
    b.iter("coordinator_overhead/direct_batch32", || {
        black_box(direct.batch_accuracy(&p, &batch).unwrap())
    });
    b.iter("coordinator_overhead/service_batch32", || {
        black_box(via_service.batch_accuracy(&p, &batch).unwrap())
    });
    svc.shutdown();
}
