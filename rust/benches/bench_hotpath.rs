//! Fitness hot-path microbenchmarks (the paper's §IV time-complexity
//! discussion: "the slowest single-chromosome evaluation had a duration of
//! 3.08 ms, for the HAR dataset").
//!
//! Measures per-chromosome accuracy-evaluation latency for:
//!   * the native engine's scalar tree walk (the oracle / old baseline)
//!     and its bit-sliced kernel, single chromosome and batched;
//!   * the one-time bit-plane build the sliced kernel amortizes;
//!   * the XLA artifact, amortized over a full population execution
//!     (requires `make artifacts`; skipped otherwise);
//! on the small (seeds) and large (HAR) ends of the workload spectrum,
//! plus coordinator overhead (service round-trip vs direct call).
//!
//! Results (and the derived scalar→sliced batch speedups) are persisted
//! to `BENCH_hotpath.json` (atomic tmp+rename) for CI and EXPERIMENTS.md
//! tooling.

use std::sync::Arc;

use axdt::coordinator::{EvalService, PoolOptions, XlaEngine};
use axdt::data::generators;
use axdt::dt::{train, TrainConfig};
use axdt::fitness::native::{accuracy_sliced, BitPlanes, NativeEngine};
use axdt::fitness::{AccuracyEngine, Problem};
use axdt::hw::synth::TreeApprox;
use axdt::hw::{AreaLut, EgtLibrary};
use axdt::util::bench::{black_box, Bench};
use axdt::util::rng::Pcg64;

/// Single worker, no coalescing: the seed service's dispatch behavior,
/// which is what the latency comparisons here are calibrated against.
fn latency_opts() -> PoolOptions {
    PoolOptions { workers: 1, coalesce_window_us: 0, engine_threads: 0, ..PoolOptions::default() }
}

fn problem_for(dataset: &str) -> Problem {
    let lib = EgtLibrary::default();
    let lut = AreaLut::build(&lib);
    let spec = generators::spec(dataset).unwrap();
    let data = generators::generate(spec, 42);
    let (train_d, test_d) = data.split(0.3, 42);
    let tree = train(&train_d, &TrainConfig { max_leaves: spec.max_leaves, min_samples_split: 2 });
    Problem::new(spec.id, tree, &test_d, &lut, &lib, 5)
}

fn random_batch(p: &Problem, count: usize, seed: u64) -> Vec<TreeApprox> {
    let mut rng = Pcg64::seeded(seed);
    let n = p.n_comparators();
    (0..count)
        .map(|_| {
            let bits: Vec<u8> = (0..n).map(|_| rng.int_in(2, 8) as u8).collect();
            let thr_int: Vec<u32> = (0..n)
                .map(|j| axdt::quant::int_threshold(p.thresholds[j], bits[j]))
                .collect();
            TreeApprox { bits, thr_int }
        })
        .collect()
}

fn main() {
    let mut b = Bench::new("hotpath");
    let quick = b.quick();

    for dataset in ["seeds", "har"] {
        if quick && dataset == "har" {
            continue;
        }
        let p = problem_for(dataset);
        let batch32 = random_batch(&p, 32, 7);

        // The one-time transpose the sliced kernel amortizes (paid once
        // per problem at registration, not per chromosome).
        b.iter(&format!("plane_build/{dataset}"), || black_box(BitPlanes::build(&p)));
        b.row(&format!(
            "planes/{dataset}: {} test samples -> {} KiB",
            p.n_test,
            p.planes().bytes() / 1024,
        ));

        // Single chromosome: scalar oracle walk vs bit-sliced kernel.
        b.iter(&format!("scalar_single/{dataset}"), || {
            black_box(NativeEngine::accuracy_one(&p, &batch32[0]))
        });
        b.iter(&format!("sliced_single/{dataset}"), || {
            black_box(accuracy_sliced(&p, &batch32[0]))
        });

        // Batch of 32 across the thread pool (per-chromosome cost is this
        // divided by 32) — the GA's actual hot path, both kernels.
        let mut scalar = NativeEngine { scalar: true, ..NativeEngine::default() };
        b.iter(&format!("scalar_batch32/{dataset}"), || {
            black_box(scalar.batch_accuracy(&p, &batch32).unwrap())
        });
        let mut sliced = NativeEngine { scalar: false, ..NativeEngine::default() };
        b.iter(&format!("sliced_batch32/{dataset}"), || {
            black_box(sliced.batch_accuracy(&p, &batch32).unwrap())
        });
    }

    // XLA path (compiled only with `--features xla`; skip silently when the
    // feature is off or artifacts are absent).
    // Coalescing off: this bench measures per-request latency, and a
    // sub-width batch would otherwise wait out the merge window.
    #[cfg(feature = "xla")]
    match EvalService::spawn_xla_with("artifacts", &latency_opts()) {
        Err(e) => b.row(&format!("xla: skipped ({e})")),
        Ok(svc) => {
            for dataset in ["seeds", "har"] {
                if quick && dataset == "har" {
                    continue;
                }
                let p = Arc::new(problem_for(dataset));
                let mut engine = XlaEngine::register(&svc, Arc::clone(&p)).unwrap();
                let batch32 = random_batch(&p, 32, 7);
                // Warm (compile + first exec) before timing.
                let _ = engine.batch_accuracy(&p, &batch32[..1]);
                b.iter(&format!("xla_exec_pop32/{dataset}"), || {
                    black_box(engine.batch_accuracy(&p, &batch32).unwrap())
                });
                b.iter(&format!("xla_exec_pop1/{dataset}"), || {
                    black_box(engine.batch_accuracy(&p, &batch32[..1]).unwrap())
                });
            }
            b.row(&format!("eval service: {}", svc.metrics.render()));
            b.row("paper reference: slowest single-chromosome eval = 3.08 ms (HAR, python)");
            svc.shutdown();
        }
    }
    #[cfg(not(feature = "xla"))]
    b.row("xla: skipped (built without the `xla` feature)");

    // Coordinator overhead: service round-trip vs direct native call.
    let p = Arc::new(problem_for("seeds"));
    let svc = EvalService::spawn_native_with(32, &latency_opts());
    let mut via_service = XlaEngine::register(&svc, Arc::clone(&p)).unwrap();
    let batch = random_batch(&p, 32, 9);
    let mut direct = NativeEngine::default();
    b.iter("coordinator_overhead/direct_batch32", || {
        black_box(direct.batch_accuracy(&p, &batch).unwrap())
    });
    b.iter("coordinator_overhead/service_batch32", || {
        black_box(via_service.batch_accuracy(&p, &batch).unwrap())
    });
    svc.shutdown();

    // Machine-readable artifact with the derived scalar→sliced speedups
    // (null for datasets skipped in --quick).
    let speedup = |kind: &str, d: &str| {
        b.mean_ns(&format!("scalar_{kind}/{d}")) / b.mean_ns(&format!("sliced_{kind}/{d}"))
    };
    let derived = [
        ("speedup_batch32_seeds", speedup("batch32", "seeds")),
        ("speedup_batch32_har", speedup("batch32", "har")),
        ("speedup_single_seeds", speedup("single", "seeds")),
        ("speedup_single_har", speedup("single", "har")),
    ];
    for (name, v) in &derived {
        if v.is_finite() {
            b.row(&format!("derived {name} = {v:.2}x"));
        }
    }
    if let Err(e) = b.save_json("BENCH_hotpath.json", &derived) {
        b.row(&format!("BENCH_hotpath.json: write failed ({e})"));
    } else {
        b.row("saved BENCH_hotpath.json");
    }
}
