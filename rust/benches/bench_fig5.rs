//! Fig. 5 regeneration: NSGA-II pareto fronts per dataset.
//!
//! Environment knobs (benches must stay bounded):
//!   AXDT_BENCH_DATASETS  comma list (default: seeds,vertebral,balance —
//!                        one per size class; use "all" for the full 10)
//!   AXDT_BENCH_POP / AXDT_BENCH_GENS   GA budget (default 32 / 12)
//!   AXDT_BENCH_ENGINE    native | xla (default native; xla needs artifacts)
//!
//! The full-scale fronts for all 10 datasets are produced by
//! `examples/paper_repro.rs` / `axdt repro all` and recorded in
//! EXPERIMENTS.md.

use axdt::coordinator::{EngineChoice, EvalService, RunOptions};
use axdt::report;
use axdt::util::bench::Bench;

fn main() {
    let mut b = Bench::new("fig5");
    let datasets = match std::env::var("AXDT_BENCH_DATASETS").ok().as_deref() {
        None => vec!["seeds".to_string(), "vertebral".to_string(), "balance".to_string()],
        Some("all") => axdt::data::generators::all_ids().iter().map(|s| s.to_string()).collect(),
        Some(list) => list.split(',').map(|s| s.trim().to_string()).collect(),
    };
    let pop: usize = std::env::var("AXDT_BENCH_POP").ok().and_then(|v| v.parse().ok()).unwrap_or(32);
    let gens: usize =
        std::env::var("AXDT_BENCH_GENS").ok().and_then(|v| v.parse().ok()).unwrap_or(12);
    let engine = match std::env::var("AXDT_BENCH_ENGINE").ok().as_deref() {
        Some("xla") => EngineChoice::Xla,
        _ => EngineChoice::Native,
    };
    let service = match engine {
        EngineChoice::Xla => Some(EvalService::spawn_xla("artifacts").expect("make artifacts")),
        _ => None,
    };

    let opts = RunOptions { pop_size: pop, generations: gens, engine, ..Default::default() };
    for d in &datasets {
        let t0 = std::time::Instant::now();
        let run = report::fig5_run(d, &opts, service.as_ref()).expect("fig5 run");
        let elapsed = t0.elapsed();
        b.row(&report::render_fig5(&run));
        b.record_once(&format!("optimize/{d}/pop{pop}x{gens}"), elapsed);
        b.row(&format!(
            "fig5/{d}: {:.1} evals/s, {} front points, area gain @1% = {:.2}x, @2% = {:.2}x",
            run.evaluations as f64 / run.elapsed_s,
            run.front.len(),
            run.area_gain(0.01).unwrap_or(f64::NAN),
            run.area_gain(0.02).unwrap_or(f64::NAN),
        ));
    }
    if let Some(svc) = service {
        b.row(&format!("eval service: {}", svc.metrics.render()));
        svc.shutdown();
    }
}
