//! NSGA-II (Deb et al., 2002) — the paper's design-space explorer.
//!
//! Standard elitist loop: binary tournament selection under the crowded
//! comparison operator, simulated binary crossover (SBX), polynomial
//! mutation, fast non-dominated sorting of the combined parent+child pool,
//! and crowding-distance truncation of the last admitted front.
//!
//! Objectives are **minimized** and fixed at two for this framework:
//! `[1 − accuracy, estimated area]`.  Evaluation is population-batched
//! through the [`Evaluator`] trait so the coordinator can pack chromosomes
//! into fixed-size XLA executions.

use super::chromosome::Chromosome;
use crate::util::rng::Pcg64;

/// Batched fitness oracle. Returns one `[f64; 2]` (minimized) per input.
///
/// Call discipline: the GA hands over each generation's population as ONE
/// batch — the initial population, then every offspring set — and never
/// issues a second `evaluate` before the first returns.  Implementations
/// are therefore free to pipeline *internally*: slice the batch into
/// micro-batches, submit them all to an async backend, and overlap other
/// per-chromosome work before collecting (see
/// `fitness::FitnessEvaluator`, which rides the eval service's ticketed
/// submit/wait API) — as long as the returned vector is index-aligned
/// with `pop`.  The GA itself stays oblivious: determinism comes from the
/// seeded RNG plus this one-batch-at-a-time contract, so internal
/// pipelining can never reorder what the GA observes.
pub trait Evaluator {
    fn evaluate(&mut self, pop: &[Chromosome]) -> Vec<[f64; 2]>;
}

/// NSGA-II hyper-parameters (paper defaults in `Default`).
#[derive(Clone, Debug)]
pub struct NsgaConfig {
    pub pop_size: usize,
    pub generations: usize,
    /// SBX crossover probability / distribution index.
    pub p_crossover: f64,
    pub eta_crossover: f64,
    /// Per-gene mutation probability (None → 1/n_genes) / distribution index.
    pub p_mutation: Option<f64>,
    pub eta_mutation: f64,
    pub seed: u64,
    /// Seed the exact (8-bit, margin-0) baseline into the initial
    /// population so the search starts from the paper's reference design.
    pub seed_exact: bool,
    /// Additionally seed the uniform-precision ladder (2..8 bits, with and
    /// without substitution margin) — strong anchors that make large
    /// chromosomes (hundreds of genes) tractable at small GA budgets.
    pub seed_ladder: bool,
    /// Warm-start individuals (validated chromosomes, e.g. an archived
    /// Pareto front from a previous run): injected into the initial
    /// population after the exact/ladder anchors, clamped at `pop_size`;
    /// the remaining slots stay random.  Empty = cold start.
    pub warm_seeds: Vec<Chromosome>,
}

impl Default for NsgaConfig {
    fn default() -> Self {
        NsgaConfig {
            pop_size: 48,
            generations: 30,
            p_crossover: 0.9,
            eta_crossover: 15.0,
            p_mutation: None,
            eta_mutation: 20.0,
            seed: 0xA1D7,
            seed_exact: true,
            seed_ladder: true,
            warm_seeds: Vec::new(),
        }
    }
}

/// A chromosome with its objective vector.
#[derive(Clone, Debug)]
pub struct ScoredIndividual {
    pub chromosome: Chromosome,
    pub objectives: [f64; 2],
}

/// Per-generation telemetry.
#[derive(Clone, Copy, Debug)]
pub struct GenStats {
    pub generation: usize,
    pub best_error: f64,
    pub best_area: f64,
    pub front_size: usize,
    pub evaluations: usize,
}

/// Final result: last population + telemetry.
#[derive(Clone, Debug)]
pub struct NsgaResult {
    pub population: Vec<ScoredIndividual>,
    pub history: Vec<GenStats>,
    pub evaluations: usize,
}

impl NsgaResult {
    /// The non-dominated subset of the final population, sorted by error.
    pub fn pareto_front(&self) -> Vec<ScoredIndividual> {
        let objs: Vec<[f64; 2]> = self.population.iter().map(|s| s.objectives).collect();
        let fronts = fast_non_dominated_sort(&objs);
        let mut front: Vec<ScoredIndividual> =
            fronts[0].iter().map(|&i| self.population[i].clone()).collect();
        // total_cmp: a NaN objective (degenerate candidate) must not
        // panic the sort after the whole search already ran.
        front.sort_by(|a, b| a.objectives[0].total_cmp(&b.objectives[0]));
        front.dedup_by(|a, b| a.objectives == b.objectives);
        front
    }
}

/// Run NSGA-II for `cfg.generations`.
pub fn run(n_comparators: usize, cfg: &NsgaConfig, eval: &mut dyn Evaluator) -> NsgaResult {
    let mut rng = Pcg64::new(cfg.seed, 0x6A);
    let n_genes = 2 * n_comparators;
    let pm = cfg.p_mutation.unwrap_or(1.0 / n_genes as f64);

    let mut pop: Vec<Chromosome> =
        (0..cfg.pop_size).map(|_| Chromosome::random(&mut rng, n_comparators)).collect();
    let mut slot = 0usize;
    if cfg.seed_exact && slot < pop.len() {
        pop[slot] = Chromosome::exact(n_comparators);
        slot += 1;
    }
    if cfg.seed_ladder {
        for bits in (crate::quant::MIN_BITS..=crate::quant::MAX_BITS).rev() {
            for margin_gene in [0.999, 0.0] {
                if slot < pop.len() {
                    pop[slot] = Chromosome::uniform(n_comparators, bits, margin_gene);
                    slot += 1;
                }
            }
        }
    }
    // Warm start: archived designs take the slots after the anchors.  A
    // wrong-length seed (stale archive, different tree) is skipped so one
    // bad entry can never poison the run; overflow past `pop_size` is
    // silently clamped.
    for seed in &cfg.warm_seeds {
        if slot >= pop.len() {
            break;
        }
        if seed.genes.len() == n_genes {
            pop[slot] = seed.clone();
            slot += 1;
        }
    }
    let mut objs = eval.evaluate(&pop);
    let mut evaluations = pop.len();
    let mut history = Vec::with_capacity(cfg.generations);

    for generation in 0..cfg.generations {
        // Selection ranks for the current population.
        let (rank, crowd) = rank_and_crowding(&objs);

        // Offspring.
        let mut children = Vec::with_capacity(cfg.pop_size);
        while children.len() < cfg.pop_size {
            let p1 = tournament(&mut rng, &rank, &crowd);
            let p2 = tournament(&mut rng, &rank, &crowd);
            let (mut c1, mut c2) = sbx(&mut rng, &pop[p1], &pop[p2], cfg.p_crossover, cfg.eta_crossover);
            mutate(&mut rng, &mut c1, pm, cfg.eta_mutation);
            mutate(&mut rng, &mut c2, pm, cfg.eta_mutation);
            children.push(c1);
            if children.len() < cfg.pop_size {
                children.push(c2);
            }
        }
        let child_objs = eval.evaluate(&children);
        evaluations += children.len();

        // Elitist environmental selection over the combined pool.
        let mut all: Vec<Chromosome> = pop;
        all.extend(children);
        let mut all_objs = objs;
        all_objs.extend(child_objs);
        let selected = environmental_selection(&all_objs, cfg.pop_size);
        pop = selected.iter().map(|&i| all[i].clone()).collect();
        objs = selected.iter().map(|&i| all_objs[i]).collect();

        let fronts = fast_non_dominated_sort(&objs);
        history.push(GenStats {
            generation,
            best_error: objs.iter().map(|o| o[0]).fold(f64::INFINITY, f64::min),
            best_area: objs.iter().map(|o| o[1]).fold(f64::INFINITY, f64::min),
            front_size: fronts[0].len(),
            evaluations,
        });
    }

    NsgaResult {
        population: pop
            .into_iter()
            .zip(objs)
            .map(|(chromosome, objectives)| ScoredIndividual { chromosome, objectives })
            .collect(),
        history,
        evaluations,
    }
}

// ---- NSGA-II primitives (public for property tests) ----------------------

/// Does `a` Pareto-dominate `b` (minimization)?
#[inline]
pub fn dominates(a: &[f64; 2], b: &[f64; 2]) -> bool {
    a[0] <= b[0] && a[1] <= b[1] && (a[0] < b[0] || a[1] < b[1])
}

/// Fast non-dominated sort; returns fronts of indices, best first.
pub fn fast_non_dominated_sort(objs: &[[f64; 2]]) -> Vec<Vec<usize>> {
    let n = objs.len();
    let mut dominated_by: Vec<Vec<usize>> = vec![Vec::new(); n]; // i dominates these
    let mut dom_count = vec![0usize; n];
    for i in 0..n {
        for j in (i + 1)..n {
            if dominates(&objs[i], &objs[j]) {
                dominated_by[i].push(j);
                dom_count[j] += 1;
            } else if dominates(&objs[j], &objs[i]) {
                dominated_by[j].push(i);
                dom_count[i] += 1;
            }
        }
    }
    let mut fronts: Vec<Vec<usize>> = Vec::new();
    let mut current: Vec<usize> = (0..n).filter(|&i| dom_count[i] == 0).collect();
    while !current.is_empty() {
        let mut next = Vec::new();
        for &i in &current {
            for &j in &dominated_by[i] {
                dom_count[j] -= 1;
                if dom_count[j] == 0 {
                    next.push(j);
                }
            }
        }
        fronts.push(std::mem::take(&mut current));
        current = next;
    }
    fronts
}

/// Crowding distance of each member of `front` (index-aligned with it).
pub fn crowding_distance(objs: &[[f64; 2]], front: &[usize]) -> Vec<f64> {
    let k = front.len();
    let mut dist = vec![0.0f64; k];
    if k <= 2 {
        return vec![f64::INFINITY; k];
    }
    for obj in 0..2 {
        let mut order: Vec<usize> = (0..k).collect();
        order.sort_by(|&a, &b| objs[front[a]][obj].total_cmp(&objs[front[b]][obj]));
        let lo = objs[front[order[0]]][obj];
        let hi = objs[front[order[k - 1]]][obj];
        dist[order[0]] = f64::INFINITY;
        dist[order[k - 1]] = f64::INFINITY;
        let span = hi - lo;
        if span <= 0.0 {
            continue;
        }
        for w in 1..k - 1 {
            let prev = objs[front[order[w - 1]]][obj];
            let next = objs[front[order[w + 1]]][obj];
            dist[order[w]] += (next - prev) / span;
        }
    }
    dist
}

/// Per-individual (rank, crowding) for tournament selection.
fn rank_and_crowding(objs: &[[f64; 2]]) -> (Vec<usize>, Vec<f64>) {
    let fronts = fast_non_dominated_sort(objs);
    let mut rank = vec![0usize; objs.len()];
    let mut crowd = vec![0f64; objs.len()];
    for (r, front) in fronts.iter().enumerate() {
        let d = crowding_distance(objs, front);
        for (pos, &i) in front.iter().enumerate() {
            rank[i] = r;
            crowd[i] = d[pos];
        }
    }
    (rank, crowd)
}

/// Binary tournament under the crowded-comparison operator.
fn tournament(rng: &mut Pcg64, rank: &[usize], crowd: &[f64]) -> usize {
    let a = rng.below(rank.len() as u64) as usize;
    let b = rng.below(rank.len() as u64) as usize;
    if rank[a] < rank[b] || (rank[a] == rank[b] && crowd[a] > crowd[b]) {
        a
    } else {
        b
    }
}

/// Indices of the `target` individuals surviving elitist truncation.
pub fn environmental_selection(objs: &[[f64; 2]], target: usize) -> Vec<usize> {
    let fronts = fast_non_dominated_sort(objs);
    let mut selected = Vec::with_capacity(target);
    for front in fronts {
        if selected.len() + front.len() <= target {
            selected.extend(&front);
            if selected.len() == target {
                break;
            }
        } else {
            // Partial: take the most crowded-distant members.
            let d = crowding_distance(objs, &front);
            let mut order: Vec<usize> = (0..front.len()).collect();
            order.sort_by(|&a, &b| d[b].total_cmp(&d[a]));
            for &w in order.iter().take(target - selected.len()) {
                selected.push(front[w]);
            }
            break;
        }
    }
    selected
}

/// Simulated binary crossover on [0,1]-bounded genes.
fn sbx(
    rng: &mut Pcg64,
    p1: &Chromosome,
    p2: &Chromosome,
    pc: f64,
    eta: f64,
) -> (Chromosome, Chromosome) {
    let mut c1 = p1.clone();
    let mut c2 = p2.clone();
    if !rng.chance(pc) {
        return (c1, c2);
    }
    for g in 0..c1.genes.len() {
        if !rng.chance(0.5) {
            continue;
        }
        let (x1, x2) = (p1.genes[g], p2.genes[g]);
        if (x1 - x2).abs() < 1e-14 {
            continue;
        }
        let u: f64 = rng.f64();
        let beta = if u <= 0.5 {
            (2.0 * u).powf(1.0 / (eta + 1.0))
        } else {
            (1.0 / (2.0 * (1.0 - u))).powf(1.0 / (eta + 1.0))
        };
        let v1 = 0.5 * ((1.0 + beta) * x1 + (1.0 - beta) * x2);
        let v2 = 0.5 * ((1.0 - beta) * x1 + (1.0 + beta) * x2);
        c1.genes[g] = v1.clamp(0.0, 1.0);
        c2.genes[g] = v2.clamp(0.0, 1.0);
    }
    (c1, c2)
}

/// Polynomial mutation on [0,1]-bounded genes.
fn mutate(rng: &mut Pcg64, c: &mut Chromosome, pm: f64, eta: f64) {
    for g in 0..c.genes.len() {
        if !rng.chance(pm) {
            continue;
        }
        let x = c.genes[g];
        let u: f64 = rng.f64();
        let delta = if u < 0.5 {
            (2.0 * u + (1.0 - 2.0 * u) * (1.0 - x).powf(eta + 1.0)).powf(1.0 / (eta + 1.0)) - 1.0
        } else {
            1.0 - (2.0 * (1.0 - u) + 2.0 * (u - 0.5) * x.powf(eta + 1.0)).powf(1.0 / (eta + 1.0))
        };
        c.genes[g] = (x + delta).clamp(0.0, 1.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, PropConfig};

    /// Toy evaluator: minimize (Σ genes of even slots, Σ (1-g) of odd
    /// slots) — a clean two-objective trade-off.
    struct Toy;
    impl Evaluator for Toy {
        fn evaluate(&mut self, pop: &[Chromosome]) -> Vec<[f64; 2]> {
            pop.iter()
                .map(|c| {
                    let a: f64 = c.genes.iter().step_by(2).sum();
                    let b: f64 = c.genes.iter().skip(1).step_by(2).map(|g| 1.0 - g).sum();
                    [a, b]
                })
                .collect()
        }
    }

    #[test]
    fn dominates_relation() {
        assert!(dominates(&[0.0, 0.0], &[1.0, 1.0]));
        assert!(dominates(&[0.0, 1.0], &[0.0, 2.0]));
        assert!(!dominates(&[0.0, 2.0], &[1.0, 1.0]));
        assert!(!dominates(&[1.0, 1.0], &[1.0, 1.0]), "not strict");
    }

    #[test]
    fn fronts_partition_and_are_mutually_nondominating() {
        check(
            "nds-invariants",
            PropConfig { cases: 40, seed: 7 },
            |rng| {
                let n = 3 + rng.below(40) as usize;
                (0..n)
                    .map(|_| [rng.f64(), rng.f64()])
                    .collect::<Vec<[f64; 2]>>()
            },
            |objs| {
                let fronts = fast_non_dominated_sort(objs);
                let total: usize = fronts.iter().map(|f| f.len()).sum();
                if total != objs.len() {
                    return Err(format!("partition broken: {total} != {}", objs.len()));
                }
                // no member of front k dominates another member of front k
                for f in &fronts {
                    for &i in f {
                        for &j in f {
                            if i != j && dominates(&objs[i], &objs[j]) {
                                return Err(format!("{i} dominates {j} in same front"));
                            }
                        }
                    }
                }
                // every member of front k+1 is dominated by someone in front k
                for w in 1..fronts.len() {
                    for &j in &fronts[w] {
                        let dominated = fronts[w - 1]
                            .iter()
                            .any(|&i| dominates(&objs[i], &objs[j]));
                        if !dominated {
                            return Err(format!("front {w} member {j} undominated by front {}", w - 1));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn crowding_extremes_infinite() {
        let objs = vec![[0.0, 3.0], [1.0, 2.0], [2.0, 1.0], [3.0, 0.0]];
        let front: Vec<usize> = (0..4).collect();
        let d = crowding_distance(&objs, &front);
        assert_eq!(d[0], f64::INFINITY);
        assert_eq!(d[3], f64::INFINITY);
        assert!(d[1].is_finite() && d[1] > 0.0);
        // symmetric spacing → equal interior distances
        assert!((d[1] - d[2]).abs() < 1e-12);
    }

    #[test]
    fn environmental_selection_is_elitist() {
        check(
            "selection-elitist",
            PropConfig { cases: 30, seed: 11 },
            |rng| {
                let n = 8 + rng.below(40) as usize;
                (0..n).map(|_| [rng.f64(), rng.f64()]).collect::<Vec<[f64; 2]>>()
            },
            |objs| {
                let target = objs.len() / 2;
                let sel = environmental_selection(objs, target);
                if sel.len() != target {
                    return Err(format!("selected {} != {target}", sel.len()));
                }
                let mut uniq = sel.clone();
                uniq.sort_unstable();
                uniq.dedup();
                if uniq.len() != sel.len() {
                    return Err("duplicate selection".into());
                }
                // every front-0 member must survive (when it fits)
                let fronts = fast_non_dominated_sort(objs);
                if fronts[0].len() <= target {
                    for &i in &fronts[0] {
                        if !sel.contains(&i) {
                            return Err(format!("front-0 member {i} dropped"));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn sbx_and_mutation_stay_in_bounds() {
        let mut rng = Pcg64::seeded(3);
        for _ in 0..200 {
            let p1 = Chromosome::random(&mut rng, 6);
            let p2 = Chromosome::random(&mut rng, 6);
            let (c1, mut c2) = sbx(&mut rng, &p1, &p2, 1.0, 15.0);
            mutate(&mut rng, &mut c2, 0.5, 20.0);
            for g in c1.genes.iter().chain(c2.genes.iter()) {
                assert!((0.0..=1.0).contains(g));
            }
        }
    }

    #[test]
    fn nsga2_converges_on_toy_problem() {
        let cfg = NsgaConfig {
            pop_size: 32,
            generations: 30,
            seed: 1,
            seed_exact: false,
            ..Default::default()
        };
        let res = run(4, &cfg, &mut Toy);
        assert_eq!(res.population.len(), 32);
        assert_eq!(res.history.len(), 30);
        // The extremes of the Pareto set are reachable: error → 0, area → 0.
        let front = res.pareto_front();
        let best_a = front.iter().map(|s| s.objectives[0]).fold(f64::INFINITY, f64::min);
        let best_b = front.iter().map(|s| s.objectives[1]).fold(f64::INFINITY, f64::min);
        assert!(best_a < 0.4, "obj0 {best_a}");
        assert!(best_b < 0.4, "obj1 {best_b}");
        // Monotone improvement in evaluations count.
        assert_eq!(res.evaluations, 32 + 30 * 32);
    }

    /// Warm seeds land in the initial population right after the
    /// exact/ladder anchors, wrong-length seeds are skipped, and the
    /// injection clamps at `pop_size` instead of panicking.
    #[test]
    fn warm_seeds_injected_after_anchors_and_clamped() {
        struct Capture {
            first: Vec<Chromosome>,
            inner: Toy,
        }
        impl Evaluator for Capture {
            fn evaluate(&mut self, pop: &[Chromosome]) -> Vec<[f64; 2]> {
                if self.first.is_empty() {
                    self.first = pop.to_vec();
                }
                self.inner.evaluate(pop)
            }
        }

        let warm: Vec<Chromosome> = (0..4)
            .map(|i| Chromosome { genes: vec![0.21 + i as f64 * 0.07; 6] })
            .collect();
        let mut seeds = warm.clone();
        seeds.insert(2, Chromosome { genes: vec![0.5; 4] }); // wrong length: skipped
        let cfg = NsgaConfig {
            pop_size: 20,
            generations: 1,
            seed: 9,
            warm_seeds: seeds.clone(),
            ..Default::default()
        };
        let mut cap = Capture { first: Vec::new(), inner: Toy };
        run(3, &cfg, &mut cap);
        // Anchors: 1 exact + 7 ladder rungs x 2 margin genes = 15 slots.
        let anchors = 1 + 2 * (crate::quant::MAX_BITS - crate::quant::MIN_BITS + 1) as usize;
        assert_eq!(anchors, 15);
        for (w, seed) in warm.iter().enumerate() {
            assert_eq!(cap.first[anchors + w].genes, seed.genes, "warm seed {w}");
        }

        // A population too small for every seed clamps without panicking.
        let tight = NsgaConfig { pop_size: 16, generations: 1, seed: 9, warm_seeds: seeds, ..Default::default() };
        let mut cap = Capture { first: Vec::new(), inner: Toy };
        run(3, &tight, &mut cap);
        assert_eq!(cap.first.len(), 16);
        assert_eq!(cap.first[15].genes, warm[0].genes, "only the first seed fits");
    }

    #[test]
    fn nsga2_deterministic_in_seed() {
        let cfg = NsgaConfig { pop_size: 16, generations: 5, seed: 9, ..Default::default() };
        let a = run(3, &cfg, &mut Toy);
        let b = run(3, &cfg, &mut Toy);
        let oa: Vec<[f64; 2]> = a.population.iter().map(|s| s.objectives).collect();
        let ob: Vec<[f64; 2]> = b.population.iter().map(|s| s.objectives).collect();
        assert_eq!(oa, ob);
    }

    #[test]
    fn pareto_front_is_nondominated_and_sorted() {
        let cfg = NsgaConfig { pop_size: 24, generations: 10, seed: 2, ..Default::default() };
        let res = run(4, &cfg, &mut Toy);
        let front = res.pareto_front();
        for w in 1..front.len() {
            assert!(front[w].objectives[0] >= front[w - 1].objectives[0]);
        }
        for a in &front {
            for b in &front {
                assert!(!dominates(&a.objectives, &b.objectives) || a.objectives == b.objectives);
            }
        }
    }
}
