//! Genetic optimization (paper §III-B): NSGA-II over dual-approximation
//! chromosomes.
//!
//! * [`chromosome`] — the 2N-gene real-coded encoding of Fig. 3a: per
//!   comparator a precision gene (2–8 bits) and a substitution-margin gene
//!   (0..±m), decoded through the precision-conversion module of Fig. 3b.
//! * [`nsga2`] — elitist non-dominated sorting GA: binary tournament on the
//!   crowded comparison, simulated binary crossover, polynomial mutation,
//!   fast non-dominated sort + crowding-distance truncation.

pub mod chromosome;
pub mod nsga2;

pub use chromosome::{Chromosome, DecodeContext};
pub use nsga2::{run as run_nsga2, Evaluator, GenStats, NsgaConfig, NsgaResult, ScoredIndividual};
