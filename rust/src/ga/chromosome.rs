//! Chromosome encoding/decoding (paper Fig. 3).
//!
//! "It contains 2N genes, where N is the number of comparators in the
//! targeted bespoke classifier.  For every comparator, two genes are
//! stored: the precision of its input feature and threshold, and the margin
//! m by which to alter the threshold value, in order to substitute it with
//! a hardware-friendlier one."
//!
//! Genes are real-coded in [0, 1] (the representation SBX/polynomial
//! mutation operate on) and decoded to the discrete phenotype:
//!
//! * gene `2j`   → precision `bits_j ∈ [MIN_BITS, MAX_BITS]`
//! * gene `2j+1` → margin `m_j ∈ [0, margin_max]`; the threshold is then
//!   replaced by the *cheapest* integer within ±m_j (area-LUT argmin) —
//!   the area-driven replacement of §III-A.

use crate::hw::synth::TreeApprox;
use crate::hw::AreaLut;
use crate::quant::{self, MAX_BITS, MIN_BITS};
use crate::util::rng::Pcg64;

/// Everything needed to decode genes into a concrete [`TreeApprox`].
pub struct DecodeContext<'a> {
    /// Float thresholds of the trained tree's comparator slots.
    pub thresholds: &'a [f32],
    /// Comparator area oracle (drives the substitution argmin).
    pub lut: &'a AreaLut,
    /// Maximum substitution margin (paper: ±5).
    pub margin_max: u32,
}

/// A real-coded individual. `genes.len() == 2 * n_comparators`.
#[derive(Clone, Debug, PartialEq)]
pub struct Chromosome {
    pub genes: Vec<f64>,
}

impl Chromosome {
    pub fn random(rng: &mut Pcg64, n_comparators: usize) -> Chromosome {
        Chromosome { genes: (0..2 * n_comparators).map(|_| rng.f64()).collect() }
    }

    /// The all-exact individual: 8 bits, margin 0 (the paper's baseline as
    /// a chromosome; seeding it keeps the baseline in the initial front).
    pub fn exact(n_comparators: usize) -> Chromosome {
        let mut genes = Vec::with_capacity(2 * n_comparators);
        for _ in 0..n_comparators {
            genes.push(0.999_999); // decodes to MAX_BITS
            genes.push(0.0); // margin 0
        }
        Chromosome { genes }
    }

    /// Uniform-precision individual: every comparator at `bits`, margin
    /// gene at `margin_gene` (0.0 → no substitution, ~1.0 → full margin).
    /// These are the "ladder" anchors seeded into initial populations:
    /// coarse uniform quantization is the strongest known-good region of
    /// the space, and the GA refines per-comparator from there.
    pub fn uniform(n_comparators: usize, bits: u8, margin_gene: f64) -> Chromosome {
        assert!((MIN_BITS..=MAX_BITS).contains(&bits));
        // Center of the decode bucket for `bits`.
        let g_bits = (bits - MIN_BITS) as f64 / 7.0 + 0.5 / 7.0;
        let mut genes = Vec::with_capacity(2 * n_comparators);
        for _ in 0..n_comparators {
            genes.push(g_bits);
            genes.push(margin_gene.clamp(0.0, 1.0));
        }
        Chromosome { genes }
    }

    pub fn n_comparators(&self) -> usize {
        self.genes.len() / 2
    }

    /// Decoded precision of comparator `j`.
    pub fn bits(&self, j: usize) -> u8 {
        decode_range(self.genes[2 * j], MIN_BITS as u32, MAX_BITS as u32) as u8
    }

    /// Decoded substitution margin of comparator `j`.
    pub fn margin(&self, j: usize, margin_max: u32) -> u32 {
        decode_range(self.genes[2 * j + 1], 0, margin_max)
    }

    /// Decode to the concrete per-comparator approximation (Fig. 3b: float
    /// threshold → fixed point at `bits` → integer → area-driven
    /// substitution within ±margin).
    pub fn decode(&self, ctx: &DecodeContext) -> TreeApprox {
        let n = self.n_comparators();
        assert_eq!(n, ctx.thresholds.len());
        let mut bits = Vec::with_capacity(n);
        let mut thr_int = Vec::with_capacity(n);
        for j in 0..n {
            let b = self.bits(j);
            let t = quant::int_threshold(ctx.thresholds[j], b);
            let m = self.margin(j, ctx.margin_max);
            let (t_sub, _) = ctx.lut.cheapest_in_margin(b, t, m);
            bits.push(b);
            thr_int.push(t_sub);
        }
        TreeApprox { bits, thr_int }
    }

    /// Stable 128-bit cache key over the *phenotype* (two chromosomes that
    /// decode identically share fitness).
    ///
    /// 128 bits, not 64: these keys outlive the run in the persistent
    /// accuracy cache (`fitness::cache`), where a birthday collision at
    /// 64 bits would silently serve one phenotype another's objectives.
    pub fn phenotype_key(&self, ctx: &DecodeContext) -> u128 {
        Self::phenotype_key_of(&self.decode(ctx))
    }

    /// Key over an already-decoded phenotype (avoids re-decoding when the
    /// caller needs both — the fitness evaluator's hot path).
    pub fn phenotype_key_of(approx: &TreeApprox) -> u128 {
        crate::util::rng::fnv1a128(&Self::phenotype_bytes(approx))
    }

    /// Canonical byte encoding of a phenotype: 5 bytes per comparator
    /// (`bits` then the little-endian integer threshold). Shared by the
    /// cache keys and their tests so a crafted near-collision exercises
    /// the exact bytes the cache hashes.
    pub fn phenotype_bytes(approx: &TreeApprox) -> Vec<u8> {
        let mut bytes = Vec::with_capacity(approx.bits.len() * 5);
        for (b, t) in approx.bits.iter().zip(&approx.thr_int) {
            bytes.push(*b);
            bytes.extend_from_slice(&t.to_le_bytes());
        }
        bytes
    }
}

/// Map a [0,1) gene onto the inclusive integer range [lo, hi].
#[inline]
fn decode_range(g: f64, lo: u32, hi: u32) -> u32 {
    let span = (hi - lo + 1) as f64;
    let v = lo as f64 + (g.clamp(0.0, 1.0) * span).floor();
    (v as u32).min(hi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::EgtLibrary;

    fn ctx_fixture() -> (Vec<f32>, AreaLut) {
        (vec![0.31, 0.62, 0.05, 0.97], AreaLut::build(&EgtLibrary::default()))
    }

    #[test]
    fn decode_range_covers_bounds() {
        assert_eq!(decode_range(0.0, 2, 8), 2);
        assert_eq!(decode_range(0.999_999, 2, 8), 8);
        assert_eq!(decode_range(1.0, 2, 8), 8);
        // Uniform-ish: each of 7 values gets 1/7 of the interval.
        assert_eq!(decode_range(0.142, 2, 8), 2);
        assert_eq!(decode_range(0.143, 2, 8), 3);
    }

    #[test]
    fn exact_chromosome_is_baseline() {
        let (thr, lut) = ctx_fixture();
        let ctx = DecodeContext { thresholds: &thr, lut: &lut, margin_max: 5 };
        let c = Chromosome::exact(4);
        let approx = c.decode(&ctx);
        assert!(approx.bits.iter().all(|&b| b == MAX_BITS));
        for (j, &t) in approx.thr_int.iter().enumerate() {
            assert_eq!(t, quant::int_threshold(thr[j], MAX_BITS), "slot {j}");
        }
    }

    #[test]
    fn decode_respects_margin() {
        let (thr, lut) = ctx_fixture();
        let ctx = DecodeContext { thresholds: &thr, lut: &lut, margin_max: 5 };
        let mut rng = Pcg64::seeded(5);
        for _ in 0..50 {
            let c = Chromosome::random(&mut rng, 4);
            let approx = c.decode(&ctx);
            for j in 0..4 {
                let t0 = quant::int_threshold(thr[j], approx.bits[j]) as i64;
                let m = c.margin(j, 5) as i64;
                let d = (approx.thr_int[j] as i64 - t0).abs();
                assert!(d <= m, "slot {j}: moved {d} > margin {m}");
                assert!(approx.thr_int[j] < (1u32 << approx.bits[j]));
            }
        }
    }

    #[test]
    fn substitution_never_increases_area() {
        let (thr, lut) = ctx_fixture();
        let ctx = DecodeContext { thresholds: &thr, lut: &lut, margin_max: 5 };
        let mut rng = Pcg64::seeded(9);
        for _ in 0..50 {
            let c = Chromosome::random(&mut rng, 4);
            let approx = c.decode(&ctx);
            for j in 0..4 {
                let t0 = quant::int_threshold(thr[j], approx.bits[j]);
                assert!(
                    lut.area(approx.bits[j], approx.thr_int[j]) <= lut.area(approx.bits[j], t0)
                );
            }
        }
    }

    #[test]
    fn phenotype_key_stable_and_discriminating() {
        let (thr, lut) = ctx_fixture();
        let ctx = DecodeContext { thresholds: &thr, lut: &lut, margin_max: 5 };
        let a = Chromosome::exact(4);
        let mut b = Chromosome::exact(4);
        assert_eq!(a.phenotype_key(&ctx), b.phenotype_key(&ctx));
        // Tiny gene change within the same decode bucket: same key.
        b.genes[0] = 0.999;
        assert_eq!(a.phenotype_key(&ctx), b.phenotype_key(&ctx));
        // Crossing a decode boundary changes the key.
        b.genes[0] = 0.0;
        assert_ne!(a.phenotype_key(&ctx), b.phenotype_key(&ctx));
    }

    /// Regression for the 64-bit collision hazard: the per-run fitness
    /// cache used to key on bare `fnv1a(bytes) as u64`, so two colliding
    /// phenotypes silently shared objectives. A genuine 64-bit birthday
    /// collision needs ~2^32 candidates — out of reach for a unit test —
    /// so this crafts the same failure mode at 32 bits (where the
    /// birthday bound is ~2^16 candidates): find two distinct phenotypes
    /// whose old-style 64-bit keys agree on their low 32 bits, i.e. a
    /// pair "half way" to the collision that poisoned the old cache, and
    /// pin that the widened 128-bit key still separates them.
    #[test]
    fn crafted_near_collision_separated_by_128bit_key() {
        use crate::util::rng::{fnv1a, fnv1a128};
        use std::collections::HashMap;

        let approx_for = |t: u32| TreeApprox { bits: vec![8, 8], thr_int: vec![t & 0xff, t >> 8] };
        let old_key = |a: &TreeApprox| fnv1a(&Chromosome::phenotype_bytes(a));

        let mut seen: HashMap<u32, u32> = HashMap::new();
        let mut pair = None;
        for t in 0..200_000u32 {
            let truncated = old_key(&approx_for(t)) as u32;
            if let Some(&prev) = seen.get(&truncated) {
                pair = Some((prev, t));
                break;
            }
            seen.insert(truncated, t);
        }
        let (ta, tb) = pair.expect("birthday bound guarantees a 32-bit collision in 2^17.6 tries");
        let (a, b) = (approx_for(ta), approx_for(tb));
        assert_ne!(a.thr_int, b.thr_int, "crafted inputs must be distinct phenotypes");
        assert_eq!(old_key(&a) as u32, old_key(&b) as u32, "pair must collide at 32 bits");
        // The fix: the cache key is the full 128-bit fingerprint, which
        // separates the crafted pair (and is not a widening of the old
        // hash, so old-key collisions carry no structure into it).
        assert_ne!(Chromosome::phenotype_key_of(&a), Chromosome::phenotype_key_of(&b));
        assert_ne!(
            fnv1a128(&Chromosome::phenotype_bytes(&a)) as u64,
            fnv1a(&Chromosome::phenotype_bytes(&a)),
        );
    }
}
