//! XLA/PJRT runtime: loads the AOT artifacts and executes them.
//!
//! This is the only place the `xla` crate is touched, and everything that
//! does is gated behind the optional `xla` cargo feature — the default
//! build is pure native Rust and must compile offline.  [`ArtifactMeta`]
//! (plain JSON parsing of `artifacts/meta.json`) stays available in every
//! build: routing decisions and the `axdt info` command need it without a
//! PJRT client.
//!
//! With the feature enabled: artifacts are HLO *text* (see
//! `python/compile/aot.py` for why not serialized protos), parsed with
//! `HloModuleProto::from_text_file`, compiled once per shape bucket on the
//! CPU PJRT client, and cached.  Chromosome-independent operands (`xsel`,
//! `wleaf`, …) are uploaded to device buffers **once per problem**
//! ([`DeviceStatics`]) and reused every generation; only the per-batch
//! `(thr, scale)` tensors cross the host boundary per execution
//! (`execute_b`).
//!
//! An `XlaRuntime` (client + executable cache + uploaded statics) is
//! deliberately single-threaded and `!Send`: scaling comes from the
//! coordinator's shard pool, where **each worker constructs its own
//! runtime** inside its thread and problems are hash-pinned to the worker
//! that holds their device buffers (see `coordinator::shard`).

#[cfg(feature = "xla")]
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::fitness::encode::Bucket;
#[cfg(feature = "xla")]
use crate::fitness::encode::StaticTensors;
use crate::util::json::Json;

/// Parsed `artifacts/meta.json`.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub dir: PathBuf,
    pub tile_s: usize,
    pub buckets: Vec<(Bucket, String)>, // (shape, hlo file name)
}

impl ArtifactMeta {
    pub fn load(dir: impl AsRef<Path>) -> Result<ArtifactMeta> {
        let dir = dir.as_ref().to_path_buf();
        let meta_path = dir.join("meta.json");
        let text = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("reading {meta_path:?}; run `make artifacts` first"))?;
        let json = Json::parse(&text).context("parsing meta.json")?;
        let tile_s = json
            .get("tile_s")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("meta.json: missing tile_s"))?;
        let buckets_obj = json
            .get("buckets")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("meta.json: missing buckets"))?;
        let mut buckets = Vec::new();
        for (name, b) in buckets_obj {
            let field = |k: &str| -> Result<usize> {
                b.get(k)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("meta.json: bucket {name}: missing {k}"))
            };
            let bucket = Bucket {
                name: name.clone(),
                s: field("s")?,
                n: field("n")?,
                l: field("l")?,
                c: field("c")?,
                p: field("p")?,
            };
            let file = b
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("meta.json: bucket {name}: missing file"))?
                .to_string();
            buckets.push((bucket, file));
        }
        // Smallest-first so routing picks the tightest fit.
        buckets.sort_by_key(|(b, _)| b.s * b.n);
        Ok(ArtifactMeta { dir, tile_s, buckets })
    }

    /// Smallest bucket that fits the problem.
    pub fn route(&self, problem: &crate::fitness::Problem) -> Option<&(Bucket, String)> {
        self.buckets.iter().find(|(b, _)| b.fits(problem))
    }
}

/// Static operands resident on the PJRT device.
#[cfg(feature = "xla")]
pub struct DeviceStatics {
    pub bucket: Bucket,
    xsel: xla::PjRtBuffer,
    labels: xla::PjRtBuffer,
    valid: xla::PjRtBuffer,
    wleaf: xla::PjRtBuffer,
    bias: xla::PjRtBuffer,
    onehot: xla::PjRtBuffer,
}

/// The PJRT CPU client plus compiled executables per bucket.
#[cfg(feature = "xla")]
pub struct XlaRuntime {
    pub meta: ArtifactMeta,
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

#[cfg(feature = "xla")]
impl XlaRuntime {
    /// Create the client and lazily-compilable runtime.
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<XlaRuntime> {
        let meta = ArtifactMeta::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu().map_err(to_anyhow)?;
        Ok(XlaRuntime { meta, client, executables: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch the cached) executable for a bucket.
    pub fn ensure_compiled(&mut self, bucket_name: &str) -> Result<()> {
        if self.executables.contains_key(bucket_name) {
            return Ok(());
        }
        let (_, file) = self
            .meta
            .buckets
            .iter()
            .find(|(b, _)| b.name == bucket_name)
            .ok_or_else(|| anyhow!("unknown bucket {bucket_name}"))?;
        let path = self.meta.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(to_anyhow)
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(to_anyhow)?;
        self.executables.insert(bucket_name.to_string(), exe);
        Ok(())
    }

    /// Upload a problem's static tensors to the device.
    pub fn upload_statics(&self, st: &StaticTensors) -> Result<DeviceStatics> {
        let b = &st.bucket;
        let up = |data: &[f32], dims: &[usize]| -> Result<xla::PjRtBuffer> {
            self.client
                .buffer_from_host_buffer::<f32>(data, dims, None)
                .map_err(to_anyhow)
        };
        Ok(DeviceStatics {
            bucket: b.clone(),
            xsel: up(&st.xsel, &[b.s, b.n])?,
            labels: up(&st.labels, &[b.s])?,
            valid: up(&st.valid, &[b.s])?,
            wleaf: up(&st.wleaf, &[b.n, b.l])?,
            bias: up(&st.bias, &[b.l])?,
            onehot: up(&st.onehot, &[b.l, b.c])?,
        })
    }

    /// Execute one population evaluation; returns P accuracies.
    pub fn execute(
        &mut self,
        statics: &DeviceStatics,
        thr: &[f32],
        scale: &[f32],
    ) -> Result<Vec<f32>> {
        let b = statics.bucket.clone();
        self.ensure_compiled(&b.name)?;
        let exe = &self.executables[&b.name];
        let thr_buf = self
            .client
            .buffer_from_host_buffer::<f32>(thr, &[b.p, b.n], None)
            .map_err(to_anyhow)?;
        let scale_buf = self
            .client
            .buffer_from_host_buffer::<f32>(scale, &[b.p, b.n], None)
            .map_err(to_anyhow)?;
        let args = [
            &statics.xsel,
            &statics.labels,
            &statics.valid,
            &thr_buf,
            &scale_buf,
            &statics.wleaf,
            &statics.bias,
            &statics.onehot,
        ];
        let result = exe.execute_b::<&xla::PjRtBuffer>(&args).map_err(to_anyhow)?;
        let literal = result[0][0].to_literal_sync().map_err(to_anyhow)?;
        // Lowered with return_tuple=True → 1-tuple.
        let acc = literal.to_tuple1().map_err(to_anyhow)?;
        acc.to_vec::<f32>().map_err(to_anyhow)
    }
}

#[cfg(feature = "xla")]
fn to_anyhow(e: xla::Error) -> anyhow::Error {
    anyhow!("{e}")
}

#[cfg(test)]
mod tests {
    use super::*;

    const ART: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");

    #[test]
    fn meta_parses_and_routes() {
        if !Path::new(ART).join("meta.json").exists() {
            eprintln!(
                "skipping meta_parses_and_routes: {ART}/meta.json not found \
                 (run `make artifacts` to generate the AOT artifacts)"
            );
            return;
        }
        let meta = ArtifactMeta::load(ART).expect("run `make artifacts` first");
        assert!(meta.tile_s >= 128, "tile_s {}", meta.tile_s);
        assert_eq!(meta.buckets.len(), 3);
        assert_eq!(meta.buckets[0].0.name, "small");
        // Buckets sorted by capacity.
        assert!(meta.buckets[0].0.s <= meta.buckets[2].0.s);
    }

    // End-to-end runtime correctness is covered in rust/tests/ (integration),
    // where a real problem is routed, uploaded and executed against the
    // native oracle.
}
