//! Experiment regeneration: every table and figure of the paper.
//!
//! Shared by `cargo bench` targets, the `axdt repro` CLI subcommands and
//! `examples/paper_repro.rs`.  Each function returns the formatted report
//! (and machine-readable JSON via [`RunArchive`]) so callers decide where
//! it goes.
//!
//! | paper artifact | function |
//! |----------------|----------|
//! | Table I        | [`table1`] |
//! | Fig. 4 (a,b)   | [`fig4`]   |
//! | Fig. 5 (a–j)   | [`fig5_run`] + [`render_fig5`] |
//! | Table II       | [`table2`] |

// Every `.unwrap()` here is `fmt::Write` into a `String`, which is
// infallible — the allow keeps the report builders free of `let _ =`
// noise without weakening the crate-wide `clippy::unwrap_used` gate.
#![allow(clippy::unwrap_used)]

use std::fmt::Write as _;

use anyhow::Result;

use crate::coordinator::{optimize_dataset, DatasetRun, EvalService, RunOptions};
use crate::data::generators::{self, DatasetSpec};
use crate::dt::{train, TrainConfig};
use crate::hw::synth::{self, TreeApprox};
use crate::hw::{AreaLut, EgtLibrary, HwReport};
use crate::util::json::Json;

/// Blue Spark printed-battery budget (paper Table II highlighting).
pub const BATTERY_MW: f64 = 3.0;
/// Energy-harvester budget.
pub const HARVESTER_MW: f64 = 0.1;

/// One Table I row: the exact 8-bit bespoke baseline of a dataset.
#[derive(Clone, Debug)]
pub struct Table1Row {
    pub spec: &'static DatasetSpec,
    pub accuracy: f64,
    pub n_comparators: usize,
    pub report: HwReport,
}

/// Build the exact baseline for one dataset (generate → train → synth).
pub fn exact_baseline(dataset: &str, seed: u64) -> Result<Table1Row> {
    let spec = generators::spec(dataset)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset '{dataset}'"))?;
    let lib = EgtLibrary::default();
    let data = generators::generate(spec, seed);
    let (train_d, test_d) = data.split(0.3, seed);
    let tree = train(
        &train_d,
        &TrainConfig { max_leaves: spec.max_leaves, min_samples_split: 2 },
    );
    let accuracy = tree.accuracy(&test_d.x, &test_d.y, test_d.n_features);
    let circuit = synth::synth_tree(&tree, &TreeApprox::exact(&tree));
    let report = circuit.netlist.report(&lib);
    Ok(Table1Row { spec, accuracy, n_comparators: tree.n_comparators(), report })
}

/// Table I: evaluation of exact bespoke DT circuits.
pub fn table1(datasets: &[String], seed: u64) -> Result<(String, Vec<Table1Row>)> {
    let mut rows = Vec::new();
    for d in datasets {
        rows.push(exact_baseline(d, seed)?);
    }
    let mut out = String::new();
    writeln!(out, "TABLE I: Evaluation of exact bespoke Decision Tree circuits").unwrap();
    writeln!(
        out,
        "{:<12} {:>9} {:>9} {:>7} {:>7} {:>11} {:>12} {:>11} {:>12} {:>11}",
        "Dataset", "Accuracy", "(paper)", "#Comp", "(paper)",
        "Delay(ms)", "Area(mm^2)", "(paper)", "Power(mW)", "(paper)"
    )
    .unwrap();
    for r in &rows {
        writeln!(
            out,
            "{:<12} {:>9.3} {:>9.3} {:>7} {:>7} {:>11.1} {:>12.2} {:>11.2} {:>12.2} {:>11.2}",
            r.spec.display,
            r.accuracy,
            r.spec.paper_accuracy,
            r.n_comparators,
            r.spec.paper_comparators,
            r.report.delay_ms,
            r.report.area_mm2,
            r.spec.paper_area_mm2,
            r.report.power_mw,
            r.spec.paper_power_mw,
        )
        .unwrap();
    }
    Ok((out, rows))
}

/// Fig. 4: bespoke-comparator area vs. integer threshold at 6 and 8 bits.
/// Returns (rendered text, 6-bit curve, 8-bit curve).
pub fn fig4() -> (String, Vec<f64>, Vec<f64>) {
    let lib = EgtLibrary::default();
    let lut = AreaLut::build(&lib);
    let c6 = lut.curve(6).to_vec();
    let c8 = lut.curve(8).to_vec();
    let mut out = String::new();
    writeln!(out, "FIG 4: bespoke comparator area (mm^2) vs threshold value").unwrap();
    for (bits, curve) in [(6u8, &c6), (8u8, &c8)] {
        let mean = curve.iter().sum::<f64>() / curve.len() as f64;
        let max = curve.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        writeln!(
            out,
            "  ({}) {bits}-bit: {} thresholds, mean {mean:.3}, max {max:.3}",
            if bits == 6 { 'a' } else { 'b' },
            curve.len()
        )
        .unwrap();
        writeln!(out, "{}", ascii_curve(curve, 64, 8)).unwrap();
    }
    (out, c6, c8)
}

/// Coarse ASCII rendition of an area curve (docs + quick eyeballing).
pub fn ascii_curve(curve: &[f64], width: usize, height: usize) -> String {
    let max = curve.iter().cloned().fold(f64::EPSILON, f64::max);
    let bucket = curve.len().div_ceil(width);
    let cols: Vec<f64> = curve
        .chunks(bucket)
        .map(|c| c.iter().sum::<f64>() / c.len() as f64)
        .collect();
    let mut grid = vec![vec![' '; cols.len()]; height];
    for (x, &v) in cols.iter().enumerate() {
        let h = ((v / max) * (height as f64 - 1.0)).round() as usize;
        for row in grid.iter_mut().take(h + 1) {
            // fill from bottom: grid[height-1-k]
            let _ = row;
        }
        for k in 0..=h {
            grid[height - 1 - k][x] = if k == h { '*' } else { '.' };
        }
    }
    let mut s = String::new();
    for row in grid {
        s.push_str("    |");
        s.extend(row);
        s.push('\n');
    }
    s.push_str("    +");
    s.push_str(&"-".repeat(cols.len()));
    s.push('\n');
    s
}

/// Run Fig. 5 optimization for one dataset.
pub fn fig5_run(
    dataset: &str,
    opts: &RunOptions,
    service: Option<&EvalService>,
) -> Result<DatasetRun> {
    optimize_dataset(dataset, opts, service)
}

/// Render one dataset's pareto front (paper Fig. 5 panel): normalized area
/// (w.r.t. the exact baseline, as the paper normalizes) vs accuracy, for
/// both the GA's estimated area and the fully synthesized measurement.
pub fn render_fig5(run: &DatasetRun) -> String {
    let mut out = String::new();
    writeln!(
        out,
        "FIG 5 ({}): baseline acc {:.3} area {:.2} mm^2 | engine={} evals={} elapsed={:.1}s",
        run.spec.display,
        run.baseline_accuracy,
        run.baseline.area_mm2,
        run.engine,
        run.evaluations,
        run.elapsed_s
    )
    .unwrap();
    writeln!(
        out,
        "    {:>9} {:>12} {:>12} {:>12} {:>11}",
        "accuracy", "norm.est", "norm.area", "area(mm^2)", "power(mW)"
    )
    .unwrap();
    for p in &run.front {
        writeln!(
            out,
            "    {:>9.4} {:>12.3} {:>12.3} {:>12.2} {:>11.3}",
            p.accuracy,
            p.est_area_mm2 / run.baseline.area_mm2,
            p.measured.area_mm2 / run.baseline.area_mm2,
            p.measured.area_mm2,
            p.measured.power_mw,
        )
        .unwrap();
    }
    out
}

/// Table II: best designs within an accuracy-loss budget, with battery /
/// harvester feasibility highlighting.
pub fn table2(runs: &[DatasetRun], loss: f64) -> String {
    let mut out = String::new();
    writeln!(out, "TABLE II: area/power at accuracy threshold {:.0}%", loss * 100.0).unwrap();
    writeln!(
        out,
        "{:<12} {:>9} {:>12} {:>10} {:>11} {:>11} {:>9}",
        "Dataset", "Accuracy", "Area(mm^2)", "NormArea", "Power(mW)", "NormPower", "Supply"
    )
    .unwrap();
    let mut area_gains = Vec::new();
    let mut power_gains = Vec::new();
    for run in runs {
        match run.best_within_loss(loss) {
            None => {
                writeln!(out, "{:<12} -- no design within budget --", run.spec.display).unwrap();
            }
            Some(p) => {
                let na = p.measured.area_mm2 / run.baseline.area_mm2;
                let np = p.measured.power_mw / run.baseline.power_mw;
                area_gains.push(1.0 / na);
                power_gains.push(1.0 / np);
                let supply = if p.measured.power_mw < HARVESTER_MW {
                    "harvest"
                } else if p.measured.power_mw < BATTERY_MW {
                    "battery"
                } else {
                    "ext"
                };
                writeln!(
                    out,
                    "{:<12} {:>9.2} {:>12.2} {:>10.3} {:>11.2} {:>11.3} {:>9}",
                    run.spec.display, p.accuracy, p.measured.area_mm2, na,
                    p.measured.power_mw, np, supply
                )
                .unwrap();
            }
        }
    }
    if !area_gains.is_empty() {
        writeln!(
            out,
            "geo-mean gains: area {:.2}x  power {:.2}x   (paper: 3.2x / 3.4x)",
            crate::util::stats::geomean(&area_gains),
            crate::util::stats::geomean(&power_gains),
        )
        .unwrap();
    }
    out
}

/// Machine-readable archive of a batch of runs (written to `--out`).
pub struct RunArchive<'a> {
    pub runs: &'a [DatasetRun],
    /// Shared eval-service telemetry for the whole batch — the
    /// histogram block from
    /// [`Metrics::histograms_json`](crate::coordinator::Metrics::histograms_json)
    /// (count/p50/p90/p99/max per hot-path histogram).  `None` for
    /// serviceless (plain native) runs; archived as JSON `null`.
    pub service: Option<Json>,
}

impl<'a> RunArchive<'a> {
    pub fn to_json(&self) -> Json {
        let runs = Json::Arr(
            self.runs
                .iter()
                .map(|r| {
                    Json::obj(vec![
                        ("dataset", Json::str(r.spec.id)),
                        ("baseline_accuracy", Json::num(r.baseline_accuracy)),
                        ("baseline_area_mm2", Json::num(r.baseline.area_mm2)),
                        ("baseline_power_mw", Json::num(r.baseline.power_mw)),
                        ("baseline_delay_ms", Json::num(r.baseline.delay_ms)),
                        ("n_comparators", Json::num(r.n_comparators as f64)),
                        ("evaluations", Json::num(r.evaluations as f64)),
                        // Cache effectiveness of the fitness evaluator,
                        // next to the eval-service coalescing gauges.
                        ("eval_requested", Json::num(r.stats.requested as f64)),
                        ("eval_cache_hits", Json::num(r.stats.cache_hits as f64)),
                        // Shared-tier hits (L1 = this process, L2 = loaded
                        // from disk): `eval_engine_evals == 0` with
                        // `eval_l2_hits > 0` is the warm-repeat proof CI
                        // asserts on.
                        ("eval_l1_hits", Json::num(r.stats.l1_hits as f64)),
                        ("eval_l2_hits", Json::num(r.stats.l2_hits as f64)),
                        ("eval_engine_evals", Json::num(r.stats.engine_evals as f64)),
                        ("elapsed_s", Json::num(r.elapsed_s)),
                        ("engine", Json::str(r.engine)),
                        (
                            "front",
                            Json::Arr(
                                r.front
                                    .iter()
                                    .map(|p| {
                                        Json::obj(vec![
                                            ("accuracy", Json::num(p.accuracy)),
                                            ("est_area_mm2", Json::num(p.est_area_mm2)),
                                            ("area_mm2", Json::num(p.measured.area_mm2)),
                                            ("power_mw", Json::num(p.measured.power_mw)),
                                            ("delay_ms", Json::num(p.measured.delay_ms)),
                                            // The chromosome itself, so a
                                            // later `--warm-start` can seed
                                            // from this archive.  Gene
                                            // values round-trip bit-exactly
                                            // (shortest-repr f64 printing).
                                            ("genes", Json::arr_f64(&p.genes)),
                                        ])
                                    })
                                    .collect(),
                            ),
                        ),
                    ])
                })
                .collect(),
        );
        Json::obj(vec![
            ("runs", runs),
            ("service", self.service.clone().unwrap_or(Json::Null)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::EngineChoice;

    #[test]
    fn table1_single_dataset() {
        let (text, rows) = table1(&["seeds".into()], 42).unwrap();
        assert_eq!(rows.len(), 1);
        assert!(text.contains("Seeds"));
        assert!(rows[0].report.area_mm2 > 0.0);
        assert_eq!(rows[0].n_comparators, rows[0].spec.max_leaves - 1);
    }

    #[test]
    fn fig4_curves() {
        let (text, c6, c8) = fig4();
        assert_eq!(c6.len(), 64);
        assert_eq!(c8.len(), 256);
        assert!(text.contains("6-bit"));
        assert!(text.contains("8-bit"));
    }

    #[test]
    fn fig5_and_table2_render() {
        let opts = RunOptions {
            pop_size: 12,
            generations: 4,
            engine: EngineChoice::Native,
            ..Default::default()
        };
        let run = fig5_run("seeds", &opts, None).unwrap();
        let fig = render_fig5(&run);
        assert!(fig.contains("FIG 5 (Seeds)"));
        let t2 = table2(std::slice::from_ref(&run), 0.05);
        assert!(t2.contains("TABLE II"));
        let json = RunArchive { runs: std::slice::from_ref(&run), service: None }
            .to_json()
            .to_string();
        assert!(json.contains("\"dataset\":\"seeds\""));
        // Serviceless batch: the service telemetry slot archives as null.
        assert!(json.contains("\"service\":null"), "{json}");
        // Cache effectiveness is archived per dataset: 12 + 4x12
        // chromosomes requested; engine evals never exceed the post-cache
        // misses (within-batch dedup can shrink them further).
        assert!(json.contains("\"eval_requested\":60"), "{json}");
        assert_eq!(run.stats.requested, 60);
        assert!(run.stats.engine_evals <= 60 - run.stats.cache_hits);
        assert!(run.stats.engine_evals > 0);
        // Tier counters are archived (zero here: no shared cache wired)
        // and every front point carries its warm-startable genes.
        assert!(json.contains("\"eval_l1_hits\":0"), "{json}");
        assert!(json.contains("\"eval_l2_hits\":0"), "{json}");
        let parsed = crate::util::json::Json::parse(&json).unwrap();
        let front = parsed.get("runs").unwrap().as_arr().unwrap()[0]
            .get("front")
            .unwrap()
            .as_arr()
            .unwrap();
        for p in front {
            let genes = p.get("genes").unwrap().as_arr().unwrap();
            assert_eq!(genes.len(), 2 * run.n_comparators);
        }

        // Service-backed batches archive the shared histogram block.
        let hist = crate::coordinator::Metrics::with_shards(1).histograms_json();
        let json = RunArchive { runs: std::slice::from_ref(&run), service: Some(hist) }
            .to_json()
            .to_string();
        assert!(json.contains("\"exec_latency_ns\""), "{json}");
        assert!(json.contains("\"ticket_latency_ns\""), "{json}");
        crate::util::json::Json::parse(&json).unwrap();
    }

    #[test]
    fn ascii_curve_shape() {
        let s = ascii_curve(&[0.0, 1.0, 0.5, 1.0], 4, 4);
        assert!(s.contains('*'));
        assert!(s.lines().count() == 5);
    }
}
