// Dev probe: verify an HLO-text artifact parses, compiles and executes on the
// PJRT CPU client. Usage: probe_artifact <path> [s n l c p]
fn main() -> anyhow::Result<()> {
    let path = std::env::args().nth(1).expect("path");
    let client = xla::PjRtClient::cpu()?;
    let proto = xla::HloModuleProto::from_text_file(&path)?;
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = client.compile(&comp)?;
    println!("compiled OK: {}", path);
    let dims: Vec<usize> = std::env::args().skip(2).map(|a| a.parse().unwrap()).collect();
    if dims.len() == 5 {
        let (s, n, l, c, p) = (dims[0], dims[1], dims[2], dims[3], dims[4]);
        let f = |len: usize, v: f32| xla::Literal::vec1(&vec![v; len]);
        let xsel = f(s * n, 0.5).reshape(&[s as i64, n as i64])?;
        let labels = f(s, 1.0);
        let valid = f(s, 1.0);
        let thr = f(p * n, 3.0).reshape(&[p as i64, n as i64])?;
        let scale = f(p * n, 16.0).reshape(&[p as i64, n as i64])?;
        let mut wl = vec![0f32; n * l];
        wl[0] = -1.0; wl[1] = 1.0; // comparator 0 -> leaf0 (left), leaf1 (right)
        let wleaf = xla::Literal::vec1(&wl).reshape(&[n as i64, l as i64])?;
        let mut bi = vec![1e6f32; l];
        bi[0] = 1.0; bi[1] = 0.0;
        let bias = xla::Literal::vec1(&bi);
        let mut oh = vec![0f32; l * c];
        oh[1] = 1.0; oh[c + 3] = 1.0;
        let onehot = xla::Literal::vec1(&oh).reshape(&[l as i64, c as i64])?;
        // axdt-lint: allow(clock-seam): dev probe prints real execution latency
        let t0 = std::time::Instant::now();
        let res = exe.execute::<xla::Literal>(&[xsel, labels, valid, thr, scale, wleaf, bias, onehot])?[0][0]
            .to_literal_sync()?;
        let acc = res.to_tuple1()?.to_vec::<f32>()?;
        println!("exec {:?} acc[0..4]={:?}", t0.elapsed(), &acc[..4.min(acc.len())]);
    }
    Ok(())
}
