//! Tensor encoding for the XLA accuracy engine.
//!
//! Packs a [`Problem`] into the padded, bucket-shaped operands of the AOT
//! artifact (see `python/compile/model.py` for the contract):
//!
//! * chromosome-independent tensors (`xsel`, `labels`, `valid`, `wleaf`,
//!   `bias`, `onehot`) are built **once** per problem and reused across
//!   generations;
//! * chromosome-dependent tensors (`thr`, `scale`) are packed per batch of
//!   P approximations.
//!
//! Padding conventions (must match the kernel docstring):
//! padded comparators → zero `wleaf` row; padded leaves → `bias = 1e6`;
//! padded samples → `valid = 0`.

use super::Problem;
use crate::hw::synth::TreeApprox;

/// Shape bucket (mirrors `meta.json`).
#[derive(Clone, Debug, PartialEq)]
pub struct Bucket {
    pub name: String,
    pub s: usize,
    pub n: usize,
    pub l: usize,
    pub c: usize,
    pub p: usize,
}

impl Bucket {
    /// Does a problem fit this bucket?
    pub fn fits(&self, problem: &Problem) -> bool {
        problem.n_test <= self.s
            && problem.n_comparators() <= self.n
            && problem.tree.n_leaves() <= self.l
            && problem.tree.n_classes <= self.c
    }
}

/// The chromosome-independent operand set for one (problem, bucket) pair.
#[derive(Clone, Debug)]
pub struct StaticTensors {
    pub bucket: Bucket,
    pub xsel: Vec<f32>,   // [S, N]
    pub labels: Vec<f32>, // [S]
    pub valid: Vec<f32>,  // [S]
    pub wleaf: Vec<f32>,  // [N, L]
    pub bias: Vec<f32>,   // [L]
    pub onehot: Vec<f32>, // [L, C]
}

/// Build the static tensors for `problem` padded to `bucket`.
pub fn encode_static(problem: &Problem, bucket: &Bucket) -> StaticTensors {
    assert!(bucket.fits(problem), "problem does not fit bucket {bucket:?}");
    let (s, n, l, c) = (bucket.s, bucket.n, bucket.l, bucket.c);
    let feats = problem.tree.comparator_features();
    let n_used = feats.len();

    // xsel: gather the slot's feature per sample.
    let mut xsel = vec![0f32; s * n];
    for smp in 0..problem.n_test {
        let row = &problem.test_x[smp * problem.n_features..(smp + 1) * problem.n_features];
        for (j, &f) in feats.iter().enumerate() {
            xsel[smp * n + j] = row[f];
        }
    }
    let mut labels = vec![0f32; s];
    let mut valid = vec![0f32; s];
    for smp in 0..problem.n_test {
        labels[smp] = problem.labels[smp] as f32;
        valid[smp] = 1.0;
    }

    // Tree structure tensors.
    let paths = problem.tree.leaf_paths();
    let classes = problem.tree.leaf_classes();
    let mut wleaf = vec![0f32; n * l];
    let mut bias = vec![1e6f32; l];
    let mut onehot = vec![0f32; l * c];
    for (leaf, path) in paths.iter().enumerate() {
        let mut b = 0f32;
        for &(slot, sense) in path {
            wleaf[slot * l + leaf] = if sense { -1.0 } else { 1.0 };
            if sense {
                b += 1.0;
            }
        }
        bias[leaf] = b;
        onehot[leaf * c + classes[leaf] as usize] = 1.0;
    }
    debug_assert_eq!(paths.len(), problem.tree.n_leaves());
    let _ = n_used;

    StaticTensors {
        bucket: bucket.clone(),
        xsel,
        labels,
        valid,
        wleaf,
        bias,
        onehot,
    }
}

/// Pack up to `bucket.p` approximations into the (thr, scale) operands.
/// Short batches are padded by repeating the first entry (results past
/// `batch.len()` are discarded by the caller).
pub fn pack_population(
    problem: &Problem,
    bucket: &Bucket,
    batch: &[TreeApprox],
) -> (Vec<f32>, Vec<f32>) {
    assert!(!batch.is_empty() && batch.len() <= bucket.p);
    let (p, n) = (bucket.p, bucket.n);
    let n_comp = problem.n_comparators();
    let mut thr = vec![0f32; p * n];
    let mut scale = vec![1f32; p * n];
    for row in 0..p {
        let approx = &batch[row.min(batch.len() - 1)];
        assert_eq!(approx.bits.len(), n_comp);
        for j in 0..n_comp {
            thr[row * n + j] = approx.thr_int[j] as f32;
            scale[row * n + j] = (1u32 << approx.bits[j]) as f32;
        }
        // Padded comparator slots keep thr=0/scale=1; their wleaf rows are
        // zero so they never influence the mismatch counts.
    }
    (thr, scale)
}

/// Native re-implementation of the artifact's math (used to verify the
/// XLA runtime end-to-end and as a vectorized second oracle in tests).
pub fn reference_accuracy(st: &StaticTensors, thr: &[f32], scale: &[f32], p_rows: usize) -> Vec<f64> {
    let b = &st.bucket;
    let (s, n, l, c) = (b.s, b.n, b.l, b.c);
    let denom: f32 = st.valid.iter().sum::<f32>().max(1.0);
    let mut out = Vec::with_capacity(p_rows);
    for row in 0..p_rows {
        let thr_row = &thr[row * n..(row + 1) * n];
        let scale_row = &scale[row * n..(row + 1) * n];
        let mut correct = 0f32;
        for smp in 0..s {
            if st.valid[smp] == 0.0 {
                continue;
            }
            // comparator bits
            let mut cmp = vec![0f32; n];
            for j in 0..n {
                let x = st.xsel[smp * n + j];
                let q = (x * scale_row[j]).floor().min(scale_row[j] - 1.0);
                cmp[j] = if q <= thr_row[j] { 1.0 } else { 0.0 };
            }
            // leaf mismatch + argmax class
            let mut best_class = 0usize;
            let mut best_score = f32::NEG_INFINITY;
            let mut scores = vec![0f32; c];
            for leaf in 0..l {
                let mut mis = st.bias[leaf];
                for j in 0..n {
                    mis += cmp[j] * st.wleaf[j * l + leaf];
                }
                if mis == 0.0 {
                    for cls in 0..c {
                        scores[cls] += st.onehot[leaf * c + cls];
                    }
                }
            }
            for (cls, &sc) in scores.iter().enumerate() {
                if sc > best_score {
                    best_score = sc;
                    best_class = cls;
                }
            }
            if best_class as f32 == st.labels[smp] {
                correct += 1.0;
            }
        }
        out.push((correct / denom) as f64);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fitness::native::NativeEngine;
    use crate::fitness::testutil::small_problem;
    use crate::fitness::AccuracyEngine;
    use crate::hw::{AreaLut, EgtLibrary};
    use crate::util::rng::Pcg64;

    fn bucket_small() -> Bucket {
        Bucket { name: "small".into(), s: 256, n: 64, l: 64, c: 16, p: 32 }
    }

    #[test]
    fn bucket_fit_logic() {
        let lut = AreaLut::build(&EgtLibrary::default());
        let p = small_problem(&lut);
        assert!(bucket_small().fits(&p));
        let tiny = Bucket { name: "t".into(), s: 4, n: 2, l: 2, c: 2, p: 8 };
        assert!(!tiny.fits(&p));
    }

    #[test]
    fn static_tensors_wellformed() {
        let lut = AreaLut::build(&EgtLibrary::default());
        let p = small_problem(&lut);
        let st = encode_static(&p, &bucket_small());
        assert_eq!(st.xsel.len(), 256 * 64);
        assert_eq!(st.valid.iter().sum::<f32>() as usize, p.n_test);
        // Exactly one onehot entry per real leaf.
        let ones = st.onehot.iter().filter(|&&v| v == 1.0).count();
        assert_eq!(ones, p.tree.n_leaves());
        // Padded leaves unreachable.
        for leaf in p.tree.n_leaves()..64 {
            assert!(st.bias[leaf] >= 1e6);
        }
    }

    /// The dense tensor formulation must agree exactly with the native
    /// tree walk on every chromosome — this is the contract the XLA
    /// artifact is trusted to implement.
    #[test]
    fn dense_reference_matches_tree_walk() {
        let lut = AreaLut::build(&EgtLibrary::default());
        let p = small_problem(&lut);
        let bucket = bucket_small();
        let st = encode_static(&p, &bucket);
        let mut rng = Pcg64::seeded(0xD0);
        let n = p.n_comparators();
        let batch: Vec<crate::hw::synth::TreeApprox> = (0..5)
            .map(|_| {
                let bits: Vec<u8> = (0..n).map(|_| rng.int_in(2, 8) as u8).collect();
                let thr_int: Vec<u32> = (0..n)
                    .map(|j| {
                        let t = crate::quant::int_threshold(p.thresholds[j], bits[j]);
                        crate::quant::substitute(t, rng.int_in(-5, 5) as i32, bits[j])
                    })
                    .collect();
                crate::hw::synth::TreeApprox { bits, thr_int }
            })
            .collect();
        let (thr, scale) = pack_population(&p, &bucket, &batch);
        let dense = reference_accuracy(&st, &thr, &scale, batch.len());
        let mut engine = NativeEngine::with_threads(1);
        let walk = engine.batch_accuracy(&p, &batch).unwrap();
        for i in 0..batch.len() {
            assert!(
                (dense[i] - walk[i]).abs() < 1e-6,
                "chromosome {i}: dense {} walk {}",
                dense[i],
                walk[i]
            );
        }
    }

    #[test]
    fn pack_pads_by_repetition() {
        let lut = AreaLut::build(&EgtLibrary::default());
        let p = small_problem(&lut);
        let bucket = bucket_small();
        let one = vec![crate::hw::synth::TreeApprox::exact(&p.tree)];
        let (thr, scale) = pack_population(&p, &bucket, &one);
        let n = bucket.n;
        for row in 1..bucket.p {
            assert_eq!(&thr[row * n..row * n + 4], &thr[..4]);
            assert_eq!(&scale[row * n..row * n + 4], &scale[..4]);
        }
    }
}
