//! Native accuracy engine: per-sample quantized tree walk.
//!
//! This is the formulation the paper's own Python framework uses (and its
//! 3.08 ms/chromosome HAR headline refers to).  It serves three roles here:
//! the test oracle the XLA engine is checked against, the CPU baseline the
//! hot-path bench compares engines on, and a fallback when artifacts are
//! absent.  Work is sharded across the thread pool by chromosome.

use anyhow::Result;

use super::{AccuracyEngine, Problem};
use crate::hw::synth::{TreeApprox, FEATURE_BITS};
use crate::util::pool;

/// Tree-walk engine; `threads = 0` → auto.
#[derive(Clone, Copy, Debug, Default)]
pub struct NativeEngine {
    pub threads: usize,
}

impl NativeEngine {
    pub fn with_threads(threads: usize) -> Self {
        NativeEngine { threads }
    }

    /// Accuracy of one approximation (public: used directly by benches).
    pub fn accuracy_one(problem: &Problem, approx: &TreeApprox) -> f64 {
        let nf = problem.n_features;
        let mut correct = 0usize;
        for s in 0..problem.n_test {
            let codes = &problem.test_codes[s * nf..(s + 1) * nf];
            if predict(problem, approx, codes) == problem.labels[s] {
                correct += 1;
            }
        }
        correct as f64 / problem.n_test.max(1) as f64
    }
}

/// Quantized walk using the problem's precomputed node→slot map.
#[inline]
pub fn predict(problem: &Problem, approx: &TreeApprox, codes: &[u32]) -> u32 {
    let mut i = 0usize;
    loop {
        let n = &problem.tree.nodes[i];
        if n.is_leaf() {
            return n.leaf_class as u32;
        }
        let slot = problem.slot_of_node[i] as usize;
        let code_b = codes[n.feat as usize] >> (FEATURE_BITS - approx.bits[slot]);
        i = if code_b <= approx.thr_int[slot] {
            n.left as usize
        } else {
            n.right as usize
        };
    }
}

impl AccuracyEngine for NativeEngine {
    /// Infallible: the tree walk has no backend to lose.
    fn batch_accuracy(&mut self, problem: &Problem, batch: &[TreeApprox]) -> Result<Vec<f64>> {
        let threads = if self.threads == 0 { pool::default_threads() } else { self.threads };
        Ok(pool::par_map(batch, threads, |approx| Self::accuracy_one(problem, approx)))
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fitness::testutil::small_problem;
    use crate::hw::{AreaLut, EgtLibrary};
    use crate::util::rng::Pcg64;

    #[test]
    fn walk_matches_synth_predict_codes() {
        let lut = AreaLut::build(&EgtLibrary::default());
        let p = small_problem(&lut);
        let mut rng = Pcg64::seeded(0x51);
        let n = p.n_comparators();
        for _ in 0..10 {
            let bits: Vec<u8> = (0..n).map(|_| rng.int_in(2, 8) as u8).collect();
            let thr_int: Vec<u32> = (0..n)
                .map(|j| crate::quant::int_threshold(p.thresholds[j], bits[j]))
                .collect();
            let approx = TreeApprox { bits, thr_int };
            for s in (0..p.n_test).step_by(7) {
                let codes = &p.test_codes[s * p.n_features..(s + 1) * p.n_features];
                assert_eq!(
                    predict(&p, &approx, codes),
                    crate::hw::synth::predict_codes(&p.tree, &approx, codes)
                );
            }
        }
    }

    /// The native engine rides the default blocking submit/collect
    /// adapter of [`AccuracyEngine`]: tickets resolve to exactly what
    /// `batch_accuracy` returns, and it declares no micro-batch
    /// preference (callers submit whole batches).
    #[test]
    fn default_submit_collect_adapter_matches_batch_accuracy() {
        let lut = AreaLut::build(&EgtLibrary::default());
        let p = small_problem(&lut);
        let batch = vec![TreeApprox::exact(&p.tree); 3];
        let mut engine = NativeEngine::with_threads(2);
        let want = engine.batch_accuracy(&p, &batch).unwrap();
        let ticket = engine.submit_accuracy(&p, &batch);
        assert_eq!(engine.collect(ticket).unwrap(), want);
        assert_eq!(engine.preferred_microbatch(), 0);
    }

    #[test]
    fn batch_matches_single_and_is_thread_invariant() {
        let lut = AreaLut::build(&EgtLibrary::default());
        let p = small_problem(&lut);
        let mut rng = Pcg64::seeded(0x52);
        let n = p.n_comparators();
        let batch: Vec<TreeApprox> = (0..9)
            .map(|_| {
                let bits: Vec<u8> = (0..n).map(|_| rng.int_in(2, 8) as u8).collect();
                let thr_int: Vec<u32> = (0..n)
                    .map(|j| {
                        let t = crate::quant::int_threshold(p.thresholds[j], bits[j]);
                        crate::quant::substitute(t, rng.int_in(-5, 5) as i32, bits[j])
                    })
                    .collect();
                TreeApprox { bits, thr_int }
            })
            .collect();
        let mut e1 = NativeEngine::with_threads(1);
        let mut e4 = NativeEngine::with_threads(4);
        let a1 = e1.batch_accuracy(&p, &batch).unwrap();
        let a4 = e4.batch_accuracy(&p, &batch).unwrap();
        assert_eq!(a1, a4);
        for (i, approx) in batch.iter().enumerate() {
            assert_eq!(a1[i], NativeEngine::accuracy_one(&p, approx));
            assert!((0.0..=1.0).contains(&a1[i]));
        }
    }
}
