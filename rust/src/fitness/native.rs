//! Native accuracy engine: bit-sliced word-parallel evaluation with a
//! per-sample scalar walk kept as the oracle.
//!
//! The scalar walk is the formulation the paper's own Python framework
//! uses (its 3.08 ms/chromosome HAR headline refers to it).  The default
//! kernel here is **bit-sliced**: `Problem::planes` pre-transposes the
//! 8-bit test codes into per-(feature, bit) `u64` planes — built once,
//! reused across every chromosome — and each comparator is evaluated as
//! branch-free word ops over 64 samples at a time, the same trick the
//! paper's printed EGT comparators exploit in hardware.  Each tree node's
//! "go left" predicate becomes a mask word, leaf hits are popcounts
//! against per-class label planes, so one chromosome costs
//! `O(nodes × bits × n_test / 64)` word operations instead of
//! `O(depth × n_test)` dependent branches.
//!
//! Both kernels are exposed; `AXDT_SCALAR_EVAL` (or the engine's `scalar`
//! knob) selects the oracle walk, and the test suite pins the two
//! bit-identical — including test-set sizes that are not multiples of 64,
//! where the tail-lane mask is load-bearing.  Work is sharded across the
//! thread pool by chromosome.

use anyhow::Result;

use super::{AccuracyEngine, Problem};
use crate::hw::synth::{TreeApprox, FEATURE_BITS};
use crate::quant;
use crate::util::pool;

/// Tree-walk engine; `threads = 0` → auto.
#[derive(Clone, Copy, Debug)]
pub struct NativeEngine {
    pub threads: usize,
    /// `true` forces the per-sample scalar walk (the oracle); `false`
    /// (default) uses the bit-sliced kernel.  Defaults from the
    /// `AXDT_SCALAR_EVAL` escape hatch.
    pub scalar: bool,
}

impl Default for NativeEngine {
    fn default() -> Self {
        NativeEngine { threads: 0, scalar: scalar_eval_env() }
    }
}

/// Should the scalar walk replace the bit-sliced kernel?  Any non-empty
/// `AXDT_SCALAR_EVAL` value other than `0` opts out of bit-slicing
/// (bisecting a suspected kernel bug, measuring the old baseline).
pub fn scalar_eval_env() -> bool {
    scalar_eval_flag(std::env::var("AXDT_SCALAR_EVAL").ok().as_deref())
}

fn scalar_eval_flag(v: Option<&str>) -> bool {
    matches!(v, Some(s) if !s.is_empty() && s != "0")
}

impl NativeEngine {
    pub fn with_threads(threads: usize) -> Self {
        NativeEngine { threads, ..NativeEngine::default() }
    }

    /// Scalar-oracle accuracy of one approximation (public: the reference
    /// the bit-sliced kernel is pinned against, and the benches' baseline).
    pub fn accuracy_one(problem: &Problem, approx: &TreeApprox) -> f64 {
        let nf = problem.n_features;
        let mut correct = 0usize;
        for s in 0..problem.n_test {
            let codes = &problem.test_codes[s * nf..(s + 1) * nf];
            if predict(problem, approx, codes) == problem.labels[s] {
                correct += 1;
            }
        }
        correct as f64 / problem.n_test.max(1) as f64
    }
}

/// Quantized walk using the problem's precomputed node→slot map.
///
/// Precondition: `approx` passed [`quant::validate_approx`] (the engine
/// entry points enforce it) — precision genes outside `[MIN_BITS,
/// MAX_BITS]` would underflow the shift below.
#[inline]
pub fn predict(problem: &Problem, approx: &TreeApprox, codes: &[u32]) -> u32 {
    let mut i = 0usize;
    loop {
        let n = &problem.tree.nodes[i];
        if n.is_leaf() {
            return n.leaf_class as u32;
        }
        let slot = problem.slot_of_node[i] as usize;
        let code_b = codes[n.feat as usize] >> (FEATURE_BITS - approx.bits[slot]);
        i = if code_b <= approx.thr_int[slot] {
            n.left as usize
        } else {
            n.right as usize
        };
    }
}

/// Transposed test set for the bit-sliced kernel: one `u64` plane per
/// (comparator-read feature, code bit) over lanes of 64 samples, plus
/// per-class label planes.  Built once per [`Problem`] (see
/// [`Problem::planes`]) and reused across every chromosome.
#[derive(Debug)]
pub struct BitPlanes {
    /// Words per plane: `ceil(n_test / 64)`.
    n_words: usize,
    /// Valid-lane mask of the last word (all ones when `n_test` is a
    /// multiple of 64).
    tail_mask: u64,
    /// Feature-bit planes, `[read feature][FEATURE_BITS][n_words]`
    /// flattened: bit `l` of word `w` in plane `(f, k)` is bit `k` of
    /// sample `w·64 + l`'s 8-bit code of feature `f`.  Only features some
    /// comparator actually reads get planes, so a wide dataset (HAR: 561
    /// features) only pays for the tree's handful of split features.
    planes: Vec<u64>,
    /// Comparator slot → offset of its feature's plane block in `planes`.
    slot_base: Vec<usize>,
    /// Per-class one-hot label planes, `[class][n_words]`: bit `l` of
    /// word `w` set iff sample `w·64 + l` carries that label (invalid
    /// tail lanes are never set).
    label_planes: Vec<u64>,
}

impl BitPlanes {
    /// Transpose a problem's test codes + labels into bit planes.
    pub fn build(problem: &Problem) -> BitPlanes {
        let n_test = problem.n_test;
        let nf = problem.n_features;
        let n_words = n_test.div_ceil(64);
        let tail = n_test % 64;
        let tail_mask = if tail == 0 { !0u64 } else { (1u64 << tail) - 1 };
        let fb = FEATURE_BITS as usize;

        // Plane storage for comparator-read features only; every slot of
        // the same feature shares one plane block.
        let mut feat_base: std::collections::BTreeMap<usize, usize> =
            std::collections::BTreeMap::new();
        let mut slot_base = vec![0usize; problem.n_comparators()];
        for (i, node) in problem.tree.nodes.iter().enumerate() {
            let slot = problem.slot_of_node[i];
            if slot < 0 {
                continue;
            }
            let next = feat_base.len() * fb * n_words;
            let base = *feat_base.entry(node.feat as usize).or_insert(next);
            slot_base[slot as usize] = base;
        }

        let mut planes = vec![0u64; feat_base.len() * fb * n_words];
        for s in 0..n_test {
            let (w, lane) = (s / 64, (s % 64) as u32);
            let row = &problem.test_codes[s * nf..(s + 1) * nf];
            for (&f, &base) in &feat_base {
                let code = row[f];
                for (k, chunk) in planes[base..base + fb * n_words].chunks_mut(n_words).enumerate()
                {
                    chunk[w] |= (((code >> k) & 1) as u64) << lane;
                }
            }
        }

        let n_classes = problem.tree.n_classes.max(1);
        let mut label_planes = vec![0u64; n_classes * n_words];
        for (s, &y) in problem.labels.iter().enumerate().take(n_test) {
            label_planes[y as usize * n_words + s / 64] |= 1u64 << (s % 64);
        }

        BitPlanes { n_words, tail_mask, planes, slot_base, label_planes }
    }

    /// Approximate retained size (the plane buffers), for reporting.
    pub fn bytes(&self) -> usize {
        (self.planes.len() + self.label_planes.len()) * std::mem::size_of::<u64>()
            + self.slot_base.len() * std::mem::size_of::<usize>()
    }

    /// Branch-free comparator mask over one word: lane `l` is set iff
    /// `code >> (FEATURE_BITS − bits) <= thr` for sample `w·64 + l`.
    /// MSB→LSB less-than/equal recurrence over the slot's top `bits`
    /// planes — `bits` word ops per 64 samples, no data-dependent branch.
    #[inline]
    fn le_mask(&self, slot: usize, w: usize, bits: u8, thr: u32) -> u64 {
        let base = self.slot_base[slot];
        let (mut lt, mut eq) = (0u64, !0u64);
        for i in (0..bits as usize).rev() {
            let plane =
                self.planes[base + (FEATURE_BITS as usize - bits as usize + i) * self.n_words + w];
            if (thr >> i) & 1 == 1 {
                lt |= eq & !plane;
                eq &= plane;
            } else {
                eq &= !plane;
            }
        }
        lt | eq
    }
}

/// Bit-sliced accuracy of one approximation: walks the tree once per
/// 64-sample word carrying a lane mask, splitting it at each comparator
/// and popcounting leaf masks against the label planes.  Bit-identical to
/// [`NativeEngine::accuracy_one`] (pinned by tests and `util::prop`).
///
/// Same validation precondition as [`predict`].
pub fn accuracy_sliced(problem: &Problem, approx: &TreeApprox) -> f64 {
    let planes = problem.planes();
    let nodes = &problem.tree.nodes;
    let mut correct = 0u64;
    let mut stack: Vec<(usize, u64)> = Vec::with_capacity(64);
    for w in 0..planes.n_words {
        let full = if w + 1 == planes.n_words { planes.tail_mask } else { !0u64 };
        stack.push((0, full));
        while let Some((i, mask)) = stack.pop() {
            let n = &nodes[i];
            if n.is_leaf() {
                let labels = planes.label_planes[n.leaf_class as usize * planes.n_words + w];
                correct += (mask & labels).count_ones() as u64;
                continue;
            }
            let slot = problem.slot_of_node[i] as usize;
            let le = planes.le_mask(slot, w, approx.bits[slot], approx.thr_int[slot]);
            let left = mask & le;
            if left != 0 {
                stack.push((n.left as usize, left));
            }
            let right = mask & !le;
            if right != 0 {
                stack.push((n.right as usize, right));
            }
        }
    }
    correct as f64 / problem.n_test.max(1) as f64
}

impl AccuracyEngine for NativeEngine {
    /// Validates every approximation at entry (typed
    /// [`quant::ApproxError`] — a corrupted chromosome must not panic a
    /// worker), then shards the batch across the thread pool with the
    /// selected kernel.  The planes are forced before sharding so the
    /// workers share one build instead of racing to create it.
    fn batch_accuracy(&mut self, problem: &Problem, batch: &[TreeApprox]) -> Result<Vec<f64>> {
        let n = problem.n_comparators();
        for approx in batch {
            quant::validate_approx(n, &approx.bits, &approx.thr_int)
                .map_err(anyhow::Error::new)?;
        }
        let threads = if self.threads == 0 { pool::default_threads() } else { self.threads };
        if self.scalar {
            return Ok(pool::par_map(batch, threads, |approx| {
                Self::accuracy_one(problem, approx)
            }));
        }
        let _ = problem.planes();
        Ok(pool::par_map(batch, threads, |approx| accuracy_sliced(problem, approx)))
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators;
    use crate::dt::{train, TrainConfig};
    use crate::fitness::testutil::small_problem;
    use crate::hw::{AreaLut, EgtLibrary};
    use crate::util::rng::Pcg64;

    fn random_approx(p: &Problem, rng: &mut Pcg64, substitute: bool) -> TreeApprox {
        let n = p.n_comparators();
        let bits: Vec<u8> = (0..n).map(|_| rng.int_in(2, 8) as u8).collect();
        let thr_int: Vec<u32> = (0..n)
            .map(|j| {
                let t = crate::quant::int_threshold(p.thresholds[j], bits[j]);
                if substitute {
                    crate::quant::substitute(t, rng.int_in(-5, 5) as i32, bits[j])
                } else {
                    t
                }
            })
            .collect();
        TreeApprox { bits, thr_int }
    }

    #[test]
    fn walk_matches_synth_predict_codes() {
        let lut = AreaLut::build(&EgtLibrary::default());
        let p = small_problem(&lut);
        let mut rng = Pcg64::seeded(0x51);
        for _ in 0..10 {
            let approx = random_approx(&p, &mut rng, false);
            for s in (0..p.n_test).step_by(7) {
                let codes = &p.test_codes[s * p.n_features..(s + 1) * p.n_features];
                assert_eq!(
                    predict(&p, &approx, codes),
                    // Reuses the problem's precomputed slot table — no
                    // per-sample map rebuild.
                    crate::hw::synth::predict_codes_with_slots(
                        &p.tree,
                        &p.slot_of_node,
                        &approx,
                        codes
                    )
                );
            }
        }
    }

    /// The tentpole contract: the bit-sliced kernel is bit-identical to
    /// the scalar oracle, across random substituted approximations and
    /// test-set sizes that exercise the tail-lane mask (n_test < 64,
    /// n_test == 64 exactly, and a multi-word non-multiple-of-64 size).
    #[test]
    fn sliced_is_bit_identical_to_scalar_across_tail_sizes() {
        let lib = EgtLibrary::default();
        let lut = AreaLut::build(&lib);
        let spec = generators::spec("vertebral").unwrap();
        let data = generators::generate(spec, 7);
        let (train_d, test_d) = data.split(0.3, 7);
        let tree =
            train(&train_d, &TrainConfig { max_leaves: spec.max_leaves, min_samples_split: 2 });
        assert!(test_d.n_samples > 64, "need a multi-word test set");

        for truncate in [1usize, 5, 63, 64, usize::MAX] {
            // A fresh Problem per size: the planes cache on the instance.
            let mut p = Problem::new("vertebral", tree.clone(), &test_d, &lut, &lib, 5);
            p.n_test = p.n_test.min(truncate);
            let mut rng = Pcg64::seeded(0x1D + truncate as u64);
            for _ in 0..8 {
                let approx = random_approx(&p, &mut rng, true);
                let scalar = NativeEngine::accuracy_one(&p, &approx);
                let sliced = accuracy_sliced(&p, &approx);
                assert_eq!(
                    scalar.to_bits(),
                    sliced.to_bits(),
                    "n_test={} scalar={scalar} sliced={sliced}",
                    p.n_test
                );
            }
        }
    }

    /// Regression: precision genes outside `[MIN_BITS, MAX_BITS]` used to
    /// underflow `FEATURE_BITS - bits` (panic in debug, masked shift in
    /// release).  Both kernels must answer with the typed error instead.
    #[test]
    fn malformed_approx_is_typed_error_not_panic() {
        let lut = AreaLut::build(&EgtLibrary::default());
        let p = small_problem(&lut);
        let n = p.n_comparators();
        let cases = [
            TreeApprox { bits: vec![9; n], thr_int: vec![0; n] },
            TreeApprox { bits: vec![1; n], thr_int: vec![0; n] },
            TreeApprox { bits: vec![4; n], thr_int: vec![16; n] },
            TreeApprox { bits: vec![8; n - 1], thr_int: vec![0; n] },
        ];
        for (scalar, case) in [(false, 0), (false, 1), (false, 2), (false, 3), (true, 0)] {
            let mut engine = NativeEngine { threads: 1, scalar };
            let batch =
                vec![TreeApprox::exact(&p.tree), cases[case].clone(), TreeApprox::exact(&p.tree)];
            let err = engine.batch_accuracy(&p, &batch).unwrap_err();
            assert!(
                err.downcast_ref::<quant::ApproxError>().is_some(),
                "case {case} scalar={scalar}: {err}"
            );
        }
    }

    #[test]
    fn scalar_knob_selects_oracle_with_identical_results() {
        let lut = AreaLut::build(&EgtLibrary::default());
        let p = small_problem(&lut);
        let mut rng = Pcg64::seeded(0x53);
        let batch: Vec<TreeApprox> = (0..5).map(|_| random_approx(&p, &mut rng, true)).collect();
        let mut sliced = NativeEngine { threads: 2, scalar: false };
        let mut scalar = NativeEngine { threads: 2, scalar: true };
        assert_eq!(
            sliced.batch_accuracy(&p, &batch).unwrap(),
            scalar.batch_accuracy(&p, &batch).unwrap()
        );
        // The escape-hatch parse: only a non-empty value != "0" opts out.
        assert!(!scalar_eval_flag(None));
        assert!(!scalar_eval_flag(Some("")));
        assert!(!scalar_eval_flag(Some("0")));
        assert!(scalar_eval_flag(Some("1")));
        assert!(scalar_eval_flag(Some("yes")));
    }

    #[test]
    fn planes_build_once_and_report_size() {
        let lut = AreaLut::build(&EgtLibrary::default());
        let p = small_problem(&lut);
        assert!(!p.planes_built());
        let first = p.planes() as *const BitPlanes;
        assert!(p.planes_built());
        assert_eq!(first, p.planes() as *const BitPlanes, "planes cached");
        assert!(p.planes().bytes() > 0);
    }

    /// The native engine rides the default blocking submit/collect
    /// adapter of [`AccuracyEngine`]: tickets resolve to exactly what
    /// `batch_accuracy` returns, and it declares no micro-batch
    /// preference (callers submit whole batches).
    #[test]
    fn default_submit_collect_adapter_matches_batch_accuracy() {
        let lut = AreaLut::build(&EgtLibrary::default());
        let p = small_problem(&lut);
        let batch = vec![TreeApprox::exact(&p.tree); 3];
        let mut engine = NativeEngine::with_threads(2);
        let want = engine.batch_accuracy(&p, &batch).unwrap();
        let ticket = engine.submit_accuracy(&p, &batch);
        assert_eq!(engine.collect(ticket).unwrap(), want);
        assert_eq!(engine.preferred_microbatch(), 0);
    }

    #[test]
    fn batch_matches_single_and_is_thread_invariant() {
        let lut = AreaLut::build(&EgtLibrary::default());
        let p = small_problem(&lut);
        let mut rng = Pcg64::seeded(0x52);
        let batch: Vec<TreeApprox> = (0..9).map(|_| random_approx(&p, &mut rng, true)).collect();
        let mut e1 = NativeEngine::with_threads(1);
        let mut e4 = NativeEngine::with_threads(4);
        let a1 = e1.batch_accuracy(&p, &batch).unwrap();
        let a4 = e4.batch_accuracy(&p, &batch).unwrap();
        assert_eq!(a1, a4);
        for (i, approx) in batch.iter().enumerate() {
            // The batched (bit-sliced) path is pinned to the scalar
            // oracle, chromosome by chromosome.
            assert_eq!(a1[i], NativeEngine::accuracy_one(&p, approx));
            assert!((0.0..=1.0).contains(&a1[i]));
        }
    }
}
