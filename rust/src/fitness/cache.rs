//! Tiered, concurrent, persistent accuracy cache.
//!
//! The co-design loop spends nearly all of its time re-evaluating
//! approximate-tree phenotypes, and the same `(bits, thresholds)` design
//! points recur across runs: a repeat `optimize` of a seen dataset should
//! cost cache lookups, not bit-sliced kernel time. This module makes the
//! per-run fitness memo durable and shared:
//!
//! * **L1 — sharded in-memory tier.** A lock-striped map shared (via
//!   `Arc`) across every concurrent driver in `run_all`, so dataset A's
//!   driver can reuse phenotypes dataset A evaluated last generation even
//!   while B..H hammer the same cache. Entries produced by this process
//!   live here.
//! * **L2 — disk tier.** One append-only segment file per dataset
//!   fingerprint under `<out>/cache/`, length-prefixed checksummed
//!   records, loaded at startup. A torn tail (crash mid-append, truncated
//!   copy) is skipped record-by-record and *counted*, never fatal.
//!
//! Keys are `(dataset fingerprint, phenotype fingerprint)` — both
//! 128-bit. The dataset fingerprint hashes the generator id, seed, row
//! count and quantization width, so an entry can never leak across
//! datasets (change the seed and the fingerprint — hence the segment file
//! — changes). The phenotype fingerprint is
//! [`crate::ga::Chromosome::phenotype_key_of`], 128-bit for the same
//! reason the per-run memo was widened: a 64-bit birthday collision
//! silently serves one phenotype another's objectives.
//!
//! Seam contracts (see ROADMAP.md): this module never reads the OS clock
//! — lookup-latency timestamps come from the caller's injected `Clock`,
//! and lifecycle events (hits, misses, spills, loads) are journaled by
//! the caller through `TraceKind::Cache*` variants. Misses still flow
//! through the `submit_accuracy`/`collect` ticket seam; the cache sits in
//! front of it, it is not a second blocking path.

use std::collections::HashMap;
use std::fs;
use std::io::{self, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::util::rng::{fnv1a, fnv1a128, splitmix64};
use crate::util::sync::lock_recover;

/// Number of independent lock stripes in the L1 tier. 16 is comfortably
/// above the pool's worker cap-per-host in practice; stripes are cheap.
const STRIPES: usize = 16;

/// Segment-file magic: bumped if the record layout ever changes, so an
/// old binary never misparses a new segment (it counts one load error and
/// skips the file instead).
const SEGMENT_MAGIC: &[u8; 8] = b"AXDTSEG1";

/// Serialized record payload: key (16) + error (8) + area (8).
const RECORD_LEN: usize = 32;

/// Identity of a dataset *as the accuracy engines see it*: anything that
/// changes the trained tree or its test set must change the fingerprint,
/// or a stale cache entry could cross datasets. Hashes the generator id,
/// the experiment seed, the row count, and the feature quantization
/// width.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct DatasetFingerprint(pub u128);

impl DatasetFingerprint {
    pub fn compute(generator_id: &str, seed: u64, n_samples: usize, feature_bits: u8) -> Self {
        let mut bytes = Vec::with_capacity(generator_id.len() + 18);
        bytes.extend_from_slice(generator_id.as_bytes());
        bytes.push(0); // terminator: ("ab", 1) must never alias ("a", ...) byte-wise
        bytes.extend_from_slice(&seed.to_le_bytes());
        bytes.extend_from_slice(&(n_samples as u64).to_le_bytes());
        bytes.push(feature_bits);
        DatasetFingerprint(fnv1a128(&bytes))
    }

    /// Hex form used as the segment-file name stem.
    pub fn hex(&self) -> String {
        format!("{:032x}", self.0)
    }
}

/// Which tier satisfied a lookup. `L1` = produced by this process; `L2` =
/// loaded from a segment file at startup. The distinction is what lets
/// `runs.json` *prove* a warm repeat run touched no engine: its hits are
/// all L2.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheTier {
    L1,
    L2,
}

#[derive(Clone, Copy, Debug)]
struct Entry {
    objectives: [f64; 2],
    tier: CacheTier,
    /// Already on disk (loaded from a segment, or spilled earlier)?
    spilled: bool,
}

/// What `load()` saw on disk.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LoadReport {
    pub segments: usize,
    pub records: u64,
    /// Corrupt or truncated tails skipped (counted into
    /// `Metrics::cache_load_errors` by the caller).
    pub errors: u64,
}

/// What `spill()` wrote.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SpillReport {
    pub segments: usize,
    pub records: u64,
}

/// How a fixed-size read against a segment file ended.
enum Fill {
    Full,
    /// Zero bytes available: clean EOF at a record boundary.
    Eof,
    /// Some but not all bytes: a torn record (crash mid-append).
    Torn,
}

fn read_full(file: &mut fs::File, buf: &mut [u8]) -> Fill {
    let mut filled = 0usize;
    while filled < buf.len() {
        match file.read(&mut buf[filled..]) {
            Ok(0) => return if filled == 0 { Fill::Eof } else { Fill::Torn },
            Ok(n) => filled += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => return Fill::Torn,
        }
    }
    Fill::Full
}

/// The shared cache. Construct once in `run_all`, share via `Arc` with
/// every driver's `FitnessEvaluator`.
#[derive(Debug)]
pub struct EvalCache {
    stripes: Vec<Mutex<HashMap<(u128, u128), Entry>>>,
    dir: Option<PathBuf>,
}

impl EvalCache {
    /// A cache with an L2 directory (created on first spill).
    pub fn persistent(dir: impl Into<PathBuf>) -> Self {
        EvalCache { stripes: (0..STRIPES).map(|_| Mutex::new(HashMap::new())).collect(), dir: Some(dir.into()) }
    }

    /// L1 only — nothing is ever spilled or loaded. Used when `--no-cache`
    /// leaves persistence off but tests still want the shared tier.
    pub fn in_memory() -> Self {
        EvalCache { stripes: (0..STRIPES).map(|_| Mutex::new(HashMap::new())).collect(), dir: None }
    }

    pub fn dir(&self) -> Option<&Path> {
        self.dir.as_deref()
    }

    #[inline]
    fn stripe(&self, fp: DatasetFingerprint, key: u128) -> usize {
        let mixed = splitmix64((fp.0 as u64) ^ (key as u64) ^ ((key >> 64) as u64));
        (mixed % self.stripes.len() as u64) as usize
    }

    /// Look up `(dataset, phenotype)`. Returns the objectives and the tier
    /// that produced them. Pure map access: no clock, no I/O.
    pub fn lookup(&self, fp: DatasetFingerprint, key: u128) -> Option<([f64; 2], CacheTier)> {
        let shard = lock_recover(&self.stripes[self.stripe(fp, key)]);
        shard.get(&(fp.0, key)).map(|e| (e.objectives, e.tier))
    }

    /// Publish freshly computed objectives. First writer wins (all writers
    /// computed the same deterministic value); returns whether the entry
    /// was new.
    pub fn publish(&self, fp: DatasetFingerprint, key: u128, objectives: [f64; 2]) -> bool {
        let mut shard = lock_recover(&self.stripes[self.stripe(fp, key)]);
        match shard.entry((fp.0, key)) {
            std::collections::hash_map::Entry::Occupied(_) => false,
            std::collections::hash_map::Entry::Vacant(v) => {
                v.insert(Entry { objectives, tier: CacheTier::L1, spilled: false });
                true
            }
        }
    }

    /// Total entries across stripes (tests / reporting).
    pub fn len(&self) -> usize {
        self.stripes.iter().map(|s| lock_recover(s).len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Load every segment file under the L2 directory. Corrupt records
    /// (bad checksum, impossible length, torn tail) end that segment's
    /// replay with one counted error — the good prefix is kept, the run
    /// proceeds. A missing directory is simply an empty cache.
    pub fn load(&self) -> LoadReport {
        let mut report = LoadReport::default();
        let Some(dir) = self.dir.as_deref() else {
            return report;
        };
        let Ok(entries) = fs::read_dir(dir) else {
            return report;
        };
        let mut paths: Vec<PathBuf> = entries
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| {
                p.extension().is_some_and(|x| x == "seg")
                    && p.file_stem().is_some_and(|s| s.to_string_lossy().len() == 32)
            })
            .collect();
        paths.sort(); // deterministic load order
        for path in paths {
            report.segments += 1;
            self.load_segment(&path, &mut report);
        }
        report
    }

    fn load_segment(&self, path: &Path, report: &mut LoadReport) {
        let stem = match path.file_stem() {
            Some(s) => s.to_string_lossy().into_owned(),
            None => {
                report.errors += 1;
                return;
            }
        };
        let Ok(fp_bits) = u128::from_str_radix(&stem, 16) else {
            report.errors += 1;
            return;
        };
        let fp = DatasetFingerprint(fp_bits);
        let Ok(mut file) = fs::File::open(path) else {
            report.errors += 1;
            return;
        };
        let mut header = [0u8; 8];
        if file.read_exact(&mut header).is_err() || &header != SEGMENT_MAGIC {
            report.errors += 1;
            return;
        }
        loop {
            let mut len_buf = [0u8; 4];
            match read_full(&mut file, &mut len_buf) {
                Fill::Eof => break, // clean end at a record boundary
                Fill::Torn => {
                    report.errors += 1;
                    break;
                }
                Fill::Full => {}
            }
            let len = u32::from_le_bytes(len_buf) as usize;
            if len != RECORD_LEN {
                // Future layouts bump SEGMENT_MAGIC; any other length here
                // is corruption. Skip the rest of the file.
                report.errors += 1;
                break;
            }
            let mut payload = vec![0u8; len];
            let mut sum_buf = [0u8; 8];
            if !matches!(read_full(&mut file, &mut payload), Fill::Full)
                || !matches!(read_full(&mut file, &mut sum_buf), Fill::Full)
            {
                report.errors += 1; // torn tail: crash mid-append
                break;
            }
            if fnv1a(&payload) != u64::from_le_bytes(sum_buf) {
                report.errors += 1; // bit rot / partial overwrite
                break;
            }
            let key = u128::from_le_bytes(payload[0..16].try_into().unwrap_or([0u8; 16]));
            let err = f64::from_le_bytes(payload[16..24].try_into().unwrap_or([0u8; 8]));
            let area = f64::from_le_bytes(payload[24..32].try_into().unwrap_or([0u8; 8]));
            let mut shard = lock_recover(&self.stripes[self.stripe(fp, key)]);
            shard
                .entry((fp.0, key))
                .or_insert(Entry { objectives: [err, area], tier: CacheTier::L2, spilled: true });
            report.records += 1;
        }
    }

    /// Append every not-yet-spilled entry to its fingerprint's segment
    /// file. Records are length-prefixed and checksummed, so a crash
    /// mid-append costs exactly the torn record (the loader keeps the
    /// prefix). Call once at the end of `run_all`; entries loaded from
    /// disk are never rewritten.
    pub fn spill(&self) -> io::Result<SpillReport> {
        let mut report = SpillReport::default();
        let Some(dir) = self.dir.as_deref() else {
            return Ok(report);
        };
        // Group fresh entries per fingerprint so each segment is opened once.
        let mut fresh: HashMap<u128, Vec<(u128, [f64; 2])>> = HashMap::new();
        for stripe in &self.stripes {
            let mut shard = lock_recover(stripe);
            for ((fp, key), entry) in shard.iter_mut() {
                if !entry.spilled {
                    entry.spilled = true;
                    fresh.entry(*fp).or_default().push((*key, entry.objectives));
                }
            }
        }
        if fresh.is_empty() {
            return Ok(report);
        }
        fs::create_dir_all(dir)?;
        let mut fps: Vec<u128> = fresh.keys().copied().collect();
        fps.sort_unstable();
        for fp in fps {
            let mut records = fresh.remove(&fp).unwrap_or_default();
            records.sort_unstable_by_key(|(k, _)| *k); // deterministic file bytes
            let path = dir.join(format!("{}.seg", DatasetFingerprint(fp).hex()));
            let is_new = !path.exists();
            let mut file = fs::OpenOptions::new().create(true).append(true).open(&path)?;
            let mut buf = Vec::with_capacity(records.len() * (4 + RECORD_LEN + 8) + 8);
            if is_new {
                buf.extend_from_slice(SEGMENT_MAGIC);
            }
            for (key, obj) in &records {
                let mut payload = [0u8; RECORD_LEN];
                payload[0..16].copy_from_slice(&key.to_le_bytes());
                payload[16..24].copy_from_slice(&obj[0].to_le_bytes());
                payload[24..32].copy_from_slice(&obj[1].to_le_bytes());
                buf.extend_from_slice(&(RECORD_LEN as u32).to_le_bytes());
                buf.extend_from_slice(&payload);
                buf.extend_from_slice(&fnv1a(&payload).to_le_bytes());
            }
            file.write_all(&buf)?;
            report.segments += 1;
            report.records += records.len() as u64;
        }
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("axdt_cache_{tag}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn fingerprint_separates_every_component() {
        let base = DatasetFingerprint::compute("seeds", 42, 210, 8);
        assert_eq!(base, DatasetFingerprint::compute("seeds", 42, 210, 8));
        assert_ne!(base, DatasetFingerprint::compute("vertebral", 42, 210, 8));
        assert_ne!(base, DatasetFingerprint::compute("seeds", 43, 210, 8));
        assert_ne!(base, DatasetFingerprint::compute("seeds", 42, 211, 8));
        assert_ne!(base, DatasetFingerprint::compute("seeds", 42, 210, 7));
        // The id terminator keeps ("ab", …) from aliasing a shifted field.
        assert_ne!(
            DatasetFingerprint::compute("a", u64::from_le_bytes(*b"b\0\0\0\0\0\0\0"), 0, 0).0,
            DatasetFingerprint::compute("ab", 0, 0, 0).0,
        );
    }

    #[test]
    fn lookup_publish_and_tier_attribution() {
        let cache = EvalCache::in_memory();
        let fp = DatasetFingerprint::compute("seeds", 1, 100, 8);
        assert!(cache.lookup(fp, 7).is_none());
        assert!(cache.publish(fp, 7, [0.25, 3.5]));
        assert!(!cache.publish(fp, 7, [9.9, 9.9]), "first writer wins");
        assert_eq!(cache.lookup(fp, 7), Some(([0.25, 3.5], CacheTier::L1)));
        // Same phenotype under a different dataset is a distinct entry.
        let fp2 = DatasetFingerprint::compute("seeds", 2, 100, 8);
        assert!(cache.lookup(fp2, 7).is_none());
    }

    #[test]
    fn spill_then_load_round_trips_as_l2() {
        let dir = tmp_dir("roundtrip");
        let fp = DatasetFingerprint::compute("seeds", 42, 210, 8);
        let cache = EvalCache::persistent(&dir);
        for k in 0..10u128 {
            assert!(cache.publish(fp, k, [k as f64 / 10.0, 2.0 + k as f64]));
        }
        let spilled = cache.spill().unwrap();
        assert_eq!((spilled.segments, spilled.records), (1, 10));
        // Spilling again writes nothing: entries are marked.
        assert_eq!(cache.spill().unwrap(), SpillReport::default());

        let warm = EvalCache::persistent(&dir);
        let report = warm.load();
        assert_eq!((report.segments, report.records, report.errors), (1, 10, 0));
        for k in 0..10u128 {
            assert_eq!(warm.lookup(fp, k), Some(([k as f64 / 10.0, 2.0 + k as f64], CacheTier::L2)));
        }
        // Loaded entries are already on disk: no re-spill.
        assert_eq!(warm.spill().unwrap(), SpillReport::default());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn appends_accumulate_across_processes() {
        let dir = tmp_dir("append");
        let fp = DatasetFingerprint::compute("har", 42, 700, 8);
        {
            let cache = EvalCache::persistent(&dir);
            cache.publish(fp, 1, [0.1, 1.0]);
            cache.spill().unwrap();
        }
        {
            let cache = EvalCache::persistent(&dir);
            assert_eq!(cache.load().records, 1);
            cache.publish(fp, 2, [0.2, 2.0]);
            let r = cache.spill().unwrap();
            assert_eq!(r.records, 1, "only the fresh entry is appended");
        }
        let cache = EvalCache::persistent(&dir);
        assert_eq!(cache.load().records, 2);
        assert_eq!(cache.len(), 2);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_dir_and_in_memory_are_empty_loads() {
        assert_eq!(EvalCache::in_memory().load(), LoadReport::default());
        assert_eq!(EvalCache::in_memory().spill().unwrap(), SpillReport::default());
        let cache = EvalCache::persistent(tmp_dir("missing"));
        assert_eq!(cache.load(), LoadReport::default());
    }
}
