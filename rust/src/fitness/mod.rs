//! Fitness evaluation: the two objectives of the genetic search.
//!
//! * **Area** (minimize): the paper's high-level estimate — Σ over
//!   comparators of the area-LUT entry for (precision, substituted
//!   threshold), plus the tree's fixed routing/encoder logic measured once
//!   from the exact synthesis.  No EDA run per candidate.
//! * **Classification error** (minimize): accuracy of the quantized tree on
//!   the held-out test set, via a pluggable [`AccuracyEngine`]:
//!   [`native::NativeEngine`] (tree walk, CPU baseline/test oracle) or the
//!   coordinator's XLA engine (AOT artifact over PJRT).
//!
//! [`FitnessEvaluator`] glues both behind the GA's batched
//! [`crate::ga::Evaluator`] trait, with a phenotype-keyed fitness cache.
//! Evaluation is **two-phase**: [`AccuracyEngine::submit_accuracy`]
//! starts a batch and returns an [`AccuracyTicket`];
//! [`AccuracyEngine::collect`] redeems it.  Plain engines keep the
//! default blocking adapter (submit evaluates synchronously and parks the
//! result in the ticket); service-backed engines defer to the shard
//! pool's ticketed submit/wait so a generation's micro-batches pipeline
//! across shards while this side keeps decoding and estimating area.

pub mod cache;
pub mod encode;
pub mod native;

use std::any::Any;
use std::collections::HashMap;
use std::sync::atomic::Ordering::Relaxed;
use std::sync::{Arc, OnceLock};

use anyhow::{anyhow, Result};

use crate::coordinator::metrics::Metrics;
use crate::data::Dataset;
use crate::dt::Tree;
use crate::ga::{Chromosome, DecodeContext, Evaluator};
use crate::hw::synth::{self, TreeApprox, FEATURE_BITS};
use crate::hw::{AreaLut, EgtLibrary};
use crate::quant;
use crate::util::clock::Clock;
use crate::util::trace::TraceKind;

use cache::{CacheTier, DatasetFingerprint, EvalCache};

/// One optimization problem: a trained tree + its held-out test set +
/// precomputed structures shared by every fitness evaluation.
pub struct Problem {
    pub tree: Tree,
    pub name: String,
    /// 8-bit feature codes of the test set, row-major `[s, n_features]`.
    pub test_codes: Vec<u32>,
    /// Raw [0,1] features of the test set (XLA tensor packing).
    pub test_x: Vec<f32>,
    pub labels: Vec<u32>,
    pub n_test: usize,
    pub n_features: usize,
    /// Float threshold per comparator slot.
    pub thresholds: Vec<f32>,
    /// Comparator slot per node index (-1 for leaves).
    pub slot_of_node: Vec<i32>,
    /// Fixed (chromosome-independent) logic area: exact-synthesis area
    /// minus the exact comparators' LUT sum.
    pub routing_offset_mm2: f64,
    /// Exact-baseline full synthesis report (Table I row).
    pub exact_report: crate::hw::HwReport,
    /// Substitution margin bound (paper: 5).
    pub margin_max: u32,
    /// Bit-sliced evaluation planes (built lazily by [`Self::planes`],
    /// then shared by every chromosome evaluated against this problem).
    planes: OnceLock<native::BitPlanes>,
}

impl Problem {
    /// Precompute everything fitness needs. Runs one exact synthesis (the
    /// Table I baseline) to calibrate the routing offset.
    pub fn new(
        name: &str,
        tree: Tree,
        test: &Dataset,
        lut: &AreaLut,
        lib: &EgtLibrary,
        margin_max: u32,
    ) -> Problem {
        assert_eq!(test.n_features, tree.n_features);
        let n_test = test.n_samples;
        let test_codes: Vec<u32> = test
            .x
            .iter()
            .map(|&x| quant::code(x, FEATURE_BITS))
            .collect();
        let thresholds = tree.comparator_thresholds();
        let slot_of_node = synth::node_slots(&tree);

        let exact = TreeApprox::exact(&tree);
        let exact_report = synth::synth_tree(&tree, &exact).netlist.report(lib);
        let exact_lut_sum: f64 = exact
            .bits
            .iter()
            .zip(&exact.thr_int)
            .map(|(&b, &t)| lut.area(b, t))
            .sum();
        let routing_offset_mm2 = (exact_report.area_mm2 - exact_lut_sum).max(0.0);

        Problem {
            name: name.to_string(),
            test_x: test.x.clone(),
            labels: test.y.clone(),
            n_test,
            n_features: test.n_features,
            thresholds,
            slot_of_node,
            routing_offset_mm2,
            exact_report,
            margin_max,
            tree,
            test_codes,
            planes: OnceLock::new(),
        }
    }

    pub fn n_comparators(&self) -> usize {
        self.thresholds.len()
    }

    /// The bit-sliced evaluation planes: `test_codes` transposed into
    /// per-(feature, bit) `u64` words plus per-class label planes.  Built
    /// on first use, then reused by every chromosome evaluated against
    /// this problem (the native engine's default kernel reads them).
    ///
    /// **Invariant for engines:** the planes are a pure function of
    /// `test_codes`, `labels`, `n_test` and the tree's comparator
    /// features.  Those fields must not change once the planes exist —
    /// code that wants a different test set builds a new `Problem`.
    pub fn planes(&self) -> &native::BitPlanes {
        self.planes.get_or_init(|| native::BitPlanes::build(self))
    }

    /// Whether [`Self::planes`] has already run — shard workers use this
    /// to warm (and time) the build at registration instead of paying it
    /// inside the first evaluation window.
    pub fn planes_built(&self) -> bool {
        self.planes.get().is_some()
    }

    /// High-level area estimate of one approximation (the GA objective).
    ///
    /// Refinement over the plain LUT sum (§Perf / estimate-fidelity
    /// ablation): a comparator whose substituted threshold saturates at
    /// `2^b − 1` is *constant-true* — synthesis then removes the dead right
    /// subtree and its share of path/encoder logic.  The estimate walks the
    /// tree with constant comparators folded, sums the LUT over *reachable*
    /// comparators only, and scales the fixed routing offset by the
    /// reachable-leaf fraction.  This is still a pure high-level model (no
    /// netlist is built), but it tracks the synthesized area far better on
    /// heavily-approximated designs (see bench_ablations).
    pub fn estimate_area(&self, lut: &AreaLut, approx: &TreeApprox) -> f64 {
        let mut comps = 0.0f64;
        let mut reachable_leaves = 0usize;
        let mut stack = vec![0usize];
        while let Some(i) = stack.pop() {
            let node = &self.tree.nodes[i];
            if node.is_leaf() {
                reachable_leaves += 1;
                continue;
            }
            let slot = self.slot_of_node[i] as usize;
            let (b, t) = (approx.bits[slot], approx.thr_int[slot]);
            if t == crate::quant::levels(b) - 1 {
                // Constant-true comparator: zero area, right subtree dead.
                stack.push(node.left as usize);
            } else {
                comps += lut.area(b, t);
                stack.push(node.left as usize);
                stack.push(node.right as usize);
            }
        }
        let leaf_frac = reachable_leaves as f64 / self.tree.n_leaves().max(1) as f64;
        comps + self.routing_offset_mm2 * leaf_frac
    }

    pub fn decode_context<'a>(&'a self, lut: &'a AreaLut) -> DecodeContext<'a> {
        DecodeContext {
            thresholds: &self.thresholds,
            lut,
            margin_max: self.margin_max,
        }
    }
}

/// In-flight accuracy request: issued by
/// [`AccuracyEngine::submit_accuracy`], redeemed (in any order) by
/// [`AccuracyEngine::collect`].
///
/// Engines that cannot defer work use [`AccuracyTicket::ready`] — the
/// blocking adapter computes the result at submit time and parks it in
/// the ticket.  Engines with a real async backend park their own
/// in-flight state via [`AccuracyTicket::engine`] and downcast it back in
/// `collect` ([`AccuracyTicket::into_engine_state`]); submit-side
/// failures ride inside a ready ticket, so call sites stay uniform:
/// submit everything, then collect everything.
#[must_use = "an AccuracyTicket must be redeemed with collect(); dropping it abandons the submitted batch"]
pub struct AccuracyTicket {
    repr: TicketRepr,
}

enum TicketRepr {
    /// Blocking adapter: the result was computed at submit time.
    Ready(Result<Vec<f64>>),
    /// Engine-specific in-flight state, downcast by the engine that
    /// issued it.
    Engine(Box<dyn Any + Send>),
}

impl AccuracyTicket {
    /// A ticket that already holds its result (the blocking adapter).
    pub fn ready(result: Result<Vec<f64>>) -> AccuracyTicket {
        AccuracyTicket { repr: TicketRepr::Ready(result) }
    }

    /// A ticket wrapping engine-specific in-flight state.
    pub fn engine(state: Box<dyn Any + Send>) -> AccuracyTicket {
        AccuracyTicket { repr: TicketRepr::Engine(state) }
    }

    /// Resolve a ready ticket; an engine ticket comes back untouched so
    /// the caller can downcast it.
    pub fn try_ready(self) -> std::result::Result<Result<Vec<f64>>, AccuracyTicket> {
        match self.repr {
            TicketRepr::Ready(res) => Ok(res),
            repr => Err(AccuracyTicket { repr }),
        }
    }

    /// Downcast an engine ticket's state; a mismatched type (or a ready
    /// ticket) returns the ticket unconsumed.
    pub fn into_engine_state<T: 'static>(self) -> std::result::Result<Box<T>, AccuracyTicket> {
        match self.repr {
            TicketRepr::Engine(state) => match state.downcast::<T>() {
                Ok(s) => Ok(s),
                Err(state) => Err(AccuracyTicket { repr: TicketRepr::Engine(state) }),
            },
            repr => Err(AccuracyTicket { repr }),
        }
    }
}

/// Batched accuracy oracle over concrete approximations.
///
/// `Err` means the engine could not evaluate the batch (backend execution
/// failure, service shutdown) — callers must surface it rather than
/// fabricate fitness.  The native engine never fails; the service-backed
/// engines can, though they heal what is recoverable first (the
/// coordinator's `XlaEngine` transparently re-registers once and retries
/// on a stale registration before surfacing `Err`).
pub trait AccuracyEngine {
    fn batch_accuracy(&mut self, problem: &Problem, batch: &[TreeApprox]) -> Result<Vec<f64>>;
    /// Human-readable engine id (logs / benches).
    fn name(&self) -> &'static str;

    /// Phase one of the two-phase eval: start evaluating `batch` and
    /// return a ticket for it.  The default is the blocking adapter —
    /// evaluate now, park the result — so plain engines (the native tree
    /// walk, test fakes) need not know tickets exist.  Failures ride
    /// inside the ticket and surface at [`Self::collect`].
    fn submit_accuracy(&mut self, problem: &Problem, batch: &[TreeApprox]) -> AccuracyTicket {
        AccuracyTicket::ready(self.batch_accuracy(problem, batch))
    }

    /// Phase two: redeem a ticket from [`Self::submit_accuracy`].
    /// Tickets may be collected in any order.
    fn collect(&mut self, ticket: AccuracyTicket) -> Result<Vec<f64>> {
        match ticket.try_ready() {
            Ok(res) => res,
            Err(_) => Err(anyhow!(
                "engine '{}' was handed an engine-specific ticket it did not issue",
                self.name()
            )),
        }
    }

    /// Preferred micro-batch size for pipelined submit/collect (0 = no
    /// preference: callers submit whole batches).  Service-backed engines
    /// answer `pool workers x artifact width` so a generation's misses
    /// can keep every shard fed.
    fn preferred_microbatch(&self) -> usize {
        0
    }
}

/// Evaluation counters (exposed through coordinator metrics).
///
/// Resolution order per requested chromosome: the per-run phenotype memo
/// (`cache_hits`), then the shared L1 tier (`l1_hits`, entries produced
/// by this process), then the shared L2 tier (`l2_hits`, entries loaded
/// from disk), then the engine (`engine_evals`). A warm repeat run is
/// *provably* engine-free when `engine_evals == 0` with `l2_hits > 0` —
/// `runs.json` archives all four so CI can assert exactly that.
#[derive(Clone, Copy, Debug, Default)]
pub struct EvalStats {
    pub requested: usize,
    pub cache_hits: usize,
    pub l1_hits: usize,
    pub l2_hits: usize,
    pub engine_evals: usize,
}

/// Shared-tier wiring for a [`FitnessEvaluator`]: the process-wide cache,
/// this dataset's fingerprint, and the observability seams. Timestamps
/// come from the injected `clock` (never the OS clock — trace-seam
/// contract), hit/miss/latency accounting lands in `metrics`.
pub struct SharedCache {
    pub cache: Arc<EvalCache>,
    pub fingerprint: DatasetFingerprint,
    pub metrics: Arc<Metrics>,
    pub clock: Arc<dyn Clock>,
}

/// The GA-facing evaluator: decode → (cache | engine) → objectives.
///
/// The GA's [`Evaluator`] trait is infallible, so engine failures are
/// absorbed here: the first error is stored (see [`Self::take_error`]),
/// the affected chromosomes get pessimistic objectives (`error = 1`, real
/// area estimate) so the generation can finish, and no further engine
/// calls are issued.  The driver checks for a stored error after the run
/// and fails that dataset without fabricating results.
pub struct FitnessEvaluator<'a, E: AccuracyEngine> {
    pub problem: &'a Problem,
    pub lut: &'a AreaLut,
    pub engine: E,
    /// Micro-batch size for the pipelined two-phase eval: each
    /// generation's deduped misses are sliced into micro-batches of this
    /// size, ALL submitted before any is collected, with the area
    /// estimates computed while the tickets are in flight.  0 = auto
    /// (the engine's [`AccuracyEngine::preferred_microbatch`]; whole
    /// batch when the engine has no preference).
    pub microbatch: usize,
    /// Per-run phenotype memo (L0): dies with the evaluator. Keyed on the
    /// 128-bit phenotype fingerprint — at 64 bits a birthday collision
    /// would silently share objectives between distinct phenotypes.
    cache: HashMap<u128, [f64; 2]>,
    /// Optional shared tiers (L1 in-memory across drivers, L2 on disk):
    /// consulted on a per-run miss *before* any ticket is issued, and
    /// published back on collect. Misses still flow through the
    /// `submit_accuracy`/`collect` seam — the cache is a filter in front
    /// of it, not a second blocking path.
    pub shared: Option<SharedCache>,
    pub stats: EvalStats,
    error: Option<anyhow::Error>,
}

impl<'a, E: AccuracyEngine> FitnessEvaluator<'a, E> {
    pub fn new(problem: &'a Problem, lut: &'a AreaLut, engine: E) -> Self {
        FitnessEvaluator {
            problem,
            lut,
            engine,
            microbatch: 0,
            cache: HashMap::new(),
            shared: None,
            stats: EvalStats::default(),
            error: None,
        }
    }

    /// First engine failure observed during evaluation, if any.  Taking it
    /// re-arms the evaluator (subsequent batches will hit the engine again).
    pub fn take_error(&mut self) -> Option<anyhow::Error> {
        self.error.take()
    }
}

impl<'a, E: AccuracyEngine> Evaluator for FitnessEvaluator<'a, E> {
    fn evaluate(&mut self, pop: &[Chromosome]) -> Vec<[f64; 2]> {
        let ctx = self.problem.decode_context(self.lut);
        self.stats.requested += pop.len();

        // Decode once; split into cache hits and misses. A per-run miss
        // probes the shared tiers (when wired) before it can cost a
        // ticket; shared hits are pulled down into the per-run memo so a
        // phenotype is ever charged at most one shared lookup per run.
        let decoded: Vec<(u128, TreeApprox)> = pop
            .iter()
            .map(|c| {
                let approx = c.decode(&ctx);
                (Chromosome::phenotype_key_of(&approx), approx)
            })
            .collect();
        let mut out: Vec<Option<[f64; 2]>> = Vec::with_capacity(pop.len());
        for (key, _) in &decoded {
            if let Some(v) = self.cache.get(key) {
                self.stats.cache_hits += 1;
                out.push(Some(*v));
                continue;
            }
            let Some(shared) = &self.shared else {
                out.push(None);
                continue;
            };
            let t0 = shared.clock.now_ns();
            let hit = shared.cache.lookup(shared.fingerprint, *key);
            let t1 = shared.clock.now_ns();
            shared.metrics.record_cache_lookup(t1.saturating_sub(t0));
            match hit {
                Some((obj, tier)) => {
                    let tier_no = match tier {
                        CacheTier::L1 => {
                            self.stats.l1_hits += 1;
                            shared.metrics.cache_l1_hits.fetch_add(1, Relaxed);
                            1
                        }
                        CacheTier::L2 => {
                            self.stats.l2_hits += 1;
                            shared.metrics.cache_l2_hits.fetch_add(1, Relaxed);
                            2
                        }
                    };
                    if shared.metrics.trace.enabled() {
                        shared.metrics.trace.record(t1, TraceKind::CacheHit { tier: tier_no });
                    }
                    self.cache.insert(*key, obj);
                    out.push(Some(obj));
                }
                None => {
                    shared.metrics.cache_misses.fetch_add(1, Relaxed);
                    if shared.metrics.trace.enabled() {
                        shared.metrics.trace.record(t1, TraceKind::CacheMiss);
                    }
                    out.push(None);
                }
            }
        }

        // Deduplicate misses by phenotype within the batch, too.
        let mut unique: Vec<(u128, usize)> = Vec::new(); // (key, representative idx)
        let mut key_pos: HashMap<u128, usize> = HashMap::new();
        for i in 0..pop.len() {
            if out[i].is_none() && !key_pos.contains_key(&decoded[i].0) {
                key_pos.insert(decoded[i].0, unique.len());
                unique.push((decoded[i].0, i));
            }
        }
        if !unique.is_empty() && self.error.is_none() {
            // Phase one: slice the misses into micro-batches and submit
            // EVERY one before collecting any, so a service-backed
            // engine's shards fill with in-flight work while this thread
            // is still busy below.
            let size = match self.microbatch {
                0 => self.engine.preferred_microbatch(),
                n => n,
            };
            let size = if size == 0 { unique.len() } else { size.max(1) };
            let mut tickets: Vec<(AccuracyTicket, &[(u128, usize)])> =
                Vec::with_capacity(unique.len().div_ceil(size));
            for chunk in unique.chunks(size) {
                let batch: Vec<TreeApprox> =
                    chunk.iter().map(|&(_, i)| decoded[i].1.clone()).collect();
                let ticket = self.engine.submit_accuracy(self.problem, &batch);
                tickets.push((ticket, chunk));
            }
            // Overlap: every miss's area estimate runs while the accuracy
            // tickets are in flight on the service side.
            let areas: HashMap<u128, f64> = unique
                .iter()
                .map(|&(key, i)| (key, self.problem.estimate_area(self.lut, &decoded[i].1)))
                .collect();
            // Phase two: collect in submit order.  A failing micro-batch
            // stores the first error and leaves its chromosomes
            // unresolved (pessimistic below); completed micro-batches
            // still land in the cache.
            for (ticket, chunk) in tickets {
                match self.engine.collect(ticket) {
                    Ok(accs) if accs.len() == chunk.len() => {
                        self.stats.engine_evals += chunk.len();
                        for (&(key, _), acc) in chunk.iter().zip(accs) {
                            let obj = [1.0 - acc, areas[&key]];
                            self.cache.insert(key, obj);
                            // Publish to the shared tiers so concurrent
                            // drivers (and, after the spill, future
                            // processes) reuse this eval.
                            if let Some(shared) = &self.shared {
                                shared.cache.publish(shared.fingerprint, key, obj);
                            }
                        }
                    }
                    // A misbehaving engine returning the wrong length is a
                    // stored error + pessimistic objectives, never a
                    // GA-killing panic.
                    Ok(accs) => {
                        if self.error.is_none() {
                            self.error = Some(anyhow!(
                                "engine '{}' returned {} accuracies for a batch of {}",
                                self.engine.name(),
                                accs.len(),
                                chunk.len()
                            ));
                        }
                    }
                    Err(e) => {
                        if self.error.is_none() {
                            self.error = Some(e);
                        }
                    }
                }
            }
            for i in 0..pop.len() {
                if out[i].is_none() {
                    out[i] = self.cache.get(&decoded[i].0).copied();
                }
            }
        }
        // Unresolved entries (engine failure) get pessimistic objectives —
        // never cached — so the generation completes without fake wins.
        out.into_iter()
            .zip(&decoded)
            .map(|(o, (_, approx))| {
                o.unwrap_or_else(|| [1.0, self.problem.estimate_area(self.lut, approx)])
            })
            .collect()
    }
}

#[cfg(test)]
pub mod testutil {
    use super::*;
    use crate::data::generators;
    use crate::dt::{train, TrainConfig};

    /// A small, fast real problem (Seeds) shared by fitness/coordinator
    /// tests.
    pub fn small_problem(lut: &AreaLut) -> Problem {
        let lib = EgtLibrary::default();
        let spec = generators::spec("seeds").unwrap();
        let data = generators::generate(spec, 42);
        let (train_d, test_d) = data.split(0.3, 42);
        let tree = train(&train_d, &TrainConfig { max_leaves: spec.max_leaves, min_samples_split: 2 });
        Problem::new("seeds", tree, &test_d, lut, &lib, 5)
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::small_problem;
    use super::*;

    #[test]
    fn problem_construction_consistent() {
        let lut = AreaLut::build(&EgtLibrary::default());
        let p = small_problem(&lut);
        assert_eq!(p.n_comparators(), p.tree.n_comparators());
        assert_eq!(p.test_codes.len(), p.n_test * p.n_features);
        assert!(p.routing_offset_mm2 >= 0.0);
        assert!(p.exact_report.area_mm2 > 0.0);
        // Estimated exact area == exact synthesis area by construction.
        let exact = TreeApprox::exact(&p.tree);
        let est = p.estimate_area(&lut, &exact);
        assert!((est - p.exact_report.area_mm2).abs() < 1e-9);
    }

    #[test]
    fn estimate_area_monotone_in_precision() {
        let lut = AreaLut::build(&EgtLibrary::default());
        let p = small_problem(&lut);
        let n = p.n_comparators();
        let mk = |bits: u8| TreeApprox {
            bits: vec![bits; n],
            thr_int: p.thresholds.iter().map(|&t| quant::int_threshold(t, bits)).collect(),
        };
        assert!(p.estimate_area(&lut, &mk(2)) < p.estimate_area(&lut, &mk(8)));
    }

    #[test]
    fn evaluator_caches_phenotypes() {
        let lut = AreaLut::build(&EgtLibrary::default());
        let p = small_problem(&lut);
        let mut ev = FitnessEvaluator::new(&p, &lut, native::NativeEngine::default());
        let pop: Vec<Chromosome> = vec![Chromosome::exact(p.n_comparators()); 6];
        let objs = ev.evaluate(&pop);
        assert!(objs.iter().all(|o| o == &objs[0]));
        assert_eq!(ev.stats.engine_evals, 1, "5 of 6 identical → 1 engine eval");
        // Second round: all hits.
        ev.evaluate(&pop);
        assert_eq!(ev.stats.engine_evals, 1);
        // First call: 6 misses collapsed to 1 engine eval (0 cache hits);
        // second call: all 6 hit the cache.
        assert_eq!(ev.stats.cache_hits, 6);
    }

    /// An engine failure must surface through [`FitnessEvaluator::take_error`]
    /// with pessimistic (never cached, never winning) objectives — not a
    /// panic that kills the whole optimization process.
    #[test]
    fn engine_failure_is_stored_not_panicked() {
        struct FailingEngine;
        impl AccuracyEngine for FailingEngine {
            fn batch_accuracy(
                &mut self,
                _problem: &Problem,
                _batch: &[TreeApprox],
            ) -> Result<Vec<f64>> {
                Err(anyhow::anyhow!("backend exploded"))
            }
            fn name(&self) -> &'static str {
                "failing"
            }
        }

        let lut = AreaLut::build(&EgtLibrary::default());
        let p = small_problem(&lut);
        let mut ev = FitnessEvaluator::new(&p, &lut, FailingEngine);
        let pop = vec![Chromosome::exact(p.n_comparators()); 3];
        let objs = ev.evaluate(&pop);
        assert_eq!(objs.len(), pop.len());
        assert!(objs.iter().all(|o| o[0] == 1.0), "worst-case error objective");
        assert_eq!(ev.stats.engine_evals, 0);
        let err = ev.take_error().expect("failure must be stored");
        assert!(format!("{err}").contains("exploded"));
        assert!(ev.take_error().is_none(), "take_error drains");
    }

    /// Regression (ISSUE 5): a misbehaving engine returning the wrong
    /// number of accuracies used to hit `assert_eq!` and kill the whole
    /// GA.  It must become a stored error + pessimistic objectives.
    #[test]
    fn wrong_length_engine_is_stored_error_not_panic() {
        struct ShortEngine;
        impl AccuracyEngine for ShortEngine {
            fn batch_accuracy(
                &mut self,
                _problem: &Problem,
                batch: &[TreeApprox],
            ) -> Result<Vec<f64>> {
                Ok(vec![0.5; batch.len().saturating_sub(1)])
            }
            fn name(&self) -> &'static str {
                "short"
            }
        }

        let lut = AreaLut::build(&EgtLibrary::default());
        let p = small_problem(&lut);
        let mut ev = FitnessEvaluator::new(&p, &lut, ShortEngine);
        let pop = vec![Chromosome::exact(p.n_comparators()); 3];
        let objs = ev.evaluate(&pop);
        assert_eq!(objs.len(), pop.len());
        assert!(objs.iter().all(|o| o[0] == 1.0), "worst-case error objective");
        assert_eq!(ev.stats.engine_evals, 0, "a short result is not an eval");
        let err = ev.take_error().expect("wrong length must be stored");
        assert!(format!("{err}").contains("returned 0 accuracies for a batch of 1"), "{err}");
    }

    /// Micro-batched pipelining never changes arithmetic: slicing the
    /// deduped misses into tiny submit/collect chunks yields exactly the
    /// objectives of one whole-batch call, with the same engine-eval
    /// count.
    #[test]
    fn microbatched_evaluate_is_bit_identical_to_whole_batch() {
        let lut = AreaLut::build(&EgtLibrary::default());
        let p = small_problem(&lut);
        let mut rng = crate::util::rng::Pcg64::seeded(0x5A);
        let pop: Vec<Chromosome> =
            (0..11).map(|_| Chromosome::random(&mut rng, p.n_comparators())).collect();

        let mut whole = FitnessEvaluator::new(&p, &lut, native::NativeEngine::default());
        let want = whole.evaluate(&pop);

        let mut sliced = FitnessEvaluator::new(&p, &lut, native::NativeEngine::default());
        sliced.microbatch = 3;
        let got = sliced.evaluate(&pop);
        assert_eq!(got, want);
        assert_eq!(sliced.stats.engine_evals, whole.stats.engine_evals);
        assert_eq!(sliced.stats.requested, whole.stats.requested);
        assert_eq!(sliced.stats.cache_hits, whole.stats.cache_hits);
    }

    /// The shared-tier seam end to end: a cold evaluator publishes, a
    /// second evaluator in the same process resolves everything from L1,
    /// a spill/reload round-trip resolves everything from L2 — all with
    /// zero engine evals, correct counter attribution on the shared
    /// `Metrics`, and lookups timed purely on the injected clock.
    #[test]
    fn shared_tiers_attribute_hits_and_skip_the_engine() {
        use crate::util::clock::ManualClock;

        let lut = AreaLut::build(&EgtLibrary::default());
        let p = small_problem(&lut);
        let metrics = Arc::new(Metrics::default());
        let clock: Arc<dyn Clock> = Arc::new(ManualClock::new());
        let fp = DatasetFingerprint::compute("seeds", 42, p.n_test, FEATURE_BITS);
        let wire = |cache: &Arc<EvalCache>| SharedCache {
            cache: Arc::clone(cache),
            fingerprint: fp,
            metrics: Arc::clone(&metrics),
            clock: Arc::clone(&clock),
        };

        let dir = std::env::temp_dir()
            .join(format!("axdt-shared-tier-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cache = Arc::new(EvalCache::persistent(&dir));

        let mut rng = crate::util::rng::Pcg64::seeded(0x11);
        let pop: Vec<Chromosome> =
            (0..5).map(|_| Chromosome::random(&mut rng, p.n_comparators())).collect();

        // Cold: shared tiers miss, the engine runs, results are published.
        let mut cold = FitnessEvaluator::new(&p, &lut, native::NativeEngine::default());
        cold.shared = Some(wire(&cache));
        let want = cold.evaluate(&pop);
        let distinct = cold.stats.engine_evals;
        assert!(distinct > 0);
        assert_eq!(cold.stats.l1_hits + cold.stats.l2_hits, 0);
        assert_eq!(cache.len(), distinct, "every engine eval was published");
        assert_eq!(metrics.cache_misses.load(Relaxed) as usize, pop.len());

        // Warm, same process: every distinct phenotype resolves from L1;
        // the pull-down memo makes a re-evaluate cost no further shared
        // lookups.
        let mut warm = FitnessEvaluator::new(&p, &lut, native::NativeEngine::default());
        warm.shared = Some(wire(&cache));
        let got = warm.evaluate(&pop);
        assert_eq!(got, want, "cached objectives are bit-identical");
        assert_eq!(warm.stats.engine_evals, 0);
        assert_eq!(warm.stats.l1_hits, distinct);
        warm.evaluate(&pop);
        assert_eq!(warm.stats.l1_hits, distinct, "memo absorbs the repeat");
        assert_eq!(warm.stats.cache_hits, pop.len());

        // Spill, reload into a fresh cache (a new process, in effect):
        // the same phenotypes now resolve from L2.
        cache.spill().unwrap();
        let reloaded = Arc::new(EvalCache::persistent(&dir));
        assert_eq!(reloaded.load().records as usize, distinct);
        let mut disk = FitnessEvaluator::new(&p, &lut, native::NativeEngine::default());
        disk.shared = Some(wire(&reloaded));
        let from_disk = disk.evaluate(&pop);
        assert_eq!(from_disk, want, "disk round-trip is bit-exact");
        assert_eq!(disk.stats.engine_evals, 0);
        assert_eq!(disk.stats.l2_hits, distinct);

        // Attribution on the one shared Metrics: tier counters match the
        // per-run stats, and every shared lookup was timed (on a
        // ManualClock that never moved — durations land in bucket 0).
        assert_eq!(metrics.cache_l1_hits.load(Relaxed) as usize, warm.stats.l1_hits);
        assert_eq!(metrics.cache_l2_hits.load(Relaxed) as usize, disk.stats.l2_hits);
        assert_eq!(
            metrics.cache_lookup_hist().count() as usize,
            pop.len() + 2 * distinct,
            "cold misses + warm L1 hits + reloaded L2 hits, one timing each"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn exact_chromosome_matches_plain_tree_accuracy() {
        let lut = AreaLut::build(&EgtLibrary::default());
        let p = small_problem(&lut);
        let mut ev = FitnessEvaluator::new(&p, &lut, native::NativeEngine::default());
        let objs = ev.evaluate(&[Chromosome::exact(p.n_comparators())]);
        let acc8 = 1.0 - objs[0][0];
        // 8-bit quantization of [0,1] features barely moves accuracy; the
        // exact float-tree accuracy is the reference.
        let float_acc = p.tree.accuracy(
            &p.test_x,
            &p.labels,
            p.n_features,
        );
        assert!((acc8 - float_acc).abs() < 0.08, "acc8={acc8} float={float_acc}");
    }
}
