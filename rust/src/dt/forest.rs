//! Random-forest extension (beyond the paper's evaluation, which covers
//! single decision trees; §I motivates DT/RF/SVM as the printed-ML family).
//!
//! Bagging ensemble of CART trees with majority voting.  The approximation
//! machinery lifts directly: a forest chromosome is the concatenation of
//! the member trees' dual-approximation genes, and the bespoke circuit is
//! the member netlists sharing feature buses plus a printed majority-vote
//! stage (see [`crate::hw::vote`]).

use super::train::{train, TrainConfig};
use super::tree::Tree;
use crate::data::Dataset;
use crate::hw::synth::TreeApprox;
use crate::util::rng::Pcg64;

/// Bagging configuration.
#[derive(Clone, Copy, Debug)]
pub struct ForestConfig {
    pub n_trees: usize,
    /// Leaf cap per member tree.
    pub max_leaves: usize,
    /// Bootstrap sample fraction (with replacement).
    pub sample_frac: f64,
    pub seed: u64,
}

impl Default for ForestConfig {
    fn default() -> Self {
        ForestConfig { n_trees: 5, max_leaves: 32, sample_frac: 1.0, seed: 42 }
    }
}

/// A trained bagging ensemble.
#[derive(Clone, Debug)]
pub struct Forest {
    pub trees: Vec<Tree>,
    pub n_classes: usize,
    pub n_features: usize,
}

impl Forest {
    /// Majority vote over member predictions (ties → lowest class id).
    pub fn predict(&self, x: &[f32]) -> u32 {
        let mut votes = vec![0u32; self.n_classes];
        for t in &self.trees {
            votes[t.predict(x) as usize] += 1;
        }
        argmax(&votes)
    }

    pub fn accuracy(&self, x: &[f32], y: &[u32], n_features: usize) -> f64 {
        if y.is_empty() {
            return 0.0;
        }
        let correct = y
            .iter()
            .enumerate()
            .filter(|&(i, &label)| self.predict(&x[i * n_features..(i + 1) * n_features]) == label)
            .count();
        correct as f64 / y.len() as f64
    }

    /// Total comparators across member trees (forest chromosome length / 2).
    pub fn n_comparators(&self) -> usize {
        self.trees.iter().map(|t| t.n_comparators()).sum()
    }

    /// Concatenated comparator thresholds, member order.
    pub fn thresholds(&self) -> Vec<f32> {
        self.trees.iter().flat_map(|t| t.comparator_thresholds()).collect()
    }

    /// Split a concatenated approximation back into per-tree pieces.
    pub fn split_approx(&self, approx: &TreeApprox) -> Vec<TreeApprox> {
        assert_eq!(approx.bits.len(), self.n_comparators());
        let mut out = Vec::with_capacity(self.trees.len());
        let mut off = 0;
        for t in &self.trees {
            let n = t.n_comparators();
            out.push(TreeApprox {
                bits: approx.bits[off..off + n].to_vec(),
                thr_int: approx.thr_int[off..off + n].to_vec(),
            });
            off += n;
        }
        out
    }

    /// The exact 8-bit baseline approximation of the whole forest.
    pub fn exact_approx(&self) -> TreeApprox {
        let mut bits = Vec::new();
        let mut thr = Vec::new();
        for t in &self.trees {
            let a = TreeApprox::exact(t);
            bits.extend(a.bits);
            thr.extend(a.thr_int);
        }
        TreeApprox { bits, thr_int: thr }
    }

    /// Per-member [`crate::hw::synth::node_slots`] tables.  Hoist once and
    /// feed [`Self::predict_codes_with_slots`] when predicting many samples.
    pub fn member_slots(&self) -> Vec<Vec<i32>> {
        self.trees.iter().map(crate::hw::synth::node_slots).collect()
    }

    /// Majority-vote prediction on 8-bit feature codes under a concatenated
    /// approximation (native fitness path of the forest extension).
    pub fn predict_codes(&self, approxes: &[TreeApprox], codes: &[u32]) -> u32 {
        self.predict_codes_with_slots(&self.member_slots(), approxes, codes)
    }

    /// [`Self::predict_codes`] with the members' slot tables hoisted by the
    /// caller, so per-sample loops pay no per-call table builds.
    pub fn predict_codes_with_slots(
        &self,
        slots: &[Vec<i32>],
        approxes: &[TreeApprox],
        codes: &[u32],
    ) -> u32 {
        let mut votes = vec![0u32; self.n_classes];
        for ((t, a), s) in self.trees.iter().zip(approxes).zip(slots) {
            votes[crate::hw::synth::predict_codes_with_slots(t, s, a, codes) as usize] += 1;
        }
        argmax(&votes)
    }
}

fn argmax(votes: &[u32]) -> u32 {
    let mut best = 0usize;
    for (i, &v) in votes.iter().enumerate() {
        if v > votes[best] {
            best = i;
        }
    }
    best as u32
}

/// Train a bagging forest.
pub fn train_forest(data: &Dataset, cfg: &ForestConfig) -> Forest {
    let mut rng = Pcg64::new(cfg.seed, 0xF0E5);
    let n_boot = ((data.n_samples as f64) * cfg.sample_frac).round().max(1.0) as usize;
    let trees = (0..cfg.n_trees)
        .map(|_| {
            // Bootstrap resample (with replacement).
            let mut x = Vec::with_capacity(n_boot * data.n_features);
            let mut y = Vec::with_capacity(n_boot);
            for _ in 0..n_boot {
                let s = rng.below(data.n_samples as u64) as usize;
                x.extend_from_slice(data.row(s));
                y.push(data.y[s]);
            }
            let boot = Dataset {
                name: format!("{}/boot", data.name),
                x,
                y,
                n_samples: n_boot,
                n_features: data.n_features,
                n_classes: data.n_classes,
            };
            train(&boot, &TrainConfig { max_leaves: cfg.max_leaves, min_samples_split: 2 })
        })
        .collect();
    Forest { trees, n_classes: data.n_classes, n_features: data.n_features }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators;

    fn setup() -> (Forest, Dataset, Dataset) {
        let spec = generators::spec("seeds").unwrap();
        let data = generators::generate(spec, 42);
        let (train_d, test_d) = data.split(0.3, 42);
        let forest = train_forest(
            &train_d,
            &ForestConfig { n_trees: 5, max_leaves: 12, sample_frac: 1.0, seed: 7 },
        );
        (forest, train_d, test_d)
    }

    #[test]
    fn forest_trains_and_votes() {
        let (forest, _, test_d) = setup();
        assert_eq!(forest.trees.len(), 5);
        for t in &forest.trees {
            assert!(t.validate().is_ok());
        }
        let acc = forest.accuracy(&test_d.x, &test_d.y, test_d.n_features);
        assert!(acc > 0.75, "forest accuracy {acc}");
    }

    #[test]
    fn forest_at_least_close_to_single_tree() {
        let (forest, train_d, test_d) = setup();
        let single = train(&train_d, &TrainConfig { max_leaves: 12, min_samples_split: 2 });
        let acc_f = forest.accuracy(&test_d.x, &test_d.y, test_d.n_features);
        let acc_t = single.accuracy(&test_d.x, &test_d.y, test_d.n_features);
        assert!(acc_f >= acc_t - 0.08, "forest {acc_f} vs tree {acc_t}");
    }

    #[test]
    fn approx_roundtrip_and_exact_codes_vote() {
        let (forest, _, test_d) = setup();
        let exact = forest.exact_approx();
        assert_eq!(exact.bits.len(), forest.n_comparators());
        let parts = forest.split_approx(&exact);
        assert_eq!(parts.len(), forest.trees.len());

        // 8-bit code votes ≈ float votes.
        let slots = forest.member_slots();
        let mut agree = 0usize;
        for s in 0..test_d.n_samples {
            let row = test_d.row(s);
            let codes: Vec<u32> = row
                .iter()
                .map(|&x| crate::quant::code(x, crate::hw::synth::FEATURE_BITS))
                .collect();
            if forest.predict_codes_with_slots(&slots, &parts, &codes) == forest.predict(row) {
                agree += 1;
            }
        }
        assert!(agree as f64 / test_d.n_samples as f64 > 0.93);
    }

    #[test]
    fn bootstrap_diversity() {
        let (forest, _, _) = setup();
        // Member trees should not all be identical.
        let first = format!("{:?}", forest.trees[0].nodes);
        assert!(forest.trees.iter().skip(1).any(|t| format!("{:?}", t.nodes) != first));
    }
}
