//! Decision-tree substrate: CART training and the tree model.
//!
//! The paper trains its exact trees with scikit-learn ("nodes are expanded
//! until all leaves are pure … maximum number of leafs").  This module is a
//! from-scratch CART: Gini impurity, midpoint thresholds, best-first
//! (largest weighted impurity decrease) node expansion with an optional
//! leaf cap — the exact semantics of sklearn's `max_leaf_nodes` growth.

pub mod forest;
pub mod prune;
pub mod train;
pub mod tree;

pub use train::{train, TrainConfig};
pub use tree::{Node, Tree};
