//! Approximation-aware tree pruning.
//!
//! Threshold substitution can saturate a comparator at `2^b − 1`, making it
//! constant-true (the `≤` branch always taken).  Synthesis removes the dead
//! logic automatically (constant propagation), but downstream consumers of
//! the *tree* — the RTL emitter, the exported model, accuracy evaluation —
//! benefit from an explicitly pruned structure: fewer comparators, shallower
//! paths, and an exported design whose documentation matches its silicon
//! (well, ink).

use super::tree::{Node, Tree};
use crate::hw::synth::TreeApprox;
use crate::quant;

/// Result of pruning: the reduced tree + approximation, and the mapping
/// from new comparator slots to original slots.
#[derive(Clone, Debug)]
pub struct Pruned {
    pub tree: Tree,
    pub approx: TreeApprox,
    /// `slot_map[new_slot] == old_slot`.
    pub slot_map: Vec<usize>,
    /// Comparators removed because they were constant-true.
    pub removed_constant: usize,
    /// Leaves removed as unreachable.
    pub removed_leaves: usize,
}

/// Fold constant-true comparators and drop unreachable subtrees.
pub fn prune(tree: &Tree, approx: &TreeApprox) -> Pruned {
    let n = tree.n_comparators();
    assert_eq!(approx.bits.len(), n);
    assert_eq!(approx.thr_int.len(), n);
    let mut slot_of_node = vec![usize::MAX; tree.nodes.len()];
    for (slot, node) in tree.comparator_nodes().into_iter().enumerate() {
        slot_of_node[node] = slot;
    }

    // Rebuild reachable structure depth-first.
    let mut nodes: Vec<Node> = Vec::new();
    let mut bits = Vec::new();
    let mut thr_int = Vec::new();
    let mut slot_map = Vec::new();
    let mut removed_constant = 0usize;

    // Returns new node index.
    fn rebuild(
        tree: &Tree,
        approx: &TreeApprox,
        slot_of_node: &[usize],
        i: usize,
        nodes: &mut Vec<Node>,
        bits: &mut Vec<u8>,
        thr_int: &mut Vec<u32>,
        slot_map: &mut Vec<usize>,
        removed_constant: &mut usize,
    ) -> i32 {
        let node = tree.nodes[i];
        if node.is_leaf() {
            nodes.push(node);
            return (nodes.len() - 1) as i32;
        }
        let slot = slot_of_node[i];
        let (b, t) = (approx.bits[slot], approx.thr_int[slot]);
        if t == quant::levels(b) - 1 {
            // Constant-true: the left branch is always taken.
            *removed_constant += 1;
            return rebuild(
                tree, approx, slot_of_node, node.left as usize, nodes, bits, thr_int,
                slot_map, removed_constant,
            );
        }
        let idx = nodes.len();
        nodes.push(node); // children fixed below
        bits.push(b);
        thr_int.push(t);
        slot_map.push(slot);
        // NOTE: comparator slots are defined by node order; we push nodes
        // pre-order, so slot indices match `bits`/`thr_int` pushed here only
        // if internal nodes appear in the same relative order. They do:
        // comparator_nodes() of the new tree enumerates internal nodes in
        // node-index order, which is exactly our push order.
        let l = rebuild(
            tree, approx, slot_of_node, node.left as usize, nodes, bits, thr_int,
            slot_map, removed_constant,
        );
        let r = rebuild(
            tree, approx, slot_of_node, node.right as usize, nodes, bits, thr_int,
            slot_map, removed_constant,
        );
        nodes[idx].left = l;
        nodes[idx].right = r;
        idx as i32
    }

    let root = rebuild(
        tree,
        approx,
        &slot_of_node,
        0,
        &mut nodes,
        &mut bits,
        &mut thr_int,
        &mut slot_map,
        &mut removed_constant,
    );
    assert_eq!(root, 0);

    let pruned_tree = Tree { nodes, n_features: tree.n_features, n_classes: tree.n_classes };
    let removed_leaves = tree.n_leaves() - pruned_tree.n_leaves();
    debug_assert!(pruned_tree.validate().is_ok());

    // Fix the slot ordering: comparator_nodes() is node-index order; our
    // pre-order pushes interleave leaves, so recompute the permutation.
    let comp_nodes = pruned_tree.comparator_nodes();
    // Map node index -> position in push order of internal nodes.
    let mut push_pos = std::collections::HashMap::new();
    let mut k = 0usize;
    for (idx, node) in pruned_tree.nodes.iter().enumerate() {
        if !node.is_leaf() {
            push_pos.insert(idx, k);
            k += 1;
        }
    }
    let mut bits2 = Vec::with_capacity(bits.len());
    let mut thr2 = Vec::with_capacity(bits.len());
    let mut slot_map2 = Vec::with_capacity(bits.len());
    for &node_idx in &comp_nodes {
        // push order == node-index order for internal nodes? nodes were
        // appended in pre-order, so node indices increase with push order:
        // the two orders coincide.
        let pos = push_pos[&node_idx];
        bits2.push(bits[pos]);
        thr2.push(thr_int[pos]);
        slot_map2.push(slot_map[pos]);
    }

    Pruned {
        tree: pruned_tree,
        approx: TreeApprox { bits: bits2, thr_int: thr2 },
        slot_map: slot_map2,
        removed_constant,
        removed_leaves,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators;
    use crate::dt::{train, TrainConfig};
    use crate::hw::synth;
    use crate::util::rng::Pcg64;

    fn setup() -> (Tree, Vec<f32>) {
        let spec = generators::spec("vertebral").unwrap();
        let data = generators::generate(spec, 3);
        let tree = train(&data, &TrainConfig { max_leaves: 16, min_samples_split: 2 });
        let thr = tree.comparator_thresholds();
        (tree, thr)
    }

    #[test]
    fn pruning_noop_without_constants() {
        let (tree, _) = setup();
        let approx = TreeApprox::exact(&tree);
        // exact thresholds rarely saturate; force non-saturated
        let approx = TreeApprox {
            bits: approx.bits.clone(),
            thr_int: approx.thr_int.iter().map(|&t| t.min(254)).collect(),
        };
        let pr = prune(&tree, &approx);
        assert_eq!(pr.removed_constant, 0);
        assert_eq!(pr.tree.n_comparators(), tree.n_comparators());
        // Slot order may be permuted (pruned tree is rebuilt pre-order);
        // contents must map back exactly.
        for (new_slot, &old_slot) in pr.slot_map.iter().enumerate() {
            assert_eq!(pr.approx.thr_int[new_slot], approx.thr_int[old_slot]);
            assert_eq!(pr.approx.bits[new_slot], approx.bits[old_slot]);
        }
    }

    #[test]
    fn constant_comparators_removed_and_semantics_preserved() {
        let (tree, thr) = setup();
        let mut rng = Pcg64::seeded(0xBEE);
        for _ in 0..10 {
            let n = tree.n_comparators();
            let bits: Vec<u8> = (0..n).map(|_| rng.int_in(2, 8) as u8).collect();
            let thr_int: Vec<u32> = (0..n)
                .map(|j| {
                    let t = crate::quant::int_threshold(thr[j], bits[j]);
                    // Saturate ~1/3 of comparators to force pruning.
                    if rng.chance(0.33) {
                        crate::quant::levels(bits[j]) - 1
                    } else {
                        t.min(crate::quant::levels(bits[j]) - 2)
                    }
                })
                .collect();
            let approx = TreeApprox { bits, thr_int };
            let pr = prune(&tree, &approx);
            assert!(pr.tree.validate().is_ok());
            // Constant folds remove themselves AND any comparators inside
            // the dead subtree.
            assert!(
                pr.tree.n_comparators() + pr.removed_constant <= tree.n_comparators()
            );
            assert!(pr.removed_constant > 0 || pr.tree.n_comparators() == tree.n_comparators());
            // Prediction equivalence on random codes.
            let slots = synth::node_slots(&tree);
            let pr_slots = synth::node_slots(&pr.tree);
            for _ in 0..50 {
                let codes: Vec<u32> =
                    (0..tree.n_features).map(|_| rng.below(256) as u32).collect();
                assert_eq!(
                    synth::predict_codes_with_slots(&tree, &slots, &approx, &codes),
                    synth::predict_codes_with_slots(&pr.tree, &pr_slots, &pr.approx, &codes)
                );
            }
        }
    }

    #[test]
    fn pruned_synthesis_never_larger() {
        let (tree, thr) = setup();
        let lib = crate::hw::EgtLibrary::default();
        let n = tree.n_comparators();
        let bits = vec![4u8; n];
        let thr_int: Vec<u32> = (0..n)
            .map(|j| {
                if j % 3 == 0 {
                    15 // constant-true at 4 bits
                } else {
                    crate::quant::int_threshold(thr[j], 4)
                }
            })
            .collect();
        let approx = TreeApprox { bits, thr_int };
        let full = synth::synth_tree(&tree, &approx).netlist.area_mm2(&lib);
        let pr = prune(&tree, &approx);
        let pruned = synth::synth_tree(&pr.tree, &pr.approx).netlist.area_mm2(&lib);
        assert!(pruned <= full * 1.0001, "pruned {pruned} full {full}");
    }

    #[test]
    fn slot_map_points_to_originals() {
        let (tree, thr) = setup();
        let n = tree.n_comparators();
        let bits = vec![5u8; n];
        let thr_int: Vec<u32> = (0..n)
            .map(|j| if j == 0 { 31 } else { crate::quant::int_threshold(thr[j], 5).min(30) })
            .collect();
        let approx = TreeApprox { bits, thr_int };
        let pr = prune(&tree, &approx);
        for (new_slot, &old_slot) in pr.slot_map.iter().enumerate() {
            assert_eq!(pr.approx.bits[new_slot], approx.bits[old_slot]);
            assert_eq!(pr.approx.thr_int[new_slot], approx.thr_int[old_slot]);
        }
    }
}
