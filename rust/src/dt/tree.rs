//! Tree model: storage, prediction, structural queries, path extraction.

/// One node of a binary decision tree.
///
/// Internal nodes test `x[feat] <= thr` (sklearn convention: true = left).
/// Leaves carry `leaf_class >= 0` and `feat == -1`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Node {
    pub feat: i32,
    pub thr: f32,
    pub left: i32,
    pub right: i32,
    pub leaf_class: i32,
    pub n_samples: u32,
}

impl Node {
    pub fn is_leaf(&self) -> bool {
        self.leaf_class >= 0
    }
}

/// A trained decision tree. Node 0 is the root.
#[derive(Clone, Debug, Default)]
pub struct Tree {
    pub nodes: Vec<Node>,
    pub n_features: usize,
    pub n_classes: usize,
}

/// One step on a root→leaf path: (comparator slot, required outcome).
/// `sense == true` means the path takes the `<=` (left) branch.
pub type PathStep = (usize, bool);

impl Tree {
    /// Plain (un-approximated) prediction.
    pub fn predict(&self, x: &[f32]) -> u32 {
        let mut i = 0usize;
        loop {
            let n = &self.nodes[i];
            if n.is_leaf() {
                return n.leaf_class as u32;
            }
            i = if x[n.feat as usize] <= n.thr {
                n.left as usize
            } else {
                n.right as usize
            };
        }
    }

    /// Test accuracy of the plain tree.
    pub fn accuracy(&self, x: &[f32], y: &[u32], n_features: usize) -> f64 {
        if y.is_empty() {
            return 0.0;
        }
        let correct = y
            .iter()
            .enumerate()
            .filter(|&(i, &label)| self.predict(&x[i * n_features..(i + 1) * n_features]) == label)
            .count();
        correct as f64 / y.len() as f64
    }

    /// Internal (comparator) node indices, in node-index order.  The
    /// position in this list is the node's *comparator slot*: the index used
    /// by chromosomes, the area LUT, and the tensor encoding alike.
    pub fn comparator_nodes(&self) -> Vec<usize> {
        (0..self.nodes.len()).filter(|&i| !self.nodes[i].is_leaf()).collect()
    }

    /// Leaf node indices in node-index order.
    pub fn leaf_nodes(&self) -> Vec<usize> {
        (0..self.nodes.len()).filter(|&i| self.nodes[i].is_leaf()).collect()
    }

    pub fn n_comparators(&self) -> usize {
        self.nodes.iter().filter(|n| !n.is_leaf()).count()
    }

    pub fn n_leaves(&self) -> usize {
        self.nodes.iter().filter(|n| n.is_leaf()).count()
    }

    /// Maximum root→leaf depth (edges).
    pub fn depth(&self) -> usize {
        fn rec(t: &Tree, i: usize) -> usize {
            let n = &t.nodes[i];
            if n.is_leaf() {
                0
            } else {
                1 + rec(t, n.left as usize).max(rec(t, n.right as usize))
            }
        }
        if self.nodes.is_empty() {
            0
        } else {
            rec(self, 0)
        }
    }

    /// Root→leaf path for every leaf (leaf-node-index order), as
    /// (comparator slot, sense) steps.  This is the structure behind the
    /// kernel's `wleaf`/`bias` encoding and the RTL path-AND trees.
    pub fn leaf_paths(&self) -> Vec<Vec<PathStep>> {
        let comp_slot: std::collections::HashMap<usize, usize> = self
            .comparator_nodes()
            .into_iter()
            .enumerate()
            .map(|(slot, node)| (node, slot))
            .collect();
        let mut paths = Vec::with_capacity(self.n_leaves());
        let mut stack: Vec<PathStep> = Vec::new();
        fn rec(
            t: &Tree,
            i: usize,
            comp_slot: &std::collections::HashMap<usize, usize>,
            stack: &mut Vec<PathStep>,
            out: &mut Vec<Vec<PathStep>>,
        ) {
            let n = &t.nodes[i];
            if n.is_leaf() {
                out.push(stack.clone());
                return;
            }
            let slot = comp_slot[&i];
            stack.push((slot, true));
            rec(t, n.left as usize, comp_slot, stack, out);
            stack.pop();
            stack.push((slot, false));
            rec(t, n.right as usize, comp_slot, stack, out);
            stack.pop();
        }
        if !self.nodes.is_empty() {
            rec(self, 0, &comp_slot, &mut stack, &mut paths);
        }
        // rec emits in DFS order == leaf_nodes() order? DFS visits leaves in
        // left-to-right order; leaf_nodes() is node-index order. Reorder to
        // node-index order for a stable slot mapping.
        let leaf_order = self.leaf_nodes();
        let mut dfs_leaves = Vec::new();
        fn dfs_leaf_ids(t: &Tree, i: usize, out: &mut Vec<usize>) {
            let n = &t.nodes[i];
            if n.is_leaf() {
                out.push(i);
            } else {
                dfs_leaf_ids(t, n.left as usize, out);
                dfs_leaf_ids(t, n.right as usize, out);
            }
        }
        if !self.nodes.is_empty() {
            dfs_leaf_ids(self, 0, &mut dfs_leaves);
        }
        let pos: std::collections::HashMap<usize, usize> =
            dfs_leaves.iter().enumerate().map(|(k, &id)| (id, k)).collect();
        leaf_order.iter().map(|id| paths[pos[id]].clone()).collect()
    }

    /// Class id of each leaf, in leaf-node-index order.
    pub fn leaf_classes(&self) -> Vec<u32> {
        self.leaf_nodes()
            .into_iter()
            .map(|i| self.nodes[i].leaf_class as u32)
            .collect()
    }

    /// Feature tested by each comparator slot.
    pub fn comparator_features(&self) -> Vec<usize> {
        self.comparator_nodes()
            .into_iter()
            .map(|i| self.nodes[i].feat as usize)
            .collect()
    }

    /// Threshold of each comparator slot (float, in [0, 1]).
    pub fn comparator_thresholds(&self) -> Vec<f32> {
        self.comparator_nodes()
            .into_iter()
            .map(|i| self.nodes[i].thr)
            .collect()
    }

    /// Structural sanity check: every node reachable exactly once, children
    /// in bounds, leaves classed, internals not.
    pub fn validate(&self) -> Result<(), String> {
        if self.nodes.is_empty() {
            return Err("empty tree".into());
        }
        let mut seen = vec![false; self.nodes.len()];
        let mut stack = vec![0usize];
        while let Some(i) = stack.pop() {
            if i >= self.nodes.len() {
                return Err(format!("child index {i} out of bounds"));
            }
            if seen[i] {
                return Err(format!("node {i} reachable twice"));
            }
            seen[i] = true;
            let n = &self.nodes[i];
            if n.is_leaf() {
                if n.leaf_class as usize >= self.n_classes {
                    return Err(format!("leaf {i} class {} out of range", n.leaf_class));
                }
            } else {
                if n.feat < 0 || n.feat as usize >= self.n_features {
                    return Err(format!("node {i} feature {} out of range", n.feat));
                }
                stack.push(n.left as usize);
                stack.push(n.right as usize);
            }
        }
        if !seen.iter().all(|&s| s) {
            return Err("unreachable nodes present".into());
        }
        Ok(())
    }
}

#[cfg(test)]
pub mod testutil {
    use super::*;

    /// leaf helper
    pub fn leaf(class: i32) -> Node {
        Node { feat: -1, thr: 0.0, left: -1, right: -1, leaf_class: class, n_samples: 1 }
    }

    /// internal helper
    pub fn split(feat: i32, thr: f32, left: i32, right: i32) -> Node {
        Node { feat, thr, left, right, leaf_class: -1, n_samples: 1 }
    }

    /// Depth-2 demo tree:
    ///   n0: x0 <= 0.5 ? n1 : n2
    ///   n1: x1 <= 0.25 ? leaf(0) : leaf(1)
    ///   n2: leaf(2)
    pub fn demo_tree() -> Tree {
        Tree {
            nodes: vec![
                split(0, 0.5, 1, 2),
                split(1, 0.25, 3, 4),
                leaf(2),
                leaf(0),
                leaf(1),
            ],
            n_features: 2,
            n_classes: 3,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::*;

    #[test]
    fn predict_routes_correctly() {
        let t = demo_tree();
        assert_eq!(t.predict(&[0.4, 0.2]), 0);
        assert_eq!(t.predict(&[0.4, 0.3]), 1);
        assert_eq!(t.predict(&[0.6, 0.0]), 2);
        // boundary: <= goes left
        assert_eq!(t.predict(&[0.5, 0.25]), 0);
    }

    #[test]
    fn structure_queries() {
        let t = demo_tree();
        assert_eq!(t.n_comparators(), 2);
        assert_eq!(t.n_leaves(), 3);
        assert_eq!(t.depth(), 2);
        assert_eq!(t.comparator_nodes(), vec![0, 1]);
        assert_eq!(t.leaf_nodes(), vec![2, 3, 4]);
        assert_eq!(t.leaf_classes(), vec![2, 0, 1]);
        assert_eq!(t.comparator_features(), vec![0, 1]);
        assert_eq!(t.comparator_thresholds(), vec![0.5, 0.25]);
    }

    #[test]
    fn leaf_paths_match_routing() {
        let t = demo_tree();
        let paths = t.leaf_paths();
        // leaf order: node2 (right of root), node3, node4
        assert_eq!(paths[0], vec![(0, false)]);
        assert_eq!(paths[1], vec![(0, true), (1, true)]);
        assert_eq!(paths[2], vec![(0, true), (1, false)]);
    }

    #[test]
    fn validate_accepts_good_rejects_bad() {
        let t = demo_tree();
        assert!(t.validate().is_ok());
        let mut bad = demo_tree();
        bad.nodes[1].left = 0; // cycle
        assert!(bad.validate().is_err());
        let mut bad2 = demo_tree();
        bad2.nodes[2].leaf_class = 99;
        assert!(bad2.validate().is_err());
    }

    #[test]
    fn accuracy_counts_matches() {
        let t = demo_tree();
        let x = [0.4f32, 0.2, 0.6, 0.9];
        let y = [0u32, 0];
        assert_eq!(t.accuracy(&x, &y, 2), 0.5);
    }
}
