//! Best-first CART training (Gini), sklearn `max_leaf_nodes` semantics.
//!
//! Candidate frontier nodes are expanded in order of *weighted impurity
//! decrease*; growth stops at the leaf cap or when no split improves Gini —
//! with no cap this grows until all leaves are pure, exactly the paper's
//! setup ("nodes are expanded until all leaves are pure").

use super::tree::{Node, Tree};
use crate::data::Dataset;

/// Training configuration.
#[derive(Clone, Copy, Debug)]
pub struct TrainConfig {
    /// Maximum number of leaves (`usize::MAX` = grow to purity).
    pub max_leaves: usize,
    /// Do not split nodes with fewer samples.
    pub min_samples_split: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig { max_leaves: usize::MAX, min_samples_split: 2 }
    }
}

/// A scored candidate split for one frontier node.
#[derive(Clone, Debug)]
struct Candidate {
    node_idx: usize,
    samples: Vec<u32>,
    feat: usize,
    thr: f32,
    /// Weighted impurity decrease `n·gini - (nl·gini_l + nr·gini_r)`.
    gain: f64,
}

/// Train a tree on `data` (features must already be in [0, 1]).
pub fn train(data: &Dataset, cfg: &TrainConfig) -> Tree {
    assert!(data.n_samples > 0, "cannot train on an empty dataset");
    let mut tree = Tree {
        nodes: Vec::new(),
        n_features: data.n_features,
        n_classes: data.n_classes,
    };

    let all: Vec<u32> = (0..data.n_samples as u32).collect();
    tree.nodes.push(leaf_node(data, &all));
    let mut n_leaves = 1usize;

    // Frontier of splittable leaves, kept sorted by gain (small Vec; the
    // trees here have at most a few hundred leaves, so O(n) insert is fine).
    let mut frontier: Vec<Candidate> = Vec::new();
    if let Some(c) = best_split(data, cfg, 0, all) {
        frontier.push(c);
    }

    while n_leaves < cfg.max_leaves {
        // Pop the highest-gain candidate.
        let Some(best_pos) = frontier
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.gain.total_cmp(&b.1.gain))
            .map(|(i, _)| i)
        else {
            break;
        };
        let cand = frontier.swap_remove(best_pos);

        // Partition the node's samples.
        let (ls, rs): (Vec<u32>, Vec<u32>) = cand
            .samples
            .iter()
            .partition(|&&s| data.x[s as usize * data.n_features + cand.feat] <= cand.thr);
        debug_assert!(!ls.is_empty() && !rs.is_empty());

        let li = tree.nodes.len();
        tree.nodes.push(leaf_node(data, &ls));
        let ri = tree.nodes.len();
        tree.nodes.push(leaf_node(data, &rs));
        let n = &mut tree.nodes[cand.node_idx];
        n.feat = cand.feat as i32;
        n.thr = cand.thr;
        n.left = li as i32;
        n.right = ri as i32;
        n.leaf_class = -1;
        n_leaves += 1;

        if let Some(c) = best_split(data, cfg, li, ls) {
            frontier.push(c);
        }
        if let Some(c) = best_split(data, cfg, ri, rs) {
            frontier.push(c);
        }
    }
    tree
}

fn leaf_node(data: &Dataset, samples: &[u32]) -> Node {
    let mut counts = vec![0u32; data.n_classes];
    for &s in samples {
        counts[data.y[s as usize] as usize] += 1;
    }
    let class = counts
        .iter()
        .enumerate()
        .max_by_key(|&(i, &c)| (c, std::cmp::Reverse(i)))
        .map(|(i, _)| i as i32)
        .unwrap_or(0);
    Node {
        feat: -1,
        thr: 0.0,
        left: -1,
        right: -1,
        leaf_class: class,
        n_samples: samples.len() as u32,
    }
}

#[inline]
fn gini_from_counts(counts: &[u32], n: f64) -> f64 {
    if n <= 0.0 {
        return 0.0;
    }
    1.0 - counts.iter().map(|&c| {
        let p = c as f64 / n;
        p * p
    }).sum::<f64>()
}

/// Best (feature, midpoint-threshold) Gini split for one node, or None if
/// the node is pure / too small / no split has positive gain.
fn best_split(
    data: &Dataset,
    cfg: &TrainConfig,
    node_idx: usize,
    samples: Vec<u32>,
) -> Option<Candidate> {
    let n = samples.len();
    if n < cfg.min_samples_split {
        return None;
    }
    let mut counts = vec![0u32; data.n_classes];
    for &s in &samples {
        counts[data.y[s as usize] as usize] += 1;
    }
    let parent_gini = gini_from_counts(&counts, n as f64);
    if parent_gini == 0.0 {
        return None; // pure
    }
    let parent_weighted = n as f64 * parent_gini;

    let mut best: Option<(usize, f32, f64)> = None; // (feat, thr, gain)
    let mut order: Vec<u32> = samples.clone();
    let mut left = vec![0u32; data.n_classes];

    for feat in 0..data.n_features {
        order.sort_unstable_by(|&a, &b| {
            let va = data.x[a as usize * data.n_features + feat];
            let vb = data.x[b as usize * data.n_features + feat];
            va.total_cmp(&vb)
        });
        left.iter_mut().for_each(|c| *c = 0);
        for i in 0..n - 1 {
            let s = order[i] as usize;
            left[data.y[s] as usize] += 1;
            let v = data.x[s * data.n_features + feat];
            let v_next = data.x[order[i + 1] as usize * data.n_features + feat];
            if v_next <= v {
                continue; // no threshold between equal values
            }
            let nl = (i + 1) as f64;
            let nr = (n - i - 1) as f64;
            let gini_l = gini_from_counts(&left, nl);
            // right counts = total - left
            let mut gini_r_sum = 0.0;
            for k in 0..data.n_classes {
                let c = (counts[k] - left[k]) as f64 / nr;
                gini_r_sum += c * c;
            }
            let gini_r = 1.0 - gini_r_sum;
            // sklearn semantics: any valid split of an impure node is
            // allowed (min_impurity_decrease = 0), so zero-gain splits —
            // e.g. the root of an XOR pattern — still expand.
            let gain = parent_weighted - (nl * gini_l + nr * gini_r);
            if best.map_or(true, |(_, _, g)| gain > g) {
                let thr = 0.5 * (v + v_next); // midpoint, sklearn convention
                best = Some((feat, thr, gain));
            }
        }
    }
    best.map(|(feat, thr, gain)| Candidate { node_idx, samples, feat, thr, gain })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators;

    fn make(xs: &[(f32, f32, u32)]) -> Dataset {
        Dataset {
            name: "t".into(),
            x: xs.iter().flat_map(|&(a, b, _)| [a, b]).collect(),
            y: xs.iter().map(|&(_, _, c)| c).collect(),
            n_samples: xs.len(),
            n_features: 2,
            n_classes: xs.iter().map(|&(_, _, c)| c + 1).max().unwrap() as usize,
        }
    }

    #[test]
    fn separable_data_trains_to_perfect_accuracy() {
        let d = make(&[
            (0.1, 0.9, 0), (0.2, 0.8, 0), (0.15, 0.2, 0),
            (0.8, 0.1, 1), (0.9, 0.3, 1), (0.7, 0.2, 1),
        ]);
        let t = train(&d, &TrainConfig::default());
        assert!(t.validate().is_ok());
        assert_eq!(t.accuracy(&d.x, &d.y, 2), 1.0);
        assert_eq!(t.n_comparators(), 1, "one split suffices");
    }

    #[test]
    fn grows_to_purity_without_cap() {
        // XOR-ish: needs depth 2.
        let d = make(&[
            (0.1, 0.1, 0), (0.9, 0.9, 0),
            (0.1, 0.9, 1), (0.9, 0.1, 1),
        ]);
        let t = train(&d, &TrainConfig::default());
        assert_eq!(t.accuracy(&d.x, &d.y, 2), 1.0);
        assert!(t.depth() >= 2);
    }

    #[test]
    fn leaf_cap_respected() {
        let s = generators::spec("seeds").unwrap();
        let data = generators::generate(s, 1);
        for cap in [2usize, 4, 8] {
            let t = train(&data, &TrainConfig { max_leaves: cap, min_samples_split: 2 });
            assert!(t.n_leaves() <= cap, "cap {cap} leaves {}", t.n_leaves());
            assert_eq!(t.n_comparators(), t.n_leaves() - 1);
            assert!(t.validate().is_ok());
        }
    }

    #[test]
    fn single_class_yields_single_leaf() {
        let d = make(&[(0.1, 0.1, 0), (0.9, 0.9, 0)]);
        let t = train(&d, &TrainConfig::default());
        assert_eq!(t.nodes.len(), 1);
        assert_eq!(t.predict(&[0.5, 0.5]), 0);
    }

    #[test]
    fn best_first_matches_gain_order() {
        // The first split must be the globally best one: x0 at ~0.5
        // separates classes perfectly, x1 is noise.
        let d = make(&[
            (0.1, 0.5, 0), (0.2, 0.1, 0), (0.3, 0.9, 0),
            (0.7, 0.4, 1), (0.8, 0.95, 1), (0.9, 0.05, 1),
        ]);
        let t = train(&d, &TrainConfig { max_leaves: 2, min_samples_split: 2 });
        assert_eq!(t.nodes[0].feat, 0);
        assert!((t.nodes[0].thr - 0.5).abs() < 0.21);
    }

    #[test]
    fn thresholds_are_midpoints_of_observed_values() {
        let d = make(&[(0.2, 0.0, 0), (0.4, 0.0, 1)]);
        let t = train(&d, &TrainConfig::default());
        assert_eq!(t.nodes[0].thr, 0.3);
    }

    #[test]
    fn train_real_generator_accuracy_reasonable() {
        let s = generators::spec("seeds").unwrap();
        let data = generators::generate(s, 42);
        let (train_d, test_d) = data.split(0.3, 42);
        let t = train(&train_d, &TrainConfig { max_leaves: s.max_leaves, min_samples_split: 2 });
        let acc = t.accuracy(&test_d.x, &test_d.y, test_d.n_features);
        assert!(acc > 0.7, "seeds accuracy {acc}");
        assert!(t.validate().is_ok());
    }
}
