//! `axdt` — launcher for the approximate printed-decision-tree framework.
//!
//! ```text
//! axdt repro table1|fig4|fig5|table2|all   regenerate the paper's artifacts
//! axdt optimize                            run the NSGA-II co-design search
//! axdt export-rtl                          emit bespoke Verilog for a design
//! axdt info                                runtime / artifact / library info
//! ```
//!
//! Python never runs here: accuracy fitness executes the AOT-compiled XLA
//! artifacts through the PJRT runtime (`--engine xla`, the default when the
//! binary is built with `--features xla`), or the native tree-walk engine
//! (`--engine native` / `--engine native-service`, the offline default).

use anyhow::{anyhow, Context, Result};

use axdt::config::RunConfig;
use axdt::coordinator::{
    finish_dataset, optimize_dataset, optimize_dataset_ga, DatasetRun, EngineChoice, EvalService,
    SnapshotEmitter,
};
use axdt::fitness::cache::EvalCache;
use axdt::report;
use axdt::util::cli::{flag, opt, usage, Args, OptSpec};
use axdt::util::json::Json;
use axdt::util::sync::lock_recover;
use axdt::util::trace::{chrome_trace_json, TraceKind};

const OPTS: &[OptSpec] = &[
    opt("config", "JSON config file (defaults < config < flags)"),
    opt("seed", "experiment seed (default 42)"),
    opt("datasets", "comma list or 'all' (default all 10)"),
    opt("pop", "NSGA-II population size (default 48)"),
    opt("generations", "NSGA-II generations (default 30)"),
    opt("margin", "threshold substitution margin (default 5)"),
    opt("engine", "native | native-service | xla (default: xla if built in, else native-service)"),
    opt("artifacts", "artifact directory (default artifacts)"),
    opt("threads", "worker threads (default: cores)"),
    opt("workers", "eval-service shard workers (0 = auto, max 64)"),
    opt("coalesce", "eval coalescing policy: adaptive | fixed | off (default fixed)"),
    opt("coalesce-window-us", "fixed-mode coalescing window in us (0 = off, default 200)"),
    opt("coalesce-window-max-us", "adaptive-mode window cap in us (default 1000)"),
    flag("respawn-shards", "respawn a dead eval-shard worker once before giving up on it"),
    opt("microbatch", "pipelined-eval micro-batch size (0 = auto: workers x width)"),
    opt("loss", "Table II accuracy-loss budget (default 0.01)"),
    opt("out", "output directory for JSON results (default results)"),
    opt("cache-dir", "persistent eval-cache directory (default <out>/cache)"),
    flag("no-cache", "disable the persistent eval cache (in-memory L1 only)"),
    opt("warm-start", "seed the GA from a previous run's runs.json Pareto fronts"),
    opt("trace-out", "write the run's ticket-lifecycle trace as Chrome trace-event JSON (Perfetto-loadable)"),
    opt("metrics-interval-ms", "emit a JSON metrics-snapshot line to stderr every N ms (0 = off)"),
    opt("dataset", "single dataset (export-rtl)"),
    opt("rtl-out", "output .v path (export-rtl)"),
    flag("verbose", "chatty progress"),
    flag("help", "show usage"),
];

const COMMANDS: &[(&str, &str)] = &[
    ("repro table1", "exact bespoke baselines for each dataset (Table I)"),
    ("repro fig4", "comparator area-vs-threshold curves (Fig. 4)"),
    ("repro fig5", "pareto fronts per dataset (Fig. 5)"),
    ("repro table2", "best designs within the loss budget (Table II)"),
    ("repro all", "everything above, in order"),
    ("optimize", "co-design search; writes <out>/runs.json"),
    ("export-rtl", "emit bespoke Verilog for the best design of --dataset"),
    ("info", "platform, buckets, cell library, config"),
];

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match run(&argv) {
        Ok(()) => {}
        Err(e) => {
            eprintln!("axdt error: {e:#}");
            std::process::exit(1);
        }
    }
}

fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, OPTS).map_err(|e| anyhow!("{e}\n\n{}", help()))?;
    if args.has_flag("help") || args.command.is_empty() {
        println!("{}", help());
        return Ok(());
    }
    let cfg = RunConfig::resolve(&args)?;
    if args.get("threads").is_some() {
        std::env::set_var("AXDT_THREADS", cfg.threads.to_string());
    }

    match args.command.iter().map(String::as_str).collect::<Vec<_>>()[..] {
        ["repro", "table1"] => {
            let (text, _) = report::table1(&cfg.datasets, cfg.seed)?;
            print!("{text}");
        }
        ["repro", "fig4"] => {
            let (text, _, _) = report::fig4();
            print!("{text}");
        }
        ["repro", "fig5"] => {
            let batch = run_all(&cfg, args.has_flag("verbose"))?;
            for r in &batch.runs {
                print!("{}", report::render_fig5(r));
            }
            partial_failure(&batch.failed)?;
        }
        ["repro", "table2"] => {
            let batch = run_all(&cfg, args.has_flag("verbose"))?;
            print!("{}", report::table2(&batch.runs, cfg.accuracy_loss));
            partial_failure(&batch.failed)?;
        }
        ["repro", "all"] => {
            let (t1, _) = report::table1(&cfg.datasets, cfg.seed)?;
            print!("{t1}\n");
            let (f4, _, _) = report::fig4();
            print!("{f4}\n");
            let batch = run_all(&cfg, args.has_flag("verbose"))?;
            for r in &batch.runs {
                print!("{}", report::render_fig5(r));
            }
            println!();
            print!("{}", report::table2(&batch.runs, cfg.accuracy_loss));
            save_runs(&cfg, &batch)?;
            partial_failure(&batch.failed)?;
        }
        ["optimize"] => {
            let batch = run_all(&cfg, args.has_flag("verbose"))?;
            for r in &batch.runs {
                print!("{}", report::render_fig5(r));
            }
            save_runs(&cfg, &batch)?;
            partial_failure(&batch.failed)?;
        }
        ["export-rtl"] => {
            let dataset = args
                .get("dataset")
                .ok_or_else(|| anyhow!("export-rtl needs --dataset"))?;
            export_rtl(&cfg, dataset, args.get("rtl-out"))?;
        }
        ["info"] => info(&cfg)?,
        _ => {
            return Err(anyhow!("unknown command {:?}\n\n{}", args.command, help()));
        }
    }
    Ok(())
}

fn help() -> String {
    usage("axdt", COMMANDS, OPTS)
}

/// What one `run_all` batch produced: the completed runs, the datasets
/// that failed, and the shared eval service's histogram telemetry
/// (`None` for serviceless native runs) for the `runs.json` archive.
struct RunBatch {
    runs: Vec<DatasetRun>,
    failed: Vec<String>,
    service_hist: Option<axdt::util::json::Json>,
}

/// Surface a partial multi-dataset failure as a non-zero exit — after the
/// completed runs have been rendered and archived — so pipelines wrapping
/// `axdt` don't mistake an incomplete reproduction for success.
fn partial_failure(failed: &[String]) -> Result<()> {
    if failed.is_empty() {
        Ok(())
    } else {
        Err(anyhow!(
            "{} dataset run(s) failed: {} (completed runs were reported/saved above)",
            failed.len(),
            failed.join(", ")
        ))
    }
}

/// Run the optimization pipeline for every configured dataset, sharing one
/// sharded evaluation service when the engine needs it.  Service-backed
/// runs drive datasets concurrently, bounded to the pool's worker count by
/// a token channel (no barrier: a slow dataset never stalls the rest) —
/// problems hash-pin to shards, so datasets fan out across workers instead
/// of queueing behind one.  (Batch coalescing pays off when several
/// clients evaluate the *same* problem concurrently — multi-tenant
/// serving, benches — see `coordinator::shard`.)  Each driver releases
/// its token after the GA phase and runs the CPU-only Pareto-front full
/// synthesis tokenless, so one dataset's synthesis overlaps the next
/// dataset's first generations.  Returns the completed runs, the ids of
/// datasets that failed (callers decide how to surface those once their
/// reports are out), and the shared service's histogram telemetry for
/// the archive.
fn run_all(cfg: &RunConfig, verbose: bool) -> Result<RunBatch> {
    let engine = cfg.engine_choice();
    let pool_opts = cfg.pool_options();
    let service = match engine {
        EngineChoice::Native => None,
        EngineChoice::NativeService => {
            Some(EvalService::spawn_native_with(cfg.pop_size, &pool_opts))
        }
        EngineChoice::Xla => Some(
            EvalService::spawn_xla_with(&cfg.artifact_dir, &pool_opts)
                .context("starting XLA eval service (did you run `make artifacts`?)")?,
        ),
    };
    // Observability: a non-empty --trace-out arms the service's
    // ticket-lifecycle journal for the whole run; --metrics-interval-ms
    // streams live Metrics snapshots to stderr while the GA runs.  Both
    // ride the service's Metrics, so the plain native engine (no
    // service) reports them unavailable instead of silently dropping
    // the request.
    if !cfg.trace_out.is_empty() {
        match &service {
            Some(svc) => svc.metrics.trace.set_enabled(true),
            None => eprintln!(
                "[axdt] --trace-out needs a service engine (native-service|xla); tracing is off"
            ),
        }
    }
    let snapshots = match &service {
        Some(svc) if cfg.metrics_interval_ms > 0 => Some(SnapshotEmitter::spawn(
            std::sync::Arc::clone(&svc.metrics),
            svc.clock(),
            cfg.metrics_interval_ms,
            Box::new(std::io::stderr()),
        )),
        None if cfg.metrics_interval_ms > 0 => {
            eprintln!(
                "[axdt] --metrics-interval-ms needs a service engine (native-service|xla); \
                 snapshots are off"
            );
            None
        }
        _ => None,
    };
    // Tiered eval cache: one L1 shared across every concurrent driver;
    // the L2 tier replays previous runs' segment files so repeat
    // optimization requests cost lookups, not engine evals.  `--no-cache`
    // keeps the shared L1 but turns persistence off.
    let cache = match cfg.resolved_cache_dir() {
        Some(dir) => std::sync::Arc::new(EvalCache::persistent(dir)),
        None => std::sync::Arc::new(EvalCache::in_memory()),
    };
    let loaded = cache.load();
    if let Some(svc) = &service {
        svc.metrics
            .cache_load_errors
            .fetch_add(loaded.errors, std::sync::atomic::Ordering::Relaxed);
        if svc.metrics.trace.enabled() {
            svc.metrics.trace.record(
                svc.clock().now_ns(),
                TraceKind::CacheLoad { records: loaded.records, errors: loaded.errors },
            );
        }
    }
    if verbose && (loaded.records > 0 || loaded.errors > 0) {
        eprintln!(
            "[axdt] eval cache: loaded {} record(s) from {} segment(s), {} error(s)",
            loaded.records, loaded.segments, loaded.errors
        );
    }
    let warm_start = if cfg.warm_start.is_empty() {
        None
    } else {
        let archive = load_warm_start(&cfg.warm_start)?;
        if verbose {
            eprintln!(
                "[axdt] warm-start: {} dataset(s) with archived fronts in {}",
                archive.len(),
                cfg.warm_start
            );
        }
        Some(std::sync::Arc::new(archive))
    };
    let mut opts = cfg.run_options();
    opts.cache = Some(std::sync::Arc::clone(&cache));
    opts.warm_start = warm_start;
    let drivers = service
        .as_ref()
        .map_or(1, |s| s.workers())
        .min(cfg.datasets.len())
        .max(1);
    // One failing dataset (e.g. a backend execution error) must not abort
    // the remaining datasets of a multi-dataset run.
    let mut results: Vec<(String, Result<DatasetRun>)> = Vec::new();
    if drivers > 1 {
        // `drivers` tokens bound the concurrency; each thread claims one
        // before optimizing and returns it after, so finished slots are
        // rehanded to waiting datasets immediately.
        let (token_tx, token_rx) = std::sync::mpsc::channel::<()>();
        for _ in 0..drivers {
            token_tx.send(()).expect("token channel open");
        }
        let token_rx = std::sync::Arc::new(std::sync::Mutex::new(token_rx));
        // Returns its token on drop, so a panicking driver cannot strand
        // the datasets still waiting for a slot.
        struct TokenGuard(std::sync::mpsc::Sender<()>);
        impl Drop for TokenGuard {
            fn drop(&mut self) {
                let _ = self.0.send(());
            }
        }
        let handles: Vec<_> = cfg
            .datasets
            .iter()
            .map(|d| {
                let d = d.clone();
                let opts = opts.clone();
                let service = service.clone();
                let token_tx = token_tx.clone();
                let token_rx = std::sync::Arc::clone(&token_rx);
                std::thread::spawn(move || {
                    lock_recover(&token_rx).recv().expect("token channel open");
                    let ga = {
                        let _token = TokenGuard(token_tx);
                        if verbose {
                            eprintln!("[axdt] optimizing {d} (engine {engine:?})…");
                        }
                        optimize_dataset_ga(&d, &opts, service.as_ref())
                    };
                    // The token is back in the pool: the next dataset's GA
                    // starts on the eval service while this thread runs
                    // the CPU-only Pareto-front full synthesis.
                    (d, ga.map(finish_dataset))
                })
            })
            .collect();
        drop(token_tx);
        for (h, d) in handles.into_iter().zip(&cfg.datasets) {
            // A panicking driver counts as that dataset failing; it must
            // not discard every other dataset's completed run.
            results.push(match h.join() {
                Ok(r) => r,
                Err(_) => (d.clone(), Err(anyhow!("driver thread panicked"))),
            });
        }
    } else {
        for d in &cfg.datasets {
            if verbose {
                eprintln!("[axdt] optimizing {d} (engine {engine:?})…");
            }
            results.push((d.clone(), optimize_dataset(d, &opts, service.as_ref())));
        }
    }
    let mut runs = Vec::new();
    let mut failed: Vec<String> = Vec::new();
    for (d, res) in results {
        match res {
            Ok(run) => {
                if verbose {
                    eprintln!(
                        "[axdt]   {d}: front {} points, best area gain {:.2}x, {:.1}s",
                        run.front.len(),
                        run.area_gain(cfg.accuracy_loss).unwrap_or(1.0),
                        run.elapsed_s
                    );
                }
                runs.push(run);
            }
            Err(e) => {
                eprintln!("[axdt] {d}: optimization failed: {e:#}");
                failed.push(d);
            }
        }
    }
    if let Some(emitter) = snapshots {
        // Stop the ticker before the final render so its last snapshot
        // line lands ahead of the summary.
        emitter.stop();
    }
    // Persist the L1 tier: fresh entries append to per-fingerprint
    // segment files so the next run into this cache dir starts warm.
    match cache.spill() {
        Ok(spilled) => {
            if let Some(svc) = &service {
                svc.metrics
                    .cache_spills
                    .fetch_add(spilled.records, std::sync::atomic::Ordering::Relaxed);
                if svc.metrics.trace.enabled() {
                    svc.metrics.trace.record(
                        svc.clock().now_ns(),
                        TraceKind::CacheSpill { records: spilled.records },
                    );
                }
            }
            if verbose && spilled.records > 0 {
                eprintln!(
                    "[axdt] eval cache: spilled {} record(s) to {} segment(s)",
                    spilled.records, spilled.segments
                );
            }
        }
        // A failed spill costs next run's warmth, not this run's results.
        Err(e) => eprintln!("[axdt] eval cache: spill failed: {e}"),
    }
    if let Some(svc) = &service {
        eprintln!(
            "[axdt] eval service ({} worker(s), {} driver(s)): {}",
            svc.workers(),
            drivers,
            svc.metrics.render()
        );
        if !cfg.trace_out.is_empty() && svc.metrics.trace.enabled() {
            let trace = &svc.metrics.trace;
            let json =
                chrome_trace_json(&trace.snapshot(), &trace.track_names(), trace.dropped());
            write_atomic(&cfg.trace_out, &format!("{json}\n"))
                .with_context(|| format!("writing trace {}", cfg.trace_out))?;
            eprintln!(
                "[axdt] wrote trace {} ({} event(s), {} dropped)",
                cfg.trace_out,
                trace.len(),
                trace.dropped()
            );
        }
        svc.shutdown();
    }
    if runs.is_empty() {
        return Err(anyhow!("all {} dataset run(s) failed", failed.len()));
    }
    if !failed.is_empty() {
        eprintln!(
            "[axdt] completed {}/{} datasets ({} failed: {})",
            runs.len(),
            cfg.datasets.len(),
            failed.len(),
            failed.join(", ")
        );
    }
    let service_hist = service.as_ref().map(|s| s.metrics.histograms_json());
    Ok(RunBatch { runs, failed, service_hist })
}

/// Parse a previous run's `runs.json` into `dataset -> front genes` for GA
/// warm-starting.  Points without a `genes` array (older archives) are
/// skipped; the driver re-validates and length-checks every seed anyway,
/// so an archive from a different configuration degrades to a cold start
/// instead of failing the run.
fn load_warm_start(path: &str) -> Result<std::collections::HashMap<String, Vec<Vec<f64>>>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading warm-start archive {path}"))?;
    let j = Json::parse(&text).with_context(|| format!("parsing warm-start archive {path}"))?;
    let runs = j
        .get("runs")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("warm-start archive {path} has no runs[]"))?;
    let mut map: std::collections::HashMap<String, Vec<Vec<f64>>> =
        std::collections::HashMap::new();
    for run in runs {
        let Some(dataset) = run.get("dataset").and_then(Json::as_str) else {
            continue;
        };
        let fronts = map.entry(dataset.to_string()).or_default();
        for point in run.get("front").and_then(Json::as_arr).unwrap_or(&[]) {
            if let Some(genes) = point.get("genes").and_then(Json::as_arr) {
                let genes: Vec<f64> = genes.iter().filter_map(Json::as_f64).collect();
                if !genes.is_empty() {
                    fronts.push(genes);
                }
            }
        }
    }
    Ok(map)
}

/// Write a results artifact atomically (`util::fsx::write_atomic`), so a
/// crash (or a ctrl-C) mid-write can never leave a truncated JSON file
/// where a pipeline watching `runs.json` / the trace expects a parseable
/// one.
fn write_atomic(path: &str, contents: &str) -> Result<()> {
    axdt::util::fsx::write_atomic(path, contents)
        .with_context(|| format!("atomically writing {path}"))
}

fn save_runs(cfg: &RunConfig, batch: &RunBatch) -> Result<()> {
    std::fs::create_dir_all(&cfg.out_dir)?;
    let path = format!("{}/runs.json", cfg.out_dir);
    let archive = report::RunArchive {
        runs: &batch.runs,
        service: batch.service_hist.clone(),
    };
    write_atomic(&path, &format!("{}\n", archive.to_json()))?;
    let cfg_path = format!("{}/config.json", cfg.out_dir);
    write_atomic(&cfg_path, &cfg.to_json())?;
    eprintln!("[axdt] wrote {path} and {cfg_path}");
    Ok(())
}

fn export_rtl(cfg: &RunConfig, dataset: &str, out: Option<&str>) -> Result<()> {
    let mut one = cfg.clone();
    one.datasets = vec![dataset.to_string()];
    let batch = run_all(&one, false)?;
    partial_failure(&batch.failed)?;
    let run = &batch.runs[0];
    let point = run
        .best_within_loss(cfg.accuracy_loss)
        .ok_or_else(|| anyhow!("no design within loss budget {}", cfg.accuracy_loss))?;
    let spec = axdt::data::generators::spec(dataset).unwrap();
    let data = axdt::data::generators::generate(spec, cfg.seed);
    let (train_d, _) = data.split(0.3, cfg.seed);
    let tree = axdt::dt::train(
        &train_d,
        &axdt::dt::TrainConfig { max_leaves: spec.max_leaves, min_samples_split: 2 },
    );
    let circuit = axdt::hw::synth::synth_tree(&tree, &point.approx);
    let verilog = axdt::hw::rtl::export(&tree, &point.approx, &circuit, dataset);
    match out {
        Some(path) => {
            std::fs::write(path, &verilog)?;
            println!(
                "wrote {path}: {} (acc {:.3}, {:.2} mm^2, {:.2} mW)",
                dataset, point.accuracy, point.measured.area_mm2, point.measured.power_mw
            );
        }
        None => print!("{verilog}"),
    }
    Ok(())
}

fn info(cfg: &RunConfig) -> Result<()> {
    println!("axdt {} — approximate bespoke decision trees for printed circuits", axdt::VERSION);
    println!("config: {}", cfg.to_json());
    let lib = axdt::hw::EgtLibrary::default();
    println!(
        "EGT library: {:.3} mm^2/T, {:.2} uW/T, {:.2} ms base delay",
        lib.mm2_per_transistor, lib.uw_per_transistor, lib.base_delay_ms
    );
    match axdt::runtime::ArtifactMeta::load(&cfg.artifact_dir) {
        Ok(meta) => {
            println!("artifacts ({}):", cfg.artifact_dir);
            for (b, file) in &meta.buckets {
                println!(
                    "  {:<8} S={:<5} N={:<4} L={:<4} C={:<3} P={:<3} {}",
                    b.name, b.s, b.n, b.l, b.c, b.p, file
                );
            }
        }
        Err(e) => println!("artifacts: unavailable ({e})"),
    }
    println!("datasets:");
    for s in axdt::data::generators::SPECS {
        println!(
            "  {:<13} {:>6} samples {:>4} features {:>3} classes (paper acc {:.3}, {} comparators)",
            s.id, s.n_samples, s.n_features, s.n_classes, s.paper_accuracy, s.paper_comparators
        );
    }
    Ok(())
}
