//! # axdt — Approximate Decision Trees for Tiny Printed Circuits
//!
//! Production-shaped reproduction of *"Approximate Decision Trees For
//! Machine Learning Classification on Tiny Printed Circuits"* (Balaskas,
//! Zervakis, Siozios, Tahoori, Henkel — 2022) as a three-layer
//! Rust + JAX + Pallas stack.
//!
//! Layer map (see DESIGN.md):
//! * **L3 (this crate)** — the co-design framework: dataset substrate, CART
//!   trainer, printed-EGT synthesis simulator + comparator area LUT,
//!   NSGA-II, and the evaluation coordinator (router / batcher / cache)
//!   that drives fitness through AOT-compiled XLA artifacts.
//! * **L2/L1 (build-time python)** — the population accuracy-evaluation
//!   graph and its Pallas kernel, lowered once to `artifacts/*.hlo.txt`.
//!
//! Python never runs at optimization time: `runtime` loads the HLO text via
//! the PJRT C API and the whole search runs from this binary.

// Library code answers with typed errors; `.unwrap()` is reserved for
// tests.  (`axdt-lint` enforces the stricter worker-path rules; this
// clippy gate catches the long tail everywhere else in the lib.)
#![cfg_attr(not(test), warn(clippy::unwrap_used))]

pub mod config;
pub mod coordinator;
pub mod data;
pub mod dt;
pub mod fitness;
pub mod ga;
pub mod hw;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod util;

/// Crate version, surfaced by the CLI.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
