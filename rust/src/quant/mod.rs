//! Fixed-point precision scaling (paper §III-A, Fig. 3b).
//!
//! The framework's "flexible threshold conversion module": float thresholds
//! in [0, 1] are scaled to a per-comparator precision `b ∈ [MIN_BITS,
//! MAX_BITS]`, converted to integers for (a) the area LUT lookup and (b) the
//! hardware-friendly substitution within ±m, and back to fixed point for
//! accuracy evaluation.  Feature codes use the same `b`-bit grid, so the
//! comparator hardware compares two `b`-bit unsigned integers.

/// Paper §IV: per-comparator precision varies between 2 and 8 bits.
pub const MIN_BITS: u8 = 2;
pub const MAX_BITS: u8 = 8;
/// Paper §IV: threshold substitution margin ±5 (integer steps).
pub const DEFAULT_MARGIN: i32 = 5;

/// Number of representable codes at `bits` precision.
#[inline]
pub fn levels(bits: u8) -> u32 {
    1u32 << bits
}

/// Quantize a [0, 1] feature to its `bits`-bit integer code:
/// `min(floor(x · 2^b), 2^b − 1)` — identical to the Pallas kernel.
#[inline]
pub fn code(x: f32, bits: u8) -> u32 {
    let scale = levels(bits) as f32;
    let q = (x * scale).floor();
    (q.max(0.0) as u32).min(levels(bits) - 1)
}

/// Convert a float threshold in [0, 1] to its `bits`-bit integer threshold.
///
/// `floor` keeps the comparator semantics aligned with `code`: the
/// quantized rule `code(x) <= thr_int` approximates `x <= thr` from below.
#[inline]
pub fn int_threshold(thr: f32, bits: u8) -> u32 {
    code(thr, bits)
}

/// Hardware-friendly substitution: move the integer threshold by `delta`
/// (a gene in [−m, +m]), clamped to the representable range.
#[inline]
pub fn substitute(thr_int: u32, delta: i32, bits: u8) -> u32 {
    let max = (levels(bits) - 1) as i64;
    (thr_int as i64 + delta as i64).clamp(0, max) as u32
}

/// Fixed-point real value of an integer threshold (used when exporting
/// designs / reporting; the kernel compares integer codes directly).
#[inline]
pub fn to_real(thr_int: u32, bits: u8) -> f32 {
    thr_int as f32 / levels(bits) as f32
}

/// The quantized comparator decision: `code(x) <= thr_int`.
#[inline]
pub fn cmp_le(x: f32, thr_int: u32, bits: u8) -> bool {
    code(x, bits) <= thr_int
}

/// A malformed approximation arriving at an accuracy engine.
///
/// The engines shift feature codes by `FEATURE_BITS - bits`, so an
/// out-of-range precision underflows the `u8` subtraction (panic in debug,
/// silently masked shift in release) — engines validate at entry and
/// return this typed error instead, keeping the panic-free-workers
/// guarantee honest for hand-built or corrupted chromosomes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ApproxError {
    /// `bits`/`thr_int` gene counts disagree with the tree's comparators.
    LengthMismatch { n_comparators: usize, bits_len: usize, thr_len: usize },
    /// A precision gene outside `[MIN_BITS, MAX_BITS]`.
    BitsOutOfRange { slot: usize, bits: u8 },
    /// An integer threshold not representable at its slot's precision.
    ThresholdOutOfRange { slot: usize, thr_int: u32, bits: u8 },
}

impl std::fmt::Display for ApproxError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ApproxError::LengthMismatch { n_comparators, bits_len, thr_len } => write!(
                f,
                "approximation has {bits_len} precision / {thr_len} threshold genes \
                 for a tree with {n_comparators} comparators"
            ),
            ApproxError::BitsOutOfRange { slot, bits } => write!(
                f,
                "comparator slot {slot}: precision {bits} bits outside \
                 [{MIN_BITS}, {MAX_BITS}]"
            ),
            ApproxError::ThresholdOutOfRange { slot, thr_int, bits } => write!(
                f,
                "comparator slot {slot}: threshold {thr_int} not representable \
                 at {bits} bits (max {})",
                levels(*bits) - 1
            ),
        }
    }
}

impl std::error::Error for ApproxError {}

/// Validate one approximation's genes against a tree with `n_comparators`
/// comparator slots: matching lengths, every precision in
/// `[MIN_BITS, MAX_BITS]`, every threshold representable at its precision.
pub fn validate_approx(
    n_comparators: usize,
    bits: &[u8],
    thr_int: &[u32],
) -> Result<(), ApproxError> {
    if bits.len() != n_comparators || thr_int.len() != n_comparators {
        return Err(ApproxError::LengthMismatch {
            n_comparators,
            bits_len: bits.len(),
            thr_len: thr_int.len(),
        });
    }
    for (slot, (&b, &t)) in bits.iter().zip(thr_int).enumerate() {
        if !(MIN_BITS..=MAX_BITS).contains(&b) {
            return Err(ApproxError::BitsOutOfRange { slot, bits: b });
        }
        if t >= levels(b) {
            return Err(ApproxError::ThresholdOutOfRange { slot, thr_int: t, bits: b });
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, PropConfig};

    #[test]
    fn code_bounds_and_monotonicity() {
        for bits in MIN_BITS..=MAX_BITS {
            assert_eq!(code(0.0, bits), 0);
            assert_eq!(code(1.0, bits), levels(bits) - 1, "x=1 clamps");
            let mut prev = 0;
            for i in 0..=100 {
                let c = code(i as f32 / 100.0, bits);
                assert!(c >= prev, "monotone");
                assert!(c < levels(bits));
                prev = c;
            }
        }
    }

    #[test]
    fn code_exact_grid() {
        // On the exact grid k/2^b the code is k.
        for bits in MIN_BITS..=MAX_BITS {
            for k in 0..levels(bits) {
                let x = k as f32 / levels(bits) as f32;
                assert_eq!(code(x, bits), k, "bits={bits} k={k}");
            }
        }
    }

    #[test]
    fn substitute_clamps() {
        assert_eq!(substitute(0, -5, 4), 0);
        assert_eq!(substitute(15, 5, 4), 15);
        assert_eq!(substitute(7, 3, 4), 10);
        assert_eq!(substitute(7, -3, 4), 4);
    }

    #[test]
    fn cmp_matches_kernel_semantics() {
        // Mirror of the kernel: min(floor(x*scale), scale-1) <= thr.
        check(
            "cmp-kernel-equiv",
            PropConfig { cases: 256, seed: 0xC0DE },
            |rng| {
                let bits = rng.int_in(MIN_BITS as i64, MAX_BITS as i64) as u8;
                let x = rng.f32();
                let thr = rng.below(levels(bits) as u64) as u32;
                (bits, x, thr)
            },
            |&(bits, x, thr)| {
                let scale = levels(bits) as f32;
                let kernel = (x * scale).floor().min(scale - 1.0) <= thr as f32;
                if kernel == cmp_le(x, thr, bits) {
                    Ok(())
                } else {
                    Err(format!("kernel={kernel} rust={}", cmp_le(x, thr, bits)))
                }
            },
        );
    }

    #[test]
    fn higher_precision_refines_threshold() {
        // int_threshold at b+1 bits is 2x (or 2x+1) of the b-bit one.
        check(
            "precision-refinement",
            PropConfig { cases: 128, seed: 0xBEEF },
            |rng| (rng.f32(), rng.int_in(MIN_BITS as i64, (MAX_BITS - 1) as i64) as u8),
            |&(thr, bits)| {
                let lo = int_threshold(thr, bits);
                let hi = int_threshold(thr, bits + 1);
                if hi == 2 * lo || hi == 2 * lo + 1 {
                    Ok(())
                } else {
                    Err(format!("lo={lo} hi={hi}"))
                }
            },
        );
    }

    #[test]
    fn validate_approx_accepts_legal_and_names_the_bad_slot() {
        assert_eq!(validate_approx(2, &[2, 8], &[3, 255]), Ok(()));
        assert_eq!(
            validate_approx(2, &[2], &[3, 255]),
            Err(ApproxError::LengthMismatch { n_comparators: 2, bits_len: 1, thr_len: 2 })
        );
        // bits = 9 would underflow `FEATURE_BITS - bits` in the engines.
        assert_eq!(
            validate_approx(2, &[4, 9], &[3, 0]),
            Err(ApproxError::BitsOutOfRange { slot: 1, bits: 9 })
        );
        assert_eq!(
            validate_approx(1, &[1], &[0]),
            Err(ApproxError::BitsOutOfRange { slot: 0, bits: 1 })
        );
        assert_eq!(
            validate_approx(1, &[4], &[16]),
            Err(ApproxError::ThresholdOutOfRange { slot: 0, thr_int: 16, bits: 4 })
        );
        // The Display strings are what engine errors surface to drivers.
        let msg = ApproxError::BitsOutOfRange { slot: 3, bits: 11 }.to_string();
        assert!(msg.contains("slot 3") && msg.contains("11"), "{msg}");
    }

    #[test]
    fn to_real_inverts_on_grid() {
        for bits in MIN_BITS..=MAX_BITS {
            for k in (0..levels(bits)).step_by(3) {
                assert_eq!(int_threshold(to_real(k, bits), bits), k);
            }
        }
    }
}
