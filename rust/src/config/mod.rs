//! Run configuration: defaults ← JSON config file ← CLI flags.
//!
//! The `axdt` launcher resolves its configuration in three layers, each
//! overriding the previous: built-in defaults, an optional `--config
//! file.json`, then explicit command-line options.  `to_json`/`from_json`
//! round-trip so runs can be archived next to their results.

use anyhow::{anyhow, Context, Result};

use crate::coordinator::{CoalesceMode, EngineChoice, PoolOptions};
use crate::util::cli::Args;
use crate::util::json::Json;

/// Default engine name: the XLA artifact path when it is compiled in,
/// otherwise the service-backed native engine (same routing/batching
/// machinery, no PJRT dependency).
pub fn default_engine() -> &'static str {
    if cfg!(feature = "xla") {
        "xla"
    } else {
        "native-service"
    }
}

/// Fully resolved run configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct RunConfig {
    pub seed: u64,
    pub datasets: Vec<String>,
    pub pop_size: usize,
    pub generations: usize,
    pub margin_max: u32,
    pub engine: String,
    pub artifact_dir: String,
    pub threads: usize,
    /// Eval-service workers (shards); 0 = auto (see [`Self::pool_options`]).
    pub workers: usize,
    /// Eval-service coalescing policy: "adaptive" | "fixed" | "off"
    /// (`--coalesce`).
    pub coalesce: String,
    /// Fixed-mode coalescing window in microseconds (0 = off).
    pub coalesce_window_us: u64,
    /// Adaptive-mode window cap in microseconds
    /// (`--coalesce-window-max-us`).
    pub coalesce_window_max_us: u64,
    /// Respawn a dead eval-shard worker once (`--respawn-shards`).
    pub respawn_shards: bool,
    /// Pipelined-eval micro-batch size (`--microbatch`): how each
    /// generation's deduped misses are sliced for ticketed submit/poll.
    /// 0 = auto (pool workers x artifact width for service engines).
    pub microbatch: usize,
    pub accuracy_loss: f64,
    pub out_dir: String,
    /// L2 eval-cache directory (`--cache-dir`); "" = `<out_dir>/cache`.
    /// Segment files are keyed by dataset fingerprint, so a directory can
    /// be shared across runs — stale entries are simply never looked up.
    pub cache_dir: String,
    /// Disable the persistent eval cache entirely (`--no-cache`): no L2
    /// load at startup, no spill at exit, in-memory L1 only.
    pub no_cache: bool,
    /// Path to a previous run's `runs.json` (`--warm-start`); "" = off.
    /// Archived Pareto-front chromosomes seed the initial NSGA-II
    /// population for matching datasets (re-validated, padded random).
    pub warm_start: String,
    /// Chrome trace-event JSON output path (`--trace-out`); "" = tracing
    /// off.  A non-empty path enables the service's ticket-lifecycle
    /// [`TraceJournal`](crate::util::trace::TraceJournal) and writes the
    /// Perfetto-loadable trace there at the end of the run.
    pub trace_out: String,
    /// Live metrics-snapshot interval in milliseconds
    /// (`--metrics-interval-ms`); 0 = off.  Emits one JSON line of
    /// `Metrics` gauges per interval to stderr while the run executes.
    pub metrics_interval_ms: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            seed: 42,
            datasets: crate::data::generators::all_ids()
                .into_iter()
                .map(String::from)
                .collect(),
            pop_size: 48,
            generations: 30,
            margin_max: 5,
            engine: default_engine().into(),
            artifact_dir: "artifacts".into(),
            threads: 0, // auto
            workers: 0, // auto
            coalesce: "fixed".into(),
            coalesce_window_us: 200,
            coalesce_window_max_us: 1_000,
            respawn_shards: false,
            microbatch: 0, // auto
            accuracy_loss: 0.01,
            out_dir: "results".into(),
            cache_dir: String::new(), // auto: <out_dir>/cache
            no_cache: false,
            warm_start: String::new(),
            trace_out: String::new(),
            metrics_interval_ms: 0,
        }
    }
}

impl RunConfig {
    /// Layer CLI options (and optional `--config`) over the defaults.
    pub fn resolve(args: &Args) -> Result<RunConfig> {
        let mut cfg = RunConfig::default();
        if let Some(path) = args.get("config") {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading config {path}"))?;
            cfg = RunConfig::from_json(&text)?;
        }
        cfg.seed = args.u64_or("seed", cfg.seed)?;
        if args.get("datasets").is_some() {
            cfg.datasets = args.list_or("datasets", &[]);
            if cfg.datasets.len() == 1 && cfg.datasets[0] == "all" {
                cfg.datasets = crate::data::generators::all_ids()
                    .into_iter()
                    .map(String::from)
                    .collect();
            }
        }
        cfg.pop_size = args.usize_or("pop", cfg.pop_size)?;
        cfg.generations = args.usize_or("generations", cfg.generations)?;
        cfg.margin_max = args.u64_or("margin", cfg.margin_max as u64)? as u32;
        cfg.engine = args.str_or("engine", &cfg.engine);
        cfg.artifact_dir = args.str_or("artifacts", &cfg.artifact_dir);
        cfg.threads = args.usize_or("threads", cfg.threads)?;
        cfg.workers = args.usize_or("workers", cfg.workers)?;
        cfg.coalesce = args.str_or("coalesce", &cfg.coalesce);
        cfg.coalesce_window_us =
            args.u64_or("coalesce-window-us", cfg.coalesce_window_us)?;
        cfg.coalesce_window_max_us =
            args.u64_or("coalesce-window-max-us", cfg.coalesce_window_max_us)?;
        if args.has_flag("respawn-shards") {
            cfg.respawn_shards = true;
        }
        cfg.microbatch = args.usize_or("microbatch", cfg.microbatch)?;
        cfg.accuracy_loss = args.f64_or("loss", cfg.accuracy_loss)?;
        cfg.out_dir = args.str_or("out", &cfg.out_dir);
        cfg.cache_dir = args.str_or("cache-dir", &cfg.cache_dir);
        if args.has_flag("no-cache") {
            cfg.no_cache = true;
        }
        cfg.warm_start = args.str_or("warm-start", &cfg.warm_start);
        cfg.trace_out = args.str_or("trace-out", &cfg.trace_out);
        cfg.metrics_interval_ms =
            args.u64_or("metrics-interval-ms", cfg.metrics_interval_ms)?;
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        EngineChoice::parse(&self.engine)?;
        if self.pop_size < 4 {
            return Err(anyhow!("pop_size must be >= 4"));
        }
        if self.datasets.is_empty() {
            return Err(anyhow!("no datasets selected"));
        }
        for d in &self.datasets {
            if crate::data::generators::spec(d).is_none() {
                return Err(anyhow!("unknown dataset '{d}'"));
            }
        }
        if !(0.0..=1.0).contains(&self.accuracy_loss) {
            return Err(anyhow!("loss must be in [0,1]"));
        }
        if self.workers > 64 {
            return Err(anyhow!("workers must be in [0, 64] (0 = auto)"));
        }
        CoalesceMode::parse(&self.coalesce)?;
        if self.coalesce_window_us > 1_000_000 {
            return Err(anyhow!("coalesce-window-us must be <= 1000000 (1 s)"));
        }
        if self.coalesce_window_max_us > 1_000_000 {
            return Err(anyhow!("coalesce-window-max-us must be <= 1000000 (1 s)"));
        }
        if self.microbatch > 1_000_000 {
            return Err(anyhow!("microbatch must be <= 1000000 (0 = auto)"));
        }
        if self.metrics_interval_ms > 3_600_000 {
            return Err(anyhow!("metrics-interval-ms must be <= 3600000 (1 h; 0 = off)"));
        }
        Ok(())
    }

    /// The parsed coalescing mode (validated by [`Self::validate`]).
    pub fn coalesce_mode(&self) -> CoalesceMode {
        CoalesceMode::parse(&self.coalesce).expect("validated")
    }

    pub fn engine_choice(&self) -> EngineChoice {
        EngineChoice::parse(&self.engine).expect("validated")
    }

    /// Pool sizing for this run's eval service.  An explicit `--workers`
    /// wins; auto (0) caps the native default at the dataset count — a
    /// problem pins to exactly one shard, so more workers than datasets
    /// would idle, and a single-dataset run keeps the full thread budget
    /// inside one worker's engine (the seed service's behavior).
    pub fn pool_options(&self) -> PoolOptions {
        let workers = if self.workers == 0 && self.engine_choice() != EngineChoice::Xla {
            crate::util::pool::default_threads().min(self.datasets.len()).max(1)
        } else {
            self.workers
        };
        PoolOptions {
            workers,
            coalesce: self.coalesce_mode(),
            coalesce_window_us: self.coalesce_window_us,
            coalesce_window_max_us: self.coalesce_window_max_us,
            engine_threads: 0,
            respawn: self.respawn_shards,
        }
    }

    /// Where the persistent L2 cache tier lives, or `None` when
    /// `--no-cache` turned persistence off.  An empty `cache_dir`
    /// defaults to `<out_dir>/cache`, so repeat runs into the same
    /// `--out` are warm automatically.
    pub fn resolved_cache_dir(&self) -> Option<String> {
        if self.no_cache {
            None
        } else if self.cache_dir.is_empty() {
            Some(format!("{}/cache", self.out_dir))
        } else {
            Some(self.cache_dir.clone())
        }
    }

    pub fn run_options(&self) -> crate::coordinator::RunOptions {
        crate::coordinator::RunOptions {
            seed: self.seed,
            pop_size: self.pop_size,
            generations: self.generations,
            margin_max: self.margin_max,
            engine: self.engine_choice(),
            microbatch: self.microbatch,
            // The shared cache and warm-start archive are process-level
            // resources wired up by the launcher (`run_all`), not here.
            ..crate::coordinator::RunOptions::default()
        }
    }

    pub fn to_json(&self) -> String {
        Json::obj(vec![
            ("seed", Json::num(self.seed as f64)),
            (
                "datasets",
                Json::Arr(self.datasets.iter().map(|d| Json::str(d.clone())).collect()),
            ),
            ("pop_size", Json::num(self.pop_size as f64)),
            ("generations", Json::num(self.generations as f64)),
            ("margin_max", Json::num(self.margin_max as f64)),
            ("engine", Json::str(self.engine.clone())),
            ("artifact_dir", Json::str(self.artifact_dir.clone())),
            ("threads", Json::num(self.threads as f64)),
            ("workers", Json::num(self.workers as f64)),
            ("coalesce", Json::str(self.coalesce.clone())),
            ("coalesce_window_us", Json::num(self.coalesce_window_us as f64)),
            (
                "coalesce_window_max_us",
                Json::num(self.coalesce_window_max_us as f64),
            ),
            ("respawn_shards", Json::Bool(self.respawn_shards)),
            ("microbatch", Json::num(self.microbatch as f64)),
            ("accuracy_loss", Json::num(self.accuracy_loss)),
            ("out_dir", Json::str(self.out_dir.clone())),
            ("cache_dir", Json::str(self.cache_dir.clone())),
            ("no_cache", Json::Bool(self.no_cache)),
            ("warm_start", Json::str(self.warm_start.clone())),
            ("trace_out", Json::str(self.trace_out.clone())),
            ("metrics_interval_ms", Json::num(self.metrics_interval_ms as f64)),
        ])
        .to_string()
    }

    pub fn from_json(text: &str) -> Result<RunConfig> {
        let j = Json::parse(text).context("parsing config json")?;
        let d = RunConfig::default();
        let get_num = |k: &str, dv: f64| j.get(k).and_then(Json::as_f64).unwrap_or(dv);
        let get_str =
            |k: &str, dv: &str| j.get(k).and_then(Json::as_str).unwrap_or(dv).to_string();
        let datasets = match j.get("datasets").and_then(Json::as_arr) {
            Some(arr) => arr
                .iter()
                .filter_map(|v| v.as_str().map(String::from))
                .collect(),
            None => d.datasets.clone(),
        };
        let cfg = RunConfig {
            seed: get_num("seed", d.seed as f64) as u64,
            datasets,
            pop_size: get_num("pop_size", d.pop_size as f64) as usize,
            generations: get_num("generations", d.generations as f64) as usize,
            margin_max: get_num("margin_max", d.margin_max as f64) as u32,
            engine: get_str("engine", &d.engine),
            artifact_dir: get_str("artifact_dir", &d.artifact_dir),
            threads: get_num("threads", d.threads as f64) as usize,
            workers: get_num("workers", d.workers as f64) as usize,
            coalesce: get_str("coalesce", &d.coalesce),
            coalesce_window_us: get_num("coalesce_window_us", d.coalesce_window_us as f64)
                as u64,
            coalesce_window_max_us: get_num(
                "coalesce_window_max_us",
                d.coalesce_window_max_us as f64,
            ) as u64,
            respawn_shards: j
                .get("respawn_shards")
                .and_then(Json::as_bool)
                .unwrap_or(d.respawn_shards),
            microbatch: get_num("microbatch", d.microbatch as f64) as usize,
            accuracy_loss: get_num("accuracy_loss", d.accuracy_loss),
            out_dir: get_str("out_dir", &d.out_dir),
            cache_dir: get_str("cache_dir", &d.cache_dir),
            no_cache: j
                .get("no_cache")
                .and_then(Json::as_bool)
                .unwrap_or(d.no_cache),
            warm_start: get_str("warm_start", &d.warm_start),
            trace_out: get_str("trace_out", &d.trace_out),
            metrics_interval_ms: get_num(
                "metrics_interval_ms",
                d.metrics_interval_ms as f64,
            ) as u64,
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::cli::{flag, opt, OptSpec};

    const SPEC: &[OptSpec] = &[
        opt("seed", ""),
        opt("datasets", ""),
        opt("pop", ""),
        opt("generations", ""),
        opt("margin", ""),
        opt("engine", ""),
        opt("artifacts", ""),
        opt("threads", ""),
        opt("workers", ""),
        opt("coalesce", ""),
        opt("coalesce-window-us", ""),
        opt("coalesce-window-max-us", ""),
        flag("respawn-shards", ""),
        opt("microbatch", ""),
        opt("loss", ""),
        opt("out", ""),
        opt("cache-dir", ""),
        flag("no-cache", ""),
        opt("warm-start", ""),
        opt("trace-out", ""),
        opt("metrics-interval-ms", ""),
        opt("config", ""),
        flag("verbose", ""),
    ];

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_are_valid() {
        RunConfig::default().validate().unwrap();
        assert_eq!(RunConfig::default().datasets.len(), 10);
    }

    #[test]
    fn cli_overrides_defaults() {
        let args = Args::parse(
            &sv(&["run", "--seed", "7", "--datasets", "seeds,cardio", "--engine", "native"]),
            SPEC,
        )
        .unwrap();
        let cfg = RunConfig::resolve(&args).unwrap();
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.datasets, sv(&["seeds", "cardio"]));
        assert_eq!(cfg.engine_choice(), EngineChoice::Native);
        assert_eq!(cfg.pop_size, 48, "untouched default");
    }

    #[test]
    fn json_round_trip() {
        let mut cfg = RunConfig::default();
        cfg.seed = 99;
        cfg.datasets = sv(&["har"]);
        cfg.engine = "native".into();
        let text = cfg.to_json();
        let back = RunConfig::from_json(&text).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut cfg = RunConfig::default();
        cfg.engine = "quantum".into();
        assert!(cfg.validate().is_err());
        let mut cfg2 = RunConfig::default();
        cfg2.datasets = sv(&["atlantis"]);
        assert!(cfg2.validate().is_err());
        let mut cfg3 = RunConfig::default();
        cfg3.pop_size = 2;
        assert!(cfg3.validate().is_err());
    }

    #[test]
    fn scaling_knobs_parse_validate_and_round_trip() {
        let args = Args::parse(
            &sv(&[
                "optimize",
                "--workers",
                "4",
                "--coalesce-window-us",
                "500",
                "--respawn-shards",
            ]),
            SPEC,
        )
        .unwrap();
        let cfg = RunConfig::resolve(&args).unwrap();
        assert_eq!(cfg.workers, 4);
        assert_eq!(cfg.coalesce_window_us, 500);
        assert!(cfg.respawn_shards);
        let back = RunConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(cfg, back);
        // Explicit workers and the respawn opt-in flow straight through to
        // the pool.
        let po = cfg.pool_options();
        assert_eq!(po.workers, 4);
        assert_eq!(po.coalesce_window_us, 500);
        assert!(po.respawn);
        // A config without the key keeps the default (off).
        assert!(!RunConfig::from_json("{}").unwrap().respawn_shards);

        // Auto sizing caps native workers at the dataset count.
        let mut auto = RunConfig::default();
        auto.engine = "native-service".into();
        auto.datasets = sv(&["seeds"]);
        assert_eq!(auto.pool_options().workers, 1);

        let mut bad = RunConfig::default();
        bad.workers = 100;
        assert!(bad.validate().is_err());
        let mut bad2 = RunConfig::default();
        bad2.coalesce_window_us = 2_000_000;
        assert!(bad2.validate().is_err());
    }

    /// The pipelined-eval knob: CLI parse, JSON round-trip, flow into
    /// `RunOptions`, and the absurd-value rejection.
    #[test]
    fn microbatch_knob_parses_round_trips_and_validates() {
        let d = RunConfig::default();
        assert_eq!(d.microbatch, 0, "auto by default");
        assert_eq!(d.run_options().microbatch, 0);

        let args = Args::parse(&sv(&["optimize", "--microbatch", "96"]), SPEC).unwrap();
        let cfg = RunConfig::resolve(&args).unwrap();
        assert_eq!(cfg.microbatch, 96);
        assert_eq!(cfg.run_options().microbatch, 96);
        let back = RunConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(cfg, back);
        // A config without the key keeps the auto default.
        assert_eq!(RunConfig::from_json("{}").unwrap().microbatch, 0);

        let mut bad = RunConfig::default();
        bad.microbatch = 2_000_000;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn coalesce_policy_knobs_parse_validate_and_round_trip() {
        // Defaults keep the PR 2 behavior: fixed-mode 200us window.
        let d = RunConfig::default();
        assert_eq!(d.coalesce, "fixed");
        assert_eq!(d.coalesce_mode(), CoalesceMode::Fixed);
        assert_eq!(d.coalesce_window_max_us, 1_000);

        let args = Args::parse(
            &sv(&[
                "optimize",
                "--coalesce",
                "adaptive",
                "--coalesce-window-max-us",
                "750",
            ]),
            SPEC,
        )
        .unwrap();
        let cfg = RunConfig::resolve(&args).unwrap();
        assert_eq!(cfg.coalesce_mode(), CoalesceMode::Adaptive);
        assert_eq!(cfg.coalesce_window_max_us, 750);
        let po = cfg.pool_options();
        assert_eq!(po.coalesce, CoalesceMode::Adaptive);
        assert_eq!(po.coalesce_window_max_us, 750);
        // JSON round-trips the policy; a config without the keys keeps
        // the defaults.
        let back = RunConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(cfg, back);
        let empty = RunConfig::from_json("{}").unwrap();
        assert_eq!(empty.coalesce_mode(), CoalesceMode::Fixed);
        assert_eq!(empty.coalesce_window_max_us, 1_000);

        // Unknown modes and absurd caps are rejected.
        let mut bad = RunConfig::default();
        bad.coalesce = "sometimes".into();
        assert!(bad.validate().is_err());
        assert!(RunConfig::from_json("{\"coalesce\": \"sometimes\"}").is_err());
        let mut bad2 = RunConfig::default();
        bad2.coalesce_window_max_us = 2_000_000;
        assert!(bad2.validate().is_err());
    }

    /// The observability knobs: CLI parse, JSON round-trip, off-by-default
    /// semantics, and interval validation.
    #[test]
    fn observability_knobs_parse_round_trip_and_validate() {
        let d = RunConfig::default();
        assert_eq!(d.trace_out, "", "tracing off by default");
        assert_eq!(d.metrics_interval_ms, 0, "snapshots off by default");

        let args = Args::parse(
            &sv(&[
                "optimize",
                "--trace-out",
                "/tmp/trace.json",
                "--metrics-interval-ms",
                "250",
            ]),
            SPEC,
        )
        .unwrap();
        let cfg = RunConfig::resolve(&args).unwrap();
        assert_eq!(cfg.trace_out, "/tmp/trace.json");
        assert_eq!(cfg.metrics_interval_ms, 250);
        let back = RunConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(cfg, back);
        // A config without the keys keeps both off.
        let empty = RunConfig::from_json("{}").unwrap();
        assert_eq!(empty.trace_out, "");
        assert_eq!(empty.metrics_interval_ms, 0);

        let mut bad = RunConfig::default();
        bad.metrics_interval_ms = 4_000_000;
        assert!(bad.validate().is_err());
    }

    /// The caching / warm-start knobs: CLI parse, JSON round-trip,
    /// off-by-default semantics, and `<out>/cache` auto-resolution.
    #[test]
    fn cache_knobs_parse_round_trip_and_resolve() {
        let d = RunConfig::default();
        assert_eq!(d.cache_dir, "", "auto by default");
        assert!(!d.no_cache, "persistent cache on by default");
        assert_eq!(d.warm_start, "", "warm-start off by default");
        assert_eq!(d.resolved_cache_dir().as_deref(), Some("results/cache"));

        let args = Args::parse(
            &sv(&[
                "optimize",
                "--cache-dir",
                "/tmp/axdt-cache",
                "--warm-start",
                "prev/runs.json",
            ]),
            SPEC,
        )
        .unwrap();
        let cfg = RunConfig::resolve(&args).unwrap();
        assert_eq!(cfg.cache_dir, "/tmp/axdt-cache");
        assert_eq!(cfg.warm_start, "prev/runs.json");
        assert_eq!(cfg.resolved_cache_dir().as_deref(), Some("/tmp/axdt-cache"));
        let back = RunConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(cfg, back);
        // A config without the keys keeps the defaults.
        let empty = RunConfig::from_json("{}").unwrap();
        assert_eq!(empty.cache_dir, "");
        assert!(!empty.no_cache);
        assert_eq!(empty.warm_start, "");

        // --no-cache kills persistence regardless of --cache-dir.
        let args = Args::parse(
            &sv(&["optimize", "--no-cache", "--cache-dir", "/tmp/x"]),
            SPEC,
        )
        .unwrap();
        let off = RunConfig::resolve(&args).unwrap();
        assert!(off.no_cache);
        assert_eq!(off.resolved_cache_dir(), None);
        let back = RunConfig::from_json(&off.to_json()).unwrap();
        assert_eq!(off, back);
    }

    #[test]
    fn datasets_all_keyword() {
        let args = Args::parse(&sv(&["--datasets", "all"]), SPEC).unwrap();
        let cfg = RunConfig::resolve(&args).unwrap();
        assert_eq!(cfg.datasets.len(), 10);
    }
}
