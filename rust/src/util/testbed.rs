//! Shared eval-service workload scaffolding for integration tests and
//! benches.
//!
//! Not `#[cfg(test)]`-gated (benches link the library normally), unlike
//! `fitness::testutil`.  Keeping this in one place matters because the
//! driver-name list encodes a routing contract the shard-pool tests and
//! `bench_shard` both depend on: the pinned FNV-1a route of these names
//! spreads them 2-per-shard over a 4-worker pool.
//!
//! The module also hosts the **panic-injection backend** behind
//! [`spawn_killable_native`]: the only way an out-of-crate failover test
//! (or bench) can kill a specific shard worker mid-run, since the
//! `Backend` trait is crate-private by design.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::shard::{Backend, EvalShardPool, PoolOptions, RegisteredProblem};
use crate::data::generators;
use crate::util::clock::{Clock, SystemClock};
use crate::dt::{train, TrainConfig};
use crate::fitness::native::NativeEngine;
use crate::fitness::{AccuracyEngine, Problem};
use crate::hw::synth::TreeApprox;
use crate::hw::{AreaLut, EgtLibrary};
use crate::quant;
use crate::util::rng::Pcg64;

/// 8 names whose pinned FNV-1a route spreads 2-per-shard over 4 workers
/// (shards 1,2,3,0,1,2,3,0) — the multi-driver workload for shard tests
/// and `bench_shard`.
pub const DRIVER_NAMES: [&str; 8] =
    ["drv0", "drv1", "drv2", "drv3", "drv4", "drv5", "drv6", "drv7"];

/// The seeds problem under a custom name, so hash-routing can be driven
/// deterministically (the route depends only on the name).
pub fn named_problem(name: &str) -> Arc<Problem> {
    let lib = EgtLibrary::default();
    let lut = AreaLut::build(&lib);
    let spec = generators::spec("seeds").expect("seeds dataset spec is registered");
    let data = generators::generate(spec, 42);
    let (train_d, test_d) = data.split(0.3, 42);
    let tree = train(
        &train_d,
        &TrainConfig { max_leaves: spec.max_leaves, min_samples_split: 2 },
    );
    Arc::new(Problem::new(name, tree, &test_d, &lut, &lib, 5))
}

/// Native backend that panics mid-eval when `kill` names its shard,
/// simulating a worker crash for the failover suites.
struct KillableBackend {
    engine: NativeEngine,
    width: usize,
    shard: usize,
    kill: Arc<AtomicU64>,
}

impl Backend for KillableBackend {
    fn register(&mut self, _problem: &Arc<Problem>) -> Result<RegisteredProblem> {
        Ok(RegisteredProblem::Native { width: self.width })
    }

    fn eval(
        &mut self,
        _reg: &RegisteredProblem,
        problem: &Problem,
        chunk: &[TreeApprox],
    ) -> Result<Vec<f64>> {
        // One-shot: clear the flag before panicking so a `--respawn-shards`
        // replacement worker is not immediately re-killed.
        if self
            .kill
            .compare_exchange(
                self.shard as u64 + 1,
                0,
                Ordering::SeqCst,
                Ordering::SeqCst,
            )
            .is_ok()
        {
            panic!("injected worker panic on shard {}", self.shard);
        }
        self.engine.batch_accuracy(problem, chunk)
    }

    fn name(&self) -> &'static str {
        "killable-native"
    }
}

/// Spawn a native pool whose workers can be killed one at a time: store
/// `shard + 1` into `kill` and the next eval dispatched to that shard
/// panics its backend (0 = kill nothing).  Everything else matches
/// [`EvalShardPool::spawn_native`] with `engine_threads` forced to 1, so
/// failover timing is not masked by intra-batch parallelism.
pub fn spawn_killable_native(
    width: usize,
    opts: &PoolOptions,
    kill: Arc<AtomicU64>,
) -> EvalShardPool {
    spawn_killable_native_with_clock(width, opts, kill, Arc::new(SystemClock::new()))
}

/// [`spawn_killable_native`] with an injected clock, so the failover
/// suites drive coalescing windows and deadline decisions from a
/// [`ManualClock`](crate::util::clock::ManualClock) instead of wall time.
pub fn spawn_killable_native_with_clock(
    width: usize,
    opts: &PoolOptions,
    kill: Arc<AtomicU64>,
    clock: Arc<dyn Clock>,
) -> EvalShardPool {
    let workers = opts.native_workers();
    EvalShardPool::spawn_with_clock(workers, opts.policy(), opts.respawn, clock, move |shard| {
        Ok(Box::new(KillableBackend {
            engine: NativeEngine::with_threads(1),
            width,
            shard,
            kill: Arc::clone(&kill),
        }) as Box<dyn Backend>)
    })
    .expect("killable native backend construction cannot fail")
}

/// Deterministically wait for an observable condition (a gauge, a
/// liveness flag) by yielding, never sleeping: the condition is driven by
/// another thread's bounded work, so this terminates without depending on
/// any wall-clock window.  Panics after an absurd number of yields so a
/// genuine bug fails the test instead of hanging it.
pub fn wait_until(what: &str, cond: impl Fn() -> bool) {
    for _ in 0..500_000_000u64 {
        if cond() {
            return;
        }
        std::thread::yield_now();
    }
    panic!("timed out waiting for: {what}");
}

/// `count` random mixed-precision approximations of `p`'s tree.
pub fn random_batch(p: &Problem, count: usize, seed: u64) -> Vec<TreeApprox> {
    let mut rng = Pcg64::seeded(seed);
    let n = p.n_comparators();
    (0..count)
        .map(|_| {
            let bits: Vec<u8> = (0..n).map(|_| rng.int_in(2, 8) as u8).collect();
            let thr_int: Vec<u32> = (0..n)
                .map(|j| quant::int_threshold(p.thresholds[j], bits[j]))
                .collect();
            TreeApprox { bits, thr_int }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scaffolding_is_deterministic() {
        let p = named_problem("x");
        assert_eq!(p.name, "x");
        let a = random_batch(&p, 4, 9);
        let b = random_batch(&p, 4, 9);
        assert_eq!(a.len(), 4);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.bits, y.bits);
            assert_eq!(x.thr_int, y.thr_int);
        }
    }
}
