//! Summary statistics for benches and experiment reports.

/// Streaming summary of a sample set.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    xs: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }
    pub fn from_slice(xs: &[f64]) -> Self {
        let mut s = Self::new();
        for &x in xs {
            s.push(x);
        }
        s
    }
    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
    }
    pub fn len(&self) -> usize {
        self.xs.len()
    }
    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }
    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }
    pub fn min(&self) -> f64 {
        self.xs.iter().copied().fold(f64::INFINITY, f64::min)
    }
    pub fn max(&self) -> f64 {
        self.xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }
    pub fn stddev(&self) -> f64 {
        if self.xs.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (self.xs.len() - 1) as f64)
            .sqrt()
    }
    /// Percentile via linear interpolation on the sorted sample (q in [0,1]).
    pub fn percentile(&self, q: f64) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        let mut s = self.xs.clone();
        s.sort_by(|a, b| a.total_cmp(b));
        let pos = q.clamp(0.0, 1.0) * (s.len() - 1) as f64;
        let i = pos.floor() as usize;
        let frac = pos - i as f64;
        if i + 1 < s.len() {
            s[i] * (1.0 - frac) + s[i + 1] * frac
        } else {
            s[i]
        }
    }
    pub fn median(&self) -> f64 {
        self.percentile(0.5)
    }
}

/// Geometric mean of strictly positive values (used for the paper's
/// "average area reduction" aggregates).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Pretty duration (ns → adaptive unit).
pub fn fmt_duration_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::from_slice(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.mean(), 2.5);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert_eq!(s.median(), 2.5);
        assert!((s.stddev() - 1.2909944).abs() < 1e-6);
    }

    #[test]
    fn percentile_interpolates() {
        let s = Summary::from_slice(&[0.0, 10.0]);
        assert_eq!(s.percentile(0.25), 2.5);
        assert_eq!(s.percentile(1.0), 10.0);
        assert_eq!(s.percentile(0.0), 0.0);
    }

    #[test]
    fn geomean_known() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration_ns(500.0), "500 ns");
        assert_eq!(fmt_duration_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_duration_ns(2.5e6), "2.50 ms");
        assert_eq!(fmt_duration_ns(3.1e9), "3.10 s");
    }
}
