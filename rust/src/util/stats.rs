//! Summary statistics for benches and experiment reports.

use std::sync::atomic::{AtomicU64, Ordering};

/// Streaming summary of a sample set.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    xs: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }
    pub fn from_slice(xs: &[f64]) -> Self {
        let mut s = Self::new();
        for &x in xs {
            s.push(x);
        }
        s
    }
    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
    }
    pub fn len(&self) -> usize {
        self.xs.len()
    }
    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }
    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }
    pub fn min(&self) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.xs.iter().copied().fold(f64::INFINITY, f64::min)
    }
    pub fn max(&self) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }
    pub fn stddev(&self) -> f64 {
        if self.xs.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (self.xs.len() - 1) as f64)
            .sqrt()
    }
    /// Percentile via linear interpolation on the sorted sample (q in [0,1]).
    ///
    /// Sorts per call — a batch of quantiles (a p50/p90/p99 report line)
    /// should use [`Self::percentiles`], which sorts once.
    pub fn percentile(&self, q: f64) -> f64 {
        self.percentiles(std::slice::from_ref(&q))[0]
    }

    /// A batch of percentiles answered from ONE sort of the sample —
    /// rendering p50/p90/p99 used to cost three O(n log n) clones+sorts.
    pub fn percentiles(&self, qs: &[f64]) -> Vec<f64> {
        if self.xs.is_empty() {
            return vec![f64::NAN; qs.len()];
        }
        let mut s = self.xs.clone();
        s.sort_by(|a, b| a.total_cmp(b));
        qs.iter().map(|&q| percentile_sorted(&s, q)).collect()
    }

    pub fn median(&self) -> f64 {
        self.percentile(0.5)
    }
}

/// Linear-interpolation percentile over an already-sorted non-empty slice.
fn percentile_sorted(s: &[f64], q: f64) -> f64 {
    let pos = q.clamp(0.0, 1.0) * (s.len() - 1) as f64;
    let i = pos.floor() as usize;
    let frac = pos - i as f64;
    if i + 1 < s.len() {
        s[i] * (1.0 - frac) + s[i + 1] * frac
    } else {
        s[i]
    }
}

/// Number of buckets in a [`Log2Histogram`]: one per possible bit length
/// of a `u64` sample, plus bucket 0 for the value 0.
pub const LOG2_BUCKETS: usize = 65;

/// Fixed-size log₂-bucketed histogram for hot-path latency/width metrics.
///
/// Bucket `b` holds samples of bit length `b` (bucket 0 holds only the
/// value 0, bucket 1 holds 1, bucket 2 holds 2–3, …), so `record` is a
/// single relaxed atomic increment — no lock, no allocation, bounded
/// memory regardless of sample count.  Unlike [`Summary`] (which buffers
/// every sample in a `Vec<f64>`), a `Log2Histogram` survives
/// millions-of-samples service traffic; the price is that percentiles
/// are interpolated within a power-of-two bucket instead of exact.
/// The true maximum is tracked exactly, and percentile estimates are
/// clamped to it.
#[derive(Debug)]
pub struct Log2Histogram {
    buckets: [AtomicU64; LOG2_BUCKETS],
    max: AtomicU64,
}

impl Default for Log2Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Log2Histogram {
    pub fn new() -> Self {
        Log2Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            max: AtomicU64::new(0),
        }
    }

    /// Bucket index of a sample: its bit length.
    #[inline]
    fn bucket_of(v: u64) -> usize {
        (64 - v.leading_zeros()) as usize
    }

    /// Record one sample.  Lock-free; callable from any worker thread.
    #[inline]
    pub fn record(&self, v: u64) {
        self.buckets[Self::bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// Point-in-time copy of the bucket counts + exact max.  All reads
    /// below go through a snapshot so count/percentiles/max are mutually
    /// consistent even while workers keep recording.
    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            buckets: std::array::from_fn(|b| self.buckets[b].load(Ordering::Relaxed)),
            max: self.max.load(Ordering::Relaxed),
        }
    }

    pub fn count(&self) -> u64 {
        self.snapshot().count()
    }

    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Percentile estimate (q in [0,1]); 0 when empty.
    pub fn percentile(&self, q: f64) -> u64 {
        self.snapshot().percentile(q)
    }
}

/// Owned, immutable read of a [`Log2Histogram`].
#[derive(Debug, Clone)]
pub struct HistogramSnapshot {
    buckets: [u64; LOG2_BUCKETS],
    /// Exact maximum recorded sample.
    pub max: u64,
}

impl HistogramSnapshot {
    /// Total recorded samples.
    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }

    /// Percentile estimate (q in [0,1]) by linear interpolation inside
    /// the covering bucket's `[2^(b-1), 2^b)` range, clamped to the
    /// exact max; 0 when empty.
    pub fn percentile(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let rank = (q.clamp(0.0, 1.0) * (total - 1) as f64).round() as u64;
        if rank == total - 1 {
            // The top rank is the exact maximum — no interpolation.
            return self.max;
        }
        let mut cum = 0u64;
        for (b, &c) in self.buckets.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if cum + c > rank {
                let lo = if b == 0 { 0 } else { 1u64 << (b - 1) };
                let hi = if b == 0 {
                    0
                } else if b == 64 {
                    u64::MAX
                } else {
                    (1u64 << b) - 1
                };
                let frac = (rank - cum) as f64 / c as f64;
                let est = lo as f64 + frac * (hi - lo) as f64;
                return (est as u64).min(self.max);
            }
            cum += c;
        }
        self.max
    }

    pub fn p50(&self) -> u64 {
        self.percentile(0.50)
    }
    pub fn p90(&self) -> u64 {
        self.percentile(0.90)
    }
    pub fn p99(&self) -> u64 {
        self.percentile(0.99)
    }
}

/// Geometric mean of strictly positive values (used for the paper's
/// "average area reduction" aggregates).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Pretty duration (ns → adaptive unit).
pub fn fmt_duration_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::from_slice(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.mean(), 2.5);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert_eq!(s.median(), 2.5);
        assert!((s.stddev() - 1.2909944).abs() < 1e-6);
    }

    #[test]
    fn empty_summary_is_nan_everywhere() {
        // mean/min/max must agree on "no data": all NaN, never ±INFINITY
        // (an empty latency summary used to render min=inf, max=-inf).
        let s = Summary::new();
        assert!(s.mean().is_nan());
        assert!(s.min().is_nan());
        assert!(s.max().is_nan());
        assert!(s.percentile(0.5).is_nan());
    }

    #[test]
    fn log2_histogram_buckets_and_percentiles() {
        let h = Log2Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.max(), 0);
        assert_eq!(h.percentile(0.5), 0);
        for v in 1..=100u64 {
            h.record(v);
        }
        assert_eq!(h.count(), 100);
        assert_eq!(h.max(), 100);
        // p50 of 1..=100 is ~50; log2 buckets land it inside [32,64).
        let p50 = h.percentile(0.50);
        assert!((32..64).contains(&p50), "p50 {p50}");
        // p99 interpolates inside the top bucket but never exceeds max.
        let p99 = h.percentile(0.99);
        assert!(p99 <= 100 && p99 >= 64, "p99 {p99}");
        assert_eq!(h.percentile(1.0), 100);
        assert_eq!(h.percentile(0.0), 1);
    }

    #[test]
    fn log2_histogram_edges() {
        let h = Log2Histogram::new();
        h.record(0);
        h.record(u64::MAX);
        assert_eq!(h.count(), 2);
        assert_eq!(h.max(), u64::MAX);
        assert_eq!(h.percentile(0.0), 0);
        assert_eq!(h.percentile(1.0), u64::MAX);
        let snap = h.snapshot();
        assert_eq!(snap.count(), 2);
        assert_eq!(snap.max, u64::MAX);
    }

    #[test]
    fn percentile_interpolates() {
        let s = Summary::from_slice(&[0.0, 10.0]);
        assert_eq!(s.percentile(0.25), 2.5);
        assert_eq!(s.percentile(1.0), 10.0);
        assert_eq!(s.percentile(0.0), 0.0);
    }

    #[test]
    fn percentiles_batch_matches_singles() {
        let s = Summary::from_slice(&[5.0, 1.0, 3.0, 2.0, 4.0]);
        let qs = [0.0, 0.25, 0.5, 0.99, 1.0];
        let batch = s.percentiles(&qs);
        for (&q, &got) in qs.iter().zip(&batch) {
            assert_eq!(got, s.percentile(q), "q={q}");
        }
        assert!(Summary::new().percentiles(&qs).iter().all(|x| x.is_nan()));
        assert!(Summary::new().percentiles(&[]).is_empty());
    }

    #[test]
    fn geomean_known() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration_ns(500.0), "500 ns");
        assert_eq!(fmt_duration_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_duration_ns(2.5e6), "2.50 ms");
        assert_eq!(fmt_duration_ns(3.1e9), "3.10 s");
    }
}
