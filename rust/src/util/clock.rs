//! Injectable time: the seam that makes every coalescer/failover deadline
//! deterministic under test.
//!
//! The shard workers (`coordinator::shard`) never call `Instant::now()`
//! directly; they read a [`Clock`].  Production pools use [`SystemClock`]
//! (virtual time IS real time).  Tests use [`ManualClock`], whose time
//! only moves when the test calls [`ManualClock::advance`] — so a test can
//! queue sub-width work, advance past the coalescing window, and observe
//! the deadline flush without a single `thread::sleep`.
//!
//! # How waiting works
//!
//! A worker that has an armed deadline blocks in `recv_timeout` on its
//! message channel with a real-time budget obtained from
//! [`Clock::wait_budget`]:
//!
//! * [`SystemClock`] returns the remaining real duration, so the timeout
//!   fires exactly when the deadline passes — the pre-clock behavior.
//! * [`ManualClock`] returns an hour: virtual deadlines cannot expire on
//!   their own.  Instead, [`ManualClock::advance`] runs the wakers the
//!   pool registered at spawn ([`Clock::register_waker`]), each of which
//!   nudges its worker with a no-op message.  The woken worker re-reads
//!   the clock and flushes whatever is now expired.  Wakeups are never
//!   lost because they are *messages*, not condvar signals: a waker firing
//!   before the worker blocks simply leaves the nudge queued.
//!
//! Virtual time is a monotone `u64` nanosecond count from the clock's
//! epoch; it never goes backwards.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::util::sync::lock_recover;

/// Callback a clock runs after every virtual-time advance (used by pools
/// to nudge workers that are blocked waiting for a deadline).
pub type Waker = Box<dyn Fn() + Send + Sync>;

/// A source of monotone virtual time, injectable into the eval pool.
pub trait Clock: Send + Sync {
    /// Nanoseconds since this clock's epoch.  Monotone.
    fn now_ns(&self) -> u64;

    /// Real-time cap on how long a worker may block waiting for messages
    /// before it must re-check `deadline_ns` against [`Clock::now_ns`].
    fn wait_budget(&self, deadline_ns: u64) -> Duration;

    /// Register a waker to run after every virtual-time advance.  No-op
    /// for clocks whose time advances on its own.
    fn register_waker(&self, waker: Waker);
}

/// Production clock: virtual time is real monotonic time.
pub struct SystemClock {
    epoch: Instant,
}

impl SystemClock {
    pub fn new() -> SystemClock {
        SystemClock { epoch: Instant::now() }
    }
}

impl Default for SystemClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for SystemClock {
    fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    fn wait_budget(&self, deadline_ns: u64) -> Duration {
        Duration::from_nanos(deadline_ns.saturating_sub(self.now_ns()))
    }

    fn register_waker(&self, _waker: Waker) {
        // Real time advances without help; deadline timeouts fire on the
        // channel wait itself.
    }
}

/// Step-controlled test clock: time moves only on [`ManualClock::advance`].
///
/// Waiters are woken through the registered wakers, so a test drives the
/// whole timing surface deterministically:
///
/// ```text
/// queue sub-width batch  →  wait for it to reach the coalescer (gauge)
/// clock.advance(window)  →  worker wakes, sees the deadline expired,
///                            flushes; the blocked client returns
/// ```
pub struct ManualClock {
    now: AtomicU64,
    wakers: Mutex<Vec<Waker>>,
}

impl ManualClock {
    pub fn new() -> ManualClock {
        ManualClock { now: AtomicU64::new(0), wakers: Mutex::new(Vec::new()) }
    }

    /// Advance virtual time by `d` and run every registered waker.
    pub fn advance(&self, d: Duration) {
        self.now.fetch_add(d.as_nanos() as u64, Ordering::SeqCst);
        let wakers = lock_recover(&self.wakers);
        for w in wakers.iter() {
            w();
        }
    }
}

impl Default for ManualClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for ManualClock {
    fn now_ns(&self) -> u64 {
        self.now.load(Ordering::SeqCst)
    }

    fn wait_budget(&self, deadline_ns: u64) -> Duration {
        if deadline_ns <= self.now_ns() {
            // Already expired: the caller should re-check immediately.
            Duration::ZERO
        } else {
            // Virtual deadlines only move on `advance`, which wakes the
            // waiter through its waker; the hour is a missed-wakeup
            // safety net, never the signaling path.
            Duration::from_secs(3600)
        }
    }

    fn register_waker(&self, waker: Waker) {
        lock_recover(&self.wakers).push(waker);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::sync::Arc;

    #[test]
    fn system_clock_is_monotone_and_budget_shrinks() {
        let c = SystemClock::new();
        let a = c.now_ns();
        let b = c.now_ns();
        assert!(b >= a);
        // A deadline in the past yields a zero budget, not an underflow.
        assert_eq!(c.wait_budget(0), Duration::ZERO);
        // A future deadline yields at most its distance.
        let deadline = c.now_ns() + 1_000_000_000;
        assert!(c.wait_budget(deadline) <= Duration::from_secs(1));
    }

    #[test]
    fn manual_clock_moves_only_on_advance_and_runs_wakers() {
        let c = ManualClock::new();
        assert_eq!(c.now_ns(), 0);
        let hits = Arc::new(AtomicUsize::new(0));
        let h = Arc::clone(&hits);
        c.register_waker(Box::new(move || {
            h.fetch_add(1, Ordering::SeqCst);
        }));
        c.advance(Duration::from_micros(250));
        assert_eq!(c.now_ns(), 250_000);
        assert_eq!(hits.load(Ordering::SeqCst), 1);
        c.advance(Duration::from_nanos(1));
        assert_eq!(c.now_ns(), 250_001);
        assert_eq!(hits.load(Ordering::SeqCst), 2);
        // Expired deadlines ask for an immediate re-check; armed ones for
        // the safety-net hour.
        assert_eq!(c.wait_budget(250_001), Duration::ZERO);
        assert_eq!(c.wait_budget(250_002), Duration::from_secs(3600));
    }
}
