//! Infrastructure substrates.
//!
//! This image's crate registry is offline and ships only the `xla` crate's
//! dependency closure, so the usual ecosystem crates (rand, serde, clap,
//! rayon, criterion, proptest) are unavailable.  Everything the framework
//! needs from them is implemented here, small and fully tested:
//!
//! * [`clock`] — injectable time ([`clock::SystemClock`] /
//!   step-controlled [`clock::ManualClock`]) so every eval-pool deadline
//!   decision is deterministic under test.
//! * [`rng`] — deterministic PCG64 PRNG + distributions.
//! * [`json`] — minimal JSON value model, parser and writer (artifact
//!   metadata, config files, experiment reports).
//! * [`cli`] — declarative command-line parsing for the `axdt` launcher.
//! * [`fsx`] — atomic tmp+rename file writes (`runs.json`, trace
//!   exports, `BENCH_*.json`).
//! * [`pool`] — scoped parallel-map helpers with dynamic work claiming
//!   (chunk queue for `par_map`, atomic next-index work stealing for
//!   `par_for_each_indexed`).
//! * [`stats`] — summary statistics used by benches and reports, plus
//!   the bounded [`stats::Log2Histogram`] behind the service's hot-path
//!   latency percentiles.
//! * [`trace`] — the ticket-lifecycle event journal
//!   ([`trace::TraceJournal`]): bounded drop-oldest ring of typed
//!   events with clock-seam timestamps, exported as Chrome trace-event
//!   JSON for Perfetto.
//! * [`sync`] — poison-recovering mutex helpers ([`sync::lock_recover`]),
//!   the only sanctioned way to take a lock in `rust/src` (enforced by
//!   `axdt-lint`'s `mutex-discipline` rule).
//! * [`prop`] — a tiny property-testing harness (seeded generators, failure
//!   reporting with the reproducing seed).
//! * [`bench`] — a criterion-shaped benchmark harness (warmup, timed
//!   iterations, mean/p50/p99 reporting) used by `cargo bench`.
//! * [`testbed`] — shared eval-service workload scaffolding (named
//!   problems, random approximation batches) for integration tests and
//!   benches.

pub mod bench;
pub mod cli;
pub mod clock;
pub mod fsx;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod sync;
pub mod testbed;
pub mod trace;
