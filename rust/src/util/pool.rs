//! Scoped parallel-map helpers over std threads (rayon is not vendored).
//!
//! Two entry points, both *dynamically* scheduled (neither statically
//! pre-assigns work to a worker):
//! * [`par_map`] — parallel map over contiguous chunks that idle workers
//!   claim from a shared queue; preserves input order.
//! * [`par_for_each_indexed`] — work-stealing index loop (each worker
//!   atomically claims the next index) for irregular workloads (netlist
//!   synthesis time varies with threshold).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::util::sync::lock_recover;

/// Number of worker threads to use: `AXDT_THREADS` env override, else
/// available parallelism, clamped to [1, 64].
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("AXDT_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.clamp(1, 64);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .clamp(1, 64)
}

/// Parallel map preserving order. `f` must be `Sync`; items are split into
/// `threads` contiguous chunks that workers claim dynamically from a shared
/// queue, so a slow chunk cannot strand the unclaimed ones behind one
/// worker.
pub fn par_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        return items.iter().map(&f).collect();
    }
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let out_slices = Mutex::new(
        out.chunks_mut(n.div_ceil(threads))
            .enumerate()
            .collect::<Vec<_>>(),
    );
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let taken = lock_recover(&out_slices).pop();
                match taken {
                    None => break,
                    Some((chunk_idx, slot)) => {
                        let chunk = n.div_ceil(threads);
                        let start = chunk_idx * chunk;
                        for (j, s) in slot.iter_mut().enumerate() {
                            *s = Some(f(&items[start + j]));
                        }
                    }
                }
            });
        }
    });
    out.into_iter().map(|o| o.expect("par_map slot unfilled")).collect()
}

/// Work-stealing index loop: each worker repeatedly claims the next index.
pub fn par_for_each_indexed<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    if n == 0 {
        return;
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn par_map_preserves_order() {
        let xs: Vec<u64> = (0..1000).collect();
        for threads in [1, 2, 3, 8] {
            let ys = par_map(&xs, threads, |&x| x * x);
            assert_eq!(ys, xs.iter().map(|&x| x * x).collect::<Vec<_>>(), "t={threads}");
        }
    }

    #[test]
    fn par_map_empty_and_single() {
        let e: Vec<u32> = vec![];
        assert!(par_map(&e, 4, |&x| x).is_empty());
        assert_eq!(par_map(&[5u32], 4, |&x| x + 1), vec![6]);
    }

    #[test]
    fn par_for_each_covers_all_indices_once() {
        let n = 10_000;
        let counters: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        par_for_each_indexed(n, 8, |i| {
            counters[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(counters.iter().all(|c| c.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn default_threads_positive() {
        assert!(default_threads() >= 1);
    }
}
