//! Deterministic PRNG: PCG64 (XSL-RR variant) plus the distributions the
//! framework needs (uniform ints/floats, normal via Box–Muller, shuffles,
//! weighted choice).  `rand` is not vendored in this image; reproducibility
//! of every experiment is seeded through this module.

/// PCG-XSL-RR-128/64: 128-bit LCG state, 64-bit xorshift-rotate output.
///
/// Constants from the PCG reference implementation (pcg64).
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Pcg64 {
    /// Seed with a stream id; distinct `(seed, stream)` pairs give
    /// independent sequences (used to give GA islands / workers their own
    /// streams while keeping a single experiment-level seed).
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = (((stream as u128) << 64 | 0xda3e_39cb_94b9_5bdb) << 1) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Convenience: stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Derive a child RNG for a named sub-task; deterministic in
    /// `(parent state not consumed, tag)`.
    pub fn fork(&self, tag: u64) -> Self {
        // Hash the tag into both seed and stream so forks are independent.
        let h = splitmix64(tag ^ 0x9e37_79b9_7f4a_7c15);
        Self::new(h ^ (self.inc as u64), splitmix64(h))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in `[0, n)`. Uses Lemire's rejection method (unbiased).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    #[inline]
    pub fn int_in(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Uniform f64 in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (no cached spare: keeps state simple
    /// and fork-safe; cost is fine for data generation).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with mean/σ.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Index sampled proportionally to non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted(): all-zero weights");
        let mut t = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// `k` distinct indices from `0..n` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

/// SplitMix64 — used for seed derivation only.
#[inline]
pub fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Stable 64-bit FNV-1a hash (chromosome fitness-cache keys).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

/// Stable 128-bit FNV-1a hash (offset basis / prime from the FNV spec).
///
/// Cache keys that outlive a process (the persistent accuracy cache) ride
/// this instead of [`fnv1a`]: at 64 bits a few million distinct phenotypes
/// give a birthday-collision probability that is small but not *service*
/// small, and a collision silently serves one phenotype the other's
/// objectives. 128 bits puts that off the table.
pub fn fnv1a128(bytes: &[u8]) -> u128 {
    let mut h: u128 = 0x6c62_272e_07bb_0142_62b8_2175_6295_c58d;
    for &b in bytes {
        h ^= b as u128;
        h = h.wrapping_mul(0x0000_0000_0100_0000_0000_0000_0000_013b);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_stream_independent() {
        let mut a = Pcg64::new(42, 1);
        let mut b = Pcg64::new(42, 1);
        let mut c = Pcg64::new(42, 2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut rng = Pcg64::seeded(7);
        let n = 10u64;
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[rng.below(n) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "bucket {c}");
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Pcg64::seeded(3);
        for _ in 0..10_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seeded(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seeded(5);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Pcg64::seeded(9);
        for _ in 0..100 {
            let idx = rng.sample_indices(20, 7);
            let mut s = idx.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), 7);
        }
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut rng = Pcg64::seeded(13);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[rng.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > 8 * counts[0] / 2);
    }

    #[test]
    fn int_in_covers_bounds() {
        let mut rng = Pcg64::seeded(17);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            let v = rng.int_in(-5, 5);
            assert!((-5..=5).contains(&v));
            seen_lo |= v == -5;
            seen_hi |= v == 5;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn fnv_stability() {
        assert_eq!(fnv1a(b""), 0xcbf29ce484222325);
        assert_ne!(fnv1a(b"a"), fnv1a(b"b"));
    }

    #[test]
    fn fnv128_stability() {
        // Offset basis for the empty input, and the spec's test vector
        // property that single-byte inputs are all distinct.
        assert_eq!(fnv1a128(b""), 0x6c62272e07bb014262b821756295c58d);
        assert_ne!(fnv1a128(b"a"), fnv1a128(b"b"));
        // The 128-bit hash must not be a widening of the 64-bit one.
        assert_ne!(fnv1a128(b"axdt") as u64, fnv1a(b"axdt"));
    }

    #[test]
    fn fork_independence() {
        let base = Pcg64::seeded(1);
        let mut f1 = base.fork(1);
        let mut f2 = base.fork(2);
        assert_ne!(
            (0..4).map(|_| f1.next_u64()).collect::<Vec<_>>(),
            (0..4).map(|_| f2.next_u64()).collect::<Vec<_>>()
        );
    }
}
