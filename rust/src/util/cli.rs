//! Declarative CLI parsing for the `axdt` launcher (clap is not vendored).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value`, typed
//! accessors with defaults, and auto-generated usage text.

use std::collections::BTreeMap;

/// Parsed arguments of one (sub)command invocation.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub command: Vec<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

#[derive(Debug)]
pub enum CliError {
    Unknown(String),
    MissingValue(String),
    BadValue { key: String, value: String, why: String },
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::Unknown(name) => write!(f, "unknown option --{name}"),
            CliError::MissingValue(name) => write!(f, "option --{name} expects a value"),
            CliError::BadValue { key, value, why } => {
                write!(f, "invalid value for --{key}: {value:?} ({why})")
            }
        }
    }
}

impl std::error::Error for CliError {}

/// Option specification used for validation + help.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub takes_value: bool,
    pub help: &'static str,
}

pub const fn opt(name: &'static str, help: &'static str) -> OptSpec {
    OptSpec { name, takes_value: true, help }
}
pub const fn flag(name: &'static str, help: &'static str) -> OptSpec {
    OptSpec { name, takes_value: false, help }
}

impl Args {
    /// Parse `argv[1..]` against a spec. The first non-option tokens (before
    /// any `--key`) are the subcommand path; later bare tokens are
    /// positionals.
    pub fn parse(argv: &[String], spec: &[OptSpec]) -> Result<Args, CliError> {
        let mut args = Args::default();
        let mut i = 0;
        let mut seen_opt = false;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(rest) = tok.strip_prefix("--") {
                seen_opt = true;
                let (key, inline_val) = match rest.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                let s = spec
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| CliError::Unknown(key.clone()))?;
                if s.takes_value {
                    let v = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| CliError::MissingValue(key.clone()))?
                        }
                    };
                    args.opts.insert(key, v);
                } else {
                    args.flags.push(key);
                }
            } else if !seen_opt && args.positional.is_empty() {
                args.command.push(tok.clone());
            } else {
                args.positional.push(tok.clone());
            }
            i += 1;
        }
        Ok(args)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }
    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize, CliError> {
        self.parse_or(name, default)
    }
    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64, CliError> {
        self.parse_or(name, default)
    }
    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64, CliError> {
        self.parse_or(name, default)
    }
    pub fn i64_or(&self, name: &str, default: i64) -> Result<i64, CliError> {
        self.parse_or(name, default)
    }

    fn parse_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, CliError>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse::<T>().map_err(|e| CliError::BadValue {
                key: name.to_string(),
                value: v.to_string(),
                why: e.to_string(),
            }),
        }
    }

    /// Comma-separated list option.
    pub fn list_or(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.get(name) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(v) => v.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect(),
        }
    }
}

/// Render a usage block for `--help`.
pub fn usage(program: &str, commands: &[(&str, &str)], spec: &[OptSpec]) -> String {
    let mut s = format!("usage: {program} <command> [options]\n\ncommands:\n");
    for (c, h) in commands {
        s.push_str(&format!("  {c:<18} {h}\n"));
    }
    s.push_str("\noptions:\n");
    for o in spec {
        let name = if o.takes_value {
            format!("--{} <v>", o.name)
        } else {
            format!("--{}", o.name)
        };
        // 30 columns: fits the longest current option
        // (`--coalesce-window-max-us <v>`) without ragged help text.
        s.push_str(&format!("  {name:<30} {}\n", o.help));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    const SPEC: &[OptSpec] = &[
        opt("seed", "rng seed"),
        opt("datasets", "comma list"),
        flag("verbose", "talk more"),
    ];

    #[test]
    fn parses_subcommand_opts_flags() {
        let a = Args::parse(
            &sv(&["repro", "table1", "--seed", "42", "--verbose", "--datasets=seeds,cardio"]),
            SPEC,
        )
        .unwrap();
        assert_eq!(a.command, sv(&["repro", "table1"]));
        assert_eq!(a.u64_or("seed", 0).unwrap(), 42);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.list_or("datasets", &[]), sv(&["seeds", "cardio"]));
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(matches!(
            Args::parse(&sv(&["--nope"]), SPEC),
            Err(CliError::Unknown(_))
        ));
    }

    #[test]
    fn missing_value_rejected() {
        assert!(matches!(
            Args::parse(&sv(&["--seed"]), SPEC),
            Err(CliError::MissingValue(_))
        ));
    }

    #[test]
    fn bad_typed_value() {
        let a = Args::parse(&sv(&["--seed", "abc"]), SPEC).unwrap();
        assert!(a.u64_or("seed", 0).is_err());
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&sv(&["run"]), SPEC).unwrap();
        assert_eq!(a.u64_or("seed", 7).unwrap(), 7);
        assert_eq!(a.str_or("datasets", "all"), "all");
        assert!(!a.has_flag("verbose"));
    }

    #[test]
    fn positionals_after_options() {
        let a = Args::parse(&sv(&["export", "--seed", "1", "out.v"]), SPEC).unwrap();
        assert_eq!(a.command, sv(&["export"]));
        assert_eq!(a.positional, sv(&["out.v"]));
    }
}
