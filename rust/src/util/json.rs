//! Minimal JSON: value model, recursive-descent parser, writer.
//!
//! Used for `artifacts/meta.json` (written by the python AOT path), config
//! files, and experiment reports.  Supports the full JSON grammar except
//! `\u` surrogate pairs outside the BMP are passed through unvalidated.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use a BTreeMap for deterministic output.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Builder helpers.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for ch in s.chars() {
        match ch {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }
    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }
    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }
    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos -= usize::from(self.pos > 0);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }
    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad \\u"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Multi-byte UTF-8: copy the remaining continuation bytes.
                    let len = if c >= 0xf0 {
                        4
                    } else if c >= 0xe0 {
                        3
                    } else {
                        2
                    };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump().ok_or_else(|| self.err("truncated utf-8"))?;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Json::Bool(false)));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"buckets":{"small":{"n":64,"s":256}},"names":["a","b"],"x":1.25}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse("\"\\u00e9\\t\\\\\"").unwrap();
        assert_eq!(v, Json::Str("é\t\\".into()));
        let v2 = Json::parse("\"héllo\"").unwrap();
        assert_eq!(v2, Json::Str("héllo".into()));
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
    }

    #[test]
    fn parses_real_meta_shape() {
        let src = r#"{"tile_s": 128, "input_names": ["xsel"], "buckets":
            {"small": {"s": 256, "n": 64, "l": 64, "c": 16, "p": 32,
                       "file": "dt_eval_small.hlo.txt"}}}"#;
        let v = Json::parse(src).unwrap();
        let b = v.get("buckets").unwrap().get("small").unwrap();
        assert_eq!(b.get("s").unwrap().as_usize(), Some(256));
        assert_eq!(b.get("file").unwrap().as_str(), Some("dt_eval_small.hlo.txt"));
    }
}
