//! Ticket-lifecycle tracing: a clock-seam event journal.
//!
//! A [`TraceJournal`] is a bounded, drop-oldest ring buffer of typed
//! [`TraceEvent`]s covering the whole two-phase eval path — ticket
//! submitted / enqueued / coalesced / flushed / executing / executed /
//! collected, shard death and respawn, plus the driver-side GA spans
//! (dataset, GA phase, generation, synthesis).  Design rules:
//!
//! * **Off by default, cheap when off.**  Every producer guards its
//!   `record` call with [`TraceJournal::enabled`] — one relaxed atomic
//!   load — so a disabled journal costs nothing measurable on the
//!   eval hot path.
//! * **Bounded, never backpressuring.**  The ring holds a fixed
//!   capacity; when full, the *oldest* event is dropped and counted
//!   ([`TraceJournal::dropped`]).  A slow or absent consumer can never
//!   block a shard worker.
//! * **Clock-seam timestamps.**  This module never reads time itself:
//!   every event's `ts_ns` is passed in by a call site that already
//!   holds the injected [`crate::util::clock::Clock`].  On
//!   `ManualClock` whole traces are therefore bit-reproducible —
//!   pinned by `rust/tests/trace.rs`.
//! * **Sequence numbers.**  Events carry a global `seq` assigned under
//!   the ring lock, so concurrent shard threads' events have a total
//!   order to sort and diff on.
//!
//! [`chrome_trace_json`] renders a drained event list as Chrome
//! trace-event JSON (one track per shard, one per registered driver),
//! viewable in Perfetto / `chrome://tracing`.

use std::collections::VecDeque;
use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::json::Json;
use crate::util::sync::lock_recover;

/// Default ring capacity (events).  At ~80 bytes/event this bounds the
/// journal at a few MB regardless of run length.
pub const DEFAULT_CAPACITY: usize = 65_536;

/// One trace event: a global sequence number, a clock-seam timestamp,
/// and the typed payload.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub seq: u64,
    pub ts_ns: u64,
    pub kind: TraceKind,
}

/// The typed event payload.  Ticket-lifecycle variants are
/// allocation-free (the hot path); driver spans carry a name.
#[derive(Debug, Clone, PartialEq)]
pub enum TraceKind {
    /// Client side: a ticket was issued for `width` chromosomes routed
    /// to `shard`.
    Submitted { shard: u32, problem: u32, width: u32 },
    /// Worker side: the request left the channel and entered the
    /// coalescer.
    Enqueued { shard: u32, problem: u32 },
    /// Worker side: the request merged into its problem group
    /// (`pending` = group depth after the merge).
    Coalesced { shard: u32, problem: u32, pending: u32 },
    /// Worker side: a group flushed (`kind` = the `FlushKind` label,
    /// `width` = real chromosomes in the flush).
    Flushed { shard: u32, problem: u32, kind: &'static str, width: u32 },
    /// Worker side: the backend call is starting.
    Executing { shard: u32, problem: u32, width: u32 },
    /// Worker side: the backend call finished after `dur_ns`.
    Executed { shard: u32, problem: u32, width: u32, dur_ns: u64 },
    /// Client side: a ticket was redeemed, `latency_ns` after submit.
    Collected { shard: u32, latency_ns: u64 },
    /// A shard worker died (panicking backend).
    ShardDown { shard: u32 },
    /// A dead shard was respawned from the retained factory.
    Respawn { shard: u32 },
    /// Driver side: a named span opened on a driver track (dataset,
    /// ga, generation, synthesis).
    SpanBegin { track: u32, name: String },
    /// Driver side: the most recent same-named span on `track` closed.
    SpanEnd { track: u32, name: String },
    /// Eval-cache lookup satisfied by tier 1 (shared in-memory) or
    /// tier 2 (loaded from disk).  The per-run memo (L0) is not traced —
    /// it never leaves one evaluator.
    CacheHit { tier: u8 },
    /// Eval-cache lookup missed every shared tier; the phenotype will
    /// cost a ticket through the submit/collect seam.
    CacheMiss,
    /// `records` fresh cache entries were appended to their segment
    /// files (end of run).
    CacheSpill { records: u64 },
    /// The L2 tier was replayed at startup: `records` entries loaded,
    /// `errors` corrupt/torn tails skipped.
    CacheLoad { records: u64, errors: u64 },
}

impl fmt::Display for TraceEvent {
    /// Canonical one-line form, the unit of the byte-identity
    /// determinism test.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "seq={} ts={} ", self.seq, self.ts_ns)?;
        match &self.kind {
            TraceKind::Submitted { shard, problem, width } => {
                write!(f, "submitted shard={shard} problem={problem} width={width}")
            }
            TraceKind::Enqueued { shard, problem } => {
                write!(f, "enqueued shard={shard} problem={problem}")
            }
            TraceKind::Coalesced { shard, problem, pending } => {
                write!(f, "coalesced shard={shard} problem={problem} pending={pending}")
            }
            TraceKind::Flushed { shard, problem, kind, width } => {
                write!(f, "flushed({kind}) shard={shard} problem={problem} width={width}")
            }
            TraceKind::Executing { shard, problem, width } => {
                write!(f, "executing shard={shard} problem={problem} width={width}")
            }
            TraceKind::Executed { shard, problem, width, dur_ns } => {
                write!(f, "executed shard={shard} problem={problem} width={width} dur={dur_ns}")
            }
            TraceKind::Collected { shard, latency_ns } => {
                write!(f, "collected shard={shard} latency={latency_ns}")
            }
            TraceKind::ShardDown { shard } => write!(f, "shard-down shard={shard}"),
            TraceKind::Respawn { shard } => write!(f, "respawn shard={shard}"),
            TraceKind::SpanBegin { track, name } => {
                write!(f, "span-begin track={track} name={name}")
            }
            TraceKind::SpanEnd { track, name } => {
                write!(f, "span-end track={track} name={name}")
            }
            TraceKind::CacheHit { tier } => write!(f, "cache-hit tier=L{tier}"),
            TraceKind::CacheMiss => write!(f, "cache-miss"),
            TraceKind::CacheSpill { records } => write!(f, "cache-spill records={records}"),
            TraceKind::CacheLoad { records, errors } => {
                write!(f, "cache-load records={records} errors={errors}")
            }
        }
    }
}

/// Bounded drop-oldest event journal.  All methods are `&self`; the
/// journal is shared via the `Metrics` it hangs off.
#[derive(Debug)]
pub struct TraceJournal {
    enabled: AtomicBool,
    seq: AtomicU64,
    dropped: AtomicU64,
    capacity: usize,
    ring: Mutex<VecDeque<TraceEvent>>,
    /// Driver track registry: tid = index + 1 (tid 0 is unused so shard
    /// and driver tids never collide inside one Perfetto process group).
    tracks: Mutex<Vec<String>>,
}

impl Default for TraceJournal {
    fn default() -> Self {
        Self::with_capacity(DEFAULT_CAPACITY)
    }
}

impl TraceJournal {
    /// A disabled journal with the default capacity.
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(capacity: usize) -> Self {
        TraceJournal {
            enabled: AtomicBool::new(false),
            seq: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            capacity: capacity.max(1),
            ring: Mutex::new(VecDeque::new()),
            tracks: Mutex::new(Vec::new()),
        }
    }

    /// The producer-side fast check: one relaxed load.  Every
    /// instrumentation site guards on this before building an event.
    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::SeqCst);
    }

    /// Append one event.  `ts_ns` must come from the injected `Clock`
    /// (this module never reads time).  When the ring is full the
    /// oldest event is dropped and counted — recording never blocks on
    /// a consumer.
    pub fn record(&self, ts_ns: u64, kind: TraceKind) {
        if !self.enabled() {
            return;
        }
        let mut ring = lock_recover(&self.ring);
        // Seq is assigned under the lock so ring order == seq order.
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        if ring.len() >= self.capacity {
            ring.pop_front();
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        ring.push_back(TraceEvent { seq, ts_ns, kind });
    }

    /// Events evicted by the bounded ring so far.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    pub fn len(&self) -> usize {
        lock_recover(&self.ring).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Copy out the retained events, sorted by sequence number.
    pub fn snapshot(&self) -> Vec<TraceEvent> {
        let mut v: Vec<TraceEvent> = lock_recover(&self.ring).iter().cloned().collect();
        v.sort_by_key(|e| e.seq);
        v
    }

    /// Register (or find) a named driver track; returns its tid.
    /// Driver tids start at 1 + the registration order, so they are
    /// deterministic for a deterministic registration order.
    pub fn driver_track(&self, name: &str) -> u32 {
        let mut tracks = lock_recover(&self.tracks);
        if let Some(pos) = tracks.iter().position(|t| t == name) {
            return pos as u32 + 1;
        }
        tracks.push(name.to_string());
        tracks.len() as u32
    }

    /// Registered driver-track names, tid order (tid = index + 1).
    pub fn track_names(&self) -> Vec<String> {
        lock_recover(&self.tracks).clone()
    }
}

/// Perfetto process-group ids for the two track families.
const PID_SHARDS: u32 = 1;
const PID_DRIVERS: u32 = 2;
/// Synthetic tid for the eval-cache track (driver tids start at 1).
const CACHE_TID: u32 = 0;

fn ts_us(ts_ns: u64) -> Json {
    Json::num(ts_ns as f64 / 1e3)
}

fn instant(name: &str, ts_ns: u64, pid: u32, tid: u32, args: Vec<(&str, Json)>) -> Json {
    Json::obj(vec![
        ("ph", Json::str("i")),
        ("s", Json::str("t")),
        ("name", Json::str(name)),
        ("ts", ts_us(ts_ns)),
        ("pid", Json::num(pid as f64)),
        ("tid", Json::num(tid as f64)),
        ("args", Json::obj(args)),
    ])
}

/// Render drained events as Chrome trace-event JSON: an object with a
/// `traceEvents` array, one track per shard (pid 1) and one per
/// registered driver (pid 2), loadable in Perfetto / chrome://tracing.
pub fn chrome_trace_json(events: &[TraceEvent], driver_tracks: &[String], dropped: u64) -> Json {
    let mut out: Vec<Json> = Vec::with_capacity(events.len() + driver_tracks.len() + 2);

    // Track-name metadata: the driver tracks are known up front; shard
    // tracks are named lazily from the shards the events mention.
    let mut shard_tids: Vec<u32> = events
        .iter()
        .filter_map(|e| match e.kind {
            TraceKind::Submitted { shard, .. }
            | TraceKind::Enqueued { shard, .. }
            | TraceKind::Coalesced { shard, .. }
            | TraceKind::Flushed { shard, .. }
            | TraceKind::Executing { shard, .. }
            | TraceKind::Executed { shard, .. }
            | TraceKind::Collected { shard, .. }
            | TraceKind::ShardDown { shard }
            | TraceKind::Respawn { shard } => Some(shard),
            _ => None,
        })
        .collect();
    shard_tids.sort_unstable();
    shard_tids.dedup();
    for &shard in &shard_tids {
        out.push(Json::obj(vec![
            ("ph", Json::str("M")),
            ("name", Json::str("thread_name")),
            ("pid", Json::num(PID_SHARDS as f64)),
            ("tid", Json::num(shard as f64)),
            ("args", Json::obj(vec![("name", Json::str(format!("shard {shard}")))])),
        ]));
    }
    for (i, name) in driver_tracks.iter().enumerate() {
        out.push(Json::obj(vec![
            ("ph", Json::str("M")),
            ("name", Json::str("thread_name")),
            ("pid", Json::num(PID_DRIVERS as f64)),
            ("tid", Json::num((i + 1) as f64)),
            ("args", Json::obj(vec![("name", Json::str(format!("driver {name}")))])),
        ]));
    }
    // Cache lifecycle events share one synthetic track (driver tid 0 is
    // reserved — driver tracks start at 1).
    if events.iter().any(|e| {
        matches!(
            e.kind,
            TraceKind::CacheHit { .. }
                | TraceKind::CacheMiss
                | TraceKind::CacheSpill { .. }
                | TraceKind::CacheLoad { .. }
        )
    }) {
        out.push(Json::obj(vec![
            ("ph", Json::str("M")),
            ("name", Json::str("thread_name")),
            ("pid", Json::num(PID_DRIVERS as f64)),
            ("tid", Json::num(CACHE_TID as f64)),
            ("args", Json::obj(vec![("name", Json::str("eval cache"))])),
        ]));
    }

    for e in events {
        let seq = Json::num(e.seq as f64);
        match &e.kind {
            TraceKind::Submitted { shard, problem, width } => out.push(instant(
                "submitted",
                e.ts_ns,
                PID_SHARDS,
                *shard,
                vec![
                    ("seq", seq),
                    ("problem", Json::num(*problem as f64)),
                    ("width", Json::num(*width as f64)),
                ],
            )),
            TraceKind::Enqueued { shard, problem } => out.push(instant(
                "enqueued",
                e.ts_ns,
                PID_SHARDS,
                *shard,
                vec![("seq", seq), ("problem", Json::num(*problem as f64))],
            )),
            TraceKind::Coalesced { shard, problem, pending } => out.push(instant(
                "coalesced",
                e.ts_ns,
                PID_SHARDS,
                *shard,
                vec![
                    ("seq", seq),
                    ("problem", Json::num(*problem as f64)),
                    ("pending", Json::num(*pending as f64)),
                ],
            )),
            TraceKind::Flushed { shard, problem, kind, width } => out.push(instant(
                &format!("flushed({kind})"),
                e.ts_ns,
                PID_SHARDS,
                *shard,
                vec![
                    ("seq", seq),
                    ("problem", Json::num(*problem as f64)),
                    ("width", Json::num(*width as f64)),
                ],
            )),
            TraceKind::Executing { shard, problem, width } => out.push(instant(
                "executing",
                e.ts_ns,
                PID_SHARDS,
                *shard,
                vec![
                    ("seq", seq),
                    ("problem", Json::num(*problem as f64)),
                    ("width", Json::num(*width as f64)),
                ],
            )),
            // The backend call renders as a complete span ("X") so the
            // shard track shows busy time as solid blocks.
            TraceKind::Executed { shard, problem, width, dur_ns } => out.push(Json::obj(vec![
                ("ph", Json::str("X")),
                ("name", Json::str(format!("exec p{problem}"))),
                ("ts", ts_us(e.ts_ns.saturating_sub(*dur_ns))),
                ("dur", Json::num(*dur_ns as f64 / 1e3)),
                ("pid", Json::num(PID_SHARDS as f64)),
                ("tid", Json::num(*shard as f64)),
                (
                    "args",
                    Json::obj(vec![
                        ("seq", seq),
                        ("width", Json::num(*width as f64)),
                    ]),
                ),
            ])),
            TraceKind::Collected { shard, latency_ns } => out.push(instant(
                "collected",
                e.ts_ns,
                PID_SHARDS,
                *shard,
                vec![("seq", seq), ("latency_ns", Json::num(*latency_ns as f64))],
            )),
            TraceKind::ShardDown { shard } => out.push(instant(
                "shard-down",
                e.ts_ns,
                PID_SHARDS,
                *shard,
                vec![("seq", seq)],
            )),
            TraceKind::Respawn { shard } => out.push(instant(
                "respawn",
                e.ts_ns,
                PID_SHARDS,
                *shard,
                vec![("seq", seq)],
            )),
            TraceKind::SpanBegin { track, name } => out.push(Json::obj(vec![
                ("ph", Json::str("B")),
                ("name", Json::str(name.as_str())),
                ("ts", ts_us(e.ts_ns)),
                ("pid", Json::num(PID_DRIVERS as f64)),
                ("tid", Json::num(*track as f64)),
                ("args", Json::obj(vec![("seq", seq)])),
            ])),
            TraceKind::SpanEnd { track, name } => out.push(Json::obj(vec![
                ("ph", Json::str("E")),
                ("name", Json::str(name.as_str())),
                ("ts", ts_us(e.ts_ns)),
                ("pid", Json::num(PID_DRIVERS as f64)),
                ("tid", Json::num(*track as f64)),
                ("args", Json::obj(vec![("seq", seq)])),
            ])),
            TraceKind::CacheHit { tier } => out.push(instant(
                &format!("cache-hit L{tier}"),
                e.ts_ns,
                PID_DRIVERS,
                CACHE_TID,
                vec![("seq", seq), ("tier", Json::num(*tier as f64))],
            )),
            TraceKind::CacheMiss => out.push(instant(
                "cache-miss",
                e.ts_ns,
                PID_DRIVERS,
                CACHE_TID,
                vec![("seq", seq)],
            )),
            TraceKind::CacheSpill { records } => out.push(instant(
                "cache-spill",
                e.ts_ns,
                PID_DRIVERS,
                CACHE_TID,
                vec![("seq", seq), ("records", Json::num(*records as f64))],
            )),
            TraceKind::CacheLoad { records, errors } => out.push(instant(
                "cache-load",
                e.ts_ns,
                PID_DRIVERS,
                CACHE_TID,
                vec![
                    ("seq", seq),
                    ("records", Json::num(*records as f64)),
                    ("errors", Json::num(*errors as f64)),
                ],
            )),
        }
    }

    Json::obj(vec![
        ("traceEvents", Json::Arr(out)),
        ("displayTimeUnit", Json::str("ms")),
        (
            "otherData",
            Json::obj(vec![
                ("droppedEvents", Json::num(dropped as f64)),
                ("clock", Json::str("axdt virtual clock (ns since epoch)")),
            ]),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_journal_records_nothing() {
        let j = TraceJournal::new();
        assert!(!j.enabled());
        j.record(5, TraceKind::ShardDown { shard: 0 });
        assert!(j.is_empty());
        assert_eq!(j.dropped(), 0);
    }

    #[test]
    fn ring_drops_oldest_and_counts() {
        let j = TraceJournal::with_capacity(3);
        j.set_enabled(true);
        for i in 0..5u32 {
            j.record(i as u64, TraceKind::Enqueued { shard: 0, problem: i });
        }
        assert_eq!(j.len(), 3);
        assert_eq!(j.dropped(), 2);
        let snap = j.snapshot();
        // Oldest two (seq 0, 1) evicted; the survivors keep their seqs.
        assert_eq!(snap.iter().map(|e| e.seq).collect::<Vec<_>>(), vec![2, 3, 4]);
    }

    #[test]
    fn driver_tracks_are_stable() {
        let j = TraceJournal::new();
        assert_eq!(j.driver_track("seeds"), 1);
        assert_eq!(j.driver_track("har"), 2);
        assert_eq!(j.driver_track("seeds"), 1);
        assert_eq!(j.track_names(), vec!["seeds".to_string(), "har".to_string()]);
    }

    #[test]
    fn event_display_is_canonical() {
        let e = TraceEvent {
            seq: 7,
            ts_ns: 1_500,
            kind: TraceKind::Flushed { shard: 1, problem: 2, kind: "Full", width: 32 },
        };
        assert_eq!(e.to_string(), "seq=7 ts=1500 flushed(Full) shard=1 problem=2 width=32");
    }

    #[test]
    fn cache_event_display_is_canonical() {
        let show = |kind: TraceKind| TraceEvent { seq: 1, ts_ns: 10, kind }.to_string();
        assert_eq!(show(TraceKind::CacheHit { tier: 2 }), "seq=1 ts=10 cache-hit tier=L2");
        assert_eq!(show(TraceKind::CacheMiss), "seq=1 ts=10 cache-miss");
        assert_eq!(show(TraceKind::CacheSpill { records: 9 }), "seq=1 ts=10 cache-spill records=9");
        assert_eq!(
            show(TraceKind::CacheLoad { records: 9, errors: 1 }),
            "seq=1 ts=10 cache-load records=9 errors=1"
        );
    }

    #[test]
    fn cache_events_render_on_their_own_track() {
        let j = TraceJournal::new();
        j.set_enabled(true);
        j.record(10, TraceKind::CacheLoad { records: 3, errors: 1 });
        j.record(20, TraceKind::CacheHit { tier: 2 });
        j.record(30, TraceKind::CacheMiss);
        j.record(40, TraceKind::CacheSpill { records: 5 });
        let text = chrome_trace_json(&j.snapshot(), &[], j.dropped()).to_string();
        let parsed = Json::parse(&text).unwrap();
        // 1 thread_name metadata row (the cache track) + 4 events.
        assert_eq!(parsed.get("traceEvents").unwrap().as_arr().unwrap().len(), 5);
        assert!(text.contains("\"eval cache\""));
        assert!(text.contains("\"cache-hit L2\""));
        assert!(text.contains("\"cache-miss\""));
    }

    #[test]
    fn chrome_trace_shape_parses_and_names_tracks() {
        let j = TraceJournal::new();
        j.set_enabled(true);
        let t = j.driver_track("seeds");
        j.record(10, TraceKind::SpanBegin { track: t, name: "dataset seeds".into() });
        j.record(20, TraceKind::Submitted { shard: 0, problem: 0, width: 4 });
        j.record(30, TraceKind::Executed { shard: 0, problem: 0, width: 4, dur_ns: 8 });
        j.record(40, TraceKind::Collected { shard: 0, latency_ns: 20 });
        j.record(50, TraceKind::SpanEnd { track: t, name: "dataset seeds".into() });
        let json = chrome_trace_json(&j.snapshot(), &j.track_names(), j.dropped());
        let text = json.to_string();
        let parsed = Json::parse(&text).unwrap();
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        // 2 thread_name metadata rows + 5 events.
        assert_eq!(events.len(), 7);
        assert!(text.contains("\"shard 0\""));
        assert!(text.contains("\"driver seeds\""));
        assert!(text.contains("\"ph\":\"X\""));
        // The exec span starts at ts-dur, in microseconds.
        assert!(text.contains("\"droppedEvents\":0"));
    }
}
