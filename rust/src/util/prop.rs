//! Tiny property-testing harness (proptest is not vendored).
//!
//! Runs a property over `cases` seeded random inputs; on failure it reports
//! the reproducing seed so `AXDT_PROP_SEED=<seed>` replays exactly that
//! case.  Shrinking is intentionally out of scope — failures carry the full
//! generated value via `Debug`.

use crate::util::rng::Pcg64;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        let seed = std::env::var("AXDT_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xA1D7);
        PropConfig { cases: 64, seed }
    }
}

/// Check `prop(gen(rng))` for `cfg.cases` generated values.
/// Panics (test failure) with the case index + seed on the first violation.
pub fn check<T, G, P>(name: &str, cfg: PropConfig, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Pcg64) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let mut rng = Pcg64::new(cfg.seed, case as u64);
        let value = gen(&mut rng);
        if let Err(msg) = prop(&value) {
            panic!(
                "property '{name}' failed at case {case} \
                 (replay with AXDT_PROP_SEED={}):\n  {msg}\n  input: {value:#?}",
                cfg.seed
            );
        }
    }
}

/// Shorthand with default config.
pub fn check_default<T, G, P>(name: &str, gen: G, prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Pcg64) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    check(name, PropConfig::default(), gen, prop)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check(
            "sum-commutes",
            PropConfig { cases: 16, seed: 1 },
            |rng| (rng.int_in(-100, 100), rng.int_in(-100, 100)),
            |&(a, b)| {
                n += 1;
                if a + b == b + a {
                    Ok(())
                } else {
                    Err("math broke".into())
                }
            },
        );
        assert_eq!(n, 16);
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_seed() {
        check(
            "always-fails",
            PropConfig { cases: 4, seed: 2 },
            |rng| rng.next_u64(),
            |_| Err("nope".into()),
        );
    }
}
