//! Filesystem helpers: atomic whole-file writes.

use std::io;
use std::path::Path;

/// Write `contents` to `path` atomically: write a sibling `.tmp` file,
/// then rename over the target, so readers (dashboards tailing
/// `runs.json`, CI parsing `BENCH_*.json`) never observe a torn file.
pub fn write_atomic(path: impl AsRef<Path>, contents: &str) -> io::Result<()> {
    let path = path.as_ref();
    let tmp = path.with_extension(match path.extension() {
        Some(ext) => format!("{}.tmp", ext.to_string_lossy()),
        None => "tmp".to_string(),
    });
    std::fs::write(&tmp, contents)?;
    std::fs::rename(&tmp, path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_atomic_replaces_and_leaves_no_tmp() {
        let dir = std::env::temp_dir().join("axdt_fsx_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("out.json");
        write_atomic(&path, "{\"a\":1}").unwrap();
        write_atomic(&path, "{\"a\":2}").unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "{\"a\":2}");
        assert!(!dir.join("out.json.tmp").exists(), "tmp file must be renamed away");
    }
}
