//! Shared-state helpers enforced by `axdt-lint`'s `mutex-discipline` rule.

use std::sync::{Mutex, MutexGuard};

/// Lock a mutex, recovering from poison: a thread that panicked while
/// holding it must not cascade panics into every other client.  The
/// framework's mutexes guard monotonic aggregates, swappable senders and
/// reusable buffers, so the worst a poisoned write leaves behind is one
/// partial sample — always preferable to stranding every other thread.
///
/// `axdt-lint` forbids raw `.lock().unwrap()` in `rust/src` precisely so
/// this is the only way a lock acquisition can be written.
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    #[test]
    fn recovers_a_poisoned_mutex() {
        let m = std::sync::Arc::new(Mutex::new(7u32));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.is_poisoned());
        assert_eq!(*lock_recover(&m), 7);
        *lock_recover(&m) = 8;
        assert_eq!(*lock_recover(&m), 8);
    }
}
