//! Criterion-shaped benchmark harness (criterion is not vendored).
//!
//! Benches under `rust/benches/` use `harness = false` and drive this:
//! warmup, fixed-duration timed phase, mean/median/p99 reporting, and a
//! machine-readable JSON line per benchmark for EXPERIMENTS.md tooling.
//! Honors `--bench` / `--quick` flags that `cargo bench` passes through.

use std::time::{Duration, Instant};

use crate::util::stats::{fmt_duration_ns, Summary};

/// One benchmark group, printed like `group/name ... mean ± sd`.
pub struct Bench {
    group: String,
    warmup: Duration,
    measure: Duration,
    quick: bool,
    results: Vec<(String, Summary)>,
}

impl Bench {
    pub fn new(group: &str) -> Self {
        let argv: Vec<String> = std::env::args().collect();
        let quick = argv.iter().any(|a| a == "--quick")
            || std::env::var("AXDT_BENCH_QUICK").is_ok();
        Bench {
            group: group.to_string(),
            warmup: Duration::from_millis(if quick { 20 } else { 200 }),
            measure: Duration::from_millis(if quick { 100 } else { 1000 }),
            quick,
            results: Vec::new(),
        }
    }

    pub fn quick(&self) -> bool {
        self.quick
    }

    /// Time `f` repeatedly; `f` returns a value that is black-boxed.
    pub fn iter<R>(&mut self, name: &str, mut f: impl FnMut() -> R) {
        // Warmup.
        // axdt-lint: allow(clock-seam): the bench harness exists to measure real wall time
        let w0 = Instant::now();
        let mut warm_iters: u64 = 0;
        while w0.elapsed() < self.warmup {
            black_box(f());
            warm_iters += 1;
        }
        // Choose batch size so one sample is ~1ms..warmup time.
        let per_iter = self.warmup.as_secs_f64() / warm_iters.max(1) as f64;
        let batch = ((1e-3 / per_iter).ceil() as u64).clamp(1, 1 << 20);

        let mut summary = Summary::new();
        let m0 = Instant::now(); // axdt-lint: allow(clock-seam): wall-time measurement window
        while m0.elapsed() < self.measure || summary.len() < 5 {
            let t0 = Instant::now(); // axdt-lint: allow(clock-seam): wall-time sample start

            for _ in 0..batch {
                black_box(f());
            }
            summary.push(t0.elapsed().as_nanos() as f64 / batch as f64);
            if summary.len() >= 100_000 {
                break;
            }
        }
        self.report(name, summary);
    }

    /// Record a single already-measured duration (for long end-to-end runs
    /// that cannot be iterated).
    pub fn record_once(&mut self, name: &str, elapsed: Duration) {
        let mut s = Summary::new();
        s.push(elapsed.as_nanos() as f64);
        self.report(name, s);
    }

    fn report(&mut self, name: &str, summary: Summary) {
        let full = format!("{}/{}", self.group, name);
        // One sort answers both quantiles for both output lines (the old
        // per-call percentile() sorted the sample vec four times here).
        let ps = summary.percentiles(&[0.5, 0.99]);
        let (p50, p99) = (ps[0], ps[1]);
        println!(
            "bench {full:<52} mean {:>12}  p50 {:>12}  p99 {:>12}  (n={})",
            fmt_duration_ns(summary.mean()),
            fmt_duration_ns(p50),
            fmt_duration_ns(p99),
            summary.len(),
        );
        println!(
            "BENCHJSON {{\"bench\":\"{full}\",\"mean_ns\":{:.1},\"p50_ns\":{:.1},\"p99_ns\":{:.1},\"n\":{}}}",
            summary.mean(),
            p50,
            p99,
            summary.len(),
        );
        self.results.push((name.to_string(), summary));
    }

    /// Print a table row (used by the table/figure-regeneration benches,
    /// which report paper metrics rather than wallclock).
    pub fn row(&self, line: &str) {
        println!("{line}");
    }

    /// Every recorded `(name, summary)` pair, in report order — for
    /// benches that derive their own metrics (speedups) from the raw
    /// summaries.
    pub fn results(&self) -> &[(String, Summary)] {
        &self.results
    }

    /// Mean of a recorded benchmark by name (NaN when absent) — the
    /// building block for derived speedup entries.
    pub fn mean_ns(&self, name: &str) -> f64 {
        self.results
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, s)| s.mean())
            .unwrap_or(f64::NAN)
    }

    /// Persist every recorded benchmark (plus caller-derived scalar
    /// metrics) as machine-readable JSON, written atomically (tmp +
    /// rename, like `runs.json`) so CI / EXPERIMENTS.md tooling never
    /// reads a torn file.
    pub fn save_json(
        &self,
        path: impl AsRef<std::path::Path>,
        derived: &[(&str, f64)],
    ) -> std::io::Result<()> {
        use crate::util::json::Json;
        // Non-finite values (a derived ratio over a skipped bench) become
        // null — "NaN" is not JSON.
        fn num(v: f64) -> Json {
            if v.is_finite() {
                Json::num(v)
            } else {
                Json::Null
            }
        }
        let benches: Vec<Json> = self
            .results
            .iter()
            .map(|(name, s)| {
                let ps = s.percentiles(&[0.5, 0.99]);
                Json::obj(vec![
                    ("name", Json::str(name.clone())),
                    ("mean_ns", num(s.mean())),
                    ("p50_ns", num(ps[0])),
                    ("p99_ns", num(ps[1])),
                    ("n", Json::num(s.len() as f64)),
                ])
            })
            .collect();
        let doc = Json::obj(vec![
            ("group", Json::str(self.group.clone())),
            ("quick", Json::Bool(self.quick)),
            ("benches", Json::Arr(benches)),
            ("derived", Json::obj(derived.iter().map(|&(k, v)| (k, num(v))).collect())),
        ]);
        crate::util::fsx::write_atomic(path, &format!("{doc}\n"))
    }
}

/// `std::hint::black_box` wrapper (stable since 1.66).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Should this bench run, given `cargo bench -- <filter>` argv?
pub fn filter_allows(name: &str) -> bool {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let filters: Vec<&String> = argv
        .iter()
        .filter(|a| !a.starts_with("--"))
        .collect();
    filters.is_empty() || filters.iter().any(|f| name.contains(f.as_str()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        std::env::set_var("AXDT_BENCH_QUICK", "1");
        let mut b = Bench::new("test");
        b.iter("noop", || 1 + 1);
        assert_eq!(b.results.len(), 1);
        assert!(b.results[0].1.mean() > 0.0);
    }

    #[test]
    fn record_once_works() {
        let mut b = Bench::new("test");
        b.record_once("one", Duration::from_millis(5));
        assert_eq!(b.results[0].1.len(), 1);
    }

    #[test]
    fn save_json_roundtrips_and_nulls_nonfinite() {
        use crate::util::json::Json;
        let mut b = Bench::new("grp");
        b.record_once("a", Duration::from_millis(2));
        b.record_once("b", Duration::from_millis(4));
        assert!((b.mean_ns("a") - 2e6).abs() < 1.0);
        assert!(b.mean_ns("missing").is_nan());
        let path = std::env::temp_dir().join("axdt_bench_save.json");
        let speedup = b.mean_ns("b") / b.mean_ns("a");
        b.save_json(&path, &[("speedup", speedup), ("skipped", f64::NAN)]).unwrap();
        let doc = Json::parse(std::fs::read_to_string(&path).unwrap().trim()).unwrap();
        assert_eq!(doc.get("group").unwrap().as_str(), Some("grp"));
        assert_eq!(doc.get("benches").unwrap().as_arr().unwrap().len(), 2);
        let derived = doc.get("derived").unwrap();
        assert!((derived.get("speedup").unwrap().as_f64().unwrap() - 2.0).abs() < 1e-9);
        assert_eq!(derived.get("skipped"), Some(&Json::Null));
    }
}
