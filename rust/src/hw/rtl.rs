//! Verilog emission for bespoke decision trees.
//!
//! The paper: "the resulting RTL description of the pareto-optimal bespoke
//! Decision Trees is automatically created, by parsing the tree structure,
//! and synthesized using Synopsys Design Compiler."  We emit the same two
//! artifacts a downstream printed-PDK flow would consume:
//!
//! * [`tree_verilog`] — behavioral RTL with hardwired thresholds and
//!   per-comparator precision slicing (human-auditable).
//! * [`netlist_verilog`] — the structural gate-level result of our own
//!   synthesis, mapped to EGT cell names.

use super::egt::CellKind;
use super::netlist::{Netlist, Sig};
use super::synth::{TreeApprox, TreeCircuit, FEATURE_BITS};
use crate::dt::Tree;

/// Behavioral bespoke RTL for `tree` under `approx`.
pub fn tree_verilog(tree: &Tree, approx: &TreeApprox, module: &str) -> String {
    let feats = tree.comparator_features();
    let mut used: Vec<usize> = feats.clone();
    used.sort_unstable();
    used.dedup();
    let class_bits = super::synth::bits_for_classes(tree.n_classes);

    let mut v = String::new();
    v.push_str(&format!(
        "// Auto-generated bespoke decision tree: {} comparators, {} leaves\n",
        tree.n_comparators(),
        tree.n_leaves()
    ));
    v.push_str(&format!("module {module} (\n    input  wire clk,\n"));
    for f in &used {
        v.push_str(&format!(
            "    input  wire [{}:0] feat_{f},\n",
            FEATURE_BITS - 1
        ));
    }
    v.push_str(&format!("    output reg  [{}:0] class_id\n);\n\n", class_bits - 1));

    // Comparator bank with precision slicing.
    for (j, &f) in feats.iter().enumerate() {
        let b = approx.bits[j];
        let hi = FEATURE_BITS - 1;
        let lo = FEATURE_BITS - b;
        v.push_str(&format!(
            "    wire cmp_{j} = (feat_{f}[{hi}:{lo}] <= {b}'d{});\n",
            approx.thr_int[j]
        ));
    }
    v.push('\n');

    // Arrival chain (shared path prefixes).
    let comp_slot: std::collections::HashMap<usize, usize> = tree
        .comparator_nodes()
        .into_iter()
        .enumerate()
        .map(|(slot, node)| (node, slot))
        .collect();
    v.push_str("    wire arrive_0 = 1'b1;\n");
    let mut stack = vec![0usize];
    let mut leaf_exprs: Vec<(String, u32)> = Vec::new();
    while let Some(i) = stack.pop() {
        let n = tree.nodes[i];
        if n.is_leaf() {
            leaf_exprs.push((format!("arrive_{i}"), n.leaf_class as u32));
            continue;
        }
        let j = comp_slot[&i];
        v.push_str(&format!(
            "    wire arrive_{l} = arrive_{i} & cmp_{j};\n    wire arrive_{r} = arrive_{i} & ~cmp_{j};\n",
            l = n.left,
            r = n.right
        ));
        stack.push(n.left as usize);
        stack.push(n.right as usize);
    }
    v.push('\n');

    // Registered class encoder.
    v.push_str("    always @(posedge clk) begin\n");
    for m in 0..class_bits {
        let terms: Vec<String> = leaf_exprs
            .iter()
            .filter(|(_, c)| (c >> m) & 1 == 1)
            .map(|(e, _)| e.clone())
            .collect();
        let rhs = if terms.is_empty() { "1'b0".to_string() } else { terms.join(" | ") };
        v.push_str(&format!("        class_id[{m}] <= {rhs};\n"));
    }
    v.push_str("    end\nendmodule\n");
    v
}

/// Structural gate-level Verilog of a synthesized netlist.
pub fn netlist_verilog(nl: &Netlist, module: &str) -> String {
    let live = nl.live_mask();
    let mut v = String::new();
    v.push_str(&format!(
        "// EGT-mapped structural netlist: {} cells\n",
        live.iter().filter(|&&l| l).count()
    ));
    v.push_str(&format!(
        "module {module} (input wire clk, input wire [{}:0] in, output wire [{}:0] out);\n",
        nl.n_inputs.max(1) - 1,
        nl.outputs.len().max(1) - 1
    ));
    let sig_name = |s: Sig| match s {
        Sig::Const(true) => "1'b1".to_string(),
        Sig::Const(false) => "1'b0".to_string(),
        Sig::Input(i) => format!("in[{i}]"),
        Sig::Gate(i) => format!("n{i}"),
    };
    for (i, g) in nl.gates.iter().enumerate() {
        if !live[i] {
            continue;
        }
        let a = sig_name(g.a);
        let b = sig_name(g.b);
        let line = match g.kind {
            CellKind::Inv => format!("    EGT_INV   u{i} (.a({a}), .y(n{i}));\n"),
            CellKind::Buf => format!("    EGT_BUF   u{i} (.a({a}), .y(n{i}));\n"),
            CellKind::Nand2 => format!("    EGT_NAND2 u{i} (.a({a}), .b({b}), .y(n{i}));\n"),
            CellKind::Nor2 => format!("    EGT_NOR2  u{i} (.a({a}), .b({b}), .y(n{i}));\n"),
            CellKind::And2 => format!("    EGT_AND2  u{i} (.a({a}), .b({b}), .y(n{i}));\n"),
            CellKind::Or2 => format!("    EGT_OR2   u{i} (.a({a}), .b({b}), .y(n{i}));\n"),
            CellKind::Xor2 => format!("    EGT_XOR2  u{i} (.a({a}), .b({b}), .y(n{i}));\n"),
            CellKind::Xnor2 => format!("    EGT_XNOR2 u{i} (.a({a}), .b({b}), .y(n{i}));\n"),
            CellKind::Dff => format!("    EGT_DFF   u{i} (.clk(clk), .d({a}), .q(n{i}));\n"),
        };
        v.push_str(&declare_wire(i, g.kind));
        v.push_str(&line);
    }
    for (o, s) in nl.outputs.iter().enumerate() {
        v.push_str(&format!("    assign out[{o}] = {};\n", sig_name(*s)));
    }
    v.push_str("endmodule\n");
    v
}

fn declare_wire(i: usize, _kind: CellKind) -> String {
    format!("    wire n{i};\n")
}

/// Convenience: emit both views for a synthesized tree circuit.
pub fn export(tree: &Tree, approx: &TreeApprox, circuit: &TreeCircuit, name: &str) -> String {
    let mut s = tree_verilog(tree, approx, &format!("{name}_rtl"));
    s.push('\n');
    s.push_str(&netlist_verilog(&circuit.netlist, &format!("{name}_gates")));
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators;
    use crate::dt::{train, TrainConfig};
    use crate::hw::synth;

    fn demo() -> (Tree, TreeApprox) {
        let spec = generators::spec("seeds").unwrap();
        let data = generators::generate(spec, 5);
        let tree = train(&data, &TrainConfig { max_leaves: 8, min_samples_split: 2 });
        let approx = TreeApprox::exact(&tree);
        (tree, approx)
    }

    #[test]
    fn behavioral_rtl_structure() {
        let (tree, approx) = demo();
        let v = tree_verilog(&tree, &approx, "seeds_dt");
        assert!(v.starts_with("// Auto-generated"));
        assert!(v.contains("module seeds_dt"));
        assert!(v.ends_with("endmodule\n"));
        let n_cmp = v.matches("wire cmp_").count();
        assert_eq!(n_cmp, tree.n_comparators());
        assert!(v.contains("always @(posedge clk)"));
        // Every comparator slices at its precision: exact = full bus.
        assert!(v.contains(&format!("[{}:0] <= ", 0).replace(" <= ", "")) || v.contains("[7:0]"));
    }

    #[test]
    fn structural_netlist_counts_match() {
        let (tree, approx) = demo();
        let circuit = synth::synth_tree(&tree, &approx);
        let v = netlist_verilog(&circuit.netlist, "seeds_gates");
        let live = circuit.netlist.live_mask().iter().filter(|&&l| l).count();
        let instances = v.matches("EGT_").count();
        assert_eq!(instances, live);
        assert!(v.contains("module seeds_gates"));
    }

    #[test]
    fn mixed_precision_appears_in_rtl() {
        let (tree, _) = demo();
        let n = tree.n_comparators();
        let mut bits = vec![8u8; n];
        bits[0] = 3;
        let thr = tree.comparator_thresholds();
        let thr_int: Vec<u32> = (0..n)
            .map(|j| crate::quant::int_threshold(thr[j], bits[j]))
            .collect();
        let approx = TreeApprox { bits, thr_int };
        let v = tree_verilog(&tree, &approx, "m");
        // 3-bit comparator slices [7:5].
        assert!(v.contains("[7:5] <= 3'd"), "rtl:\n{v}");
    }

    #[test]
    fn export_contains_both_views() {
        let (tree, approx) = demo();
        let circuit = synth::synth_tree(&tree, &approx);
        let v = export(&tree, &approx, &circuit, "seeds");
        assert!(v.contains("module seeds_rtl"));
        assert!(v.contains("module seeds_gates"));
    }
}
