//! Exhaustive bespoke-comparator characterization (paper §III-B).
//!
//! "We store the comparator area measurements from our exhaustive
//! experiment to create a look-up table of area measurements for different
//! input precisions and integer coefficient values."  The GA consults this
//! LUT for its area objective (Σ over comparators) instead of synthesizing
//! every candidate — the exact high-level-estimation trick the paper uses
//! to keep fitness evaluation off the EDA tools.

use super::egt::EgtLibrary;
use super::synth::synth_comparator;
use crate::quant::{levels, MAX_BITS, MIN_BITS};
use crate::util::pool;

/// Area (mm²) of every bespoke comparator: indexed by precision (2..=8
/// bits) and hardwired integer threshold.
#[derive(Clone, Debug)]
pub struct AreaLut {
    /// `tables[b - MIN_BITS][t]` = area of the b-bit comparator with
    /// threshold t.
    tables: Vec<Vec<f64>>,
}

impl AreaLut {
    /// Exhaustively synthesize all (precision, threshold) comparators.
    /// 2²+2³+…+2⁸ = 508 synth runs; parallelized across precisions.
    pub fn build(lib: &EgtLibrary) -> AreaLut {
        let bits_range: Vec<u8> = (MIN_BITS..=MAX_BITS).collect();
        let tables = pool::par_map(&bits_range, pool::default_threads(), |&bits| {
            (0..levels(bits))
                .map(|t| synth_comparator(bits, t).area_mm2(lib))
                .collect::<Vec<f64>>()
        });
        AreaLut { tables }
    }

    /// Area of one comparator configuration.
    #[inline]
    pub fn area(&self, bits: u8, t: u32) -> f64 {
        debug_assert!((MIN_BITS..=MAX_BITS).contains(&bits));
        self.tables[(bits - MIN_BITS) as usize][t as usize]
    }

    /// The full area curve at one precision (Fig. 4 series).
    pub fn curve(&self, bits: u8) -> &[f64] {
        &self.tables[(bits - MIN_BITS) as usize]
    }

    /// Cheapest threshold within ±`margin` of `t` (clamped to range):
    /// the "hardware-friendlier coefficient in its vicinity".
    pub fn cheapest_in_margin(&self, bits: u8, t: u32, margin: u32) -> (u32, f64) {
        let max = levels(bits) - 1;
        let lo = t.saturating_sub(margin);
        let hi = (t + margin).min(max);
        let mut best = (t, self.area(bits, t));
        for cand in lo..=hi {
            let a = self.area(bits, cand);
            if a < best.1 || (a == best.1 && (cand as i64 - t as i64).abs() < (best.0 as i64 - t as i64).abs()) {
                best = (cand, a);
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lut() -> AreaLut {
        AreaLut::build(&EgtLibrary::default())
    }

    #[test]
    fn lut_matches_direct_synthesis() {
        let lib = EgtLibrary::default();
        let lut = lut();
        for &(bits, t) in &[(2u8, 1u32), (4, 7), (6, 33), (8, 170), (8, 0), (8, 255)] {
            let direct = synth_comparator(bits, t).area_mm2(&lib);
            assert_eq!(lut.area(bits, t), direct, "bits={bits} t={t}");
        }
    }

    #[test]
    fn curves_have_expected_shapes() {
        let lut = lut();
        for bits in MIN_BITS..=MAX_BITS {
            let curve = lut.curve(bits);
            assert_eq!(curve.len(), levels(bits) as usize);
            // All-ones threshold is free; curve is non-constant.
            assert_eq!(curve[curve.len() - 1], 0.0);
            assert!(curve.iter().any(|&a| a > 0.0));
        }
        // Higher precision costs more on average (Fig. 4a vs 4b).
        let mean = |bits: u8| {
            let c = lut.curve(bits);
            c.iter().sum::<f64>() / c.len() as f64
        };
        assert!(mean(6) < mean(8));
        assert!(mean(2) < mean(6));
    }

    #[test]
    fn cheapest_in_margin_finds_cheaper_neighbours() {
        let lut = lut();
        // 0b10000000 = 128: expensive pattern; 127 = 0b01111111 is cheap.
        let (t, a) = lut.cheapest_in_margin(8, 128, 5);
        assert!(a <= lut.area(8, 128));
        assert!((123..=133).contains(&t));
        // margin 0 returns the original.
        assert_eq!(lut.cheapest_in_margin(8, 77, 0).0, 77);
    }

    #[test]
    fn cheapest_in_margin_clamps_at_bounds() {
        let lut = lut();
        let (t0, _) = lut.cheapest_in_margin(4, 0, 5);
        assert!(t0 <= 5);
        let (t1, _) = lut.cheapest_in_margin(4, 15, 5);
        assert!(t1 >= 10 && t1 <= 15);
    }
}
