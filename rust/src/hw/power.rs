//! EGT power model (PrimeTime stand-in).
//!
//! Printed EGT logic draws power mostly *statically* (ratioed logic keeps a
//! resistive path active), which is why the paper's Table I/II power tracks
//! area almost linearly (≈0.045 mW/mm² on every row).  We model:
//!
//!   P = Σ_cells static(cell)  +  Σ_cells α(cell) · dynamic(cell)
//!
//! with switching activity α estimated by propagating signal probabilities
//! (inputs uniform, independence assumption) — the same first-order model
//! vectorless PrimeTime runs use.

use super::egt::{CellKind, EgtLibrary};
use super::netlist::{Netlist, Sig};

/// Signal probability of every gate output (P[out = 1]), inputs at 0.5.
pub fn signal_probabilities(nl: &Netlist) -> Vec<f64> {
    let mut p = vec![0.5f64; nl.gates.len()];
    let get = |p: &Vec<f64>, s: Sig| -> f64 {
        match s {
            Sig::Const(true) => 1.0,
            Sig::Const(false) => 0.0,
            Sig::Input(_) => 0.5,
            Sig::Gate(i) => p[i as usize],
        }
    };
    for (i, g) in nl.gates.iter().enumerate() {
        let a = get(&p, g.a);
        let b = get(&p, g.b);
        p[i] = match g.kind {
            CellKind::Inv => 1.0 - a,
            CellKind::Buf | CellKind::Dff => a,
            CellKind::And2 => a * b,
            CellKind::Nand2 => 1.0 - a * b,
            CellKind::Or2 => a + b - a * b,
            CellKind::Nor2 => 1.0 - (a + b - a * b),
            CellKind::Xor2 => a + b - 2.0 * a * b,
            CellKind::Xnor2 => 1.0 - (a + b - 2.0 * a * b),
        };
    }
    p
}

/// Total power of the live netlist, mW.
pub fn power_mw(nl: &Netlist, lib: &EgtLibrary) -> f64 {
    let live = nl.live_mask();
    let probs = signal_probabilities(nl);
    let mut uw = 0.0;
    for (i, g) in nl.gates.iter().enumerate() {
        if !live[i] {
            continue;
        }
        let cell = lib.cell(g.kind);
        let p1 = probs[i];
        let activity = 2.0 * p1 * (1.0 - p1); // toggle probability surrogate
        uw += cell.static_uw + activity * cell.dynamic_uw;
    }
    uw * 1e-3
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probabilities_basic_gates() {
        let mut nl = Netlist::new(2);
        let (a, b) = (nl.input(0), nl.input(1));
        let g_and = nl.and(a, b);
        let g_or = nl.or(a, b);
        let g_xor = nl.xor(a, b);
        nl.set_outputs(vec![g_and, g_or, g_xor]);
        let p = signal_probabilities(&nl);
        let idx = |s: Sig| match s {
            Sig::Gate(i) => i as usize,
            _ => unreachable!(),
        };
        assert!((p[idx(g_and)] - 0.25).abs() < 1e-12);
        assert!((p[idx(g_or)] - 0.75).abs() < 1e-12);
        assert!((p[idx(g_xor)] - 0.5).abs() < 1e-12);
    }

    #[test]
    fn power_scales_with_live_area() {
        let lib = EgtLibrary::default();
        let mut small = Netlist::new(2);
        let (a, b) = (small.input(0), small.input(1));
        let g = small.and(a, b);
        small.set_outputs(vec![g]);

        let mut big = Netlist::new(4);
        let ins: Vec<Sig> = (0..4).map(|i| big.input(i)).collect();
        let g1 = big.and(ins[0], ins[1]);
        let g2 = big.or(ins[2], ins[3]);
        let g3 = big.xor(g1, g2);
        big.set_outputs(vec![g3]);

        assert!(power_mw(&big, &lib) > power_mw(&small, &lib));
    }

    #[test]
    fn static_dominates() {
        // EGT: dynamic at relaxed clocks must be a small fraction.
        let lib = EgtLibrary::default();
        let mut nl = Netlist::new(3);
        let (a, b, c) = (nl.input(0), nl.input(1), nl.input(2));
        let g1 = nl.and(a, b);
        let g2 = nl.xor(g1, c);
        nl.set_outputs(vec![g2]);
        let total = power_mw(&nl, &lib);
        let static_only: f64 = nl
            .cell_counts()
            .into_iter()
            .map(|(k, n)| lib.static_power_uw(k) * n as f64)
            .sum::<f64>()
            * 1e-3;
        assert!(static_only / total > 0.9, "static share {}", static_only / total);
    }

    #[test]
    fn power_area_ratio_matches_table1_band() {
        let lib = EgtLibrary::default();
        let mut nl = Netlist::new(8);
        let ins: Vec<Sig> = (0..8).map(|i| nl.input(i)).collect();
        let mut acc = ins[0];
        for &i in &ins[1..] {
            acc = nl.and(acc, i);
        }
        nl.set_outputs(vec![acc]);
        let r = power_mw(&nl, &lib) / nl.area_mm2(&lib);
        assert!((0.035..0.065).contains(&r), "mW/mm² = {r}");
    }
}
