//! Gate-level netlist IR with a simplifying builder.
//!
//! The builder performs, *as gates are created*, the local boolean
//! optimizations a synthesis tool applies to bespoke (constant-laden) RTL:
//!
//! * constant folding (`x & 0 = 0`, `x | 1 = 1`, `x ^ 1 = !x`, …)
//! * idempotence / complement rules (`x & x = x`, `x & !x = 0`, …)
//! * double-negation elimination and INV absorption into NAND/NOR/XNOR
//! * DeMorgan rewrites that shrink transistor count
//!   (`!x & !y → NOR(x,y)`, `!x | !y → NAND(x,y)`)
//! * structural hashing (CSE) with commutative canonicalization
//!
//! Gates only reference earlier signals, so evaluation and timing are a
//! single forward pass.  Metrics count *live* gates (reachable from an
//! output) — the dead-gate sweep mirror's DC's `compile` cleanup.

use std::collections::HashMap;

use super::egt::{CellKind, EgtLibrary};
use super::HwReport;

/// A signal: constant, primary input, or gate output.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Sig {
    Const(bool),
    Input(u32),
    Gate(u32),
}

/// One gate instance. `Inv`/`Buf`/`Dff` use only `a`.
#[derive(Clone, Copy, Debug)]
pub struct Gate {
    pub kind: CellKind,
    pub a: Sig,
    pub b: Sig,
}

/// A combinational netlist with optional registered outputs.
#[derive(Clone, Debug, Default)]
pub struct Netlist {
    pub n_inputs: usize,
    pub gates: Vec<Gate>,
    pub outputs: Vec<Sig>,
    /// Structural-hash table for CSE.
    cse: HashMap<(CellKind, Sig, Sig), Sig>,
    /// Memoized inverter outputs: sig -> !sig.
    inv_of: HashMap<Sig, Sig>,
}

impl Netlist {
    pub fn new(n_inputs: usize) -> Self {
        Netlist { n_inputs, ..Default::default() }
    }

    pub fn input(&self, i: usize) -> Sig {
        assert!(i < self.n_inputs);
        Sig::Input(i as u32)
    }

    pub fn set_outputs(&mut self, outs: Vec<Sig>) {
        self.outputs = outs;
    }

    // ---- raw gate creation (CSE'd) -------------------------------------

    fn emit(&mut self, kind: CellKind, a: Sig, b: Sig) -> Sig {
        // Canonicalize commutative operand order for hashing.
        let (a, b) = match kind {
            CellKind::Inv | CellKind::Buf | CellKind::Dff => (a, b),
            _ => {
                if a <= b {
                    (a, b)
                } else {
                    (b, a)
                }
            }
        };
        if let Some(&s) = self.cse.get(&(kind, a, b)) {
            return s;
        }
        let id = self.gates.len() as u32;
        self.gates.push(Gate { kind, a, b });
        let s = Sig::Gate(id);
        self.cse.insert((kind, a, b), s);
        s
    }

    fn gate(&self, s: Sig) -> Option<&Gate> {
        match s {
            Sig::Gate(i) => Some(&self.gates[i as usize]),
            _ => None,
        }
    }

    /// Known complement of `s`, if any (without creating gates).
    fn complement_of(&self, s: Sig) -> Option<Sig> {
        if let Sig::Const(v) = s {
            return Some(Sig::Const(!v));
        }
        if let Some(g) = self.gate(s) {
            if g.kind == CellKind::Inv {
                return Some(g.a);
            }
        }
        self.inv_of.get(&s).copied()
    }

    fn are_complements(&self, a: Sig, b: Sig) -> bool {
        self.complement_of(a) == Some(b) || self.complement_of(b) == Some(a)
    }

    // ---- simplifying boolean constructors ------------------------------

    pub fn not(&mut self, x: Sig) -> Sig {
        if let Some(c) = self.complement_of(x) {
            return c;
        }
        // INV absorption: invert the producing gate's kind instead of
        // stacking an inverter (equal or lower cost, one fewer level).
        if let Some(g) = self.gate(x).copied() {
            let flipped = match g.kind {
                CellKind::And2 => Some(CellKind::Nand2),
                CellKind::Nand2 => Some(CellKind::And2),
                CellKind::Or2 => Some(CellKind::Nor2),
                CellKind::Nor2 => Some(CellKind::Or2),
                CellKind::Xor2 => Some(CellKind::Xnor2),
                CellKind::Xnor2 => Some(CellKind::Xor2),
                _ => None,
            };
            if let Some(k) = flipped {
                let s = self.emit(k, g.a, g.b);
                self.inv_of.insert(x, s);
                self.inv_of.insert(s, x);
                return s;
            }
        }
        let s = self.emit(CellKind::Inv, x, x);
        self.inv_of.insert(x, s);
        self.inv_of.insert(s, x);
        s
    }

    pub fn and(&mut self, a: Sig, b: Sig) -> Sig {
        match (a, b) {
            (Sig::Const(false), _) | (_, Sig::Const(false)) => return Sig::Const(false),
            (Sig::Const(true), x) | (x, Sig::Const(true)) => return x,
            _ => {}
        }
        if a == b {
            return a;
        }
        if self.are_complements(a, b) {
            return Sig::Const(false);
        }
        // DeMorgan shrink: !x & !y = NOR(x, y)  (4T vs 6T).
        if let (Some(xa), Some(xb)) = (self.inverted_operand(a), self.inverted_operand(b)) {
            return self.emit(CellKind::Nor2, xa, xb);
        }
        self.emit(CellKind::And2, a, b)
    }

    pub fn or(&mut self, a: Sig, b: Sig) -> Sig {
        match (a, b) {
            (Sig::Const(true), _) | (_, Sig::Const(true)) => return Sig::Const(true),
            (Sig::Const(false), x) | (x, Sig::Const(false)) => return x,
            _ => {}
        }
        if a == b {
            return a;
        }
        if self.are_complements(a, b) {
            return Sig::Const(true);
        }
        // DeMorgan shrink: !x | !y = NAND(x, y).
        if let (Some(xa), Some(xb)) = (self.inverted_operand(a), self.inverted_operand(b)) {
            return self.emit(CellKind::Nand2, xa, xb);
        }
        self.emit(CellKind::Or2, a, b)
    }

    pub fn nand(&mut self, a: Sig, b: Sig) -> Sig {
        let x = self.and(a, b);
        self.not(x)
    }

    pub fn nor(&mut self, a: Sig, b: Sig) -> Sig {
        let x = self.or(a, b);
        self.not(x)
    }

    pub fn xor(&mut self, a: Sig, b: Sig) -> Sig {
        match (a, b) {
            (Sig::Const(false), x) | (x, Sig::Const(false)) => return x,
            (Sig::Const(true), x) | (x, Sig::Const(true)) => return self.not(x),
            _ => {}
        }
        if a == b {
            return Sig::Const(false);
        }
        if self.are_complements(a, b) {
            return Sig::Const(true);
        }
        self.emit(CellKind::Xor2, a, b)
    }

    pub fn xnor(&mut self, a: Sig, b: Sig) -> Sig {
        let x = self.xor(a, b);
        self.not(x)
    }

    /// Register a signal through a DFF (output staging, paper's registered
    /// class outputs).
    pub fn dff(&mut self, d: Sig) -> Sig {
        self.emit(CellKind::Dff, d, d)
    }

    /// If `s` is an inverter (or has a cheaper complement already built),
    /// return the un-inverted source — used by the DeMorgan rules. Only
    /// returns signals that already exist (never creates gates).
    fn inverted_operand(&self, s: Sig) -> Option<Sig> {
        if let Some(g) = self.gate(s) {
            if g.kind == CellKind::Inv {
                return Some(g.a);
            }
        }
        None
    }

    // ---- evaluation -----------------------------------------------------

    /// Evaluate all outputs for one input assignment (test/verification
    /// path; DFFs are transparent here — we check combinational function).
    pub fn eval(&self, inputs: &[bool]) -> Vec<bool> {
        assert_eq!(inputs.len(), self.n_inputs);
        let mut vals = vec![false; self.gates.len()];
        let get = |vals: &Vec<bool>, s: Sig| -> bool {
            match s {
                Sig::Const(v) => v,
                Sig::Input(i) => inputs[i as usize],
                Sig::Gate(i) => vals[i as usize],
            }
        };
        for (i, g) in self.gates.iter().enumerate() {
            let a = get(&vals, g.a);
            let b = get(&vals, g.b);
            vals[i] = match g.kind {
                CellKind::Inv => !a,
                CellKind::Buf | CellKind::Dff => a,
                CellKind::And2 => a & b,
                CellKind::Nand2 => !(a & b),
                CellKind::Or2 => a | b,
                CellKind::Nor2 => !(a | b),
                CellKind::Xor2 => a ^ b,
                CellKind::Xnor2 => !(a ^ b),
            };
        }
        self.outputs.iter().map(|&o| get(&vals, o)).collect()
    }

    // ---- metrics ---------------------------------------------------------

    /// Which gates are reachable from the outputs (dead-gate sweep).
    pub fn live_mask(&self) -> Vec<bool> {
        let mut live = vec![false; self.gates.len()];
        let mut stack: Vec<u32> = self
            .outputs
            .iter()
            .filter_map(|&s| match s {
                Sig::Gate(i) => Some(i),
                _ => None,
            })
            .collect();
        while let Some(i) = stack.pop() {
            if live[i as usize] {
                continue;
            }
            live[i as usize] = true;
            let g = &self.gates[i as usize];
            for s in [g.a, g.b] {
                if let Sig::Gate(j) = s {
                    if !live[j as usize] {
                        stack.push(j);
                    }
                }
            }
        }
        live
    }

    /// Live cell-count histogram (BTreeMap: deterministic iteration, so
    /// float metric sums are reproducible).
    pub fn cell_counts(&self) -> std::collections::BTreeMap<CellKind, usize> {
        let live = self.live_mask();
        let mut m = std::collections::BTreeMap::new();
        for (g, &l) in self.gates.iter().zip(&live) {
            if l {
                *m.entry(g.kind).or_insert(0) += 1;
            }
        }
        m
    }

    /// Area of live gates, mm².
    pub fn area_mm2(&self, lib: &EgtLibrary) -> f64 {
        self.cell_counts()
            .into_iter()
            .map(|(k, n)| lib.area(k) * n as f64)
            .sum()
    }

    /// Critical-path delay over live gates, ms.
    pub fn delay_ms(&self, lib: &EgtLibrary) -> f64 {
        let live = self.live_mask();
        let mut arrival = vec![0f64; self.gates.len()];
        let get = |arrival: &Vec<f64>, s: Sig| -> f64 {
            match s {
                Sig::Gate(i) => arrival[i as usize],
                _ => 0.0,
            }
        };
        let mut worst: f64 = 0.0;
        for (i, g) in self.gates.iter().enumerate() {
            if !live[i] {
                continue;
            }
            let t = lib.delay(g.kind) + get(&arrival, g.a).max(get(&arrival, g.b));
            arrival[i] = t;
            worst = worst.max(t);
        }
        worst
    }

    /// Full synthesis report (power via [`super::power`]).
    pub fn report(&self, lib: &EgtLibrary) -> HwReport {
        HwReport {
            area_mm2: self.area_mm2(lib),
            power_mw: super::power::power_mw(self, lib),
            delay_ms: self.delay_ms(lib),
            n_cells: self.live_mask().iter().filter(|&&l| l).count(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Exhaustively compare a netlist output against a boolean spec.
    pub fn assert_equiv(nl: &Netlist, spec: impl Fn(&[bool]) -> Vec<bool>) {
        let n = nl.n_inputs;
        assert!(n <= 16, "too many inputs for exhaustive check");
        for m in 0u32..(1 << n) {
            let inputs: Vec<bool> = (0..n).map(|i| (m >> i) & 1 == 1).collect();
            assert_eq!(nl.eval(&inputs), spec(&inputs), "inputs {inputs:?}");
        }
    }

    #[test]
    fn constant_folding() {
        let mut nl = Netlist::new(1);
        let x = nl.input(0);
        assert_eq!(nl.and(x, Sig::Const(false)), Sig::Const(false));
        assert_eq!(nl.and(x, Sig::Const(true)), x);
        assert_eq!(nl.or(x, Sig::Const(true)), Sig::Const(true));
        assert_eq!(nl.or(x, Sig::Const(false)), x);
        assert_eq!(nl.xor(x, Sig::Const(false)), x);
        assert_eq!(nl.and(x, x), x);
        assert_eq!(nl.xor(x, x), Sig::Const(false));
        assert_eq!(nl.gates.len(), 0, "no gates for folded ops");
    }

    #[test]
    fn complements_fold() {
        let mut nl = Netlist::new(1);
        let x = nl.input(0);
        let nx = nl.not(x);
        assert_eq!(nl.not(nx), x, "double negation");
        assert_eq!(nl.and(x, nx), Sig::Const(false));
        assert_eq!(nl.or(x, nx), Sig::Const(true));
        assert_eq!(nl.xor(x, nx), Sig::Const(true));
    }

    #[test]
    fn cse_dedups() {
        let mut nl = Netlist::new(2);
        let (a, b) = (nl.input(0), nl.input(1));
        let g1 = nl.and(a, b);
        let g2 = nl.and(b, a); // commuted
        assert_eq!(g1, g2);
        assert_eq!(nl.gates.len(), 1);
    }

    #[test]
    fn inv_absorption_produces_nand() {
        let mut nl = Netlist::new(2);
        let (a, b) = (nl.input(0), nl.input(1));
        let g = nl.and(a, b);
        let n = nl.not(g);
        let kinds = nl.cell_counts();
        nl.set_outputs(vec![n]);
        assert_eq!(nl.gates[match n { Sig::Gate(i) => i as usize, _ => 99 }].kind, CellKind::Nand2);
        assert!(!kinds.contains_key(&CellKind::Inv) || kinds[&CellKind::Inv] == 0);
        assert_equiv(&nl, |ins| vec![!(ins[0] & ins[1])]);
    }

    #[test]
    fn demorgan_shrinks() {
        let mut nl = Netlist::new(2);
        let (a, b) = (nl.input(0), nl.input(1));
        let na = nl.not(a);
        let nb = nl.not(b);
        let g = nl.and(na, nb);
        nl.set_outputs(vec![g]);
        assert_equiv(&nl, |ins| vec![!ins[0] & !ins[1]]);
        // The AND of two inverters must have become a NOR.
        let counts = nl.cell_counts();
        assert_eq!(counts.get(&CellKind::Nor2), Some(&1));
        assert_eq!(counts.get(&CellKind::And2), None);
    }

    #[test]
    fn dead_gates_not_counted() {
        let lib = EgtLibrary::default();
        let mut nl = Netlist::new(2);
        let (a, b) = (nl.input(0), nl.input(1));
        let live = nl.and(a, b);
        let _dead = nl.xor(a, b);
        nl.set_outputs(vec![live]);
        assert_eq!(nl.live_mask().iter().filter(|&&l| l).count(), 1);
        assert!((nl.area_mm2(&lib) - lib.area(CellKind::And2)).abs() < 1e-12);
    }

    #[test]
    fn delay_is_critical_path() {
        let lib = EgtLibrary::default();
        let mut nl = Netlist::new(3);
        let (a, b, c) = (nl.input(0), nl.input(1), nl.input(2));
        let g1 = nl.and(a, b);
        let g2 = nl.or(g1, c); // depth 2 path
        nl.set_outputs(vec![g2]);
        let want = lib.delay(CellKind::And2) + lib.delay(CellKind::Or2);
        assert!((nl.delay_ms(&lib) - want).abs() < 1e-12);
    }

    #[test]
    fn random_expression_equivalence() {
        // Build random expressions through the simplifying builder and
        // check against direct boolean evaluation.
        use crate::util::rng::Pcg64;
        let mut rng = Pcg64::seeded(0xE0);
        for _case in 0..50 {
            let n_in = 4;
            let mut nl = Netlist::new(n_in);
            // spec expressions as closures over input vectors
            let mut sigs: Vec<Sig> = (0..n_in).map(|i| nl.input(i)).collect();
            let mut specs: Vec<Box<dyn Fn(&[bool]) -> bool>> = (0..n_in)
                .map(|i| Box::new(move |ins: &[bool]| ins[i]) as _)
                .collect();
            for _ in 0..12 {
                let op = rng.below(4);
                let i = rng.below(sigs.len() as u64) as usize;
                let j = rng.below(sigs.len() as u64) as usize;
                let (si, sj) = (sigs[i], sigs[j]);
                let (s, f): (Sig, Box<dyn Fn(&[bool]) -> bool>) = {
                    let fi = unsafe { &*(specs[i].as_ref() as *const dyn Fn(&[bool]) -> bool) };
                    let fj = unsafe { &*(specs[j].as_ref() as *const dyn Fn(&[bool]) -> bool) };
                    match op {
                        0 => (nl.and(si, sj), Box::new(move |x: &[bool]| fi(x) & fj(x))),
                        1 => (nl.or(si, sj), Box::new(move |x: &[bool]| fi(x) | fj(x))),
                        2 => (nl.xor(si, sj), Box::new(move |x: &[bool]| fi(x) ^ fj(x))),
                        _ => (nl.not(si), Box::new(move |x: &[bool]| !fi(x))),
                    }
                };
                sigs.push(s);
                specs.push(f);
            }
            let out = *sigs.last().unwrap();
            nl.set_outputs(vec![out]);
            for m in 0u32..16 {
                let ins: Vec<bool> = (0..4).map(|k| (m >> k) & 1 == 1).collect();
                assert_eq!(nl.eval(&ins)[0], specs.last().unwrap()(&ins), "case {_case} m={m}");
            }
        }
    }
}
