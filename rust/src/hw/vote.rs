//! Printed majority-vote stage for bespoke random forests.
//!
//! Combines K member-tree class outputs into a voted class id:
//!
//! ```text
//! votes[c]  = Σ_k [class_k == c]          (equality decoders + popcount)
//! class_out = argmax_c votes[c]           (comparator reduction tree)
//! ```
//!
//! Building blocks are plain EGT gates: ripple-carry adders for the
//! popcounts and the generic `a > b` comparator chain for the argmax — all
//! constant-free, so this stage's area is fixed per (K, #classes) while the
//! member trees shrink under approximation.

use super::netlist::{Netlist, Sig};
use super::opt;
use super::synth::{self, bits_for_classes, TreeApprox, FEATURE_BITS};
use crate::dt::forest::Forest;

/// `[bus == value]` for a little-endian signal bus and a constant.
pub fn equals_const(nl: &mut Netlist, bus: &[Sig], value: u32) -> Sig {
    let mut acc = Sig::Const(true);
    for (i, &b) in bus.iter().enumerate() {
        let bit = if (value >> i) & 1 == 1 {
            b
        } else {
            nl.not(b)
        };
        acc = nl.and(acc, bit);
    }
    acc
}

/// Ripple-carry add of two little-endian buses (unequal widths allowed);
/// returns a bus one bit wider than the longer input.
pub fn add(nl: &mut Netlist, a: &[Sig], b: &[Sig]) -> Vec<Sig> {
    let width = a.len().max(b.len());
    let mut out = Vec::with_capacity(width + 1);
    let mut carry = Sig::Const(false);
    for i in 0..width {
        let x = a.get(i).copied().unwrap_or(Sig::Const(false));
        let y = b.get(i).copied().unwrap_or(Sig::Const(false));
        // full adder
        let xy = nl.xor(x, y);
        let sum = nl.xor(xy, carry);
        let and1 = nl.and(x, y);
        let and2 = nl.and(xy, carry);
        carry = nl.or(and1, and2);
        out.push(sum);
    }
    out.push(carry);
    out
}

/// `[a > b]` for little-endian buses of equal width.
pub fn greater_than(nl: &mut Netlist, a: &[Sig], b: &[Sig]) -> Sig {
    assert_eq!(a.len(), b.len());
    // gt' from LSB to MSB: gt = (a_i & !b_i) | ((a_i == b_i) & gt)
    let mut gt = Sig::Const(false);
    for i in 0..a.len() {
        let nb = nl.not(b[i]);
        let win = nl.and(a[i], nb);
        let eq = nl.xnor(a[i], b[i]);
        let keep = nl.and(eq, gt);
        gt = nl.or(win, keep);
    }
    gt
}

/// 2:1 bus mux (`sel ? a : b`).
fn mux_bus(nl: &mut Netlist, sel: Sig, a: &[Sig], b: &[Sig]) -> Vec<Sig> {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(&x, &y)| {
            let ns = nl.not(sel);
            let t1 = nl.and(sel, x);
            let t2 = nl.and(ns, y);
            nl.or(t1, t2)
        })
        .collect()
}

/// Result of forest synthesis.
#[derive(Clone, Debug)]
pub struct ForestCircuit {
    pub netlist: Netlist,
    pub feature_bus: std::collections::BTreeMap<usize, usize>,
    pub class_bits: usize,
}

/// Synthesize a bespoke forest: member trees share feature buses; their
/// class outputs feed the vote stage; the voted class id is registered.
pub fn synth_forest(forest: &Forest, approxes: &[TreeApprox]) -> ForestCircuit {
    assert_eq!(approxes.len(), forest.trees.len());
    // Union feature-bus map across members.
    let mut feature_bus = std::collections::BTreeMap::new();
    for t in &forest.trees {
        for f in t.comparator_features() {
            let next = feature_bus.len();
            feature_bus.entry(f).or_insert(next);
        }
    }
    let mut nl = Netlist::new(feature_bus.len() * FEATURE_BITS as usize);

    // Member trees.
    let member_outs: Vec<Vec<Sig>> = forest
        .trees
        .iter()
        .zip(approxes)
        .map(|(t, a)| synth::synth_tree_into(&mut nl, t, a, &feature_bus))
        .collect();

    // Vote popcounts per class.
    let k = forest.trees.len();
    let count_bits = (usize::BITS - k.leading_zeros()) as usize;
    let class_bits = bits_for_classes(forest.n_classes);
    let mut votes: Vec<Vec<Sig>> = Vec::with_capacity(forest.n_classes);
    for c in 0..forest.n_classes {
        let mut total: Vec<Sig> = vec![];
        for outs in &member_outs {
            let is_c = equals_const(&mut nl, outs, c as u32);
            total = if total.is_empty() {
                vec![is_c]
            } else {
                add(&mut nl, &total, &[is_c])
            };
        }
        total.resize(count_bits + 1, Sig::Const(false));
        votes.push(total);
    }

    // Argmax reduction (left-biased: ties keep the lower class id).
    let mut best_count = votes[0].clone();
    let mut best_id: Vec<Sig> = (0..class_bits).map(|_| Sig::Const(false)).collect();
    for c in 1..forest.n_classes {
        let gt = greater_than(&mut nl, &votes[c], &best_count);
        let c_bus: Vec<Sig> = (0..class_bits)
            .map(|m| Sig::Const((c >> m) & 1 == 1))
            .collect();
        best_id = mux_bus(&mut nl, gt, &c_bus, &best_id);
        best_count = mux_bus(&mut nl, gt, &votes[c], &best_count);
    }

    let regs: Vec<Sig> = best_id.into_iter().map(|o| nl.dff(o)).collect();
    nl.set_outputs(regs);
    ForestCircuit { netlist: opt::optimize(&nl), feature_bus, class_bits }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators;
    use crate::dt::forest::{train_forest, ForestConfig};
    use crate::hw::EgtLibrary;
    use crate::util::rng::Pcg64;

    #[test]
    fn adder_exhaustive_3bit() {
        for a in 0u32..8 {
            for b in 0u32..8 {
                let mut nl = Netlist::new(6);
                let abus: Vec<Sig> = (0..3).map(|i| nl.input(i)).collect();
                let bbus: Vec<Sig> = (0..3).map(|i| nl.input(3 + i)).collect();
                let sum = add(&mut nl, &abus, &bbus);
                nl.set_outputs(sum);
                let mut ins = vec![false; 6];
                for i in 0..3 {
                    ins[i] = (a >> i) & 1 == 1;
                    ins[3 + i] = (b >> i) & 1 == 1;
                }
                let out = nl.eval(&ins);
                let got: u32 = out.iter().enumerate().map(|(i, &v)| (v as u32) << i).sum();
                assert_eq!(got, a + b, "{a}+{b}");
            }
        }
    }

    #[test]
    fn greater_than_exhaustive_3bit() {
        for a in 0u32..8 {
            for b in 0u32..8 {
                let mut nl = Netlist::new(6);
                let abus: Vec<Sig> = (0..3).map(|i| nl.input(i)).collect();
                let bbus: Vec<Sig> = (0..3).map(|i| nl.input(3 + i)).collect();
                let gt = greater_than(&mut nl, &abus, &bbus);
                nl.set_outputs(vec![gt]);
                let mut ins = vec![false; 6];
                for i in 0..3 {
                    ins[i] = (a >> i) & 1 == 1;
                    ins[3 + i] = (b >> i) & 1 == 1;
                }
                assert_eq!(nl.eval(&ins)[0], a > b, "{a}>{b}");
            }
        }
    }

    #[test]
    fn equals_const_exhaustive() {
        for v in 0u32..8 {
            for x in 0u32..8 {
                let mut nl = Netlist::new(3);
                let bus: Vec<Sig> = (0..3).map(|i| nl.input(i)).collect();
                let eq = equals_const(&mut nl, &bus, v);
                nl.set_outputs(vec![eq]);
                let ins: Vec<bool> = (0..3).map(|i| (x >> i) & 1 == 1).collect();
                assert_eq!(nl.eval(&ins)[0], x == v);
            }
        }
    }

    /// The synthesized forest circuit votes exactly like the software
    /// forest on random inputs and random approximations.
    #[test]
    fn forest_netlist_matches_vote() {
        let spec = generators::spec("seeds").unwrap();
        let data = generators::generate(spec, 11);
        let forest = train_forest(
            &data,
            &ForestConfig { n_trees: 3, max_leaves: 6, sample_frac: 1.0, seed: 5 },
        );
        let mut rng = Pcg64::seeded(0xF0);
        for case in 0..4 {
            let approx = if case == 0 {
                forest.exact_approx()
            } else {
                let n = forest.n_comparators();
                let thr = forest.thresholds();
                let bits: Vec<u8> = (0..n).map(|_| rng.int_in(2, 8) as u8).collect();
                let thr_int: Vec<u32> = (0..n)
                    .map(|j| crate::quant::int_threshold(thr[j], bits[j]))
                    .collect();
                TreeApprox { bits, thr_int }
            };
            let parts = forest.split_approx(&approx);
            let slots = forest.member_slots();
            let circuit = synth_forest(&forest, &parts);
            for _ in 0..40 {
                let codes: Vec<u32> =
                    (0..data.n_features).map(|_| rng.below(256) as u32).collect();
                let mut ins = vec![false; circuit.netlist.n_inputs];
                for (&feat, &bus) in &circuit.feature_bus {
                    for b in 0..FEATURE_BITS as usize {
                        ins[bus * FEATURE_BITS as usize + b] = (codes[feat] >> b) & 1 == 1;
                    }
                }
                let out = circuit.netlist.eval(&ins);
                let got: u32 =
                    out.iter().enumerate().map(|(m, &v)| (v as u32) << m).sum();
                let want = forest.predict_codes_with_slots(&slots, &parts, &codes);
                assert_eq!(got, want, "case {case}");
            }
        }
    }

    #[test]
    fn forest_circuit_report_scales_with_members() {
        let spec = generators::spec("seeds").unwrap();
        let data = generators::generate(spec, 11);
        let lib = EgtLibrary::default();
        let area_of = |k: usize| {
            let f = train_forest(
                &data,
                &ForestConfig { n_trees: k, max_leaves: 6, sample_frac: 1.0, seed: 5 },
            );
            let parts = f.split_approx(&f.exact_approx());
            synth_forest(&f, &parts).netlist.area_mm2(&lib)
        };
        assert!(area_of(5) > area_of(3));
    }
}
