//! Peephole resynthesis pass.
//!
//! The builder in [`super::netlist`] simplifies greedily as gates are
//! created, but some rewrites only become visible once the whole cone
//! exists (e.g. an inverter created before its DeMorgan partner).  This
//! pass replays the live gates, in topological order, through a fresh
//! simplifying builder — a fixpoint-style cleanup analogous to an
//! incremental `compile` in Design Compiler.  Iterating until the live cell
//! count stops improving gives the final "synthesized" netlist.

use super::egt::CellKind;
use super::netlist::{Netlist, Sig};

/// One resynthesis replay.
pub fn resynthesize(nl: &Netlist) -> Netlist {
    let mut out = Netlist::new(nl.n_inputs);
    let live = nl.live_mask();
    // Map old signal -> new signal.
    let mut map: Vec<Option<Sig>> = vec![None; nl.gates.len()];
    let translate = |map: &Vec<Option<Sig>>, s: Sig| -> Sig {
        match s {
            Sig::Gate(i) => map[i as usize].expect("topological order violated"),
            other => other,
        }
    };
    for (i, g) in nl.gates.iter().enumerate() {
        if !live[i] {
            continue;
        }
        let a = translate(&map, g.a);
        let b = translate(&map, g.b);
        let s = match g.kind {
            CellKind::Inv => out.not(a),
            CellKind::Buf => a,
            CellKind::And2 => out.and(a, b),
            CellKind::Nand2 => out.nand(a, b),
            CellKind::Or2 => out.or(a, b),
            CellKind::Nor2 => out.nor(a, b),
            CellKind::Xor2 => out.xor(a, b),
            CellKind::Xnor2 => out.xnor(a, b),
            CellKind::Dff => out.dff(a),
        };
        map[i] = Some(s);
    }
    let outs = nl.outputs.iter().map(|&o| translate(&map, o)).collect();
    out.set_outputs(outs);
    out
}

/// Resynthesize until the live cell count stops shrinking (max 4 rounds —
/// it converges in 1–2 on everything we generate).
pub fn optimize(nl: &Netlist) -> Netlist {
    let mut cur = resynthesize(nl);
    let mut count = cur.live_mask().iter().filter(|&&l| l).count();
    for _ in 0..3 {
        let next = resynthesize(&cur);
        let next_count = next.live_mask().iter().filter(|&&l| l).count();
        if next_count >= count {
            break;
        }
        cur = next;
        count = next_count;
    }
    cur
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    /// Random netlists: optimize() must preserve the function and never
    /// increase live cell count.
    #[test]
    fn optimize_preserves_function_and_shrinks() {
        let mut rng = Pcg64::seeded(0x0907);
        for case in 0..40 {
            let n_in = 5;
            let mut nl = Netlist::new(n_in);
            let mut sigs: Vec<Sig> = (0..n_in).map(|i| nl.input(i)).collect();
            for _ in 0..20 {
                let i = rng.below(sigs.len() as u64) as usize;
                let j = rng.below(sigs.len() as u64) as usize;
                let s = match rng.below(6) {
                    0 => nl.and(sigs[i], sigs[j]),
                    1 => nl.or(sigs[i], sigs[j]),
                    2 => nl.xor(sigs[i], sigs[j]),
                    3 => nl.nand(sigs[i], sigs[j]),
                    4 => nl.nor(sigs[i], sigs[j]),
                    _ => nl.not(sigs[i]),
                };
                sigs.push(s);
            }
            let outs: Vec<Sig> = (0..3)
                .map(|_| sigs[rng.below(sigs.len() as u64) as usize])
                .collect();
            nl.set_outputs(outs);

            let opt = optimize(&nl);
            let before = nl.live_mask().iter().filter(|&&l| l).count();
            let after = opt.live_mask().iter().filter(|&&l| l).count();
            assert!(after <= before, "case {case}: {after} > {before}");
            for m in 0u32..(1 << n_in) {
                let ins: Vec<bool> = (0..n_in).map(|k| (m >> k) & 1 == 1).collect();
                assert_eq!(nl.eval(&ins), opt.eval(&ins), "case {case} m={m}");
            }
        }
    }

    #[test]
    fn optimize_is_idempotent_on_fixpoint() {
        let mut nl = Netlist::new(2);
        let (a, b) = (nl.input(0), nl.input(1));
        let g = nl.nand(a, b);
        nl.set_outputs(vec![g]);
        let once = optimize(&nl);
        let twice = optimize(&once);
        assert_eq!(
            once.live_mask().iter().filter(|&&l| l).count(),
            twice.live_mask().iter().filter(|&&l| l).count()
        );
    }
}
