//! Printed-electronics hardware substrate (substitution #2 in DESIGN.md §3).
//!
//! The paper synthesizes bespoke decision-tree RTL with Synopsys Design
//! Compiler against an inkjet-printed Electrolyte-Gated-Transistor (EGT)
//! PDK, and measures power with PrimeTime.  Neither tool nor PDK exists in
//! this image, so this module implements the part of that flow the paper's
//! results actually depend on:
//!
//! * [`egt`] — an EGT standard-cell library with per-cell area/power/delay
//!   calibrated to the published EGT regime (Bleier et al., ISCA'20).
//! * [`netlist`] — a gate-level netlist IR whose *builder* performs the
//!   boolean simplifications Design Compiler would: constant folding,
//!   double-negation elimination, structural hashing (CSE).
//! * [`synth`] — bespoke synthesis: hardwired-constant comparators (the
//!   source of the non-linear area(threshold) curve of Fig. 4) and full
//!   tree netlists (comparator bank → shared-prefix path logic → class
//!   encoder → output register).
//! * [`opt`] — the peephole/tech-mapping pass (INV absorption into
//!   NAND/NOR/XNOR, DeMorgan rewrites, dead-gate sweep).
//! * [`power`] — static-dominated EGT power model with signal-probability
//!   activity estimation for the (tiny) dynamic component.
//! * [`area_lut`] — the exhaustive bespoke-comparator characterization the
//!   genetic algorithm uses as its area oracle (paper §III-B).
//! * [`rtl`] — Verilog emission for exact and approximate bespoke trees.

pub mod area_lut;
pub mod egt;
pub mod netlist;
pub mod opt;
pub mod power;
pub mod rtl;
pub mod synth;
pub mod vote;

pub use area_lut::AreaLut;
pub use egt::{CellKind, EgtLibrary};
pub use netlist::{Netlist, Sig};

/// Synthesis report for one circuit (the numbers Table I / Table II report).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct HwReport {
    pub area_mm2: f64,
    pub power_mw: f64,
    pub delay_ms: f64,
    pub n_cells: usize,
}
