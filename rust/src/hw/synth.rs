//! Bespoke synthesis: hardwired-constant comparators and full decision-tree
//! netlists (the generator Design Compiler consumes in the paper's flow,
//! fused with the synthesis itself in ours).
//!
//! ## Bespoke comparator
//!
//! A decision-tree node computes `x ≤ T` with T *hardwired*.  The LSB→MSB
//! recurrence
//!
//! ```text
//!   le' = (x_i < t_i) ∨ ((x_i == t_i) ∧ le)
//!       = t_i ? (¬x_i ∨ le) : (¬x_i ∧ le),      le₀ = 1
//! ```
//!
//! constant-folds at every bit: trailing 1-bits of T cost *nothing*
//! (`¬x ∨ 1 = 1`), the first 0-bit collapses to a single inverter, and the
//! remaining bits cost one INV+OR/AND each — which the builder's DeMorgan
//! and absorption rules then map into NAND/NOR chains.  This bit-pattern
//! dependence is exactly the non-linear area(T) behaviour of the paper's
//! Fig. 4, and the reason threshold substitution (±m) finds cheaper
//! neighbours.
//!
//! ## Bespoke tree
//!
//! Physical interface: each *used* feature arrives as an
//! [`FEATURE_BITS`]-bit bus (code = ⌊x·2⁸⌋).  A comparator at precision
//! `b` consumes the bus's top `b` bits — precision scaling is literally
//! wiring fewer bits.  Path logic shares prefixes through per-node
//! "arrival" signals (`arrive(left) = arrive ∧ cmp`), leaves OR into a
//! binary class encoder, and class bits are registered through DFFs.

use super::netlist::{Netlist, Sig};
use super::opt;
use crate::dt::Tree;

/// Full-precision width of a feature input bus.
pub const FEATURE_BITS: u8 = 8;

/// Build `[x <= t]` over the `x` bit slice (LSB first). Hardwired `t`.
pub fn le_const(nl: &mut Netlist, x: &[Sig], t: u32) -> Sig {
    assert!(x.len() <= 31);
    assert!(t < (1u32 << x.len()), "threshold {t} out of range for {} bits", x.len());
    let mut le = Sig::Const(true);
    for (i, &xi) in x.iter().enumerate() {
        let nx = nl.not(xi);
        le = if (t >> i) & 1 == 1 {
            nl.or(nx, le)
        } else {
            nl.and(nx, le)
        };
    }
    le
}

/// Standalone bespoke comparator netlist at `bits` precision with
/// hardwired integer threshold `t` (the Fig. 4 / area-LUT unit).
pub fn synth_comparator(bits: u8, t: u32) -> Netlist {
    let mut nl = Netlist::new(bits as usize);
    let x: Vec<Sig> = (0..bits as usize).map(|i| nl.input(i)).collect();
    let out = le_const(&mut nl, &x, t);
    nl.set_outputs(vec![out]);
    opt::optimize(&nl)
}

/// A conventional (non-bespoke) b-bit comparator `x <= y` with *both*
/// operands as inputs — the ~5× baseline the paper contrasts bespoke
/// designs against (§II-B).
pub fn synth_generic_comparator(bits: u8) -> Netlist {
    let b = bits as usize;
    let mut nl = Netlist::new(2 * b);
    let x: Vec<Sig> = (0..b).map(|i| nl.input(i)).collect();
    let y: Vec<Sig> = (0..b).map(|i| nl.input(b + i)).collect();
    // le' = (x_i < y_i) | ((x_i == y_i) & le)
    let mut le = Sig::Const(true);
    for i in 0..b {
        let nx = nl.not(x[i]);
        let lt = nl.and(nx, y[i]);
        let eq = nl.xnor(x[i], y[i]);
        let keep = nl.and(eq, le);
        le = nl.or(lt, keep);
    }
    nl.set_outputs(vec![le]);
    opt::optimize(&nl)
}

/// Per-comparator approximation used when instantiating a tree netlist.
#[derive(Clone, Debug)]
pub struct TreeApprox {
    /// Precision (2..=8 bits) of each comparator slot.
    pub bits: Vec<u8>,
    /// Integer threshold of each comparator slot at its precision
    /// (already substituted toward its hardware-friendly neighbour).
    pub thr_int: Vec<u32>,
}

impl TreeApprox {
    /// The exact 8-bit baseline configuration for a tree ([1]'s design).
    pub fn exact(tree: &Tree) -> TreeApprox {
        let thr = tree.comparator_thresholds();
        TreeApprox {
            bits: vec![FEATURE_BITS; thr.len()],
            thr_int: thr
                .iter()
                .map(|&t| crate::quant::int_threshold(t, FEATURE_BITS))
                .collect(),
        }
    }
}

/// Result of tree synthesis: the netlist plus the feature→bus mapping.
#[derive(Clone, Debug)]
pub struct TreeCircuit {
    pub netlist: Netlist,
    /// Dense bus index per original feature id (only used features).
    pub feature_bus: std::collections::BTreeMap<usize, usize>,
    /// Output width (class-id bits).
    pub class_bits: usize,
}

/// Synthesize the bespoke netlist of `tree` under `approx`.
pub fn synth_tree(tree: &Tree, approx: &TreeApprox) -> TreeCircuit {
    let comp_feats = tree.comparator_features();

    // Dense feature bus mapping over used features.
    let mut feature_bus = std::collections::BTreeMap::new();
    for &f in &comp_feats {
        let next = feature_bus.len();
        feature_bus.entry(f).or_insert(next);
    }
    let mut nl = Netlist::new(feature_bus.len() * FEATURE_BITS as usize);
    let outs = synth_tree_into(&mut nl, tree, approx, &feature_bus);
    // Registered outputs (paper's designs are clocked at a relaxed 50 ms).
    let regs: Vec<Sig> = outs.into_iter().map(|o| nl.dff(o)).collect();
    let class_bits = regs.len();
    nl.set_outputs(regs);

    TreeCircuit {
        netlist: opt::optimize(&nl),
        feature_bus,
        class_bits,
    }
}

/// Instantiate one bespoke tree's combinational logic inside an existing
/// netlist (shared feature buses) and return its unregistered class-bit
/// signals.  Used by [`synth_tree`] and by the random-forest extension
/// ([`crate::hw::vote`]), which shares buses between member trees.
pub fn synth_tree_into(
    nl: &mut Netlist,
    tree: &Tree,
    approx: &TreeApprox,
    feature_bus: &std::collections::BTreeMap<usize, usize>,
) -> Vec<Sig> {
    let comp_feats = tree.comparator_features();
    let n = comp_feats.len();
    assert_eq!(approx.bits.len(), n);
    assert_eq!(approx.thr_int.len(), n);

    // Comparator bank. Slot j compares the top `bits[j]` bits of its
    // feature bus against thr_int[j].
    let cmp: Vec<Sig> = (0..n)
        .map(|j| {
            let b = approx.bits[j] as usize;
            assert!((1..=FEATURE_BITS as usize).contains(&b));
            assert!(approx.thr_int[j] < (1u32 << b));
            let bus = feature_bus[&comp_feats[j]];
            let base = bus * FEATURE_BITS as usize;
            // Top b bits of the bus, LSB-first slice: bus bits [8-b .. 8).
            let xs: Vec<Sig> = (FEATURE_BITS as usize - b..FEATURE_BITS as usize)
                .map(|k| nl.input(base + k))
                .collect();
            le_const(nl, &xs, approx.thr_int[j])
        })
        .collect();

    // Path logic: every leaf ANDs its root→leaf conditions.  A naive
    // arrival chain (`arrive(left) = arrive ∧ cmp`) is area-minimal but its
    // delay grows linearly with tree depth — deep grown-to-purity trees
    // would miss the paper's relaxed 50 ms clock.  We reduce each leaf's
    // condition list as a *balanced* AND tree instead (logarithmic depth,
    // the restructuring a timing-driven `compile` performs); structural
    // hashing still shares the aligned prefix subtrees between sibling
    // leaves, so the area overhead over the chain form stays small.
    let leaf_sig: std::collections::HashMap<usize, Sig> = {
        let paths = tree.leaf_paths();
        tree.leaf_nodes()
            .into_iter()
            .zip(paths)
            .map(|(leaf, path)| {
                let mut conds: Vec<Sig> = path
                    .iter()
                    .map(|&(slot, sense)| if sense { cmp[slot] } else { nl.not(cmp[slot]) })
                    .collect();
                // Pairwise balanced reduction, prefix-aligned for CSE.
                while conds.len() > 1 {
                    let mut next = Vec::with_capacity(conds.len().div_ceil(2));
                    for pair in conds.chunks(2) {
                        next.push(if pair.len() == 2 {
                            nl.and(pair[0], pair[1])
                        } else {
                            pair[0]
                        });
                    }
                    conds = next;
                }
                (leaf, conds.pop().unwrap_or(Sig::Const(true)))
            })
            .collect()
    };

    // Binary class encoder: bit m = OR of leaves whose class sets bit m,
    // reduced as a balanced tree (same timing argument as the path ANDs).
    let class_bits = bits_for_classes(tree.n_classes);
    let leaf_order = tree.leaf_nodes();
    let mut outs = Vec::with_capacity(class_bits);
    for m in 0..class_bits {
        let mut terms: Vec<Sig> = leaf_order
            .iter()
            .filter(|&&leaf| (tree.nodes[leaf].leaf_class as u32 >> m) & 1 == 1)
            .map(|leaf| leaf_sig[leaf])
            .collect();
        while terms.len() > 1 {
            let mut next = Vec::with_capacity(terms.len().div_ceil(2));
            for pair in terms.chunks(2) {
                next.push(if pair.len() == 2 { nl.or(pair[0], pair[1]) } else { pair[0] });
            }
            terms = next;
        }
        outs.push(terms.pop().unwrap_or(Sig::Const(false)));
    }
    outs
}

/// Bits needed to encode `n` class ids.
pub fn bits_for_classes(n: usize) -> usize {
    (usize::BITS - (n.max(2) - 1).leading_zeros()) as usize
}

/// Comparator slot per node index (-1 for leaves): the lookup table
/// [`predict_codes_with_slots`] walks.  Build it once per tree and reuse
/// it across samples — `Problem::slot_of_node` is this same table,
/// precomputed, for call sites that hold a `Problem`.
pub fn node_slots(tree: &Tree) -> Vec<i32> {
    let mut slots = vec![-1i32; tree.nodes.len()];
    for (slot, node) in tree.comparator_nodes().into_iter().enumerate() {
        slots[node] = slot as i32;
    }
    slots
}

/// Reference prediction on feature *codes* (8-bit ints) with the same
/// precision-truncation semantics the hardware uses — the oracle the
/// netlist is verified against, and the scalar core of the native fitness
/// engine.  `slots` is the tree's [`node_slots`] table, hoisted by the
/// caller so per-sample loops pay no allocation or hashing.
pub fn predict_codes_with_slots(
    tree: &Tree,
    slots: &[i32],
    approx: &TreeApprox,
    codes: &[u32],
) -> u32 {
    let mut i = 0usize;
    loop {
        let n = &tree.nodes[i];
        if n.is_leaf() {
            return n.leaf_class as u32;
        }
        let j = slots[i] as usize;
        let code_b = codes[n.feat as usize] >> (FEATURE_BITS - approx.bits[j]);
        i = if code_b <= approx.thr_int[j] {
            n.left as usize
        } else {
            n.right as usize
        };
    }
}

/// One-shot convenience over [`predict_codes_with_slots`].  Builds the
/// slot table per call — loops over samples should hoist it instead.
pub fn predict_codes(tree: &Tree, approx: &TreeApprox, codes: &[u32]) -> u32 {
    predict_codes_with_slots(tree, &node_slots(tree), approx, codes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators;
    use crate::dt::{train, TrainConfig};
    use crate::hw::egt::EgtLibrary;
    use crate::util::rng::Pcg64;

    #[test]
    fn le_const_exhaustive_all_thresholds() {
        // Every (bits, T) pair up to 6 bits, every input: netlist == spec.
        for bits in 1..=6u8 {
            for t in 0..(1u32 << bits) {
                let nl = synth_comparator(bits, t);
                for x in 0..(1u32 << bits) {
                    let ins: Vec<bool> = (0..bits).map(|i| (x >> i) & 1 == 1).collect();
                    assert_eq!(
                        nl.eval(&ins)[0],
                        x <= t,
                        "bits={bits} t={t} x={x}"
                    );
                }
            }
        }
    }

    #[test]
    fn le_const_8bit_spot_checks() {
        for &t in &[0u32, 1, 127, 128, 200, 254, 255] {
            let nl = synth_comparator(8, t);
            for &x in &[0u32, 1, t.saturating_sub(1), t, (t + 1).min(255), 255] {
                let ins: Vec<bool> = (0..8).map(|i| (x >> i) & 1 == 1).collect();
                assert_eq!(nl.eval(&ins)[0], x <= t, "t={t} x={x}");
            }
        }
    }

    #[test]
    fn comparator_area_depends_on_bit_pattern() {
        let lib = EgtLibrary::default();
        // All-ones threshold: always true, zero logic.
        let free = synth_comparator(8, 255);
        assert_eq!(free.area_mm2(&lib), 0.0);
        // 0b01111111 vs 0b10101010: sparse patterns cost more.
        let cheap = synth_comparator(8, 127).area_mm2(&lib);
        let costly = synth_comparator(8, 0b10101010).area_mm2(&lib);
        assert!(cheap < costly, "cheap={cheap} costly={costly}");
    }

    #[test]
    fn bespoke_beats_generic_by_big_factor() {
        // Paper §II-B: a generic 8-bit comparator is ~5× larger than its
        // bespoke instances on average.
        let lib = EgtLibrary::default();
        let generic = synth_generic_comparator(8).area_mm2(&lib);
        let mean_bespoke: f64 =
            (0..256).map(|t| synth_comparator(8, t).area_mm2(&lib)).sum::<f64>() / 256.0;
        let factor = generic / mean_bespoke;
        assert!(factor > 3.0, "factor {factor}");
    }

    #[test]
    fn generic_comparator_correct() {
        let nl = synth_generic_comparator(4);
        for x in 0..16u32 {
            for y in 0..16u32 {
                let mut ins = vec![false; 8];
                for i in 0..4 {
                    ins[i] = (x >> i) & 1 == 1;
                    ins[4 + i] = (y >> i) & 1 == 1;
                }
                assert_eq!(nl.eval(&ins)[0], x <= y, "x={x} y={y}");
            }
        }
    }

    #[test]
    fn class_bit_width() {
        assert_eq!(bits_for_classes(2), 1);
        assert_eq!(bits_for_classes(3), 2);
        assert_eq!(bits_for_classes(4), 2);
        assert_eq!(bits_for_classes(10), 4);
        assert_eq!(bits_for_classes(13), 4);
    }

    /// Full tree netlist equals the code-level walk for random inputs and
    /// random mixed-precision approximations.
    #[test]
    fn tree_netlist_matches_walk() {
        let spec = generators::spec("seeds").unwrap();
        let data = generators::generate(spec, 5);
        let tree = train(&data, &TrainConfig { max_leaves: 12, min_samples_split: 2 });
        let slots = node_slots(&tree);
        let mut rng = Pcg64::seeded(0x7EE);

        for case in 0..8 {
            let n = tree.n_comparators();
            let approx = if case == 0 {
                TreeApprox::exact(&tree)
            } else {
                let bits: Vec<u8> =
                    (0..n).map(|_| rng.int_in(2, 8) as u8).collect();
                let thr = tree.comparator_thresholds();
                let thr_int: Vec<u32> = (0..n)
                    .map(|j| {
                        let t = crate::quant::int_threshold(thr[j], bits[j]);
                        crate::quant::substitute(t, rng.int_in(-5, 5) as i32, bits[j])
                    })
                    .collect();
                TreeApprox { bits, thr_int }
            };
            let circuit = synth_tree(&tree, &approx);

            for _ in 0..64 {
                let codes: Vec<u32> =
                    (0..data.n_features).map(|_| rng.below(256) as u32).collect();
                // Pack the used-feature buses.
                let mut ins = vec![false; circuit.netlist.n_inputs];
                for (&feat, &bus) in &circuit.feature_bus {
                    for k in 0..FEATURE_BITS as usize {
                        ins[bus * FEATURE_BITS as usize + k] = (codes[feat] >> k) & 1 == 1;
                    }
                }
                let out = circuit.netlist.eval(&ins);
                let got: u32 = out
                    .iter()
                    .enumerate()
                    .map(|(m, &b)| (b as u32) << m)
                    .sum();
                let want = predict_codes_with_slots(&tree, &slots, &approx, &codes);
                assert_eq!(got, want, "case {case} codes {codes:?}");
            }
        }
    }

    #[test]
    fn tree_report_in_printed_regime() {
        let lib = EgtLibrary::default();
        let spec = generators::spec("seeds").unwrap();
        let data = generators::generate(spec, 42);
        let (train_d, _) = data.split(0.3, 42);
        let tree = train(&train_d, &TrainConfig { max_leaves: spec.max_leaves, min_samples_split: 2 });
        let circuit = synth_tree(&tree, &TreeApprox::exact(&tree));
        let rep = circuit.netlist.report(&lib);
        // Seeds in Table I: 30.13 mm², 1.43 mW, 20.3 ms. Same order of
        // magnitude is what the calibration targets.
        assert!(rep.area_mm2 > 5.0 && rep.area_mm2 < 120.0, "area {}", rep.area_mm2);
        assert!(rep.power_mw > 0.2 && rep.power_mw < 6.0, "power {}", rep.power_mw);
        assert!(rep.delay_ms > 5.0 && rep.delay_ms < 60.0, "delay {}", rep.delay_ms);
    }

    #[test]
    fn lower_precision_never_larger() {
        // Truncating inputs can only remove logic for the same threshold
        // pattern class; verify the aggregate trend on a real tree.
        let lib = EgtLibrary::default();
        let spec = generators::spec("vertebral").unwrap();
        let data = generators::generate(spec, 9);
        let tree = train(&data, &TrainConfig { max_leaves: 16, min_samples_split: 2 });
        let n = tree.n_comparators();
        let thr = tree.comparator_thresholds();
        let area_at = |bits: u8| {
            let approx = TreeApprox {
                bits: vec![bits; n],
                thr_int: thr.iter().map(|&t| crate::quant::int_threshold(t, bits)).collect(),
            };
            synth_tree(&tree, &approx).netlist.area_mm2(&lib)
        };
        let a2 = area_at(2);
        let a4 = area_at(4);
        let a8 = area_at(8);
        assert!(a2 < a4 && a4 < a8, "a2={a2} a4={a4} a8={a8}");
    }
}
