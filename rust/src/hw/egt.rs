//! Inkjet-printed EGT (electrolyte-gated transistor) standard-cell library.
//!
//! Printed EGT logic is large (µm-scale features) and slow (ms-scale gate
//! delays), and its power is dominated by static draw — properties this
//! library encodes per cell.  Absolute numbers are calibrated (see
//! DESIGN.md §3 substitution #2, EXPERIMENTS.md §Calibration) so that the
//! paper's exact 8-bit bespoke trees land in Table I's measured regime:
//! areas of tens–hundreds of mm², powers of 1–26 mW, delays of 20–50 ms.
//!
//! Relative cell costs follow standard static-logic transistor counts
//! (INV 2T, NAND/NOR 4T, AND/OR 6T, XOR/XNOR 10T, DFF ~18T) scaled by the
//! printed EGT footprint-per-transistor.

/// Gate kinds representable in the netlist IR.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum CellKind {
    Inv,
    Buf,
    Nand2,
    Nor2,
    And2,
    Or2,
    Xor2,
    Xnor2,
    Dff,
}

pub const ALL_KINDS: &[CellKind] = &[
    CellKind::Inv,
    CellKind::Buf,
    CellKind::Nand2,
    CellKind::Nor2,
    CellKind::And2,
    CellKind::Or2,
    CellKind::Xor2,
    CellKind::Xnor2,
    CellKind::Dff,
];

/// Physical characteristics of one cell.
#[derive(Clone, Copy, Debug)]
pub struct CellParams {
    /// Printed footprint.
    pub area_mm2: f64,
    /// Static power draw (EGT logic is ratioed: always-on pull path).
    pub static_uw: f64,
    /// Switching energy surrogate: dynamic power per unit activity at the
    /// relaxed evaluation clock (µW at α = 1).
    pub dynamic_uw: f64,
    /// Propagation delay.
    pub delay_ms: f64,
}

/// The EGT cell library.
#[derive(Clone, Debug)]
pub struct EgtLibrary {
    /// Footprint of one printed transistor, mm².
    pub mm2_per_transistor: f64,
    /// Static draw per transistor, µW.
    pub uw_per_transistor: f64,
    /// Baseline gate delay, ms.
    pub base_delay_ms: f64,
}

impl Default for EgtLibrary {
    fn default() -> Self {
        // Calibration (EXPERIMENTS.md §Calibration): chosen so an average
        // exact 8-bit bespoke comparator + its share of tree logic comes to
        // ~2–3 mm² and ~0.1 mW, matching Table I per-comparator densities,
        // with power/area ≈ 0.047 mW/mm² as across all Table I rows.
        EgtLibrary {
            mm2_per_transistor: 0.045,
            uw_per_transistor: 2.1,
            base_delay_ms: 0.85,
        }
    }
}

impl EgtLibrary {
    /// Transistor count of a static CMOS-style EGT implementation.
    pub fn transistors(kind: CellKind) -> u32 {
        match kind {
            CellKind::Inv => 2,
            CellKind::Buf => 4,
            CellKind::Nand2 => 4,
            CellKind::Nor2 => 4,
            CellKind::And2 => 6,
            CellKind::Or2 => 6,
            CellKind::Xor2 => 10,
            CellKind::Xnor2 => 10,
            CellKind::Dff => 18,
        }
    }

    /// Relative delay factor (series stacks and pass-gate structures are
    /// slower in printed EGT).
    fn delay_factor(kind: CellKind) -> f64 {
        match kind {
            CellKind::Inv => 1.0,
            CellKind::Buf => 1.6,
            CellKind::Nand2 => 1.25,
            CellKind::Nor2 => 1.45,
            CellKind::And2 => 1.8,
            CellKind::Or2 => 1.95,
            CellKind::Xor2 => 2.6,
            CellKind::Xnor2 => 2.6,
            CellKind::Dff => 3.2,
        }
    }

    /// Full parameters for a cell kind.
    pub fn cell(&self, kind: CellKind) -> CellParams {
        let t = Self::transistors(kind) as f64;
        CellParams {
            area_mm2: t * self.mm2_per_transistor,
            static_uw: t * self.uw_per_transistor,
            // EGT dynamic power at ~20-50 Hz evaluation rates is a small
            // fraction of static; scale with transistor count.
            dynamic_uw: 0.12 * t * self.uw_per_transistor,
            delay_ms: self.base_delay_ms * Self::delay_factor(kind),
        }
    }

    pub fn area(&self, kind: CellKind) -> f64 {
        self.cell(kind).area_mm2
    }
    pub fn static_power_uw(&self, kind: CellKind) -> f64 {
        self.cell(kind).static_uw
    }
    pub fn delay(&self, kind: CellKind) -> f64 {
        self.cell(kind).delay_ms
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_cells_have_positive_params() {
        let lib = EgtLibrary::default();
        for &k in ALL_KINDS {
            let c = lib.cell(k);
            assert!(c.area_mm2 > 0.0 && c.static_uw > 0.0 && c.delay_ms > 0.0, "{k:?}");
        }
    }

    #[test]
    fn relative_costs_are_sane() {
        let lib = EgtLibrary::default();
        assert!(lib.area(CellKind::Inv) < lib.area(CellKind::Nand2));
        assert!(lib.area(CellKind::Nand2) < lib.area(CellKind::And2));
        assert!(lib.area(CellKind::And2) < lib.area(CellKind::Xor2));
        assert!(lib.area(CellKind::Xor2) < lib.area(CellKind::Dff));
        // NAND cheaper than AND: tech-mapping has something to exploit.
        assert!(lib.area(CellKind::Nand2) + lib.area(CellKind::Inv) > lib.area(CellKind::Nand2));
    }

    #[test]
    fn power_area_ratio_in_table1_regime() {
        // Table I rows all show power/area ≈ 0.043–0.047 mW/mm².
        let lib = EgtLibrary::default();
        for &k in ALL_KINDS {
            let c = lib.cell(k);
            let ratio = (c.static_uw * 1e-3) / c.area_mm2; // mW per mm²
            assert!((0.03..0.07).contains(&ratio), "{k:?}: {ratio}");
        }
    }

    #[test]
    fn gate_delays_in_printed_regime() {
        // Printed EGT gates switch in ~0.5–2 ms; a ~30-level path then
        // lands in Table I's 20–50 ms delay band.
        let lib = EgtLibrary::default();
        for &k in ALL_KINDS {
            let d = lib.delay(k);
            assert!((0.5..3.0).contains(&d), "{k:?}: {d} ms");
        }
    }
}
