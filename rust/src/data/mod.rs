//! Dataset substrate.
//!
//! The paper evaluates on 10 UCI repository datasets.  This image has no
//! network access, so [`generators`] synthesizes, per dataset, a
//! classification problem matching the real dataset's cardinality
//! (n_samples, n_features, n_classes) with difficulty knobs tuned so the
//! exact bespoke tree lands near the paper's Table I baseline accuracy
//! (substitution #1 in DESIGN.md §3).
//!
//! Features are min-max normalized to [0, 1] and split 70/30 train/test with
//! a seeded shuffle — exactly the preprocessing the paper describes.

pub mod generators;

use crate::util::rng::Pcg64;

/// A dense classification dataset, features row-major `[n_samples, n_features]`.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub x: Vec<f32>,
    pub y: Vec<u32>,
    pub n_samples: usize,
    pub n_features: usize,
    pub n_classes: usize,
}

impl Dataset {
    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.n_features..(i + 1) * self.n_features]
    }

    /// Min-max normalize every feature to [0, 1] in place (paper §IV:
    /// "normalized training data in the interval [0, 1]").
    ///
    /// Constant features map to 0.0.
    pub fn normalize(&mut self) {
        for f in 0..self.n_features {
            let mut lo = f32::INFINITY;
            let mut hi = f32::NEG_INFINITY;
            for s in 0..self.n_samples {
                let v = self.x[s * self.n_features + f];
                lo = lo.min(v);
                hi = hi.max(v);
            }
            let span = hi - lo;
            for s in 0..self.n_samples {
                let v = &mut self.x[s * self.n_features + f];
                *v = if span > 0.0 { (*v - lo) / span } else { 0.0 };
            }
        }
    }

    /// Seeded random split; `test_frac` of samples go to the test set.
    pub fn split(&self, test_frac: f64, seed: u64) -> (Dataset, Dataset) {
        let mut idx: Vec<usize> = (0..self.n_samples).collect();
        let mut rng = Pcg64::new(seed, 0x5117);
        rng.shuffle(&mut idx);
        let n_test = ((self.n_samples as f64) * test_frac).round() as usize;
        let (test_idx, train_idx) = idx.split_at(n_test);
        (self.subset(train_idx, "train"), self.subset(test_idx, "test"))
    }

    fn subset(&self, idx: &[usize], tag: &str) -> Dataset {
        let mut x = Vec::with_capacity(idx.len() * self.n_features);
        let mut y = Vec::with_capacity(idx.len());
        for &i in idx {
            x.extend_from_slice(self.row(i));
            y.push(self.y[i]);
        }
        Dataset {
            name: format!("{}/{}", self.name, tag),
            x,
            y,
            n_samples: idx.len(),
            n_features: self.n_features,
            n_classes: self.n_classes,
        }
    }

    /// Class histogram (sanity checks + stratification tests).
    pub fn class_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.n_classes];
        for &c in &self.y {
            counts[c as usize] += 1;
        }
        counts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        Dataset {
            name: "toy".into(),
            x: vec![0.0, 10.0, 1.0, 20.0, 2.0, 30.0, 3.0, 40.0],
            y: vec![0, 1, 0, 1],
            n_samples: 4,
            n_features: 2,
            n_classes: 2,
        }
    }

    #[test]
    fn normalize_maps_to_unit_interval() {
        let mut d = toy();
        d.normalize();
        for f in 0..2 {
            let vals: Vec<f32> = (0..4).map(|s| d.x[s * 2 + f]).collect();
            assert_eq!(vals, vec![0.0, 1.0 / 3.0, 2.0 / 3.0, 1.0]);
        }
    }

    #[test]
    fn normalize_constant_feature_is_zero() {
        let mut d = toy();
        for s in 0..4 {
            d.x[s * 2] = 7.0;
        }
        d.normalize();
        for s in 0..4 {
            assert_eq!(d.x[s * 2], 0.0);
        }
    }

    #[test]
    fn split_sizes_and_disjointness() {
        let mut rng = Pcg64::seeded(1);
        let n = 100;
        let d = Dataset {
            name: "r".into(),
            x: (0..n).map(|i| i as f32).collect(),
            y: (0..n).map(|_| rng.below(3) as u32).collect(),
            n_samples: n,
            n_features: 1,
            n_classes: 3,
        };
        let (train, test) = d.split(0.3, 42);
        assert_eq!(test.n_samples, 30);
        assert_eq!(train.n_samples, 70);
        // Feature values are unique ids here: verify disjoint + complete.
        let mut all: Vec<i64> = train.x.iter().chain(test.x.iter()).map(|&v| v as i64).collect();
        all.sort_unstable();
        assert_eq!(all, (0..n as i64).collect::<Vec<_>>());
    }

    #[test]
    fn split_deterministic_in_seed() {
        let d = toy();
        let (a1, _) = d.split(0.5, 9);
        let (a2, _) = d.split(0.5, 9);
        let (b1, _) = d.split(0.5, 10);
        assert_eq!(a1.x, a2.x);
        assert_ne!(a1.x, b1.x);
    }
}
