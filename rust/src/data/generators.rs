//! Synthetic UCI-mimetic dataset generators.
//!
//! Each generator reproduces the *cardinality* of the corresponding UCI
//! dataset (n_samples, n_features, n_classes) and is tuned, via the
//! difficulty knobs below, so the exact bespoke decision tree's test
//! accuracy lands near the paper's Table I baseline.  The model is a
//! Gaussian mixture: every class owns `clusters_per_class` axis-aligned
//! Gaussian blobs over an informative-feature subspace; remaining features
//! are uniform noise; a `label_noise` fraction of samples gets a random
//! label (this is the main accuracy-ceiling knob, mimicking the class
//! overlap that makes e.g. the wine datasets hard).

use super::Dataset;
use crate::util::rng::Pcg64;

/// Static description of one benchmark dataset.
#[derive(Clone, Debug)]
pub struct DatasetSpec {
    /// Canonical lowercase id, e.g. "cardio".
    pub id: &'static str,
    /// Display name as in the paper's tables.
    pub display: &'static str,
    pub n_samples: usize,
    pub n_features: usize,
    pub n_classes: usize,
    /// Informative features (rest are uniform noise).
    pub n_informative: usize,
    /// Gaussian blobs per class.
    pub clusters_per_class: usize,
    /// Cluster σ relative to the unit feature cube: higher = more overlap.
    pub cluster_std: f64,
    /// Fraction of labels replaced with a uniformly random class.
    pub label_noise: f64,
    /// Quantize features onto k discrete levels (ordinal/categorical
    /// datasets like Balance and Mammographic: their thresholds land on a
    /// coarse grid, which is exactly why the paper's bespoke comparators
    /// for them are so cheap). `0` = continuous.
    pub discrete_levels: u32,
    /// Probability mass of class 0 (imbalanced datasets: Arrhythmia's
    /// "normal" class, the wines' middle quality grades). `0.0` = uniform.
    pub majority_frac: f64,
    /// Best-first leaf cap when training the exact tree = paper's #Comp + 1,
    /// mirroring the paper's reported comparator counts (Table I).
    pub max_leaves: usize,
    /// Paper's Table I baseline (for EXPERIMENTS.md comparisons).
    pub paper_accuracy: f64,
    pub paper_comparators: usize,
    pub paper_area_mm2: f64,
    pub paper_power_mw: f64,
    pub paper_delay_ms: f64,
}

/// The 10 evaluation datasets, in the paper's Table I order.
///
/// `cluster_std` / `label_noise` were calibrated against the exact-tree
/// harness (see EXPERIMENTS.md §Table I) so baseline accuracies track the
/// paper within a few points.
pub const SPECS: &[DatasetSpec] = &[
    DatasetSpec {
        id: "arrhythmia", display: "Arrhythmia",
        n_samples: 452, n_features: 279, n_classes: 13,
        n_informative: 24, clusters_per_class: 2,
        cluster_std: 0.15, label_noise: 0.085,
        discrete_levels: 0,
        majority_frac: 0.60,
        max_leaves: 55,
        paper_accuracy: 0.564, paper_comparators: 54,
        paper_area_mm2: 162.50, paper_power_mw: 7.55, paper_delay_ms: 27.0,
    },
    DatasetSpec {
        id: "balance", display: "Balance",
        n_samples: 625, n_features: 4, n_classes: 3,
        n_informative: 4, clusters_per_class: 3,
        cluster_std: 0.12, label_noise: 0.045,
        discrete_levels: 5,
        majority_frac: 0.0,
        max_leaves: 103,
        paper_accuracy: 0.745, paper_comparators: 102,
        paper_area_mm2: 68.04, paper_power_mw: 3.11, paper_delay_ms: 28.0,
    },
    DatasetSpec {
        id: "cardio", display: "Cardio",
        n_samples: 2126, n_features: 21, n_classes: 3,
        n_informative: 10, clusters_per_class: 2,
        cluster_std: 0.10, label_noise: 0.030,
        discrete_levels: 0,
        majority_frac: 0.0,
        max_leaves: 80,
        paper_accuracy: 0.928, paper_comparators: 79,
        paper_area_mm2: 178.63, paper_power_mw: 8.12, paper_delay_ms: 30.4,
    },
    DatasetSpec {
        id: "har", display: "HAR",
        n_samples: 10299, n_features: 561, n_classes: 6,
        n_informative: 40, clusters_per_class: 3,
        cluster_std: 0.14, label_noise: 0.08,
        discrete_levels: 0,
        majority_frac: 0.0,
        max_leaves: 179,
        paper_accuracy: 0.835, paper_comparators: 178,
        paper_area_mm2: 551.08, paper_power_mw: 26.10, paper_delay_ms: 33.7,
    },
    DatasetSpec {
        id: "mammographic", display: "Mammogr.",
        n_samples: 961, n_features: 5, n_classes: 2,
        n_informative: 5, clusters_per_class: 2,
        cluster_std: 0.15, label_noise: 0.115,
        discrete_levels: 6,
        majority_frac: 0.0,
        max_leaves: 151,
        paper_accuracy: 0.759, paper_comparators: 150,
        paper_area_mm2: 98.75, paper_power_mw: 4.47, paper_delay_ms: 34.2,
    },
    DatasetSpec {
        id: "pendigits", display: "PenDigits",
        n_samples: 10992, n_features: 16, n_classes: 10,
        n_informative: 16, clusters_per_class: 2,
        cluster_std: 0.09, label_noise: 0.008,
        discrete_levels: 101,
        majority_frac: 0.0,
        max_leaves: 244,
        paper_accuracy: 0.968, paper_comparators: 243,
        paper_area_mm2: 574.46, paper_power_mw: 25.00, paper_delay_ms: 36.9,
    },
    DatasetSpec {
        id: "redwine", display: "RedWine",
        n_samples: 1599, n_features: 11, n_classes: 6,
        n_informative: 8, clusters_per_class: 2,
        cluster_std: 0.135, label_noise: 0.135,
        discrete_levels: 0,
        majority_frac: 0.42,
        max_leaves: 260,
        paper_accuracy: 0.600, paper_comparators: 259,
        paper_area_mm2: 513.84, paper_power_mw: 22.30, paper_delay_ms: 38.7,
    },
    DatasetSpec {
        id: "seeds", display: "Seeds",
        n_samples: 210, n_features: 7, n_classes: 3,
        n_informative: 7, clusters_per_class: 1,
        cluster_std: 0.18, label_noise: 0.06,
        discrete_levels: 0,
        majority_frac: 0.0,
        max_leaves: 11,
        paper_accuracy: 0.889, paper_comparators: 10,
        paper_area_mm2: 30.13, paper_power_mw: 1.43, paper_delay_ms: 20.3,
    },
    DatasetSpec {
        id: "vertebral", display: "Vertebral",
        n_samples: 310, n_features: 6, n_classes: 3,
        n_informative: 6, clusters_per_class: 1,
        cluster_std: 0.125, label_noise: 0.08,
        discrete_levels: 0,
        majority_frac: 0.0,
        max_leaves: 28,
        paper_accuracy: 0.850, paper_comparators: 27,
        paper_area_mm2: 57.70, paper_power_mw: 2.68, paper_delay_ms: 20.9,
    },
    DatasetSpec {
        id: "whitewine", display: "WhiteWine",
        n_samples: 4898, n_features: 11, n_classes: 7,
        n_informative: 8, clusters_per_class: 2,
        cluster_std: 0.15, label_noise: 0.24,
        discrete_levels: 0,
        majority_frac: 0.44,
        max_leaves: 281,
        paper_accuracy: 0.617, paper_comparators: 280,
        paper_area_mm2: 543.12, paper_power_mw: 23.20, paper_delay_ms: 49.9,
    },
];

/// Look up a spec by id (case-insensitive).
pub fn spec(id: &str) -> Option<&'static DatasetSpec> {
    let id = id.to_ascii_lowercase();
    SPECS.iter().find(|s| s.id == id)
}

/// All dataset ids, paper order.
pub fn all_ids() -> Vec<&'static str> {
    SPECS.iter().map(|s| s.id).collect()
}

/// Generate the dataset for `spec`, normalized to [0, 1].
///
/// Deterministic in `(spec.id, seed)`.
pub fn generate(spec: &DatasetSpec, seed: u64) -> Dataset {
    let mut rng = Pcg64::new(seed ^ crate::util::rng::fnv1a(spec.id.as_bytes()), 1);
    let k = spec.n_classes * spec.clusters_per_class;

    // Cluster centers in the informative subspace, kept away from the cube
    // walls so σ doesn't truncate asymmetrically.
    let centers: Vec<Vec<f64>> = (0..k)
        .map(|_| (0..spec.n_informative).map(|_| rng.range_f64(0.15, 0.85)).collect())
        .collect();

    let mut x = vec![0f32; spec.n_samples * spec.n_features];
    let mut y = vec![0u32; spec.n_samples];
    for s in 0..spec.n_samples {
        let class = if spec.majority_frac > 0.0 && rng.chance(spec.majority_frac) {
            0
        } else if spec.majority_frac > 0.0 {
            1 + rng.below(spec.n_classes as u64 - 1) as usize
        } else {
            rng.below(spec.n_classes as u64) as usize
        };
        let cluster = class * spec.clusters_per_class
            + rng.below(spec.clusters_per_class as u64) as usize;
        let row = &mut x[s * spec.n_features..(s + 1) * spec.n_features];
        for f in 0..spec.n_features {
            row[f] = if f < spec.n_informative {
                rng.normal_ms(centers[cluster][f], spec.cluster_std) as f32
            } else {
                rng.f32() // pure noise feature
            };
        }
        y[s] = if rng.chance(spec.label_noise) {
            rng.below(spec.n_classes as u64) as u32
        } else {
            class as u32
        };
    }

    // Ordinal datasets: snap features onto a discrete grid.
    if spec.discrete_levels > 1 {
        let k = (spec.discrete_levels - 1) as f32;
        for v in x.iter_mut() {
            *v = ((v.clamp(0.0, 1.0) * k).round()) / k;
        }
    }

    let mut d = Dataset {
        name: spec.id.to_string(),
        x,
        y,
        n_samples: spec.n_samples,
        n_features: spec.n_features,
        n_classes: spec.n_classes,
    };
    d.normalize();
    d
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ten_specs_in_paper_order() {
        assert_eq!(SPECS.len(), 10);
        assert_eq!(SPECS[0].id, "arrhythmia");
        assert_eq!(SPECS[9].id, "whitewine");
    }

    #[test]
    fn cardinalities_match_table() {
        let s = spec("pendigits").unwrap();
        assert_eq!((s.n_samples, s.n_features, s.n_classes), (10992, 16, 10));
        let h = spec("har").unwrap();
        assert_eq!((h.n_samples, h.n_features, h.n_classes), (10299, 561, 6));
    }

    #[test]
    fn generate_is_deterministic_and_normalized() {
        let s = spec("seeds").unwrap();
        let a = generate(s, 42);
        let b = generate(s, 42);
        let c = generate(s, 43);
        assert_eq!(a.x, b.x);
        assert_ne!(a.x, c.x);
        assert!(a.x.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn labels_in_range_and_all_classes_present() {
        for s in SPECS {
            let d = generate(s, 7);
            assert!(d.y.iter().all(|&c| (c as usize) < s.n_classes));
            let counts = d.class_counts();
            assert!(
                counts.iter().all(|&c| c > 0),
                "{}: class histogram {counts:?}",
                s.id
            );
        }
    }

    #[test]
    fn spec_lookup_case_insensitive() {
        assert!(spec("Seeds").is_some());
        assert!(spec("SEEDS").is_some());
        assert!(spec("nope").is_none());
    }

    #[test]
    fn informative_features_carry_signal() {
        // Class-conditional means must differ more on informative features
        // than on noise features.
        let s = spec("cardio").unwrap();
        let d = generate(s, 3);
        let mean_for = |class: u32, f: usize| -> f64 {
            let mut sum = 0.0;
            let mut n = 0.0;
            for i in 0..d.n_samples {
                if d.y[i] == class {
                    sum += d.x[i * d.n_features + f] as f64;
                    n += 1.0;
                }
            }
            sum / n
        };
        let spread = |f: usize| -> f64 {
            let ms: Vec<f64> = (0..s.n_classes as u32).map(|c| mean_for(c, f)).collect();
            let lo = ms.iter().cloned().fold(f64::INFINITY, f64::min);
            let hi = ms.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            hi - lo
        };
        let info: f64 = (0..s.n_informative).map(spread).sum::<f64>() / s.n_informative as f64;
        let noise: f64 = (s.n_informative..s.n_features).map(spread).sum::<f64>()
            / (s.n_features - s.n_informative) as f64;
        assert!(info > 2.0 * noise, "info spread {info} vs noise {noise}");
    }
}
