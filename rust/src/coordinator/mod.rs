//! L3 coordinator: the evaluation service and the optimization driver.
//!
//! The paper's framework is an optimization *service*: many GA populations
//! (one per dataset, possibly concurrent) need fitness evaluated, and the
//! expensive part — accuracy over the test set — runs on an accelerator
//! artifact with fixed shapes.  The coordinator owns that traffic:
//!
//! * [`service::EvalService`] — a leader thread that owns the PJRT runtime;
//!   clients register problems (routing them to a shape bucket, uploading
//!   static tensors once) and submit chromosome batches over channels.  The
//!   service splits/pads batches to the artifact's population width,
//!   executes, and replies.  Tokio is not available in this image, so the
//!   event loop is plain `std::sync::mpsc` + threads.
//! * [`service::XlaEngine`] — the client-side [`AccuracyEngine`] facade that
//!   makes the service pluggable wherever the native engine is.
//! * [`metrics::Metrics`] — execution counters (executions, chromosomes,
//!   padding waste, cache traffic, latency) surfaced by the CLI.
//! * [`driver`] — the per-dataset pipeline: generate → split → train →
//!   [`crate::fitness::Problem`] → NSGA-II → pareto front with *measured*
//!   (fully synthesized) area/power for every front design.
//!
//! [`AccuracyEngine`]: crate::fitness::AccuracyEngine

pub mod driver;
pub mod metrics;
pub mod service;

pub use driver::{optimize_dataset, DatasetRun, EngineChoice, ParetoPoint, RunOptions};
pub use metrics::Metrics;
pub use service::{EvalService, XlaEngine};
