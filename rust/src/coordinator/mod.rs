//! L3 coordinator: the sharded evaluation service and the optimization
//! driver.
//!
//! The paper's framework is an optimization *service*: many GA populations
//! (one per dataset, possibly concurrent) need fitness evaluated, and the
//! expensive part — accuracy over the test set — runs on an accelerator
//! artifact with fixed shapes.  The coordinator owns that traffic:
//!
//! * [`shard::EvalShardPool`] — N worker threads, each owning its own
//!   backend instance (its own PJRT client for XLA).  Problems hash-route
//!   to a stable shard ([`shard::ProblemId`] records it), and each worker
//!   fronts its backend with a coalescer that merges sub-width batches
//!   from concurrent drivers into one padded execution (flushing on
//!   width-full or a small deadline).  Evaluation is two-phase:
//!   `submit` returns a [`shard::Ticket`] without blocking, `wait`
//!   redeems it (in any order), and the blocking `eval` is
//!   `wait(submit(..))` — one driver can keep every shard busy by
//!   submitting micro-batches before collecting.  Tokio is not available
//!   in this image, so the event loops are plain `std::sync::mpsc` +
//!   threads.
//!   Workers are panic-safe: a backend panic downs only its shard (typed
//!   [`service::ServiceError::ShardDown`] to everyone it strands),
//!   registrations re-route to live shards, and `--respawn-shards` opts
//!   into one replacement worker per shard.
//! * [`service::EvalService`] — the thin client facade over the pool:
//!   seed-era call sites unchanged, plus the [`shard::PoolOptions`] knobs
//!   (`--workers`, `--coalesce adaptive|fixed|off`, `--coalesce-window-us`,
//!   `--coalesce-window-max-us`) and typed [`service::ServiceError`]
//!   results.  Every worker deadline reads the pool's injected
//!   [`Clock`](crate::util::clock::Clock) (the `*_with_clock`
//!   constructors), so the timing surface is testable without sleeps.
//! * [`service::XlaEngine`] — the client-side [`AccuracyEngine`] facade
//!   that makes the service pluggable wherever the native engine is; it
//!   transparently re-registers once and retries on a stale
//!   [`shard::ProblemId`].
//! * [`metrics::Metrics`] / [`metrics::ShardMetrics`] — execution counters
//!   (executions, chromosomes, padding waste, coalesced-batch widths,
//!   per-shard queue depth) surfaced in the run report, with hot-path
//!   latencies in bounded log₂ histograms
//!   ([`crate::util::stats::Log2Histogram`]), the ticket-lifecycle
//!   [`crate::util::trace::TraceJournal`] (`--trace-out`), and the
//!   [`metrics::SnapshotEmitter`] live JSON gauge stream
//!   (`--metrics-interval-ms`).
//! * [`driver`] — the per-dataset pipeline: generate → split → train →
//!   [`crate::fitness::Problem`] → NSGA-II → pareto front with *measured*
//!   (fully synthesized) area/power for every front design.  Split as
//!   [`driver::optimize_dataset_ga`] (eval-service-bound) +
//!   [`driver::finish_dataset`] (CPU-only synthesis), so multi-dataset
//!   runs overlap one dataset's front synthesis with the next one's
//!   generations.
//!
//! [`AccuracyEngine`]: crate::fitness::AccuracyEngine

pub mod driver;
pub mod metrics;
pub mod service;
pub mod shard;

pub use driver::{
    finish_dataset, optimize_dataset, optimize_dataset_ga, DatasetRun, EngineChoice, GaPhase,
    ParetoPoint, RunOptions,
};
pub use metrics::{FlushKind, Metrics, ShardMetrics, SnapshotEmitter};
pub use service::{EvalService, ServiceError, XlaEngine};
pub use shard::{
    rendezvous_route, rendezvous_score, CoalesceMode, EvalShardPool, PoolOptions, ProblemId,
    Ticket,
};
