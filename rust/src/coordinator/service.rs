//! The evaluation service facade: typed errors, client retry, and the
//! [`AccuracyEngine`] adapter over the sharded worker pool.
//!
//! The actual workers live in [`super::shard`]: [`EvalService`] is a thin,
//! cheaply-cloneable handle that keeps the seed service's call sites
//! (`spawn_native`/`spawn_xla`, `register`, `eval`, `shutdown`) while the
//! pool underneath scales to N workers with cross-driver batch
//! coalescing.  The `*_with` constructors expose the pool knobs
//! ([`PoolOptions`]: `--workers`, `--coalesce`, `--coalesce-window-us`,
//! `--coalesce-window-max-us`), and `spawn_native_with_clock` injects a
//! [`Clock`] so timing tests run on virtual time.
//!
//! Error handling is typed end to end: the pool speaks [`ServiceError`],
//! the facade's `register`/`eval` wrap it into `anyhow` for existing
//! callers, and [`XlaEngine`] heals stale registrations transparently
//! (re-register once + retry) before surfacing anything.
//!
//! Evaluation is two-phase: `submit`/`submit_typed` return a [`Ticket`]
//! without blocking, `wait`/`wait_typed` redeem it, and the blocking
//! `eval` is `wait(submit(..))`.  [`XlaEngine`] exposes the same split
//! through [`AccuracyEngine::submit_accuracy`]/[`AccuracyEngine::collect`]
//! — with the re-register-and-retry heal on the collect side, where a
//! shard dying with tickets in flight first becomes visible.

use std::sync::Arc;

use anyhow::{anyhow, Context as _, Result};

use super::metrics::Metrics;
use super::shard::{EvalShardPool, PoolOptions};
use crate::fitness::encode::Bucket;
use crate::fitness::{AccuracyEngine, AccuracyTicket, Problem};
use crate::hw::synth::TreeApprox;
use crate::util::clock::Clock;

pub use super::shard::{ProblemId, Ticket};

/// Typed service-layer failure (the ROADMAP's error-hardening item).
///
/// The `Display` fragments existing callers match on (foreign-id
/// detection, shutdown, the feature-off message) are kept stable with the
/// seed's stringly errors; `UnknownProblemId` now names the owning shard
/// instead of the whole service, since the count it reports is per-shard.
#[derive(Clone, Debug)]
pub enum ServiceError {
    /// The id was issued by a different service/pool instance.
    ForeignProblemId { id: ProblemId, registered: usize },
    /// The id's token matches this service but nothing is registered at
    /// its index (e.g. a handle that outlived a restart).
    UnknownProblemId { id: ProblemId, registered: usize },
    /// The shard's worker died (its backend panicked).  Registrations
    /// re-route to live shards, so clients heal by re-registering — this
    /// is a stale-id error, not a terminal one.
    ShardDown { shard: usize },
    /// The worker threads are gone (after `shutdown()` or a crash).
    ServiceDown,
    /// A worker dropped the reply channel without answering.
    ReplyDropped,
    /// The backend failed to register or execute (routing, compile,
    /// upload, execution); the detail preserves the backend's message.
    Backend { detail: String },
    /// This binary was built without the `xla` cargo feature.
    XlaUnavailable,
}

impl std::fmt::Display for ServiceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServiceError::ForeignProblemId { id, registered } => write!(
                f,
                "{id:?} was issued by a different EvalService (this service has \
                 {registered} registered problem(s))"
            ),
            ServiceError::UnknownProblemId { id, registered } => write!(
                f,
                "unknown {id:?}: its shard has {registered} registered problem(s)"
            ),
            ServiceError::ShardDown { shard } => write!(
                f,
                "eval shard {shard} is down (its worker died); re-register to \
                 route to a live shard"
            ),
            ServiceError::ServiceDown => write!(f, "eval service is down"),
            ServiceError::ReplyDropped => write!(f, "eval service dropped reply"),
            ServiceError::Backend { detail } => write!(f, "{detail}"),
            ServiceError::XlaUnavailable => write!(
                f,
                "this binary was built without the `xla` cargo feature, so the XLA \
                 eval service is unavailable; rebuild with `cargo build --features xla` \
                 or use `--engine native` / `--engine native-service`"
            ),
        }
    }
}

impl std::error::Error for ServiceError {}

impl ServiceError {
    /// Stale-registration failures a client can heal by re-registering.
    /// `ShardDown` belongs here: registration re-routes around the dead
    /// shard, so re-register-and-retry lands the problem on a survivor.
    pub fn is_stale_id(&self) -> bool {
        matches!(
            self,
            ServiceError::ForeignProblemId { .. }
                | ServiceError::UnknownProblemId { .. }
                | ServiceError::ShardDown { .. }
        )
    }
}

/// Client handle to the evaluation service (cheap to clone): a facade
/// over [`EvalShardPool`].
#[derive(Clone)]
pub struct EvalService {
    pool: EvalShardPool,
    pub metrics: Arc<Metrics>,
}

impl EvalService {
    /// Spawn a service over the PJRT runtime (artifacts required) with
    /// default pool sizing (1 worker per device).  Each worker constructs
    /// its own runtime *inside* its thread (the PJRT client is not
    /// `Send`); construction failure is reported synchronously.
    #[cfg(feature = "xla")]
    pub fn spawn_xla(artifact_dir: impl AsRef<std::path::Path>) -> Result<EvalService> {
        Self::spawn_xla_with(artifact_dir, &PoolOptions::default())
    }

    /// [`Self::spawn_xla`] with explicit pool sizing/coalescing knobs.
    #[cfg(feature = "xla")]
    pub fn spawn_xla_with(
        artifact_dir: impl AsRef<std::path::Path>,
        opts: &PoolOptions,
    ) -> Result<EvalService> {
        let pool = EvalShardPool::spawn_xla(artifact_dir, opts)?;
        let metrics = Arc::clone(&pool.metrics);
        Ok(EvalService { pool, metrics })
    }

    /// Feature-off stand-in: the XLA backend is not compiled into this
    /// build, so spawning it is a clear, synchronous error instead of a
    /// missing symbol at every call site.
    #[cfg(not(feature = "xla"))]
    pub fn spawn_xla(_artifact_dir: impl AsRef<std::path::Path>) -> Result<EvalService> {
        Err(ServiceError::XlaUnavailable.into())
    }

    /// Feature-off stand-in for [`Self::spawn_xla_with`].
    #[cfg(not(feature = "xla"))]
    pub fn spawn_xla_with(
        _artifact_dir: impl AsRef<std::path::Path>,
        _opts: &PoolOptions,
    ) -> Result<EvalService> {
        Err(ServiceError::XlaUnavailable.into())
    }

    /// Spawn a service over the native engine (tests / no-artifact runs)
    /// with seed-compatible sizing: one worker whose engine keeps the full
    /// thread budget, exactly like the pre-pool service.  Sharding is
    /// opt-in via [`Self::spawn_native_with`] (the `--workers` knob).
    /// `width` emulates the artifact population width for batching.
    pub fn spawn_native(width: usize) -> EvalService {
        Self::spawn_native_with(width, &PoolOptions { workers: 1, ..PoolOptions::default() })
    }

    /// [`Self::spawn_native`] with explicit pool sizing/coalescing knobs.
    pub fn spawn_native_with(width: usize, opts: &PoolOptions) -> EvalService {
        Self::from_pool(EvalShardPool::spawn_native(width, opts))
    }

    /// [`Self::spawn_native_with`] with an injected [`Clock`] — how the
    /// deterministic timing suites drive coalescing windows and deadline
    /// flushes from a [`ManualClock`](crate::util::clock::ManualClock)
    /// instead of wall time.
    pub fn spawn_native_with_clock(
        width: usize,
        opts: &PoolOptions,
        clock: Arc<dyn Clock>,
    ) -> EvalService {
        Self::from_pool(EvalShardPool::spawn_native_with_clock(width, opts, clock))
    }

    /// Wrap an already-spawned pool.  This is how the failover suites
    /// drive panic-injection pools (`util::testbed`) through the same
    /// facade as production spawns.
    pub fn from_pool(pool: EvalShardPool) -> EvalService {
        let metrics = Arc::clone(&pool.metrics);
        EvalService { pool, metrics }
    }

    /// The sharded pool behind this facade.
    pub fn pool(&self) -> &EvalShardPool {
        &self.pool
    }

    /// The pool's injected [`Clock`] — the seam driver-side trace spans
    /// stamp through, so they share one timeline with shard events.
    pub fn clock(&self) -> Arc<dyn Clock> {
        self.pool.clock()
    }

    /// Number of shard workers serving this handle.
    pub fn workers(&self) -> usize {
        self.pool.workers()
    }

    /// Register a problem: hash-routes it to its shard, routes it to a
    /// bucket there, and uploads statics once.
    pub fn register(&self, problem: Arc<Problem>) -> Result<(ProblemId, Option<Bucket>)> {
        Ok(self.register_typed(problem)?)
    }

    /// Typed-result variant of [`Self::register`].
    pub fn register_typed(
        &self,
        problem: Arc<Problem>,
    ) -> Result<(ProblemId, Option<Bucket>), ServiceError> {
        self.pool.register(problem)
    }

    /// Evaluate a batch (blocking until the owning shard replies).
    pub fn eval(&self, id: ProblemId, batch: Vec<TreeApprox>) -> Result<Vec<f64>> {
        Ok(self.eval_typed(id, batch)?)
    }

    /// Typed-result variant of [`Self::eval`] (lets clients distinguish
    /// recoverable stale-id failures from backend ones).
    pub fn eval_typed(
        &self,
        id: ProblemId,
        batch: Vec<TreeApprox>,
    ) -> Result<Vec<f64>, ServiceError> {
        self.pool.eval(id, batch)
    }

    /// Phase one of the two-phase eval: enqueue a batch on its shard and
    /// return a [`Ticket`] without blocking (see
    /// [`EvalShardPool::submit`]).
    pub fn submit(&self, id: ProblemId, batch: Vec<TreeApprox>) -> Result<Ticket> {
        Ok(self.submit_typed(id, batch)?)
    }

    /// Typed-result variant of [`Self::submit`].
    pub fn submit_typed(
        &self,
        id: ProblemId,
        batch: Vec<TreeApprox>,
    ) -> Result<Ticket, ServiceError> {
        self.pool.submit(id, batch)
    }

    /// Phase two: block on a ticket's result (see [`EvalShardPool::wait`]).
    pub fn wait(&self, ticket: Ticket) -> Result<Vec<f64>> {
        Ok(self.wait_typed(ticket)?)
    }

    /// Typed-result variant of [`Self::wait`].
    pub fn wait_typed(&self, ticket: Ticket) -> Result<Vec<f64>, ServiceError> {
        self.pool.wait(ticket)
    }

    /// Ask the workers to drain pending jobs and exit (idempotent;
    /// dropping all handles also works).
    pub fn shutdown(&self) {
        self.pool.shutdown()
    }
}

fn bucket_label(bucket: &Option<Bucket>) -> String {
    match bucket {
        Some(b) => format!("{} (P={})", b.name, b.p),
        None => "native".to_string(),
    }
}

/// Client-side [`AccuracyEngine`] facade over the service.
pub struct XlaEngine {
    service: EvalService,
    /// Kept for transparent re-registration on a stale [`ProblemId`].
    problem: Arc<Problem>,
    id: ProblemId,
    /// Batching width of the problem's registration (the routed bucket's
    /// P, or the native pool's emulated width) — sizes the preferred
    /// pipelining micro-batch.  0 when unknown.
    width: usize,
    /// Bucket the problem routed to ("native" for the native backend) —
    /// kept for error messages.
    bucket_name: String,
}

/// [`XlaEngine`]'s parked submit state: the pool ticket plus the batch it
/// covers, retained so a stale-id failure at collect time (a shard dying
/// with the ticket in flight) can re-register and repeat the batch.  The
/// id the ticket was submitted under gates the heal: with K tickets in
/// flight on a dying shard, only the FIRST collected failure re-registers
/// — the rest see the registration already moved and just retry, so one
/// real driver never inflates the coalescing group's member count K-fold
/// (which would disarm the adaptive all-drivers early flush forever).
struct InFlightBatch {
    ticket: Ticket,
    id: ProblemId,
    batch: Vec<TreeApprox>,
}

impl XlaEngine {
    /// Register `problem` with the service and wrap the handle.
    pub fn register(service: &EvalService, problem: Arc<Problem>) -> Result<XlaEngine> {
        let (id, bucket) = service.register_typed(Arc::clone(&problem))?;
        Ok(XlaEngine {
            service: service.clone(),
            problem,
            id,
            width: registration_width(service, &bucket),
            bucket_name: bucket_label(&bucket),
        })
    }

    /// The pool shard this engine's problem is pinned to.
    pub fn shard(&self) -> usize {
        self.id.shard()
    }

    /// Heal a stale registration: re-register (routing around any dead
    /// shard) and refresh the pinned id, width and bucket label.
    fn reregister(&mut self) -> Result<(), ServiceError> {
        let (id, bucket) = self.service.register_typed(Arc::clone(&self.problem))?;
        self.id = id;
        self.width = registration_width(&self.service, &bucket);
        self.bucket_name = bucket_label(&bucket);
        Ok(())
    }

    fn batch_context(&self, n: usize) -> String {
        format!(
            "eval service failed on a batch of {} for problem '{}' (bucket {})",
            n, self.problem.name, self.bucket_name
        )
    }
}

/// Batching width of a fresh registration: the routed bucket's P, else
/// the pool's native width hint (0 when neither is known).
fn registration_width(service: &EvalService, bucket: &Option<Bucket>) -> usize {
    bucket.as_ref().map(|b| b.p).unwrap_or_else(|| service.pool().width_hint())
}

impl AccuracyEngine for XlaEngine {
    /// Batched accuracy through the service: exactly
    /// [`Self::collect`] of [`Self::submit_accuracy`], so the blocking
    /// path and the pipelined path cannot diverge.  A stale registration
    /// (foreign/unknown [`ProblemId`], dead shard) is healed
    /// transparently — re-register once and retry — on whichever side it
    /// surfaces.  Remaining failures propagate as `Err` naming the
    /// problem and its bucket instead of aborting the whole process — a
    /// multi-dataset optimization run survives one failing dataset.
    fn batch_accuracy(&mut self, problem: &Problem, batch: &[TreeApprox]) -> Result<Vec<f64>> {
        let ticket = self.submit_accuracy(problem, batch);
        self.collect(ticket)
    }

    /// Submit the batch to the problem's shard and park the pool ticket.
    /// A synchronously-detected stale id (the shard died before this
    /// batch) heals here, before anything is in flight; submit failures
    /// ride inside a ready ticket and surface at [`Self::collect`].
    fn submit_accuracy(&mut self, problem: &Problem, batch: &[TreeApprox]) -> AccuracyTicket {
        if problem.name != self.problem.name {
            return AccuracyTicket::ready(Err(anyhow!(
                "engine registered for problem '{}' but asked to evaluate '{}'",
                self.problem.name,
                problem.name
            )));
        }
        let submitted = match self.service.submit_typed(self.id, batch.to_vec()) {
            Err(e) if e.is_stale_id() => match self.reregister() {
                Ok(()) => self.service.submit_typed(self.id, batch.to_vec()),
                Err(e) => Err(e),
            },
            other => other,
        };
        match submitted {
            Ok(ticket) => AccuracyTicket::engine(Box::new(InFlightBatch {
                ticket,
                id: self.id,
                batch: batch.to_vec(),
            })),
            Err(e) => {
                let ctx = self.batch_context(batch.len());
                AccuracyTicket::ready(Err(anyhow::Error::from(e).context(ctx)))
            }
        }
    }

    /// Redeem a parked pool ticket.  A stale-id failure here means the
    /// shard died with the batch in flight: heal by re-registering
    /// (routing to a live shard) and repeating the retained batch —
    /// blocking is fine, the pipeline is already stalled on this ticket.
    fn collect(&mut self, ticket: AccuracyTicket) -> Result<Vec<f64>> {
        let ticket = match ticket.try_ready() {
            Ok(res) => return res,
            Err(t) => t,
        };
        let Ok(state) = ticket.into_engine_state::<InFlightBatch>() else {
            return Err(anyhow!("engine 'xla-service' was handed a ticket another engine issued"));
        };
        let InFlightBatch { ticket, id, batch } = *state;
        let n = batch.len();
        let res = match self.service.wait_typed(ticket) {
            Err(e) if e.is_stale_id() => {
                // Re-register once — unless an earlier ticket's heal (or a
                // submit-side heal) already moved the registration off the
                // dead shard, in which case retrying under the current id
                // is enough.
                if self.id == id {
                    self.reregister()?;
                }
                self.service.eval_typed(self.id, batch)
            }
            other => other,
        };
        res.with_context(|| self.batch_context(n))
    }

    /// Pipelining hint: enough chromosomes to fill every pool worker's
    /// artifact width at once.
    fn preferred_microbatch(&self) -> usize {
        if self.width == 0 {
            0
        } else {
            self.service.workers() * self.width
        }
    }

    fn name(&self) -> &'static str {
        "xla-service"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fitness::native::NativeEngine;
    use crate::fitness::testutil::small_problem;
    use crate::hw::{AreaLut, EgtLibrary};
    use crate::util::rng::Pcg64;
    use std::sync::atomic::Ordering;

    fn random_batch(p: &Problem, n: usize, seed: u64) -> Vec<TreeApprox> {
        let mut rng = Pcg64::seeded(seed);
        let nc = p.n_comparators();
        (0..n)
            .map(|_| {
                let bits: Vec<u8> = (0..nc).map(|_| rng.int_in(2, 8) as u8).collect();
                let thr_int: Vec<u32> = (0..nc)
                    .map(|j| crate::quant::int_threshold(p.thresholds[j], bits[j]))
                    .collect();
                TreeApprox { bits, thr_int }
            })
            .collect()
    }

    #[test]
    fn native_service_round_trip_matches_direct() {
        let lut = AreaLut::build(&EgtLibrary::default());
        let p = Arc::new(small_problem(&lut));
        let svc = EvalService::spawn_native(8);
        let (id, bucket) = svc.register(Arc::clone(&p)).unwrap();
        assert!(bucket.is_none());

        let batch = random_batch(&p, 21, 3); // 21 > width → multiple chunks
        let got = svc.eval(id, batch.clone()).unwrap();
        let mut direct = NativeEngine::default();
        let want = direct.batch_accuracy(&p, &batch).unwrap();
        assert_eq!(got, want);
        // 21 chromosomes at width 8 from a single client → 2 full flushes
        // + the 5-tail after the coalescing window: 3 executions, exactly
        // like the seed service's split.
        assert_eq!(svc.metrics.executions.load(Ordering::Relaxed), 3);
        svc.shutdown();
    }

    #[test]
    fn concurrent_clients_share_service() {
        let lut = AreaLut::build(&EgtLibrary::default());
        let p = Arc::new(small_problem(&lut));
        let svc = EvalService::spawn_native(16);
        let (id, _) = svc.register(Arc::clone(&p)).unwrap();

        let mut handles = Vec::new();
        for t in 0..4u64 {
            let svc = svc.clone();
            let p = Arc::clone(&p);
            handles.push(std::thread::spawn(move || {
                let batch = random_batch(&p, 10, 100 + t);
                let got = svc.eval(id, batch.clone()).unwrap();
                let mut direct = NativeEngine::default();
                let want = direct.batch_accuracy(&p, &batch).unwrap();
                assert_eq!(got, want);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // 40 chromosomes at width 16: fully coalesced → 3 executions;
        // fully serialized (each request alone) → 4.  Never more, never
        // fewer, and nothing is lost.
        let execs = svc.metrics.executions.load(Ordering::Relaxed);
        assert!((3..=4).contains(&execs), "execs={execs}");
        assert_eq!(svc.metrics.chromosomes.load(Ordering::Relaxed), 40);
        svc.shutdown();
    }

    #[test]
    fn empty_batch_is_noop() {
        let lut = AreaLut::build(&EgtLibrary::default());
        let p = Arc::new(small_problem(&lut));
        let svc = EvalService::spawn_native(8);
        let (id, _) = svc.register(p).unwrap();
        assert!(svc.eval(id, vec![]).unwrap().is_empty());
        assert_eq!(svc.metrics.executions.load(Ordering::Relaxed), 0);
        svc.shutdown();
    }

    /// A stale [`ProblemId`] — wrong service token (failover) or an
    /// unknown index on the right service — heals transparently: the
    /// engine re-registers once and retries instead of surfacing the
    /// error to the GA.
    #[test]
    fn stale_id_triggers_transparent_reregister() {
        let lut = AreaLut::build(&EgtLibrary::default());
        let p = Arc::new(small_problem(&lut));
        let svc = EvalService::spawn_native(8);
        let mut engine = XlaEngine::register(&svc, Arc::clone(&p)).unwrap();
        let good_id = engine.id;
        let batch = random_batch(&p, 5, 17);
        let mut direct = NativeEngine::default();
        let want = direct.batch_accuracy(&p, &batch).unwrap();

        // Foreign token (token 0 is never issued).
        engine.id = ProblemId { service: 0, shard: 0, index: 0 };
        assert_eq!(engine.batch_accuracy(&p, &batch).unwrap(), want);
        assert_ne!(engine.id, good_id, "a fresh registration was taken");
        assert_eq!(engine.id.shard(), good_id.shard(), "re-registration stays pinned");

        // Unknown index on the correct service.
        engine.id = ProblemId { index: 4096, ..engine.id };
        assert_eq!(engine.batch_accuracy(&p, &batch).unwrap(), want);

        // Initial + two healing re-registrations.
        assert_eq!(svc.metrics.problems.load(Ordering::Relaxed), 3);
        svc.shutdown();
    }

    /// The engine's two-phase path: several sub-width micro-batches
    /// submitted before any is collected come back (out of order) exactly
    /// as the direct native engine computes them, and a stale id at
    /// submit time heals without the caller noticing — same contract as
    /// the blocking path, same re-register accounting.
    #[test]
    fn engine_submit_collect_pipelines_and_heals() {
        let lut = AreaLut::build(&EgtLibrary::default());
        let p = Arc::new(small_problem(&lut));
        let svc = EvalService::spawn_native(8);
        let mut engine = XlaEngine::register(&svc, Arc::clone(&p)).unwrap();
        assert_eq!(engine.preferred_microbatch(), 8, "1 worker x width 8");

        let batch = random_batch(&p, 10, 23);
        let mut direct = NativeEngine::default();
        let want = direct.batch_accuracy(&p, &batch).unwrap();

        let t1 = engine.submit_accuracy(&p, &batch[..4]);
        let t2 = engine.submit_accuracy(&p, &batch[4..]);
        assert_eq!(engine.collect(t2).unwrap(), want[4..].to_vec());
        assert_eq!(engine.collect(t1).unwrap(), want[..4].to_vec());

        // Stale id at submit: heals before anything is in flight.
        engine.id = ProblemId { service: 0, shard: 0, index: 0 };
        let t = engine.submit_accuracy(&p, &batch);
        assert_eq!(engine.collect(t).unwrap(), want);
        assert_eq!(svc.metrics.problems.load(Ordering::Relaxed), 2);
        // Ticket gauges saw the pipelined submits (plus the heal's).
        assert!(svc.metrics.tickets_submitted.load(Ordering::Relaxed) >= 3);
        assert_eq!(svc.metrics.tickets_in_flight.load(Ordering::Relaxed), 0);
        svc.shutdown();
    }

    // Error-path contracts (invalid/stale ProblemId, requests after
    // shutdown, width-1 batching parity) are pinned through the public API
    // in rust/tests/service_errors.rs; pool routing/coalescing contracts
    // in rust/tests/shard_pool.rs.
}
