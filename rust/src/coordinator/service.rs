//! The evaluation service: leader thread, routing, dynamic batching.
//!
//! One worker thread owns the backend (the PJRT runtime, or the native
//! engine in tests/fallback).  Clients talk to it over an mpsc channel:
//!
//! ```text
//!  GA driver (dataset A) ──┐                 ┌─ route → bucket, statics
//!  GA driver (dataset B) ──┼──> job queue ───┤  split/pad to P
//!  benches / CLI        ──┘    (mpsc)        └─ execute → reply channel
//! ```
//!
//! Registration uploads a problem's static tensors once; each job then
//! carries only the decoded approximations.  Batches larger than the
//! artifact width P are split; the tail chunk is padded (and the padding
//! recorded in [`Metrics`]).  Backpressure is the bounded job queue: with
//! `QUEUE_DEPTH` jobs in flight, senders block — GA drivers naturally
//! throttle to the evaluator's throughput.

use std::sync::atomic::Ordering;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Context as _, Result};

use super::metrics::Metrics;
use crate::fitness::encode::Bucket;
#[cfg(feature = "xla")]
use crate::fitness::encode::{self, StaticTensors};
use crate::fitness::{native::NativeEngine, AccuracyEngine, Problem};
use crate::hw::synth::TreeApprox;
#[cfg(feature = "xla")]
use crate::runtime::{DeviceStatics, XlaRuntime};

/// Bounded queue depth (jobs in flight before senders block).
const QUEUE_DEPTH: usize = 16;

/// What actually evaluates a padded population batch.
///
/// Not `Send`: the PJRT client wraps an `Rc`.  Backends are therefore
/// *constructed inside* the service thread (see [`EvalService::spawn_xla`]).
trait Backend {
    fn register(&mut self, problem: &Arc<Problem>) -> Result<RegisteredProblem>;
    fn eval(
        &mut self,
        reg: &RegisteredProblem,
        problem: &Problem,
        chunk: &[TreeApprox],
    ) -> Result<Vec<f64>>;
    /// Backend id (surfaced in logs / metrics lines).
    #[allow(dead_code)]
    fn name(&self) -> &'static str;
}

/// Backend-side registration state.
enum RegisteredProblem {
    #[cfg(feature = "xla")]
    Xla { statics: DeviceStatics },
    Native { width: usize },
}

impl RegisteredProblem {
    fn bucket(&self) -> Option<&Bucket> {
        match self {
            #[cfg(feature = "xla")]
            RegisteredProblem::Xla { statics } => Some(&statics.bucket),
            RegisteredProblem::Native { .. } => None,
        }
    }

    /// Population width the backend executes at (batch-splitting unit).
    fn width(&self) -> usize {
        match self {
            #[cfg(feature = "xla")]
            RegisteredProblem::Xla { statics } => statics.bucket.p,
            RegisteredProblem::Native { width } => *width,
        }
    }
}

/// PJRT-backed backend.
#[cfg(feature = "xla")]
struct XlaBackend {
    runtime: XlaRuntime,
}

#[cfg(feature = "xla")]
impl Backend for XlaBackend {
    fn register(&mut self, problem: &Arc<Problem>) -> Result<RegisteredProblem> {
        let (bucket, _) = self
            .runtime
            .meta
            .route(problem)
            .ok_or_else(|| {
                anyhow!(
                    "no bucket fits problem '{}' (n_test={}, n_comp={}, leaves={})",
                    problem.name,
                    problem.n_test,
                    problem.n_comparators(),
                    problem.tree.n_leaves()
                )
            })?
            .clone();
        self.runtime.ensure_compiled(&bucket.name)?;
        let st: StaticTensors = encode::encode_static(problem, &bucket);
        let statics = self.runtime.upload_statics(&st)?;
        Ok(RegisteredProblem::Xla { statics })
    }

    fn eval(
        &mut self,
        reg: &RegisteredProblem,
        problem: &Problem,
        chunk: &[TreeApprox],
    ) -> Result<Vec<f64>> {
        let RegisteredProblem::Xla { statics } = reg else {
            return Err(anyhow!("backend mismatch"));
        };
        let bucket = statics.bucket.clone();
        let (thr, scale) = encode::pack_population(problem, &bucket, chunk);
        let acc = self.runtime.execute(statics, &thr, &scale)?;
        Ok(acc.iter().take(chunk.len()).map(|&a| a as f64).collect())
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

/// Native backend: same service machinery, tree-walk arithmetic.  Used by
/// unit tests (no artifacts needed) and `--engine native-service`.
struct NativeBackend {
    engine: NativeEngine,
    /// Emulated artifact width, so batching/padding paths are exercised.
    pub width: usize,
}

impl Backend for NativeBackend {
    fn register(&mut self, _problem: &Arc<Problem>) -> Result<RegisteredProblem> {
        Ok(RegisteredProblem::Native { width: self.width })
    }

    fn eval(
        &mut self,
        _reg: &RegisteredProblem,
        problem: &Problem,
        chunk: &[TreeApprox],
    ) -> Result<Vec<f64>> {
        self.engine.batch_accuracy(problem, chunk)
    }

    fn name(&self) -> &'static str {
        "native-service"
    }
}

/// Problem handle returned by registration.  Carries the issuing service's
/// token so an id presented to a *different* service is rejected even when
/// its index happens to be in range there.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ProblemId {
    service: u32,
    index: u32,
}

/// Process-unique service tokens (0 is never issued, so a forged
/// `ProblemId` default can't match).
static NEXT_SERVICE_TOKEN: std::sync::atomic::AtomicU32 =
    std::sync::atomic::AtomicU32::new(1);

enum Msg {
    Register {
        problem: Arc<Problem>,
        reply: mpsc::SyncSender<Result<(ProblemId, Option<Bucket>)>>,
    },
    Eval {
        id: ProblemId,
        batch: Vec<TreeApprox>,
        reply: mpsc::SyncSender<Result<Vec<f64>>>,
    },
    Shutdown,
}

/// Client handle to the evaluation service (cheap to clone).
#[derive(Clone)]
pub struct EvalService {
    tx: mpsc::SyncSender<Msg>,
    pub metrics: Arc<Metrics>,
}

impl EvalService {
    /// Spawn a service over the PJRT runtime (artifacts required).  The
    /// runtime is constructed *inside* the worker thread (the PJRT client
    /// is not `Send`); construction failure is reported synchronously.
    #[cfg(feature = "xla")]
    pub fn spawn_xla(artifact_dir: impl AsRef<std::path::Path>) -> Result<EvalService> {
        let dir = artifact_dir.as_ref().to_path_buf();
        Self::spawn_factory(move || {
            Ok(Box::new(XlaBackend { runtime: XlaRuntime::new(dir)? }) as Box<dyn Backend>)
        })
    }

    /// Feature-off stand-in: the XLA backend is not compiled into this
    /// build, so spawning it is a clear, synchronous error instead of a
    /// missing symbol at every call site.
    #[cfg(not(feature = "xla"))]
    pub fn spawn_xla(_artifact_dir: impl AsRef<std::path::Path>) -> Result<EvalService> {
        Err(anyhow!(
            "this binary was built without the `xla` cargo feature, so the XLA \
             eval service is unavailable; rebuild with `cargo build --features xla` \
             or use `--engine native` / `--engine native-service`"
        ))
    }

    /// Spawn a service over the native engine (tests / no-artifact runs).
    /// `width` emulates the artifact population width for batching.
    pub fn spawn_native(width: usize) -> EvalService {
        Self::spawn_factory(move || {
            Ok(Box::new(NativeBackend { engine: NativeEngine::default(), width })
                as Box<dyn Backend>)
        })
        .expect("native backend construction cannot fail")
    }

    fn spawn_factory(
        factory: impl FnOnce() -> Result<Box<dyn Backend>> + Send + 'static,
    ) -> Result<EvalService> {
        let (tx, rx) = mpsc::sync_channel::<Msg>(QUEUE_DEPTH);
        let metrics = Arc::new(Metrics::default());
        let m = Arc::clone(&metrics);
        let token = NEXT_SERVICE_TOKEN.fetch_add(1, Ordering::Relaxed);
        let (init_tx, init_rx) = mpsc::sync_channel::<Result<()>>(1);
        std::thread::Builder::new()
            .name("axdt-eval-service".into())
            .spawn(move || {
                let mut backend = match factory() {
                    Ok(b) => {
                        let _ = init_tx.send(Ok(()));
                        b
                    }
                    Err(e) => {
                        let _ = init_tx.send(Err(e));
                        return;
                    }
                };
                let mut problems: Vec<(Arc<Problem>, RegisteredProblem)> = Vec::new();
                while let Ok(msg) = rx.recv() {
                    match msg {
                        Msg::Shutdown => break,
                        Msg::Register { problem, reply } => {
                            let res = backend.register(&problem).map(|reg| {
                                let id = ProblemId {
                                    service: token,
                                    index: problems.len() as u32,
                                };
                                let bucket = reg.bucket().cloned();
                                problems.push((problem, reg));
                                m.problems.fetch_add(1, Ordering::Relaxed);
                                (id, bucket)
                            });
                            let _ = reply.send(res);
                        }
                        Msg::Eval { id, batch, reply } => {
                            // A stale or foreign id must not kill the worker
                            // thread (which would wedge every other client)
                            // NOR silently evaluate against the wrong
                            // problem: reply with an error and keep serving.
                            if id.service != token {
                                let _ = reply.send(Err(anyhow!(
                                    "{id:?} was issued by a different EvalService \
                                     (this service has {} registered problem(s))",
                                    problems.len()
                                )));
                                continue;
                            }
                            let Some((problem, reg)) = problems.get(id.index as usize) else {
                                let _ = reply.send(Err(anyhow!(
                                    "unknown {id:?}: this eval service has {} registered \
                                     problem(s)",
                                    problems.len()
                                )));
                                continue;
                            };
                            let width = reg.width();
                            let mut out = Vec::with_capacity(batch.len());
                            let mut failed = None;
                            for chunk in batch.chunks(width.max(1)) {
                                let t0 = Instant::now();
                                match backend.eval(reg, problem, chunk) {
                                    Ok(accs) => {
                                        m.record_execution(
                                            chunk.len(),
                                            width.max(chunk.len()),
                                            t0.elapsed().as_nanos() as u64,
                                        );
                                        out.extend(accs);
                                    }
                                    Err(e) => {
                                        failed = Some(e);
                                        break;
                                    }
                                }
                            }
                            let _ = reply.send(match failed {
                                Some(e) => Err(e),
                                None => Ok(out),
                            });
                        }
                    }
                }
            })
            .expect("spawn eval service");
        init_rx
            .recv()
            .map_err(|_| anyhow!("eval service died during init"))??;
        Ok(EvalService { tx, metrics })
    }

    /// Register a problem: routes it to a bucket and uploads statics.
    pub fn register(&self, problem: Arc<Problem>) -> Result<(ProblemId, Option<Bucket>)> {
        let (reply_tx, reply_rx) = mpsc::sync_channel(1);
        self.tx
            .send(Msg::Register { problem, reply: reply_tx })
            .map_err(|_| anyhow!("eval service is down"))?;
        reply_rx.recv().map_err(|_| anyhow!("eval service dropped reply"))?
    }

    /// Evaluate a batch (blocking until the service replies).
    pub fn eval(&self, id: ProblemId, batch: Vec<TreeApprox>) -> Result<Vec<f64>> {
        if batch.is_empty() {
            return Ok(Vec::new());
        }
        let (reply_tx, reply_rx) = mpsc::sync_channel(1);
        self.tx
            .send(Msg::Eval { id, batch, reply: reply_tx })
            .map_err(|_| anyhow!("eval service is down"))?;
        reply_rx.recv().map_err(|_| anyhow!("eval service dropped reply"))?
    }

    /// Ask the worker to exit (idempotent; dropping all handles also works).
    pub fn shutdown(&self) {
        let _ = self.tx.send(Msg::Shutdown);
    }
}

/// Client-side [`AccuracyEngine`] facade over the service.
pub struct XlaEngine {
    service: EvalService,
    id: ProblemId,
    problem_name: String,
    /// Bucket the problem routed to (None for the native backend) — kept
    /// for error messages.
    bucket_name: String,
}

impl XlaEngine {
    /// Register `problem` with the service and wrap the handle.
    pub fn register(service: &EvalService, problem: Arc<Problem>) -> Result<XlaEngine> {
        let name = problem.name.clone();
        let (id, bucket) = service.register(problem)?;
        let bucket_name = match &bucket {
            Some(b) => format!("{} (P={})", b.name, b.p),
            None => "native".to_string(),
        };
        Ok(XlaEngine { service: service.clone(), id, problem_name: name, bucket_name })
    }
}

impl AccuracyEngine for XlaEngine {
    /// Batched accuracy through the service.  Failures (stale id, backend
    /// execution error, service shutdown) propagate as `Err` naming the
    /// problem and its bucket instead of aborting the whole process — a
    /// multi-dataset optimization run survives one failing dataset.
    fn batch_accuracy(&mut self, problem: &Problem, batch: &[TreeApprox]) -> Result<Vec<f64>> {
        if problem.name != self.problem_name {
            return Err(anyhow!(
                "engine registered for problem '{}' but asked to evaluate '{}'",
                self.problem_name,
                problem.name
            ));
        }
        self.service.eval(self.id, batch.to_vec()).with_context(|| {
            format!(
                "eval service failed on a batch of {} for problem '{}' (bucket {})",
                batch.len(),
                self.problem_name,
                self.bucket_name
            )
        })
    }

    fn name(&self) -> &'static str {
        "xla-service"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fitness::testutil::small_problem;
    use crate::hw::{AreaLut, EgtLibrary};
    use crate::util::rng::Pcg64;

    fn random_batch(p: &Problem, n: usize, seed: u64) -> Vec<TreeApprox> {
        let mut rng = Pcg64::seeded(seed);
        let nc = p.n_comparators();
        (0..n)
            .map(|_| {
                let bits: Vec<u8> = (0..nc).map(|_| rng.int_in(2, 8) as u8).collect();
                let thr_int: Vec<u32> = (0..nc)
                    .map(|j| crate::quant::int_threshold(p.thresholds[j], bits[j]))
                    .collect();
                TreeApprox { bits, thr_int }
            })
            .collect()
    }

    #[test]
    fn native_service_round_trip_matches_direct() {
        let lut = AreaLut::build(&EgtLibrary::default());
        let p = Arc::new(small_problem(&lut));
        let svc = EvalService::spawn_native(8);
        let (id, bucket) = svc.register(Arc::clone(&p)).unwrap();
        assert!(bucket.is_none());

        let batch = random_batch(&p, 21, 3); // 21 > width → multiple chunks
        let got = svc.eval(id, batch.clone()).unwrap();
        let mut direct = NativeEngine::default();
        let want = direct.batch_accuracy(&p, &batch).unwrap();
        assert_eq!(got, want);
        // 21 chromosomes at width 8 → 3 executions, last padded 8-5=3... the
        // native backend pads to chunk len, so waste is 0 but execs == 3.
        assert_eq!(svc.metrics.executions.load(Ordering::Relaxed), 3);
        svc.shutdown();
    }

    #[test]
    fn concurrent_clients_share_service() {
        let lut = AreaLut::build(&EgtLibrary::default());
        let p = Arc::new(small_problem(&lut));
        let svc = EvalService::spawn_native(16);
        let (id, _) = svc.register(Arc::clone(&p)).unwrap();

        let mut handles = Vec::new();
        for t in 0..4u64 {
            let svc = svc.clone();
            let p = Arc::clone(&p);
            handles.push(std::thread::spawn(move || {
                let batch = random_batch(&p, 10, 100 + t);
                let got = svc.eval(id, batch.clone()).unwrap();
                let mut direct = NativeEngine::default();
                let want = direct.batch_accuracy(&p, &batch).unwrap();
                assert_eq!(got, want);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(svc.metrics.executions.load(Ordering::Relaxed) >= 4);
        svc.shutdown();
    }

    #[test]
    fn empty_batch_is_noop() {
        let lut = AreaLut::build(&EgtLibrary::default());
        let p = Arc::new(small_problem(&lut));
        let svc = EvalService::spawn_native(8);
        let (id, _) = svc.register(p).unwrap();
        assert!(svc.eval(id, vec![]).unwrap().is_empty());
        assert_eq!(svc.metrics.executions.load(Ordering::Relaxed), 0);
        svc.shutdown();
    }

    // Error-path contracts (invalid/stale ProblemId, requests after
    // shutdown, width-1 batching parity) are pinned through the public API
    // in rust/tests/service_errors.rs.
}
