//! The per-dataset optimization pipeline (the paper's Fig. 2, end to end).
//!
//! generate → normalize → split → train exact tree → build [`Problem`]
//! (one exact synthesis = Table I baseline) → NSGA-II over the chosen
//! accuracy engine → Pareto front → *full synthesis* of every front design
//! (the paper's "all presented pareto points are evaluated using the tool
//! flow described above").

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use super::metrics::Metrics;
use super::service::{EvalService, XlaEngine};
use crate::data::generators::{self, DatasetSpec};
use crate::dt::{train, TrainConfig};
use crate::fitness::cache::{DatasetFingerprint, EvalCache};
use crate::fitness::{native::NativeEngine, EvalStats, FitnessEvaluator, Problem, SharedCache};
use crate::ga::{run_nsga2, Chromosome, Evaluator, GenStats, NsgaConfig};
use crate::hw::synth::{self, TreeApprox, FEATURE_BITS};
use crate::hw::{AreaLut, EgtLibrary, HwReport};
use crate::quant;
use crate::util::clock::{Clock, SystemClock};
use crate::util::trace::TraceKind;

/// Which accuracy engine evaluates fitness.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineChoice {
    /// In-process tree walk (CPU baseline).
    Native,
    /// Tree walk behind the eval service (exercises routing/batching).
    NativeService,
    /// AOT XLA artifact over PJRT (the production path).
    Xla,
}

impl EngineChoice {
    /// Parse a CLI/config engine name.  `"xla"` only resolves when the
    /// binary was built with the `xla` cargo feature; otherwise it is a
    /// clear error instead of a runtime failure deep in the run.
    pub fn parse(s: &str) -> Result<EngineChoice> {
        match s {
            "native" => Ok(EngineChoice::Native),
            "native-service" => Ok(EngineChoice::NativeService),
            #[cfg(feature = "xla")]
            "xla" => Ok(EngineChoice::Xla),
            #[cfg(not(feature = "xla"))]
            "xla" => Err(anyhow!(
                "engine 'xla' requires a build with `--features xla` (this binary \
                 was built without it); use 'native' or 'native-service'"
            )),
            other => Err(anyhow!("unknown engine '{other}' (native|native-service|xla)")),
        }
    }
}

/// Options for one dataset optimization.
#[derive(Clone, Debug)]
pub struct RunOptions {
    pub seed: u64,
    pub pop_size: usize,
    pub generations: usize,
    pub margin_max: u32,
    pub engine: EngineChoice,
    /// Micro-batch size for the pipelined two-phase eval (CLI
    /// `--microbatch`): each generation's deduped misses are sliced into
    /// micro-batches of this size and all submitted before any is
    /// collected.  0 = auto (the engine's preference: pool workers x
    /// artifact width for service engines, whole-batch for native).
    pub microbatch: usize,
    /// Shared tiered accuracy cache (L1 in-memory, optional L2 on disk),
    /// `Arc`-shared across every concurrent driver in `run_all`.  `None`
    /// keeps the pre-cache behavior: a per-run memo only.  The shared
    /// tiers also need an eval service (its injected clock stamps lookup
    /// latencies; its metrics take the hit/miss counters).
    pub cache: Option<Arc<EvalCache>>,
    /// Archived Pareto-front genes per dataset id (`--warm-start
    /// runs.json`): re-validated against this run's tree and seeded into
    /// the initial NSGA-II population after the exact/ladder anchors.
    pub warm_start: Option<Arc<HashMap<String, Vec<Vec<f64>>>>>,
}

impl Default for RunOptions {
    fn default() -> Self {
        RunOptions {
            seed: 42,
            pop_size: 48,
            generations: 30,
            margin_max: 5,
            engine: EngineChoice::Native,
            microbatch: 0,
            cache: None,
            warm_start: None,
        }
    }
}

/// One pareto-front design with both the GA's estimate and the fully
/// synthesized measurement.
#[derive(Clone, Debug)]
pub struct ParetoPoint {
    pub accuracy: f64,
    pub est_area_mm2: f64,
    pub measured: HwReport,
    pub approx: TreeApprox,
    /// The raw chromosome behind this design, archived in `runs.json` so
    /// a later run can `--warm-start` from it.
    pub genes: Vec<f64>,
}

/// Everything a table/figure needs about one dataset's run.
#[derive(Clone, Debug)]
pub struct DatasetRun {
    pub spec: &'static DatasetSpec,
    /// Exact float-tree test accuracy.
    pub float_accuracy: f64,
    /// Exact 8-bit bespoke baseline (Table I row).
    pub baseline_accuracy: f64,
    pub baseline: HwReport,
    pub n_comparators: usize,
    /// Final non-dominated set, sorted by accuracy descending.
    pub front: Vec<ParetoPoint>,
    pub history: Vec<GenStats>,
    pub evaluations: usize,
    /// Fitness-evaluator cache effectiveness for this run (requested /
    /// cache hits / engine evals) — archived next to the front so
    /// operators see it per dataset, and folded into the shared service's
    /// `Metrics::render()` line by the driver.
    pub stats: EvalStats,
    pub elapsed_s: f64,
    pub engine: &'static str,
}

impl DatasetRun {
    /// Smallest-area front design within `loss` of the baseline accuracy
    /// (Table II uses loss = 0.01).  NaN-safe: a NaN accuracy (either
    /// sign) fails the `>=` filter, non-finite areas are filtered out
    /// before `min_by` (a negative NaN would otherwise sort BELOW every
    /// finite area under `total_cmp` and win), and `total_cmp` itself
    /// cannot panic like the old `partial_cmp(..).unwrap()` did.
    pub fn best_within_loss(&self, loss: f64) -> Option<&ParetoPoint> {
        self.front
            .iter()
            .filter(|p| {
                p.accuracy >= self.baseline_accuracy - loss && p.measured.area_mm2.is_finite()
            })
            .min_by(|a, b| a.measured.area_mm2.total_cmp(&b.measured.area_mm2))
    }

    /// Area reduction factor (baseline / best-within-loss), as in §IV.
    pub fn area_gain(&self, loss: f64) -> Option<f64> {
        self.best_within_loss(loss)
            .map(|p| self.baseline.area_mm2 / p.measured.area_mm2)
    }
}

/// Driver-side tracing context for one dataset run: the service's
/// shared [`TraceJournal`](crate::util::trace::TraceJournal), the clock
/// it stamps through (the *pool's* clock, so driver spans and shard
/// events share one timeline), and this dataset's driver track.
/// `open` returns `None` when tracing is disabled, so untraced runs
/// never pay for span bookkeeping.
struct SpanScope {
    metrics: Arc<Metrics>,
    clock: Arc<dyn Clock>,
    track: u32,
}

impl SpanScope {
    fn open(service: &EvalService, dataset_id: &str) -> Option<SpanScope> {
        if !service.metrics.trace.enabled() {
            return None;
        }
        Some(SpanScope {
            track: service.metrics.trace.driver_track(dataset_id),
            metrics: Arc::clone(&service.metrics),
            clock: service.clock(),
        })
    }

    fn begin(&self, name: &str) {
        self.metrics.trace.record(
            self.clock.now_ns(),
            TraceKind::SpanBegin { track: self.track, name: name.to_string() },
        );
    }

    fn end(&self, name: &str) {
        self.metrics.trace.record(
            self.clock.now_ns(),
            TraceKind::SpanEnd { track: self.track, name: name.to_string() },
        );
    }
}

/// Brackets each NSGA-II generation in a span on the dataset's driver
/// track — `run_nsga2` calls [`Evaluator::evaluate`] exactly once per
/// generation ("gen 0" is the initial population), so counting calls
/// *is* counting generations.  Only wrapped in when tracing is on.
struct TracingEvaluator<'a> {
    inner: &'a mut dyn Evaluator,
    scope: &'a SpanScope,
    generation: usize,
}

impl Evaluator for TracingEvaluator<'_> {
    fn evaluate(&mut self, pop: &[Chromosome]) -> Vec<[f64; 2]> {
        let name = format!("gen {}", self.generation);
        self.generation += 1;
        self.scope.begin(&name);
        let objectives = self.inner.evaluate(pop);
        self.scope.end(&name);
        objectives
    }
}

/// Output of the GA phase of a dataset run: everything
/// [`finish_dataset`] needs to synthesize and package the front.
///
/// Holding a `GaPhase` instead of a finished [`DatasetRun`] is what lets
/// `run_all` release its evaluation slot *before* the (CPU-only) full
/// synthesis of the Pareto front, overlapping that synthesis with the
/// next dataset's first generations on the eval service.
pub struct GaPhase {
    spec: &'static DatasetSpec,
    problem: Arc<Problem>,
    float_accuracy: f64,
    baseline_accuracy: f64,
    result: crate::ga::NsgaResult,
    stats: EvalStats,
    engine: &'static str,
    /// Library + area LUT carried over from the GA phase, so synthesis
    /// reuses the exact area model the search ran with (and skips the
    /// 508-synth LUT rebuild).
    lib: EgtLibrary,
    lut: AreaLut,
    /// Phase clock: its epoch is the GA start, so `now_ns()` reads the
    /// elapsed wall time directly.  Going through the Clock seam keeps
    /// `elapsed_s` injectable if run timing ever needs deterministic tests.
    clock: SystemClock,
    /// Tracing context carried into [`finish_dataset`] so the synthesis
    /// span and the dataset span's close land on the same driver track
    /// the GA spans used.  `None` when tracing is off.
    trace: Option<SpanScope>,
}

/// Run the full pipeline for one dataset: the GA phase followed by full
/// front synthesis (see [`optimize_dataset_ga`] / [`finish_dataset`] for
/// the two-phase form `run_all` pipelines).
///
/// `service` is required for [`EngineChoice::Xla`]; it is also used for
/// [`EngineChoice::NativeService`] when provided a native-backed service.
pub fn optimize_dataset(
    dataset_id: &str,
    opts: &RunOptions,
    service: Option<&EvalService>,
) -> Result<DatasetRun> {
    Ok(finish_dataset(optimize_dataset_ga(dataset_id, opts, service)?))
}

/// The eval-service-bound half of [`optimize_dataset`]: generate →
/// normalize → split → train → build [`Problem`] (one exact synthesis =
/// Table I baseline) → NSGA-II over the chosen accuracy engine.
pub fn optimize_dataset_ga(
    dataset_id: &str,
    opts: &RunOptions,
    service: Option<&EvalService>,
) -> Result<GaPhase> {
    let clock = SystemClock::new();
    let trace = service.and_then(|s| SpanScope::open(s, dataset_id));
    if let Some(scope) = &trace {
        scope.begin(&format!("dataset {dataset_id}"));
    }
    let spec = generators::spec(dataset_id)
        .ok_or_else(|| anyhow!("unknown dataset '{dataset_id}'"))?;
    let lib = EgtLibrary::default();
    let lut = AreaLut::build(&lib);

    // Data + exact tree (the paper's scikit-learn stage).
    let data = generators::generate(spec, opts.seed);
    let (train_d, test_d) = data.split(0.3, opts.seed);
    let tree = train(
        &train_d,
        &TrainConfig { max_leaves: spec.max_leaves, min_samples_split: 2 },
    );
    let float_accuracy = tree.accuracy(&test_d.x, &test_d.y, test_d.n_features);

    let problem = Arc::new(Problem::new(
        spec.id,
        tree,
        &test_d,
        &lut,
        &lib,
        opts.margin_max,
    ));
    let n_comparators = problem.n_comparators();

    // Baseline accuracy = exact chromosome under the chosen engine's
    // semantics (8-bit quantization).
    let exact = TreeApprox::exact(&problem.tree);
    let baseline_accuracy =
        crate::fitness::native::NativeEngine::accuracy_one(&problem, &exact);

    // Shared-cache wiring: fingerprint this dataset exactly as the
    // engines see it, so a cached entry can never cross datasets (new
    // seed → new fingerprint → different segment file).  The shared tiers
    // ride the service's seams — its injected clock stamps lookup
    // latencies, its metrics take the hit/miss counters — so without a
    // service the tiers stay off and only the per-run memo runs.
    let shared = match (&opts.cache, service) {
        (Some(cache), Some(svc)) => Some(SharedCache {
            cache: Arc::clone(cache),
            fingerprint: DatasetFingerprint::compute(
                spec.id,
                opts.seed,
                spec.n_samples,
                FEATURE_BITS,
            ),
            metrics: Arc::clone(&svc.metrics),
            clock: svc.clock(),
        }),
        _ => None,
    };

    // Warm start: archived front genes for this dataset, re-validated
    // against *this* run's tree (gene count, finite [0,1] range, and the
    // decoded phenotype's representability) before they may seed the
    // population — a stale archive degrades to a cold start, never a
    // poisoned one.
    let warm_seeds: Vec<Chromosome> = opts
        .warm_start
        .as_ref()
        .and_then(|archive| archive.get(spec.id))
        .map(|fronts| {
            let ctx = problem.decode_context(&lut);
            fronts
                .iter()
                .filter(|genes| {
                    genes.len() == 2 * n_comparators
                        && genes.iter().all(|g| g.is_finite() && (0.0..=1.0).contains(g))
                })
                .map(|genes| Chromosome { genes: genes.clone() })
                .filter(|c| {
                    let a = c.decode(&ctx);
                    quant::validate_approx(n_comparators, &a.bits, &a.thr_int).is_ok()
                })
                .collect()
        })
        .unwrap_or_default();

    // GA.
    let ga_cfg = NsgaConfig {
        pop_size: opts.pop_size,
        generations: opts.generations,
        seed: opts.seed,
        warm_seeds,
        ..Default::default()
    };
    let (result, stats, engine_name): (crate::ga::NsgaResult, EvalStats, &'static str) =
        match opts.engine {
            EngineChoice::Native => {
                let mut ev = FitnessEvaluator::new(&problem, &lut, NativeEngine::default());
                ev.microbatch = opts.microbatch;
                ev.shared = shared;
                let result = run_ga(n_comparators, &ga_cfg, &mut ev, trace.as_ref());
                // The native engine cannot fail today, but the evaluator
                // stores errors instead of panicking — never let one pass
                // silently as a front of pessimistic placeholders.
                if let Some(e) = ev.take_error() {
                    return Err(e.context(format!(
                        "accuracy engine failed while optimizing '{dataset_id}'"
                    )));
                }
                (result, ev.stats, "native")
            }
            EngineChoice::NativeService | EngineChoice::Xla => {
                let service = service.ok_or_else(|| {
                    anyhow!("engine {:?} requires an EvalService", opts.engine)
                })?;
                let engine = XlaEngine::register(service, Arc::clone(&problem))?;
                let mut ev = FitnessEvaluator::new(&problem, &lut, engine);
                ev.microbatch = opts.microbatch;
                ev.shared = shared;
                let result = run_ga(n_comparators, &ga_cfg, &mut ev, trace.as_ref());
                // A failed batch poisons the run's fitness values: fail
                // this dataset instead of reporting a front built on
                // placeholders.
                if let Some(e) = ev.take_error() {
                    return Err(e.context(format!(
                        "accuracy engine failed while optimizing '{dataset_id}'"
                    )));
                }
                // Cache effectiveness lands next to the coalescing gauges
                // in the shared service's render line.
                service.metrics.record_eval_stats(&ev.stats);
                (
                    result,
                    ev.stats,
                    if opts.engine == EngineChoice::Xla { "xla" } else { "native-service" },
                )
            }
        };

    Ok(GaPhase {
        spec,
        problem,
        float_accuracy,
        baseline_accuracy,
        result,
        stats,
        engine: engine_name,
        lib,
        lut,
        clock,
        trace,
    })
}

/// The CPU-only half of [`optimize_dataset`]: full synthesis of every
/// front design (the "actual" pareto points) and [`DatasetRun`]
/// packaging.  Needs no eval service, which is exactly why callers may
/// run it after releasing their evaluation slot.
pub fn finish_dataset(phase: GaPhase) -> DatasetRun {
    if let Some(scope) = &phase.trace {
        scope.begin("synthesis");
    }
    let lib = &phase.lib;
    let lut = &phase.lut;
    let ctx = phase.problem.decode_context(lut);
    let mut front: Vec<ParetoPoint> = phase
        .result
        .pareto_front()
        .into_iter()
        .map(|s| {
            let approx = s.chromosome.decode(&ctx);
            let measured = synth::synth_tree(&phase.problem.tree, &approx).netlist.report(lib);
            ParetoPoint {
                accuracy: 1.0 - s.objectives[0],
                est_area_mm2: s.objectives[1],
                measured,
                approx,
                genes: s.chromosome.genes.clone(),
            }
        })
        .collect();
    // total_cmp: a NaN accuracy (e.g. a degenerate candidate) must not
    // panic the whole run after the GA already finished.
    front.sort_by(|a, b| b.accuracy.total_cmp(&a.accuracy));
    if let Some(scope) = &phase.trace {
        scope.end("synthesis");
        scope.end(&format!("dataset {}", phase.spec.id));
    }

    DatasetRun {
        spec: phase.spec,
        float_accuracy: phase.float_accuracy,
        baseline_accuracy: phase.baseline_accuracy,
        baseline: phase.problem.exact_report,
        n_comparators: phase.problem.n_comparators(),
        front,
        history: phase.result.history,
        evaluations: phase.result.evaluations,
        stats: phase.stats,
        elapsed_s: phase.clock.now_ns() as f64 / 1e9,
        engine: phase.engine,
    }
}

fn run_ga(
    n_comparators: usize,
    cfg: &NsgaConfig,
    ev: &mut dyn Evaluator,
    scope: Option<&SpanScope>,
) -> crate::ga::NsgaResult {
    match scope {
        Some(scope) => {
            scope.begin("ga");
            let result = run_nsga2(
                n_comparators,
                cfg,
                &mut TracingEvaluator { inner: ev, scope, generation: 0 },
            );
            scope.end("ga");
            result
        }
        None => run_nsga2(n_comparators, cfg, ev),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_opts() -> RunOptions {
        RunOptions { pop_size: 16, generations: 6, ..RunOptions::default() }
    }

    #[test]
    fn seeds_pipeline_native() {
        let run = optimize_dataset("seeds", &quick_opts(), None).unwrap();
        assert_eq!(run.spec.id, "seeds");
        assert!(!run.front.is_empty());
        // Every front design must be no larger than the baseline.
        for p in &run.front {
            assert!(p.measured.area_mm2 <= run.baseline.area_mm2 * 1.001);
            assert!((0.0..=1.0).contains(&p.accuracy));
            assert!(p.est_area_mm2 > 0.0);
        }
        // The search must find something materially smaller.
        let best = run.front.iter().map(|p| p.measured.area_mm2).fold(f64::INFINITY, f64::min);
        assert!(best < 0.8 * run.baseline.area_mm2, "best {best} baseline {}", run.baseline.area_mm2);
        assert_eq!(run.evaluations, 16 + 6 * 16);
    }

    #[test]
    fn seeds_pipeline_via_service_matches_native() {
        let svc = EvalService::spawn_native(8);
        let a = optimize_dataset("seeds", &quick_opts(), None).unwrap();
        let b = optimize_dataset(
            "seeds",
            &RunOptions { engine: EngineChoice::NativeService, ..quick_opts() },
            Some(&svc),
        )
        .unwrap();
        // Same seed + same arithmetic → identical fronts.
        assert_eq!(a.front.len(), b.front.len());
        for (pa, pb) in a.front.iter().zip(&b.front) {
            assert_eq!(pa.accuracy, pb.accuracy);
            assert_eq!(pa.est_area_mm2, pb.est_area_mm2);
        }
        svc.shutdown();
    }

    /// A sharded, coalescing pool must stay bit-identical to the direct
    /// native engine: routing, chunk merging and padding never change the
    /// per-chromosome arithmetic.
    #[test]
    fn seeds_pipeline_via_sharded_coalescing_service_matches_native() {
        use crate::coordinator::shard::PoolOptions;
        let svc = EvalService::spawn_native_with(
            8,
            &PoolOptions {
                workers: 4,
                coalesce_window_us: 150,
                engine_threads: 1,
                ..PoolOptions::default()
            },
        );
        let a = optimize_dataset("seeds", &quick_opts(), None).unwrap();
        let b = optimize_dataset(
            "seeds",
            &RunOptions { engine: EngineChoice::NativeService, ..quick_opts() },
            Some(&svc),
        )
        .unwrap();
        assert_eq!(a.front.len(), b.front.len());
        for (pa, pb) in a.front.iter().zip(&b.front) {
            assert_eq!(pa.accuracy, pb.accuracy);
            assert_eq!(pa.est_area_mm2, pb.est_area_mm2);
        }
        assert!(svc.metrics.executions.load(std::sync::atomic::Ordering::Relaxed) > 0);
        svc.shutdown();
    }

    /// The two-phase split is lossless (running the GA phase and the
    /// synthesis phase separately produces exactly `optimize_dataset`'s
    /// result) and a micro-batched pipelined service run stays
    /// bit-identical to the native engine, with its [`EvalStats`]
    /// archived on the run and folded into the service metrics.
    #[test]
    fn ga_finish_split_and_microbatching_match_monolithic() {
        let whole = optimize_dataset("seeds", &quick_opts(), None).unwrap();
        let split = finish_dataset(optimize_dataset_ga("seeds", &quick_opts(), None).unwrap());
        assert_eq!(whole.front.len(), split.front.len());
        for (a, b) in whole.front.iter().zip(&split.front) {
            assert_eq!(a.accuracy, b.accuracy);
            assert_eq!(a.est_area_mm2, b.est_area_mm2);
        }

        let svc = EvalService::spawn_native(8);
        let piped = optimize_dataset(
            "seeds",
            &RunOptions {
                engine: EngineChoice::NativeService,
                microbatch: 3,
                ..quick_opts()
            },
            Some(&svc),
        )
        .unwrap();
        assert_eq!(whole.front.len(), piped.front.len());
        for (a, b) in whole.front.iter().zip(&piped.front) {
            assert_eq!(a.accuracy, b.accuracy);
            assert_eq!(a.est_area_mm2, b.est_area_mm2);
        }
        assert_eq!(piped.stats.requested, 16 + 6 * 16);
        assert_eq!(whole.stats.requested, piped.stats.requested);
        assert_eq!(whole.stats.engine_evals, piped.stats.engine_evals);
        let render = svc.metrics.render();
        assert!(render.contains("eval: requested="), "{render}");
        assert!(
            svc.metrics.tickets_submitted.load(std::sync::atomic::Ordering::Relaxed) > 0,
            "pipelined run must ride the ticket API"
        );
        svc.shutdown();
    }

    /// Two runs of the same dataset against one shared cache: the repeat
    /// costs zero engine evaluations (every unique phenotype of the
    /// deterministic trajectory hits L1) and reproduces the front
    /// bit-exactly — the tentpole's core promise, at unit scale.
    #[test]
    fn repeat_run_on_shared_cache_is_engine_free() {
        let svc = EvalService::spawn_native(8);
        let cache = Arc::new(EvalCache::in_memory());
        let opts = RunOptions {
            engine: EngineChoice::NativeService,
            cache: Some(Arc::clone(&cache)),
            ..quick_opts()
        };
        let cold = optimize_dataset("seeds", &opts, Some(&svc)).unwrap();
        assert!(cold.stats.engine_evals > 0);
        assert_eq!(cold.stats.l1_hits + cold.stats.l2_hits, 0, "first run has no shared hits");
        assert!(!cache.is_empty(), "cold run must publish its evals");

        let warm = optimize_dataset("seeds", &opts, Some(&svc)).unwrap();
        assert_eq!(warm.stats.engine_evals, 0, "repeat must be pure lookups: {:?}", warm.stats);
        assert!(warm.stats.l1_hits > 0);
        assert_eq!(warm.stats.requested, cold.stats.requested);
        assert_eq!(cold.front.len(), warm.front.len());
        for (a, b) in cold.front.iter().zip(&warm.front) {
            assert_eq!(a.accuracy, b.accuracy);
            assert_eq!(a.est_area_mm2, b.est_area_mm2);
            assert_eq!(a.genes, b.genes);
        }
        let l1 = svc.metrics.cache_l1_hits.load(std::sync::atomic::Ordering::Relaxed);
        assert!(l1 >= warm.stats.l1_hits as u64, "live counter tracks the run");
        assert!(svc.metrics.render().contains("cache: l1_hits="), "{}", svc.metrics.render());
        svc.shutdown();
    }

    /// A warm-started GA accepts only seeds that survive re-validation,
    /// and the seeded run stays deterministic (same opts → same front).
    #[test]
    fn warm_start_seeds_are_validated_and_deterministic() {
        let cold = optimize_dataset("seeds", &quick_opts(), None).unwrap();
        let genes: Vec<Vec<f64>> = cold.front.iter().map(|p| p.genes.clone()).collect();
        assert!(genes.iter().all(|g| !g.is_empty()), "front archives its genes");

        let mut archive: HashMap<String, Vec<Vec<f64>>> = HashMap::new();
        let mut seeds = genes.clone();
        seeds.push(vec![0.5; 3]); // wrong gene count: dropped by validation
        seeds.push(vec![f64::NAN; genes[0].len()]); // non-finite: dropped
        archive.insert("seeds".to_string(), seeds);
        let opts = RunOptions { warm_start: Some(Arc::new(archive)), ..quick_opts() };
        let a = optimize_dataset("seeds", &opts, None).unwrap();
        let b = optimize_dataset("seeds", &opts, None).unwrap();
        assert_eq!(a.front.len(), b.front.len());
        for (pa, pb) in a.front.iter().zip(&b.front) {
            assert_eq!(pa.accuracy, pb.accuracy);
            assert_eq!(pa.est_area_mm2, pb.est_area_mm2);
        }
        // Warm-started search must never end below the cold baseline's
        // best accuracy: the archived best is in its initial population.
        let best = |run: &DatasetRun| {
            run.front.iter().map(|p| p.accuracy).fold(f64::NEG_INFINITY, f64::max)
        };
        assert!(best(&a) >= best(&cold) - 1e-12, "{} vs {}", best(&a), best(&cold));
    }

    #[test]
    fn best_within_loss_selection() {
        let run = optimize_dataset("seeds", &quick_opts(), None).unwrap();
        if let Some(p) = run.best_within_loss(0.01) {
            assert!(p.accuracy >= run.baseline_accuracy - 0.01);
            let gain = run.area_gain(0.01).unwrap();
            assert!(gain >= 1.0, "gain {gain}");
        }
        // Looser budget → no larger best area.
        let a1 = run.best_within_loss(0.01).map(|p| p.measured.area_mm2);
        let a2 = run.best_within_loss(0.02).map(|p| p.measured.area_mm2);
        if let (Some(a1), Some(a2)) = (a1, a2) {
            assert!(a2 <= a1);
        }
    }

    #[test]
    fn unknown_dataset_rejected() {
        assert!(optimize_dataset("nope", &quick_opts(), None).is_err());
    }

    /// A NaN-producing candidate (degenerate accuracy or area) used to
    /// panic `best_within_loss`/the front sort via
    /// `partial_cmp(..).unwrap()`.  With `total_cmp` the selection is
    /// deterministic and a NaN design can never be picked.
    #[test]
    fn nan_candidates_neither_panic_nor_win_selection() {
        let spec = generators::spec("seeds").unwrap();
        let report = |area: f64| HwReport {
            area_mm2: area,
            power_mw: 1.0,
            delay_ms: 1.0,
            n_cells: 10,
        };
        let point = |accuracy: f64, area: f64| ParetoPoint {
            accuracy,
            est_area_mm2: area,
            measured: report(area),
            approx: TreeApprox { bits: vec![8], thr_int: vec![0] },
            genes: Vec::new(),
        };
        let run = DatasetRun {
            spec,
            float_accuracy: 0.9,
            baseline_accuracy: 0.9,
            baseline: report(2.0),
            n_comparators: 1,
            front: vec![
                point(0.90, 1.0),         // legitimate best
                point(f64::NAN, 0.1),     // NaN accuracy: filtered out
                point(-f64::NAN, 0.1),    // negative-NaN accuracy: same
                point(0.95, f64::NAN),    // NaN area: filtered out
                point(0.95, -f64::NAN),   // negative NaN sorts below every
                                          // finite area — must not win
                point(0.95, f64::INFINITY), // non-finite area: filtered out
            ],
            history: Vec::new(),
            evaluations: 0,
            stats: EvalStats::default(),
            elapsed_s: 0.0,
            engine: "native",
        };
        let best = run.best_within_loss(0.01).expect("finite candidate survives");
        assert_eq!(best.measured.area_mm2, 1.0, "non-finite areas must not win min_by");
        let gain = run.area_gain(0.01).unwrap();
        assert!(gain.is_finite() && (gain - 2.0).abs() < 1e-12, "gain {gain}");

        // A front with no finite-area design within the loss budget yields
        // None (no design), never a garbage selection or a panic.
        let mut all_nan = run.clone();
        all_nan.front = vec![point(0.95, f64::NAN), point(0.95, -f64::NAN)];
        assert!(all_nan.best_within_loss(0.01).is_none());
        assert!(all_nan.area_gain(0.01).is_none());
    }
}
