//! Coordinator metrics: lock-free counters + latency aggregation.
//!
//! One [`Metrics`] instance is shared by every worker of an eval-service
//! pool.  Global counters (executions, chromosomes, padding) aggregate
//! across shards; [`ShardMetrics`] adds per-shard queue depth and
//! execution counts so a skewed hash-route or a stuck worker is visible
//! in the run report.  The coalescer records how each execution was
//! flushed ([`FlushKind`]) and how many client requests it merged.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use crate::fitness::EvalStats;
use crate::util::stats::Summary;

// Poison-recovering lock helper, re-exported where the coordinator took
// it from before it moved to `util::sync` (the `axdt` binary needs it
// `pub`, which a `pub(crate)` item in the lib crate cannot provide).
pub(crate) use crate::util::sync::lock_recover;

/// How a batch left the coalescer and hit the backend.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FlushKind {
    /// Pending work reached the artifact width P.
    Full,
    /// The coalescing window expired on a sub-width batch.
    Deadline,
    /// Coalescing disabled: the request's tail was dispatched immediately.
    Immediate,
    /// Every registered driver of the problem had a request queued
    /// (adaptive mode): drivers block on their in-flight eval, so no more
    /// work can arrive — flush now instead of waiting out the window.
    AllDrivers,
    /// Shutdown/disconnect drain of still-pending work (not a window
    /// expiry, so it does not count toward `deadline_flushes`).
    Drain,
}

/// Per-shard counters (one per pool worker).
#[derive(Debug, Default)]
pub struct ShardMetrics {
    /// Jobs currently queued on this shard (incremented at the client
    /// facade, decremented when the worker dequeues; approximate around
    /// shutdown and worker death, where a send racing the final channel
    /// drop can leave a charge behind — the gauge saturates at 0, never
    /// wraps).
    pub queue_depth: AtomicU64,
    /// Highest queue depth observed.
    pub queue_peak: AtomicU64,
    /// Backend executions issued by this shard's worker.
    pub executions: AtomicU64,
    /// Chromosomes this shard evaluated (pre-padding).
    pub chromosomes: AtomicU64,
    /// Total backend-execution time (ns) this shard's worker has spent
    /// inside `Backend::eval`.  `busy_ns / wall_ns` is the shard's
    /// occupancy; summed across shards it is how many workers the
    /// workload kept busy on average (the pipelined-vs-blocking bench's
    /// acceptance gauge).
    pub busy_ns: AtomicU64,
    /// Chromosomes currently queued in this shard's coalescer (waiting
    /// for a width-full, deadline, or all-drivers flush).  Tests use this
    /// gauge to observe "the batch reached the coalescer" without sleeps.
    pub coalescing: AtomicU64,
    /// Effective coalescing window (ns): the fixed window, or — in
    /// adaptive mode — the controller's latest choice (updated on every
    /// arrival).  0 = coalescing off / no window computed yet.
    pub window_ns: AtomicU64,
    /// Latest per-problem EWMA of request inter-arrival times (ns) on
    /// this shard (0 = fewer than two arrivals so far).
    pub ewma_ia_ns: AtomicU64,
    /// True while this shard's worker is dead (its backend panicked);
    /// cleared again by a successful `--respawn-shards` respawn.
    pub down: AtomicBool,
}

/// Shared counters for the evaluation service.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Backend executions issued.
    pub executions: AtomicU64,
    /// Chromosomes whose fitness was computed (pre-padding).
    pub chromosomes: AtomicU64,
    /// Chromosome slots wasted to padding.
    pub padded_slots: AtomicU64,
    /// Problems registered.
    pub problems: AtomicU64,
    /// Executions that merged >= 2 client requests into one batch.
    pub coalesced_executions: AtomicU64,
    /// Client requests that rode a coalesced execution.
    pub coalesced_requests: AtomicU64,
    /// Width-full coalescer flushes.
    pub full_flushes: AtomicU64,
    /// Deadline-expiry coalescer flushes.
    pub deadline_flushes: AtomicU64,
    /// All-drivers-queued early flushes that merged >= 2 requests
    /// (adaptive coalescing: every registered driver of the problem had
    /// work queued, so the window was cut short).  A solo driver's
    /// all-drivers dispatch is not counted — it merges nothing.
    pub early_flushes: AtomicU64,
    /// Shard-worker deaths (a backend panic killed the worker).
    pub shard_deaths: AtomicU64,
    /// Requests answered with `ShardDown` because their shard's worker
    /// died with them in flight, coalescing, or queued.
    pub stranded_requests: AtomicU64,
    /// Dead workers successfully respawned (`--respawn-shards`).
    pub respawns: AtomicU64,
    /// Tickets issued by the two-phase submit/wait API.  The blocking
    /// `eval` is `wait(submit(..))`, so every evaluation counts.
    pub tickets_submitted: AtomicU64,
    /// Tickets currently in flight (submitted, not yet collected or
    /// dropped).  Saturates at 0, like the queue-depth gauge.
    pub tickets_in_flight: AtomicU64,
    /// Highest in-flight ticket count observed — how deep clients
    /// actually pipeline.
    pub tickets_peak: AtomicU64,
    /// Fitness-evaluator totals across the runs this service served
    /// (recorded per dataset by the driver): chromosome evaluations
    /// requested by the GA…
    pub eval_requested: AtomicU64,
    /// …of which the phenotype cache answered without the engine…
    pub eval_cache_hits: AtomicU64,
    /// …and the engine actually evaluated (post-dedup misses).
    pub eval_engine_evals: AtomicU64,
    /// Per-execution latency (ns).
    latency: Mutex<Summary>,
    /// Real (pre-padding) width of each executed batch.
    batch_width: Mutex<Summary>,
    /// Chromosomes per submitted ticket (the micro-batch width clients
    /// actually pipeline at).
    microbatch_width: Mutex<Summary>,
    /// Submit→collect latency per ticket (ns): queueing + coalescing +
    /// execution, as the client experiences it.
    ticket_latency: Mutex<Summary>,
    /// Per-shard counters (empty for a legacy/default instance).
    shards: Vec<ShardMetrics>,
}

impl Metrics {
    /// Metrics for a pool of `n` shards.
    pub fn with_shards(n: usize) -> Metrics {
        Metrics {
            shards: (0..n).map(|_| ShardMetrics::default()).collect(),
            ..Metrics::default()
        }
    }

    /// Per-shard counters (empty when the instance predates the pool).
    pub fn shards(&self) -> &[ShardMetrics] {
        &self.shards
    }

    pub fn record_execution(&self, real: usize, padded: usize, elapsed_ns: u64) {
        self.executions.fetch_add(1, Ordering::Relaxed);
        self.chromosomes.fetch_add(real as u64, Ordering::Relaxed);
        self.padded_slots.fetch_add((padded - real) as u64, Ordering::Relaxed);
        lock_recover(&self.latency).push(elapsed_ns as f64);
        lock_recover(&self.batch_width).push(real as f64);
    }

    /// Full record for one pool execution: global counters, the issuing
    /// shard's counters, and the coalescer's flush bookkeeping.
    pub fn record_shard_execution(
        &self,
        shard: usize,
        real: usize,
        padded: usize,
        elapsed_ns: u64,
        merged_requests: usize,
        kind: FlushKind,
    ) {
        self.record_execution(real, padded, elapsed_ns);
        if merged_requests >= 2 {
            self.coalesced_executions.fetch_add(1, Ordering::Relaxed);
            self.coalesced_requests.fetch_add(merged_requests as u64, Ordering::Relaxed);
        }
        match kind {
            FlushKind::Full => {
                self.full_flushes.fetch_add(1, Ordering::Relaxed);
            }
            FlushKind::Deadline => {
                self.deadline_flushes.fetch_add(1, Ordering::Relaxed);
            }
            FlushKind::AllDrivers => {
                // A solo driver's all-drivers dispatch is just an
                // immediate dispatch; only count flushes that actually
                // cut a window short to merge >= 2 requests, so `early N`
                // in the render keeps meaning "the controller merged".
                if merged_requests >= 2 {
                    self.early_flushes.fetch_add(1, Ordering::Relaxed);
                }
            }
            FlushKind::Immediate | FlushKind::Drain => {}
        }
        if let Some(s) = self.shards.get(shard) {
            s.executions.fetch_add(1, Ordering::Relaxed);
            s.chromosomes.fetch_add(real as u64, Ordering::Relaxed);
            s.busy_ns.fetch_add(elapsed_ns, Ordering::Relaxed);
        }
    }

    /// A ticket was issued for a batch of `width` chromosomes (the
    /// submit half of the two-phase eval).
    pub fn ticket_submitted(&self, width: u64) {
        self.tickets_submitted.fetch_add(1, Ordering::Relaxed);
        let in_flight = self.tickets_in_flight.fetch_add(1, Ordering::Relaxed) + 1;
        self.tickets_peak.fetch_max(in_flight, Ordering::Relaxed);
        lock_recover(&self.microbatch_width).push(width as f64);
    }

    /// A ticket's result was collected `latency_ns` after its submit.
    pub fn ticket_collected(&self, latency_ns: u64) {
        lock_recover(&self.ticket_latency).push(latency_ns as f64);
    }

    /// A ticket left flight (collected or dropped unredeemed).
    /// Saturating, like the queue-depth gauge.
    pub fn ticket_done(&self) {
        let _ = self.tickets_in_flight.fetch_update(
            Ordering::Relaxed,
            Ordering::Relaxed,
            |d| d.checked_sub(1),
        );
    }

    /// Fold one dataset run's [`EvalStats`] into the service-wide
    /// totals, so cache effectiveness shows up in [`Metrics::render`]
    /// next to the coalescing gauges.
    pub fn record_eval_stats(&self, stats: &EvalStats) {
        self.eval_requested.fetch_add(stats.requested as u64, Ordering::Relaxed);
        self.eval_cache_hits.fetch_add(stats.cache_hits as u64, Ordering::Relaxed);
        self.eval_engine_evals.fetch_add(stats.engine_evals as u64, Ordering::Relaxed);
    }

    /// A job was queued on `shard` (called by the client facade).
    pub fn shard_enqueued(&self, shard: usize) {
        if let Some(s) = self.shards.get(shard) {
            let depth = s.queue_depth.fetch_add(1, Ordering::Relaxed) + 1;
            s.queue_peak.fetch_max(depth, Ordering::Relaxed);
        }
    }

    /// A job left `shard`'s queue (dequeued by the worker, or the send
    /// failed after the enqueue was counted).
    pub fn shard_dequeued(&self, shard: usize) {
        if let Some(s) = self.shards.get(shard) {
            // Saturating: shutdown can drop queued jobs without a dequeue.
            let _ = s.queue_depth.fetch_update(
                Ordering::Relaxed,
                Ordering::Relaxed,
                |d| d.checked_sub(1),
            );
        }
    }

    /// `n` chromosomes entered `shard`'s coalescer queue.
    pub fn coalescing_add(&self, shard: usize, n: u64) {
        if let Some(s) = self.shards.get(shard) {
            s.coalescing.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// `n` chromosomes left `shard`'s coalescer (flushed or purged).
    /// Saturating, like the queue-depth gauge.
    pub fn coalescing_sub(&self, shard: usize, n: u64) {
        if let Some(s) = self.shards.get(shard) {
            let _ = s.coalescing.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |d| {
                d.checked_sub(n).or(Some(0))
            });
        }
    }

    /// A dying worker dropped everything still coalescing on `shard`.
    pub fn coalescing_reset(&self, shard: usize) {
        if let Some(s) = self.shards.get(shard) {
            s.coalescing.store(0, Ordering::Relaxed);
        }
    }

    /// Record the effective coalescing window `shard`'s worker is using
    /// (and, in adaptive mode, the EWMA it was derived from) so
    /// [`Metrics::render`] shows what the controller chose.
    pub fn set_window(&self, shard: usize, window_ns: u64, ewma_ia_ns: Option<u64>) {
        if let Some(s) = self.shards.get(shard) {
            s.window_ns.store(window_ns, Ordering::Relaxed);
            if let Some(e) = ewma_ia_ns {
                s.ewma_ia_ns.store(e, Ordering::Relaxed);
            }
        }
    }

    /// A shard's worker died: count it and flag the shard for `render`.
    pub fn shard_died(&self, shard: usize) {
        self.shard_deaths.fetch_add(1, Ordering::Relaxed);
        if let Some(s) = self.shards.get(shard) {
            s.down.store(true, Ordering::Relaxed);
        }
    }

    /// A dead shard's worker was respawned and serves again.
    pub fn shard_respawned(&self, shard: usize) {
        self.respawns.fetch_add(1, Ordering::Relaxed);
        if let Some(s) = self.shards.get(shard) {
            s.down.store(false, Ordering::Relaxed);
        }
    }

    /// `n` requests were answered with `ShardDown` by a dying worker.
    pub fn record_stranded(&self, n: u64) {
        if n > 0 {
            self.stranded_requests.fetch_add(n, Ordering::Relaxed);
        }
    }

    pub fn latency_summary(&self) -> Summary {
        lock_recover(&self.latency).clone()
    }

    /// Distribution of real (pre-padding) executed batch widths.
    pub fn batch_width_summary(&self) -> Summary {
        lock_recover(&self.batch_width).clone()
    }

    /// Distribution of chromosomes per submitted ticket.
    pub fn microbatch_width_summary(&self) -> Summary {
        lock_recover(&self.microbatch_width).clone()
    }

    /// Distribution of per-ticket submit→collect latencies (ns).
    pub fn ticket_latency_summary(&self) -> Summary {
        lock_recover(&self.ticket_latency).clone()
    }

    /// Fraction of executed chromosome slots that were padding.
    pub fn padding_waste(&self) -> f64 {
        let real = self.chromosomes.load(Ordering::Relaxed) as f64;
        let pad = self.padded_slots.load(Ordering::Relaxed) as f64;
        if real + pad == 0.0 {
            0.0
        } else {
            pad / (real + pad)
        }
    }

    /// One-line human summary (the run report's eval-service line).
    pub fn render(&self) -> String {
        let lat = self.latency_summary();
        let width = self.batch_width_summary();
        let mut s = format!(
            "execs={} chromosomes={} padding_waste={:.1}% batch_width_p50={:.0} \
             coalesced={} (reqs {}, full {}, deadline {}, early {}) \
             exec_latency_p50={} p99={}",
            self.executions.load(Ordering::Relaxed),
            self.chromosomes.load(Ordering::Relaxed),
            100.0 * self.padding_waste(),
            if width.is_empty() { 0.0 } else { width.median() },
            self.coalesced_executions.load(Ordering::Relaxed),
            self.coalesced_requests.load(Ordering::Relaxed),
            self.full_flushes.load(Ordering::Relaxed),
            self.deadline_flushes.load(Ordering::Relaxed),
            self.early_flushes.load(Ordering::Relaxed),
            crate::util::stats::fmt_duration_ns(lat.median()),
            crate::util::stats::fmt_duration_ns(lat.percentile(0.99)),
        );
        if !self.shards.is_empty() {
            s.push_str(" shards=[");
            for (i, sh) in self.shards.iter().enumerate() {
                if i > 0 {
                    s.push(' ');
                }
                s.push_str(&format!(
                    "{}:execs={},qpeak={}",
                    i,
                    sh.executions.load(Ordering::Relaxed),
                    sh.queue_peak.load(Ordering::Relaxed),
                ));
                // The window the worker is actually using: fixed, or the
                // adaptive controller's latest choice.  Omitted while no
                // window exists (coalescing off / legacy instance), so
                // operators never see a phantom knob.
                let win = sh.window_ns.load(Ordering::Relaxed);
                if win > 0 {
                    s.push_str(&format!(
                        ",win={}",
                        crate::util::stats::fmt_duration_ns(win as f64)
                    ));
                }
                let ia = sh.ewma_ia_ns.load(Ordering::Relaxed);
                if ia > 0 {
                    s.push_str(&format!(
                        ",ia={}",
                        crate::util::stats::fmt_duration_ns(ia as f64)
                    ));
                }
                if sh.down.load(Ordering::Relaxed) {
                    s.push_str(",down");
                }
            }
            s.push(']');
        }
        // Two-phase eval surface: only rendered once a ticket exists, so
        // legacy instances keep their exact line.
        let tickets = self.tickets_submitted.load(Ordering::Relaxed);
        if tickets > 0 {
            let tl = self.ticket_latency_summary();
            let mb = self.microbatch_width_summary();
            let ticket_p50 = if tl.is_empty() { 0.0 } else { tl.median() };
            s.push_str(&format!(
                " tickets={} inflight={} peak={} ubatch_p50={:.0} ticket_p50={}",
                tickets,
                self.tickets_in_flight.load(Ordering::Relaxed),
                self.tickets_peak.load(Ordering::Relaxed),
                if mb.is_empty() { 0.0 } else { mb.median() },
                crate::util::stats::fmt_duration_ns(ticket_p50),
            ));
        }
        // Cache effectiveness, recorded per dataset by the driver.
        let requested = self.eval_requested.load(Ordering::Relaxed);
        if requested > 0 {
            s.push_str(&format!(
                " eval: requested={} cache_hits={} engine_evals={}",
                requested,
                self.eval_cache_hits.load(Ordering::Relaxed),
                self.eval_engine_evals.load(Ordering::Relaxed),
            ));
        }
        let deaths = self.shard_deaths.load(Ordering::Relaxed);
        if deaths > 0 {
            s.push_str(&format!(
                " deaths={} stranded={} respawns={}",
                deaths,
                self.stranded_requests.load(Ordering::Relaxed),
                self.respawns.load(Ordering::Relaxed),
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_accumulate() {
        let m = Metrics::default();
        m.record_execution(30, 32, 1_000_000);
        m.record_execution(32, 32, 2_000_000);
        assert_eq!(m.executions.load(Ordering::Relaxed), 2);
        assert_eq!(m.chromosomes.load(Ordering::Relaxed), 62);
        assert_eq!(m.padded_slots.load(Ordering::Relaxed), 2);
        assert!((m.padding_waste() - 2.0 / 64.0).abs() < 1e-12);
        assert_eq!(m.latency_summary().len(), 2);
        assert!(m.render().contains("execs=2"));
    }

    #[test]
    fn shard_records_split_by_worker() {
        let m = Metrics::with_shards(2);
        m.record_shard_execution(0, 8, 8, 1_000, 1, FlushKind::Full);
        m.record_shard_execution(1, 3, 8, 2_000, 2, FlushKind::Deadline);
        assert_eq!(m.executions.load(Ordering::Relaxed), 2);
        assert_eq!(m.shards()[0].executions.load(Ordering::Relaxed), 1);
        assert_eq!(m.shards()[1].executions.load(Ordering::Relaxed), 1);
        assert_eq!(m.shards()[1].chromosomes.load(Ordering::Relaxed), 3);
        assert_eq!(m.coalesced_executions.load(Ordering::Relaxed), 1);
        assert_eq!(m.coalesced_requests.load(Ordering::Relaxed), 2);
        assert_eq!(m.full_flushes.load(Ordering::Relaxed), 1);
        assert_eq!(m.deadline_flushes.load(Ordering::Relaxed), 1);
        assert_eq!(m.padded_slots.load(Ordering::Relaxed), 5);
        assert!(m.render().contains("shards=["));
    }

    /// A thread that panics while holding a metrics mutex poisons it; the
    /// other clients' record/summary calls must recover, not cascade the
    /// panic into every GA driver sharing the service.
    #[test]
    fn poisoned_mutexes_recover_instead_of_cascading() {
        let m = std::sync::Arc::new(Metrics::default());
        m.record_execution(8, 8, 1_000);
        let m2 = std::sync::Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _guard = m2.latency.lock().unwrap();
            let _guard2 = m2.batch_width.lock().unwrap();
            panic!("poison both metrics mutexes");
        })
        .join();
        // All four lock sites keep working on the poisoned mutexes.
        m.record_execution(4, 8, 2_000);
        assert_eq!(m.latency_summary().len(), 2);
        assert_eq!(m.batch_width_summary().len(), 2);
        assert!(m.render().contains("execs=2"));
    }

    #[test]
    fn death_counters_and_render_flags() {
        let m = Metrics::with_shards(2);
        m.shard_died(1);
        m.record_stranded(3);
        assert_eq!(m.shard_deaths.load(Ordering::Relaxed), 1);
        assert_eq!(m.stranded_requests.load(Ordering::Relaxed), 3);
        assert!(m.shards()[1].down.load(Ordering::Relaxed));
        let r = m.render();
        assert!(r.contains("1:execs=0,qpeak=0,down"), "{r}");
        assert!(r.contains("deaths=1 stranded=3 respawns=0"), "{r}");
        m.shard_respawned(1);
        assert!(!m.shards()[1].down.load(Ordering::Relaxed));
        assert!(m.render().contains("respawns=1"));
        // Zero strandings are not counted; out-of-range shards ignored.
        m.record_stranded(0);
        assert_eq!(m.stranded_requests.load(Ordering::Relaxed), 3);
        m.shard_died(9);
        assert_eq!(m.shard_deaths.load(Ordering::Relaxed), 2);
    }

    /// The adaptive-coalescing surface: early-flush counting, the
    /// coalescing gauge, and the effective window/EWMA rendered per shard.
    #[test]
    fn adaptive_gauges_and_early_flushes_render() {
        let m = Metrics::with_shards(2);
        m.record_shard_execution(0, 6, 8, 1_000, 3, FlushKind::AllDrivers);
        assert_eq!(m.early_flushes.load(Ordering::Relaxed), 1);
        assert_eq!(m.deadline_flushes.load(Ordering::Relaxed), 0);
        assert!(m.render().contains("early 1"), "{}", m.render());

        m.coalescing_add(0, 5);
        m.coalescing_add(0, 4);
        m.coalescing_sub(0, 6);
        assert_eq!(m.shards()[0].coalescing.load(Ordering::Relaxed), 3);
        // Saturates instead of wrapping; reset zeroes (worker death).
        m.coalescing_sub(0, 100);
        assert_eq!(m.shards()[0].coalescing.load(Ordering::Relaxed), 0);
        m.coalescing_add(0, 2);
        m.coalescing_reset(0);
        assert_eq!(m.shards()[0].coalescing.load(Ordering::Relaxed), 0);

        // No window recorded → no phantom knob in the render.
        assert!(!m.render().contains("win="), "{}", m.render());
        m.set_window(1, 150_000, None);
        let r = m.render();
        assert!(r.contains("1:execs=0,qpeak=0,win="), "{r}");
        assert!(!r.contains("ia="), "no EWMA recorded yet: {r}");
        m.set_window(1, 300_000, Some(140_000));
        let r = m.render();
        assert!(r.contains("win=") && r.contains("ia="), "{r}");
        // Out-of-range shards are ignored, like every other gauge.
        m.set_window(9, 1, Some(1));
        m.coalescing_add(9, 1);
        m.coalescing_sub(9, 1);
        m.coalescing_reset(9);
    }

    /// The two-phase-eval surface: ticket gauges saturate like the other
    /// gauges, render only appears once a ticket exists, per-shard busy
    /// time accumulates, and driver-recorded [`EvalStats`] fold into the
    /// render line.
    #[test]
    fn ticket_gauges_busy_time_and_eval_stats_render() {
        let m = Metrics::with_shards(1);
        assert!(!m.render().contains("tickets="), "{}", m.render());
        m.ticket_submitted(5);
        m.ticket_submitted(7);
        assert_eq!(m.tickets_in_flight.load(Ordering::Relaxed), 2);
        assert_eq!(m.tickets_peak.load(Ordering::Relaxed), 2);
        assert_eq!(m.microbatch_width_summary().len(), 2);
        m.ticket_collected(1_000);
        m.ticket_done();
        assert_eq!(m.tickets_in_flight.load(Ordering::Relaxed), 1);
        assert_eq!(m.ticket_latency_summary().len(), 1);
        let r = m.render();
        assert!(r.contains("tickets=2 inflight=1 peak=2"), "{r}");
        // Saturates instead of wrapping (abandoned-ticket double count).
        m.ticket_done();
        m.ticket_done();
        assert_eq!(m.tickets_in_flight.load(Ordering::Relaxed), 0);

        assert!(!m.render().contains("eval:"), "{}", m.render());
        m.record_eval_stats(&EvalStats { requested: 10, cache_hits: 4, engine_evals: 6 });
        m.record_eval_stats(&EvalStats { requested: 10, cache_hits: 9, engine_evals: 1 });
        let r = m.render();
        assert!(r.contains("eval: requested=20 cache_hits=13 engine_evals=7"), "{r}");

        m.record_shard_execution(0, 8, 8, 2_000, 1, FlushKind::Full);
        m.record_shard_execution(0, 4, 8, 3_000, 1, FlushKind::Deadline);
        assert_eq!(m.shards()[0].busy_ns.load(Ordering::Relaxed), 5_000);
    }

    #[test]
    fn queue_gauge_tracks_depth_and_peak() {
        let m = Metrics::with_shards(1);
        m.shard_enqueued(0);
        m.shard_enqueued(0);
        m.shard_dequeued(0);
        assert_eq!(m.shards()[0].queue_depth.load(Ordering::Relaxed), 1);
        assert_eq!(m.shards()[0].queue_peak.load(Ordering::Relaxed), 2);
        // Saturates instead of wrapping when shutdown drops queued jobs.
        m.shard_dequeued(0);
        m.shard_dequeued(0);
        assert_eq!(m.shards()[0].queue_depth.load(Ordering::Relaxed), 0);
        // Out-of-range shard indices are ignored (legacy Metrics::default()).
        Metrics::default().shard_enqueued(3);
    }
}
