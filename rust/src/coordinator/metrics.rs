//! Coordinator metrics: lock-free counters + latency aggregation.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::stats::Summary;

/// Shared counters for the evaluation service.
#[derive(Debug, Default)]
pub struct Metrics {
    /// XLA executions issued.
    pub executions: AtomicU64,
    /// Chromosomes whose fitness was computed (pre-padding).
    pub chromosomes: AtomicU64,
    /// Chromosome slots wasted to padding.
    pub padded_slots: AtomicU64,
    /// Problems registered.
    pub problems: AtomicU64,
    /// Per-execution latency (ns).
    latency: Mutex<Summary>,
}

impl Metrics {
    pub fn record_execution(&self, real: usize, padded: usize, elapsed_ns: u64) {
        self.executions.fetch_add(1, Ordering::Relaxed);
        self.chromosomes.fetch_add(real as u64, Ordering::Relaxed);
        self.padded_slots.fetch_add((padded - real) as u64, Ordering::Relaxed);
        self.latency.lock().unwrap().push(elapsed_ns as f64);
    }

    pub fn latency_summary(&self) -> Summary {
        self.latency.lock().unwrap().clone()
    }

    /// Fraction of executed chromosome slots that were padding.
    pub fn padding_waste(&self) -> f64 {
        let real = self.chromosomes.load(Ordering::Relaxed) as f64;
        let pad = self.padded_slots.load(Ordering::Relaxed) as f64;
        if real + pad == 0.0 {
            0.0
        } else {
            pad / (real + pad)
        }
    }

    /// One-line human summary.
    pub fn render(&self) -> String {
        let lat = self.latency_summary();
        format!(
            "execs={} chromosomes={} padding_waste={:.1}% exec_latency_p50={} p99={}",
            self.executions.load(Ordering::Relaxed),
            self.chromosomes.load(Ordering::Relaxed),
            100.0 * self.padding_waste(),
            crate::util::stats::fmt_duration_ns(lat.median()),
            crate::util::stats::fmt_duration_ns(lat.percentile(0.99)),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_accumulate() {
        let m = Metrics::default();
        m.record_execution(30, 32, 1_000_000);
        m.record_execution(32, 32, 2_000_000);
        assert_eq!(m.executions.load(Ordering::Relaxed), 2);
        assert_eq!(m.chromosomes.load(Ordering::Relaxed), 62);
        assert_eq!(m.padded_slots.load(Ordering::Relaxed), 2);
        assert!((m.padding_waste() - 2.0 / 64.0).abs() < 1e-12);
        assert_eq!(m.latency_summary().len(), 2);
        assert!(m.render().contains("execs=2"));
    }
}
