//! Coordinator metrics: lock-free counters + latency aggregation.
//!
//! One [`Metrics`] instance is shared by every worker of an eval-service
//! pool.  Global counters (executions, chromosomes, padding) aggregate
//! across shards; [`ShardMetrics`] adds per-shard queue depth and
//! execution counts so a skewed hash-route or a stuck worker is visible
//! in the run report.  The coalescer records how each execution was
//! flushed ([`FlushKind`]) and how many client requests it merged.

use std::io::Write;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, RecvTimeoutError};
use std::sync::Arc;
use std::thread;

use crate::fitness::EvalStats;
use crate::util::clock::Clock;
use crate::util::json::Json;
use crate::util::stats::{HistogramSnapshot, Log2Histogram};
use crate::util::trace::TraceJournal;

// Poison-recovering lock helper, re-exported where the coordinator took
// it from before it moved to `util::sync` (the `axdt` binary needs it
// `pub`, which a `pub(crate)` item in the lib crate cannot provide).
pub(crate) use crate::util::sync::lock_recover;

/// How a batch left the coalescer and hit the backend.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FlushKind {
    /// Pending work reached the artifact width P.
    Full,
    /// The coalescing window expired on a sub-width batch.
    Deadline,
    /// Coalescing disabled: the request's tail was dispatched immediately.
    Immediate,
    /// Every registered driver of the problem had a request queued
    /// (adaptive mode): drivers block on their in-flight eval, so no more
    /// work can arrive — flush now instead of waiting out the window.
    AllDrivers,
    /// Shutdown/disconnect drain of still-pending work (not a window
    /// expiry, so it does not count toward `deadline_flushes`).
    Drain,
}

impl FlushKind {
    /// Stable label used by trace events and the Perfetto export.
    pub fn label(self) -> &'static str {
        match self {
            FlushKind::Full => "Full",
            FlushKind::Deadline => "Deadline",
            FlushKind::Immediate => "Immediate",
            FlushKind::AllDrivers => "AllDrivers",
            FlushKind::Drain => "Drain",
        }
    }
}

/// Per-shard counters (one per pool worker).
#[derive(Debug, Default)]
pub struct ShardMetrics {
    /// Jobs currently queued on this shard (incremented at the client
    /// facade, decremented when the worker dequeues; approximate around
    /// shutdown and worker death, where a send racing the final channel
    /// drop can leave a charge behind — the gauge saturates at 0, never
    /// wraps).
    pub queue_depth: AtomicU64,
    /// Highest queue depth observed.
    pub queue_peak: AtomicU64,
    /// Backend executions issued by this shard's worker.
    pub executions: AtomicU64,
    /// Chromosomes this shard evaluated (pre-padding).
    pub chromosomes: AtomicU64,
    /// Total backend-execution time (ns) this shard's worker has spent
    /// inside `Backend::eval`.  `busy_ns / wall_ns` is the shard's
    /// occupancy; summed across shards it is how many workers the
    /// workload kept busy on average (the pipelined-vs-blocking bench's
    /// acceptance gauge).
    pub busy_ns: AtomicU64,
    /// Chromosomes currently queued in this shard's coalescer (waiting
    /// for a width-full, deadline, or all-drivers flush).  Tests use this
    /// gauge to observe "the batch reached the coalescer" without sleeps.
    pub coalescing: AtomicU64,
    /// Effective coalescing window (ns): the fixed window, or — in
    /// adaptive mode — the controller's latest choice (updated on every
    /// arrival).  0 = coalescing off / no window computed yet.
    pub window_ns: AtomicU64,
    /// Latest per-problem EWMA of request inter-arrival times (ns) on
    /// this shard (0 = fewer than two arrivals so far).
    pub ewma_ia_ns: AtomicU64,
    /// True while this shard's worker is dead (its backend panicked);
    /// cleared again by a successful `--respawn-shards` respawn.
    pub down: AtomicBool,
}

/// Shared counters for the evaluation service.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Backend executions issued.
    pub executions: AtomicU64,
    /// Chromosomes whose fitness was computed (pre-padding).
    pub chromosomes: AtomicU64,
    /// Chromosome slots wasted to padding.
    pub padded_slots: AtomicU64,
    /// Problems registered.
    pub problems: AtomicU64,
    /// Executions that merged >= 2 client requests into one batch.
    pub coalesced_executions: AtomicU64,
    /// Client requests that rode a coalesced execution.
    pub coalesced_requests: AtomicU64,
    /// Width-full coalescer flushes.
    pub full_flushes: AtomicU64,
    /// Deadline-expiry coalescer flushes.
    pub deadline_flushes: AtomicU64,
    /// All-drivers-queued early flushes that merged >= 2 requests
    /// (adaptive coalescing: every registered driver of the problem had
    /// work queued, so the window was cut short).  A solo driver's
    /// all-drivers dispatch is not counted — it merges nothing.
    pub early_flushes: AtomicU64,
    /// Shard-worker deaths (a backend panic killed the worker).
    pub shard_deaths: AtomicU64,
    /// Requests answered with `ShardDown` because their shard's worker
    /// died with them in flight, coalescing, or queued.
    pub stranded_requests: AtomicU64,
    /// Dead workers successfully respawned (`--respawn-shards`).
    pub respawns: AtomicU64,
    /// Tickets issued by the two-phase submit/wait API.  The blocking
    /// `eval` is `wait(submit(..))`, so every evaluation counts.
    pub tickets_submitted: AtomicU64,
    /// Tickets currently in flight (submitted, not yet collected or
    /// dropped).  Saturates at 0, like the queue-depth gauge.
    pub tickets_in_flight: AtomicU64,
    /// Highest in-flight ticket count observed — how deep clients
    /// actually pipeline.
    pub tickets_peak: AtomicU64,
    /// Fitness-evaluator totals across the runs this service served
    /// (recorded per dataset by the driver): chromosome evaluations
    /// requested by the GA…
    pub eval_requested: AtomicU64,
    /// …of which the phenotype cache answered without the engine…
    pub eval_cache_hits: AtomicU64,
    /// …and the engine actually evaluated (post-dedup misses).
    pub eval_engine_evals: AtomicU64,
    /// Shared-cache hits answered by the in-memory L1 tier (entries this
    /// process produced), bumped live by the fitness evaluator.
    pub cache_l1_hits: AtomicU64,
    /// Shared-cache hits answered by the persistent L2 tier (entries
    /// loaded from disk segments).  A warm repeat run proves itself with
    /// `engine_evals == 0` next to a nonzero value here.
    pub cache_l2_hits: AtomicU64,
    /// Shared-cache probes that found nothing in either tier.
    pub cache_misses: AtomicU64,
    /// Entries appended to disk segments by cache spills.
    pub cache_spills: AtomicU64,
    /// Corrupt/torn segment records that made the L2 loader stop a file
    /// early (each counts once; the good prefix is still served).
    pub cache_load_errors: AtomicU64,
    /// Bit-plane builds performed at problem registration (the native
    /// engine's one-time test-set transpose; at most one per problem).
    pub plane_builds: AtomicU64,
    /// Total time (ns) spent building bit planes, on the injected clock.
    pub plane_build_ns: AtomicU64,
    /// Test samples scored by backend executions (chromosomes × n_test):
    /// the numerator of the engine's samples/sec throughput gauge.
    pub eval_samples: AtomicU64,
    /// Per-execution backend latency (ns).  A bounded log₂ histogram —
    /// the service can record millions of executions without growing
    /// (the old `Summary` buffered every sample in a `Vec<f64>`).
    exec_latency: Log2Histogram,
    /// Real (pre-padding) width of each executed batch.
    batch_width: Log2Histogram,
    /// Chromosomes per submitted ticket (the micro-batch width clients
    /// actually pipeline at).
    microbatch_width: Log2Histogram,
    /// Submit→collect latency per ticket (ns): queueing + coalescing +
    /// execution, as the client experiences it.
    ticket_latency: Log2Histogram,
    /// Shared-cache probe latency (ns), hit or miss: the price a repeat
    /// request pays instead of an engine evaluation.
    cache_lookup: Log2Histogram,
    /// Ticket-lifecycle event journal (off by default; enabled by
    /// `--trace-out`).  Producers guard on `trace.enabled()` — one
    /// relaxed load — so a disabled journal stays off the hot path.
    pub trace: TraceJournal,
    /// Per-shard counters (empty for a legacy/default instance).
    shards: Vec<ShardMetrics>,
}

impl Metrics {
    /// Metrics for a pool of `n` shards.
    pub fn with_shards(n: usize) -> Metrics {
        Metrics {
            shards: (0..n).map(|_| ShardMetrics::default()).collect(),
            ..Metrics::default()
        }
    }

    /// Per-shard counters (empty when the instance predates the pool).
    pub fn shards(&self) -> &[ShardMetrics] {
        &self.shards
    }

    pub fn record_execution(&self, real: usize, padded: usize, elapsed_ns: u64) {
        self.executions.fetch_add(1, Ordering::Relaxed);
        self.chromosomes.fetch_add(real as u64, Ordering::Relaxed);
        self.padded_slots.fetch_add((padded - real) as u64, Ordering::Relaxed);
        self.exec_latency.record(elapsed_ns);
        self.batch_width.record(real as u64);
    }

    /// Full record for one pool execution: global counters, the issuing
    /// shard's counters, and the coalescer's flush bookkeeping.
    pub fn record_shard_execution(
        &self,
        shard: usize,
        real: usize,
        padded: usize,
        elapsed_ns: u64,
        merged_requests: usize,
        kind: FlushKind,
    ) {
        self.record_execution(real, padded, elapsed_ns);
        if merged_requests >= 2 {
            self.coalesced_executions.fetch_add(1, Ordering::Relaxed);
            self.coalesced_requests.fetch_add(merged_requests as u64, Ordering::Relaxed);
        }
        match kind {
            FlushKind::Full => {
                self.full_flushes.fetch_add(1, Ordering::Relaxed);
            }
            FlushKind::Deadline => {
                self.deadline_flushes.fetch_add(1, Ordering::Relaxed);
            }
            FlushKind::AllDrivers => {
                // A solo driver's all-drivers dispatch is just an
                // immediate dispatch; only count flushes that actually
                // cut a window short to merge >= 2 requests, so `early N`
                // in the render keeps meaning "the controller merged".
                if merged_requests >= 2 {
                    self.early_flushes.fetch_add(1, Ordering::Relaxed);
                }
            }
            FlushKind::Immediate | FlushKind::Drain => {}
        }
        if let Some(s) = self.shards.get(shard) {
            s.executions.fetch_add(1, Ordering::Relaxed);
            s.chromosomes.fetch_add(real as u64, Ordering::Relaxed);
            s.busy_ns.fetch_add(elapsed_ns, Ordering::Relaxed);
        }
    }

    /// A ticket was issued for a batch of `width` chromosomes (the
    /// submit half of the two-phase eval).
    pub fn ticket_submitted(&self, width: u64) {
        self.tickets_submitted.fetch_add(1, Ordering::Relaxed);
        let in_flight = self.tickets_in_flight.fetch_add(1, Ordering::Relaxed) + 1;
        self.tickets_peak.fetch_max(in_flight, Ordering::Relaxed);
        self.microbatch_width.record(width);
    }

    /// A ticket's result was collected `latency_ns` after its submit.
    pub fn ticket_collected(&self, latency_ns: u64) {
        self.ticket_latency.record(latency_ns);
    }

    /// A ticket left flight (collected or dropped unredeemed).
    /// Saturating, like the queue-depth gauge.
    pub fn ticket_done(&self) {
        let _ = self.tickets_in_flight.fetch_update(
            Ordering::Relaxed,
            Ordering::Relaxed,
            |d| d.checked_sub(1),
        );
    }

    /// Fold one dataset run's [`EvalStats`] into the service-wide
    /// totals, so cache effectiveness shows up in [`Metrics::render`]
    /// next to the coalescing gauges.
    pub fn record_eval_stats(&self, stats: &EvalStats) {
        self.eval_requested.fetch_add(stats.requested as u64, Ordering::Relaxed);
        self.eval_cache_hits.fetch_add(stats.cache_hits as u64, Ordering::Relaxed);
        self.eval_engine_evals.fetch_add(stats.engine_evals as u64, Ordering::Relaxed);
        // Tier hits (`l1_hits`/`l2_hits`) are NOT folded here: the
        // evaluator bumps `cache_l1_hits`/`cache_l2_hits` live on the same
        // shared instance, so folding them again would double count.
    }

    /// One shared-cache probe took `ns` on the caller's injected clock.
    pub fn record_cache_lookup(&self, ns: u64) {
        self.cache_lookup.record(ns);
    }

    /// Distribution of shared-cache probe latencies (ns).
    pub fn cache_lookup_hist(&self) -> HistogramSnapshot {
        self.cache_lookup.snapshot()
    }

    /// One bit-plane build finished, `elapsed_ns` on the caller's
    /// injected clock (planes are built once per registered problem and
    /// reused by every execution, so builds ≤ problems always).
    pub fn record_plane_build(&self, elapsed_ns: u64) {
        self.plane_builds.fetch_add(1, Ordering::Relaxed);
        self.plane_build_ns.fetch_add(elapsed_ns, Ordering::Relaxed);
    }

    /// A backend execution scored `n` test samples (chromosomes in the
    /// real batch × the problem's test-set size).
    pub fn record_eval_samples(&self, n: u64) {
        self.eval_samples.fetch_add(n, Ordering::Relaxed);
    }

    /// Engine throughput in test samples per second of shard busy time
    /// (NaN until an execution with sample accounting has run).
    pub fn samples_per_sec(&self) -> f64 {
        let samples = self.eval_samples.load(Ordering::Relaxed) as f64;
        let busy: u64 = self.shards.iter().map(|s| s.busy_ns.load(Ordering::Relaxed)).sum();
        if samples == 0.0 || busy == 0 {
            return f64::NAN;
        }
        samples / (busy as f64 / 1e9)
    }

    /// A job was queued on `shard` (called by the client facade).
    pub fn shard_enqueued(&self, shard: usize) {
        if let Some(s) = self.shards.get(shard) {
            let depth = s.queue_depth.fetch_add(1, Ordering::Relaxed) + 1;
            s.queue_peak.fetch_max(depth, Ordering::Relaxed);
        }
    }

    /// A job left `shard`'s queue (dequeued by the worker, or the send
    /// failed after the enqueue was counted).
    pub fn shard_dequeued(&self, shard: usize) {
        if let Some(s) = self.shards.get(shard) {
            // Saturating: shutdown can drop queued jobs without a dequeue.
            let _ = s.queue_depth.fetch_update(
                Ordering::Relaxed,
                Ordering::Relaxed,
                |d| d.checked_sub(1),
            );
        }
    }

    /// `n` chromosomes entered `shard`'s coalescer queue.
    pub fn coalescing_add(&self, shard: usize, n: u64) {
        if let Some(s) = self.shards.get(shard) {
            s.coalescing.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// `n` chromosomes left `shard`'s coalescer (flushed or purged).
    /// Saturating, like the queue-depth gauge.
    pub fn coalescing_sub(&self, shard: usize, n: u64) {
        if let Some(s) = self.shards.get(shard) {
            let _ = s.coalescing.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |d| {
                d.checked_sub(n).or(Some(0))
            });
        }
    }

    /// A dying worker dropped everything still coalescing on `shard`.
    pub fn coalescing_reset(&self, shard: usize) {
        if let Some(s) = self.shards.get(shard) {
            s.coalescing.store(0, Ordering::Relaxed);
        }
    }

    /// Record the effective coalescing window `shard`'s worker is using
    /// (and, in adaptive mode, the EWMA it was derived from) so
    /// [`Metrics::render`] shows what the controller chose.
    pub fn set_window(&self, shard: usize, window_ns: u64, ewma_ia_ns: Option<u64>) {
        if let Some(s) = self.shards.get(shard) {
            s.window_ns.store(window_ns, Ordering::Relaxed);
            if let Some(e) = ewma_ia_ns {
                s.ewma_ia_ns.store(e, Ordering::Relaxed);
            }
        }
    }

    /// A shard's worker died: count it and flag the shard for `render`.
    pub fn shard_died(&self, shard: usize) {
        self.shard_deaths.fetch_add(1, Ordering::Relaxed);
        if let Some(s) = self.shards.get(shard) {
            s.down.store(true, Ordering::Relaxed);
        }
    }

    /// A dead shard's worker was respawned and serves again.
    pub fn shard_respawned(&self, shard: usize) {
        self.respawns.fetch_add(1, Ordering::Relaxed);
        if let Some(s) = self.shards.get(shard) {
            s.down.store(false, Ordering::Relaxed);
        }
    }

    /// `n` requests were answered with `ShardDown` by a dying worker.
    pub fn record_stranded(&self, n: u64) {
        if n > 0 {
            self.stranded_requests.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Distribution of per-execution backend latencies (ns).
    pub fn exec_latency_hist(&self) -> HistogramSnapshot {
        self.exec_latency.snapshot()
    }

    /// Distribution of real (pre-padding) executed batch widths.
    pub fn batch_width_hist(&self) -> HistogramSnapshot {
        self.batch_width.snapshot()
    }

    /// Exact mean executed batch width (the histogram buckets widths,
    /// so the mean comes from the exact counters instead).
    pub fn batch_width_mean(&self) -> f64 {
        let execs = self.executions.load(Ordering::Relaxed) as f64;
        if execs == 0.0 {
            return f64::NAN;
        }
        self.chromosomes.load(Ordering::Relaxed) as f64 / execs
    }

    /// Distribution of chromosomes per submitted ticket.
    pub fn microbatch_width_hist(&self) -> HistogramSnapshot {
        self.microbatch_width.snapshot()
    }

    /// Distribution of per-ticket submit→collect latencies (ns).
    pub fn ticket_latency_hist(&self) -> HistogramSnapshot {
        self.ticket_latency.snapshot()
    }

    /// Fraction of executed chromosome slots that were padding.
    pub fn padding_waste(&self) -> f64 {
        let real = self.chromosomes.load(Ordering::Relaxed) as f64;
        let pad = self.padded_slots.load(Ordering::Relaxed) as f64;
        if real + pad == 0.0 {
            0.0
        } else {
            pad / (real + pad)
        }
    }

    /// One-line human summary (the run report's eval-service line).
    pub fn render(&self) -> String {
        let lat = self.exec_latency_hist();
        let width = self.batch_width_hist();
        let mut s = format!(
            "execs={} chromosomes={} padding_waste={:.1}% batch_width_p50={} \
             coalesced={} (reqs {}, full {}, deadline {}, early {}) \
             exec_latency_p50={} p90={} p99={} max={}",
            self.executions.load(Ordering::Relaxed),
            self.chromosomes.load(Ordering::Relaxed),
            100.0 * self.padding_waste(),
            width.p50(),
            self.coalesced_executions.load(Ordering::Relaxed),
            self.coalesced_requests.load(Ordering::Relaxed),
            self.full_flushes.load(Ordering::Relaxed),
            self.deadline_flushes.load(Ordering::Relaxed),
            self.early_flushes.load(Ordering::Relaxed),
            crate::util::stats::fmt_duration_ns(lat.p50() as f64),
            crate::util::stats::fmt_duration_ns(lat.p90() as f64),
            crate::util::stats::fmt_duration_ns(lat.p99() as f64),
            crate::util::stats::fmt_duration_ns(lat.max as f64),
        );
        if !self.shards.is_empty() {
            s.push_str(" shards=[");
            for (i, sh) in self.shards.iter().enumerate() {
                if i > 0 {
                    s.push(' ');
                }
                s.push_str(&format!(
                    "{}:execs={},qpeak={}",
                    i,
                    sh.executions.load(Ordering::Relaxed),
                    sh.queue_peak.load(Ordering::Relaxed),
                ));
                // The window the worker is actually using: fixed, or the
                // adaptive controller's latest choice.  Omitted while no
                // window exists (coalescing off / legacy instance), so
                // operators never see a phantom knob.
                let win = sh.window_ns.load(Ordering::Relaxed);
                if win > 0 {
                    s.push_str(&format!(
                        ",win={}",
                        crate::util::stats::fmt_duration_ns(win as f64)
                    ));
                }
                let ia = sh.ewma_ia_ns.load(Ordering::Relaxed);
                if ia > 0 {
                    s.push_str(&format!(
                        ",ia={}",
                        crate::util::stats::fmt_duration_ns(ia as f64)
                    ));
                }
                if sh.down.load(Ordering::Relaxed) {
                    s.push_str(",down");
                }
            }
            s.push(']');
        }
        // Two-phase eval surface: only rendered once a ticket exists, so
        // legacy instances keep their exact line.
        let tickets = self.tickets_submitted.load(Ordering::Relaxed);
        if tickets > 0 {
            let tl = self.ticket_latency_hist();
            let mb = self.microbatch_width_hist();
            s.push_str(&format!(
                " tickets={} inflight={} peak={} ubatch_p50={} ticket_p50={} p99={}",
                tickets,
                self.tickets_in_flight.load(Ordering::Relaxed),
                self.tickets_peak.load(Ordering::Relaxed),
                mb.p50(),
                crate::util::stats::fmt_duration_ns(tl.p50() as f64),
                crate::util::stats::fmt_duration_ns(tl.p99() as f64),
            ));
        }
        // Cache effectiveness, recorded per dataset by the driver.
        let requested = self.eval_requested.load(Ordering::Relaxed);
        if requested > 0 {
            s.push_str(&format!(
                " eval: requested={} cache_hits={} engine_evals={}",
                requested,
                self.eval_cache_hits.load(Ordering::Relaxed),
                self.eval_engine_evals.load(Ordering::Relaxed),
            ));
        }
        // Tiered shared-cache surface: only rendered once a probe, spill,
        // or load-error happened, so untiered runs keep their exact line.
        let cache_activity = self.cache_l1_hits.load(Ordering::Relaxed)
            + self.cache_l2_hits.load(Ordering::Relaxed)
            + self.cache_misses.load(Ordering::Relaxed)
            + self.cache_spills.load(Ordering::Relaxed)
            + self.cache_load_errors.load(Ordering::Relaxed);
        if cache_activity > 0 {
            let cl = self.cache_lookup_hist();
            s.push_str(&format!(
                " cache: l1_hits={} l2_hits={} misses={} spills={} load_errors={} lookup_p50={}",
                self.cache_l1_hits.load(Ordering::Relaxed),
                self.cache_l2_hits.load(Ordering::Relaxed),
                self.cache_misses.load(Ordering::Relaxed),
                self.cache_spills.load(Ordering::Relaxed),
                self.cache_load_errors.load(Ordering::Relaxed),
                crate::util::stats::fmt_duration_ns(cl.p50() as f64),
            ));
        }
        // Native-engine throughput surface: only rendered once a plane
        // build or sample-accounted execution happened, so XLA-only and
        // legacy instances keep their exact line.
        let plane_builds = self.plane_builds.load(Ordering::Relaxed);
        if plane_builds > 0 {
            s.push_str(&format!(
                " planes: builds={} build_time={}",
                plane_builds,
                crate::util::stats::fmt_duration_ns(
                    self.plane_build_ns.load(Ordering::Relaxed) as f64
                ),
            ));
        }
        let samples = self.eval_samples.load(Ordering::Relaxed);
        if samples > 0 {
            let sps = self.samples_per_sec();
            if sps.is_finite() {
                s.push_str(&format!(" samples={samples} samples_per_sec={sps:.3e}"));
            } else {
                s.push_str(&format!(" samples={samples}"));
            }
        }
        let deaths = self.shard_deaths.load(Ordering::Relaxed);
        if deaths > 0 {
            s.push_str(&format!(
                " deaths={} stranded={} respawns={}",
                deaths,
                self.stranded_requests.load(Ordering::Relaxed),
                self.respawns.load(Ordering::Relaxed),
            ));
        }
        let trace_dropped = self.trace.dropped();
        if trace_dropped > 0 {
            s.push_str(&format!(" trace_dropped={trace_dropped}"));
        }
        s
    }

    /// Histogram block for `runs.json` / snapshots: count, p50/p90/p99
    /// and the exact max per hot-path distribution.
    pub fn histograms_json(&self) -> Json {
        fn hist(h: &HistogramSnapshot) -> Json {
            Json::obj(vec![
                ("count", Json::num(h.count() as f64)),
                ("p50", Json::num(h.p50() as f64)),
                ("p90", Json::num(h.p90() as f64)),
                ("p99", Json::num(h.p99() as f64)),
                ("max", Json::num(h.max as f64)),
            ])
        }
        Json::obj(vec![
            ("exec_latency_ns", hist(&self.exec_latency_hist())),
            ("batch_width", hist(&self.batch_width_hist())),
            ("microbatch_width", hist(&self.microbatch_width_hist())),
            ("ticket_latency_ns", hist(&self.ticket_latency_hist())),
            ("cache_lookup_ns", hist(&self.cache_lookup_hist())),
        ])
    }

    /// One point-in-time JSON snapshot of the live gauges (the
    /// `--metrics-interval-ms` JSON-lines payload).  `now_ns` comes from
    /// the caller's injected clock.
    pub fn snapshot_json(&self, now_ns: u64) -> Json {
        let shards = self
            .shards
            .iter()
            .map(|sh| {
                Json::obj(vec![
                    ("queue_depth", Json::num(sh.queue_depth.load(Ordering::Relaxed) as f64)),
                    ("executions", Json::num(sh.executions.load(Ordering::Relaxed) as f64)),
                    ("coalescing", Json::num(sh.coalescing.load(Ordering::Relaxed) as f64)),
                    ("busy_ns", Json::num(sh.busy_ns.load(Ordering::Relaxed) as f64)),
                    ("down", Json::Bool(sh.down.load(Ordering::Relaxed))),
                ])
            })
            .collect();
        Json::obj(vec![
            ("ts_ns", Json::num(now_ns as f64)),
            ("executions", Json::num(self.executions.load(Ordering::Relaxed) as f64)),
            ("chromosomes", Json::num(self.chromosomes.load(Ordering::Relaxed) as f64)),
            (
                "tickets_in_flight",
                Json::num(self.tickets_in_flight.load(Ordering::Relaxed) as f64),
            ),
            (
                "tickets_submitted",
                Json::num(self.tickets_submitted.load(Ordering::Relaxed) as f64),
            ),
            ("plane_builds", Json::num(self.plane_builds.load(Ordering::Relaxed) as f64)),
            ("plane_build_ns", Json::num(self.plane_build_ns.load(Ordering::Relaxed) as f64)),
            ("eval_samples", Json::num(self.eval_samples.load(Ordering::Relaxed) as f64)),
            ("cache_l1_hits", Json::num(self.cache_l1_hits.load(Ordering::Relaxed) as f64)),
            ("cache_l2_hits", Json::num(self.cache_l2_hits.load(Ordering::Relaxed) as f64)),
            ("cache_misses", Json::num(self.cache_misses.load(Ordering::Relaxed) as f64)),
            ("cache_spills", Json::num(self.cache_spills.load(Ordering::Relaxed) as f64)),
            (
                "cache_load_errors",
                Json::num(self.cache_load_errors.load(Ordering::Relaxed) as f64),
            ),
            ("shard_deaths", Json::num(self.shard_deaths.load(Ordering::Relaxed) as f64)),
            ("trace_dropped", Json::num(self.trace.dropped() as f64)),
            ("hist", self.histograms_json()),
            ("shards", Json::Arr(shards)),
        ])
    }
}

/// Message type of the snapshot emitter's control channel: clock wakers
/// nudge it on virtual-time advances, `stop` shuts it down.
enum EmitterMsg {
    Nudge,
    Stop,
}

/// Periodic live-metrics emitter: a thread that writes one
/// [`Metrics::snapshot_json`] line per interval to `out` (JSON lines).
///
/// All timing reads the injected [`Clock`]: on `SystemClock` the
/// channel timeout is the real remaining interval; on `ManualClock` the
/// emitter blocks until the test advances the clock (the registered
/// waker nudges it awake), so snapshot cadence is deterministic under
/// test — the same recv-timeout idiom the shard workers use.
pub struct SnapshotEmitter {
    tx: mpsc::Sender<EmitterMsg>,
    handle: Option<thread::JoinHandle<()>>,
}

impl SnapshotEmitter {
    /// Spawn the emitter.  `interval_ms` must be > 0 (callers gate the
    /// 0 = disabled case); sub-millisecond clamping is the caller's
    /// `validate()` problem.
    pub fn spawn(
        metrics: Arc<Metrics>,
        clock: Arc<dyn Clock>,
        interval_ms: u64,
        mut out: Box<dyn Write + Send>,
    ) -> SnapshotEmitter {
        let (tx, rx) = mpsc::channel::<EmitterMsg>();
        let nudge = tx.clone();
        clock.register_waker(Box::new(move || {
            let _ = nudge.send(EmitterMsg::Nudge);
        }));
        let interval_ns = interval_ms.saturating_mul(1_000_000).max(1);
        // The first deadline is fixed before the thread starts, so a
        // ManualClock advance that lands between spawn and the thread's
        // first wait is never missed (its nudge is already queued).
        let mut next = clock.now_ns().saturating_add(interval_ns);
        let handle = thread::spawn(move || {
            loop {
                match rx.recv_timeout(clock.wait_budget(next)) {
                    Ok(EmitterMsg::Stop) | Err(RecvTimeoutError::Disconnected) => break,
                    Ok(EmitterMsg::Nudge) | Err(RecvTimeoutError::Timeout) => {}
                }
                let now = clock.now_ns();
                if now >= next {
                    let _ = writeln!(out, "{}", metrics.snapshot_json(now));
                    next = now.saturating_add(interval_ns);
                }
            }
            // Final snapshot on shutdown so short runs always emit.
            let _ = writeln!(out, "{}", metrics.snapshot_json(clock.now_ns()));
            let _ = out.flush();
        });
        SnapshotEmitter { tx, handle: Some(handle) }
    }

    /// Stop the emitter and join it (flushes a final snapshot line).
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        let _ = self.tx.send(EmitterMsg::Stop);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for SnapshotEmitter {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_accumulate() {
        let m = Metrics::default();
        m.record_execution(30, 32, 1_000_000);
        m.record_execution(32, 32, 2_000_000);
        assert_eq!(m.executions.load(Ordering::Relaxed), 2);
        assert_eq!(m.chromosomes.load(Ordering::Relaxed), 62);
        assert_eq!(m.padded_slots.load(Ordering::Relaxed), 2);
        assert!((m.padding_waste() - 2.0 / 64.0).abs() < 1e-12);
        assert_eq!(m.exec_latency_hist().count(), 2);
        assert_eq!(m.exec_latency_hist().max, 2_000_000);
        assert_eq!(m.batch_width_hist().count(), 2);
        assert!((m.batch_width_mean() - 31.0).abs() < 1e-12);
        assert!(m.render().contains("execs=2"));
    }

    #[test]
    fn shard_records_split_by_worker() {
        let m = Metrics::with_shards(2);
        m.record_shard_execution(0, 8, 8, 1_000, 1, FlushKind::Full);
        m.record_shard_execution(1, 3, 8, 2_000, 2, FlushKind::Deadline);
        assert_eq!(m.executions.load(Ordering::Relaxed), 2);
        assert_eq!(m.shards()[0].executions.load(Ordering::Relaxed), 1);
        assert_eq!(m.shards()[1].executions.load(Ordering::Relaxed), 1);
        assert_eq!(m.shards()[1].chromosomes.load(Ordering::Relaxed), 3);
        assert_eq!(m.coalesced_executions.load(Ordering::Relaxed), 1);
        assert_eq!(m.coalesced_requests.load(Ordering::Relaxed), 2);
        assert_eq!(m.full_flushes.load(Ordering::Relaxed), 1);
        assert_eq!(m.deadline_flushes.load(Ordering::Relaxed), 1);
        assert_eq!(m.padded_slots.load(Ordering::Relaxed), 5);
        assert!(m.render().contains("shards=["));
    }

    /// The latency/width aggregates are lock-free histograms now: a
    /// panicking recorder thread can never poison them, and concurrent
    /// recorders never lose samples.
    #[test]
    fn histograms_survive_concurrent_and_panicking_recorders() {
        let m = std::sync::Arc::new(Metrics::default());
        let threads: Vec<_> = (0..4)
            .map(|t| {
                let m2 = std::sync::Arc::clone(&m);
                std::thread::spawn(move || {
                    for i in 0..100u64 {
                        m2.record_execution(8, 8, 1_000 + i);
                    }
                    if t == 0 {
                        panic!("a dying recorder must not poison anything");
                    }
                })
            })
            .collect();
        for t in threads {
            let _ = t.join();
        }
        assert_eq!(m.exec_latency_hist().count(), 400);
        assert_eq!(m.batch_width_hist().count(), 400);
        assert!(m.render().contains("execs=400"));
    }

    #[test]
    fn death_counters_and_render_flags() {
        let m = Metrics::with_shards(2);
        m.shard_died(1);
        m.record_stranded(3);
        assert_eq!(m.shard_deaths.load(Ordering::Relaxed), 1);
        assert_eq!(m.stranded_requests.load(Ordering::Relaxed), 3);
        assert!(m.shards()[1].down.load(Ordering::Relaxed));
        let r = m.render();
        assert!(r.contains("1:execs=0,qpeak=0,down"), "{r}");
        assert!(r.contains("deaths=1 stranded=3 respawns=0"), "{r}");
        m.shard_respawned(1);
        assert!(!m.shards()[1].down.load(Ordering::Relaxed));
        assert!(m.render().contains("respawns=1"));
        // Zero strandings are not counted; out-of-range shards ignored.
        m.record_stranded(0);
        assert_eq!(m.stranded_requests.load(Ordering::Relaxed), 3);
        m.shard_died(9);
        assert_eq!(m.shard_deaths.load(Ordering::Relaxed), 2);
    }

    /// The adaptive-coalescing surface: early-flush counting, the
    /// coalescing gauge, and the effective window/EWMA rendered per shard.
    #[test]
    fn adaptive_gauges_and_early_flushes_render() {
        let m = Metrics::with_shards(2);
        m.record_shard_execution(0, 6, 8, 1_000, 3, FlushKind::AllDrivers);
        assert_eq!(m.early_flushes.load(Ordering::Relaxed), 1);
        assert_eq!(m.deadline_flushes.load(Ordering::Relaxed), 0);
        assert!(m.render().contains("early 1"), "{}", m.render());

        m.coalescing_add(0, 5);
        m.coalescing_add(0, 4);
        m.coalescing_sub(0, 6);
        assert_eq!(m.shards()[0].coalescing.load(Ordering::Relaxed), 3);
        // Saturates instead of wrapping; reset zeroes (worker death).
        m.coalescing_sub(0, 100);
        assert_eq!(m.shards()[0].coalescing.load(Ordering::Relaxed), 0);
        m.coalescing_add(0, 2);
        m.coalescing_reset(0);
        assert_eq!(m.shards()[0].coalescing.load(Ordering::Relaxed), 0);

        // No window recorded → no phantom knob in the render.
        assert!(!m.render().contains("win="), "{}", m.render());
        m.set_window(1, 150_000, None);
        let r = m.render();
        assert!(r.contains("1:execs=0,qpeak=0,win="), "{r}");
        assert!(!r.contains("ia="), "no EWMA recorded yet: {r}");
        m.set_window(1, 300_000, Some(140_000));
        let r = m.render();
        assert!(r.contains("win=") && r.contains("ia="), "{r}");
        // Out-of-range shards are ignored, like every other gauge.
        m.set_window(9, 1, Some(1));
        m.coalescing_add(9, 1);
        m.coalescing_sub(9, 1);
        m.coalescing_reset(9);
    }

    /// The two-phase-eval surface: ticket gauges saturate like the other
    /// gauges, render only appears once a ticket exists, per-shard busy
    /// time accumulates, and driver-recorded [`EvalStats`] fold into the
    /// render line.
    #[test]
    fn ticket_gauges_busy_time_and_eval_stats_render() {
        let m = Metrics::with_shards(1);
        assert!(!m.render().contains("tickets="), "{}", m.render());
        m.ticket_submitted(5);
        m.ticket_submitted(7);
        assert_eq!(m.tickets_in_flight.load(Ordering::Relaxed), 2);
        assert_eq!(m.tickets_peak.load(Ordering::Relaxed), 2);
        assert_eq!(m.microbatch_width_hist().count(), 2);
        m.ticket_collected(1_000);
        m.ticket_done();
        assert_eq!(m.tickets_in_flight.load(Ordering::Relaxed), 1);
        assert_eq!(m.ticket_latency_hist().count(), 1);
        assert_eq!(m.ticket_latency_hist().max, 1_000);
        let r = m.render();
        assert!(r.contains("tickets=2 inflight=1 peak=2"), "{r}");
        // Saturates instead of wrapping (abandoned-ticket double count).
        m.ticket_done();
        m.ticket_done();
        assert_eq!(m.tickets_in_flight.load(Ordering::Relaxed), 0);

        assert!(!m.render().contains("eval:"), "{}", m.render());
        m.record_eval_stats(&EvalStats {
            requested: 10,
            cache_hits: 4,
            engine_evals: 6,
            ..EvalStats::default()
        });
        m.record_eval_stats(&EvalStats {
            requested: 10,
            cache_hits: 9,
            engine_evals: 1,
            ..EvalStats::default()
        });
        let r = m.render();
        assert!(r.contains("eval: requested=20 cache_hits=13 engine_evals=7"), "{r}");

        m.record_shard_execution(0, 8, 8, 2_000, 1, FlushKind::Full);
        m.record_shard_execution(0, 4, 8, 3_000, 1, FlushKind::Deadline);
        assert_eq!(m.shards()[0].busy_ns.load(Ordering::Relaxed), 5_000);
    }

    /// The native-engine throughput surface: plane builds and scored
    /// samples render only once recorded (legacy lines unchanged), and
    /// samples/sec divides by summed shard busy time.
    #[test]
    fn plane_and_sample_gauges_render_and_snapshot() {
        let m = Metrics::with_shards(1);
        assert!(!m.render().contains("planes:"), "{}", m.render());
        assert!(!m.render().contains("samples="), "{}", m.render());
        assert!(m.samples_per_sec().is_nan());
        m.record_plane_build(2_000);
        m.record_plane_build(3_000);
        assert_eq!(m.plane_builds.load(Ordering::Relaxed), 2);
        assert_eq!(m.plane_build_ns.load(Ordering::Relaxed), 5_000);
        assert!(m.render().contains("planes: builds=2"), "{}", m.render());

        // 32 chromosomes × 310 samples over 1ms of busy time.
        m.record_shard_execution(0, 32, 32, 1_000_000, 1, FlushKind::Full);
        m.record_eval_samples(32 * 310);
        let sps = m.samples_per_sec();
        assert!((sps - 32.0 * 310.0 * 1e3).abs() < 1e-6, "{sps}");
        assert!(m.render().contains("samples=9920"), "{}", m.render());

        let snap = m.snapshot_json(1).to_string();
        let v = Json::parse(&snap).unwrap();
        assert_eq!(v.get("plane_builds").unwrap().as_f64(), Some(2.0));
        assert_eq!(v.get("plane_build_ns").unwrap().as_f64(), Some(5_000.0));
        assert_eq!(v.get("eval_samples").unwrap().as_f64(), Some(9_920.0));
    }

    /// The tiered-cache surface: counters and lookup latencies render
    /// only once a probe/spill/load-error happened (untiered runs keep
    /// their exact line), and the snapshot carries every tier counter.
    #[test]
    fn cache_gauges_render_and_snapshot() {
        let m = Metrics::default();
        assert!(!m.render().contains("cache:"), "{}", m.render());
        m.cache_l1_hits.fetch_add(3, Ordering::Relaxed);
        m.cache_l2_hits.fetch_add(7, Ordering::Relaxed);
        m.cache_misses.fetch_add(2, Ordering::Relaxed);
        m.record_cache_lookup(800);
        m.record_cache_lookup(1_200);
        let r = m.render();
        assert!(r.contains("cache: l1_hits=3 l2_hits=7 misses=2 spills=0 load_errors=0"), "{r}");
        assert_eq!(m.cache_lookup_hist().count(), 2);
        assert_eq!(m.cache_lookup_hist().max, 1_200);

        // A load error alone (corrupt segment tail, zero probes so far)
        // still surfaces the segment.
        let m2 = Metrics::default();
        m2.cache_load_errors.fetch_add(1, Ordering::Relaxed);
        assert!(m2.render().contains("load_errors=1"), "{}", m2.render());

        let snap = m.snapshot_json(5).to_string();
        let v = Json::parse(&snap).unwrap();
        assert_eq!(v.get("cache_l1_hits").unwrap().as_f64(), Some(3.0));
        assert_eq!(v.get("cache_l2_hits").unwrap().as_f64(), Some(7.0));
        assert_eq!(v.get("cache_misses").unwrap().as_f64(), Some(2.0));
        assert_eq!(v.get("cache_spills").unwrap().as_f64(), Some(0.0));
        assert_eq!(v.get("cache_load_errors").unwrap().as_f64(), Some(0.0));
        let cl = v.get("hist").unwrap().get("cache_lookup_ns").unwrap();
        assert_eq!(cl.get("count").unwrap().as_f64(), Some(2.0));
        assert_eq!(cl.get("max").unwrap().as_f64(), Some(1_200.0));
    }

    #[test]
    fn snapshot_and_histogram_json_parse() {
        let m = Metrics::with_shards(2);
        m.record_shard_execution(0, 8, 8, 2_000, 1, FlushKind::Full);
        m.ticket_submitted(8);
        m.ticket_collected(5_000);
        let snap = m.snapshot_json(1_234).to_string();
        let v = Json::parse(&snap).unwrap();
        assert_eq!(v.get("ts_ns").unwrap().as_f64(), Some(1_234.0));
        assert_eq!(v.get("executions").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("shards").unwrap().as_arr().unwrap().len(), 2);
        let hist = v.get("hist").unwrap();
        let tl = hist.get("ticket_latency_ns").unwrap();
        assert_eq!(tl.get("count").unwrap().as_f64(), Some(1.0));
        assert_eq!(tl.get("max").unwrap().as_f64(), Some(5_000.0));
        for key in ["exec_latency_ns", "batch_width", "microbatch_width"] {
            assert!(hist.get(key).is_some(), "missing {key}");
        }
    }

    /// `Write` sink shared with the test thread.
    struct SharedBuf(std::sync::Arc<std::sync::Mutex<Vec<u8>>>);
    impl Write for SharedBuf {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            self.0.lock().unwrap().extend_from_slice(buf);
            Ok(buf.len())
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    /// The emitter ticks on the injected clock: each ManualClock advance
    /// past the interval produces exactly one JSON line, and stop()
    /// flushes one final snapshot — fully deterministic, zero real-time
    /// waits beyond joining the thread.
    #[test]
    fn snapshot_emitter_ticks_on_manual_clock() {
        use crate::util::clock::ManualClock;
        use std::time::Duration;

        let lines_in = |buf: &std::sync::Arc<std::sync::Mutex<Vec<u8>>>| {
            String::from_utf8(buf.lock().unwrap().clone()).unwrap().lines().count()
        };
        let wait_for_lines = |buf: &std::sync::Arc<std::sync::Mutex<Vec<u8>>>, n: usize| {
            for _ in 0..2_000 {
                if lines_in(buf) >= n {
                    return;
                }
                std::thread::sleep(Duration::from_millis(1));
            }
            panic!("emitter never produced {n} lines");
        };

        let m = Arc::new(Metrics::with_shards(1));
        let clock = Arc::new(ManualClock::new());
        let buf = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
        let emitter = SnapshotEmitter::spawn(
            Arc::clone(&m),
            Arc::clone(&clock) as Arc<dyn Clock>,
            10,
            Box::new(SharedBuf(std::sync::Arc::clone(&buf))),
        );
        m.record_execution(4, 8, 1_000);
        clock.advance(Duration::from_millis(10));
        wait_for_lines(&buf, 1);
        clock.advance(Duration::from_millis(10));
        wait_for_lines(&buf, 2);
        emitter.stop();
        let text = String::from_utf8(buf.lock().unwrap().clone()).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3, "2 ticks + final flush: {text}");
        for line in &lines {
            let v = Json::parse(line).unwrap();
            assert_eq!(v.get("executions").unwrap().as_f64(), Some(1.0));
        }
        // Tick timestamps are the virtual instants of the advances.
        assert_eq!(
            Json::parse(lines[0]).unwrap().get("ts_ns").unwrap().as_f64(),
            Some(10_000_000.0)
        );
        assert_eq!(
            Json::parse(lines[1]).unwrap().get("ts_ns").unwrap().as_f64(),
            Some(20_000_000.0)
        );
    }

    #[test]
    fn queue_gauge_tracks_depth_and_peak() {
        let m = Metrics::with_shards(1);
        m.shard_enqueued(0);
        m.shard_enqueued(0);
        m.shard_dequeued(0);
        assert_eq!(m.shards()[0].queue_depth.load(Ordering::Relaxed), 1);
        assert_eq!(m.shards()[0].queue_peak.load(Ordering::Relaxed), 2);
        // Saturates instead of wrapping when shutdown drops queued jobs.
        m.shard_dequeued(0);
        m.shard_dequeued(0);
        assert_eq!(m.shards()[0].queue_depth.load(Ordering::Relaxed), 0);
        // Out-of-range shard indices are ignored (legacy Metrics::default()).
        Metrics::default().shard_enqueued(3);
    }
}
