//! Sharded evaluation pool: N backend workers + cross-driver coalescing.
//!
//! The seed service ran exactly one worker thread per backend, which made
//! the evaluation service the throughput ceiling of every GA-driven search
//! (ROADMAP: multi-worker sharding, batch coalescing).  This module owns
//! the scaled-up machinery:
//!
//! ```text
//!  GA driver (dataset A) ──┐  route by ProblemId.shard   ┌─ worker 0 (backend 0)
//!  GA driver (dataset B) ──┼──────────────────────────────┤  worker 1 (backend 1)
//!  benches / CLI        ──┘   (FNV-1a(problem) % N)       └─ worker k: Coalescer → execute
//! ```
//!
//! * [`EvalShardPool`] spawns N workers; each constructs its **own**
//!   backend instance inside its thread (the PJRT client is not `Send`,
//!   and per-worker clients are exactly how the pool scales past a single
//!   PJRT client).
//! * Registration hash-routes a problem to a stable shard
//!   (FNV-1a of the problem name, mod N).  The returned [`ProblemId`]
//!   records the shard, pinning every later job to the worker that holds
//!   the problem's device buffers.
//! * Each worker fronts its backend with a **coalescer**: sub-width
//!   batches from concurrent drivers queue per problem and are merged into
//!   one padded execution, flushing when the artifact width P fills or a
//!   small deadline (`coalesce_window_us`) expires.  This converts the
//!   padding waste the metrics record into useful work.  A window of 0
//!   disables merging (legacy per-request dispatch).
//!
//! Clients normally reach this through the [`EvalService`] facade.
//!
//! [`EvalService`]: super::service::EvalService

use std::cell::RefCell;
use std::collections::VecDeque;
use std::rc::Rc;
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::metrics::{FlushKind, Metrics};
use super::service::ServiceError;
use crate::fitness::encode::Bucket;
#[cfg(feature = "xla")]
use crate::fitness::encode::{self, StaticTensors};
use crate::fitness::{native::NativeEngine, AccuracyEngine, Problem};
use crate::hw::synth::TreeApprox;
#[cfg(feature = "xla")]
use crate::runtime::{DeviceStatics, XlaRuntime};
use crate::util::pool;

/// Bounded per-worker queue depth (jobs in flight before senders block).
const QUEUE_DEPTH: usize = 16;

/// What actually evaluates a padded population batch.
///
/// Not `Send`: the PJRT client wraps an `Rc`.  Backends are therefore
/// *constructed inside* each worker thread by the spawn factory.
pub(crate) trait Backend {
    fn register(&mut self, problem: &Arc<Problem>) -> Result<RegisteredProblem>;
    fn eval(
        &mut self,
        reg: &RegisteredProblem,
        problem: &Problem,
        chunk: &[TreeApprox],
    ) -> Result<Vec<f64>>;
    /// Backend id (surfaced in logs / metrics lines).
    #[allow(dead_code)]
    fn name(&self) -> &'static str;
}

/// Backend-side registration state.
pub(crate) enum RegisteredProblem {
    #[cfg(feature = "xla")]
    Xla { statics: DeviceStatics },
    Native { width: usize },
}

impl RegisteredProblem {
    fn bucket(&self) -> Option<&Bucket> {
        match self {
            #[cfg(feature = "xla")]
            RegisteredProblem::Xla { statics } => Some(&statics.bucket),
            RegisteredProblem::Native { .. } => None,
        }
    }

    /// Population width the backend executes at (batch-splitting unit).
    fn width(&self) -> usize {
        match self {
            #[cfg(feature = "xla")]
            RegisteredProblem::Xla { statics } => statics.bucket.p,
            RegisteredProblem::Native { width } => *width,
        }
    }
}

/// PJRT-backed backend (one PJRT client per worker).
#[cfg(feature = "xla")]
struct XlaBackend {
    runtime: XlaRuntime,
}

#[cfg(feature = "xla")]
impl Backend for XlaBackend {
    fn register(&mut self, problem: &Arc<Problem>) -> Result<RegisteredProblem> {
        let (bucket, _) = self
            .runtime
            .meta
            .route(problem)
            .ok_or_else(|| {
                anyhow!(
                    "no bucket fits problem '{}' (n_test={}, n_comp={}, leaves={})",
                    problem.name,
                    problem.n_test,
                    problem.n_comparators(),
                    problem.tree.n_leaves()
                )
            })?
            .clone();
        self.runtime.ensure_compiled(&bucket.name)?;
        let st: StaticTensors = encode::encode_static(problem, &bucket);
        let statics = self.runtime.upload_statics(&st)?;
        Ok(RegisteredProblem::Xla { statics })
    }

    fn eval(
        &mut self,
        reg: &RegisteredProblem,
        problem: &Problem,
        chunk: &[TreeApprox],
    ) -> Result<Vec<f64>> {
        let RegisteredProblem::Xla { statics } = reg else {
            return Err(anyhow!("backend mismatch"));
        };
        let bucket = statics.bucket.clone();
        let (thr, scale) = encode::pack_population(problem, &bucket, chunk);
        let acc = self.runtime.execute(statics, &thr, &scale)?;
        Ok(acc.iter().take(chunk.len()).map(|&a| a as f64).collect())
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

/// Native backend: same pool machinery, tree-walk arithmetic.  Used by
/// unit tests (no artifacts needed) and `--engine native-service`.
struct NativeBackend {
    engine: NativeEngine,
    /// Emulated artifact width, so batching/padding paths are exercised.
    width: usize,
}

impl Backend for NativeBackend {
    fn register(&mut self, _problem: &Arc<Problem>) -> Result<RegisteredProblem> {
        Ok(RegisteredProblem::Native { width: self.width })
    }

    fn eval(
        &mut self,
        _reg: &RegisteredProblem,
        problem: &Problem,
        chunk: &[TreeApprox],
    ) -> Result<Vec<f64>> {
        self.engine.batch_accuracy(problem, chunk)
    }

    fn name(&self) -> &'static str {
        "native-service"
    }
}

/// Problem handle returned by registration.  Carries the issuing pool's
/// token (so an id presented to a *different* pool is rejected even when
/// its index happens to be in range there) and the shard the problem is
/// pinned to.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ProblemId {
    pub(crate) service: u32,
    pub(crate) shard: u32,
    pub(crate) index: u32,
}

impl ProblemId {
    /// The pool shard (worker) this problem is pinned to.
    pub fn shard(&self) -> usize {
        self.shard as usize
    }
}

/// Process-unique pool tokens (0 is never issued, so a forged
/// `ProblemId` default can't match).
static NEXT_POOL_TOKEN: AtomicU32 = AtomicU32::new(1);

/// Sizing/behavior knobs for an [`EvalShardPool`] (CLI: `--workers`,
/// `--coalesce-window-us`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PoolOptions {
    /// Worker (shard) count.  0 = auto: one per core for the native
    /// backend, one per device (currently 1, the CPU PJRT client) for XLA.
    /// Clamped to [1, 64].
    pub workers: usize,
    /// Coalescing window in microseconds: how long a sub-width batch may
    /// wait for concurrent drivers' work before a padded flush.  0 turns
    /// coalescing off (every request dispatches immediately).
    pub coalesce_window_us: u64,
    /// Native-engine threads per worker.  0 = auto (total thread budget /
    /// workers), so `workers=1` keeps the seed service's full batch-level
    /// parallelism.  Ignored by the XLA backend.
    pub engine_threads: usize,
}

impl Default for PoolOptions {
    fn default() -> Self {
        PoolOptions { workers: 0, coalesce_window_us: 200, engine_threads: 0 }
    }
}

impl PoolOptions {
    /// Resolved worker count for the native backend.
    pub fn native_workers(&self) -> usize {
        if self.workers == 0 {
            pool::default_threads()
        } else {
            self.workers.clamp(1, 64)
        }
    }

    /// Resolved worker count for the XLA backend (1 per device; the CPU
    /// PJRT client exposes one).
    pub fn xla_workers(&self) -> usize {
        if self.workers == 0 {
            1
        } else {
            self.workers.clamp(1, 64)
        }
    }
}

enum Msg {
    Register {
        problem: Arc<Problem>,
        reply: mpsc::SyncSender<Result<(ProblemId, Option<Bucket>), ServiceError>>,
    },
    Eval {
        id: ProblemId,
        batch: Vec<TreeApprox>,
        reply: mpsc::SyncSender<Result<Vec<f64>, ServiceError>>,
    },
    Shutdown,
}

/// Client handle to a pool of shard workers (cheap to clone; dropping all
/// clones shuts the workers down after they drain pending work).
#[derive(Clone)]
pub struct EvalShardPool {
    token: u32,
    txs: Vec<mpsc::SyncSender<Msg>>,
    pub metrics: Arc<Metrics>,
}

impl EvalShardPool {
    /// Spawn a native-backed pool (tests / no-artifact runs).  `width`
    /// emulates the artifact population width for batching.
    pub fn spawn_native(width: usize, opts: &PoolOptions) -> EvalShardPool {
        let workers = opts.native_workers();
        let engine_threads = if opts.engine_threads == 0 {
            (pool::default_threads() / workers).max(1)
        } else {
            opts.engine_threads
        };
        Self::spawn(workers, opts.coalesce_window_us, move |_shard| {
            Ok(Box::new(NativeBackend {
                engine: NativeEngine::with_threads(engine_threads),
                width,
            }) as Box<dyn Backend>)
        })
        .expect("native backend construction cannot fail")
    }

    /// Spawn a PJRT-backed pool (artifacts required); each worker builds
    /// its own `XlaRuntime`/client, which is what lets the pool scale past
    /// a single PJRT client.
    #[cfg(feature = "xla")]
    pub fn spawn_xla(
        artifact_dir: impl AsRef<std::path::Path>,
        opts: &PoolOptions,
    ) -> Result<EvalShardPool> {
        let dir = artifact_dir.as_ref().to_path_buf();
        Self::spawn(opts.xla_workers(), opts.coalesce_window_us, move |_shard| {
            Ok(Box::new(XlaBackend { runtime: XlaRuntime::new(dir.clone())? })
                as Box<dyn Backend>)
        })
    }

    fn spawn(
        workers: usize,
        window_us: u64,
        factory: impl Fn(usize) -> Result<Box<dyn Backend>> + Send + Sync + 'static,
    ) -> Result<EvalShardPool> {
        let workers = workers.max(1);
        let window = (window_us > 0).then_some(Duration::from_micros(window_us));
        let metrics = Arc::new(Metrics::with_shards(workers));
        let token = NEXT_POOL_TOKEN.fetch_add(1, Ordering::Relaxed);
        let factory: Arc<dyn Fn(usize) -> Result<Box<dyn Backend>> + Send + Sync> =
            Arc::new(factory);
        let mut txs = Vec::with_capacity(workers);
        let mut inits = Vec::with_capacity(workers);
        for shard in 0..workers {
            let (tx, rx) = mpsc::sync_channel::<Msg>(QUEUE_DEPTH);
            let (init_tx, init_rx) = mpsc::sync_channel::<Result<()>>(1);
            let f = Arc::clone(&factory);
            let m = Arc::clone(&metrics);
            std::thread::Builder::new()
                .name(format!("axdt-eval-shard-{shard}"))
                .spawn(move || {
                    let backend = match f(shard) {
                        Ok(b) => {
                            let _ = init_tx.send(Ok(()));
                            b
                        }
                        Err(e) => {
                            let _ = init_tx.send(Err(e));
                            return;
                        }
                    };
                    worker_loop(backend, rx, token, shard as u32, window, m);
                })
                .expect("spawn eval shard worker");
            txs.push(tx);
            inits.push(init_rx);
        }
        for init_rx in inits {
            init_rx
                .recv()
                .map_err(|_| anyhow!("eval shard worker died during init"))??;
        }
        Ok(EvalShardPool { token, txs, metrics })
    }

    /// Number of shard workers.
    pub fn workers(&self) -> usize {
        self.txs.len()
    }

    /// Stable shard for a problem name: FNV-1a mod worker count.  Stable
    /// within a pool by construction (the hash is pinned, not
    /// `DefaultHasher`), so re-registration lands on the worker that
    /// already holds the problem's device buffers.
    pub fn shard_for(&self, name: &str) -> usize {
        (fnv1a(name.as_bytes()) % self.txs.len() as u64) as usize
    }

    /// Register a problem on its shard: routes it to a bucket and uploads
    /// statics on the owning worker.
    pub fn register(
        &self,
        problem: Arc<Problem>,
    ) -> Result<(ProblemId, Option<Bucket>), ServiceError> {
        let shard = self.shard_for(&problem.name);
        let (reply_tx, reply_rx) = mpsc::sync_channel(1);
        self.txs[shard]
            .send(Msg::Register { problem, reply: reply_tx })
            .map_err(|_| ServiceError::ServiceDown)?;
        reply_rx.recv().map_err(|_| ServiceError::ReplyDropped)?
    }

    /// Evaluate a batch (blocking until the owning shard replies).
    pub fn eval(
        &self,
        id: ProblemId,
        batch: Vec<TreeApprox>,
    ) -> Result<Vec<f64>, ServiceError> {
        if batch.is_empty() {
            return Ok(Vec::new());
        }
        if id.service != self.token {
            return Err(ServiceError::ForeignProblemId {
                id,
                registered: self.metrics.problems.load(Ordering::Relaxed) as usize,
            });
        }
        // Ids we issued are in range; clamp defensively for forged ones.
        let shard = (id.shard as usize).min(self.txs.len() - 1);
        let (reply_tx, reply_rx) = mpsc::sync_channel(1);
        self.metrics.shard_enqueued(shard);
        if self.txs[shard].send(Msg::Eval { id, batch, reply: reply_tx }).is_err() {
            self.metrics.shard_dequeued(shard);
            return Err(ServiceError::ServiceDown);
        }
        reply_rx.recv().map_err(|_| ServiceError::ReplyDropped)?
    }

    /// Ask every worker to drain pending work and exit (idempotent;
    /// dropping all handles also works).
    pub fn shutdown(&self) {
        for tx in &self.txs {
            let _ = tx.send(Msg::Shutdown);
        }
    }
}

/// FNV-1a, pinned (routing must never change across Rust releases).
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0100_0000_01b3);
    }
    h
}

// ---- worker side (coalescer) ----------------------------------------------

/// One client eval request being assembled across >= 1 executions.
struct RequestState {
    reply: mpsc::SyncSender<Result<Vec<f64>, ServiceError>>,
    results: Vec<f64>,
    remaining: usize,
}

/// A request's chromosomes queued on its problem (consumed from `next`).
struct QueuedSlice {
    req: Rc<RefCell<RequestState>>,
    items: Vec<TreeApprox>,
    next: usize,
}

/// Per-problem coalescer state: FIFO of queued slices plus the armed
/// flush deadline (set when the oldest pending sub-width work arrived).
#[derive(Default)]
struct ProblemQueue {
    queue: VecDeque<QueuedSlice>,
    pending: usize,
    deadline: Option<Instant>,
}

fn worker_loop(
    mut backend: Box<dyn Backend>,
    rx: mpsc::Receiver<Msg>,
    token: u32,
    shard: u32,
    window: Option<Duration>,
    metrics: Arc<Metrics>,
) {
    let mut problems: Vec<(Arc<Problem>, RegisteredProblem)> = Vec::new();
    let mut queues: Vec<ProblemQueue> = Vec::new();
    loop {
        // Wait for work, bounded by the earliest armed coalescer deadline.
        let next_deadline = queues.iter().filter_map(|q| q.deadline).min();
        let msg = match next_deadline {
            // Invariant: no deadline => nothing pending, so a disconnect
            // here cannot strand queued work.
            None => match rx.recv() {
                Ok(m) => m,
                Err(_) => return,
            },
            Some(deadline) => {
                let now = Instant::now();
                if deadline <= now {
                    flush_expired(backend.as_mut(), &problems, &mut queues, shard, &metrics);
                    continue;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(m) => m,
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        flush_expired(backend.as_mut(), &problems, &mut queues, shard, &metrics);
                        continue;
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        flush_all(backend.as_mut(), &problems, &mut queues, shard, &metrics);
                        return;
                    }
                }
            }
        };
        match msg {
            Msg::Shutdown => {
                // In-flight jobs still get their replies: drain the
                // coalescer before exiting.
                flush_all(backend.as_mut(), &problems, &mut queues, shard, &metrics);
                return;
            }
            Msg::Register { problem, reply } => {
                let res = match backend.register(&problem) {
                    Ok(reg) => {
                        let id = ProblemId {
                            service: token,
                            shard,
                            index: problems.len() as u32,
                        };
                        let bucket = reg.bucket().cloned();
                        problems.push((problem, reg));
                        queues.push(ProblemQueue::default());
                        metrics.problems.fetch_add(1, Ordering::Relaxed);
                        Ok((id, bucket))
                    }
                    Err(e) => Err(ServiceError::Backend { detail: format!("{e:#}") }),
                };
                let _ = reply.send(res);
            }
            Msg::Eval { id, batch, reply } => {
                metrics.shard_dequeued(shard as usize);
                let idx = id.index as usize;
                // A stale or foreign id must not kill the worker thread
                // (which would wedge every other client) NOR silently
                // evaluate against the wrong problem.
                if id.service != token || id.shard != shard || idx >= problems.len() {
                    let _ = reply.send(Err(ServiceError::UnknownProblemId {
                        id,
                        registered: problems.len(),
                    }));
                    continue;
                }
                if batch.is_empty() {
                    let _ = reply.send(Ok(Vec::new()));
                    continue;
                }
                let n = batch.len();
                let req = Rc::new(RefCell::new(RequestState {
                    reply,
                    results: Vec::with_capacity(n),
                    remaining: n,
                }));
                queues[idx].pending += n;
                queues[idx].queue.push_back(QueuedSlice { req, items: batch, next: 0 });
                let width = problems[idx].1.width().max(1);
                while queues[idx].pending >= width {
                    execute_chunk(
                        backend.as_mut(),
                        &problems[idx],
                        &mut queues[idx],
                        width,
                        FlushKind::Full,
                        shard,
                        &metrics,
                    );
                }
                match window {
                    None => {
                        // Coalescing off: dispatch the tail immediately.
                        let take = queues[idx].pending;
                        if take > 0 {
                            execute_chunk(
                                backend.as_mut(),
                                &problems[idx],
                                &mut queues[idx],
                                take,
                                FlushKind::Immediate,
                                shard,
                                &metrics,
                            );
                        }
                    }
                    Some(w) => {
                        if queues[idx].pending > 0 && queues[idx].deadline.is_none() {
                            queues[idx].deadline = Some(Instant::now() + w);
                        }
                    }
                }
            }
        }
    }
}

fn flush_expired(
    backend: &mut dyn Backend,
    problems: &[(Arc<Problem>, RegisteredProblem)],
    queues: &mut [ProblemQueue],
    shard: u32,
    metrics: &Metrics,
) {
    let now = Instant::now();
    for idx in 0..queues.len() {
        if queues[idx].deadline.is_some_and(|d| d <= now) {
            let take = queues[idx].pending;
            execute_chunk(
                backend,
                &problems[idx],
                &mut queues[idx],
                take,
                FlushKind::Deadline,
                shard,
                metrics,
            );
        }
    }
}

fn flush_all(
    backend: &mut dyn Backend,
    problems: &[(Arc<Problem>, RegisteredProblem)],
    queues: &mut [ProblemQueue],
    shard: u32,
    metrics: &Metrics,
) {
    for idx in 0..queues.len() {
        while queues[idx].pending > 0 {
            let take = queues[idx].pending;
            execute_chunk(
                backend,
                &problems[idx],
                &mut queues[idx],
                take,
                FlushKind::Drain,
                shard,
                metrics,
            );
        }
    }
}

/// Pop up to `take` queued chromosomes for one problem, execute them as a
/// single backend batch, and distribute results (or the failure) to every
/// contributing request.
fn execute_chunk(
    backend: &mut dyn Backend,
    problem_entry: &(Arc<Problem>, RegisteredProblem),
    pq: &mut ProblemQueue,
    take: usize,
    kind: FlushKind,
    shard: u32,
    metrics: &Metrics,
) {
    let (problem, reg) = problem_entry;
    let width = reg.width().max(1);
    // Never hand the backend more than one artifact width at once, even if
    // an invariant slips (callers keep pending < width between flushes).
    let take = take.min(pq.pending).min(width);
    if take == 0 {
        pq.deadline = None;
        return;
    }
    let mut chunk: Vec<TreeApprox> = Vec::with_capacity(take);
    let mut contributors: Vec<(Rc<RefCell<RequestState>>, usize)> = Vec::new();
    while chunk.len() < take {
        let front = pq.queue.front_mut().expect("pending count matches queued items");
        let n = (take - chunk.len()).min(front.items.len() - front.next);
        chunk.extend_from_slice(&front.items[front.next..front.next + n]);
        front.next += n;
        contributors.push((Rc::clone(&front.req), n));
        if front.next == front.items.len() {
            pq.queue.pop_front();
        }
    }
    pq.pending -= take;
    if pq.pending == 0 {
        pq.deadline = None;
    }
    let t0 = Instant::now();
    let res = backend.eval(reg, problem.as_ref(), &chunk).and_then(|accs| {
        // A short result must fail the requests, not panic the worker
        // (which would wedge every client of this shard).
        if accs.len() == chunk.len() {
            Ok(accs)
        } else {
            Err(anyhow!(
                "backend returned {} accuracies for a chunk of {}",
                accs.len(),
                chunk.len()
            ))
        }
    });
    match res {
        Ok(accs) => {
            metrics.record_shard_execution(
                shard as usize,
                chunk.len(),
                width.max(chunk.len()),
                t0.elapsed().as_nanos() as u64,
                contributors.len(),
                kind,
            );
            let mut off = 0usize;
            for (req, n) in contributors {
                let mut r = req.borrow_mut();
                r.results.extend_from_slice(&accs[off..off + n]);
                off += n;
                r.remaining -= n;
                if r.remaining == 0 {
                    let results = std::mem::take(&mut r.results);
                    let _ = r.reply.send(Ok(results));
                }
            }
        }
        Err(e) => {
            // Every contributor's fitness is poisoned: fail them all and
            // purge their queued tails so they are not executed (and
            // double-replied) later.  Other requests keep their place.
            let err = ServiceError::Backend { detail: format!("{e:#}") };
            let dead: Vec<*const RefCell<RequestState>> =
                contributors.iter().map(|(r, _)| Rc::as_ptr(r)).collect();
            for (req, _) in &contributors {
                let mut r = req.borrow_mut();
                r.remaining = 0;
                let _ = r.reply.send(Err(err.clone()));
            }
            let mut purged = 0usize;
            let kept: VecDeque<QueuedSlice> = pq
                .queue
                .drain(..)
                .filter(|s| {
                    if dead.contains(&Rc::as_ptr(&s.req)) {
                        purged += s.items.len() - s.next;
                        false
                    } else {
                        true
                    }
                })
                .collect();
            pq.queue = kept;
            pq.pending -= purged;
            if pq.pending == 0 {
                pq.deadline = None;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fitness::testutil::small_problem;
    use crate::hw::{AreaLut, EgtLibrary};
    use std::sync::atomic::AtomicBool;
    use std::sync::Mutex;

    /// Fake backend recording every executed chunk width.
    struct CountingBackend {
        width: usize,
        chunks: Arc<Mutex<Vec<usize>>>,
    }

    impl Backend for CountingBackend {
        fn register(&mut self, _p: &Arc<Problem>) -> Result<RegisteredProblem> {
            Ok(RegisteredProblem::Native { width: self.width })
        }
        fn eval(
            &mut self,
            _reg: &RegisteredProblem,
            _p: &Problem,
            chunk: &[TreeApprox],
        ) -> Result<Vec<f64>> {
            self.chunks.lock().unwrap().push(chunk.len());
            Ok(vec![0.25; chunk.len()])
        }
        fn name(&self) -> &'static str {
            "counting"
        }
    }

    fn seeds() -> Arc<Problem> {
        Arc::new(small_problem(&AreaLut::build(&EgtLibrary::default())))
    }

    #[test]
    fn fnv_route_is_pinned() {
        // The empty-input value is the FNV offset basis; routing stability
        // across releases is a hard requirement (device-buffer pinning).
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"seeds"), fnv1a(b"seeds"));
        assert_ne!(fnv1a(b"seeds"), fnv1a(b"cardio"));
    }

    #[test]
    fn uncoalesced_chunking_matches_legacy_split() {
        let chunks = Arc::new(Mutex::new(Vec::new()));
        let c = Arc::clone(&chunks);
        let pool = EvalShardPool::spawn(1, 0, move |_| {
            Ok(Box::new(CountingBackend { width: 8, chunks: Arc::clone(&c) })
                as Box<dyn Backend>)
        })
        .unwrap();
        let p = seeds();
        let (id, bucket) = pool.register(Arc::clone(&p)).unwrap();
        assert!(bucket.is_none());
        let batch = vec![TreeApprox::exact(&p.tree); 21];
        let got = pool.eval(id, batch).unwrap();
        assert_eq!(got, vec![0.25; 21]);
        // 21 at width 8: two full chunks + the immediate tail, like the
        // seed service.
        assert_eq!(*chunks.lock().unwrap(), vec![8, 8, 5]);
        assert_eq!(pool.metrics.full_flushes.load(Ordering::Relaxed), 2);
        assert_eq!(pool.metrics.deadline_flushes.load(Ordering::Relaxed), 0);
        pool.shutdown();
    }

    #[test]
    fn backend_error_fails_request_and_worker_survives() {
        struct FlakyBackend {
            width: usize,
            fail: Arc<AtomicBool>,
        }
        impl Backend for FlakyBackend {
            fn register(&mut self, _p: &Arc<Problem>) -> Result<RegisteredProblem> {
                Ok(RegisteredProblem::Native { width: self.width })
            }
            fn eval(
                &mut self,
                _reg: &RegisteredProblem,
                _p: &Problem,
                chunk: &[TreeApprox],
            ) -> Result<Vec<f64>> {
                if self.fail.load(Ordering::Relaxed) {
                    Err(anyhow!("injected backend failure"))
                } else {
                    Ok(vec![0.5; chunk.len()])
                }
            }
            fn name(&self) -> &'static str {
                "flaky"
            }
        }

        let fail = Arc::new(AtomicBool::new(true));
        let f = Arc::clone(&fail);
        let pool = EvalShardPool::spawn(1, 0, move |_| {
            Ok(Box::new(FlakyBackend { width: 8, fail: Arc::clone(&f) })
                as Box<dyn Backend>)
        })
        .unwrap();
        let p = seeds();
        let (id, _) = pool.register(Arc::clone(&p)).unwrap();
        let batch = vec![TreeApprox::exact(&p.tree); 3];
        let err = pool.eval(id, batch.clone()).unwrap_err();
        assert!(format!("{err}").contains("injected backend failure"), "{err}");
        // The worker survives and serves the next request.
        fail.store(false, Ordering::Relaxed);
        assert_eq!(pool.eval(id, batch).unwrap(), vec![0.5; 3]);
        pool.shutdown();
    }

    #[test]
    fn pool_options_resolve_worker_counts() {
        let auto = PoolOptions::default();
        assert!(auto.native_workers() >= 1);
        assert_eq!(auto.xla_workers(), 1);
        let fixed = PoolOptions { workers: 4, ..PoolOptions::default() };
        assert_eq!(fixed.native_workers(), 4);
        assert_eq!(fixed.xla_workers(), 4);
        let huge = PoolOptions { workers: 1000, ..PoolOptions::default() };
        assert_eq!(huge.native_workers(), 64);
    }
}
