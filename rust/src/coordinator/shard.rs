//! Sharded evaluation pool: N backend workers + cross-driver coalescing +
//! shard failover.
//!
//! The seed service ran exactly one worker thread per backend, which made
//! the evaluation service the throughput ceiling of every GA-driven search
//! (ROADMAP: multi-worker sharding, batch coalescing).  This module owns
//! the scaled-up machinery:
//!
//! ```text
//!  GA driver (dataset A) ──┐  route by ProblemId.shard   ┌─ worker 0 (backend 0)
//!  GA driver (dataset B) ──┼──────────────────────────────┤  worker 1 (backend 1)
//!  benches / CLI        ──┘   (FNV-1a(problem) % N)       └─ worker k: Coalescer → execute
//! ```
//!
//! * [`EvalShardPool`] spawns N workers; each constructs its **own**
//!   backend instance inside its thread (the PJRT client is not `Send`,
//!   and per-worker clients are exactly how the pool scales past a single
//!   PJRT client).
//! * Registration hash-routes a problem to a stable shard
//!   (FNV-1a of the problem name, mod N).  The returned [`ProblemId`]
//!   records the shard, pinning every later job to the worker that holds
//!   the problem's device buffers.
//! * Each worker fronts its backend with a **coalescer**: sub-width
//!   batches from concurrent drivers queue per problem and are merged into
//!   one padded execution, flushing when the artifact width P fills or a
//!   small deadline (`coalesce_window_us`) expires.  This converts the
//!   padding waste the metrics record into useful work.  A window of 0
//!   disables merging (legacy per-request dispatch).
//!
//! # Failover
//!
//! A backend panic must not strand a long multi-dataset run (the search
//! spaces take thousands of evaluations per dataset).  Worker loops
//! therefore treat a panicking backend as a *shard death*, not a process
//! problem:
//!
//! * every backend call runs under `catch_unwind`; on panic the worker
//!   marks its shard dead, answers every in-flight, coalescing, and queued
//!   request with a typed [`ServiceError::ShardDown`] (never a silently
//!   dropped reply channel), zeroes its queue-depth gauge, and exits;
//! * [`EvalShardPool::register`] re-routes problems whose home shard is
//!   dead to the rendezvous-best **live** shard (scored by a pinned FNV-1a
//!   of name+shard, so survivors' routes never move);
//! * clients heal transparently: `ShardDown` is a stale-id error, so the
//!   [`XlaEngine`] re-register-and-retry path lands the problem on a live
//!   shard and repeats the failed batch — a run loses at most the
//!   in-flight generation, never a dataset;
//! * with [`PoolOptions::respawn`] (CLI `--respawn-shards`) the dying
//!   worker spawns ONE replacement from the retained backend factory;
//!   after a second death the shard stays permanently dead.
//!
//! Clients normally reach this through the [`EvalService`] facade.
//!
//! [`EvalService`]: super::service::EvalService
//! [`XlaEngine`]: super::service::XlaEngine

use std::cell::RefCell;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::rc::Rc;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU8, Ordering};
use std::sync::{mpsc, Arc, Mutex, Weak};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::metrics::{lock_recover, FlushKind, Metrics};
use super::service::ServiceError;
use crate::fitness::encode::Bucket;
#[cfg(feature = "xla")]
use crate::fitness::encode::{self, StaticTensors};
use crate::fitness::{native::NativeEngine, AccuracyEngine, Problem};
use crate::hw::synth::TreeApprox;
#[cfg(feature = "xla")]
use crate::runtime::{DeviceStatics, XlaRuntime};
use crate::util::pool;

/// Bounded per-worker queue depth (jobs in flight before senders block).
const QUEUE_DEPTH: usize = 16;

/// What actually evaluates a padded population batch.
///
/// Not `Send`: the PJRT client wraps an `Rc`.  Backends are therefore
/// *constructed inside* each worker thread by the spawn factory.
pub(crate) trait Backend {
    fn register(&mut self, problem: &Arc<Problem>) -> Result<RegisteredProblem>;
    fn eval(
        &mut self,
        reg: &RegisteredProblem,
        problem: &Problem,
        chunk: &[TreeApprox],
    ) -> Result<Vec<f64>>;
    /// Backend id (surfaced in logs / metrics lines).
    #[allow(dead_code)]
    fn name(&self) -> &'static str;
}

/// Backend-side registration state.
pub(crate) enum RegisteredProblem {
    #[cfg(feature = "xla")]
    Xla { statics: DeviceStatics },
    Native { width: usize },
}

impl RegisteredProblem {
    fn bucket(&self) -> Option<&Bucket> {
        match self {
            #[cfg(feature = "xla")]
            RegisteredProblem::Xla { statics } => Some(&statics.bucket),
            RegisteredProblem::Native { .. } => None,
        }
    }

    /// Population width the backend executes at (batch-splitting unit).
    fn width(&self) -> usize {
        match self {
            #[cfg(feature = "xla")]
            RegisteredProblem::Xla { statics } => statics.bucket.p,
            RegisteredProblem::Native { width } => *width,
        }
    }
}

/// PJRT-backed backend (one PJRT client per worker).
#[cfg(feature = "xla")]
struct XlaBackend {
    runtime: XlaRuntime,
}

#[cfg(feature = "xla")]
impl Backend for XlaBackend {
    fn register(&mut self, problem: &Arc<Problem>) -> Result<RegisteredProblem> {
        let (bucket, _) = self
            .runtime
            .meta
            .route(problem)
            .ok_or_else(|| {
                anyhow!(
                    "no bucket fits problem '{}' (n_test={}, n_comp={}, leaves={})",
                    problem.name,
                    problem.n_test,
                    problem.n_comparators(),
                    problem.tree.n_leaves()
                )
            })?
            .clone();
        self.runtime.ensure_compiled(&bucket.name)?;
        let st: StaticTensors = encode::encode_static(problem, &bucket);
        let statics = self.runtime.upload_statics(&st)?;
        Ok(RegisteredProblem::Xla { statics })
    }

    fn eval(
        &mut self,
        reg: &RegisteredProblem,
        problem: &Problem,
        chunk: &[TreeApprox],
    ) -> Result<Vec<f64>> {
        let RegisteredProblem::Xla { statics } = reg else {
            return Err(anyhow!("backend mismatch"));
        };
        let bucket = statics.bucket.clone();
        let (thr, scale) = encode::pack_population(problem, &bucket, chunk);
        let acc = self.runtime.execute(statics, &thr, &scale)?;
        Ok(acc.iter().take(chunk.len()).map(|&a| a as f64).collect())
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

/// Native backend: same pool machinery, tree-walk arithmetic.  Used by
/// unit tests (no artifacts needed) and `--engine native-service`.
struct NativeBackend {
    engine: NativeEngine,
    /// Emulated artifact width, so batching/padding paths are exercised.
    width: usize,
}

impl Backend for NativeBackend {
    fn register(&mut self, _problem: &Arc<Problem>) -> Result<RegisteredProblem> {
        Ok(RegisteredProblem::Native { width: self.width })
    }

    fn eval(
        &mut self,
        _reg: &RegisteredProblem,
        problem: &Problem,
        chunk: &[TreeApprox],
    ) -> Result<Vec<f64>> {
        self.engine.batch_accuracy(problem, chunk)
    }

    fn name(&self) -> &'static str {
        "native-service"
    }
}

/// Problem handle returned by registration.  Carries the issuing pool's
/// token (so an id presented to a *different* pool is rejected even when
/// its index happens to be in range there) and the shard the problem is
/// pinned to.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ProblemId {
    pub(crate) service: u32,
    pub(crate) shard: u32,
    pub(crate) index: u32,
}

impl ProblemId {
    /// The pool shard (worker) this problem is pinned to.
    pub fn shard(&self) -> usize {
        self.shard as usize
    }
}

/// Process-unique pool tokens (0 is never issued, so a forged
/// `ProblemId` default can't match).
static NEXT_POOL_TOKEN: AtomicU32 = AtomicU32::new(1);

/// Sizing/behavior knobs for an [`EvalShardPool`] (CLI: `--workers`,
/// `--coalesce-window-us`, `--respawn-shards`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PoolOptions {
    /// Worker (shard) count.  0 = auto: one per core for the native
    /// backend, one per device (currently 1, the CPU PJRT client) for XLA.
    /// Clamped to [1, 64].
    pub workers: usize,
    /// Coalescing window in microseconds: how long a sub-width batch may
    /// wait for concurrent drivers' work before a padded flush.  0 turns
    /// coalescing off (every request dispatches immediately).
    pub coalesce_window_us: u64,
    /// Native-engine threads per worker.  0 = auto (total thread budget /
    /// workers), so `workers=1` keeps the seed service's full batch-level
    /// parallelism.  Ignored by the XLA backend.
    pub engine_threads: usize,
    /// Respawn a dead shard's worker once from the retained backend
    /// factory (CLI `--respawn-shards`); after a second death the shard is
    /// permanently dead.  Off by default: a panicking backend usually
    /// deserves a postmortem before it is restarted.
    pub respawn: bool,
}

impl Default for PoolOptions {
    fn default() -> Self {
        PoolOptions {
            workers: 0,
            coalesce_window_us: 200,
            engine_threads: 0,
            respawn: false,
        }
    }
}

impl PoolOptions {
    /// Resolved worker count for the native backend, clamped to [1, 64]
    /// on BOTH the auto and the explicit path (the documented contract;
    /// `default_threads` also clamps today, but this method must not lean
    /// on that).
    pub fn native_workers(&self) -> usize {
        let w = if self.workers == 0 { pool::default_threads() } else { self.workers };
        w.clamp(1, 64)
    }

    /// Resolved worker count for the XLA backend (1 per device; the CPU
    /// PJRT client exposes one), clamped to [1, 64].
    pub fn xla_workers(&self) -> usize {
        let w = if self.workers == 0 { 1 } else { self.workers };
        w.clamp(1, 64)
    }
}

enum Msg {
    Register {
        problem: Arc<Problem>,
        reply: mpsc::SyncSender<Result<(ProblemId, Option<Bucket>), ServiceError>>,
    },
    Eval {
        id: ProblemId,
        batch: Vec<TreeApprox>,
        reply: mpsc::SyncSender<Result<Vec<f64>, ServiceError>>,
    },
    Shutdown,
}

const SHARD_ALIVE: u8 = 0;
const SHARD_DEAD: u8 = 1;

/// Client-visible state of one shard: the current sender to its worker
/// (swapped by a respawn) and a liveness flag the dying worker flips
/// BEFORE it answers anyone with `ShardDown`, so routing decisions made
/// after an error see the death.
struct ShardSlot {
    tx: Mutex<mpsc::SyncSender<Msg>>,
    state: AtomicU8,
    /// Latched forever by the first death (survives a respawn flipping
    /// `state` back to alive).  Reply-channel failures on a shard that
    /// has EVER died map to the healable `ShardDown` — an instantaneous
    /// liveness read can miss a death that a completed respawn already
    /// papered over — while shards with no death history keep reporting
    /// the genuine-bug `ReplyDropped`.
    died_once: AtomicBool,
    /// Latched by the first death; a shard is respawned at most once.
    respawn_attempted: AtomicBool,
    /// Total problems ever registered on this shard, across worker
    /// incarnations.  A respawned worker starts issuing `ProblemId`
    /// indices from here, so an id issued before the death can never
    /// alias a post-respawn registration (it must fail `UnknownProblemId`
    /// and heal, not silently evaluate against the wrong problem).
    issued: AtomicU32,
}

impl ShardSlot {
    fn is_alive(&self) -> bool {
        self.state.load(Ordering::Acquire) == SHARD_ALIVE
    }

    fn ever_died(&self) -> bool {
        self.died_once.load(Ordering::Acquire)
    }

    /// Typed error for a reply channel that died without an answer.
    /// Shards with any death history map to the healable `ShardDown` (an
    /// instantaneous liveness read can miss a death that a completed
    /// respawn already papered over); shards that never died report the
    /// genuine-bug `ReplyDropped`.  Shared by `register` and `eval` so
    /// their error typing cannot diverge.
    fn reply_dropped_error(&self, shard: usize) -> ServiceError {
        if self.is_alive() && !self.ever_died() {
            ServiceError::ReplyDropped
        } else {
            ServiceError::ShardDown { shard }
        }
    }

    /// Clone the current sender (never hold the slot lock across a
    /// blocking channel send).
    fn sender(&self) -> mpsc::SyncSender<Msg> {
        lock_recover(&self.tx).clone()
    }
}

/// State shared by every pool handle AND (weakly) by the workers: the
/// slots, and the backend factory retained for respawns.
struct PoolShared {
    token: u32,
    window: Option<Duration>,
    respawn: bool,
    metrics: Arc<Metrics>,
    factory: Box<dyn Fn(usize) -> Result<Box<dyn Backend>> + Send + Sync>,
    slots: Vec<ShardSlot>,
}

/// Client handle to a pool of shard workers (cheap to clone; dropping all
/// clones shuts the workers down after they drain pending work — workers
/// only hold the shared state weakly, so they cannot keep their own
/// senders alive).
#[derive(Clone)]
pub struct EvalShardPool {
    token: u32,
    shared: Arc<PoolShared>,
    pub metrics: Arc<Metrics>,
}

impl EvalShardPool {
    /// Spawn a native-backed pool (tests / no-artifact runs).  `width`
    /// emulates the artifact population width for batching.
    pub fn spawn_native(width: usize, opts: &PoolOptions) -> EvalShardPool {
        let workers = opts.native_workers();
        let engine_threads = if opts.engine_threads == 0 {
            (pool::default_threads() / workers).max(1)
        } else {
            opts.engine_threads
        };
        Self::spawn(workers, opts.coalesce_window_us, opts.respawn, move |_shard| {
            Ok(Box::new(NativeBackend {
                engine: NativeEngine::with_threads(engine_threads),
                width,
            }) as Box<dyn Backend>)
        })
        .expect("native backend construction cannot fail")
    }

    /// Spawn a PJRT-backed pool (artifacts required); each worker builds
    /// its own `XlaRuntime`/client, which is what lets the pool scale past
    /// a single PJRT client.
    #[cfg(feature = "xla")]
    pub fn spawn_xla(
        artifact_dir: impl AsRef<std::path::Path>,
        opts: &PoolOptions,
    ) -> Result<EvalShardPool> {
        let dir = artifact_dir.as_ref().to_path_buf();
        Self::spawn(opts.xla_workers(), opts.coalesce_window_us, opts.respawn, move |_shard| {
            Ok(Box::new(XlaBackend { runtime: XlaRuntime::new(dir.clone())? })
                as Box<dyn Backend>)
        })
    }

    pub(crate) fn spawn(
        workers: usize,
        window_us: u64,
        respawn: bool,
        factory: impl Fn(usize) -> Result<Box<dyn Backend>> + Send + Sync + 'static,
    ) -> Result<EvalShardPool> {
        let workers = workers.max(1);
        let window = (window_us > 0).then_some(Duration::from_micros(window_us));
        let metrics = Arc::new(Metrics::with_shards(workers));
        let token = NEXT_POOL_TOKEN.fetch_add(1, Ordering::Relaxed);
        let mut slots = Vec::with_capacity(workers);
        let mut rxs = Vec::with_capacity(workers);
        for _ in 0..workers {
            let (tx, rx) = mpsc::sync_channel::<Msg>(QUEUE_DEPTH);
            slots.push(ShardSlot {
                tx: Mutex::new(tx),
                state: AtomicU8::new(SHARD_ALIVE),
                died_once: AtomicBool::new(false),
                respawn_attempted: AtomicBool::new(false),
                issued: AtomicU32::new(0),
            });
            rxs.push(rx);
        }
        let shared = Arc::new(PoolShared {
            token,
            window,
            respawn,
            metrics: Arc::clone(&metrics),
            factory: Box::new(factory),
            slots,
        });
        let inits: Vec<_> = rxs
            .into_iter()
            .enumerate()
            .map(|(shard, rx)| spawn_worker(Arc::downgrade(&shared), shard, rx))
            .collect();
        for init_rx in inits {
            init_rx
                .recv()
                .map_err(|_| anyhow!("eval shard worker died during init"))??;
        }
        Ok(EvalShardPool { token, shared, metrics })
    }

    /// Number of shard workers (live or dead).
    pub fn workers(&self) -> usize {
        self.shared.slots.len()
    }

    /// Number of shard workers currently serving.
    pub fn live_workers(&self) -> usize {
        self.shared.slots.iter().filter(|s| s.is_alive()).count()
    }

    /// Whether `shard`'s worker is serving: false once its backend has
    /// panicked, true again after a successful `--respawn-shards` respawn.
    pub fn shard_alive(&self, shard: usize) -> bool {
        self.shared.slots.get(shard).is_some_and(|s| s.is_alive())
    }

    /// Home shard for a problem name: FNV-1a mod worker count, ignoring
    /// liveness.  Stable within a pool by construction (the hash is
    /// pinned, not `DefaultHasher`), so re-registration lands on the
    /// worker that already holds the problem's device buffers.
    /// [`Self::register`] falls back to a live shard when the home worker
    /// is dead.
    pub fn shard_for(&self, name: &str) -> usize {
        (fnv1a(name.as_bytes()) % self.shared.slots.len() as u64) as usize
    }

    /// Routing with failover: the home shard when it is alive, else the
    /// rendezvous-best live shard.  Survivors' routes never move (their
    /// home shard is still alive), and every client deterministically
    /// picks the same fallback for a given dead-set.
    fn route_live(&self, name: &str) -> Result<usize, ServiceError> {
        let slots = &self.shared.slots;
        let home = self.shard_for(name);
        if slots[home].is_alive() {
            return Ok(home);
        }
        let mut best: Option<(u64, usize)> = None;
        for (shard, slot) in slots.iter().enumerate() {
            if !slot.is_alive() {
                continue;
            }
            let score = rendezvous_score(name, shard);
            let better = match best {
                None => true,
                Some((bs, _)) => score > bs,
            };
            if better {
                best = Some((score, shard));
            }
        }
        best.map(|(_, shard)| shard).ok_or(ServiceError::ServiceDown)
    }

    /// Register a problem on its shard: routes it to a bucket and uploads
    /// statics on the owning worker.  A dead home shard re-routes to the
    /// rendezvous-best live shard; a shard dying *between* routing and the
    /// reply is retried against the survivors (bounded by the worker
    /// count — each retry requires a fresh death).  A send failure with
    /// the slot alive is retried too: it is either the respawn swapping
    /// the sender mid-send (the retry reaches the new worker) or a real
    /// shutdown (every retry fails the same way and `ServiceDown` stands).
    pub fn register(
        &self,
        problem: Arc<Problem>,
    ) -> Result<(ProblemId, Option<Bucket>), ServiceError> {
        let mut last = ServiceError::ServiceDown;
        for _attempt in 0..self.shared.slots.len() + 1 {
            let shard = self.route_live(&problem.name)?;
            let slot = &self.shared.slots[shard];
            let (reply_tx, reply_rx) = mpsc::sync_channel(1);
            let sent = slot
                .sender()
                .send(Msg::Register { problem: Arc::clone(&problem), reply: reply_tx });
            let res = match sent {
                Err(_) if slot.is_alive() => Err(ServiceError::ServiceDown),
                Err(_) => Err(ServiceError::ShardDown { shard }),
                Ok(()) => match reply_rx.recv() {
                    Ok(r) => r,
                    Err(_) => Err(slot.reply_dropped_error(shard)),
                },
            };
            match res {
                Err(e @ (ServiceError::ShardDown { .. } | ServiceError::ServiceDown)) => {
                    last = e;
                }
                other => return other,
            }
        }
        Err(last)
    }

    /// Evaluate a batch (blocking until the owning shard replies).  A dead
    /// shard answers immediately with [`ServiceError::ShardDown`] — a
    /// stale-id error, so engine clients heal by re-registering (which
    /// routes to a live shard).
    pub fn eval(
        &self,
        id: ProblemId,
        mut batch: Vec<TreeApprox>,
    ) -> Result<Vec<f64>, ServiceError> {
        if batch.is_empty() {
            return Ok(Vec::new());
        }
        if id.service != self.token {
            return Err(ServiceError::ForeignProblemId {
                id,
                registered: self.metrics.problems.load(Ordering::Relaxed) as usize,
            });
        }
        // A forged/stale id naming a shard this pool never had is rejected
        // up front — clamping it onto the last shard would mis-charge that
        // shard's queue-depth gauge and evaluate on a worker that cannot
        // know the problem.
        let shard = id.shard as usize;
        if shard >= self.shared.slots.len() {
            return Err(ServiceError::UnknownProblemId { id, registered: 0 });
        }
        let slot = &self.shared.slots[shard];
        // Two attempts: a send can race a respawn swapping the sender (the
        // old channel closes while the slot is already alive again).
        for _attempt in 0..2 {
            if !slot.is_alive() {
                return Err(ServiceError::ShardDown { shard });
            }
            let (reply_tx, reply_rx) = mpsc::sync_channel(1);
            self.metrics.shard_enqueued(shard);
            match slot.sender().send(Msg::Eval { id, batch, reply: reply_tx }) {
                Ok(()) => {
                    return match reply_rx.recv() {
                        Ok(res) => res,
                        Err(_) => Err(slot.reply_dropped_error(shard)),
                    };
                }
                Err(mpsc::SendError(msg)) => {
                    self.metrics.shard_dequeued(shard);
                    let Msg::Eval { batch: b, .. } = msg else { unreachable!() };
                    batch = b;
                }
            }
        }
        Err(if slot.is_alive() {
            ServiceError::ServiceDown
        } else {
            ServiceError::ShardDown { shard }
        })
    }

    /// Ask every worker to drain pending work and exit (idempotent;
    /// dropping all handles also works).
    pub fn shutdown(&self) {
        for slot in &self.shared.slots {
            let _ = slot.sender().send(Msg::Shutdown);
        }
    }
}

/// FNV-1a, pinned (routing must never change across Rust releases).
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0100_0000_01b3);
    }
    h
}

/// Pinned rendezvous score for (problem, shard): FNV-1a over the name
/// bytes followed by the shard index (little-endian u64).  Only consulted
/// for failover fallback, so the primary route stays the plain
/// `fnv1a % N` the seed pool shipped with.
fn rendezvous_score(name: &str, shard: usize) -> u64 {
    let mut h = fnv1a(name.as_bytes());
    for b in (shard as u64).to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0100_0000_01b3);
    }
    h
}

// ---- worker side (coalescer) ----------------------------------------------

/// One client eval request being assembled across >= 1 executions.
struct RequestState {
    reply: mpsc::SyncSender<Result<Vec<f64>, ServiceError>>,
    results: Vec<f64>,
    remaining: usize,
}

/// A request's chromosomes queued on its problem (consumed from `next`).
struct QueuedSlice {
    req: Rc<RefCell<RequestState>>,
    items: Vec<TreeApprox>,
    next: usize,
}

/// Per-problem coalescer state: FIFO of queued slices plus the armed
/// flush deadline (set when the oldest pending sub-width work arrived).
#[derive(Default)]
struct ProblemQueue {
    queue: VecDeque<QueuedSlice>,
    pending: usize,
    deadline: Option<Instant>,
}

/// Everything a worker needs besides its backend and receiver.  The pool
/// state is held weakly: worker threads must never keep their own senders
/// alive once every client handle is gone (drop-based shutdown).
struct WorkerCtx {
    token: u32,
    shard: u32,
    /// First `ProblemId` index this worker incarnation issues (the
    /// shard's all-time registration count at spawn).  Ids below it were
    /// issued by a dead predecessor and must read as unknown.
    index_base: u32,
    window: Option<Duration>,
    metrics: Arc<Metrics>,
    shared: Weak<PoolShared>,
}

/// Spawn one shard worker thread; returns the receiver for its one-shot
/// init result (backend construction happens inside the thread).  Used by
/// the initial pool spawn and by the respawn path.
fn spawn_worker(
    shared: Weak<PoolShared>,
    shard: usize,
    rx: mpsc::Receiver<Msg>,
) -> mpsc::Receiver<Result<()>> {
    let (init_tx, init_rx) = mpsc::sync_channel::<Result<()>>(1);
    std::thread::Builder::new()
        .name(format!("axdt-eval-shard-{shard}"))
        .spawn(move || {
            // Construct the backend while briefly holding a strong ref,
            // then drop it so the loop below runs with only the Weak.
            let started = match shared.upgrade() {
                Some(strong) => match (strong.factory)(shard) {
                    Ok(backend) => {
                        let ctx = WorkerCtx {
                            token: strong.token,
                            shard: shard as u32,
                            index_base: strong.slots[shard].issued.load(Ordering::Acquire),
                            window: strong.window,
                            metrics: Arc::clone(&strong.metrics),
                            shared: Weak::clone(&shared),
                        };
                        let _ = init_tx.send(Ok(()));
                        Some((backend, ctx))
                    }
                    Err(e) => {
                        let _ = init_tx.send(Err(e));
                        None
                    }
                },
                // Pool handles already gone: nothing to serve.
                None => None,
            };
            if let Some((backend, ctx)) = started {
                worker_loop(backend, rx, ctx);
            }
        })
        .expect("spawn eval shard worker");
    init_rx
}

/// Flip the shard dead — BEFORE any `ShardDown` reply goes out, so a
/// client that reacts to the error by re-registering already sees the
/// death and routes to a survivor.
fn mark_shard_dead(ctx: &WorkerCtx) {
    if let Some(shared) = ctx.shared.upgrade() {
        let slot = &shared.slots[ctx.shard as usize];
        slot.died_once.store(true, Ordering::Release);
        slot.state.store(SHARD_DEAD, Ordering::Release);
    }
    ctx.metrics.shard_died(ctx.shard as usize);
}

fn worker_loop(mut backend: Box<dyn Backend>, rx: mpsc::Receiver<Msg>, ctx: WorkerCtx) {
    let mut problems: Vec<(Arc<Problem>, RegisteredProblem)> = Vec::new();
    let mut queues: Vec<ProblemQueue> = Vec::new();
    loop {
        // Wait for work, bounded by the earliest armed coalescer deadline.
        let next_deadline = queues.iter().filter_map(|q| q.deadline).min();
        let msg = match next_deadline {
            // Invariant: no deadline => nothing pending, so a disconnect
            // here cannot strand queued work.
            None => match rx.recv() {
                Ok(m) => m,
                Err(_) => return,
            },
            Some(deadline) => {
                let now = Instant::now();
                if deadline <= now {
                    if !flush_expired(backend.as_mut(), &problems, &mut queues, &ctx) {
                        return die(rx, &mut queues, &ctx, RespawnPolicy::IfConfigured);
                    }
                    continue;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(m) => m,
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        if !flush_expired(backend.as_mut(), &problems, &mut queues, &ctx) {
                            return die(rx, &mut queues, &ctx, RespawnPolicy::IfConfigured);
                        }
                        continue;
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        // Every pool handle is gone: no respawn either.
                        if !flush_all(backend.as_mut(), &problems, &mut queues, &ctx) {
                            return die(rx, &mut queues, &ctx, RespawnPolicy::Never);
                        }
                        return;
                    }
                }
            }
        };
        match msg {
            Msg::Shutdown => {
                // In-flight jobs still get their replies: drain the
                // coalescer before exiting.  A panic during THIS drain
                // still answers everyone with `ShardDown`, but must not
                // respawn a worker for a pool that was told to stop.
                if !flush_all(backend.as_mut(), &problems, &mut queues, &ctx) {
                    return die(rx, &mut queues, &ctx, RespawnPolicy::Never);
                }
                return;
            }
            Msg::Register { problem, reply } => {
                match catch_unwind(AssertUnwindSafe(|| backend.register(&problem))) {
                    Ok(Ok(reg)) => {
                        let index = ctx.index_base + problems.len() as u32;
                        let id = ProblemId { service: ctx.token, shard: ctx.shard, index };
                        let bucket = reg.bucket().cloned();
                        problems.push((problem, reg));
                        queues.push(ProblemQueue::default());
                        // Advance the shard's all-time counter so a future
                        // respawn starts past this id (no aliasing).
                        if let Some(shared) = ctx.shared.upgrade() {
                            shared.slots[ctx.shard as usize]
                                .issued
                                .store(index + 1, Ordering::Release);
                        }
                        ctx.metrics.problems.fetch_add(1, Ordering::Relaxed);
                        let _ = reply.send(Ok((id, bucket)));
                    }
                    Ok(Err(e)) => {
                        let _ = reply
                            .send(Err(ServiceError::Backend { detail: format!("{e:#}") }));
                    }
                    Err(_) => {
                        // Backend panicked during registration: the worker
                        // cannot continue on a possibly-broken backend.
                        mark_shard_dead(&ctx);
                        let _ = reply.send(Err(ServiceError::ShardDown {
                            shard: ctx.shard as usize,
                        }));
                        ctx.metrics.record_stranded(1);
                        return die(rx, &mut queues, &ctx, RespawnPolicy::IfConfigured);
                    }
                }
            }
            Msg::Eval { id, batch, reply } => {
                ctx.metrics.shard_dequeued(ctx.shard as usize);
                // A stale or foreign id must not kill the worker thread
                // (which would wedge every other client) NOR silently
                // evaluate against the wrong problem — including ids the
                // shard's PREVIOUS incarnation issued: indices restart
                // behind `index_base` after a respawn, so those read as
                // unknown here and heal via re-registration.
                let idx = match id.index.checked_sub(ctx.index_base) {
                    Some(i)
                        if id.service == ctx.token
                            && id.shard == ctx.shard
                            && (i as usize) < problems.len() =>
                    {
                        i as usize
                    }
                    _ => {
                        let _ = reply.send(Err(ServiceError::UnknownProblemId {
                            id,
                            registered: problems.len(),
                        }));
                        continue;
                    }
                };
                if batch.is_empty() {
                    let _ = reply.send(Ok(Vec::new()));
                    continue;
                }
                let n = batch.len();
                let req = Rc::new(RefCell::new(RequestState {
                    reply,
                    results: Vec::with_capacity(n),
                    remaining: n,
                }));
                queues[idx].pending += n;
                queues[idx].queue.push_back(QueuedSlice { req, items: batch, next: 0 });
                let width = problems[idx].1.width().max(1);
                while queues[idx].pending >= width {
                    if !execute_chunk(
                        backend.as_mut(),
                        &problems[idx],
                        &mut queues[idx],
                        width,
                        FlushKind::Full,
                        &ctx,
                    ) {
                        return die(rx, &mut queues, &ctx, RespawnPolicy::IfConfigured);
                    }
                }
                match ctx.window {
                    None => {
                        // Coalescing off: dispatch the tail immediately.
                        let take = queues[idx].pending;
                        if take > 0
                            && !execute_chunk(
                                backend.as_mut(),
                                &problems[idx],
                                &mut queues[idx],
                                take,
                                FlushKind::Immediate,
                                &ctx,
                            )
                        {
                            return die(rx, &mut queues, &ctx, RespawnPolicy::IfConfigured);
                        }
                    }
                    Some(w) => {
                        if queues[idx].pending > 0 && queues[idx].deadline.is_none() {
                            queues[idx].deadline = Some(Instant::now() + w);
                        }
                    }
                }
            }
        }
    }
}

/// Whether a dying worker may spawn its one replacement.  `Never` is for
/// deaths during a shutdown/disconnect drain: the pool is stopping, and a
/// replacement would idle forever waiting for work that cannot come.
#[derive(Clone, Copy, PartialEq, Eq)]
enum RespawnPolicy {
    IfConfigured,
    Never,
}

/// Terminal path of a worker whose backend panicked: answer every request
/// still queued in the coalescer or sitting in the channel with a typed
/// [`ServiceError::ShardDown`] (never a silently dropped reply channel),
/// return the queue-depth gauge to zero, and — when the pool opted in and
/// `policy` allows — spawn ONE replacement worker from the retained
/// factory.  A respawned worker starts with no registered problems and
/// issues ids from the shard's all-time `issued` counter; stale ids heal
/// through the clients' re-register path.
fn die(
    rx: mpsc::Receiver<Msg>,
    queues: &mut [ProblemQueue],
    ctx: &WorkerCtx,
    policy: RespawnPolicy,
) {
    let shard = ctx.shard as usize;
    let down = ServiceError::ShardDown { shard };
    let mut stranded = 0u64;
    for q in queues.iter_mut() {
        for slice in q.queue.drain(..) {
            let mut r = slice.req.borrow_mut();
            // Contributors to the panicked chunk were already answered
            // (remaining forced to 0); everyone else is stranded here.
            if r.remaining > 0 {
                r.remaining = 0;
                let _ = r.reply.send(Err(down.clone()));
                stranded += 1;
            }
        }
        q.pending = 0;
        q.deadline = None;
    }
    let mut saw_shutdown = false;
    while let Ok(msg) = rx.try_recv() {
        match msg {
            Msg::Eval { reply, .. } => {
                ctx.metrics.shard_dequeued(shard);
                let _ = reply.send(Err(down.clone()));
                stranded += 1;
            }
            Msg::Register { reply, .. } => {
                let _ = reply.send(Err(down.clone()));
                stranded += 1;
            }
            // A Shutdown queued behind the panicking job means the pool
            // was already told to stop — honoring it here prevents a
            // replacement worker that would never receive it and would
            // idle until the last handle drops.
            Msg::Shutdown => saw_shutdown = true,
        }
    }
    ctx.metrics.record_stranded(stranded);
    // Close the channel BEFORE any respawn revives the shard: a racing
    // sender then fails while the slot still reads dead, which the facade
    // maps to `ShardDown` rather than a bogus `ServiceDown`.
    drop(rx);
    if policy == RespawnPolicy::Never || saw_shutdown {
        return;
    }
    let Some(shared) = ctx.shared.upgrade() else { return };
    let slot = &shared.slots[shard];
    if !shared.respawn || slot.respawn_attempted.swap(true, Ordering::AcqRel) {
        return;
    }
    let (tx, new_rx) = mpsc::sync_channel::<Msg>(QUEUE_DEPTH);
    let init_rx = spawn_worker(Weak::clone(&ctx.shared), shard, new_rx);
    match init_rx.recv() {
        Ok(Ok(())) => {
            // Install the sender before flipping alive: anyone who sees
            // the shard live must reach the NEW worker.
            *lock_recover(&slot.tx) = tx;
            slot.state.store(SHARD_ALIVE, Ordering::Release);
            ctx.metrics.shard_respawned(shard);
        }
        Ok(Err(e)) => {
            eprintln!("[axdt] shard {shard} respawn failed: {e:#} (shard stays dead)");
        }
        Err(_) => {
            eprintln!(
                "[axdt] shard {shard} respawn worker died during init (shard stays dead)"
            );
        }
    }
}

/// Flush every problem whose coalescing deadline has expired.  Returns
/// false when the backend panicked (the worker must die).
fn flush_expired(
    backend: &mut dyn Backend,
    problems: &[(Arc<Problem>, RegisteredProblem)],
    queues: &mut [ProblemQueue],
    ctx: &WorkerCtx,
) -> bool {
    let now = Instant::now();
    for idx in 0..queues.len() {
        if queues[idx].deadline.is_some_and(|d| d <= now) {
            let take = queues[idx].pending;
            if !execute_chunk(
                backend,
                &problems[idx],
                &mut queues[idx],
                take,
                FlushKind::Deadline,
                ctx,
            ) {
                return false;
            }
        }
    }
    true
}

/// Drain every pending chunk (shutdown/disconnect).  Returns false when
/// the backend panicked mid-drain.
fn flush_all(
    backend: &mut dyn Backend,
    problems: &[(Arc<Problem>, RegisteredProblem)],
    queues: &mut [ProblemQueue],
    ctx: &WorkerCtx,
) -> bool {
    for idx in 0..queues.len() {
        while queues[idx].pending > 0 {
            let take = queues[idx].pending;
            if !execute_chunk(
                backend,
                &problems[idx],
                &mut queues[idx],
                take,
                FlushKind::Drain,
                ctx,
            ) {
                return false;
            }
        }
    }
    true
}

/// Pop up to `take` queued chromosomes for one problem, execute them as a
/// single backend batch, and distribute results (or the failure) to every
/// contributing request.  Returns false when the backend PANICKED (as
/// opposed to returning an error): contributors have been answered with
/// [`ServiceError::ShardDown`], the shard is marked dead, and the caller
/// must stop and drain via [`die`].
fn execute_chunk(
    backend: &mut dyn Backend,
    problem_entry: &(Arc<Problem>, RegisteredProblem),
    pq: &mut ProblemQueue,
    take: usize,
    kind: FlushKind,
    ctx: &WorkerCtx,
) -> bool {
    let shard = ctx.shard as usize;
    let metrics = &ctx.metrics;
    let (problem, reg) = problem_entry;
    let width = reg.width().max(1);
    // Never hand the backend more than one artifact width at once, even if
    // an invariant slips (callers keep pending < width between flushes).
    let take = take.min(pq.pending).min(width);
    if take == 0 {
        pq.deadline = None;
        return true;
    }
    let mut chunk: Vec<TreeApprox> = Vec::with_capacity(take);
    let mut contributors: Vec<(Rc<RefCell<RequestState>>, usize)> = Vec::new();
    while chunk.len() < take {
        let front = pq.queue.front_mut().expect("pending count matches queued items");
        let n = (take - chunk.len()).min(front.items.len() - front.next);
        chunk.extend_from_slice(&front.items[front.next..front.next + n]);
        front.next += n;
        contributors.push((Rc::clone(&front.req), n));
        if front.next == front.items.len() {
            pq.queue.pop_front();
        }
    }
    pq.pending -= take;
    if pq.pending == 0 {
        pq.deadline = None;
    }
    let t0 = Instant::now();
    let outcome = catch_unwind(AssertUnwindSafe(|| backend.eval(reg, problem.as_ref(), &chunk)));
    let res = match outcome {
        Ok(r) => r.and_then(|accs| {
            // A short result must fail the requests, not panic the worker
            // (which would wedge every client of this shard).
            if accs.len() == chunk.len() {
                Ok(accs)
            } else {
                Err(anyhow!(
                    "backend returned {} accuracies for a chunk of {}",
                    accs.len(),
                    chunk.len()
                ))
            }
        }),
        Err(_) => {
            // The backend panicked mid-eval and may be in an arbitrary
            // broken state: this shard is dead.  Mark it first (so healing
            // clients route elsewhere), then answer every contributor with
            // the typed error instead of dropping their reply channels.
            mark_shard_dead(ctx);
            let downed = ServiceError::ShardDown { shard };
            for (req, _) in &contributors {
                let mut r = req.borrow_mut();
                r.remaining = 0;
                let _ = r.reply.send(Err(downed.clone()));
            }
            metrics.record_stranded(contributors.len() as u64);
            return false;
        }
    };
    match res {
        Ok(accs) => {
            metrics.record_shard_execution(
                shard,
                chunk.len(),
                width.max(chunk.len()),
                t0.elapsed().as_nanos() as u64,
                contributors.len(),
                kind,
            );
            let mut off = 0usize;
            for (req, n) in contributors {
                let mut r = req.borrow_mut();
                r.results.extend_from_slice(&accs[off..off + n]);
                off += n;
                r.remaining -= n;
                if r.remaining == 0 {
                    let results = std::mem::take(&mut r.results);
                    let _ = r.reply.send(Ok(results));
                }
            }
        }
        Err(e) => {
            // Every contributor's fitness is poisoned: fail them all and
            // purge their queued tails so they are not executed (and
            // double-replied) later.  Other requests keep their place.
            let err = ServiceError::Backend { detail: format!("{e:#}") };
            let dead: Vec<*const RefCell<RequestState>> =
                contributors.iter().map(|(r, _)| Rc::as_ptr(r)).collect();
            for (req, _) in &contributors {
                let mut r = req.borrow_mut();
                r.remaining = 0;
                let _ = r.reply.send(Err(err.clone()));
            }
            let mut purged = 0usize;
            let kept: VecDeque<QueuedSlice> = pq
                .queue
                .drain(..)
                .filter(|s| {
                    if dead.contains(&Rc::as_ptr(&s.req)) {
                        purged += s.items.len() - s.next;
                        false
                    } else {
                        true
                    }
                })
                .collect();
            pq.queue = kept;
            pq.pending -= purged;
            if pq.pending == 0 {
                pq.deadline = None;
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fitness::testutil::small_problem;
    use crate::hw::{AreaLut, EgtLibrary};
    use std::sync::atomic::AtomicBool;
    use std::sync::Mutex;

    /// Fake backend recording every executed chunk width.
    struct CountingBackend {
        width: usize,
        chunks: Arc<Mutex<Vec<usize>>>,
    }

    impl Backend for CountingBackend {
        fn register(&mut self, _p: &Arc<Problem>) -> Result<RegisteredProblem> {
            Ok(RegisteredProblem::Native { width: self.width })
        }
        fn eval(
            &mut self,
            _reg: &RegisteredProblem,
            _p: &Problem,
            chunk: &[TreeApprox],
        ) -> Result<Vec<f64>> {
            self.chunks.lock().unwrap().push(chunk.len());
            Ok(vec![0.25; chunk.len()])
        }
        fn name(&self) -> &'static str {
            "counting"
        }
    }

    fn seeds() -> Arc<Problem> {
        Arc::new(small_problem(&AreaLut::build(&EgtLibrary::default())))
    }

    #[test]
    fn fnv_route_is_pinned() {
        // The empty-input value is the FNV offset basis; routing stability
        // across releases is a hard requirement (device-buffer pinning).
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"seeds"), fnv1a(b"seeds"));
        assert_ne!(fnv1a(b"seeds"), fnv1a(b"cardio"));
        // The rendezvous fallback score is pinned the same way: the
        // continuation of the name hash over the shard index bytes.
        assert_eq!(rendezvous_score("seeds", 3), rendezvous_score("seeds", 3));
        assert_ne!(rendezvous_score("seeds", 0), rendezvous_score("seeds", 1));
    }

    #[test]
    fn uncoalesced_chunking_matches_legacy_split() {
        let chunks = Arc::new(Mutex::new(Vec::new()));
        let c = Arc::clone(&chunks);
        let pool = EvalShardPool::spawn(1, 0, false, move |_| {
            Ok(Box::new(CountingBackend { width: 8, chunks: Arc::clone(&c) })
                as Box<dyn Backend>)
        })
        .unwrap();
        let p = seeds();
        let (id, bucket) = pool.register(Arc::clone(&p)).unwrap();
        assert!(bucket.is_none());
        let batch = vec![TreeApprox::exact(&p.tree); 21];
        let got = pool.eval(id, batch).unwrap();
        assert_eq!(got, vec![0.25; 21]);
        // 21 at width 8: two full chunks + the immediate tail, like the
        // seed service.
        assert_eq!(*chunks.lock().unwrap(), vec![8, 8, 5]);
        assert_eq!(pool.metrics.full_flushes.load(Ordering::Relaxed), 2);
        assert_eq!(pool.metrics.deadline_flushes.load(Ordering::Relaxed), 0);
        pool.shutdown();
    }

    #[test]
    fn backend_error_fails_request_and_worker_survives() {
        struct FlakyBackend {
            width: usize,
            fail: Arc<AtomicBool>,
        }
        impl Backend for FlakyBackend {
            fn register(&mut self, _p: &Arc<Problem>) -> Result<RegisteredProblem> {
                Ok(RegisteredProblem::Native { width: self.width })
            }
            fn eval(
                &mut self,
                _reg: &RegisteredProblem,
                _p: &Problem,
                chunk: &[TreeApprox],
            ) -> Result<Vec<f64>> {
                if self.fail.load(Ordering::Relaxed) {
                    Err(anyhow!("injected backend failure"))
                } else {
                    Ok(vec![0.5; chunk.len()])
                }
            }
            fn name(&self) -> &'static str {
                "flaky"
            }
        }

        let fail = Arc::new(AtomicBool::new(true));
        let f = Arc::clone(&fail);
        let pool = EvalShardPool::spawn(1, 0, false, move |_| {
            Ok(Box::new(FlakyBackend { width: 8, fail: Arc::clone(&f) })
                as Box<dyn Backend>)
        })
        .unwrap();
        let p = seeds();
        let (id, _) = pool.register(Arc::clone(&p)).unwrap();
        let batch = vec![TreeApprox::exact(&p.tree); 3];
        let err = pool.eval(id, batch.clone()).unwrap_err();
        assert!(format!("{err}").contains("injected backend failure"), "{err}");
        // An error `Result` is NOT a death: the worker survives and the
        // shard stays live.
        assert!(pool.shard_alive(id.shard()));
        fail.store(false, Ordering::Relaxed);
        assert_eq!(pool.eval(id, batch).unwrap(), vec![0.5; 3]);
        pool.shutdown();
    }

    /// A panicking backend kills only its shard: in-flight work gets a
    /// typed `ShardDown`, survivors keep serving, and registration falls
    /// back to a live shard (rendezvous, not a clamp).
    #[test]
    fn backend_panic_downs_shard_and_registration_falls_back() {
        struct PanicOnEval;
        impl Backend for PanicOnEval {
            fn register(&mut self, _p: &Arc<Problem>) -> Result<RegisteredProblem> {
                Ok(RegisteredProblem::Native { width: 8 })
            }
            fn eval(
                &mut self,
                _reg: &RegisteredProblem,
                _p: &Problem,
                _chunk: &[TreeApprox],
            ) -> Result<Vec<f64>> {
                panic!("injected backend panic");
            }
            fn name(&self) -> &'static str {
                "panic-on-eval"
            }
        }
        struct Ok25 {
            width: usize,
        }
        impl Backend for Ok25 {
            fn register(&mut self, _p: &Arc<Problem>) -> Result<RegisteredProblem> {
                Ok(RegisteredProblem::Native { width: self.width })
            }
            fn eval(
                &mut self,
                _reg: &RegisteredProblem,
                _p: &Problem,
                chunk: &[TreeApprox],
            ) -> Result<Vec<f64>> {
                Ok(vec![0.25; chunk.len()])
            }
            fn name(&self) -> &'static str {
                "ok25"
            }
        }

        let p = seeds();
        let victim = {
            // Find the problem's home shard on a 2-worker pool first.
            let probe = EvalShardPool::spawn(2, 0, false, |_| {
                Ok(Box::new(Ok25 { width: 8 }) as Box<dyn Backend>)
            })
            .unwrap();
            let s = probe.shard_for(&p.name);
            probe.shutdown();
            s
        };
        let pool = EvalShardPool::spawn(2, 0, false, move |shard| {
            if shard == victim {
                Ok(Box::new(PanicOnEval) as Box<dyn Backend>)
            } else {
                Ok(Box::new(Ok25 { width: 8 }) as Box<dyn Backend>)
            }
        })
        .unwrap();

        let (id, _) = pool.register(Arc::clone(&p)).unwrap();
        assert_eq!(id.shard(), victim);
        let batch = vec![TreeApprox::exact(&p.tree); 3];
        let err = pool.eval(id, batch.clone()).unwrap_err();
        assert!(
            matches!(err, ServiceError::ShardDown { shard } if shard == victim),
            "{err:?}"
        );
        assert!(err.is_stale_id(), "clients must heal ShardDown by re-registering");
        assert!(!pool.shard_alive(victim));
        assert_eq!(pool.live_workers(), 1);

        // Later evals against the dead shard fail fast and typed.
        let err = pool.eval(id, batch.clone()).unwrap_err();
        assert!(matches!(err, ServiceError::ShardDown { .. }), "{err:?}");

        // Registration re-routes to the survivor; evals work there.
        let (id2, _) = pool.register(Arc::clone(&p)).unwrap();
        assert_ne!(id2.shard(), victim);
        assert_eq!(pool.eval(id2, batch).unwrap(), vec![0.25; 3]);

        // The dead shard's gauge went back to zero; the death is counted.
        assert_eq!(
            pool.metrics.shards()[victim].queue_depth.load(Ordering::Relaxed),
            0
        );
        assert_eq!(pool.metrics.shard_deaths.load(Ordering::Relaxed), 1);
        pool.shutdown();
    }

    /// A forged id naming a shard the pool never had is rejected before it
    /// can charge any queue-depth gauge (it used to be clamped onto the
    /// last shard).
    #[test]
    fn out_of_range_shard_is_rejected_not_clamped() {
        let chunks = Arc::new(Mutex::new(Vec::new()));
        let c = Arc::clone(&chunks);
        let pool = EvalShardPool::spawn(2, 0, false, move |_| {
            Ok(Box::new(CountingBackend { width: 8, chunks: Arc::clone(&c) })
                as Box<dyn Backend>)
        })
        .unwrap();
        let p = seeds();
        let (id, _) = pool.register(Arc::clone(&p)).unwrap();
        let forged = ProblemId { shard: 7, ..id };
        let err = pool.eval(forged, vec![TreeApprox::exact(&p.tree); 2]).unwrap_err();
        assert!(matches!(err, ServiceError::UnknownProblemId { .. }), "{err:?}");
        assert!(err.is_stale_id());
        for s in pool.metrics.shards() {
            assert_eq!(s.queue_depth.load(Ordering::Relaxed), 0);
            assert_eq!(s.queue_peak.load(Ordering::Relaxed), 0, "no gauge was charged");
        }
        // The real id still works.
        assert_eq!(pool.eval(id, vec![TreeApprox::exact(&p.tree); 2]).unwrap().len(), 2);
        pool.shutdown();
    }

    #[test]
    fn pool_options_resolve_worker_counts() {
        let auto = PoolOptions::default();
        // Auto path: whatever default_threads() says, the documented
        // [1, 64] clamp holds.
        assert!((1..=64).contains(&auto.native_workers()));
        assert_eq!(auto.xla_workers(), 1);
        assert!(!auto.respawn, "respawn is opt-in");
        let fixed = PoolOptions { workers: 4, ..PoolOptions::default() };
        assert_eq!(fixed.native_workers(), 4);
        assert_eq!(fixed.xla_workers(), 4);
        let huge = PoolOptions { workers: 1000, ..PoolOptions::default() };
        assert_eq!(huge.native_workers(), 64);
        assert_eq!(huge.xla_workers(), 64);
    }
}
