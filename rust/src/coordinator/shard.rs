//! Sharded evaluation pool: N backend workers + cross-driver coalescing +
//! shard failover.
//!
//! The seed service ran exactly one worker thread per backend, which made
//! the evaluation service the throughput ceiling of every GA-driven search
//! (ROADMAP: multi-worker sharding, batch coalescing).  This module owns
//! the scaled-up machinery:
//!
//! ```text
//!  GA driver (dataset A) ──┐  route by ProblemId.shard   ┌─ worker 0 (backend 0)
//!  GA driver (dataset B) ──┼──────────────────────────────┤  worker 1 (backend 1)
//!  benches / CLI        ──┘   (FNV-1a(problem) % N)       └─ worker k: Coalescer → execute
//! ```
//!
//! * [`EvalShardPool`] spawns N workers; each constructs its **own**
//!   backend instance inside its thread (the PJRT client is not `Send`,
//!   and per-worker clients are exactly how the pool scales past a single
//!   PJRT client).
//! * Registration hash-routes a problem to a stable shard
//!   (FNV-1a of the problem name, mod N).  The returned [`ProblemId`]
//!   records the shard, pinning every later job to the worker that holds
//!   the problem's device buffers.
//! * Each worker fronts its backend with a **coalescer**: sub-width
//!   batches from concurrent drivers queue per problem and are merged into
//!   one padded execution, flushing when the artifact width P fills or a
//!   deadline expires.  This converts the padding waste the metrics record
//!   into useful work.  Registrations of the *same* `Arc<Problem>` share
//!   one coalescer queue, so per-driver registrations still merge.
//!
//! # Coalescing policy ([`CoalesceMode`])
//!
//! * `off` — every request dispatches immediately (legacy per-request
//!   behavior; also what `fixed` with a 0 window resolves to).
//! * `fixed` — sub-width batches wait up to `--coalesce-window-us` for
//!   concurrent work before a padded flush (PR 2 behavior).
//! * `adaptive` — the worker sizes the window itself: it tracks a
//!   per-problem EWMA of request inter-arrival times and arms each flush
//!   deadline at `IA_MULT x EWMA`, clamped to
//!   `[0, --coalesce-window-max-us]`.  And because a driver *blocks* on
//!   its in-flight `eval`, the moment every registered driver of a
//!   problem has a request queued no more work can arrive — the worker
//!   flushes immediately ([`FlushKind::AllDrivers`]) instead of waiting
//!   out the window.  Bursty generation-synchronized traffic therefore
//!   pays ~zero added latency while still coalescing fully; steady
//!   trickles get a window matched to the observed arrival rate.
//!
//! # Two-phase eval ([`EvalShardPool::submit`] / [`EvalShardPool::wait`])
//!
//! Evaluation is ticketed: `submit` enqueues a batch on its problem's
//! shard and returns a [`Ticket`] immediately; `wait` blocks on that
//! ticket's result.  The blocking [`EvalShardPool::eval`] is literally
//! `wait(submit(..))`, so both phases share one code path — routing,
//! coalescing groups, clock-driven deadlines, and ShardDown/failover
//! semantics are identical whichever entry point a client uses.  A single
//! driver that submits micro-batches for several problems before
//! collecting any keeps every shard busy at once instead of ping-ponging
//! one request at a time; tickets may be collected in any order (results
//! are matched by reply channel, not arrival order), and a shard dying
//! with tickets in flight fails each of them with the healable
//! [`ServiceError::ShardDown`].
//!
//! # Time
//!
//! Workers never read `Instant::now()`: every deadline decision goes
//! through the pool's injected [`Clock`] (`util::clock`).  Production
//! pools run on [`SystemClock`]; the `*_with_clock` constructors accept a
//! [`ManualClock`](crate::util::clock::ManualClock) so tests drive
//! windows, deadline flushes, and failover drains deterministically —
//! zero `thread::sleep`.
//!
//! # Failover
//!
//! A backend panic must not strand a long multi-dataset run (the search
//! spaces take thousands of evaluations per dataset).  Worker loops
//! therefore treat a panicking backend as a *shard death*, not a process
//! problem:
//!
//! * every backend call runs under `catch_unwind`; on panic the worker
//!   marks its shard dead, answers every in-flight, coalescing, and queued
//!   request with a typed [`ServiceError::ShardDown`] (never a silently
//!   dropped reply channel), zeroes its queue-depth gauge, and exits;
//! * [`EvalShardPool::register`] re-routes problems whose home shard is
//!   dead to the rendezvous-best **live** shard (scored by a pinned FNV-1a
//!   of name+shard, so survivors' routes never move);
//! * clients heal transparently: `ShardDown` is a stale-id error, so the
//!   [`XlaEngine`] re-register-and-retry path lands the problem on a live
//!   shard and repeats the failed batch — a run loses at most the
//!   in-flight generation, never a dataset;
//! * with [`PoolOptions::respawn`] (CLI `--respawn-shards`) the dying
//!   worker spawns ONE replacement from the retained backend factory;
//!   after a second death the shard stays permanently dead.
//!
//! Clients normally reach this through the [`EvalService`] facade.
//!
//! [`EvalService`]: super::service::EvalService
//! [`XlaEngine`]: super::service::XlaEngine

use std::cell::RefCell;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::rc::Rc;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU8, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex, Weak};
use std::time::Duration;

use anyhow::{anyhow, Result};

use super::metrics::{lock_recover, FlushKind, Metrics};
use super::service::ServiceError;
use crate::util::trace::TraceKind;
use crate::fitness::encode::Bucket;
#[cfg(feature = "xla")]
use crate::fitness::encode::{self, StaticTensors};
use crate::fitness::{native::NativeEngine, AccuracyEngine, Problem};
use crate::hw::synth::TreeApprox;
#[cfg(feature = "xla")]
use crate::runtime::{DeviceStatics, XlaRuntime};
use crate::util::clock::{Clock, SystemClock};
use crate::util::pool;

/// Bounded per-worker queue depth (jobs in flight before senders block).
const QUEUE_DEPTH: usize = 16;

/// What actually evaluates a padded population batch.
///
/// Not `Send`: the PJRT client wraps an `Rc`.  Backends are therefore
/// *constructed inside* each worker thread by the spawn factory.
pub(crate) trait Backend {
    fn register(&mut self, problem: &Arc<Problem>) -> Result<RegisteredProblem>;
    fn eval(
        &mut self,
        reg: &RegisteredProblem,
        problem: &Problem,
        chunk: &[TreeApprox],
    ) -> Result<Vec<f64>>;
    /// Backend id (surfaced in logs / metrics lines).
    #[allow(dead_code)]
    fn name(&self) -> &'static str;
}

/// Backend-side registration state.
pub(crate) enum RegisteredProblem {
    #[cfg(feature = "xla")]
    Xla { statics: DeviceStatics },
    Native { width: usize },
}

impl RegisteredProblem {
    fn bucket(&self) -> Option<&Bucket> {
        match self {
            #[cfg(feature = "xla")]
            RegisteredProblem::Xla { statics } => Some(&statics.bucket),
            RegisteredProblem::Native { .. } => None,
        }
    }

    /// Population width the backend executes at (batch-splitting unit).
    fn width(&self) -> usize {
        match self {
            #[cfg(feature = "xla")]
            RegisteredProblem::Xla { statics } => statics.bucket.p,
            RegisteredProblem::Native { width } => *width,
        }
    }
}

/// PJRT-backed backend (one PJRT client per worker).
#[cfg(feature = "xla")]
struct XlaBackend {
    runtime: XlaRuntime,
}

#[cfg(feature = "xla")]
impl Backend for XlaBackend {
    fn register(&mut self, problem: &Arc<Problem>) -> Result<RegisteredProblem> {
        let (bucket, _) = self
            .runtime
            .meta
            .route(problem)
            .ok_or_else(|| {
                anyhow!(
                    "no bucket fits problem '{}' (n_test={}, n_comp={}, leaves={})",
                    problem.name,
                    problem.n_test,
                    problem.n_comparators(),
                    problem.tree.n_leaves()
                )
            })?
            .clone();
        self.runtime.ensure_compiled(&bucket.name)?;
        let st: StaticTensors = encode::encode_static(problem, &bucket);
        let statics = self.runtime.upload_statics(&st)?;
        Ok(RegisteredProblem::Xla { statics })
    }

    fn eval(
        &mut self,
        reg: &RegisteredProblem,
        problem: &Problem,
        chunk: &[TreeApprox],
    ) -> Result<Vec<f64>> {
        let RegisteredProblem::Xla { statics } = reg else {
            return Err(anyhow!("backend mismatch"));
        };
        let bucket = statics.bucket.clone();
        let (thr, scale) = encode::pack_population(problem, &bucket, chunk);
        let acc = self.runtime.execute(statics, &thr, &scale)?;
        Ok(acc.iter().take(chunk.len()).map(|&a| a as f64).collect())
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

/// Native backend: same pool machinery, tree-walk arithmetic.  Used by
/// unit tests (no artifacts needed) and `--engine native-service`.
struct NativeBackend {
    engine: NativeEngine,
    /// Emulated artifact width, so batching/padding paths are exercised.
    width: usize,
}

impl Backend for NativeBackend {
    fn register(&mut self, _problem: &Arc<Problem>) -> Result<RegisteredProblem> {
        Ok(RegisteredProblem::Native { width: self.width })
    }

    fn eval(
        &mut self,
        _reg: &RegisteredProblem,
        problem: &Problem,
        chunk: &[TreeApprox],
    ) -> Result<Vec<f64>> {
        self.engine.batch_accuracy(problem, chunk)
    }

    fn name(&self) -> &'static str {
        "native-service"
    }
}

/// Problem handle returned by registration.  Carries the issuing pool's
/// token (so an id presented to a *different* pool is rejected even when
/// its index happens to be in range there) and the shard the problem is
/// pinned to.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ProblemId {
    pub(crate) service: u32,
    pub(crate) shard: u32,
    pub(crate) index: u32,
}

impl ProblemId {
    /// The pool shard (worker) this problem is pinned to.
    pub fn shard(&self) -> usize {
        self.shard as usize
    }
}

/// Process-unique pool tokens (0 is never issued, so a forged
/// `ProblemId` default can't match).
static NEXT_POOL_TOKEN: AtomicU32 = AtomicU32::new(1);

/// In-flight evaluation handle: phase one of the two-phase eval API.
/// Issued by [`EvalShardPool::submit`], redeemed (in any order) by
/// [`EvalShardPool::wait`].  Dropping a ticket without waiting abandons
/// the request — the worker still executes it and discards the reply —
/// and releases the in-flight gauge.
#[must_use = "a Ticket must be redeemed with wait(); dropping it abandons the submitted work"]
pub struct Ticket {
    repr: TicketRepr,
}

enum TicketRepr {
    /// Empty batches resolve immediately; nothing was ever sent.
    Empty,
    Pending {
        shard: usize,
        rx: mpsc::Receiver<Result<Vec<f64>, ServiceError>>,
        /// Submit timestamp (pool clock ns) for the submit→collect gauge.
        submitted_ns: u64,
        /// RAII release of the in-flight ticket gauge (collected OR
        /// abandoned, the gauge must come back down).
        gauge: TicketGauge,
    },
}

struct TicketGauge(Arc<Metrics>);

impl Drop for TicketGauge {
    fn drop(&mut self) {
        self.0.ticket_done();
    }
}

impl Ticket {
    /// The shard serving this ticket (`None` for the empty ticket).
    pub fn shard(&self) -> Option<usize> {
        match &self.repr {
            TicketRepr::Empty => None,
            TicketRepr::Pending { shard, .. } => Some(*shard),
        }
    }
}

/// Coalescing policy selector (CLI `--coalesce adaptive|fixed|off`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CoalesceMode {
    /// Every request dispatches immediately (legacy per-request behavior).
    Off,
    /// Sub-width batches wait a fixed `--coalesce-window-us` window.
    Fixed,
    /// The worker sizes the window from the observed per-problem EWMA of
    /// request inter-arrival times, clamped to
    /// `[0, --coalesce-window-max-us]`, and flushes early the moment
    /// every registered driver of the problem has work queued.
    Adaptive,
}

impl CoalesceMode {
    pub fn parse(s: &str) -> Result<CoalesceMode> {
        match s {
            "off" => Ok(CoalesceMode::Off),
            "fixed" => Ok(CoalesceMode::Fixed),
            "adaptive" => Ok(CoalesceMode::Adaptive),
            _ => Err(anyhow!(
                "unknown coalesce mode '{s}' (expected adaptive | fixed | off)"
            )),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            CoalesceMode::Off => "off",
            CoalesceMode::Fixed => "fixed",
            CoalesceMode::Adaptive => "adaptive",
        }
    }
}

/// Fully resolved coalescing policy a pool's workers run with (the mode
/// plus its duration knob, pre-converted).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum CoalescePolicy {
    Off,
    Fixed(Duration),
    Adaptive { max: Duration },
}

/// EWMA smoothing factor for the adaptive controller's inter-arrival
/// estimate: `ewma' = ALPHA * sample + (1 - ALPHA) * ewma`.  Exposed so
/// timing tests can compute the expected estimate bit-exactly.
pub const ADAPTIVE_EWMA_ALPHA: f64 = 0.25;

/// Adaptive window = `IA_MULT x EWMA(inter-arrival)`, clamped to the
/// configured max: one expected arrival gap plus one of slack for a
/// straggling driver.  Exposed for the same bit-exact-test reason.
pub const ADAPTIVE_WINDOW_IA_MULT: f64 = 2.0;

/// Sizing/behavior knobs for an [`EvalShardPool`] (CLI: `--workers`,
/// `--coalesce`, `--coalesce-window-us`, `--coalesce-window-max-us`,
/// `--respawn-shards`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PoolOptions {
    /// Worker (shard) count.  0 = auto: one per core for the native
    /// backend, one per device (currently 1, the CPU PJRT client) for XLA.
    /// Clamped to [1, 64].
    pub workers: usize,
    /// Coalescing policy (default [`CoalesceMode::Fixed`], the PR 2
    /// behavior).
    pub coalesce: CoalesceMode,
    /// Fixed-mode coalescing window in microseconds: how long a sub-width
    /// batch may wait for concurrent drivers' work before a padded flush.
    /// 0 turns coalescing off (every request dispatches immediately).
    /// Ignored by the other modes.
    pub coalesce_window_us: u64,
    /// Adaptive-mode cap in microseconds: the controller's window never
    /// exceeds it, whatever the EWMA says.  Ignored by the other modes.
    pub coalesce_window_max_us: u64,
    /// Native-engine threads per worker.  0 = auto (total thread budget /
    /// workers), so `workers=1` keeps the seed service's full batch-level
    /// parallelism.  Ignored by the XLA backend.
    pub engine_threads: usize,
    /// Respawn a dead shard's worker once from the retained backend
    /// factory (CLI `--respawn-shards`); after a second death the shard is
    /// permanently dead.  Off by default: a panicking backend usually
    /// deserves a postmortem before it is restarted.
    pub respawn: bool,
}

impl Default for PoolOptions {
    fn default() -> Self {
        PoolOptions {
            workers: 0,
            coalesce: CoalesceMode::Fixed,
            coalesce_window_us: 200,
            coalesce_window_max_us: 1_000,
            engine_threads: 0,
            respawn: false,
        }
    }
}

impl PoolOptions {
    /// Resolved worker count for the native backend, clamped to [1, 64]
    /// on BOTH the auto and the explicit path (the documented contract;
    /// `default_threads` also clamps today, but this method must not lean
    /// on that).
    pub fn native_workers(&self) -> usize {
        let w = if self.workers == 0 { pool::default_threads() } else { self.workers };
        w.clamp(1, 64)
    }

    /// Resolved worker count for the XLA backend (1 per device; the CPU
    /// PJRT client exposes one), clamped to [1, 64].
    pub fn xla_workers(&self) -> usize {
        let w = if self.workers == 0 { 1 } else { self.workers };
        w.clamp(1, 64)
    }

    /// The coalescing policy workers run with.  `fixed` with a zero
    /// window resolves to `Off` (the pre-policy contract for
    /// `--coalesce-window-us 0`).
    pub(crate) fn policy(&self) -> CoalescePolicy {
        match self.coalesce {
            CoalesceMode::Off => CoalescePolicy::Off,
            CoalesceMode::Fixed => {
                if self.coalesce_window_us == 0 {
                    CoalescePolicy::Off
                } else {
                    CoalescePolicy::Fixed(Duration::from_micros(self.coalesce_window_us))
                }
            }
            CoalesceMode::Adaptive => CoalescePolicy::Adaptive {
                max: Duration::from_micros(self.coalesce_window_max_us),
            },
        }
    }
}

enum Msg {
    Register {
        problem: Arc<Problem>,
        reply: mpsc::SyncSender<Result<(ProblemId, Option<Bucket>), ServiceError>>,
    },
    Eval {
        id: ProblemId,
        batch: Vec<TreeApprox>,
        reply: mpsc::SyncSender<Result<Vec<f64>, ServiceError>>,
    },
    /// No-op nudge: sent by a [`ManualClock`](crate::util::clock::
    /// ManualClock) waker after a virtual-time advance, so a worker
    /// blocked waiting on a (virtual) deadline wakes and re-reads the
    /// clock.  Wakeups are messages, not condvar signals — they cannot be
    /// lost to a block/notify race.
    Tick,
    Shutdown,
}

const SHARD_ALIVE: u8 = 0;
const SHARD_DEAD: u8 = 1;

/// Client-visible state of one shard: the current sender to its worker
/// (swapped by a respawn) and a liveness flag the dying worker flips
/// BEFORE it answers anyone with `ShardDown`, so routing decisions made
/// after an error see the death.
struct ShardSlot {
    tx: Mutex<mpsc::SyncSender<Msg>>,
    state: AtomicU8,
    /// Latched forever by the first death (survives a respawn flipping
    /// `state` back to alive).  Reply-channel failures on a shard that
    /// has EVER died map to the healable `ShardDown` — an instantaneous
    /// liveness read can miss a death that a completed respawn already
    /// papered over — while shards with no death history keep reporting
    /// the genuine-bug `ReplyDropped`.
    died_once: AtomicBool,
    /// Latched by the first death; a shard is respawned at most once.
    respawn_attempted: AtomicBool,
    /// Total problems ever registered on this shard, across worker
    /// incarnations.  A respawned worker starts issuing `ProblemId`
    /// indices from here, so an id issued before the death can never
    /// alias a post-respawn registration (it must fail `UnknownProblemId`
    /// and heal, not silently evaluate against the wrong problem).
    issued: AtomicU32,
}

impl ShardSlot {
    fn is_alive(&self) -> bool {
        self.state.load(Ordering::Acquire) == SHARD_ALIVE
    }

    fn ever_died(&self) -> bool {
        self.died_once.load(Ordering::Acquire)
    }

    /// Typed error for a reply channel that died without an answer.
    /// Shards with any death history map to the healable `ShardDown` (an
    /// instantaneous liveness read can miss a death that a completed
    /// respawn already papered over); shards that never died report the
    /// genuine-bug `ReplyDropped`.  Shared by `register` and `eval` so
    /// their error typing cannot diverge.
    fn reply_dropped_error(&self, shard: usize) -> ServiceError {
        if self.is_alive() && !self.ever_died() {
            ServiceError::ReplyDropped
        } else {
            ServiceError::ShardDown { shard }
        }
    }

    /// Clone the current sender (never hold the slot lock across a
    /// blocking channel send).
    fn sender(&self) -> mpsc::SyncSender<Msg> {
        lock_recover(&self.tx).clone()
    }
}

/// State shared by every pool handle AND (weakly) by the workers: the
/// slots, and the backend factory retained for respawns.
struct PoolShared {
    token: u32,
    policy: CoalescePolicy,
    clock: Arc<dyn Clock>,
    respawn: bool,
    metrics: Arc<Metrics>,
    factory: Box<dyn Fn(usize) -> Result<Box<dyn Backend>> + Send + Sync>,
    slots: Vec<ShardSlot>,
    /// Emulated artifact width of a native pool (set after spawn by the
    /// native constructors; 0 when width is per-bucket, i.e. XLA pools or
    /// custom test backends).  Client-side hint only — workers never read
    /// it — used by engines to size pipelined micro-batches.
    width_hint: AtomicUsize,
}

/// Client handle to a pool of shard workers (cheap to clone; dropping all
/// clones shuts the workers down after they drain pending work — workers
/// only hold the shared state weakly, so they cannot keep their own
/// senders alive).
#[derive(Clone)]
pub struct EvalShardPool {
    token: u32,
    shared: Arc<PoolShared>,
    pub metrics: Arc<Metrics>,
}

impl EvalShardPool {
    /// Spawn a native-backed pool (tests / no-artifact runs).  `width`
    /// emulates the artifact population width for batching.
    pub fn spawn_native(width: usize, opts: &PoolOptions) -> EvalShardPool {
        Self::spawn_native_with_clock(width, opts, Arc::new(SystemClock::new()))
    }

    /// [`Self::spawn_native`] with an injected [`Clock`] — the seam the
    /// deterministic timing tests drive with a
    /// [`ManualClock`](crate::util::clock::ManualClock).
    pub fn spawn_native_with_clock(
        width: usize,
        opts: &PoolOptions,
        clock: Arc<dyn Clock>,
    ) -> EvalShardPool {
        let workers = opts.native_workers();
        let engine_threads = if opts.engine_threads == 0 {
            (pool::default_threads() / workers).max(1)
        } else {
            opts.engine_threads
        };
        let pool =
            Self::spawn_with_clock(workers, opts.policy(), opts.respawn, clock, move |_shard| {
                Ok(Box::new(NativeBackend {
                    engine: NativeEngine::with_threads(engine_threads),
                    width,
                }) as Box<dyn Backend>)
            })
            // axdt-lint: allow(panic-free-workers): runs on the client thread at pool construction, not in a worker; the factory above is the only one and returns Ok unconditionally
            .expect("native backend construction cannot fail");
        // Client-side micro-batch sizing hint (every registration on a
        // native pool batches at this width); XLA pools leave it 0 and
        // clients size from the routed bucket instead.
        pool.shared.width_hint.store(width, Ordering::Relaxed);
        pool
    }

    /// Spawn a PJRT-backed pool (artifacts required); each worker builds
    /// its own `XlaRuntime`/client, which is what lets the pool scale past
    /// a single PJRT client.
    #[cfg(feature = "xla")]
    pub fn spawn_xla(
        artifact_dir: impl AsRef<std::path::Path>,
        opts: &PoolOptions,
    ) -> Result<EvalShardPool> {
        let dir = artifact_dir.as_ref().to_path_buf();
        Self::spawn(opts.xla_workers(), opts.policy(), opts.respawn, move |_shard| {
            Ok(Box::new(XlaBackend { runtime: XlaRuntime::new(dir.clone())? })
                as Box<dyn Backend>)
        })
    }

    pub(crate) fn spawn(
        workers: usize,
        policy: CoalescePolicy,
        respawn: bool,
        factory: impl Fn(usize) -> Result<Box<dyn Backend>> + Send + Sync + 'static,
    ) -> Result<EvalShardPool> {
        Self::spawn_with_clock(workers, policy, respawn, Arc::new(SystemClock::new()), factory)
    }

    pub(crate) fn spawn_with_clock(
        workers: usize,
        policy: CoalescePolicy,
        respawn: bool,
        clock: Arc<dyn Clock>,
        factory: impl Fn(usize) -> Result<Box<dyn Backend>> + Send + Sync + 'static,
    ) -> Result<EvalShardPool> {
        let workers = workers.max(1);
        let metrics = Arc::new(Metrics::with_shards(workers));
        let token = NEXT_POOL_TOKEN.fetch_add(1, Ordering::Relaxed);
        let mut slots = Vec::with_capacity(workers);
        let mut rxs = Vec::with_capacity(workers);
        for _ in 0..workers {
            let (tx, rx) = mpsc::sync_channel::<Msg>(QUEUE_DEPTH);
            slots.push(ShardSlot {
                tx: Mutex::new(tx),
                state: AtomicU8::new(SHARD_ALIVE),
                died_once: AtomicBool::new(false),
                respawn_attempted: AtomicBool::new(false),
                issued: AtomicU32::new(0),
            });
            rxs.push(rx);
        }
        let shared = Arc::new(PoolShared {
            token,
            policy,
            clock: Arc::clone(&clock),
            respawn,
            metrics: Arc::clone(&metrics),
            factory: Box::new(factory),
            slots,
            width_hint: AtomicUsize::new(0),
        });
        // Seed the per-shard window gauge so `render()` shows the
        // effective window before the first flush decision: the fixed
        // window, or the adaptive cap until an EWMA exists.
        let initial_window_ns = match policy {
            CoalescePolicy::Off => 0,
            CoalescePolicy::Fixed(w) => w.as_nanos() as u64,
            CoalescePolicy::Adaptive { max } => max.as_nanos() as u64,
        };
        for shard in 0..workers {
            if initial_window_ns > 0 {
                metrics.set_window(shard, initial_window_ns, None);
            }
            // Virtual-time advances must wake workers that are blocked on
            // an armed deadline.  The waker holds the pool only weakly and
            // re-reads the slot's sender each firing, so it survives
            // respawns and goes inert once the pool is dropped.
            let weak = Arc::downgrade(&shared);
            clock.register_waker(Box::new(move || {
                if let Some(shared) = weak.upgrade() {
                    let tx = lock_recover(&shared.slots[shard].tx).clone();
                    let _ = tx.try_send(Msg::Tick);
                }
            }));
        }
        let inits: Vec<_> = rxs
            .into_iter()
            .enumerate()
            .map(|(shard, rx)| spawn_worker(Arc::downgrade(&shared), shard, rx))
            .collect();
        for init_rx in inits {
            init_rx
                .recv()
                .map_err(|_| anyhow!("eval shard worker died during init"))??;
        }
        Ok(EvalShardPool { token, shared, metrics })
    }

    /// Number of shard workers (live or dead).
    pub fn workers(&self) -> usize {
        self.shared.slots.len()
    }

    /// The pool's injected [`Clock`].  Drivers stamp their trace spans
    /// through this same seam so shard events and driver spans share one
    /// timeline (and stay deterministic under a `ManualClock`).
    pub fn clock(&self) -> Arc<dyn Clock> {
        Arc::clone(&self.shared.clock)
    }

    /// Number of shard workers currently serving.
    pub fn live_workers(&self) -> usize {
        self.shared.slots.iter().filter(|s| s.is_alive()).count()
    }

    /// Whether `shard`'s worker is serving: false once its backend has
    /// panicked, true again after a successful `--respawn-shards` respawn.
    pub fn shard_alive(&self, shard: usize) -> bool {
        self.shared.slots.get(shard).is_some_and(|s| s.is_alive())
    }

    /// Home shard for a problem name: FNV-1a mod worker count, ignoring
    /// liveness.  Stable within a pool by construction (the hash is
    /// pinned, not `DefaultHasher`), so re-registration lands on the
    /// worker that already holds the problem's device buffers.
    /// [`Self::register`] falls back to a live shard when the home worker
    /// is dead.
    pub fn shard_for(&self, name: &str) -> usize {
        (fnv1a(name.as_bytes()) % self.shared.slots.len() as u64) as usize
    }

    /// Routing with failover: the home shard when it is alive, else the
    /// rendezvous-best live shard.  Survivors' routes never move (their
    /// home shard is still alive), and every client deterministically
    /// picks the same fallback for a given dead-set.  Delegates to the
    /// pure [`rendezvous_route`] so the routing tests exercise the exact
    /// decision procedure the pool runs.
    fn route_live(&self, name: &str) -> Result<usize, ServiceError> {
        let alive: Vec<bool> =
            self.shared.slots.iter().map(|s| s.is_alive()).collect();
        rendezvous_route(name, &alive).ok_or(ServiceError::ServiceDown)
    }

    /// Register a problem on its shard: routes it to a bucket and uploads
    /// statics on the owning worker.  A dead home shard re-routes to the
    /// rendezvous-best live shard; a shard dying *between* routing and the
    /// reply is retried against the survivors (bounded by the worker
    /// count — each retry requires a fresh death).  A send failure with
    /// the slot alive is retried too: it is either the respawn swapping
    /// the sender mid-send (the retry reaches the new worker) or a real
    /// shutdown (every retry fails the same way and `ServiceDown` stands).
    pub fn register(
        &self,
        problem: Arc<Problem>,
    ) -> Result<(ProblemId, Option<Bucket>), ServiceError> {
        let mut last = ServiceError::ServiceDown;
        for _attempt in 0..self.shared.slots.len() + 1 {
            let shard = self.route_live(&problem.name)?;
            let slot = &self.shared.slots[shard];
            let (reply_tx, reply_rx) = mpsc::sync_channel(1);
            let sent = slot
                .sender()
                .send(Msg::Register { problem: Arc::clone(&problem), reply: reply_tx });
            let res = match sent {
                Err(_) if slot.is_alive() => Err(ServiceError::ServiceDown),
                Err(_) => Err(ServiceError::ShardDown { shard }),
                Ok(()) => match reply_rx.recv() {
                    Ok(r) => r,
                    Err(_) => Err(slot.reply_dropped_error(shard)),
                },
            };
            match res {
                Err(e @ (ServiceError::ShardDown { .. } | ServiceError::ServiceDown)) => {
                    last = e;
                }
                other => return other,
            }
        }
        Err(last)
    }

    /// Phase one of the two-phase eval: enqueue `batch` on its problem's
    /// shard and return a [`Ticket`] without waiting for the result.
    /// Submitting micro-batches for several problems before collecting any
    /// keeps every shard busy from one driver thread (the blocking
    /// [`Self::eval`] is literally `wait(submit(..))`).  Synchronously
    /// detectable failures (foreign/unknown id, dead shard, shutdown)
    /// surface here; execution failures surface at [`Self::wait`].  The
    /// send only blocks when the shard's bounded queue is full — natural
    /// backpressure, drained independently by the worker.
    pub fn submit(
        &self,
        id: ProblemId,
        mut batch: Vec<TreeApprox>,
    ) -> Result<Ticket, ServiceError> {
        if batch.is_empty() {
            return Ok(Ticket { repr: TicketRepr::Empty });
        }
        if id.service != self.token {
            return Err(ServiceError::ForeignProblemId {
                id,
                registered: self.metrics.problems.load(Ordering::Relaxed) as usize,
            });
        }
        // A forged/stale id naming a shard this pool never had is rejected
        // up front — clamping it onto the last shard would mis-charge that
        // shard's queue-depth gauge and evaluate on a worker that cannot
        // know the problem.
        let shard = id.shard as usize;
        if shard >= self.shared.slots.len() {
            return Err(ServiceError::UnknownProblemId { id, registered: 0 });
        }
        let slot = &self.shared.slots[shard];
        let width = batch.len();
        // Two attempts: a send can race a respawn swapping the sender (the
        // old channel closes while the slot is already alive again).
        for _attempt in 0..2 {
            if !slot.is_alive() {
                return Err(ServiceError::ShardDown { shard });
            }
            let (reply_tx, reply_rx) = mpsc::sync_channel(1);
            self.metrics.shard_enqueued(shard);
            // The `Submitted` record takes its sequence number BEFORE the
            // send makes the message visible to the worker — otherwise the
            // worker's `Enqueued` could win the seq race and the journal
            // would not be bit-reproducible under a `ManualClock`.  (A
            // send that then fails leaves the record standing as a visible
            // submit attempt against a dying shard.)
            let submitted_ns = self.shared.clock.now_ns();
            if self.metrics.trace.enabled() {
                self.metrics.trace.record(
                    submitted_ns,
                    TraceKind::Submitted {
                        shard: shard as u32,
                        problem: id.index,
                        width: width as u32,
                    },
                );
            }
            match slot.sender().send(Msg::Eval { id, batch, reply: reply_tx }) {
                Ok(()) => {
                    self.metrics.ticket_submitted(width as u64);
                    return Ok(Ticket {
                        repr: TicketRepr::Pending {
                            shard,
                            rx: reply_rx,
                            submitted_ns,
                            gauge: TicketGauge(Arc::clone(&self.metrics)),
                        },
                    });
                }
                Err(mpsc::SendError(msg)) => {
                    self.metrics.shard_dequeued(shard);
                    let Msg::Eval { batch: b, .. } = msg else { unreachable!() };
                    batch = b;
                }
            }
        }
        Err(if slot.is_alive() {
            ServiceError::ServiceDown
        } else {
            ServiceError::ShardDown { shard }
        })
    }

    /// Phase two: block until `ticket`'s batch has executed and return its
    /// accuracies.  Tickets may be collected in any order — results are
    /// matched by reply channel, not arrival order.  A shard dying with
    /// the ticket in flight answers with the healable
    /// [`ServiceError::ShardDown`].
    pub fn wait(&self, ticket: Ticket) -> Result<Vec<f64>, ServiceError> {
        match ticket.repr {
            TicketRepr::Empty => Ok(Vec::new()),
            TicketRepr::Pending { shard, rx, submitted_ns, gauge } => {
                let res = match rx.recv() {
                    Ok(res) => res,
                    Err(_) => Err(self.shared.slots[shard].reply_dropped_error(shard)),
                };
                let now = self.shared.clock.now_ns();
                let latency_ns = now.saturating_sub(submitted_ns);
                self.metrics.ticket_collected(latency_ns);
                if self.metrics.trace.enabled() {
                    self.metrics.trace.record(
                        now,
                        TraceKind::Collected { shard: shard as u32, latency_ns },
                    );
                }
                drop(gauge);
                res
            }
        }
    }

    /// Evaluate a batch (blocking until the owning shard replies): exactly
    /// [`Self::wait`] of [`Self::submit`].  A dead shard answers
    /// immediately with [`ServiceError::ShardDown`] — a stale-id error, so
    /// engine clients heal by re-registering (which routes to a live
    /// shard).
    pub fn eval(&self, id: ProblemId, batch: Vec<TreeApprox>) -> Result<Vec<f64>, ServiceError> {
        self.wait(self.submit(id, batch)?)
    }

    /// Emulated artifact width of a native pool — the batching unit every
    /// registration on it executes at.  0 when width is per-bucket (XLA
    /// pools) or the pool was spawned over a custom test backend.
    pub fn width_hint(&self) -> usize {
        self.shared.width_hint.load(Ordering::Relaxed)
    }

    /// Ask every worker to drain pending work and exit (idempotent;
    /// dropping all handles also works).
    pub fn shutdown(&self) {
        for slot in &self.shared.slots {
            let _ = slot.sender().send(Msg::Shutdown);
        }
    }
}

/// FNV-1a, pinned (routing must never change across Rust releases).
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0100_0000_01b3);
    }
    h
}

/// Pinned rendezvous score for (problem, shard): FNV-1a over the name
/// bytes followed by the shard index (little-endian u64).  Only consulted
/// for failover fallback, so the primary route stays the plain
/// `fnv1a % N` the seed pool shipped with.  Public so the randomized
/// routing tests can check the argmax property independently.
pub fn rendezvous_score(name: &str, shard: usize) -> u64 {
    let mut h = fnv1a(name.as_bytes());
    for b in (shard as u64).to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0100_0000_01b3);
    }
    h
}

/// The pool's routing decision as a pure function of `(name, liveness)`:
/// the pinned home shard (`FNV-1a(name) % N`) while it is alive, else the
/// rendezvous-best live shard, else `None` (every shard dead).
///
/// [`EvalShardPool::register`] routes through exactly this function, which
/// gives it two properties the failover suites pin:
///
/// * **survivor stability** — a name whose current route is alive keeps
///   that route under any additional deaths (the home fast-path is
///   unaffected, and a rendezvous argmax cannot move to a shard it
///   already beat);
/// * **determinism** — every client picks the same fallback for a given
///   dead-set, with no state beyond the liveness vector.
pub fn rendezvous_route(name: &str, alive: &[bool]) -> Option<usize> {
    if alive.is_empty() {
        return None;
    }
    let home = (fnv1a(name.as_bytes()) % alive.len() as u64) as usize;
    if alive[home] {
        return Some(home);
    }
    let mut best: Option<(u64, usize)> = None;
    for (shard, &ok) in alive.iter().enumerate() {
        if !ok {
            continue;
        }
        let score = rendezvous_score(name, shard);
        let better = match best {
            None => true,
            Some((bs, _)) => score > bs,
        };
        if better {
            best = Some((score, shard));
        }
    }
    best.map(|(_, shard)| shard)
}

// ---- worker side (coalescer) ----------------------------------------------

/// One client eval request being assembled across >= 1 executions.
struct RequestState {
    reply: mpsc::SyncSender<Result<Vec<f64>, ServiceError>>,
    results: Vec<f64>,
    remaining: usize,
}

/// A request's chromosomes queued on its problem (consumed from `next`).
struct QueuedSlice {
    req: Rc<RefCell<RequestState>>,
    items: Vec<TreeApprox>,
    next: usize,
}

/// Per-problem coalescer state.  Registrations of the same `Arc<Problem>`
/// share ONE group (pointer equality), which is what lets per-driver
/// registrations coalesce with each other; `members` counts them for the
/// adaptive all-drivers early flush.  The group keeps the first
/// registration's backend state — re-registering the same problem never
/// re-uploads statics.
struct Group {
    problem: Arc<Problem>,
    reg: RegisteredProblem,
    /// `ProblemId::index` of the group's first registration — the label
    /// worker-side trace events carry, so a flush correlates with the
    /// submits that fed it (re-registrations share the group and keep
    /// the founding index).
    trace_problem: u32,
    /// Registrations pointing at this group (the driver count, under the
    /// driver-per-registration convention adaptive mode assumes).  Never
    /// decremented — there is no deregistration — so a registration whose
    /// holder stops evaluating (finished driver, heal re-register) makes
    /// the all-drivers early flush unreachable for this problem; the
    /// damage is bounded by the adaptive cap, since the EWMA deadline
    /// still flushes every batch within `coalesce_window_max_us`.
    members: usize,
    /// FIFO of queued request slices (each entry = one client request
    /// with unconsumed chromosomes).
    queue: VecDeque<QueuedSlice>,
    /// Chromosomes queued across `queue` (mirrored by the per-shard
    /// `coalescing` gauge).
    pending: usize,
    /// Armed flush deadline in clock-ns (set when the oldest pending
    /// sub-width work arrived).
    deadline: Option<u64>,
    /// Clock-ns of the last request arrival (adaptive mode only).
    last_arrival_ns: Option<u64>,
    /// EWMA of request inter-arrival times in ns (adaptive mode only).
    ewma_ia_ns: Option<f64>,
}

impl Group {
    fn new(problem: Arc<Problem>, reg: RegisteredProblem, trace_problem: u32) -> Group {
        Group {
            problem,
            reg,
            trace_problem,
            members: 1,
            queue: VecDeque::new(),
            pending: 0,
            deadline: None,
            last_arrival_ns: None,
            ewma_ia_ns: None,
        }
    }
}

/// Everything a worker needs besides its backend and receiver.  The pool
/// state is held weakly: worker threads must never keep their own senders
/// alive once every client handle is gone (drop-based shutdown).
struct WorkerCtx {
    token: u32,
    shard: u32,
    /// First `ProblemId` index this worker incarnation issues (the
    /// shard's all-time registration count at spawn).  Ids below it were
    /// issued by a dead predecessor and must read as unknown.
    index_base: u32,
    policy: CoalescePolicy,
    /// Injected time: every deadline decision reads this, never
    /// `Instant::now()`.
    clock: Arc<dyn Clock>,
    metrics: Arc<Metrics>,
    shared: Weak<PoolShared>,
}

/// Spawn one shard worker thread; returns the receiver for its one-shot
/// init result (backend construction happens inside the thread).  Used by
/// the initial pool spawn and by the respawn path.
fn spawn_worker(
    shared: Weak<PoolShared>,
    shard: usize,
    rx: mpsc::Receiver<Msg>,
) -> mpsc::Receiver<Result<()>> {
    let (init_tx, init_rx) = mpsc::sync_channel::<Result<()>>(1);
    let err_tx = init_tx.clone();
    let spawned = std::thread::Builder::new()
        .name(format!("axdt-eval-shard-{shard}"))
        .spawn(move || {
            // Construct the backend while briefly holding a strong ref,
            // then drop it so the loop below runs with only the Weak.
            let started = match shared.upgrade() {
                Some(strong) => match (strong.factory)(shard) {
                    Ok(backend) => {
                        let ctx = WorkerCtx {
                            token: strong.token,
                            shard: shard as u32,
                            index_base: strong.slots[shard].issued.load(Ordering::Acquire),
                            policy: strong.policy,
                            clock: Arc::clone(&strong.clock),
                            metrics: Arc::clone(&strong.metrics),
                            shared: Weak::clone(&shared),
                        };
                        let _ = init_tx.send(Ok(()));
                        Some((backend, ctx))
                    }
                    Err(e) => {
                        let _ = init_tx.send(Err(e));
                        None
                    }
                },
                // Pool handles already gone: nothing to serve.
                None => None,
            };
            if let Some((backend, ctx)) = started {
                worker_loop(backend, rx, ctx);
            }
        });
    if let Err(e) = spawned {
        // The OS refused the thread (resource exhaustion).  Route it
        // through the init channel like a backend-factory failure: the
        // initial spawn surfaces it as a typed pool-construction error,
        // and a respawn logs it and leaves the shard dead.
        let _ = err_tx.send(Err(anyhow!("spawning eval shard worker {shard}: {e}")));
    }
    init_rx
}

/// Flip the shard dead — BEFORE any `ShardDown` reply goes out, so a
/// client that reacts to the error by re-registering already sees the
/// death and routes to a survivor.
fn mark_shard_dead(ctx: &WorkerCtx) {
    if let Some(shared) = ctx.shared.upgrade() {
        let slot = &shared.slots[ctx.shard as usize];
        slot.died_once.store(true, Ordering::Release);
        slot.state.store(SHARD_DEAD, Ordering::Release);
    }
    ctx.metrics.shard_died(ctx.shard as usize);
    if ctx.metrics.trace.enabled() {
        ctx.metrics
            .trace
            .record(ctx.clock.now_ns(), TraceKind::ShardDown { shard: ctx.shard });
    }
}

/// Update a group's inter-arrival EWMA for a request arriving at `now`
/// (clock-ns) and return the flush window (ns) the policy prescribes.
/// Publishes the per-shard window/EWMA gauges so `Metrics::render()`
/// shows what the controller chose.
fn arrival_window_ns(group: &mut Group, now: u64, ctx: &WorkerCtx) -> u64 {
    match ctx.policy {
        CoalescePolicy::Off => 0,
        CoalescePolicy::Fixed(w) => w.as_nanos() as u64,
        CoalescePolicy::Adaptive { max } => {
            if let Some(prev) = group.last_arrival_ns {
                let sample = now.saturating_sub(prev) as f64;
                group.ewma_ia_ns = Some(match group.ewma_ia_ns {
                    None => sample,
                    Some(e) => {
                        ADAPTIVE_EWMA_ALPHA * sample + (1.0 - ADAPTIVE_EWMA_ALPHA) * e
                    }
                });
            }
            group.last_arrival_ns = Some(now);
            let max_ns = max.as_nanos() as u64;
            let window = match group.ewma_ia_ns {
                // No estimate yet: wait the cap (conservative merging;
                // the all-drivers early flush bounds the latency cost).
                None => max_ns,
                Some(e) => ((ADAPTIVE_WINDOW_IA_MULT * e) as u64).min(max_ns),
            };
            ctx.metrics.set_window(
                ctx.shard as usize,
                window,
                group.ewma_ia_ns.map(|e| e as u64),
            );
            window
        }
    }
}

fn worker_loop(mut backend: Box<dyn Backend>, rx: mpsc::Receiver<Msg>, ctx: WorkerCtx) {
    // Registration index -> coalescer group.  Re-registrations of the
    // same `Arc<Problem>` map to one group (and skip the backend
    // re-register), so per-driver registrations share a queue.
    let mut regs: Vec<usize> = Vec::new();
    let mut groups: Vec<Group> = Vec::new();
    loop {
        // Wait for work, bounded by the earliest armed coalescer deadline.
        let next_deadline = groups.iter().filter_map(|g| g.deadline).min();
        let msg = match next_deadline {
            // Invariant: no deadline => nothing pending, so a disconnect
            // here cannot strand queued work.
            None => match rx.recv() {
                Ok(m) => m,
                Err(_) => return,
            },
            Some(deadline) => {
                let now = ctx.clock.now_ns();
                if deadline <= now {
                    if !flush_expired(backend.as_mut(), &mut groups, &ctx) {
                        return die(rx, &mut groups, &ctx, RespawnPolicy::IfConfigured);
                    }
                    continue;
                }
                // The clock bounds how long we may block before
                // re-checking: remaining real time for `SystemClock`, the
                // safety-net hour for `ManualClock` (whose advances nudge
                // us with `Msg::Tick` instead).
                match rx.recv_timeout(ctx.clock.wait_budget(deadline)) {
                    Ok(m) => m,
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        if !flush_expired(backend.as_mut(), &mut groups, &ctx) {
                            return die(rx, &mut groups, &ctx, RespawnPolicy::IfConfigured);
                        }
                        continue;
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        // Every pool handle is gone: no respawn either.
                        if !flush_all(backend.as_mut(), &mut groups, &ctx) {
                            return die(rx, &mut groups, &ctx, RespawnPolicy::Never);
                        }
                        return;
                    }
                }
            }
        };
        match msg {
            // Virtual time advanced: the loop head re-reads the clock and
            // flushes whatever is now expired.
            Msg::Tick => continue,
            Msg::Shutdown => {
                // In-flight jobs still get their replies: drain the
                // coalescer before exiting.  A panic during THIS drain
                // still answers everyone with `ShardDown`, but must not
                // respawn a worker for a pool that was told to stop.
                if !flush_all(backend.as_mut(), &mut groups, &ctx) {
                    return die(rx, &mut groups, &ctx, RespawnPolicy::Never);
                }
                return;
            }
            Msg::Register { problem, reply } => {
                let group = match groups
                    .iter()
                    .position(|g| Arc::ptr_eq(&g.problem, &problem))
                {
                    // Same problem, new driver: reuse the backend state
                    // (no duplicate statics upload) and bump the member
                    // count the all-drivers early flush consults.
                    Some(g) => {
                        groups[g].members += 1;
                        g
                    }
                    None => match catch_unwind(AssertUnwindSafe(|| backend.register(&problem)))
                    {
                        Ok(Ok(reg)) => {
                            // Native registrations pay the one-time bit-plane
                            // transpose here, off the eval hot path, timed on
                            // the injected clock.  Idempotent across shards:
                            // whoever registers the Arc first builds, the
                            // rest see `planes_built()` and skip.
                            if matches!(reg, RegisteredProblem::Native { .. })
                                && !problem.planes_built()
                            {
                                let t0 = ctx.clock.now_ns();
                                let _ = problem.planes();
                                ctx.metrics.record_plane_build(
                                    ctx.clock.now_ns().saturating_sub(t0),
                                );
                            }
                            groups.push(Group::new(
                                problem,
                                reg,
                                ctx.index_base + regs.len() as u32,
                            ));
                            groups.len() - 1
                        }
                        Ok(Err(e)) => {
                            let _ = reply.send(Err(ServiceError::Backend {
                                detail: format!("{e:#}"),
                            }));
                            continue;
                        }
                        Err(_) => {
                            // Backend panicked during registration: the
                            // worker cannot continue on a possibly-broken
                            // backend.
                            mark_shard_dead(&ctx);
                            let _ = reply.send(Err(ServiceError::ShardDown {
                                shard: ctx.shard as usize,
                            }));
                            ctx.metrics.record_stranded(1);
                            return die(rx, &mut groups, &ctx, RespawnPolicy::IfConfigured);
                        }
                    },
                };
                let index = ctx.index_base + regs.len() as u32;
                let id = ProblemId { service: ctx.token, shard: ctx.shard, index };
                let bucket = groups[group].reg.bucket().cloned();
                regs.push(group);
                // Advance the shard's all-time counter so a future
                // respawn starts past this id (no aliasing).
                if let Some(shared) = ctx.shared.upgrade() {
                    shared.slots[ctx.shard as usize]
                        .issued
                        .store(index + 1, Ordering::Release);
                }
                ctx.metrics.problems.fetch_add(1, Ordering::Relaxed);
                let _ = reply.send(Ok((id, bucket)));
            }
            Msg::Eval { id, batch, reply } => {
                ctx.metrics.shard_dequeued(ctx.shard as usize);
                // A stale or foreign id must not kill the worker thread
                // (which would wedge every other client) NOR silently
                // evaluate against the wrong problem — including ids the
                // shard's PREVIOUS incarnation issued: indices restart
                // behind `index_base` after a respawn, so those read as
                // unknown here and heal via re-registration.
                let ridx = match id.index.checked_sub(ctx.index_base) {
                    Some(i)
                        if id.service == ctx.token
                            && id.shard == ctx.shard
                            && (i as usize) < regs.len() =>
                    {
                        i as usize
                    }
                    _ => {
                        let _ = reply.send(Err(ServiceError::UnknownProblemId {
                            id,
                            registered: regs.len(),
                        }));
                        continue;
                    }
                };
                if batch.is_empty() {
                    let _ = reply.send(Ok(Vec::new()));
                    continue;
                }
                let g = regs[ridx];
                // Arrival bookkeeping before queuing: the adaptive
                // controller sees every request, including ones a flush
                // below dispatches immediately.
                let now = ctx.clock.now_ns();
                let window_ns = arrival_window_ns(&mut groups[g], now, &ctx);
                let n = batch.len();
                let req = Rc::new(RefCell::new(RequestState {
                    reply,
                    results: Vec::with_capacity(n),
                    remaining: n,
                }));
                groups[g].pending += n;
                groups[g].queue.push_back(QueuedSlice { req, items: batch, next: 0 });
                ctx.metrics.coalescing_add(ctx.shard as usize, n as u64);
                if ctx.metrics.trace.enabled() {
                    ctx.metrics
                        .trace
                        .record(now, TraceKind::Enqueued { shard: ctx.shard, problem: id.index });
                    ctx.metrics.trace.record(
                        now,
                        TraceKind::Coalesced {
                            shard: ctx.shard,
                            problem: id.index,
                            pending: groups[g].pending as u32,
                        },
                    );
                }
                let width = groups[g].reg.width().max(1);
                // Deadlines arm from the arrival timestamp — but a
                // synchronous width-full flush below can consume real
                // time, and an overflow tail still deserves its full
                // window of merging opportunity, so the anchor is
                // refreshed after each flush.  (Without a flush the
                // arrival anchor stands, which is what keeps the armed
                // deadline deterministic for virtual-clock tests.)
                let mut arm_now = now;
                while groups[g].pending >= width {
                    if !execute_chunk(
                        backend.as_mut(),
                        &mut groups[g],
                        width,
                        FlushKind::Full,
                        &ctx,
                    ) {
                        return die(rx, &mut groups, &ctx, RespawnPolicy::IfConfigured);
                    }
                    arm_now = ctx.clock.now_ns();
                }
                match ctx.policy {
                    CoalescePolicy::Off => {
                        // Coalescing off: dispatch the tail immediately.
                        let take = groups[g].pending;
                        if take > 0
                            && !execute_chunk(
                                backend.as_mut(),
                                &mut groups[g],
                                take,
                                FlushKind::Immediate,
                                &ctx,
                            )
                        {
                            return die(rx, &mut groups, &ctx, RespawnPolicy::IfConfigured);
                        }
                    }
                    CoalescePolicy::Fixed(_) => {
                        if groups[g].pending > 0 && groups[g].deadline.is_none() {
                            groups[g].deadline = Some(arm_now + window_ns);
                        }
                    }
                    CoalescePolicy::Adaptive { .. } => {
                        if groups[g].pending > 0 && groups[g].queue.len() >= groups[g].members
                        {
                            // Every registered driver has a request
                            // queued.  Under the blocking-eval convention
                            // nothing more can arrive, so waiting out the
                            // window buys no merging.  (A TICKETED driver
                            // pipelining several sub-width submits per
                            // registration breaks that assumption and
                            // gets per-submit dispatch here — prefer
                            // `fixed` when combining `--coalesce adaptive`
                            // with a small explicit `--microbatch`.)
                            let take = groups[g].pending;
                            if !execute_chunk(
                                backend.as_mut(),
                                &mut groups[g],
                                take,
                                FlushKind::AllDrivers,
                                &ctx,
                            ) {
                                return die(rx, &mut groups, &ctx, RespawnPolicy::IfConfigured);
                            }
                        } else if groups[g].pending > 0 && groups[g].deadline.is_none() {
                            groups[g].deadline = Some(arm_now + window_ns);
                        }
                    }
                }
            }
        }
    }
}

/// Whether a dying worker may spawn its one replacement.  `Never` is for
/// deaths during a shutdown/disconnect drain: the pool is stopping, and a
/// replacement would idle forever waiting for work that cannot come.
#[derive(Clone, Copy, PartialEq, Eq)]
enum RespawnPolicy {
    IfConfigured,
    Never,
}

/// Terminal path of a worker whose backend panicked: answer every request
/// still queued in the coalescer or sitting in the channel with a typed
/// [`ServiceError::ShardDown`] (never a silently dropped reply channel),
/// return the queue-depth gauge to zero, and — when the pool opted in and
/// `policy` allows — spawn ONE replacement worker from the retained
/// factory.  A respawned worker starts with no registered problems and
/// issues ids from the shard's all-time `issued` counter; stale ids heal
/// through the clients' re-register path.
fn die(
    rx: mpsc::Receiver<Msg>,
    groups: &mut [Group],
    ctx: &WorkerCtx,
    policy: RespawnPolicy,
) {
    let shard = ctx.shard as usize;
    let down = ServiceError::ShardDown { shard };
    let mut stranded = 0u64;
    for g in groups.iter_mut() {
        for slice in g.queue.drain(..) {
            let mut r = slice.req.borrow_mut();
            // Contributors to the panicked chunk were already answered
            // (remaining forced to 0); everyone else is stranded here.
            if r.remaining > 0 {
                r.remaining = 0;
                let _ = r.reply.send(Err(down.clone()));
                stranded += 1;
            }
        }
        g.pending = 0;
        g.deadline = None;
    }
    ctx.metrics.coalescing_reset(shard);
    let mut saw_shutdown = false;
    while let Ok(msg) = rx.try_recv() {
        match msg {
            Msg::Eval { reply, .. } => {
                ctx.metrics.shard_dequeued(shard);
                let _ = reply.send(Err(down.clone()));
                stranded += 1;
            }
            Msg::Register { reply, .. } => {
                let _ = reply.send(Err(down.clone()));
                stranded += 1;
            }
            // Clock nudges carry no reply channel; nothing to answer.
            Msg::Tick => {}
            // A Shutdown queued behind the panicking job means the pool
            // was already told to stop — honoring it here prevents a
            // replacement worker that would never receive it and would
            // idle until the last handle drops.
            Msg::Shutdown => saw_shutdown = true,
        }
    }
    ctx.metrics.record_stranded(stranded);
    // Close the channel BEFORE any respawn revives the shard: a racing
    // sender then fails while the slot still reads dead, which the facade
    // maps to `ShardDown` rather than a bogus `ServiceDown`.
    drop(rx);
    if policy == RespawnPolicy::Never || saw_shutdown {
        return;
    }
    let Some(shared) = ctx.shared.upgrade() else { return };
    let slot = &shared.slots[shard];
    if !shared.respawn || slot.respawn_attempted.swap(true, Ordering::AcqRel) {
        return;
    }
    let (tx, new_rx) = mpsc::sync_channel::<Msg>(QUEUE_DEPTH);
    let init_rx = spawn_worker(Weak::clone(&ctx.shared), shard, new_rx);
    match init_rx.recv() {
        Ok(Ok(())) => {
            // Install the sender before flipping alive: anyone who sees
            // the shard live must reach the NEW worker.
            *lock_recover(&slot.tx) = tx;
            slot.state.store(SHARD_ALIVE, Ordering::Release);
            ctx.metrics.shard_respawned(shard);
            if ctx.metrics.trace.enabled() {
                ctx.metrics
                    .trace
                    .record(ctx.clock.now_ns(), TraceKind::Respawn { shard: ctx.shard });
            }
        }
        Ok(Err(e)) => {
            eprintln!("[axdt] shard {shard} respawn failed: {e:#} (shard stays dead)");
        }
        Err(_) => {
            eprintln!(
                "[axdt] shard {shard} respawn worker died during init (shard stays dead)"
            );
        }
    }
}

/// Flush every problem whose coalescing deadline has expired (per the
/// injected clock).  Returns false when the backend panicked (the worker
/// must die).
fn flush_expired(backend: &mut dyn Backend, groups: &mut [Group], ctx: &WorkerCtx) -> bool {
    let now = ctx.clock.now_ns();
    for group in groups.iter_mut() {
        if group.deadline.is_some_and(|d| d <= now) {
            let take = group.pending;
            if !execute_chunk(backend, group, take, FlushKind::Deadline, ctx) {
                return false;
            }
        }
    }
    true
}

/// Drain every pending chunk (shutdown/disconnect).  Returns false when
/// the backend panicked mid-drain.
fn flush_all(backend: &mut dyn Backend, groups: &mut [Group], ctx: &WorkerCtx) -> bool {
    for group in groups.iter_mut() {
        while group.pending > 0 {
            let take = group.pending;
            if !execute_chunk(backend, group, take, FlushKind::Drain, ctx) {
                return false;
            }
        }
    }
    true
}

/// Pop up to `take` queued chromosomes for one problem, execute them as a
/// single backend batch, and distribute results (or the failure) to every
/// contributing request.  Returns false when the backend PANICKED (as
/// opposed to returning an error): contributors have been answered with
/// [`ServiceError::ShardDown`], the shard is marked dead, and the caller
/// must stop and drain via [`die`].
fn execute_chunk(
    backend: &mut dyn Backend,
    group: &mut Group,
    take: usize,
    kind: FlushKind,
    ctx: &WorkerCtx,
) -> bool {
    let shard = ctx.shard as usize;
    let metrics = &ctx.metrics;
    let width = group.reg.width().max(1);
    // Never hand the backend more than one artifact width at once, even if
    // an invariant slips (callers keep pending < width between flushes).
    let take = take.min(group.pending).min(width);
    if take == 0 {
        group.deadline = None;
        return true;
    }
    let mut chunk: Vec<TreeApprox> = Vec::with_capacity(take);
    let mut contributors: Vec<(Rc<RefCell<RequestState>>, usize)> = Vec::new();
    while chunk.len() < take {
        let Some(front) = group.queue.front_mut() else {
            // `pending` disagrees with the queue (an invariant slip):
            // batch what was actually found instead of panicking the
            // worker — a dead shard strands every client, a short batch
            // strands nobody.
            break;
        };
        let n = (take - chunk.len()).min(front.items.len() - front.next);
        chunk.extend_from_slice(&front.items[front.next..front.next + n]);
        front.next += n;
        contributors.push((Rc::clone(&front.req), n));
        if front.next == front.items.len() {
            group.queue.pop_front();
        }
    }
    let take = chunk.len();
    group.pending = group.pending.saturating_sub(take);
    metrics.coalescing_sub(shard, take as u64);
    if group.pending == 0 {
        group.deadline = None;
    }
    if take == 0 {
        group.deadline = None;
        return true;
    }
    let t0 = ctx.clock.now_ns();
    if metrics.trace.enabled() {
        metrics.trace.record(
            t0,
            TraceKind::Flushed {
                shard: ctx.shard,
                problem: group.trace_problem,
                kind: kind.label(),
                width: take as u32,
            },
        );
        metrics.trace.record(
            t0,
            TraceKind::Executing {
                shard: ctx.shard,
                problem: group.trace_problem,
                width: take as u32,
            },
        );
    }
    let outcome = catch_unwind(AssertUnwindSafe(|| {
        backend.eval(&group.reg, group.problem.as_ref(), &chunk)
    }));
    let res = match outcome {
        Ok(r) => r.and_then(|accs| {
            // A short result must fail the requests, not panic the worker
            // (which would wedge every client of this shard).
            if accs.len() == chunk.len() {
                Ok(accs)
            } else {
                Err(anyhow!(
                    "backend returned {} accuracies for a chunk of {}",
                    accs.len(),
                    chunk.len()
                ))
            }
        }),
        Err(_) => {
            // The backend panicked mid-eval and may be in an arbitrary
            // broken state: this shard is dead.  Mark it first (so healing
            // clients route elsewhere), then answer every contributor with
            // the typed error instead of dropping their reply channels.
            mark_shard_dead(ctx);
            let downed = ServiceError::ShardDown { shard };
            for (req, _) in &contributors {
                let mut r = req.borrow_mut();
                r.remaining = 0;
                let _ = r.reply.send(Err(downed.clone()));
            }
            metrics.record_stranded(contributors.len() as u64);
            return false;
        }
    };
    match res {
        Ok(accs) => {
            let done_ns = ctx.clock.now_ns();
            let dur_ns = done_ns.saturating_sub(t0);
            metrics.record_shard_execution(
                shard,
                chunk.len(),
                width.max(chunk.len()),
                dur_ns,
                contributors.len(),
                kind,
            );
            metrics.record_eval_samples(chunk.len() as u64 * group.problem.n_test as u64);
            if metrics.trace.enabled() {
                metrics.trace.record(
                    done_ns,
                    TraceKind::Executed {
                        shard: ctx.shard,
                        problem: group.trace_problem,
                        width: chunk.len() as u32,
                        dur_ns,
                    },
                );
            }
            let mut off = 0usize;
            for (req, n) in contributors {
                let mut r = req.borrow_mut();
                r.results.extend_from_slice(&accs[off..off + n]);
                off += n;
                r.remaining -= n;
                if r.remaining == 0 {
                    let results = std::mem::take(&mut r.results);
                    let _ = r.reply.send(Ok(results));
                }
            }
        }
        Err(e) => {
            // Every contributor's fitness is poisoned: fail them all and
            // purge their queued tails so they are not executed (and
            // double-replied) later.  Other requests keep their place.
            let err = ServiceError::Backend { detail: format!("{e:#}") };
            let dead: Vec<*const RefCell<RequestState>> =
                contributors.iter().map(|(r, _)| Rc::as_ptr(r)).collect();
            for (req, _) in &contributors {
                let mut r = req.borrow_mut();
                r.remaining = 0;
                let _ = r.reply.send(Err(err.clone()));
            }
            let mut purged = 0usize;
            let kept: VecDeque<QueuedSlice> = group
                .queue
                .drain(..)
                .filter(|s| {
                    if dead.contains(&Rc::as_ptr(&s.req)) {
                        purged += s.items.len() - s.next;
                        false
                    } else {
                        true
                    }
                })
                .collect();
            group.queue = kept;
            group.pending -= purged;
            metrics.coalescing_sub(shard, purged as u64);
            if group.pending == 0 {
                group.deadline = None;
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fitness::testutil::small_problem;
    use crate::hw::{AreaLut, EgtLibrary};
    use std::sync::atomic::AtomicBool;
    use std::sync::Mutex;

    /// Fake backend recording every executed chunk width.
    struct CountingBackend {
        width: usize,
        chunks: Arc<Mutex<Vec<usize>>>,
    }

    impl Backend for CountingBackend {
        fn register(&mut self, _p: &Arc<Problem>) -> Result<RegisteredProblem> {
            Ok(RegisteredProblem::Native { width: self.width })
        }
        fn eval(
            &mut self,
            _reg: &RegisteredProblem,
            _p: &Problem,
            chunk: &[TreeApprox],
        ) -> Result<Vec<f64>> {
            self.chunks.lock().unwrap().push(chunk.len());
            Ok(vec![0.25; chunk.len()])
        }
        fn name(&self) -> &'static str {
            "counting"
        }
    }

    fn seeds() -> Arc<Problem> {
        Arc::new(small_problem(&AreaLut::build(&EgtLibrary::default())))
    }

    #[test]
    fn fnv_route_is_pinned() {
        // The empty-input value is the FNV offset basis; routing stability
        // across releases is a hard requirement (device-buffer pinning).
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"seeds"), fnv1a(b"seeds"));
        assert_ne!(fnv1a(b"seeds"), fnv1a(b"cardio"));
        // The rendezvous fallback score is pinned the same way: the
        // continuation of the name hash over the shard index bytes.
        assert_eq!(rendezvous_score("seeds", 3), rendezvous_score("seeds", 3));
        assert_ne!(rendezvous_score("seeds", 0), rendezvous_score("seeds", 1));
    }

    #[test]
    fn uncoalesced_chunking_matches_legacy_split() {
        let chunks = Arc::new(Mutex::new(Vec::new()));
        let c = Arc::clone(&chunks);
        let pool = EvalShardPool::spawn(1, CoalescePolicy::Off, false, move |_| {
            Ok(Box::new(CountingBackend { width: 8, chunks: Arc::clone(&c) })
                as Box<dyn Backend>)
        })
        .unwrap();
        let p = seeds();
        let (id, bucket) = pool.register(Arc::clone(&p)).unwrap();
        assert!(bucket.is_none());
        let batch = vec![TreeApprox::exact(&p.tree); 21];
        let got = pool.eval(id, batch).unwrap();
        assert_eq!(got, vec![0.25; 21]);
        // 21 at width 8: two full chunks + the immediate tail, like the
        // seed service.
        assert_eq!(*chunks.lock().unwrap(), vec![8, 8, 5]);
        assert_eq!(pool.metrics.full_flushes.load(Ordering::Relaxed), 2);
        assert_eq!(pool.metrics.deadline_flushes.load(Ordering::Relaxed), 0);
        pool.shutdown();
    }

    /// The blocking eval is literally `wait(submit(..))`: tickets collect
    /// out of order, the in-flight gauges track them, an empty batch never
    /// issues a ticket, and an abandoned ticket releases its gauge on
    /// drop.
    #[test]
    fn submit_wait_out_of_order_and_ticket_gauges() {
        let chunks = Arc::new(Mutex::new(Vec::new()));
        let c = Arc::clone(&chunks);
        let pool = EvalShardPool::spawn(1, CoalescePolicy::Off, false, move |_| {
            Ok(Box::new(CountingBackend { width: 8, chunks: Arc::clone(&c) })
                as Box<dyn Backend>)
        })
        .unwrap();
        let p = seeds();
        let (id, _) = pool.register(Arc::clone(&p)).unwrap();
        let t1 = pool.submit(id, vec![TreeApprox::exact(&p.tree); 3]).unwrap();
        let t2 = pool.submit(id, vec![TreeApprox::exact(&p.tree); 2]).unwrap();
        assert_eq!(t1.shard(), Some(0));
        assert_eq!(pool.metrics.tickets_submitted.load(Ordering::Relaxed), 2);
        // Collected out of order: results match the ticket, not FIFO.
        assert_eq!(pool.wait(t2).unwrap(), vec![0.25; 2]);
        assert_eq!(pool.wait(t1).unwrap(), vec![0.25; 3]);
        assert_eq!(pool.metrics.tickets_in_flight.load(Ordering::Relaxed), 0);
        assert_eq!(pool.metrics.tickets_peak.load(Ordering::Relaxed), 2);
        // An empty batch resolves without a ticket ever being issued…
        let t = pool.submit(id, Vec::new()).unwrap();
        assert_eq!(t.shard(), None);
        assert!(pool.wait(t).unwrap().is_empty());
        assert_eq!(pool.metrics.tickets_submitted.load(Ordering::Relaxed), 2);
        // …and an abandoned ticket releases the in-flight gauge on drop.
        let t = pool.submit(id, vec![TreeApprox::exact(&p.tree); 1]).unwrap();
        drop(t);
        assert_eq!(pool.metrics.tickets_in_flight.load(Ordering::Relaxed), 0);
        // Width hint: generic spawns leave it unset; native pools set it.
        assert_eq!(pool.width_hint(), 0);
        let native = EvalShardPool::spawn_native(
            16,
            &PoolOptions { workers: 1, ..PoolOptions::default() },
        );
        assert_eq!(native.width_hint(), 16);
        native.shutdown();
        pool.shutdown();
    }

    #[test]
    fn backend_error_fails_request_and_worker_survives() {
        struct FlakyBackend {
            width: usize,
            fail: Arc<AtomicBool>,
        }
        impl Backend for FlakyBackend {
            fn register(&mut self, _p: &Arc<Problem>) -> Result<RegisteredProblem> {
                Ok(RegisteredProblem::Native { width: self.width })
            }
            fn eval(
                &mut self,
                _reg: &RegisteredProblem,
                _p: &Problem,
                chunk: &[TreeApprox],
            ) -> Result<Vec<f64>> {
                if self.fail.load(Ordering::Relaxed) {
                    Err(anyhow!("injected backend failure"))
                } else {
                    Ok(vec![0.5; chunk.len()])
                }
            }
            fn name(&self) -> &'static str {
                "flaky"
            }
        }

        let fail = Arc::new(AtomicBool::new(true));
        let f = Arc::clone(&fail);
        let pool = EvalShardPool::spawn(1, CoalescePolicy::Off, false, move |_| {
            Ok(Box::new(FlakyBackend { width: 8, fail: Arc::clone(&f) })
                as Box<dyn Backend>)
        })
        .unwrap();
        let p = seeds();
        let (id, _) = pool.register(Arc::clone(&p)).unwrap();
        let batch = vec![TreeApprox::exact(&p.tree); 3];
        let err = pool.eval(id, batch.clone()).unwrap_err();
        assert!(format!("{err}").contains("injected backend failure"), "{err}");
        // An error `Result` is NOT a death: the worker survives and the
        // shard stays live.
        assert!(pool.shard_alive(id.shard()));
        fail.store(false, Ordering::Relaxed);
        assert_eq!(pool.eval(id, batch).unwrap(), vec![0.5; 3]);
        pool.shutdown();
    }

    /// A panicking backend kills only its shard: in-flight work gets a
    /// typed `ShardDown`, survivors keep serving, and registration falls
    /// back to a live shard (rendezvous, not a clamp).
    #[test]
    fn backend_panic_downs_shard_and_registration_falls_back() {
        struct PanicOnEval;
        impl Backend for PanicOnEval {
            fn register(&mut self, _p: &Arc<Problem>) -> Result<RegisteredProblem> {
                Ok(RegisteredProblem::Native { width: 8 })
            }
            fn eval(
                &mut self,
                _reg: &RegisteredProblem,
                _p: &Problem,
                _chunk: &[TreeApprox],
            ) -> Result<Vec<f64>> {
                panic!("injected backend panic");
            }
            fn name(&self) -> &'static str {
                "panic-on-eval"
            }
        }
        struct Ok25 {
            width: usize,
        }
        impl Backend for Ok25 {
            fn register(&mut self, _p: &Arc<Problem>) -> Result<RegisteredProblem> {
                Ok(RegisteredProblem::Native { width: self.width })
            }
            fn eval(
                &mut self,
                _reg: &RegisteredProblem,
                _p: &Problem,
                chunk: &[TreeApprox],
            ) -> Result<Vec<f64>> {
                Ok(vec![0.25; chunk.len()])
            }
            fn name(&self) -> &'static str {
                "ok25"
            }
        }

        let p = seeds();
        let victim = {
            // Find the problem's home shard on a 2-worker pool first.
            let probe = EvalShardPool::spawn(2, CoalescePolicy::Off, false, |_| {
                Ok(Box::new(Ok25 { width: 8 }) as Box<dyn Backend>)
            })
            .unwrap();
            let s = probe.shard_for(&p.name);
            probe.shutdown();
            s
        };
        let pool = EvalShardPool::spawn(2, CoalescePolicy::Off, false, move |shard| {
            if shard == victim {
                Ok(Box::new(PanicOnEval) as Box<dyn Backend>)
            } else {
                Ok(Box::new(Ok25 { width: 8 }) as Box<dyn Backend>)
            }
        })
        .unwrap();

        let (id, _) = pool.register(Arc::clone(&p)).unwrap();
        assert_eq!(id.shard(), victim);
        let batch = vec![TreeApprox::exact(&p.tree); 3];
        let err = pool.eval(id, batch.clone()).unwrap_err();
        assert!(
            matches!(err, ServiceError::ShardDown { shard } if shard == victim),
            "{err:?}"
        );
        assert!(err.is_stale_id(), "clients must heal ShardDown by re-registering");
        assert!(!pool.shard_alive(victim));
        assert_eq!(pool.live_workers(), 1);

        // Later evals against the dead shard fail fast and typed.
        let err = pool.eval(id, batch.clone()).unwrap_err();
        assert!(matches!(err, ServiceError::ShardDown { .. }), "{err:?}");

        // Registration re-routes to the survivor; evals work there.
        let (id2, _) = pool.register(Arc::clone(&p)).unwrap();
        assert_ne!(id2.shard(), victim);
        assert_eq!(pool.eval(id2, batch).unwrap(), vec![0.25; 3]);

        // The dead shard's gauge went back to zero; the death is counted.
        assert_eq!(
            pool.metrics.shards()[victim].queue_depth.load(Ordering::Relaxed),
            0
        );
        assert_eq!(pool.metrics.shard_deaths.load(Ordering::Relaxed), 1);
        pool.shutdown();
    }

    /// A forged id naming a shard the pool never had is rejected before it
    /// can charge any queue-depth gauge (it used to be clamped onto the
    /// last shard).
    #[test]
    fn out_of_range_shard_is_rejected_not_clamped() {
        let chunks = Arc::new(Mutex::new(Vec::new()));
        let c = Arc::clone(&chunks);
        let pool = EvalShardPool::spawn(2, CoalescePolicy::Off, false, move |_| {
            Ok(Box::new(CountingBackend { width: 8, chunks: Arc::clone(&c) })
                as Box<dyn Backend>)
        })
        .unwrap();
        let p = seeds();
        let (id, _) = pool.register(Arc::clone(&p)).unwrap();
        let forged = ProblemId { shard: 7, ..id };
        let err = pool.eval(forged, vec![TreeApprox::exact(&p.tree); 2]).unwrap_err();
        assert!(matches!(err, ServiceError::UnknownProblemId { .. }), "{err:?}");
        assert!(err.is_stale_id());
        for s in pool.metrics.shards() {
            assert_eq!(s.queue_depth.load(Ordering::Relaxed), 0);
            assert_eq!(s.queue_peak.load(Ordering::Relaxed), 0, "no gauge was charged");
        }
        // The real id still works.
        assert_eq!(pool.eval(id, vec![TreeApprox::exact(&p.tree); 2]).unwrap().len(), 2);
        pool.shutdown();
    }

    #[test]
    fn pool_options_resolve_worker_counts() {
        let auto = PoolOptions::default();
        // Auto path: whatever default_threads() says, the documented
        // [1, 64] clamp holds.
        assert!((1..=64).contains(&auto.native_workers()));
        assert_eq!(auto.xla_workers(), 1);
        assert!(!auto.respawn, "respawn is opt-in");
        let fixed = PoolOptions { workers: 4, ..PoolOptions::default() };
        assert_eq!(fixed.native_workers(), 4);
        assert_eq!(fixed.xla_workers(), 4);
        let huge = PoolOptions { workers: 1000, ..PoolOptions::default() };
        assert_eq!(huge.native_workers(), 64);
        assert_eq!(huge.xla_workers(), 64);
    }

    #[test]
    fn coalesce_mode_parses_and_resolves_to_policy() {
        assert_eq!(CoalesceMode::parse("off").unwrap(), CoalesceMode::Off);
        assert_eq!(CoalesceMode::parse("fixed").unwrap(), CoalesceMode::Fixed);
        assert_eq!(CoalesceMode::parse("adaptive").unwrap(), CoalesceMode::Adaptive);
        assert!(CoalesceMode::parse("sometimes").is_err());
        for m in [CoalesceMode::Off, CoalesceMode::Fixed, CoalesceMode::Adaptive] {
            assert_eq!(CoalesceMode::parse(m.as_str()).unwrap(), m, "round-trip");
        }

        // Default options keep the PR 2 behavior: fixed 200us.
        let d = PoolOptions::default();
        assert_eq!(d.coalesce, CoalesceMode::Fixed);
        assert_eq!(d.policy(), CoalescePolicy::Fixed(Duration::from_micros(200)));
        // The pre-policy `--coalesce-window-us 0` contract: fixed+0 = off.
        let zero = PoolOptions { coalesce_window_us: 0, ..PoolOptions::default() };
        assert_eq!(zero.policy(), CoalescePolicy::Off);
        let off = PoolOptions { coalesce: CoalesceMode::Off, ..PoolOptions::default() };
        assert_eq!(off.policy(), CoalescePolicy::Off);
        let ad = PoolOptions {
            coalesce: CoalesceMode::Adaptive,
            coalesce_window_max_us: 750,
            ..PoolOptions::default()
        };
        assert_eq!(
            ad.policy(),
            CoalescePolicy::Adaptive { max: Duration::from_micros(750) }
        );
    }

    #[test]
    fn rendezvous_route_prefers_live_home_then_best_survivor() {
        // Home alive → home, regardless of other deaths.
        let n = 4;
        let home = (fnv1a(b"seeds") % n as u64) as usize;
        let mut alive = vec![true; n];
        assert_eq!(rendezvous_route("seeds", &alive), Some(home));
        for dead in 0..n {
            if dead == home {
                continue;
            }
            let mut a = alive.clone();
            a[dead] = false;
            assert_eq!(rendezvous_route("seeds", &a), Some(home));
        }
        // Home dead → the rendezvous argmax over the survivors.
        alive[home] = false;
        let got = rendezvous_route("seeds", &alive).unwrap();
        assert_ne!(got, home);
        for (s, &ok) in alive.iter().enumerate() {
            if ok {
                assert!(
                    rendezvous_score("seeds", got) >= rendezvous_score("seeds", s),
                    "fallback must be the argmax"
                );
            }
        }
        // All dead / empty → None.
        let all_dead = vec![false; n];
        assert_eq!(rendezvous_route("seeds", &all_dead), None);
        assert_eq!(rendezvous_route("seeds", &[]), None);
    }

    /// Registrations of the same `Arc<Problem>` share a coalescer group:
    /// the backend registers once, both ids evaluate correctly, and — in
    /// adaptive mode — the second driver's queued request triggers the
    /// all-drivers early flush that merges both sub-width batches.
    #[test]
    fn same_arc_registrations_share_group_and_early_flush_merges() {
        use crate::util::clock::ManualClock;

        let registered = Arc::new(Mutex::new(0usize));
        let chunks = Arc::new(Mutex::new(Vec::new()));
        struct OnceBackend {
            width: usize,
            registered: Arc<Mutex<usize>>,
            chunks: Arc<Mutex<Vec<usize>>>,
        }
        impl Backend for OnceBackend {
            fn register(&mut self, _p: &Arc<Problem>) -> Result<RegisteredProblem> {
                *self.registered.lock().unwrap() += 1;
                Ok(RegisteredProblem::Native { width: self.width })
            }
            fn eval(
                &mut self,
                _reg: &RegisteredProblem,
                _p: &Problem,
                chunk: &[TreeApprox],
            ) -> Result<Vec<f64>> {
                self.chunks.lock().unwrap().push(chunk.len());
                Ok(vec![0.25; chunk.len()])
            }
            fn name(&self) -> &'static str {
                "once"
            }
        }

        let clock = Arc::new(ManualClock::new());
        let r = Arc::clone(&registered);
        let c = Arc::clone(&chunks);
        let pool = EvalShardPool::spawn_with_clock(
            1,
            CoalescePolicy::Adaptive { max: Duration::from_micros(1_000_000) },
            false,
            Arc::clone(&clock) as Arc<dyn Clock>,
            move |_| {
                Ok(Box::new(OnceBackend {
                    width: 64,
                    registered: Arc::clone(&r),
                    chunks: Arc::clone(&c),
                }) as Box<dyn Backend>)
            },
        )
        .unwrap();
        let p = seeds();
        let (id_a, _) = pool.register(Arc::clone(&p)).unwrap();
        let (id_b, _) = pool.register(Arc::clone(&p)).unwrap();
        assert_ne!(id_a, id_b);
        assert_eq!(
            *registered.lock().unwrap(),
            1,
            "same-Arc re-registration must not re-upload backend state"
        );

        // Two driver threads, one sub-width batch each: with both drivers
        // queued no more work can arrive, so the worker flushes ONE merged
        // chunk without any clock advance.
        let batch = vec![TreeApprox::exact(&p.tree); 5];
        std::thread::scope(|s| {
            let pa = pool.clone();
            let pb = pool.clone();
            let ba = batch.clone();
            let bb = batch.clone();
            let ha = s.spawn(move || pa.eval(id_a, ba).unwrap());
            let hb = s.spawn(move || pb.eval(id_b, bb).unwrap());
            assert_eq!(ha.join().unwrap(), vec![0.25; 5]);
            assert_eq!(hb.join().unwrap(), vec![0.25; 5]);
        });
        assert_eq!(*chunks.lock().unwrap(), vec![10], "one merged execution");
        assert_eq!(pool.metrics.early_flushes.load(Ordering::Relaxed), 1);
        assert_eq!(pool.metrics.deadline_flushes.load(Ordering::Relaxed), 0);
        assert_eq!(pool.metrics.coalesced_executions.load(Ordering::Relaxed), 1);
        pool.shutdown();
    }
}
