"""AOT compile path: lower the L2 graph to HLO *text* artifacts.

HLO text (NOT ``lowered.compile()``/``.serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the
xla crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly.  Lowered with
``return_tuple=True`` so the rust side unwraps a 1-tuple.

Run once by ``make artifacts`` (skipped when inputs are unchanged):

    cd python && python -m compile.aot --out-dir ../artifacts

Outputs:
    artifacts/dt_eval_<bucket>.hlo.txt   one per shape bucket
    artifacts/meta.json                  shapes + parameter order for rust
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels.dt_infer import TILE_S, mxu_flops, vmem_bytes


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_bucket(name):
    s, n, l, c, p = model.BUCKETS[name]
    shapes = model.input_shapes(s, n, l, c, p)
    return jax.jit(model.dt_eval_accuracy).lower(*shapes)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--buckets", nargs="*", default=list(model.BUCKETS))
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    meta = {"tile_s": TILE_S, "input_names": model.INPUT_NAMES, "buckets": {}}
    for name in args.buckets:
        s, n, l, c, p = model.BUCKETS[name]
        text = to_hlo_text(lower_bucket(name))
        path = os.path.join(args.out_dir, f"dt_eval_{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        meta["buckets"][name] = {
            "s": s, "n": n, "l": l, "c": c, "p": p,
            "file": os.path.basename(path),
            "vmem_bytes_per_step": vmem_bytes(n, l, c),
            "mxu_flops_per_exec": mxu_flops(s, n, l, c, p),
        }
        print(f"[aot] {name}: S={s} N={n} L={l} C={c} P={p} "
              f"-> {path} ({len(text)} chars, "
              f"vmem/step={vmem_bytes(n, l, c)/2**20:.2f} MiB)")

    with open(os.path.join(args.out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=2, sort_keys=True)
    print(f"[aot] wrote {os.path.join(args.out_dir, 'meta.json')}")


if __name__ == "__main__":
    main()
