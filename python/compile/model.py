"""L2: the JAX evaluation graph that is AOT-lowered to artifacts/*.hlo.txt.

The "model" of this paper is a trained decision tree being evaluated under a
population of dual approximations (per-comparator precision + substituted
integer thresholds).  The graph wraps the L1 Pallas kernel
(:mod:`compile.kernels.dt_infer`) with the final accuracy reduction.  All
tree structure arrives as runtime inputs, so one artifact per *shape bucket*
serves every dataset/tree that fits it (padding conventions documented in the
kernel module).

Input order (this IS the PJRT parameter order the rust runtime packs):

  0. xsel   f32[S, N]
  1. labels f32[S]
  2. valid  f32[S]
  3. thr    f32[P, N]
  4. scale  f32[P, N]
  5. wleaf  f32[N, L]
  6. bias   f32[L]
  7. onehot f32[L, C]

Output: 1-tuple (acc f32[P]) -- lowered with return_tuple=True, so the rust
side unwraps with to_tuple1().
"""

import jax
import jax.numpy as jnp

from compile.kernels import dt_infer

#: Shape buckets compiled by aot.py: name -> (S, N, L, C, P).
#: Rust routes each dataset to the smallest bucket that fits and pads.
BUCKETS = {
    "small": (256, 64, 64, 16, 32),
    "medium": (1024, 256, 256, 16, 32),
    "large": (4096, 320, 320, 16, 32),
}

INPUT_NAMES = [
    "xsel", "labels", "valid", "thr", "scale", "wleaf", "bias", "onehot",
]


def input_shapes(s, n, l, c, p):
    """ShapeDtypeStructs in artifact parameter order."""
    f32 = jnp.float32
    return [
        jax.ShapeDtypeStruct((s, n), f32),   # xsel
        jax.ShapeDtypeStruct((s,), f32),     # labels
        jax.ShapeDtypeStruct((s,), f32),     # valid
        jax.ShapeDtypeStruct((p, n), f32),   # thr
        jax.ShapeDtypeStruct((p, n), f32),   # scale
        jax.ShapeDtypeStruct((n, l), f32),   # wleaf
        jax.ShapeDtypeStruct((l,), f32),     # bias
        jax.ShapeDtypeStruct((l, c), f32),   # onehot
    ]


def dt_eval_accuracy(xsel, labels, valid, thr, scale, wleaf, bias, onehot):
    """Accuracy in [0, 1] per chromosome; the AOT entry point."""
    counts = dt_infer.dt_eval_counts(
        xsel, labels, valid, thr, scale, wleaf, bias, onehot
    )
    denom = jnp.maximum(jnp.sum(valid), 1.0)
    return (counts / denom,)


def dt_eval_accuracy_ref(xsel, labels, valid, thr, scale, wleaf, bias, onehot):
    """Same graph over the pure-jnp oracle (test-only, never exported)."""
    from compile.kernels import ref

    counts = ref.dt_eval_counts_ref(
        xsel, labels, valid, thr, scale, wleaf, bias, onehot
    )
    denom = jnp.maximum(jnp.sum(valid), 1.0)
    return (counts / denom,)
