"""L1 Pallas kernel: population-batched oblivious decision-tree inference.

This is the fitness-evaluation hot-spot of the approximate-DT framework: for a
population of P chromosomes (each a dual-approximation of the same trained
tree: per-comparator precision + substituted integer thresholds), evaluate the
quantized tree on S test samples and return the number of correct predictions
per chromosome.

The paper evaluates chromosomes with a per-sample recursive tree walk in
Python.  That formulation is branchy and serial; here the tree is evaluated
*obliviously* so the hot loop is two back-to-back matmuls that map onto the
TPU MXU systolic array (see DESIGN.md "Hardware-Adaptation"):

    cmp[s, i]   = (min(floor(x[s, i] * scale[i]), scale[i] - 1) <= thr[i])
    mis[s, l]   = cmp[s, :] @ wleaf[:, l] + bias[l]      # mismatch count
    active      = (mis == 0)                             # unique per sample
    score[s, c] = active[s, :] @ onehot[:, c]
    correct     = sum(valid * (argmax_c score == label))

Tensor encoding of the tree structure (computed once in rust, passed as
runtime inputs so one artifact serves any tree that fits the shape bucket):

  * ``wleaf[i, l] = mask[i, l] * (1 - 2 * sense[i, l])`` where ``mask`` marks
    comparator *i* on the root path of leaf *l* and ``sense`` is the outcome
    (1 = "take the <=, i.e. left, branch") required to reach *l*.
  * ``bias[l] = sum_i mask[i, l] * sense[i, l]``.  Then ``mis[s, l]`` counts
    path mismatches exactly (small integers, exact in f32), and is zero for
    precisely one leaf per sample.
  * padded comparators: ``wleaf`` row of zeros (thr/scale arbitrary).
  * padded leaves: ``bias[l] >= 1e6`` so they can never activate.
  * padded samples: ``valid = 0``.

Grid/BlockSpec schedule: grid = (S // TILE_S, P), **population innermost**.
Each step loads one sample tile of the pre-gathered feature matrix and one
chromosome's (thr, scale) rows; the two matmuls run at [TILE_S, N] @ [N, L]
and [TILE_S, L] @ [L, C].  The whole correct-count vector [P] is a single
persistent output block accumulated in place.

Why this grid order (the §Perf L1 iteration, EXPERIMENTS.md): with the
population axis innermost, the *large* streamed operand — the [TILE_S, N]
xsel tile — changes only once per P steps, while the per-chromosome rows
(2·N·4 B, ~2.5 KB) stream cheaply.  The original (P, S//TILE_S) order
re-fetched the full S×N matrix once per chromosome: ~P× more HBM traffic
(large bucket: 160 MB vs 7.6 MB per execution on a real TPU).

interpret=True everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls; on a real TPU the same BlockSpecs express the HBM->VMEM
pipeline (VMEM budget per step is reported by ``vmem_bytes``).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Sample-tile height. 128 keeps the [TILE_S, N] x [N, L] matmul MXU-aligned
# (128x128 systolic tiles) and the per-step VMEM footprint under ~1 MiB even
# for the "large" bucket (N = L = 320).
TILE_S = 256


def _dt_eval_kernel(
    xsel_ref,      # [TILE_S, N]  pre-gathered features, in [0, 1]
    labels_ref,    # [TILE_S]     class ids as f32
    valid_ref,     # [TILE_S]     1.0 for real samples, 0.0 for padding
    thr_ref,       # [1, N]       integer thresholds (as f32) of chromosome p
    scale_ref,     # [1, N]       2^bits per comparator of chromosome p
    wleaf_ref,     # [N, L]       mask * (1 - 2 * sense)
    bias_ref,      # [1, L]       sum_i mask * sense (+1e6 on padded leaves)
    onehot_ref,    # [L, C]       leaf -> class one-hot
    out_ref,       # [P]          correct-prediction counts (persistent block)
):
    """One (sample-tile, chromosome) grid step."""
    s_tile = pl.program_id(0)
    p = pl.program_id(1)

    # Zero the whole accumulator vector on the very first step.
    @pl.when((s_tile == 0) & (p == 0))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = xsel_ref[...]
    scale = scale_ref[...]            # [1, N] broadcasts over the tile
    thr = thr_ref[...]

    # Dual approximation, integer-exact in f32 (values < 2^24):
    # quantize the input feature to b bits and compare against the
    # (already substituted) integer threshold.
    xq = jnp.minimum(jnp.floor(x * scale), scale - 1.0)
    cmp = (xq <= thr).astype(jnp.float32)                   # [TILE_S, N]

    # Leaf matching: mismatch count per (sample, leaf) is a matmul.
    # bf16 inputs double MXU throughput on a real TPU and stay exact here
    # (cmp is 0/1, wleaf is -1/0/+1, counts <= tree depth << 256); the
    # accumulator stays f32.
    mis = (
        jnp.dot(
            cmp.astype(jnp.bfloat16),
            wleaf_ref[...].astype(jnp.bfloat16),
            preferred_element_type=jnp.float32,
        )
        + bias_ref[...]
    )                                                        # [TILE_S, L]
    active = (mis == 0.0).astype(jnp.float32)                # [TILE_S, L]

    # Class scores and prediction.
    score = jnp.dot(
        active, onehot_ref[...], preferred_element_type=jnp.float32
    )                                                        # [TILE_S, C]
    pred = jnp.argmax(score, axis=-1).astype(jnp.float32)    # [TILE_S]

    correct = (pred == labels_ref[...]).astype(jnp.float32) * valid_ref[...]
    out_ref[p] += jnp.sum(correct)


def dt_eval_counts(xsel, labels, valid, thr, scale, wleaf, bias, onehot):
    """Correct-prediction counts per chromosome.

    Args:
      xsel:   f32[S, N]  test features pre-gathered per comparator.
      labels: f32[S]     class ids.
      valid:  f32[S]     sample mask.
      thr:    f32[P, N]  integer thresholds per chromosome.
      scale:  f32[P, N]  2^bits per chromosome/comparator.
      wleaf:  f32[N, L]  tree-structure contraction matrix.
      bias:   f32[L]     path-length bias (padded leaves >= 1e6).
      onehot: f32[L, C]  leaf class one-hot.

    Returns:
      f32[P] number of correct predictions among valid samples.
    """
    s, n = xsel.shape
    p, _ = thr.shape
    l, c = onehot.shape
    tile_s = min(TILE_S, s)  # small buckets fit in one tile
    if s % tile_s != 0:
        raise ValueError(f"S={s} must be a multiple of tile_s={tile_s}")

    grid = (s // tile_s, p)  # population innermost: xsel tile reused P times
    bias2 = bias.reshape(1, l)

    return pl.pallas_call(
        _dt_eval_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_s, n), lambda js, ip: (js, 0)),   # xsel
            pl.BlockSpec((tile_s,), lambda js, ip: (js,)),       # labels
            pl.BlockSpec((tile_s,), lambda js, ip: (js,)),       # valid
            pl.BlockSpec((1, n), lambda js, ip: (ip, 0)),        # thr
            pl.BlockSpec((1, n), lambda js, ip: (ip, 0)),        # scale
            pl.BlockSpec((n, l), lambda js, ip: (0, 0)),         # wleaf
            pl.BlockSpec((1, l), lambda js, ip: (0, 0)),         # bias
            pl.BlockSpec((l, c), lambda js, ip: (0, 0)),         # onehot
        ],
        # Single persistent [P] block: accumulated in place every step.
        out_specs=pl.BlockSpec((p,), lambda js, ip: (0,)),
        out_shape=jax.ShapeDtypeStruct((p,), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(xsel, labels, valid, thr, scale, wleaf, bias2, onehot)


def vmem_bytes(n: int, l: int, c: int, tile_s: int = TILE_S) -> int:
    """Estimated VMEM residency of one grid step (all operands f32).

    Used by DESIGN.md/EXPERIMENTS.md to argue the real-TPU schedule fits:
    everything below must sit in the ~16 MiB per-core VMEM simultaneously
    (double-buffered inputs would roughly double the input terms).
    """
    f = 4  # sizeof f32
    return (
        tile_s * n * f      # xsel tile
        + 2 * tile_s * f    # labels + valid
        + 2 * n * f         # thr + scale rows
        + n * l * f         # wleaf
        + l * f             # bias
        + l * c * f         # onehot
        + tile_s * l * f    # mis/active intermediate
        + tile_s * c * f    # score
        + tile_s * f        # pred/correct
    )


def mxu_flops(s: int, n: int, l: int, c: int, p: int) -> int:
    """Total MXU FLOPs for one population evaluation (2 matmuls)."""
    return 2 * p * s * (n * l + l * c)


dt_eval_counts_jit = jax.jit(dt_eval_counts)
