"""Pure-jnp oracle for the population DT-evaluation kernel.

Two independent reference implementations:

  * :func:`dt_eval_counts_ref` -- the same oblivious (matmul) formulation as
    the Pallas kernel, written as plain vectorized jnp.  This is the
    numerical oracle pytest compares the kernel against.
  * :func:`dt_walk_predict` -- a literal per-sample recursive tree walk over
    an explicit node table (the formulation the paper uses in its Python
    framework).  Used by the tests to prove that the *encoding* (wleaf /
    bias / onehot) is faithful to real tree routing, not just that two
    copies of the same algebra agree.
"""

import numpy as np
import jax.numpy as jnp


def quantize(x, scale):
    """b-bit quantization of a [0, 1] feature: min(floor(x*2^b), 2^b-1)."""
    return jnp.minimum(jnp.floor(x * scale), scale - 1.0)


def dt_eval_counts_ref(xsel, labels, valid, thr, scale, wleaf, bias, onehot):
    """Vectorized oracle; same contract as dt_infer.dt_eval_counts."""
    xq = quantize(xsel[None, :, :], scale[:, None, :])       # [P, S, N]
    cmp = (xq <= thr[:, None, :]).astype(jnp.float32)
    mis = jnp.einsum("psn,nl->psl", cmp, wleaf) + bias[None, None, :]
    active = (mis == 0.0).astype(jnp.float32)                # [P, S, L]
    score = jnp.einsum("psl,lc->psc", active, onehot)        # [P, S, C]
    pred = jnp.argmax(score, axis=-1).astype(jnp.float32)    # [P, S]
    correct = (pred == labels[None, :]).astype(jnp.float32) * valid[None, :]
    return jnp.sum(correct, axis=-1)


def dt_walk_predict(node_feat, node_thr_int, node_scale, node_left,
                    node_right, node_leaf_class, x):
    """Recursive tree walk for a single sample (numpy, test-only).

    Node table layout (index 0 = root):
      node_feat[i]       feature index tested at node i (-1 for leaves)
      node_thr_int[i]    integer threshold at node i's precision
      node_scale[i]      2^bits at node i
      node_left/right[i] child indices
      node_leaf_class[i] class id for leaves, -1 otherwise
    Routing rule (sklearn convention, as in the paper):
      go left iff quantize(x[feat]) <= thr_int.
    """
    i = 0
    while node_leaf_class[i] < 0:
        sc = node_scale[i]
        code = min(np.floor(x[node_feat[i]] * sc), sc - 1.0)
        i = node_left[i] if code <= node_thr_int[i] else node_right[i]
    return node_leaf_class[i]


def tree_tensors(node_feat, node_left, node_right, node_leaf_class,
                 n_pad, l_pad, c_pad):
    """Encode an explicit node table into the kernel's tensor format.

    Returns (comp_of_node, wleaf, bias, onehot, comp_feat) where
    comp_of_node maps internal node index -> comparator slot, and
    comp_feat[j] is the feature gathered for comparator slot j.
    """
    internal = [i for i in range(len(node_feat)) if node_leaf_class[i] < 0]
    comp_of_node = {n: j for j, n in enumerate(internal)}
    leaves = [i for i in range(len(node_feat)) if node_leaf_class[i] >= 0]

    wleaf = np.zeros((n_pad, l_pad), np.float32)
    bias = np.full((l_pad,), 1e6, np.float32)
    onehot = np.zeros((l_pad, c_pad), np.float32)

    parent = {}
    for i in internal:
        parent[node_left[i]] = (i, 1)   # sense 1: left = (<= thr) taken
        parent[node_right[i]] = (i, 0)

    def path(leaf):
        steps = []
        cur = leaf
        while cur in parent:
            node, sense = parent[cur]
            steps.append((comp_of_node[node], sense))
            cur = node
        return steps

    for l_idx, leaf in enumerate(leaves):
        b = 0.0
        for j, sense in path(leaf):
            wleaf[j, l_idx] = 1.0 - 2.0 * sense
            b += sense
        bias[l_idx] = b
        onehot[l_idx, int(node_leaf_class[leaf])] = 1.0

    comp_feat = np.zeros((n_pad,), np.int64)
    for n, j in comp_of_node.items():
        comp_feat[j] = node_feat[n]
    return comp_of_node, wleaf, bias, onehot, comp_feat
