"""Kernel-vs-oracle correctness: the CORE signal for the L1/L2 layers.

Three tiers:
  1. hypothesis sweeps of random shapes/populations: pallas kernel ==
     pure-jnp oracle, bit-exact (all arithmetic is integer-exact in f32).
  2. encoding faithfulness: oblivious evaluation == literal per-sample
     recursive tree walk on randomly grown trees.
  3. padding semantics: padded samples/comparators/leaves never change
     results.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import dt_infer, ref
from compile import model


def make_problem(rng, s, n, l, c, p, valid_frac=1.0):
    xsel = rng.random((s, n), dtype=np.float32)
    labels = rng.integers(0, c, s).astype(np.float32)
    valid = (rng.random(s) < valid_frac).astype(np.float32)
    bits = rng.integers(2, 9, (p, n))
    scale = (2.0 ** bits).astype(np.float32)
    thr = np.floor(rng.random((p, n)) * scale).astype(np.float32)
    wleaf, bias, onehot = random_tree_tensors(rng, n, l, c)
    return xsel, labels, valid, thr, scale, wleaf, bias, onehot


def random_tree_tensors(rng, n_pad, l_pad, c_pad):
    """Random binary tree with <= min(n_pad, l_pad - 1) internal nodes."""
    node = grow_random_tree(rng, n_pad, l_pad, c_pad)
    _, wleaf, bias, onehot, _ = ref.tree_tensors(
        node["feat"], node["left"], node["right"], node["leaf_class"],
        n_pad, l_pad, c_pad,
    )
    return wleaf, bias, onehot


def grow_random_tree(rng, n_pad, l_pad, c_pad, n_feat=None):
    """Explicit node-table random tree (for walk-vs-oblivious tests)."""
    n_feat = n_feat or n_pad
    max_internal = int(min(n_pad, l_pad - 1))
    n_internal = int(rng.integers(1, max_internal + 1))
    feat, left, right, leaf_class = [], [], [], []

    def add(internal_budget):
        idx = len(feat)
        if internal_budget[0] > 0 and (len(feat) == 0 or rng.random() < 0.7):
            internal_budget[0] -= 1
            feat.append(int(rng.integers(0, n_feat)))
            left.append(-1); right.append(-1); leaf_class.append(-1)
            l_child = add(internal_budget)
            r_child = add(internal_budget)
            left[idx], right[idx] = l_child, r_child
        else:
            feat.append(-1); left.append(-1); right.append(-1)
            leaf_class.append(int(rng.integers(0, c_pad)))
        return idx

    add([n_internal])
    return {
        "feat": np.array(feat), "left": np.array(left),
        "right": np.array(right), "leaf_class": np.array(leaf_class),
    }


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    s_tiles=st.integers(1, 3),
    n=st.integers(2, 96),
    l_extra=st.integers(1, 32),
    c=st.integers(2, 16),
    p=st.integers(1, 8),
    valid_frac=st.sampled_from([0.5, 0.9, 1.0]),
)
def test_kernel_matches_ref_hypothesis(seed, s_tiles, n, l_extra, c, p, valid_frac):
    rng = np.random.default_rng(seed)
    s = dt_infer.TILE_S * s_tiles
    l = min(n, l_extra) + 1 + int(np.random.default_rng(seed + 1).integers(0, 8))
    prob = make_problem(rng, s, n, l, c, p, valid_frac)
    got = np.asarray(dt_infer.dt_eval_counts(*prob))
    want = np.asarray(ref.dt_eval_counts_ref(*[jnp.asarray(a) for a in prob]))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_oblivious_matches_tree_walk(seed):
    """The tensor encoding routes every sample to the same leaf/class as a
    literal recursive walk with per-node quantization."""
    rng = np.random.default_rng(seed)
    n_pad, l_pad, c_pad = 32, 33, 8
    s = dt_infer.TILE_S
    node = grow_random_tree(rng, n_pad, l_pad, c_pad, n_feat=5)
    comp_of_node, wleaf, bias, onehot, comp_feat = ref.tree_tensors(
        node["feat"], node["left"], node["right"], node["leaf_class"],
        n_pad, l_pad, c_pad,
    )
    n_comp = len(comp_of_node)
    x = rng.random((s, 5), dtype=np.float32)
    bits = rng.integers(2, 9, n_pad)
    scale = (2.0 ** bits).astype(np.float32)
    thr = np.floor(rng.random(n_pad) * scale).astype(np.float32)

    # node-table view of the same approximation
    nt_thr = np.zeros(len(node["feat"]), np.float32)
    nt_scale = np.ones(len(node["feat"]), np.float32)
    for nd, j in comp_of_node.items():
        nt_thr[nd] = thr[j]
        nt_scale[nd] = scale[j]

    walk = np.array([
        ref.dt_walk_predict(node["feat"], nt_thr, nt_scale, node["left"],
                            node["right"], node["leaf_class"], x[i])
        for i in range(s)
    ], dtype=np.float32)

    xsel = x[:, comp_feat]                      # gather per comparator slot
    valid = np.ones(s, np.float32)
    got = np.asarray(dt_infer.dt_eval_counts(
        xsel, walk, valid, thr[None, :], scale[None, :], wleaf, bias, onehot,
    ))
    # labels == walk predictions, so a faithful encoding scores 100%.
    assert got[0] == s, f"oblivious eval disagrees with tree walk: {got[0]}/{s}"


def test_padding_invariance():
    """Adding padded comparators/leaves/samples never changes counts."""
    rng = np.random.default_rng(7)
    s, n, l, c, p = dt_infer.TILE_S, 8, 9, 4, 4
    xsel, labels, valid, thr, scale, wleaf, bias, onehot = make_problem(
        rng, s, n, l, c, p)
    base = np.asarray(dt_infer.dt_eval_counts(
        xsel, labels, valid, thr, scale, wleaf, bias, onehot))

    n2, l2, s2 = n + 8, l + 7, s + dt_infer.TILE_S
    xsel2 = np.zeros((s2, n2), np.float32); xsel2[:s, :n] = xsel
    labels2 = np.zeros(s2, np.float32); labels2[:s] = labels
    valid2 = np.zeros(s2, np.float32); valid2[:s] = valid
    thr2 = np.zeros((p, n2), np.float32); thr2[:, :n] = thr
    scale2 = np.ones((p, n2), np.float32); scale2[:, :n] = scale
    wleaf2 = np.zeros((n2, l2), np.float32); wleaf2[:n, :l] = wleaf
    bias2 = np.full(l2, 1e6, np.float32); bias2[:l] = bias
    onehot2 = np.zeros((l2, c), np.float32); onehot2[:l] = onehot
    padded = np.asarray(dt_infer.dt_eval_counts(
        xsel2, labels2, valid2, thr2, scale2, wleaf2, bias2, onehot2))
    np.testing.assert_array_equal(base, padded)


def test_exactly_one_leaf_active():
    """Structural invariant: every sample activates exactly one leaf."""
    rng = np.random.default_rng(3)
    s, n, l, c, p = dt_infer.TILE_S, 16, 17, 5, 3
    xsel, labels, valid, thr, scale, wleaf, bias, onehot = make_problem(
        rng, s, n, l, c, p)
    xq = np.minimum(np.floor(xsel[None] * scale[:, None]), scale[:, None] - 1)
    cmp = (xq <= thr[:, None]).astype(np.float32)
    mis = np.einsum("psn,nl->psl", cmp, wleaf) + bias[None, None]
    active = (mis == 0).sum(axis=-1)
    assert np.all(active == 1)


def test_quantize_bounds():
    """Quantized code stays in [0, 2^b - 1] even at x == 1.0."""
    for b in range(2, 9):
        sc = np.float32(2.0 ** b)
        xs = np.array([0.0, 1.0, 0.999999, 1e-9, 0.5], np.float32)
        q = np.asarray(ref.quantize(jnp.asarray(xs), sc))
        assert q.min() >= 0.0 and q.max() <= sc - 1


@pytest.mark.parametrize("bucket", list(model.BUCKETS))
def test_bucket_shapes_lowerable(bucket):
    """Every shape bucket traces + lowers (abstract eval only, no compile)."""
    import jax
    s, n, l, c, p = model.BUCKETS[bucket]
    shapes = model.input_shapes(s, n, l, c, p)
    lowered = jax.jit(model.dt_eval_accuracy).lower(*shapes)
    assert lowered is not None


def test_accuracy_normalization():
    """model.dt_eval_accuracy divides by the number of *valid* samples."""
    rng = np.random.default_rng(11)
    s, n, l, c, p = dt_infer.TILE_S, 4, 5, 3, 2
    prob = list(make_problem(rng, s, n, l, c, p, valid_frac=0.5))
    acc = np.asarray(model.dt_eval_accuracy(*prob)[0])
    counts = np.asarray(dt_infer.dt_eval_counts(*prob))
    denom = max(prob[2].sum(), 1.0)
    np.testing.assert_allclose(acc, counts / denom, rtol=1e-6)
