"""AOT lowering path: HLO-text generation, bucket metadata, bf16 exactness."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import aot, model
from compile.kernels import dt_infer, ref


def test_hlo_text_small_bucket():
    text = aot.to_hlo_text(aot.lower_bucket("small"))
    assert "ENTRY" in text
    assert "HloModule" in text
    # 8 parameters (xsel..onehot), in artifact order.
    for i in range(8):
        assert f"parameter({i})" in text, f"missing parameter {i}"
    # Tuple return (rust unwraps with to_tuple1).
    assert "tuple(" in text.lower() or "(f32[32]" in text


def test_bucket_meta_math():
    for name, (s, n, l, c, p) in model.BUCKETS.items():
        vb = dt_infer.vmem_bytes(n, l, c)
        assert vb < 16 * 2**20, f"{name}: VMEM/step {vb} exceeds 16 MiB budget"
        flops = dt_infer.mxu_flops(s, n, l, c, p)
        assert flops > 0
        assert s % min(dt_infer.TILE_S, s) == 0


def test_bf16_matmul_exactness_deep_paths():
    """Mismatch counts (<= tree depth) are exact in bf16: build a worst-case
    deep chain (path length 64) and verify kernel == f32 oracle."""
    rng = np.random.default_rng(0)
    s, n, l, c, p = min(dt_infer.TILE_S, 128), 64, 65, 4, 2
    s = dt_infer.TILE_S  # one tile
    # One long chain: leaf l on path of all comparators 0..l-1.
    wleaf = np.zeros((n, l), np.float32)
    bias = np.full(l, 1e6, np.float32)
    onehot = np.zeros((l, c), np.float32)
    for leaf in range(l):
        depth = min(leaf + 1, n)
        for j in range(depth):
            sense = 1 if j < depth - 1 or leaf == l - 1 else 0
            wleaf[j, leaf] = 1.0 - 2.0 * sense
        bias[leaf] = np.sum(wleaf[:, leaf] == -1.0)
    # Not a consistent tree necessarily, but exercises large counts; compare
    # kernel vs f32 reference exactly.
    xsel = rng.random((s, n), dtype=np.float32)
    labels = rng.integers(0, c, s).astype(np.float32)
    valid = np.ones(s, np.float32)
    bits = rng.integers(2, 9, (p, n))
    scale = (2.0 ** bits).astype(np.float32)
    thr = np.floor(rng.random((p, n)) * scale).astype(np.float32)
    got = np.asarray(dt_infer.dt_eval_counts(
        xsel, labels, valid, thr, scale, wleaf, bias, onehot))
    want = np.asarray(ref.dt_eval_counts_ref(
        xsel, labels, valid, thr, scale,
        jnp.asarray(wleaf), jnp.asarray(bias), jnp.asarray(onehot)))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("bucket", list(model.BUCKETS))
def test_meta_written_fields(tmp_path, bucket):
    import json
    import subprocess
    import sys
    # Re-running the full aot per bucket is slow; emulate main()'s metadata
    # for one bucket directly.
    s, n, l, c, p = model.BUCKETS[bucket]
    meta = {
        "s": s, "n": n, "l": l, "c": c, "p": p,
        "vmem_bytes_per_step": dt_infer.vmem_bytes(n, l, c),
        "mxu_flops_per_exec": dt_infer.mxu_flops(s, n, l, c, p),
    }
    out = tmp_path / "m.json"
    out.write_text(json.dumps(meta))
    back = json.loads(out.read_text())
    assert back["s"] == s and back["p"] == p
