//! Fixture harness: every file in `tests/fixtures/` is a self-describing
//! lint case.
//!
//! Header directives (ordinary `//@` comments, invisible to the lexer's
//! rule matching):
//!
//! * `//@ path: <repo-relative path>` — the virtual path the snippet is
//!   linted under (this is what selects the rule scope);
//! * `//@ expect: <rule>@<line>` — one expected diagnostic on a full run
//!   (all rules active); repeatable; omit entirely for a clean fixture;
//! * `//@ partial: <rule>[,<rule>...]` — additionally run with only these
//!   rules active and assert `//@ expect-partial:` entries (none = clean).
//!
//! The assertion is exact: the multiset of (rule, line) pairs must match,
//! so a fixture catches both missed violations and spurious ones.

use std::fs;
use std::path::PathBuf;

use axdt_lint::lint_source;

#[derive(Debug, Default)]
struct Fixture {
    path: String,
    expect: Vec<(String, u32)>,
    partial: Option<Vec<String>>,
    expect_partial: Vec<(String, u32)>,
}

fn parse_fixture(src: &str, name: &str) -> Fixture {
    let mut fx = Fixture::default();
    for line in src.lines() {
        let Some(directive) = line.strip_prefix("//@ ") else { continue };
        if let Some(p) = directive.strip_prefix("path: ") {
            fx.path = p.trim().to_string();
        } else if let Some(e) = directive.strip_prefix("expect: ") {
            fx.expect.push(parse_expect(e, name));
        } else if let Some(e) = directive.strip_prefix("expect-partial: ") {
            fx.expect_partial.push(parse_expect(e, name));
        } else if let Some(r) = directive.strip_prefix("partial: ") {
            fx.partial = Some(r.split(',').map(|s| s.trim().to_string()).collect());
        } else {
            panic!("{name}: unknown fixture directive `//@ {directive}`");
        }
    }
    assert!(!fx.path.is_empty(), "{name}: missing `//@ path:` directive");
    fx
}

fn parse_expect(spec: &str, name: &str) -> (String, u32) {
    let (rule, line) = spec
        .trim()
        .split_once('@')
        .unwrap_or_else(|| panic!("{name}: expect directive `{spec}` is not <rule>@<line>"));
    let line: u32 = line
        .parse()
        .unwrap_or_else(|_| panic!("{name}: bad line number in expect `{spec}`"));
    (rule.to_string(), line)
}

fn check(name: &str, fx_path: &str, src: &str, active: &[&str], want: &[(String, u32)]) {
    let got: Vec<(String, u32)> = lint_source(fx_path, src, active)
        .into_iter()
        .map(|d| (d.rule.to_string(), d.line))
        .collect();
    let mut want: Vec<(String, u32)> = want.to_vec();
    let mut got_sorted = got.clone();
    want.sort();
    got_sorted.sort();
    assert_eq!(
        got_sorted, want,
        "{name} (active={active:?}): diagnostics mismatch\nfull output:\n{}",
        lint_source(fx_path, src, active)
            .iter()
            .map(|d| format!("  {d}"))
            .collect::<Vec<_>>()
            .join("\n")
    );
}

#[test]
fn fixtures() {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures");
    let mut entries: Vec<PathBuf> = fs::read_dir(&dir)
        .expect("tests/fixtures exists")
        .map(|e| e.expect("readable dir entry").path())
        .filter(|p| p.extension().is_some_and(|x| x == "rs"))
        .collect();
    entries.sort();
    assert!(
        entries.len() >= 16,
        "expected the full fixture set, found {}",
        entries.len()
    );

    for path in entries {
        let name = path.file_name().unwrap().to_string_lossy().to_string();
        let src = fs::read_to_string(&path).expect("fixture readable");
        let fx = parse_fixture(&src, &name);

        check(&name, &fx.path, &src, &[], &fx.expect);

        if let Some(partial) = &fx.partial {
            let active: Vec<&str> = partial.iter().map(|s| s.as_str()).collect();
            check(&name, &fx.path, &src, &active, &fx.expect_partial);
        }
    }
}

/// Every registered rule must be exercised by at least one seeded
/// violation across the fixture set — a rule nobody can trip is dead.
#[test]
fn every_rule_has_a_seeded_fixture() {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures");
    let mut seeded: Vec<String> = Vec::new();
    for entry in fs::read_dir(&dir).expect("tests/fixtures exists") {
        let path = entry.expect("readable dir entry").path();
        if !path.extension().is_some_and(|x| x == "rs") {
            continue;
        }
        let src = fs::read_to_string(&path).expect("fixture readable");
        let name = path.file_name().unwrap().to_string_lossy().to_string();
        let fx = parse_fixture(&src, &name);
        seeded.extend(fx.expect.iter().map(|(r, _)| r.clone()));
    }
    for (rule, _) in axdt_lint::ALL_RULES {
        assert!(
            seeded.iter().any(|r| r == rule),
            "rule `{rule}` has no seeded fixture violation"
        );
    }
}
