//@ path: rust/src/util/clock.rs

// The clock module is the one place allowed to touch the OS clock: it is
// the seam's implementation, so nothing here may fire.

pub fn now_ns_impl() -> u64 {
    let epoch = Instant::now();
    epoch.elapsed().as_nanos() as u64
}

pub fn park(d: Duration) {
    std::thread::sleep(d);
}
