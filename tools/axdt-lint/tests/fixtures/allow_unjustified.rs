//@ path: rust/src/util/bench.rs
//@ expect: clock-seam@9
//@ expect: bad-allow@8

// An allow with no justification does NOT suppress, even right above the
// violation: both the original diagnostic and a bad-allow fire.
fn stamp() -> Instant {
    // axdt-lint: allow(clock-seam)
    Instant::now()
}
