//@ path: rust/src/util/bench.rs

// A justified allow suppresses the diagnostic, whether it sits on the
// flagged line or on the line directly above.

fn measure(f: impl Fn()) -> f64 {
    // axdt-lint: allow(clock-seam): bench harness measures real wall time
    let t0 = Instant::now();
    f();
    let t1 = Instant::now(); // axdt-lint: allow(clock-seam): wall-time endpoint of the measured span
    span_secs(t0, t1)
}
