//@ path: rust/src/coordinator/shard.rs
//@ expect: panic-free-workers@8
//@ expect: panic-free-workers@9
//@ expect: panic-free-workers@11

fn worker_loop(rx: Receiver<Job>) {
    // job.reply.unwrap() in a comment must not fire.
    let job = rx.recv().unwrap();
    let out = job.run().expect("job must succeed");
    if out.is_empty() {
        panic!("empty result");
    }
    let err = "panic! in a log string must not fire: x.unwrap()";
    let _ = err;
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_is_fine_in_tests() {
        let v = make_pool().submit(1, &batch).unwrap();
        assert!(!v.is_empty(), "got {v:?}");
    }
}
