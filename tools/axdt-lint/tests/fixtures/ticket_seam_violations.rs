//@ path: rust/src/fitness/mod.rs
//@ expect: ticket-seam@9
//@ expect: ticket-seam@10
//@ expect: ticket-seam@11
//@ expect: ticket-seam@12

fn score(pool: &ShardPool, svc: &Service, trees: &[Tree]) -> Vec<f32> {
    // pool.eval( in this comment must not fire.
    let a = pool.eval(&trees[0]);
    let b = svc.eval(&trees[1]);
    let c = self.pool().eval(&trees[2]);
    let d = backend.eval_typed(&trees[3]);
    let msg = "service.eval(batch) is the blocking adapter";
    let tree_val = tree.eval(&x);
    vec![a, b, c, d, tree_val, msg.len() as f32]
}

#[cfg(test)]
mod tests {
    #[test]
    fn blocking_baseline_is_fine_in_tests() {
        let _ = pool.eval(&tree);
    }
}
