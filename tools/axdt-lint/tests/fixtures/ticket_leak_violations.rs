//@ path: rust/src/coordinator/driver.rs
//@ expect: ticket-leak@12
//@ expect: ticket-leak@18
//@ partial: ticket-leak
//@ expect-partial: ticket-leak@12
//@ expect-partial: ticket-leak@18

// Two leaks: a plainly forgotten ticket and a stored-and-forgotten one.

fn fire_and_forget(pool: &EvalShardPool, id: ProblemId, batch: Batch) {
    // pool.submit(id, batch) in a comment must not fire.
    let ticket = pool.submit(id, batch);
}

fn stash(pool: &EvalShardPool, id: ProblemId, batches: Vec<Batch>) {
    let mut parked = Vec::new();
    for batch in batches {
        let t = pool.submit(id, batch);
        parked.push(t);
    }
}

fn pipelined(pool: &EvalShardPool, id: ProblemId, batches: Vec<Batch>) -> Vec<f32> {
    let mut tickets = Vec::new();
    for batch in batches {
        let t = pool.submit(id, batch);
        tickets.push(t);
    }
    let mut out = Vec::new();
    for t in tickets {
        out.extend(pool.wait(t));
    }
    out
}

fn handoff(pool: &EvalShardPool, id: ProblemId, batch: Batch) -> AccuracyTicket {
    let t = pool.submit(id, batch);
    t
}

fn relabel(pool: &EvalShardPool, id: ProblemId, batch: Batch) -> Vec<f32> {
    let t = pool.submit(id, batch);
    let moved = t;
    pool.wait(moved)
}

fn cancel(pool: &EvalShardPool, id: ProblemId, batch: Batch) {
    // axdt-lint: allow(ticket-leak): cancellation drops the in-flight batch on purpose
    let t = pool.submit(id, batch);
    drop(t);
}
