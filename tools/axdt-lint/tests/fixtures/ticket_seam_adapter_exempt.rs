//@ path: rust/src/coordinator/service.rs

// The facade IS the documented blocking adapter: `eval` here wraps
// submit+wait, so the rule is scoped out of this file entirely.

impl Service {
    pub fn eval(&self, batch: &Batch) -> Result<Vec<f32>, ServiceError> {
        let ticket = self.pool.submit(self.next_id(), batch)?;
        self.pool.wait(ticket)
    }

    fn baseline(&self, batch: &Batch) -> Result<Vec<f32>, ServiceError> {
        let svc = self;
        svc.eval(batch)
    }
}
