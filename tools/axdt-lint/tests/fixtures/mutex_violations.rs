//@ path: rust/src/util/pool.rs
//@ expect: mutex-discipline@8
//@ expect: mutex-discipline@9

fn drain(slots: &Mutex<Vec<Slot>>) -> Option<Slot> {
    // state.lock().unwrap() in a comment must not fire.
    let doc = ".lock().unwrap() in a string must not fire";
    let mut guard = slots.lock().unwrap();
    let n = COUNTER.lock().expect("counter mutex");
    let ok = lock_recover(slots).pop();
    let _ = (doc, n);
    ok
}

#[cfg(test)]
mod tests {
    #[test]
    fn raw_lock_is_fine_in_tests() {
        let g = m.lock().unwrap();
        drop(g);
    }
}
