//@ path: rust/src/util/pool.rs
//@ expect: mutex-discipline@12
//@ expect: mutex-discipline@13
//@ expect: mutex-discipline@15

// All acquisitions keep one order (slots before COUNTER), so only the
// mutex-discipline spellings fire — never lock-order.

fn drain(slots: &Mutex<Vec<Slot>>) -> Option<Slot> {
    // state.lock().unwrap() in a comment must not fire.
    let doc = ".lock().unwrap() in a string must not fire";
    let mut guard = slots.lock().unwrap();
    let again = slots.lock().unwrap_or_else(|e| e.into_inner());
    let ok = lock_recover(slots).pop();
    let n = COUNTER.lock().expect("counter mutex");
    let _ = (doc, guard, again, n);
    ok
}

#[cfg(test)]
mod tests {
    #[test]
    fn raw_lock_is_fine_in_tests() {
        let g = m.lock().unwrap();
        drop(g);
    }
}
