//@ path: rust/src/fitness/cache.rs
//@ expect: clock-seam@17
//@ partial: clock-seam
//@ expect-partial: clock-seam@17

// The tiered eval cache sits behind the Clock seam: lookup/publish
// timestamps arrive as `ts_ns` arguments from the injected clock, so the
// cache itself may never read the OS clock — not even for "cheap" latency
// accounting on the L2 load path, where a stray wall read would taint the
// trace journal's byte-identity on the ManualClock.

pub fn record_lookup(ts_ns: u64, journal: &mut Vec<u64>) {
    journal.push(ts_ns);
}

pub fn load_segment_timed(records: u64) -> u64 {
    let _t0 = std::time::Instant::now();
    records
}
