//@ path: rust/src/coordinator/driver.rs
//@ expect: clock-seam@8
//@ expect: clock-seam@9
//@ expect: clock-seam@12

fn run() {
    // Instant::now() in this comment must not fire.
    let t0 = Instant::now();
    let wall = std::time::SystemTime::now();
    let s = "thread::sleep(Duration::from_secs(5))";
    let _ = (t0, wall, s);
    std::thread::sleep(std::time::Duration::from_millis(5));
}

#[cfg(test)]
mod tests {
    #[test]
    fn wall_time_is_fine_in_tests() {
        let _t = Instant::now();
        thread::sleep(Duration::from_millis(1));
    }
}
