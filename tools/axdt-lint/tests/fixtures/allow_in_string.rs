//@ path: rust/src/util/pool.rs
//@ expect: mutex-discipline@9

// A suppression spelled inside a string literal is data, not a
// comment: the violation on the next line must still fire.

fn doc_and_drain(slots: &Mutex<Vec<Slot>>) -> Option<Slot> {
    let advice = "// axdt-lint: allow(mutex-discipline): only real comments suppress";
    let mut g = slots.lock().unwrap();
    let _ = advice;
    g.pop()
}
