//@ path: rust/src/util/pool.rs
//@ expect: mutex-discipline@14

// Raw strings with hash guards are literals: the documentation text
// below contains `.lock().unwrap()` and an embedded `"#`, and the
// lexer must skip it exactly and resume — the real violation after
// it must still fire.

fn help() -> &'static str {
    r##"never write slots.lock().unwrap() — "# embedded — use lock_recover"##
}

fn drain(slots: &Mutex<Vec<Slot>>) -> Option<Slot> {
    slots.lock().unwrap().pop()
}
