//@ path: rust/src/util/trace.rs
//@ expect: clock-seam@16
//@ partial: clock-seam
//@ expect-partial: clock-seam@16

// The trace journal is NOT clock-exempt: events are stamped by their
// call sites through the injected Clock (that is what makes the journal
// bit-reproducible on a ManualClock), so a journal that reads the OS
// clock itself must fire.

pub fn record_ok(ring: &mut Vec<(u64, u32)>, ts_ns: u64, shard: u32) {
    ring.push((ts_ns, shard)); // timestamp passed IN: clean
}

pub fn record_bad(ring: &mut Vec<(u64, u32)>, shard: u32) {
    ring.push((Instant::now().elapsed().as_nanos() as u64, shard));
}

#[cfg(test)]
mod tests {
    #[test]
    fn wall_time_is_fine_in_tests() {
        let _t = Instant::now();
    }
}
