//@ path: rust/src/dt/train.rs
//@ expect: bad-allow@9
//@ partial: mutex-discipline

// An allow naming a rule that does not exist is flagged on full runs,
// but a partial run (--rule mutex-discipline) stays silent: it cannot
// tell a typo from a rule it was asked not to load.

// axdt-lint: allow(clock-seams): close but wrong rule id
fn train() {}
